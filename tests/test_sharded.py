"""Sharded query plans: partitioning, stacked execution, churn.

PR-level contract: for every registered engine, ``filter_batch_sharded``
over {1, 2, 4} parts is bit-identical to the unsharded ``filter_batch``
and to the oracle; a random subscribe/unsubscribe sequence keeps a
``ShardedPlan``'s verdicts equal to a from-scratch compile of the final
query set.

Run under ``XLA_FLAGS=--xla_force_host_platform_device_count=4`` (the CI
multi-device step) the same tests exercise the real ``shard_map`` path
with a >1-device mesh; single-device runs cover the vmap fallback.
"""
import numpy as np
import pytest

from _hypothesis_shim import given, settings, st

from repro.core import engines
from repro.core.area import SCENARIOS, area_report, area_report_sharded
from repro.core.dictionary import TagDictionary
from repro.core.engines.matscan import exact_class
from repro.core.engines.oracle import filter_document as oracle_filter
from repro.core.events import EventBatch, ByteBatch, encode_bytes
from repro.core.nfa import compile_queries, pad_states, partition_queries
from repro.core.xpath import parse
from repro.data.filter_stage import FilterStage
from repro.data.generator import DTD, gen_corpus, gen_document, gen_profiles
from repro.launch.mesh import make_filter_mesh, make_host_mesh

ALL_ENGINES = ("levelwise", "matscan", "oracle", "streaming", "wavefront",
               "yfilter")


def _workload(engine: str, seed: int = 0, n_docs: int = 5, n_queries: int = 18):
    """Profiles + docs valid for ``engine`` (matscan: descendant-only
    concrete-tag profiles on exact-class documents)."""
    dtd = DTD.generate(n_tags=24, seed=seed)
    d = TagDictionary()
    dtd.register(d)
    if engine == "matscan":
        profiles = gen_profiles(dtd, n=n_queries, length=3, p_desc=1.0,
                                p_wild=0.0, seed=seed)
        docs = [doc for i in range(40 * n_docs)
                if exact_class(doc := gen_document(dtd, target_nodes=20,
                                                   max_depth=4,
                                                   seed=seed + i))][:n_docs]
        assert len(docs) == n_docs, "not enough exact-class documents"
    else:
        profiles = gen_profiles(dtd, n=n_queries, length=3, p_desc=0.4,
                                p_wild=0.15, seed=seed)
        docs = gen_corpus(dtd, n_docs=n_docs, nodes_per_doc=60, seed=seed)
    return profiles, docs, d


# -------------------------------------------------------------- partitioning
class TestPartitionQueries:
    def _parts(self, n_parts, n=20, seed=0):
        dtd = DTD.generate(n_tags=24, seed=seed)
        d = TagDictionary()
        dtd.register(d)
        qs = gen_profiles(dtd, n=n, length=3, seed=seed)
        return qs, *partition_queries(qs, n_parts, d)

    def test_round_trip_mapping(self):
        qs, parts, part = self._parts(3)
        assert part.n_parts == 3
        assert part.n_global == len(qs)
        assert part.n_live == len(qs)
        for gid in range(len(qs)):
            p, c = part.lookup(gid)
            assert parts[p].queries[c] == qs[gid]

    def test_partition_is_balanced(self):
        qs, parts, part = self._parts(4, n=40)
        sizes = part.part_sizes()
        assert sizes.sum() == 40
        # greedy packing cannot be off by more than one prefix group
        group_sizes: dict = {}
        for q in qs:
            key = (q.steps[0].axis, q.steps[0].tag)
            group_sizes[key] = group_sizes.get(key, 0) + 1
        assert sizes.max() - sizes.min() <= max(group_sizes.values())

    def test_shared_prefix_groups_stay_together(self):
        qs, parts, part = self._parts(4, n=40)
        group_part = {}
        for gid, q in enumerate(qs):
            key = (q.steps[0].axis, q.steps[0].tag)
            p = int(part.part_of[gid])
            assert group_part.setdefault(key, p) == p, \
                "prefix group split across parts"

    def test_all_tags_registered_uniformly(self):
        qs, parts, part = self._parts(3)
        assert len({nfa.n_tags for nfa in parts}) == 1

    def test_n_parts_validation(self):
        with pytest.raises(ValueError, match="n_parts"):
            self._parts(0)

    def test_more_parts_than_groups_leaves_empty_parts_working(self):
        d = TagDictionary()
        qs = [parse("a//b"), parse("a/c")]  # one prefix group
        parts, part = partition_queries(qs, 3, d)
        assert part.part_sizes().sum() == 2
        assert sum(nfa.n_queries == 0 for nfa in parts) == 2


# ------------------------------------------------------------- pad threading
class TestPadStates:
    def test_pad_to_exact(self):
        d = TagDictionary.build(["a", "b"])
        nfa = compile_queries([parse("a//b")], d)
        assert pad_states(nfa, to=nfa.n_states).n_states == nfa.n_states
        assert pad_states(nfa, to=50).n_states == 50
        with pytest.raises(ValueError):
            pad_states(nfa, to=1)

    def test_engine_threads_state_multiple(self):
        """The pad multiple comes from the engine, not a hard-coded 128:
        a small profile set on a lane-8 engine stays small."""
        d = TagDictionary.build(["a", "b"])
        nfa = compile_queries([parse("a//b")], d)
        small = engines.create("levelwise", nfa, dictionary=d,
                               state_multiple=8)
        big = engines.create("levelwise", nfa, dictionary=d)
        assert small.plan_.meta["state_multiple"] == 8
        assert small.plan_.meta["n_states"] == 8
        assert big.plan_.meta["n_states"] == 128
        profiles, docs, dd = _workload("levelwise", seed=2)
        nfa2 = compile_queries(profiles, dd, shared=True)
        a = engines.create("levelwise", nfa2, dictionary=dd,
                           state_multiple=8)
        b = engines.create("levelwise", nfa2, dictionary=dd)
        batch = EventBatch.from_streams(docs, bucket=32)
        ra, rb = a.filter_batch(batch), b.filter_batch(batch)
        np.testing.assert_array_equal(ra.matched, rb.matched)

    def test_streaming_rejects_unpacked_multiple(self):
        d = TagDictionary.build(["a", "b"])
        nfa = compile_queries([parse("a//b")], d)
        with pytest.raises(ValueError, match="multiple of 32"):
            engines.create("streaming", nfa, dictionary=d, state_multiple=8)


# ----------------------------------------------------------------- the mesh
class TestMesh:
    def test_make_host_mesh_raises_value_error(self):
        import jax
        n = len(jax.devices())
        with pytest.raises(ValueError, match=f"{n} devices"):
            make_host_mesh(n + 1)

    def test_make_filter_mesh_axes(self):
        """Default mesh: every device on "model", a degenerate data axis
        (the 2-D composition is tested in tests/test_mesh2d.py)."""
        mesh = make_filter_mesh()
        assert tuple(mesh.axis_names) == ("data", "model")
        assert dict(mesh.shape)["data"] == 1

    def test_make_filter_mesh_divides_parts(self):
        import jax
        mesh = make_filter_mesh(3)  # 3 parts always placeable
        assert 3 % dict(mesh.shape)["model"] == 0
        assert dict(make_filter_mesh(
            len(jax.devices())).shape)["model"] == len(jax.devices())


# ------------------------------------------- sharded-vs-unsharded equivalence
class TestShardedEquivalence:
    """Acceptance: every engine, {1,2,4} parts, bit-identical to the
    unsharded batched path and to the per-document oracle."""

    @pytest.mark.parametrize("name", ALL_ENGINES)
    @pytest.mark.parametrize("n_parts", [1, 2, 4])
    def test_sharded_equals_unsharded_and_oracle(self, name, n_parts):
        profiles, docs, d = _workload(name, seed=1)
        nfa = compile_queries(profiles, d, shared=True)
        eng = engines.create(name, nfa, dictionary=d)
        batch = EventBatch.from_streams(docs, bucket=32)
        want = eng.filter_batch(batch)
        sp = eng.plan_sharded(n_parts)
        got = eng.filter_batch_sharded(batch, sp)
        np.testing.assert_array_equal(got.matched, want.matched,
                                      err_msg=f"{name}/{n_parts} matched")
        np.testing.assert_array_equal(got.first_event, want.first_event,
                                      err_msg=f"{name}/{n_parts} location")
        for i, doc in enumerate(docs):
            ref = oracle_filter(nfa, doc, d)
            np.testing.assert_array_equal(got[i].matched, ref.matched,
                                          err_msg=f"{name}/{n_parts} oracle")

    @pytest.mark.parametrize("name", ("streaming", "levelwise", "wavefront",
                                      "matscan"))
    def test_sharded_over_mesh(self, name):
        """shard_map path: parts spread over the mesh "model" axis (with
        XLA_FLAGS=--xla_force_host_platform_device_count=4 this runs on
        a real 4-device mesh; single-device runs still cross shard_map)."""
        profiles, docs, d = _workload(name, seed=4)
        nfa = compile_queries(profiles, d, shared=True)
        eng = engines.create(name, nfa, dictionary=d)
        batch = EventBatch.from_streams(docs, bucket=32)
        want = eng.filter_batch(batch)
        mesh = make_filter_mesh(4)
        sp = eng.plan_sharded(4)
        got = eng.filter_batch_sharded(batch, sp, mesh=mesh)
        np.testing.assert_array_equal(got.matched, want.matched)
        np.testing.assert_array_equal(got.first_event, want.first_event)

    def test_sharded_bytes_path(self):
        profiles, docs, d = _workload("streaming", seed=3)
        nfa = compile_queries(profiles, d, shared=True)
        eng = engines.create("streaming", nfa, dictionary=d)
        sp = eng.plan_sharded(2)
        bb = ByteBatch.from_buffers(
            [encode_bytes(x, text_fill=8) for x in docs], bucket=1024)
        got = eng.filter_bytes_sharded(bb, sp)
        want = eng.filter_batch(EventBatch.from_streams(docs, bucket=128))
        np.testing.assert_array_equal(got.matched, want.matched)

    def test_mesh_part_mismatch_raises(self):
        import jax
        if len(jax.devices()) == 1:
            pytest.skip("needs >1 device for an indivisible mesh")
        profiles, docs, d = _workload("streaming", seed=0)
        nfa = compile_queries(profiles, d, shared=True)
        eng = engines.create("streaming", nfa, dictionary=d)
        sp = eng.plan_sharded(3)
        mesh = make_filter_mesh()  # all devices
        if 3 % dict(mesh.shape)["model"] == 0:
            pytest.skip("device count divides 3")
        with pytest.raises(ValueError, match="not divisible"):
            eng.filter_batch_sharded(
                EventBatch.from_streams(docs), sp, mesh=mesh)


# ----------------------------------------------------------- churn semantics
def _fresh_verdict(engine, queries, d, batch):
    nfa = compile_queries(list(queries), d, shared=True)
    eng = engines.create(engine, nfa, dictionary=d)
    return eng.filter_batch(batch)


class TestChurn:
    def _setup(self, engine="streaming", seed=0, n=16):
        profiles, docs, d = _workload(engine, seed=seed, n_queries=n)
        pool = gen_profiles(DTD.generate(n_tags=24, seed=seed), n=40,
                            length=3, p_desc=0.4, p_wild=0.15,
                            seed=seed + 31)
        nfa = compile_queries(profiles, d, shared=True)
        eng = engines.create(engine, nfa, dictionary=d)
        batch = EventBatch.from_streams(docs, bucket=32)
        return eng, eng.plan_sharded(4), pool, d, batch

    def test_add_recompiles_one_part(self):
        eng, sp, pool, d, batch = self._setup()
        sp2, gids = sp.add_queries(pool[:2])
        assert len(gids) == 2
        # only the least-loaded part's plan object changed (no re-pad)
        changed = [i for i, (a, b) in enumerate(zip(sp.plans, sp2.plans))
                   if a is not b]
        if sp2.pads == sp.pads:
            assert len(changed) == 1
        res = eng.filter_batch_sharded(batch, sp2)
        want = _fresh_verdict("streaming", sp2.live_queries(), d, batch)
        np.testing.assert_array_equal(res.matched, want.matched)

    def test_churn_with_hot_stacked_cache(self):
        """Filtering before churn populates the cached stacked tables;
        adds must update them incrementally (one row overwritten) and
        removals carry them over — verdicts stay equal to fresh compile."""
        eng, sp, pool, d, batch = self._setup()
        eng.filter_batch_sharded(batch, sp)  # hot cache
        assert sp._stacked is not None
        sp2, _ = sp.add_queries(pool[:1])
        if sp2.pads == sp.pads:
            assert sp2._stacked is not None, "add must restack incrementally"
        res = eng.filter_batch_sharded(batch, sp2)
        want = _fresh_verdict("streaming", sp2.live_queries(), d, batch)
        np.testing.assert_array_equal(res.matched, want.matched)
        np.testing.assert_array_equal(res.first_event, want.first_event)
        sp3 = sp2.remove_queries([int(sp2.live_ids()[0])])
        assert sp3._stacked is sp2._stacked, "remove must not restack"
        res3 = eng.filter_batch_sharded(batch, sp3)
        want3 = _fresh_verdict("streaming", sp3.live_queries(), d, batch)
        np.testing.assert_array_equal(res3.matched, want3.matched)

    def test_remove_is_metadata_only(self):
        eng, sp, pool, d, batch = self._setup()
        sp2 = sp.remove_queries([3, 7])
        assert all(a is b for a, b in zip(sp.plans, sp2.plans)), \
            "remove must not recompile any part"
        assert sp2.n_queries == sp.n_queries - 2
        res = eng.filter_batch_sharded(batch, sp2)
        want = _fresh_verdict("streaming", sp2.live_queries(), d, batch)
        np.testing.assert_array_equal(res.matched, want.matched)
        np.testing.assert_array_equal(res.first_event, want.first_event)

    def test_remove_unknown_raises(self):
        _, sp, _, _, _ = self._setup()
        with pytest.raises(KeyError):
            sp.remove_queries([999])
        sp2 = sp.remove_queries([0])
        with pytest.raises(KeyError):
            sp2.remove_queries([0])  # double-unsubscribe

    def test_tombstone_reclaimed_on_next_add(self):
        _, sp, pool, _, _ = self._setup()
        # remove from the currently smallest part → it is strictly the
        # least loaded, so the next add recompiles it and compacts
        p = int(np.argmin(sp.part_sizes()))
        gid = next(int(g) for g in sp.live_ids()
                   if int(sp.partition.part_of[g]) == p)
        sp2 = sp.remove_queries([gid])
        assert -1 in sp2.part_cols[p]
        sp3, _ = sp2.add_queries([pool[0]])
        assert -1 not in sp3.part_cols[p], "tombstone not reclaimed"

    @pytest.mark.parametrize("name", ALL_ENGINES)
    def test_fifty_op_churn_equals_fresh_compile(self, name):
        """Acceptance: 50 random subscribe/unsubscribe ops ≡ from-scratch
        compile of the final query set, for every registered engine."""
        if name == "matscan":
            dtd = DTD.generate(n_tags=24, seed=2)
            d = TagDictionary()
            dtd.register(d)
            base_qs = gen_profiles(dtd, n=12, length=3, p_desc=1.0,
                                   p_wild=0.0, seed=2)
            pool = gen_profiles(dtd, n=60, length=3, p_desc=1.0,
                                p_wild=0.0, seed=33)
            docs = [doc for i in range(400)
                    if exact_class(doc := gen_document(
                        dtd, target_nodes=20, max_depth=4, seed=i))][:4]
        else:
            base_qs, docs, d = _workload(name, seed=2, n_docs=4,
                                         n_queries=12)
            pool = gen_profiles(DTD.generate(n_tags=24, seed=2), n=60,
                                length=3, p_desc=0.4, p_wild=0.15, seed=33)
        batch = EventBatch.from_streams(docs, bucket=32)
        eng = engines.create(name,
                             compile_queries(base_qs, d, shared=True),
                             dictionary=d)
        sp = eng.plan_sharded(4)
        rng = np.random.default_rng(7)
        live = list(sp.live_ids())
        k = 0
        for _ in range(50):
            if live and rng.random() < 0.45:
                sp = sp.remove_queries([live.pop(rng.integers(len(live)))])
            else:
                sp, gids = sp.add_queries([pool[k % len(pool)]])
                k += 1
                live += gids
        res = eng.filter_batch_sharded(batch, sp)
        want = _fresh_verdict(name, sp.live_queries(), d, batch)
        np.testing.assert_array_equal(res.matched, want.matched,
                                      err_msg=f"{name} churn matched")
        np.testing.assert_array_equal(res.first_event, want.first_event,
                                      err_msg=f"{name} churn location")

    @settings(max_examples=8, deadline=None)
    @given(ops=st.lists(st.integers(min_value=0, max_value=99),
                        min_size=1, max_size=25),
           seed=st.integers(min_value=0, max_value=3))
    def test_property_random_churn_equals_fresh_compile(self, ops, seed):
        """Hypothesis: ANY add/remove sequence keeps sharded verdicts
        equal to a from-scratch compile of the surviving query set."""
        profiles, docs, d = _workload("streaming", seed=seed, n_docs=3,
                                      n_queries=8)
        pool = gen_profiles(DTD.generate(n_tags=24, seed=seed), n=50,
                            length=3, p_desc=0.4, p_wild=0.15,
                            seed=seed + 13)
        batch = EventBatch.from_streams(docs, bucket=32)
        eng = engines.create("streaming",
                             compile_queries(profiles, d, shared=True),
                             dictionary=d)
        sp = eng.plan_sharded(2)
        live = list(sp.live_ids())
        k = 0
        for op in ops:
            if live and op % 2:
                sp = sp.remove_queries([live.pop(op % len(live))])
            else:
                sp, gids = sp.add_queries([pool[k % len(pool)]])
                k += 1
                live += gids
        res = eng.filter_batch_sharded(batch, sp)
        want = _fresh_verdict("streaming", sp.live_queries(), d, batch)
        np.testing.assert_array_equal(res.matched, want.matched)
        np.testing.assert_array_equal(res.first_event, want.first_event)


# --------------------------------------------------------- stage integration
class TestShardedFilterStage:
    def _routes(self, stage, docs):
        got = [r for b in stage.route(docs) for r in b]
        return {(r.doc_index, r.shard): tuple(r.matched_profiles)
                for r in got}

    def test_routing_identical_with_and_without_query_shards(self):
        profiles, docs, _ = _workload("streaming", seed=5, n_docs=8)
        d1 = TagDictionary()
        d2 = TagDictionary()
        mono = FilterStage(profiles, d1, n_shards=3, engine="streaming",
                           batch_size=3)
        shard = FilterStage(profiles, d2, n_shards=3, engine="streaming",
                            batch_size=3, query_shards=4)
        assert self._routes(mono, docs) == self._routes(shard, docs)

    def test_live_subscribe_unsubscribe_route_parity(self):
        profiles, docs, _ = _workload("streaming", seed=6, n_docs=6)
        extra = gen_profiles(DTD.generate(n_tags=24, seed=6), n=3,
                             length=3, seed=77)
        d1 = TagDictionary()
        d2 = TagDictionary()
        mono = FilterStage(profiles, d1, n_shards=2, engine="streaming",
                           batch_size=3)
        shard = FilterStage(profiles, d2, n_shards=2, engine="streaming",
                            batch_size=3, query_shards=2)
        for stage in (mono, shard):
            gids = [stage.subscribe(q) for q in extra]
            assert gids == sorted(gids)
            stage.unsubscribe(gids[0])
            stage.unsubscribe(1)
        assert self._routes(mono, docs) == self._routes(shard, docs)

    @pytest.mark.parametrize("query_shards", [1, 2])
    def test_gids_never_reused(self, query_shards):
        """A freed global id must not be handed to a later subscriber
        (a stale caller holding it would act on the wrong profile)."""
        profiles, _, _ = _workload("streaming", seed=0, n_queries=6)
        extra = gen_profiles(DTD.generate(n_tags=24, seed=0), n=2,
                             length=3, seed=55)
        stage = FilterStage(profiles, TagDictionary(), engine="streaming",
                            query_shards=query_shards)
        stage.unsubscribe(5)
        gid = stage.subscribe(extra[0])
        assert gid == 6, "freed id must not be reused"
        assert stage.subscribe(extra[1]) == 7

    def test_unsubscribe_unknown_raises(self):
        profiles, _, _ = _workload("streaming", seed=0)
        stage = FilterStage(profiles, TagDictionary(), query_shards=2,
                            engine="streaming")
        with pytest.raises(KeyError):
            stage.unsubscribe(10**6)


# --------------------------------------------------------------- area model
class TestShardedArea:
    def test_one_row_per_part(self):
        dtd = DTD.generate(n_tags=24, seed=0)
        d = TagDictionary()
        dtd.register(d)
        qs = gen_profiles(dtd, n=32, length=3, seed=0)
        for scenario in SCENARIOS:
            rows = area_report_sharded(qs, TagDictionary(), scenario, 4)
            assert len(rows) == 4
            assert [r.part for r in rows] == [0, 1, 2, 3]
            assert sum(r.n_queries for r in rows) == 32
            whole = area_report(qs, TagDictionary(), scenario)
            # each chip pays its own fixed blocks (char decoder, stack);
            # net of those, the partitioned total stays within 2× of the
            # monolithic chip (prefix groups kept together bound the
            # sharing lost to the split)
            from repro.core.area import CHARDEC_COST
            fixed = CHARDEC_COST if scenario.endswith("CharDec") else 0
            assert sum(r.bit_cost - fixed for r in rows) < 2 * whole.bit_cost
            assert all(r.bit_cost < whole.bit_cost + fixed for r in rows)
