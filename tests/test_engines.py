"""Engine equivalence: oracle == yfilter == streaming == levelwise.

The core correctness claim of the reproduction — every engine implements
the same XPath filtering semantics, from the pure-python ground truth to
the TPU-shaped levelwise matmul engine.
"""
import numpy as np
import pytest
from _hypothesis_shim import given, settings, st

from repro.core.dictionary import TagDictionary
from repro.core.engines import FilterResult
from repro.core.engines.levelwise import LevelwiseEngine
from repro.core.engines.oracle import filter_document as oracle_filter
from repro.core.engines.streaming import StreamingEngine
from repro.core.engines.yfilter import YFilterEngine
from repro.core.events import CLOSE, OPEN, EventStream
from repro.core.nfa import compile_queries
from repro.core.xpath import parse
from repro.data.generator import DTD, gen_document, gen_profiles


def ev_from_nested(spec) -> EventStream:
    """spec: nested lists of (tag, [children])."""
    ks, ts = [], []

    def walk(node):
        tag, kids = node
        ks.append(OPEN)
        ts.append(tag)
        for k in kids:
            walk(k)
        ks.append(CLOSE)
        ts.append(tag)

    for n in spec:
        walk(n)
    return EventStream(np.array(ks, np.int8), np.array(ts, np.int32))


def run_all_engines(profiles, ev, dictionary, shared=True):
    from repro.core.engines.levelwise import WavefrontEngine
    queries = [parse(p) if isinstance(p, str) else p for p in profiles]
    nfa = compile_queries(queries, dictionary, shared=shared)
    res = {
        "oracle": oracle_filter(nfa, ev, dictionary),
        "yfilter": YFilterEngine(nfa).filter_document(ev),
        "streaming": StreamingEngine(nfa, max_depth=32).filter_document(ev),
        "levelwise": LevelwiseEngine(nfa, use_matmul=True).filter_document(ev),
        "levelwise_cmp": LevelwiseEngine(nfa, use_matmul=False).filter_document(ev),
        "wavefront": WavefrontEngine(nfa, chunk=16).filter_document(ev),
    }
    return res


def assert_all_equal(res: dict[str, FilterResult]):
    ref = res["oracle"]
    for name, r in res.items():
        np.testing.assert_array_equal(
            r.matched, ref.matched, err_msg=f"{name} matched != oracle")
        np.testing.assert_array_equal(
            r.first_event, ref.first_event, err_msg=f"{name} location != oracle")


# --------------------------------------------------------- directed cases
def fresh_dict(n=30):
    return TagDictionary.build([f"t{i}" for i in range(n)])


class TestDirectedSemantics:
    def test_ancestor_descendant(self):
        d = fresh_dict()
        #  t0 > t1 > t2 ; t3
        ev = ev_from_nested([(0, [(1, [(2, [])])]), (3, [])])
        res = run_all_engines(["t0//t2", "t0//t3", "t3", "//t1//t2"], ev, d)
        assert list(res["oracle"].matched) == [True, False, True, True]
        assert_all_equal(res)

    def test_parent_child_needs_consecutive_levels(self):
        d = fresh_dict()
        # t0 > t1 > t2 — t0/t2 must NOT match (t2 is grandchild)
        ev = ev_from_nested([(0, [(1, [(2, [])])])])
        res = run_all_engines(["t0/t2", "t0/t1", "t1/t2", "t0/t1/t2"], ev, d)
        assert list(res["oracle"].matched) == [False, True, True, True]
        assert_all_equal(res)

    def test_descendant_must_be_inside(self):
        d = fresh_dict()
        # <t0></t0><t1></t1>: t0//t1 must NOT match (t1 is sibling)
        ev = ev_from_nested([(0, []), (1, [])])
        res = run_all_engines(["t0//t1", "t0/t1"], ev, d)
        assert list(res["oracle"].matched) == [False, False]
        assert_all_equal(res)

    def test_root_anchoring(self):
        d = fresh_dict()
        # /t1 anchored: t1 exists only nested → no match
        ev = ev_from_nested([(0, [(1, [])])])
        res = run_all_engines(["/t1", "/t0", "/t0/t1"], ev, d)
        assert list(res["oracle"].matched) == [False, True, True]
        assert_all_equal(res)

    def test_wildcards(self):
        d = fresh_dict()
        ev = ev_from_nested([(0, [(1, [(2, [])])])])
        res = run_all_engines(["//*", "t0/*/t2", "//*/t1", "t0//*"], ev, d)
        assert list(res["oracle"].matched) == [True, True, True, True]
        assert_all_equal(res)

    def test_recursive_tags(self):
        d = fresh_dict()
        # t0 > t0 > t1 — tests the nested-same-tag case where the paper's
        # flat regex is approximate but the stack engines are exact
        ev = ev_from_nested([(0, [(0, [(1, [])]), (2, [])])])
        res = run_all_engines(["t0/t0", "t0/t0/t1", "t0//t1", "t1/t0"], ev, d)
        assert list(res["oracle"].matched) == [True, True, True, False]
        assert_all_equal(res)

    def test_match_location_is_first(self):
        d = fresh_dict()
        # two matches of t0//t1; first is event 1
        ev = ev_from_nested([(0, [(1, []), (1, [])])])
        res = run_all_engines(["t0//t1"], ev, d)
        assert res["oracle"].first_event[0] == 1
        assert_all_equal(res)

    def test_unshared_equals_shared(self):
        d = fresh_dict()
        ev = ev_from_nested([(0, [(1, [(2, [])]), (3, [])])])
        profiles = ["t0//t2", "t0//t3", "t0/t1/t2", "t0/t1", "t0//t1//t2"]
        r_shared = run_all_engines(profiles, ev, d, shared=True)
        r_unshared = run_all_engines(profiles, ev, d, shared=False)
        assert_all_equal(r_shared)
        assert_all_equal(r_unshared)
        np.testing.assert_array_equal(r_shared["oracle"].matched,
                                      r_unshared["oracle"].matched)

    def test_deep_chain(self):
        d = fresh_dict()
        spec = (9, [])
        for t in range(8, -1, -1):
            spec = (t, [spec])
        ev = ev_from_nested([spec])
        res = run_all_engines(
            ["t0/t1/t2/t3/t4/t5/t6/t7/t8/t9", "t0//t9", "t0//t4/t5//t9",
             "t9/t0"], ev, d)
        assert list(res["oracle"].matched) == [True, True, True, False]
        assert_all_equal(res)


# ------------------------------------------------------- randomized sweep
class TestRandomizedEquivalence:
    @pytest.mark.parametrize("seed", range(8))
    def test_generated_workload(self, seed):
        dtd = DTD.generate(n_tags=16, seed=seed)
        d = TagDictionary()
        dtd.register(d)
        profiles = gen_profiles(dtd, n=24, length=3 + seed % 3,
                                p_desc=0.4, p_wild=0.15, seed=seed)
        ev = gen_document(dtd, target_nodes=120, seed=seed)
        res = run_all_engines(profiles, ev, d)
        assert_all_equal(res)

    @settings(max_examples=25, deadline=None)
    @given(data=st.data())
    def test_property_random_trees(self, data):
        n_tags = data.draw(st.integers(2, 6))
        d = TagDictionary.build([f"t{i}" for i in range(n_tags)])

        def tree(depth):
            return st.tuples(
                st.integers(0, n_tags - 1),
                st.lists(tree(depth - 1), max_size=3) if depth > 0
                else st.just([]))

        spec = data.draw(st.lists(tree(3), min_size=1, max_size=3))
        ev = ev_from_nested(spec)
        profiles = []
        for _ in range(data.draw(st.integers(1, 6))):
            k = data.draw(st.integers(1, 3))
            parts = []
            for i in range(k):
                axis = data.draw(st.sampled_from(["/", "//"]))
                tag = data.draw(st.sampled_from(
                    [f"t{j}" for j in range(n_tags)] + ["*"]))
                parts.append(axis + tag)
            profiles.append("".join(parts))
        res = run_all_engines(profiles, ev, d)
        assert_all_equal(res)


class TestBatchedPaths:
    def test_streaming_batched_matches_single(self):
        dtd = DTD.generate(n_tags=12, seed=3)
        d = TagDictionary()
        dtd.register(d)
        profiles = gen_profiles(dtd, n=16, length=3, seed=3)
        docs = [gen_document(dtd, target_nodes=60, seed=i) for i in range(4)]
        nfa = compile_queries(profiles, d)
        eng = StreamingEngine(nfa, max_depth=32)
        singles = [eng.filter_document(doc) for doc in docs]
        n = max(len(doc) for doc in docs)
        kind = np.stack([doc.padded(n).kind for doc in docs])
        tag = np.stack([doc.padded(n).tag_id for doc in docs])
        batched = eng.filter_documents_batched(kind, tag)
        for i, s in enumerate(singles):
            np.testing.assert_array_equal(batched.matched[i], s.matched)
            np.testing.assert_array_equal(batched.first_event[i], s.first_event)

    def test_levelwise_batched_matches_single(self):
        dtd = DTD.generate(n_tags=12, seed=4)
        d = TagDictionary()
        dtd.register(d)
        profiles = gen_profiles(dtd, n=16, length=4, seed=4)
        docs = [gen_document(dtd, target_nodes=60, seed=10 + i) for i in range(4)]
        nfa = compile_queries(profiles, d)
        eng = LevelwiseEngine(nfa)
        singles = [eng.filter_document(doc) for doc in docs]
        batched = eng.filter_documents_batched(docs)
        for s, b in zip(singles, batched):
            np.testing.assert_array_equal(b.matched, s.matched)
            np.testing.assert_array_equal(b.first_event, s.first_event)
