"""Continuous serve-loop tests (:mod:`repro.serve.loop`).

The contract under test: the loop is *schedule*, not *semantics* —
whatever the arrival pattern, batch-close reason, pipeline depth or
overload policy, every admitted request gets the bit-identical verdict
the synchronous ``route_bytes`` path computes, delivered in admission
order per subscriber; and every bound (queue cap, K in-flight slots)
actually binds, with the corresponding counter observable.

These tests run threaded code with real deadlines — they are written so
that a *wedged* loop fails by pytest-timeout (the CI serve job runs
them under a suite-wide ``--timeout``), never by flaky sleeps: waits
are generous upper bounds, assertions never depend on tight timing.
"""
import threading
import time

import numpy as np
import pytest

from repro.core.dictionary import TagDictionary
from repro.core.events import KernelFault, encode_bytes
from repro.data.filter_stage import TEXT_FILL, FilterStage
from repro.data.generator import DTD, gen_corpus, gen_profiles
from repro.serve.loop import (ServeLoop, burst_arrivals, make_arrivals,
                              poisson_arrivals, replay_arrivals, run_trace)

ENGINE = "streaming"   # fixed device shapes: no content-dependent compiles
N_QUERIES = 16
BATCH = 4


def _workload(n_docs=16, seed=0):
    dtd = DTD.generate(n_tags=24, seed=seed)
    d = TagDictionary()
    dtd.register(d)
    profiles = gen_profiles(dtd, n=N_QUERIES, length=3, seed=seed)
    docs = gen_corpus(dtd, n_docs=n_docs, nodes_per_doc=40, seed=1)
    raw = [encode_bytes(x, text_fill=TEXT_FILL) for x in docs]
    return profiles, d, raw


def _stage(profiles, d, **kw):
    kw.setdefault("engine", ENGINE)
    kw.setdefault("keep_unmatched", True)
    kw.setdefault("batch_size", BATCH)
    return FilterStage(profiles, d, n_shards=2, **kw)


def _routes(batches):
    return {(r.doc_index, r.shard): tuple(r.matched_profiles)
            for b in batches for r in b}


def _ticket_routes(tickets):
    return {(rd.doc_index, rd.shard): tuple(rd.matched_profiles)
            for t in tickets if not t.shed for rd in t.routed}


# ------------------------------------------------------------ batch closing
class TestAdaptiveBatching:
    def test_size_close_fires_before_deadline(self):
        profiles, d, raw = _workload(n_docs=2 * BATCH)
        loop = ServeLoop(_stage(profiles, d), max_batch=BATCH,
                         deadline_ms=60_000, queue_cap=64)
        with loop:
            tickets = [loop.submit(p) for p in raw]
            for t in tickets:
                assert t.done.wait(timeout=60), "verdict never arrived"
        s = loop.slo_summary()
        # an exact multiple of max_batch under an effectively infinite
        # deadline: every close is a size close
        assert s["size_closes"] == 2
        assert s["deadline_closes"] == 0 and s["flush_closes"] == 0
        assert s["batch_fill"] == 1.0
        assert s["completed"] == len(raw) and s["shed"] == 0

    def test_deadline_close_fires_under_size(self):
        profiles, d, raw = _workload(n_docs=BATCH - 1)
        loop = ServeLoop(_stage(profiles, d), max_batch=BATCH,
                         deadline_ms=50, queue_cap=64)
        with loop:
            tickets = [loop.submit(p) for p in raw]
            # fewer than max_batch queued and nothing else arriving: only
            # the deadline can close this batch
            for t in tickets:
                assert t.done.wait(timeout=60), "deadline close never fired"
            assert loop.slo_summary()["deadline_closes"] >= 1
        s = loop.slo_summary()
        assert s["completed"] == BATCH - 1
        assert s["size_closes"] == 0

    def test_flush_close_on_exit(self):
        profiles, d, raw = _workload(n_docs=2)
        loop = ServeLoop(_stage(profiles, d), max_batch=BATCH,
                         deadline_ms=60_000, queue_cap=64)
        with loop:
            tickets = [loop.submit(p) for p in raw]
            # no wait: close() must flush the sub-deadline remainder
        assert all(t.t_verdict is not None for t in tickets)
        assert loop.slo_summary()["flush_closes"] >= 1


# --------------------------------------------------------- admission control
class TestAdmissionControl:
    def _stalled_loop(self, profiles, d, overload, queue_cap):
        """A loop whose consumer is stalled: the completer blocks in
        deliver() holding the single in-flight slot, so the queue can
        only fill — admission at the cap is what's under test."""
        release = threading.Event()
        delivered = []

        def deliver(routed):
            delivered.append(routed)
            release.wait(timeout=120)

        loop = ServeLoop(_stage(profiles, d), max_batch=BATCH,
                         deadline_ms=5, queue_cap=queue_cap,
                         max_inflight=1, overload=overload,
                         deliver=deliver)
        return loop, release, delivered

    def test_shed_beyond_queue_cap(self):
        profiles, d, raw = _workload(n_docs=32)
        cap = 4
        loop, release, delivered = self._stalled_loop(profiles, d,
                                                      "shed", cap)
        try:
            tickets = [loop.submit(p) for p in raw]
            shed = [t for t in tickets if t.shed]
            # the queue is bounded: with the pipeline wedged, at most
            # cap + (in flight through the batcher) requests can be
            # admitted; the rest MUST shed, immediately (no blocking)
            assert len(shed) > 0
            s = loop.slo_summary()
            assert s["shed"] == len(shed)
            assert s["max_queue_depth"] <= cap
            assert s["admitted"] + s["shed"] == len(raw)
            # shed tickets resolve instantly, with no verdict
            for t in shed:
                assert t.done.is_set() and t.t_verdict is None
                assert t.seq == -1
        finally:
            release.set()
            loop.close()
        # everything admitted (not shed) still got its verdict
        assert loop.slo_summary()["completed"] == \
            loop.slo_summary()["admitted"]

    def test_block_at_queue_cap_stalls_producer(self):
        profiles, d, raw = _workload(n_docs=12)
        loop, release, delivered = self._stalled_loop(profiles, d,
                                                      "block", 2)
        produced = threading.Event()
        tickets = []

        def producer():
            for p in raw:
                tickets.append(loop.submit(p))
            produced.set()

        t = threading.Thread(target=producer, daemon=True)
        t.start()
        try:
            # the producer must wedge against the bounded queue while
            # the consumer is stalled...
            assert not produced.wait(timeout=1.0), \
                "submit() never blocked at queue_cap under block policy"
        finally:
            release.set()
            # ...and drain completely once the consumer resumes
            assert produced.wait(timeout=120), "producer stayed blocked"
            t.join(timeout=120)
            loop.close()
        s = loop.slo_summary()
        assert s["shed"] == 0
        assert s["completed"] == len(raw)
        assert all(not t_.shed for t_ in tickets)

    def test_backpressure_counter_under_stalled_consumer(self):
        profiles, d, raw = _workload(n_docs=16)
        loop, release, delivered = self._stalled_loop(profiles, d,
                                                      "shed", 16)
        try:
            for p in raw:
                loop.submit(p)
            # K=1 and a stalled consumer: the batcher must report
            # waiting on an in-flight slot
            deadline = time.monotonic() + 60
            while (loop.slo_summary()["backpressure_waits"] == 0
                   and time.monotonic() < deadline):
                time.sleep(0.01)
            assert loop.slo_summary()["backpressure_waits"] >= 1
        finally:
            release.set()
            loop.close()


# ------------------------------------------------------ parity & ordering
class TestParity:
    @pytest.mark.parametrize("max_inflight", [1, 2, 4])
    def test_verdicts_bit_identical_to_route_bytes(self, max_inflight):
        """K-deep pipelining parity: whatever K, verdicts equal the
        synchronous path bit for bit and arrive in order."""
        profiles, d, raw = _workload(n_docs=17)  # ragged tail on purpose
        deliveries = []
        loop = ServeLoop(_stage(profiles, d), max_batch=BATCH,
                         deadline_ms=60_000, queue_cap=64,
                         max_inflight=max_inflight,
                         deliver=deliveries.append)
        with loop:
            tickets = [loop.submit(p) for p in raw]
        want = _routes(_stage(profiles, d).route_bytes(raw))
        assert _ticket_routes(tickets) == want
        assert _routes(deliveries) == want
        # ordered delivery per subscriber: each shard sees its documents
        # in admission order
        per_shard: dict[int, list[int]] = {}
        for batch in deliveries:
            for rd in batch:
                per_shard.setdefault(rd.shard, []).append(rd.doc_index)
        for shard, seq in per_shard.items():
            assert seq == sorted(seq), f"shard {shard} out of order: {seq}"

    def test_parity_with_deadline_closed_padded_batches(self):
        """Undersized deadline-closed batches are padded back to
        max_batch (one compiled shape) — the pad rows must never leak
        into verdicts."""
        profiles, d, raw = _workload(n_docs=10)
        loop = ServeLoop(_stage(profiles, d), max_batch=BATCH,
                         deadline_ms=1, queue_cap=64)
        assert loop.pad_batches
        with loop:
            tickets = []
            for p in raw:
                tickets.append(loop.submit(p))
                time.sleep(0.01)  # let deadlines fire mid-stream
        assert loop.slo_summary()["completed"] == len(raw)
        want = _routes(_stage(profiles, d).route_bytes(raw))
        assert _ticket_routes(tickets) == want

    def test_parity_sparse_stage(self):
        """Sparse verdict delivery through the loop (pad_batches is
        auto-disabled: match lists carry real doc ids)."""
        profiles, d, raw = _workload(n_docs=9)
        loop = ServeLoop(_stage(profiles, d, sparse=True),
                         max_batch=BATCH, deadline_ms=60_000,
                         queue_cap=64)
        assert not loop.pad_batches
        with loop:
            tickets = [loop.submit(p) for p in raw]
        want = _routes(_stage(profiles, d).route_bytes(raw))
        assert _ticket_routes(tickets) == want

    def test_parity_2d_mesh_stage(self):
        """The loop over a 2-D (data × model) stage: the worker rides
        the sharded bytes→verdict program, parity must hold."""
        profiles, d, raw = _workload(n_docs=8)
        loop = ServeLoop(_stage(profiles, d, query_shards=2,
                                data_shards=2),
                         max_batch=BATCH, deadline_ms=60_000,
                         queue_cap=64)
        with loop:
            tickets = [loop.submit(p) for p in raw]
        want = _routes(_stage(profiles, d).route_bytes(raw))
        assert _ticket_routes(tickets) == want

    def test_latencies_and_slo_summary(self):
        profiles, d, raw = _workload(n_docs=BATCH * 2)
        loop = ServeLoop(_stage(profiles, d), max_batch=BATCH,
                         deadline_ms=60_000, queue_cap=64)
        with loop:
            tickets = [loop.submit(p) for p in raw]
        lat = loop.latencies_ms()
        assert lat.shape == (len(raw),) and (lat > 0).all()
        s = loop.slo_summary()
        assert np.isfinite([s["p50_ms"], s["p99_ms"], s["p999_ms"]]).all()
        assert s["p50_ms"] <= s["p99_ms"] <= s["p999_ms"]
        assert s["served_per_s"] > 0
        for t in tickets:
            assert t.latency_s is not None and t.latency_s > 0
        hist = loop.latency_histogram(n_bins=8)
        assert sum(hist["counts"]) == len(raw)
        assert len(hist["edges_ms"]) == len(hist["counts"]) + 1

    def test_persistent_worker_error_quarantines_not_crashes(self):
        """A fault that survives retry + bisection quarantines the
        affected requests as typed ``KernelFault``s — the loop keeps
        serving and close() does NOT raise (containment, not crash)."""
        profiles, d, raw = _workload(n_docs=2)
        stage = _stage(profiles, d)

        def boom(payloads, record=True, epoch=None):
            raise RuntimeError("device fell over")

        stage._filter_bytebatch = boom
        loop = ServeLoop(stage, max_batch=BATCH, deadline_ms=5,
                         queue_cap=8)
        tickets = [loop.submit(p) for p in raw]
        for t in tickets:
            assert t.done.wait(timeout=60)
        loop.close()  # must not raise: the fault was contained
        for t in tickets:
            assert t.failed and isinstance(t.error, KernelFault)
            assert "device fell over" in str(t.error)
        s = loop.slo_summary()
        assert s["quarantined"] == len(raw) and s["failed"] == 0
        assert len(loop.dead_letter) == len(raw)

    def test_worker_error_propagates_on_close_without_recovery(self):
        """``recover=False`` restores the strict contract: a worker
        error fails the affected requests and re-raises at close()."""
        profiles, d, raw = _workload(n_docs=2)
        stage = _stage(profiles, d)

        def boom(payloads, record=True, epoch=None):
            raise RuntimeError("device fell over")

        stage._filter_bytebatch = boom
        loop = ServeLoop(stage, max_batch=BATCH, deadline_ms=5,
                         queue_cap=8, recover=False)
        tickets = [loop.submit(p) for p in raw]
        for t in tickets:
            assert t.done.wait(timeout=60)
        with pytest.raises(RuntimeError, match="device fell over"):
            loop.close()
        assert all(t.failed for t in tickets)
        s = loop.slo_summary()
        assert s["failed"] == len(raw) and s["quarantined"] == 0


# ------------------------------------------------------------ arrival traces
class TestArrivalTraces:
    def test_poisson_seeded_and_monotonic(self):
        a = poisson_arrivals(256, 100.0, seed=7)
        b = poisson_arrivals(256, 100.0, seed=7)
        c = poisson_arrivals(256, 100.0, seed=8)
        assert np.array_equal(a, b) and not np.array_equal(a, c)
        assert (np.diff(a) > 0).all()
        # mean inter-arrival ~ 1/rate (loose 3-sigma-ish bound)
        assert 1 / 100.0 * 0.7 < np.diff(a).mean() < 1 / 100.0 * 1.3

    def test_burst_arrivals_live_in_on_windows(self):
        on_s, off_s = 0.02, 0.08
        a = burst_arrivals(200, 1000.0, on_s=on_s, off_s=off_s, seed=3)
        assert (np.diff(a) > 0).all()
        phase = np.mod(a, on_s + off_s)
        assert (phase <= on_s + 1e-9).all(), "arrival outside ON window"
        assert np.array_equal(
            a, burst_arrivals(200, 1000.0, on_s=on_s, off_s=off_s, seed=3))

    def test_replay_arrivals(self):
        assert np.array_equal(replay_arrivals(4), np.zeros(4))
        r = replay_arrivals(4, 100.0)
        assert np.allclose(np.diff(r), 0.01)

    def test_make_arrivals_dispatch(self):
        assert len(make_arrivals("poisson", 8, rate_hz=50.0)) == 8
        assert len(make_arrivals("burst", 8, rate_hz=500.0)) == 8
        assert len(make_arrivals("replay", 8, rate_hz=50.0)) == 8
        with pytest.raises(ValueError, match="unknown arrival"):
            make_arrivals("fractal", 8, rate_hz=50.0)

    def test_run_trace_under_seeded_burst(self):
        """The CI serve job's scenario in miniature: a seeded bursty
        trace through a bounded loop — terminates, p99 finite, the
        counters account for every arrival."""
        profiles, d, raw = _workload(n_docs=24)
        arrivals = burst_arrivals(len(raw), 2000.0, on_s=0.01,
                                  off_s=0.02, seed=11)
        loop = ServeLoop(_stage(profiles, d), max_batch=BATCH,
                         deadline_ms=10, queue_cap=16, max_inflight=2)
        with loop:
            tickets = run_trace(loop, raw, arrivals)
        assert len(tickets) == len(raw)
        s = loop.slo_summary()
        assert s["admitted"] + s["shed"] == len(raw)
        assert s["completed"] == s["admitted"]
        assert np.isfinite(s["p99_ms"])

    def test_run_trace_length_mismatch_raises(self):
        profiles, d, raw = _workload(n_docs=4)
        loop = ServeLoop(_stage(profiles, d), max_batch=BATCH,
                         deadline_ms=10, queue_cap=8)
        with loop:
            with pytest.raises(ValueError, match="payloads"):
                run_trace(loop, raw, np.zeros(3))


# ----------------------------------------- K-deep route_bytes_pipelined
class TestRouteBytesPipelinedKDeep:
    """Regression coverage for the satellite fix: the 2-deep double
    buffer is now the K=2 case of the K-deep machinery, and staging
    (→ ``put_seconds``) happens exactly once per batch at any depth."""

    def _workload2d(self, n_docs=12):
        profiles, d, raw = _workload(n_docs=n_docs, seed=5)
        return profiles, d, raw

    @pytest.mark.parametrize("depth", [1, 2, 3, 8])
    def test_depth_parity_and_single_staging(self, depth):
        profiles, d, raw = self._workload2d()
        stage = _stage(profiles, d, data_shards=2)
        stages_in = []
        orig = stage._stage_in
        stage._stage_in = lambda bufs: (stages_in.append(len(bufs))
                                        or orig(bufs))
        got = _routes(stage.route_bytes_pipelined(iter(raw), depth=depth))
        want = _routes(_stage(profiles, d,
                              data_shards=2).route_bytes(raw))
        assert got == want
        # 12 docs / batch 4 = 3 batches, each staged EXACTLY once —
        # this is the put_seconds single-count regression: staging is
        # where put_seconds accrues, so one staging per batch means one
        # accounting per batch at every depth
        assert stages_in == [BATCH] * 3
        assert stage.stats["batches"] == 3
        # depth 1 is fully synchronous (no overlap); deeper pipelines
        # overlap every batch after the first
        want_overlap = 0 if depth == 1 else 2
        assert stage.stats["overlapped_batches"] == want_overlap

    def test_default_depth_is_double_buffer(self):
        profiles, d, raw = self._workload2d()
        stage = _stage(profiles, d, data_shards=2)
        assert stage.pipeline_depth == 2
        got = _routes(stage.route_bytes_pipelined(raw))
        want = _routes(_stage(profiles, d,
                              data_shards=2).route_bytes(raw))
        assert got == want
        assert stage.stats["overlapped_batches"] == 2

    def test_pipeline_depth_field_threads_through(self):
        profiles, d, raw = self._workload2d()
        stage = _stage(profiles, d, data_shards=2, pipeline_depth=3)
        got = _routes(stage.route_bytes_pipelined(raw))
        want = _routes(_stage(profiles, d,
                              data_shards=2).route_bytes(raw))
        assert got == want
