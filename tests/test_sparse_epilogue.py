"""Fused in-kernel sparse epilogue: one launch from bytes to match lists.

PR-level contract, four legs:

* **Route taxonomy** — every sparse call records which path actually ran
  in ``SparseResult.meta["path"]``: ``kernel-fused`` (in-kernel bounded
  emission), ``lane-compact`` (two-launch bitmap compaction),
  ``base-fallback`` (non-kernel engines through the base class) and
  ``dense-overflow`` (buffer saturated, exact dense recompute).
* **Overflow boundaries** — matches == cap, cap ± 1, zero matches and
  all-docs-match-everything are each bit-exact against the scan oracle
  via ``densify()`` on the plain, sharded, bytes and churned-gid paths.
* **No bitmap in HBM** — a jaxpr inspection asserts the fused program's
  ``pallas_call`` outputs are ONLY the bounded ``(cap + win, 3)`` match
  buffer and the ``(1, 1)`` counter: the ``(B, G, QB)`` accept bitmap
  never materializes outside VMEM.
* **Kernel vs oracle** — the raw kernel's buffer equals
  :func:`repro.kernels.ref.sparse_epilogue` row for row (emission order
  included) across grid orders and caps, saturation included.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import engines
from repro.core.dictionary import TagDictionary
from repro.core.events import ByteBatch, EventBatch
from repro.core.nfa import compile_queries
from repro.core.xpath import parse
from repro.data.generator import DTD, gen_corpus, gen_profiles
from repro.kernels import ref
from repro.kernels import stream_filter as sf
from repro.launch.mesh import make_filter_mesh

KERNEL_OPTS = dict(kernel="pallas", kernel_interpret=True)


def _workload(seed=0, n_docs=5, n_queries=12, minimize=True, **opts):
    dtd = DTD.generate(n_tags=24, seed=seed)
    d = TagDictionary()
    dtd.register(d)
    profiles = gen_profiles(dtd, n=n_queries, length=3, p_desc=0.4,
                            p_wild=0.15, seed=seed)
    docs = gen_corpus(dtd, n_docs=n_docs, nodes_per_doc=60, seed=seed)
    nfa = compile_queries(profiles, d, shared=True)
    eng = engines.create("streaming", nfa, dictionary=d,
                         minimize=minimize, **{**KERNEL_OPTS, **opts})
    return eng, d, docs, dtd


def _assert_dense_parity(sp, dense):
    back = sp.densify()
    np.testing.assert_array_equal(back.matched, dense.matched)
    np.testing.assert_array_equal(back.first_event, dense.first_event)


def _pallas_eqns(jaxpr):
    """Every pallas_call equation reachable from ``jaxpr``."""
    found = []
    for eqn in jaxpr.eqns:
        if eqn.primitive.name == "pallas_call":
            found.append(eqn)
        for v in eqn.params.values():
            if isinstance(v, jax.extend.core.ClosedJaxpr):
                found.extend(_pallas_eqns(v.jaxpr))
            elif isinstance(v, jax.extend.core.Jaxpr):
                found.extend(_pallas_eqns(v))
    return found


# ------------------------------------------------------- route taxonomy
class TestPathTaxonomy:
    def test_kernel_fused_is_default(self):
        eng, d, docs, _ = _workload()
        batch = EventBatch.from_streams(docs, bucket=64)
        sp = eng.filter_batch_sparse(batch)
        assert sp.meta["path"] == "kernel-fused"
        assert not sp.overflowed
        _assert_dense_parity(sp, eng.filter_batch(batch))

    def test_lane_compact_when_epilogue_off_or_cap_too_big(self):
        eng, d, docs, _ = _workload(sparse_epilogue="off")
        batch = EventBatch.from_streams(docs, bucket=64)
        sp = eng.filter_batch_sparse(batch)
        assert sp.meta["path"] == "lane-compact"
        _assert_dense_parity(sp, eng.filter_batch(batch))
        # "auto" routes by the VMEM budget: a cap past it compacts lanes
        auto, _, _, _ = _workload()
        assert not auto._fused_sparse_ok(10**7)
        assert auto._fused_sparse_ok(1024)

    def test_base_fallback_for_scan_engines(self):
        eng, d, docs, _ = _workload(kernel="scan")
        batch = EventBatch.from_streams(docs, bucket=64)
        sp = eng.filter_batch_sparse(batch)
        assert sp.meta["path"] == "base-fallback"
        assert sp.meta["base_path"] == "device-compact"
        _assert_dense_parity(sp, eng.filter_batch(batch))

    def test_dense_overflow_names_attempted_path(self):
        eng, d, docs, _ = _workload()
        batch = EventBatch.from_streams(docs, bucket=64)
        sp = eng.filter_batch_sparse(batch, match_cap=1)
        assert sp.n_matches > 1, "workload must overflow cap=1"
        assert sp.overflowed
        assert sp.meta["path"] == "dense-overflow"
        assert sp.meta["attempted_path"] == "kernel-fused"
        _assert_dense_parity(sp, eng.filter_batch(batch))

    def test_sharded_mesh_runs_fused_not_base(self):
        """The pre-PR behavior — ``mesh is not None`` silently taking
        the base compaction — is gone: the mesh route is the fused
        kernel under shard_map, and says so."""
        eng, d, docs, _ = _workload()
        batch = EventBatch.from_streams(docs, bucket=64)
        sharded = eng.plan_sharded(2)
        mesh = make_filter_mesh(2)
        sp = eng.filter_batch_sharded_sparse(batch, sharded, mesh=mesh)
        assert sp.meta["path"] == "kernel-fused"
        _assert_dense_parity(sp, eng.filter_batch_sharded(batch, sharded))

    def test_bytes_path_is_one_launch(self):
        eng, d, docs, _ = _workload()
        bb = ByteBatch.from_streams(docs, bucket=256)
        batch = EventBatch.from_streams(docs, bucket=64)
        for pack in (False, True):
            sp = eng.filter_bytes_sparse(bb, pack=pack)
            assert sp.meta["path"] == "kernel-fused"
            assert sp.meta["launch"] == "bytes"
            _assert_dense_parity(sp, eng.filter_batch(batch))

    def test_sharded2d_sparse_fused(self):
        eng, d, docs, _ = _workload(n_docs=6)
        batch = EventBatch.from_streams(docs, bucket=64)
        sharded = eng.plan_sharded(2)
        mesh = make_filter_mesh(2, data_shards=2)
        sp = eng.filter_batch_sharded2d_sparse(batch, sharded, mesh=mesh)
        assert sp.meta["path"] == "kernel-fused"
        _assert_dense_parity(
            sp, eng.filter_batch_sharded2d(batch, sharded, mesh=mesh))


# -------------------------------------------------- overflow boundaries
class TestOverflowBoundaries:
    @pytest.mark.parametrize("route", ["plain", "sharded", "bytes",
                                       "churned"])
    def test_cap_boundary_sweep(self, route):
        eng, d, docs, _ = _workload(seed=1)
        batch = EventBatch.from_streams(docs, bucket=64)
        bb = ByteBatch.from_streams(docs, bucket=256)
        sharded = eng.plan_sharded(3)
        if route == "churned":
            sharded = sharded.remove_queries([1, 4])

        def run(cap):
            if route == "plain":
                return (eng.filter_batch_sparse(batch, match_cap=cap),
                        eng.filter_batch(batch))
            if route == "bytes":
                return (eng.filter_bytes_sparse(bb, match_cap=cap),
                        eng.filter_batch(batch))
            return (eng.filter_batch_sharded_sparse(
                        batch, sharded, match_cap=cap),
                    eng.filter_batch_sharded(batch, sharded))

        n = run(batch.batch_size * eng.n_queries)[0].meta["device_rows"]
        assert n > 2, "workload must produce a few device rows"
        for cap, over in ((n, False), (n + 1, False), (n - 1, True)):
            sp, dense = run(cap)
            assert sp.overflowed == over, (route, cap)
            assert sp.meta["path"] == ("dense-overflow" if over
                                       else "kernel-fused")
            _assert_dense_parity(sp, dense)

    def test_zero_matches(self):
        """Profiles over a disjoint tag alphabet: zero rows, no
        overflow, an empty exact densify."""
        dtd_docs = DTD.generate(n_tags=12, seed=2)
        dtd_qs = DTD.generate(n_tags=12, seed=99)
        d = TagDictionary()
        dtd_docs.register(d)
        dtd_qs.register(d)
        profiles = gen_profiles(dtd_qs, n=6, length=3, p_desc=0.4,
                                p_wild=0.0, seed=2)
        docs = gen_corpus(dtd_docs, n_docs=4, nodes_per_doc=40, seed=2)
        nfa = compile_queries(profiles, d, shared=True)
        eng = engines.create("streaming", nfa, dictionary=d,
                             minimize=True, **KERNEL_OPTS)
        batch = EventBatch.from_streams(docs, bucket=64)
        sp = eng.filter_batch_sparse(batch, match_cap=4)
        assert sp.n_matches == 0 and not sp.overflowed
        assert sp.meta["path"] == "kernel-fused"
        assert sp.meta["device_rows"] == 0
        _assert_dense_parity(sp, eng.filter_batch(batch))

    def test_all_docs_match_all_classes(self):
        """``//*`` profiles: every document hits every accept class —
        the densest possible buffer still round-trips exactly, and one
        row less than needed overflows."""
        dtd = DTD.generate(n_tags=8, seed=3)
        d = TagDictionary()
        dtd.register(d)
        profiles = [parse("//*")] * 3 + gen_profiles(dtd, n=3, length=1,
                                                     p_desc=1.0,
                                                     p_wild=1.0, seed=3)
        docs = gen_corpus(dtd, n_docs=4, nodes_per_doc=20, seed=3)
        nfa = compile_queries(profiles, d, shared=True)
        eng = engines.create("streaming", nfa, dictionary=d,
                             minimize=True, **KERNEL_OPTS)
        batch = EventBatch.from_streams(docs, bucket=64)
        dense = eng.filter_batch(batch)
        assert dense.matched.all()
        n = eng.filter_batch_sparse(batch).meta["device_rows"]
        exact = eng.filter_batch_sparse(batch, match_cap=n)
        assert not exact.overflowed and exact.meta["device_rows"] == n
        _assert_dense_parity(exact, dense)
        spill = eng.filter_batch_sparse(batch, match_cap=n - 1)
        assert spill.overflowed
        _assert_dense_parity(spill, dense)


# --------------------------------------------------- no bitmap in HBM
class TestNoBitmapInHBM:
    def test_fused_program_outputs_only_buffer_and_counter(self):
        eng, d, docs, _ = _workload()
        batch = EventBatch.from_streams(docs, bucket=64)
        kind, tag = eng._prep(batch)
        lane_cls, _, _ = eng._plain_lane_tables(eng.plan_)
        p, meta = eng.plan_, eng.plan_.meta
        cap = 64
        doc_ids = jnp.arange(batch.batch_size, dtype=jnp.int32)[:, None]

        def fused():
            return sf.stream_filter_pallas_sparse(
                sf.fuse_events(kind, tag), doc_ids,
                p["kb_tagmask"], p["kb_pw"], p["kb_pb"],
                p["kb_selfloop"], p["kb_init"],
                p["kb_acc_word"], p["kb_acc_bit"], jnp.asarray(lane_cls),
                cap=cap, max_depth=meta["max_depth"],
                chunk=meta["chunk"], interpret=True)

        calls = _pallas_eqns(jax.make_jaxpr(fused)().jaxpr)
        assert len(calls) == 1, "fusion means ONE pallas_call"
        win = sf._epilogue_window(meta["block_queries"], 8)
        shapes = sorted(tuple(v.aval.shape) for v in calls[0].outvars)
        assert shapes == sorted([(cap + win, 3), (1, 1)]), (
            "the fused program may emit ONLY the bounded match buffer "
            f"and its counter, got {shapes}")
        assert all(len(s) != 3 for s in shapes), \
            "no (B, G, QB) accept bitmap may reach HBM"

    def test_dense_program_does_materialize_the_bitmap(self):
        """Contrast case: the unfused kernel's outputs are the dense
        per-lane buffers — what the tentpole removed from the sparse
        hot path."""
        eng, d, docs, _ = _workload()
        batch = EventBatch.from_streams(docs, bucket=64)
        kind, tag = eng._prep(batch)
        p, meta = eng.plan_, eng.plan_.meta

        def dense():
            return sf.stream_filter_pallas(
                sf.fuse_events(kind, tag),
                p["kb_tagmask"], p["kb_pw"], p["kb_pb"],
                p["kb_selfloop"], p["kb_init"],
                p["kb_acc_word"], p["kb_acc_bit"],
                max_depth=meta["max_depth"], chunk=meta["chunk"],
                interpret=True)

        calls = _pallas_eqns(jax.make_jaxpr(dense)().jaxpr)
        assert any(len(v.aval.shape) == 3 for c in calls
                   for v in c.outvars)


# ------------------------------------------------- kernel vs ref oracle
class TestKernelVsOracle:
    @pytest.mark.parametrize("grid_order", ["bg", "gb"])
    def test_event_kernel_matches_oracle_rows(self, grid_order):
        eng, d, docs, _ = _workload(seed=4, grid_order=grid_order)
        batch = EventBatch.from_streams(docs, bucket=64)
        kind, tag = eng._prep(batch)
        lane_cls, _, _ = eng._plain_lane_tables(eng.plan_)
        p, meta = eng.plan_, eng.plan_.meta
        ev = sf.fuse_events(kind, tag)
        args = (p["kb_tagmask"], p["kb_pw"], p["kb_pb"],
                p["kb_selfloop"], p["kb_init"],
                p["kb_acc_word"], p["kb_acc_bit"])
        mb, fb = sf.stream_filter_pallas(
            ev, *args, max_depth=meta["max_depth"], chunk=meta["chunk"],
            interpret=True, grid_order=grid_order)
        doc_ids = np.arange(batch.batch_size, dtype=np.int32)
        want_rows, want_n = ref.sparse_epilogue(
            np.asarray(mb) != 0, np.asarray(fb), lane_cls, doc_ids,
            10**6, grid_order=grid_order)
        for cap in (max(1, want_n - 1), want_n, want_n + 3):
            buf, cnt = sf.stream_filter_pallas_sparse(
                ev, jnp.asarray(doc_ids[:, None]), *args,
                jnp.asarray(lane_cls), cap=cap,
                max_depth=meta["max_depth"], chunk=meta["chunk"],
                interpret=True, grid_order=grid_order)
            assert int(np.asarray(cnt)[0, 0]) == want_n
            got = np.asarray(buf)[:min(want_n, cap)]
            exp, _ = ref.sparse_epilogue(
                np.asarray(mb) != 0, np.asarray(fb), lane_cls, doc_ids,
                cap, grid_order=grid_order)
            np.testing.assert_array_equal(got, exp)

    def test_bytes_kernel_matches_engine_oracle(self):
        """Segment-packed bytes launch (ragged docs sharing grid slots,
        pad slots dropped in-kernel) against the scan-engine truth."""
        eng, d, docs, _ = _workload(seed=5, pack=True)
        bb = ByteBatch.from_streams(docs, bucket=256)
        sp = eng.filter_bytes_sparse(bb, pack=True)
        assert sp.meta["path"] == "kernel-fused"
        scan = engines.create(
            "streaming", eng.nfa, dictionary=d, kernel="scan",
            minimize=True)
        _assert_dense_parity(sp, scan.filter_bytes(bb))
