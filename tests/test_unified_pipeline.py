"""Unified filtering pipeline: EventBatch, engine registry, FilterPlan.

The PR-level contract: every registered engine consumes the same
``EventBatch`` and produces the same batched ``(B, Q)`` ``FilterResult``
as the per-document oracle — and the pipeline/routing layer is
engine-agnostic.
"""
import jax
import numpy as np
import pytest

from repro.core import engines
from repro.core.dictionary import TagDictionary
from repro.core.engines import FilterResult
from repro.core.engines.matscan import exact_class
from repro.core.engines.oracle import filter_document as oracle_filter
from repro.core.events import (CLOSE, OPEN, PAD, EventBatch, EventStream,
                               bucket_length)
from repro.core.nfa import compile_queries
from repro.core.xpath import parse
from repro.data.filter_stage import FilterStage
from repro.data.generator import DTD, gen_corpus, gen_document, gen_profiles

ALL_ENGINES = ("levelwise", "matscan", "oracle", "streaming", "wavefront",
               "yfilter")


def _workload(engine: str, seed: int = 0, n_docs: int = 6, n_queries: int = 16):
    """Profiles + docs valid for ``engine`` (matscan only supports
    descendant chains with concrete tags, and its regex semantics is
    exact only on documents without nested same-tag occurrences)."""
    dtd = DTD.generate(n_tags=24, seed=seed)
    d = TagDictionary()
    dtd.register(d)
    if engine == "matscan":
        profiles = gen_profiles(dtd, n=n_queries, length=3, p_desc=1.0,
                                p_wild=0.0, seed=seed)
        # shallow documents keep the workload in matscan's exact class
        # (no nested same-tag occurrence — see matscan module docstring)
        docs = [doc for i in range(40 * n_docs)
                if exact_class(doc := gen_document(dtd, target_nodes=20,
                                                   max_depth=4,
                                                   seed=seed + i))][:n_docs]
        assert len(docs) == n_docs, "not enough exact-class documents"
    else:
        profiles = gen_profiles(dtd, n=n_queries, length=3, p_desc=0.4,
                                p_wild=0.15, seed=seed)
        docs = gen_corpus(dtd, n_docs=n_docs, nodes_per_doc=60, seed=seed)
    return profiles, docs, d


# ----------------------------------------------------------------- registry
class TestRegistry:
    def test_all_five_engines_plus_wavefront_registered(self):
        assert set(ALL_ENGINES) <= set(engines.names())

    def test_get_returns_engine_class(self):
        cls = engines.get("levelwise")
        assert issubclass(cls, engines.FilterEngine)
        assert cls.name == "levelwise"

    def test_unknown_engine_lists_known(self):
        with pytest.raises(ValueError, match="levelwise"):
            engines.get("nope")


# --------------------------------------------------------------- EventBatch
class TestEventBatch:
    def test_bucket_length(self):
        assert bucket_length(5, None) == 5
        assert bucket_length(5, 8) == 8
        assert bucket_length(8, 8) == 8
        assert bucket_length(9, 8) == 16
        assert bucket_length(0, 8) == 8

    def test_from_streams_pads_and_round_trips(self):
        dtd = DTD.generate(n_tags=8, seed=0)
        docs = gen_corpus(dtd, n_docs=5, nodes_per_doc=30, seed=0)
        batch = EventBatch.from_streams(docs, bucket=64)
        assert batch.batch_size == 5
        assert batch.length % 64 == 0
        assert batch.length >= max(len(d) for d in docs)
        for i, doc in enumerate(docs):
            got = batch.stream(i)
            np.testing.assert_array_equal(got.kind, doc.kind)
            np.testing.assert_array_equal(got.tag_id, doc.tag_id)
        # padding tail is PAD/-1/invalid
        for i, doc in enumerate(docs):
            assert (batch.kind[i, len(doc):] == PAD).all()
            assert (batch.tag_id[i, len(doc):] == -1).all()
            assert not batch.valid[i, len(doc):].any()

    def test_structure_matches_event_stream(self):
        dtd = DTD.generate(n_tags=8, seed=1)
        docs = gen_corpus(dtd, n_docs=3, nodes_per_doc=40, seed=1)
        batch = EventBatch.from_streams(docs)
        for i, doc in enumerate(docs):
            depth, parent = doc.structure()
            m = len(doc)
            np.testing.assert_array_equal(batch.depth[i, :m], depth)
            np.testing.assert_array_equal(batch.parent[i, :m], parent)

    def test_pad_to(self):
        ev = EventStream(np.array([OPEN, CLOSE], np.int8),
                         np.array([0, 0], np.int32))
        batch = EventBatch.from_streams([ev]).pad_to(16)
        assert batch.length == 16
        assert batch.n_events[0] == 2
        with pytest.raises(ValueError):
            batch.pad_to(4)


# -------------------------------------------------------------- FilterPlan
class TestFilterPlan:
    def test_plan_is_a_pytree(self):
        d = TagDictionary.build([f"t{i}" for i in range(4)])
        nfa = compile_queries([parse(p) for p in ["t0//t1", "t0/t2"]], d)
        eng = engines.create("streaming", nfa)
        leaves = jax.tree_util.tree_leaves(eng.plan_)
        assert leaves, "plan should carry device tables as pytree leaves"
        rebuilt = jax.tree_util.tree_unflatten(
            jax.tree_util.tree_structure(eng.plan_), leaves)
        assert rebuilt.meta == eng.plan_.meta
        assert sorted(rebuilt.tables) == sorted(eng.plan_.tables)

    def test_plan_is_frozen(self):
        d = TagDictionary.build(["a", "b"])
        nfa = compile_queries([parse("a//b")], d)
        eng = engines.create("levelwise", nfa)
        with pytest.raises(AttributeError):
            eng.plan_.engine = "other"


# ------------------------------------------- batched-vs-oracle equivalence
class TestBatchedEquivalence:
    """The acceptance-criteria suite: every registered engine, same
    EventBatch input, equals the per-document oracle."""

    @pytest.mark.parametrize("name", ALL_ENGINES)
    @pytest.mark.parametrize("seed", [0, 3])
    def test_filter_batch_equals_oracle(self, name, seed):
        profiles, docs, d = _workload(name, seed=seed)
        nfa = compile_queries(profiles, d, shared=True)
        eng = engines.create(name, nfa, dictionary=d)
        batch = EventBatch.from_streams(docs, bucket=32)
        res = eng.filter_batch(batch)
        assert res.batch_shape == (len(docs),)
        assert res.n_queries == len(profiles)
        for i, doc in enumerate(docs):
            want = oracle_filter(nfa, doc, d)
            np.testing.assert_array_equal(
                res[i].matched, want.matched,
                err_msg=f"{name} doc {i} matched != oracle")
            np.testing.assert_array_equal(
                res[i].first_event, want.first_event,
                err_msg=f"{name} doc {i} location != oracle")

    @pytest.mark.parametrize("name", ALL_ENGINES)
    def test_padding_is_inert(self, name):
        """Extra bucket padding must not change any engine's answer."""
        profiles, docs, d = _workload(name, seed=5, n_docs=3)
        nfa = compile_queries(profiles, d, shared=True)
        eng = engines.create(name, nfa, dictionary=d)
        tight = eng.filter_batch(EventBatch.from_streams(docs))
        padded = eng.filter_batch(
            EventBatch.from_streams(docs).pad_to(
                bucket_length(max(len(x) for x in docs) + 37, 64)))
        np.testing.assert_array_equal(tight.matched, padded.matched)
        np.testing.assert_array_equal(tight.first_event, padded.first_event)


# --------------------------------------------------------- routing parity
class TestEngineAgnosticRouting:
    """Regression for the old per-backend return-type split:
    FilterStage routing must be identical for every registered engine."""

    def _routes(self, engine):
        profiles, docs, d = _workload("matscan", seed=2, n_docs=8,
                                      n_queries=24)
        stage = FilterStage(profiles, d, n_shards=4, engine=engine,
                            batch_size=3)
        got = [r for batch in stage.route(docs) for r in batch]
        return {(r.doc_index, r.shard): tuple(r.matched_profiles)
                for r in got}

    def test_routing_identical_across_all_engines(self):
        routes = {name: self._routes(name) for name in ALL_ENGINES}
        ref = routes["oracle"]
        for name, r in routes.items():
            assert r == ref, f"routing diverged for {name}"

    def test_selectivity_engine_agnostic(self):
        profiles, docs, d = _workload("matscan", seed=2, n_docs=8)
        sel = []
        for name in ALL_ENGINES:
            stage = FilterStage(profiles, d, n_shards=2, engine=name)
            sel.append(stage.selectivity(docs))
        assert len(set(sel)) == 1

    def test_throughput_stats_accumulate(self):
        profiles, docs, d = _workload("levelwise", seed=1, n_docs=6)
        stage = FilterStage(profiles, d, n_shards=2, engine="levelwise",
                            batch_size=3)
        list(stage.route(docs))
        tp = stage.throughput()
        assert tp["docs"] == len(docs)
        assert tp["docs_per_s"] > 0
        assert tp["mb_per_s"] > 0
        assert 0.0 <= tp["selectivity"] <= 1.0


# ------------------------------------------------- kernel padding bugfix
class TestKernelStatePadding:
    def test_nfa_transition_pads_state_axis(self):
        """n_states not a multiple of bs used to raise; now padded+sliced."""
        import jax.numpy as jnp

        from repro.kernels import ref
        from repro.kernels.nfa_transition import nfa_transition_pallas

        rng = np.random.default_rng(7)
        w, s, t = 12, 192, 9   # 192 % 128 != 0
        parent = (rng.random((w, s)) < 0.3).astype(np.float32)
        tags = rng.integers(-1, t, size=w).astype(np.int32)
        req = (rng.random((t, s)) < 0.1).astype(np.float32)
        wild = (rng.random(s) < 0.05).astype(np.float32)
        in_state = rng.integers(0, s, size=s).astype(np.int32)
        p1h = np.zeros((s, s), np.float32)
        p1h[in_state, np.arange(s)] = 1
        sl = (rng.random(s) < 0.2).astype(np.float32)
        args = [jnp.asarray(x) for x in (parent, tags, req, wild, p1h, sl)]
        got = nfa_transition_pallas(*args, bs=128, interpret=True)
        want = ref.nfa_transition(*args)
        assert got.shape == (w, s)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want))


# ------------------------------------------------------- batched results
class TestFilterResultBatch:
    def test_stack_index_iterate(self):
        a = FilterResult(np.array([True, False]), np.array([1, 2**31 - 1]))
        b = FilterResult(np.array([False, True]), np.array([2**31 - 1, 5]))
        batched = FilterResult.stack([a, b])
        assert batched.batch_shape == (2,)
        assert len(batched) == 2
        assert batched[0] == a
        docs = list(batched.per_document())
        assert docs[1] == b
        with pytest.raises(TypeError):
            a.__getitem__(0)
        with pytest.raises(TypeError):
            batched.matching_queries()
