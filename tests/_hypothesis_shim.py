"""Import-safe hypothesis shim.

``hypothesis`` is an optional dev dependency (see requirements-dev.txt).
Importing ``given``/``settings``/``st`` from here keeps test *modules*
importable without it: property-based tests are skipped cleanly instead
of erroring the whole module at collection time (which also broke
modules that merely import helpers from a hypothesis-using module).

With hypothesis installed, this is a pass-through re-export.
"""
from __future__ import annotations

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised when hypothesis absent
    import pytest

    HAVE_HYPOTHESIS = False

    def given(*_a, **_k):
        def deco(_fn):
            @pytest.mark.skip(reason="hypothesis not installed")
            def skipped(*args, **kwargs):  # pragma: no cover
                pass

            return skipped

        return deco

    def settings(*_a, **_k):
        def deco(fn):
            return fn

        return deco

    class _AnyStrategy:
        """Stands in for any `st.*` strategy builder; the decorated test
        body never runs, so the placeholder value is never used."""

        def __call__(self, *a, **k):
            return self

        def __getattr__(self, name):
            return self

    st = _AnyStrategy()
