"""Segment-packing: the one-launch bytes path on dense ragged batches.

``core.events.pack_segments`` concatenates a ragged :class:`ByteBatch`
into dense segments (per-segment doc-id/boundary tables); the fused
megakernel resets its stack/accept state at every document boundary and
the host scatters accept lanes back to ``(B, Q)``.  Every packed result
must be *bit-identical* to the unpacked scan oracle — including
all-PAD/empty docs, single-event docs and docs longer than the segment
target — across the plain, sharded and 2-D mesh bytes paths.  The
measured-autotune cache (``kernels.autotune``) and the VMEM/SMEM budget
env overrides ride along.
"""
import os
import sys

import numpy as np
import pytest

sys.path.insert(0, os.path.dirname(__file__))

from _hypothesis_shim import given, settings, st  # noqa: E402
from test_megakernel import (MODES, assert_same, engine_pair,  # noqa: E402
                             workload)

from repro.core import engines  # noqa: E402
from repro.core.engines.base import FilterEngine  # noqa: E402
from repro.core.events import (CLOSE, OPEN, ByteBatch, EventStream,  # noqa: E402
                               SEG_SENTINEL, encode_bytes, pack_segments)
from repro.data.generator import gen_corpus  # noqa: E402


def _single_event_doc(d, dtd):
    """One lone open event — the smallest non-empty document."""
    tid = d.lookup(dtd.tag_names[0])
    return EventStream(np.array([OPEN], np.int8),
                       np.array([tid], np.int32))


def _ragged_bb(dtd, d, seed, bucket=128):
    """The ISSUE's worst-case mix: one doc longer than the segment
    target, several tiny docs, a single-event doc and empty (all-PAD)
    docs."""
    docs = (gen_corpus(dtd, n_docs=1, nodes_per_doc=90, seed=seed)
            + gen_corpus(dtd, n_docs=4, nodes_per_doc=3, seed=seed + 1))
    bufs = ([encode_bytes(docs[0], text_fill=4)]
            + [b""]
            + [encode_bytes(x, text_fill=2) for x in docs[1:]]
            + [encode_bytes(_single_event_doc(d, dtd)), b""])
    return ByteBatch.from_buffers(bufs, bucket=bucket)


# ----------------------------------------------------------- host packer
class TestPackSegments:
    def test_bytes_preserved_and_tables_consistent(self):
        dtd, d, qs, nfa = workload(n_queries=8, seed=0)
        bb = _ragged_bb(dtd, d, seed=0)
        sp = pack_segments(bb, target_len=256)
        data = np.asarray(bb.data)
        lengths = np.asarray(bb.n_bytes)
        seen = set()
        for s in range(sp.n_segments):
            for j in range(sp.docs_per_segment):
                doc = int(sp.doc_ids[s, j])
                if doc < 0:
                    continue
                a, b = int(sp.starts[s, j]), int(sp.starts[s, j + 1])
                if b == SEG_SENTINEL:  # last real doc: sentinel wall
                    b = a + int(lengths[doc])
                assert b - a == int(lengths[doc]) and b <= sp.seg_len
                np.testing.assert_array_equal(
                    sp.data[s, a:b], data[doc, :lengths[doc]])
                seen.add(doc)
        # every non-empty doc appears exactly once; empty docs never do
        assert seen == {i for i in range(bb.batch_size) if lengths[i]}
        # boundary table ends in the sentinel wall
        for s in range(sp.n_segments):
            row = sp.starts[s]
            n_real = int((sp.doc_ids[s] >= 0).sum())
            assert (row[n_real:] == SEG_SENTINEL).all() or n_real == 0

    def test_doc_longer_than_target_gets_a_segment(self):
        dtd, d, qs, nfa = workload(n_queries=8, seed=1)
        bb = _ragged_bb(dtd, d, seed=1)
        sp = pack_segments(bb, target_len=64)  # far below the long doc
        assert sp.seg_len >= int(np.asarray(bb.n_bytes).max())
        assert 0 < sp.fill_fraction() <= 1.0

    def test_all_empty_batch_is_one_inert_segment(self):
        bb = ByteBatch.from_buffers([b"", b"", b""], bucket=32)
        sp = pack_segments(bb, target_len=128)
        assert sp.n_segments == 1
        assert (np.asarray(sp.doc_ids) < 0).all()
        m, f = sp.scatter(np.zeros((1, sp.docs_per_segment, 4), np.int32),
                          np.zeros((1, sp.docs_per_segment, 4), np.int32),
                          -1)
        assert m.shape == (3, 4) and not m.any() and (f == -1).all()

    def test_packing_is_denser_than_padding_on_skew(self):
        dtd, d, qs, nfa = workload(n_queries=8, seed=2)
        bb = _ragged_bb(dtd, d, seed=2, bucket=1024)
        sp = pack_segments(bb, target_len=2048)
        assert sp.data.size < np.asarray(bb.data).size


# --------------------------------------------------- packed == oracle
class TestPackedBitIdentity:
    @pytest.mark.parametrize("interpret", MODES)
    @pytest.mark.parametrize("seed", [0, 3])
    def test_plain_bytes_path(self, interpret, seed):
        dtd, d, qs, nfa = workload(n_queries=24, seed=seed)
        bb = _ragged_bb(dtd, d, seed=seed)
        scan, pallas = engine_pair(nfa, d, interpret, segment_target=256)
        oracle = scan.filter_bytes(bb)
        assert_same(oracle, pallas.filter_bytes(bb))            # fused
        assert_same(oracle, pallas.filter_bytes(bb, pack=True))  # packed
        # the two-stage comparison path stays available and identical
        _, unfused = engine_pair(nfa, d, interpret, fuse=False)
        assert_same(oracle, unfused.filter_bytes(bb))

    @pytest.mark.parametrize("interpret", MODES)
    def test_sharded_bytes_path(self, interpret):
        dtd, d, qs, nfa = workload(n_queries=20, seed=4)
        bb = _ragged_bb(dtd, d, seed=4)
        scan, pallas = engine_pair(nfa, d, interpret,
                                   pack=True, segment_target=256)
        o = scan.filter_bytes_sharded(bb, scan.plan_sharded(2))
        assert_same(o, pallas.filter_bytes_sharded(
            bb, pallas.plan_sharded(2)))

    @pytest.mark.parametrize("interpret", MODES)
    def test_mesh2d_bytes_path(self, interpret):
        from repro.launch.mesh import make_filter_mesh

        dtd, d, qs, nfa = workload(n_queries=16, seed=5)
        bb = _ragged_bb(dtd, d, seed=5)
        scan, pallas = engine_pair(nfa, d, interpret,
                                   pack=True, segment_target=256)
        mesh = make_filter_mesh(2)
        o = scan.filter_bytes_sharded2d(bb, scan.plan_sharded(2),
                                        mesh=mesh)
        assert_same(o, pallas.filter_bytes_sharded2d(
            bb, pallas.plan_sharded(2), mesh=mesh))

    @given(n_tiny=st.integers(min_value=0, max_value=5),
           n_empty=st.integers(min_value=0, max_value=3),
           target=st.sampled_from([128, 512]),
           seed=st.integers(min_value=0, max_value=3))
    @settings(max_examples=10, deadline=None)
    def test_pack_filter_scatter_roundtrip(self, n_tiny, n_empty,
                                           target, seed):
        """Property: packing is invisible — for any ragged mix, the
        packed fused verdict equals the unpacked fused verdict."""
        dtd, d, qs, nfa = workload(n_queries=12, seed=seed)
        docs = gen_corpus(dtd, n_docs=1, nodes_per_doc=40, seed=seed)
        if n_tiny:
            docs += gen_corpus(dtd, n_docs=n_tiny, nodes_per_doc=2,
                               seed=seed + 1)
        bufs = [encode_bytes(x, text_fill=2) for x in docs] \
            + [b""] * n_empty
        bb = ByteBatch.from_buffers(bufs, bucket=64)
        _, pallas = engine_pair(nfa, d, True, segment_target=target)
        assert_same(pallas.filter_bytes(bb),
                    pallas.filter_bytes(bb, pack=True))


# ------------------------------------------------ autotune loop + budgets
class TestMeasuredAutotune:
    def test_cache_round_trip(self, tmp_path):
        from repro.kernels import autotune as at

        path = str(tmp_path / "cache.json")
        key = at.plan_key("interpret", 64, 14, 64, 32)
        cfg = {"blk": 32, "byte_chunk": 64, "grid_order": "gb",
               "segment_target": 256}
        at.save_cache({key: {"config": cfg, "seconds": 0.5,
                             "trials": 1, "timestamp": 0}}, path)
        assert at.cached_config(key, path) == cfg
        assert at.cached_config("missing:key", path) is None
        # corrupt files degrade to a miss, never an error
        with open(path, "w") as fh:
            fh.write("not json")
        assert at.load_cache(path) == {}

    def test_search_persists_and_engine_consumes(self, tmp_path,
                                                 monkeypatch):
        from repro.kernels import autotune as at

        cache = str(tmp_path / "cache.json")
        dtd, d, qs, nfa = workload(n_queries=8, seed=6)
        docs = gen_corpus(dtd, n_docs=3, nodes_per_doc=8, seed=6)
        bb = ByteBatch.from_streams(docs, text_fill=2, bucket=64)
        best, rows = at.search(
            nfa, d, bb, blks=(32,), byte_chunks=(64,),
            grid_orders=("gb",), segment_targets=(256,),
            trials=1, interpret=True, cache_file=cache)
        assert best["grid_order"] == "gb" and best["seconds"] > 0
        assert [r for r in rows if "seconds" in r]
        # an engine with autotune="measured" overlays the cached winner
        monkeypatch.setenv(at.CACHE_ENV, cache)
        eng = engines.create("streaming", nfa, dictionary=d,
                             kernel="pallas", kernel_interpret=True,
                             autotune="measured")
        meta = eng.plan_.meta
        assert (meta["byte_chunk"], meta["grid_order"],
                meta["segment_target"]) == (64, "gb", 256)
        # explicit engine options still beat the measured overlay
        eng2 = engines.create("streaming", nfa, dictionary=d,
                              kernel="pallas", kernel_interpret=True,
                              autotune="measured", byte_chunk=128)
        assert eng2.plan_.meta["byte_chunk"] == 128

    def test_budget_env_overrides(self, monkeypatch):
        wide = FilterEngine.autotune_blocks(4096, 64, n_tags=4096)
        monkeypatch.setenv("REPRO_PALLAS_VMEM_BUDGET", str(128 << 10))
        tight = FilterEngine.autotune_blocks(4096, 64, n_tags=4096)
        assert tight["blk"] < wide["blk"]
        monkeypatch.setenv("REPRO_PALLAS_SMEM_BUDGET", "512")
        assert FilterEngine.autotune_blocks(
            256, 64, n_tags=16)["chunk"] == 64
        # explicit kwargs always beat the environment
        assert FilterEngine.autotune_blocks(
            4096, 64, n_tags=4096,
            vmem_budget=4 << 20)["blk"] == wide["blk"]


# ------------------------------------------------- regression-gate policy
class TestCompareBaselineGate:
    def test_speedup_gated_only_on_compiled_rows(self):
        sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..",
                                        "benchmarks"))
        import compare_baseline as cb

        assert "speedup_vs_scan" not in cb.gated_metrics(
            {"backend": "interpret"})
        assert "speedup_vs_scan" in cb.gated_metrics(
            {"backend": "compiled"})
        base = {"bench": "kernel_vs_scan", "backend": "interpret",
                "path": "pallas", "docs_per_s": 10.0, "mb_s": 1.0,
                "speedup_vs_scan": 1.0}
        fresh = dict(base, speedup_vs_scan=0.2)  # huge ratio drop
        b = {cb.row_key(base): base}
        f = {cb.row_key(fresh): fresh}
        table, regressions = cb.compare(b, f, threshold=0.25)
        assert not regressions  # interpret rows never gate the ratio
        base_c = dict(base, backend="compiled")
        fresh_c = dict(fresh, backend="compiled")
        table, regressions = cb.compare(
            {cb.row_key(base_c): base_c},
            {cb.row_key(fresh_c): fresh_c}, threshold=0.25)
        assert any(m == "speedup_vs_scan" for _, m, *_ in regressions)
