"""Per-architecture smoke tests: reduced config, one forward/train step.

Every assigned architecture instantiates a smoke-sized config of the same
family, runs train_loss + grad and a prefill→decode round, and asserts
output shapes and finiteness.  The FULL configs are exercised only via
the dry-run (ShapeDtypeStruct — see launch/dryrun.py).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config
from repro.models import transformer as T
from repro.models.config import ModelConfig

BATCH, SEQ = 2, 16


def make_batch(cfg: ModelConfig, key=0):
    rng = np.random.default_rng(key)
    b = {
        "tokens": rng.integers(0, cfg.vocab, (BATCH, SEQ)).astype(np.int32),
        "labels": rng.integers(0, cfg.vocab, (BATCH, SEQ)).astype(np.int32),
    }
    if cfg.family == "vlm":
        b["patches"] = rng.normal(
            size=(BATCH, cfg.frontend_len, cfg.d_model)).astype(np.float32)
    if cfg.family == "encdec":
        b["frames"] = rng.normal(
            size=(BATCH, cfg.frontend_len, cfg.d_model)).astype(np.float32)
    return b


@pytest.fixture(scope="module")
def built():
    cache = {}

    def build(name):
        if name not in cache:
            cfg = get_config(name, reduced=True)
            params = T.init_model(cfg, jax.random.PRNGKey(0))
            cache[name] = (cfg, params)
        return cache[name]

    return build


@pytest.mark.parametrize("name", ARCHS)
def test_train_step_finite(name, built):
    cfg, params = built(name)
    batch = make_batch(cfg)
    loss, metrics = T.train_loss(cfg, params, batch)
    assert np.isfinite(float(loss)), (name, metrics)
    grads = jax.grad(lambda p: T.train_loss(cfg, p, batch)[0])(params)
    leaves = jax.tree.leaves(grads)
    assert all(np.isfinite(np.asarray(g)).all() for g in leaves), name
    # at least one non-trivial gradient
    assert any(float(jnp.abs(g).max()) > 0 for g in leaves), name


@pytest.mark.parametrize("name", ARCHS)
def test_logits_shape(name, built):
    cfg, params = built(name)
    batch = make_batch(cfg)
    logits, _ = T.forward_logits(cfg, params, batch)
    extra = cfg.frontend_len if cfg.family == "vlm" else 0
    assert logits.shape == (BATCH, SEQ + extra, cfg.vocab_eff), name
    assert np.isfinite(np.asarray(logits)).all(), name


@pytest.mark.parametrize("name", ARCHS)
def test_prefill_decode_consistency(name, built):
    """Prefill on S tokens then decode token S must equal the full
    forward at position S — validates every cache implementation."""
    cfg, params = built(name)
    batch = make_batch(cfg)
    toks = batch["tokens"]
    max_len = SEQ + 4 + (cfg.frontend_len if cfg.family == "vlm" else 0)
    caches = T.init_cache(cfg, BATCH, max_len, dtype=jnp.float32)

    pre_batch = dict(batch)
    pre_batch["tokens"] = toks[:, :SEQ - 1]
    _, caches = T.prefill(cfg, params, pre_batch, caches)
    pos = SEQ - 1 + (cfg.frontend_len if cfg.family == "vlm" else 0)
    dec_logits, _ = T.decode_step(cfg, params, toks[:, SEQ - 1:SEQ],
                                  caches, jnp.int32(pos))
    full_logits, _ = T.forward_logits(cfg, params, batch)
    want = np.asarray(full_logits[:, -1, :cfg.vocab])
    got = np.asarray(dec_logits[:, -1, :cfg.vocab])
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4,
                               err_msg=name)


def test_head_padding_is_inert():
    """Padded configs (TP=16 geometry) must match unpadded outputs when
    the padded parameter slices coincide with the real ones."""
    cfg = get_config("starcoder2-7b", reduced=True)
    # reduced starcoder2: 4 heads, kv 2 — pad to tp=3 geometry
    cfg_pad = cfg.with_(pad_heads_to=8)
    assert cfg_pad.n_heads_eff >= cfg.n_heads
    params = T.init_model(cfg_pad, jax.random.PRNGKey(1))
    batch = make_batch(cfg_pad)
    logits_pad, _ = T.forward_logits(cfg_pad, params, batch)
    assert np.isfinite(np.asarray(logits_pad)).all()
    # gradients to masked q-head slices must be exactly zero
    def loss_fn(p):
        return T.train_loss(cfg_pad, p, batch)[0]
    grads = jax.grad(loss_fn)(params)

    h_eff, kv_eff, factor, g_eff = cfg_pad._head_geometry()
    g = cfg_pad.n_heads // cfg_pad.n_kv_heads
    per = factor * g_eff
    mask = np.tile(np.arange(per) < g, cfg_pad.n_kv_heads)
    wq_grad = np.asarray(grads["layers"]["attn"]["wq"])  # (L, d, h_eff, dh)
    assert np.abs(wq_grad[:, :, ~mask, :]).max() == 0.0
    assert np.abs(wq_grad[:, :, mask, :]).max() > 0.0


def test_param_count_sanity():
    """Full-config param counts land near the published sizes."""
    approx = {
        "deepseek-coder-33b": (33e9, 0.15),
        "qwen1.5-110b": (111e9, 0.15),
        "starcoder2-7b": (7e9, 0.25),
        "internvl2-76b": (76e9, 0.20),
        "mamba2-780m": (0.78e9, 0.30),
        "deepseek-v3-671b": (671e9, 0.15),
        "qwen3-moe-30b-a3b": (30e9, 0.20),
    }
    for name, (want, tol) in approx.items():
        got = get_config(name).param_count()
        assert abs(got - want) / want < tol, (name, got, want)
