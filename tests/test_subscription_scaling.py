"""Subscription-axis scale-up: minimization, sparse verdicts, rebalance.

PR-level contract, three legs:

* **Global NFA minimization** (``repro.core.nfa.minimize``, the
  ``minimize=True`` engine option): merging behavior-identical states
  and deduplicating accept lanes must be invisible in the verdicts —
  minimize → plan → filter is bit-identical to the *unminimized* dense
  oracle on every path (plain, sharded, 2-D mesh, bytes).
* **Sparse verdict delivery** (``filter_batch_sparse`` family): the
  bounded (doc_id, query_id, first_event) match list densifies back to
  the dense bitmap exactly; overflowing the match buffer falls back to
  dense recomputation (exact, flagged) instead of dropping matches.
* **Live shard rebalancing** (``ShardedPlan.rebalance``): migrating trie
  groups between parts off the hot path reduces imbalance, preserves
  every live global id, and leaves verdicts equal to a fresh compile —
  including under a 50-op churn sequence with periodic auto-rebalance.
"""
import numpy as np
import pytest

from _hypothesis_shim import given, settings, st

from repro.core import engines
from repro.core.dictionary import TagDictionary
from repro.core.engines.matscan import exact_class
from repro.core.engines.result import NO_MATCH, FilterResult, SparseResult
from repro.core.events import ByteBatch, EventBatch, encode_bytes
from repro.core.nfa import compile_queries, minimize, unshared_state_count
from repro.core.xpath import parse
from repro.data.filter_stage import FilterStage
from repro.data.generator import DTD, gen_corpus, gen_document, gen_profiles
from repro.launch.mesh import make_filter_mesh

ALL_ENGINES = ("levelwise", "matscan", "oracle", "streaming", "wavefront",
               "yfilter")
DEVICE_ENGINES = ("levelwise", "matscan", "streaming", "wavefront")


def _workload(engine: str, seed: int = 0, n_docs: int = 5,
              n_queries: int = 18):
    """Profiles + docs valid for ``engine`` (matscan: descendant-only
    concrete-tag profiles on exact-class documents)."""
    dtd = DTD.generate(n_tags=24, seed=seed)
    d = TagDictionary()
    dtd.register(d)
    if engine == "matscan":
        profiles = gen_profiles(dtd, n=n_queries, length=3, p_desc=1.0,
                                p_wild=0.0, seed=seed)
        docs = [doc for i in range(40 * n_docs)
                if exact_class(doc := gen_document(dtd, target_nodes=20,
                                                   max_depth=4,
                                                   seed=seed + i))][:n_docs]
        assert len(docs) == n_docs, "not enough exact-class documents"
    else:
        profiles = gen_profiles(dtd, n=n_queries, length=3, p_desc=0.4,
                                p_wild=0.15, seed=seed)
        docs = gen_corpus(dtd, n_docs=n_docs, nodes_per_doc=60, seed=seed)
    return profiles, docs, d


def _oracle_dense(profiles, d, batch) -> FilterResult:
    """Ground truth: UNminimized oracle over the same batch."""
    nfa = compile_queries(profiles, d, shared=True)
    return engines.create("oracle", nfa, dictionary=d).filter_batch(batch)


def _assert_same(res: FilterResult, want: FilterResult) -> None:
    np.testing.assert_array_equal(res.matched, want.matched)
    np.testing.assert_array_equal(res.first_event, want.first_event)


# ---------------------------------------------------- global minimization
class TestMinimize:
    def _nfa(self, n=24, seed=0, dup=False):
        dtd = DTD.generate(n_tags=24, seed=seed)
        d = TagDictionary()
        dtd.register(d)
        qs = gen_profiles(dtd, n=n, length=3, seed=seed)
        if dup:  # duplicate subscriptions — the accept-lane dedup case
            qs = qs + qs
        return compile_queries(qs, d, shared=True), d, qs

    def test_stats_shape_and_idempotence(self):
        nfa, _, qs = self._nfa()
        m1, s1 = minimize(nfa)
        assert s1.states_before == nfa.n_states
        assert s1.states_after == m1.n_states <= nfa.n_states
        assert s1.unshared_states == unshared_state_count(nfa.queries)
        assert s1.compression >= 1.0
        m2, s2 = minimize(m1)
        assert s2.states_after == s2.states_before == m1.n_states

    def test_duplicate_profiles_share_accept_classes(self):
        """Two copies of every subscription: the minimized automaton has
        one accept class per *distinct* profile — beyond-trie sharing
        (the trie alone keeps duplicate queries on duplicate lanes)."""
        nfa, _, qs = self._nfa(dup=True)
        _, stats = minimize(nfa)
        assert stats.accept_classes <= len(qs) // 2
        # compression vs the paper's Unop per-profile-blocks baseline
        assert stats.compression >= 2.0

    def test_engine_option_records_stats(self):
        nfa, d, _ = self._nfa()
        eng = engines.create("streaming", nfa, dictionary=d, minimize=True)
        assert eng.minimize_stats is not None
        assert eng.nfa.n_states == eng.minimize_stats.states_after
        off = engines.create("streaming", nfa, dictionary=d)
        assert off.minimize_stats is None

    @given(seed=st.integers(0, 30), n=st.integers(2, 40))
    @settings(max_examples=12, deadline=None)
    def test_property_minimized_equals_unminimized_oracle(self, seed, n):
        """Hypothesis leg of the acceptance bar: random profile sets,
        minimize → plan → filter ≡ unminimized dense oracle."""
        profiles, docs, d = _workload("streaming", seed=seed, n_docs=3,
                                      n_queries=n)
        batch = EventBatch.from_streams(docs, bucket=64)
        want = _oracle_dense(profiles, d, batch)
        nfa = compile_queries(profiles, d, shared=True)
        eng = engines.create("streaming", nfa, dictionary=d, minimize=True)
        _assert_same(eng.filter_batch(batch), want)


class TestMinimizedEquivalence:
    """minimize=True is invisible on every execution path."""

    @pytest.mark.parametrize("name", ALL_ENGINES)
    def test_plain(self, name):
        profiles, docs, d = _workload(name)
        batch = EventBatch.from_streams(docs, bucket=64)
        nfa = compile_queries(profiles, d, shared=True)
        eng = engines.create(name, nfa, dictionary=d, minimize=True)
        _assert_same(eng.filter_batch(batch), _oracle_dense(profiles, d,
                                                            batch))

    @pytest.mark.parametrize("name", ALL_ENGINES)
    def test_sharded(self, name):
        profiles, docs, d = _workload(name)
        batch = EventBatch.from_streams(docs, bucket=64)
        nfa = compile_queries(profiles, d, shared=True)
        eng = engines.create(name, nfa, dictionary=d, minimize=True)
        sp = eng.plan_sharded(3)
        _assert_same(eng.filter_batch_sharded(batch, sp),
                     _oracle_dense(profiles, d, batch))

    def test_sharded_mesh_2d(self):
        profiles, docs, d = _workload("streaming", n_docs=6)
        batch = EventBatch.from_streams(docs, bucket=64)
        nfa = compile_queries(profiles, d, shared=True)
        eng = engines.create("streaming", nfa, dictionary=d, minimize=True)
        sp = eng.plan_sharded(2)
        mesh = make_filter_mesh(2, data_shards=2)
        _assert_same(eng.filter_batch_sharded2d(batch, sp, mesh=mesh),
                     _oracle_dense(profiles, d, batch))

    def test_bytes(self):
        profiles, docs, d = _workload("streaming")
        bb = ByteBatch.from_streams(docs, bucket=256)
        batch = EventBatch.from_streams(docs, bucket=64)
        nfa = compile_queries(profiles, d, shared=True)
        eng = engines.create("streaming", nfa, dictionary=d, minimize=True)
        _assert_same(eng.filter_bytes(bb),
                     _oracle_dense(profiles, d, batch))


# ------------------------------------------------- sparse verdict delivery
class TestSparseVerdicts:
    @pytest.mark.parametrize("name", ALL_ENGINES)
    @pytest.mark.parametrize("minimized", (False, True))
    def test_plain_round_trip(self, name, minimized):
        profiles, docs, d = _workload(name)
        batch = EventBatch.from_streams(docs, bucket=64)
        nfa = compile_queries(profiles, d, shared=True)
        eng = engines.create(name, nfa, dictionary=d, minimize=minimized)
        dense = eng.filter_batch(batch)
        sp = eng.filter_batch_sparse(batch)
        assert isinstance(sp, SparseResult) and not sp.overflowed
        _assert_same(sp.densify(), dense)
        assert sp.verdict_bytes == 12 * sp.n_matches <= sp.dense_bytes
        assert sp.selectivity() == dense.selectivity()

    @pytest.mark.parametrize("name", ALL_ENGINES)
    def test_sharded_round_trip_with_churn(self, name):
        """Global ids survive the sparse wire format across a churned
        (tombstoned) sharded plan."""
        profiles, docs, d = _workload(name)
        batch = EventBatch.from_streams(docs, bucket=64)
        nfa = compile_queries(profiles, d, shared=True)
        eng = engines.create(name, nfa, dictionary=d, minimize=True)
        sharded = eng.plan_sharded(3).remove_queries([1, 4])
        dense = eng.filter_batch_sharded(batch, sharded)
        sp = eng.filter_batch_sharded_sparse(batch, sharded)
        assert np.array_equal(sp.live_ids, sharded.live_ids())
        _assert_same(sp.densify(), dense)
        # match list is (doc, global id) sorted and within the live set
        assert all(int(g) in set(map(int, sp.live_ids))
                   for g in sp.query_ids)

    @pytest.mark.parametrize("name", DEVICE_ENGINES)
    def test_overflow_falls_back_to_dense(self, name):
        """A match buffer smaller than the match count must not lose
        matches: device engines recompute dense and flag ``overflowed``."""
        profiles, docs, d = _workload(name)
        batch = EventBatch.from_streams(docs, bucket=64)
        nfa = compile_queries(profiles, d, shared=True)
        eng = engines.create(name, nfa, dictionary=d)
        dense = eng.filter_batch(batch)
        assert int(dense.matched.sum()) > 1, "workload must match"
        sp = eng.filter_batch_sparse(batch, match_cap=1)
        if eng.device_sharded:
            assert sp.overflowed
        _assert_same(sp.densify(), dense)

    def test_match_cap_resolution(self):
        profiles, _, d = _workload("streaming")
        nfa = compile_queries(profiles, d, shared=True)
        eng = engines.create("streaming", nfa, dictionary=d, match_cap=64)
        assert eng.match_cap(8, 100) == 64          # engine option
        assert eng.match_cap(8, 100, cap=7) == 7    # explicit wins
        assert eng.match_cap(2, 3, cap=10**9) == 6  # clamped to dense
        no_opt = engines.create("streaming", nfa, dictionary=d)
        assert no_opt.match_cap(8, 10_000) == 4096  # floor default
        assert no_opt.match_cap(8, 100) == 800      # dense clamp again

    def test_kernel_sparse_is_many_to_one(self):
        """The megakernel sparse path compacts in accept-*class* space:
        with duplicated subscriptions the device emits fewer rows than
        the expanded per-subscriber match list — on BOTH kernel routes
        (fused in-kernel epilogue and two-launch lane compaction)."""
        profiles, docs, d = _workload("streaming", n_queries=9)
        profiles = profiles + profiles        # every class has ≥ 2 members
        batch = EventBatch.from_streams(docs, bucket=64)
        nfa = compile_queries(profiles, d, shared=True)
        eng = engines.create("streaming", nfa, dictionary=d, minimize=True,
                             kernel="pallas", kernel_interpret=True)
        dense = eng.filter_batch(batch)
        sp = eng.filter_batch_sparse(batch)
        assert sp.meta["path"] == "kernel-fused"
        _assert_same(sp.densify(), dense)
        if sp.n_matches:
            assert sp.meta["device_rows"] < sp.n_matches
        lane = engines.create(
            "streaming", nfa, dictionary=d, minimize=True,
            kernel="pallas", kernel_interpret=True, sparse_epilogue="off")
        sp2 = lane.filter_batch_sparse(batch)
        assert sp2.meta["path"] == "lane-compact"
        _assert_same(sp2.densify(), dense)
        if sp2.n_matches:
            assert sp2.meta["device_rows"] < sp2.n_matches

    def test_kernel_sparse_sharded(self):
        profiles, docs, d = _workload("streaming")
        batch = EventBatch.from_streams(docs, bucket=64)
        nfa = compile_queries(profiles, d, shared=True)
        eng = engines.create("streaming", nfa, dictionary=d, minimize=True,
                             kernel="pallas", kernel_interpret=True)
        sharded = eng.plan_sharded(3).remove_queries([2])
        dense = eng.filter_batch_sharded(batch, sharded)
        sp = eng.filter_batch_sharded_sparse(batch, sharded)
        assert sp.meta["path"] == "kernel-fused"
        _assert_same(sp.densify(), dense)
        lane = engines.create(
            "streaming", nfa, dictionary=d, minimize=True,
            kernel="pallas", kernel_interpret=True, sparse_epilogue="off")
        sp2 = lane.filter_batch_sharded_sparse(batch, sharded)
        assert sp2.meta["path"] == "lane-compact"
        _assert_same(sp2.densify(), dense)


# ------------------------------------------------------ S1: live-mask math
class TestLiveMaskAccounting:
    def test_selectivity_excludes_tombstones(self):
        matched = np.array([[True, False, True, False]])
        first = np.where(matched, 3, NO_MATCH).astype(np.int32)
        live = np.array([True, True, False, False])
        res = FilterResult(matched, first, live=live)
        assert res.n_live == 2
        # dead column 2's stale True must not count anywhere
        assert res.selectivity() == 0.5
        assert list(res[0].matching_queries()) == [0]

    def test_sparsify_round_trip_keeps_live_mask(self):
        matched = np.array([[True, False, True]])
        first = np.where(matched, 1, NO_MATCH).astype(np.int32)
        res = FilterResult(matched, first,
                           live=np.array([True, True, False]))
        sp = res.sparsify()
        assert sp.n_matches == 1 and sp.selectivity() == res.selectivity()
        back = sp.densify()
        assert back.matched[0, 0] and not back.matched[0, 2]


# ------------------------------------------------------- shard rebalancing
class TestRebalance:
    def _skewed(self, engine="streaming", n_parts=4, seed=3):
        """A 4-part plan churned until part 0 holds all the weight."""
        profiles, docs, d = _workload(engine, seed=seed, n_queries=24)
        batch = EventBatch.from_streams(docs, bucket=64)
        nfa = compile_queries(profiles, d, shared=True)
        eng = engines.create(engine, nfa, dictionary=d, minimize=True)
        sp = eng.plan_sharded(n_parts)
        drop = [int(g) for g in sp.live_ids()
                if int(sp.partition.part_of[g]) != 0]
        if len(drop) == len(sp.live_ids()):  # keep at least one query
            drop = drop[:-1]
        return eng, sp.remove_queries(drop), d, batch

    def test_rebalance_reduces_imbalance(self):
        eng, sp, _, _ = self._skewed()
        before = sp.imbalance()
        assert before > 0.25, "setup must be skewed"
        new, stats = sp.rebalance(tolerance=0.25)
        assert stats["moves"] > 0 and stats["moved_queries"] > 0
        assert stats["imbalance_after"] < stats["imbalance_before"]
        assert new.imbalance() < before
        w = new.part_weights()
        assert w.max() > 0 and (w > 0).sum() > 1, "load must spread"

    @pytest.mark.parametrize("engine", ("streaming", "oracle"))
    def test_rebalance_preserves_verdicts_and_ids(self, engine):
        eng, sp, d, batch = self._skewed(engine)
        want = eng.filter_batch_sharded(batch, sp)
        new, stats = sp.rebalance()
        assert np.array_equal(new.live_ids(), sp.live_ids()), \
            "rebalance must not change the subscriber set"
        _assert_same(eng.filter_batch_sharded(batch, new), want)
        # the old plan stays usable — the swap is atomic, not in-place
        _assert_same(eng.filter_batch_sharded(batch, sp), want)
        # sparse delivery agrees across the move too
        _assert_same(eng.filter_batch_sharded_sparse(batch, new).densify(),
                     want)

    def test_rebalance_splits_monolithic_groups(self):
        """When one trie group outweighs the inter-part gap the balancer
        must split it at query granularity — prefix co-location is a
        heuristic, not a correctness invariant."""
        dtd = DTD.generate(n_tags=24, seed=0)
        d = TagDictionary()
        dtd.register(d)
        tag = dtd.tag_names[0]
        qs = [parse(f"/{tag}/{dtd.tag_names[1 + i % 6]}"
                    + ("//" + dtd.tag_names[2 + i % 5] if i % 2 else ""))
              for i in range(16)]     # ONE shared first step → one group
        nfa = compile_queries(qs, d, shared=True)
        eng = engines.create("streaming", nfa, dictionary=d)
        sp = eng.plan_sharded(4)
        new, stats = sp.rebalance(tolerance=0.25)
        if sp.imbalance() > 0.25:
            assert new.imbalance() < sp.imbalance()
            assert stats["moved_queries"] > 0

    def test_balanced_plan_is_a_noop(self):
        profiles, docs, d = _workload("streaming")
        nfa = compile_queries(profiles, d, shared=True)
        eng = engines.create("streaming", nfa, dictionary=d)
        sp = eng.plan_sharded(3)
        new, stats = sp.rebalance(tolerance=10.0)
        assert stats["moves"] == 0 and new is sp


# ----------------------------------------- churn + rebalance, stage-level
class TestStageChurnRebalance:
    @pytest.mark.parametrize("engine", ("streaming", "oracle"))
    def test_fifty_op_churn_with_auto_rebalance(self, engine):
        """50 random subscribe/unsubscribe ops with auto-rebalance every
        10 and sparse delivery on: verdicts stay equal to a from-scratch
        dense compile of the surviving query set."""
        dtd = DTD.generate(n_tags=24, seed=7)
        d = TagDictionary()
        dtd.register(d)
        base_qs = gen_profiles(dtd, n=20, length=3, seed=7)
        pool = gen_profiles(dtd, n=40, length=3, seed=99)
        docs = gen_corpus(dtd, n_docs=4, nodes_per_doc=50, seed=7)
        stage = FilterStage(list(base_qs), d, n_shards=2, engine=engine,
                            query_shards=3, sparse=True, rebalance_every=10,
                            engine_options={"minimize": True})
        rng = np.random.default_rng(11)
        live = list(stage.sharded_.live_ids())
        for k in range(50):
            if live and rng.random() < 0.5:
                stage.unsubscribe(int(live.pop(rng.integers(len(live)))))
            else:
                live.append(stage.subscribe(pool[k % len(pool)]))
        assert stage.stats["rebalances"] > 0
        res = stage._filter_batch(docs)
        assert isinstance(res, SparseResult)
        final_qs = stage.sharded_.live_queries()
        batch = EventBatch.from_streams(docs, bucket=stage.bucket)
        _assert_same(res.densify(), _oracle_dense(final_qs, d, batch))
        assert stage.stats["verdict_bytes"] > 0

    def test_sparse_routing_matches_dense_routing(self):
        """The router's fan-out is identical with sparse delivery on and
        off, events and bytes paths alike."""
        dtd = DTD.generate(n_tags=24, seed=4)
        d = TagDictionary()
        dtd.register(d)
        qs = gen_profiles(dtd, n=16, length=3, seed=4)
        docs = gen_corpus(dtd, n_docs=6, nodes_per_doc=50, seed=4)
        payloads = [encode_bytes(doc) for doc in docs]

        def destinations(**kw):
            stage = FilterStage(list(qs), d, n_shards=3, engine="streaming",
                                batch_size=3, **kw)
            ev = [sorted((r.shard, r.doc_index) for batch in
                         stage.route(iter(docs)) for r in batch)]
            by = [sorted((r.shard, r.doc_index) for batch in
                         stage.route_bytes(iter(payloads)) for r in batch)]
            return ev, by

        dense = destinations(query_shards=2)
        sparse = destinations(query_shards=2, sparse=True,
                              engine_options={"minimize": True})
        assert dense == sparse
