"""Bit-equivalence of the streaming megakernel against the scan oracle.

The Pallas megakernel (``StreamingEngine(kernel="pallas")``) must be
*bit-identical* to the ``lax.scan`` path (``kernel="scan"``) on every
plan and every batch — ragged/padded batches, churned (add/remove-query)
sharded plans, depth-overflow documents, fused byte ingestion and the
2-D mesh program.  Tests are parametrized over interpret mode (runs
everywhere) and compiled mode (runs only on a real TPU backend).
"""

import jax
import numpy as np
import pytest

from repro.core import engines
from repro.core.dictionary import TagDictionary
from repro.core.engines.base import FilterEngine
from repro.core.events import (CLOSE, OPEN, ByteBatch, EventBatch,
                               EventStream)
from repro.core.nfa import compile_queries
from repro.data.generator import DTD, gen_corpus, gen_profiles

#: interpret=True runs on any backend; interpret=False (the compiled
#: megakernel) only on a real TPU
MODES = [
    pytest.param(True, id="interpret"),
    pytest.param(False, id="compiled", marks=pytest.mark.skipif(
        jax.default_backend() != "tpu",
        reason="compiled Pallas needs a TPU backend")),
]


def workload(n_queries=32, seed=0, n_tags=14, p_wild=0.1, p_desc=0.3,
             length=4):
    dtd = DTD.generate(n_tags=n_tags, seed=seed)
    d = TagDictionary()
    dtd.register(d)
    qs = gen_profiles(dtd, n=n_queries, length=length, p_wild=p_wild,
                      p_desc=p_desc, seed=seed)
    return dtd, d, qs, compile_queries(qs, d, shared=True)


def engine_pair(nfa, d, interpret, **kw):
    """The scan oracle and the megakernel over the SAME profile set."""
    scan = engines.create("streaming", nfa, dictionary=d,
                          kernel="scan", **kw)
    pallas = engines.create("streaming", nfa, dictionary=d,
                            kernel="pallas", kernel_interpret=interpret,
                            **kw)
    return scan, pallas


def assert_same(a, b):
    np.testing.assert_array_equal(a.matched, b.matched)
    np.testing.assert_array_equal(a.first_event, b.first_event)


# ------------------------------------------------------------ batch paths
class TestKernelVsScanBatches:
    @pytest.mark.parametrize("interpret", MODES)
    @pytest.mark.parametrize("n_queries,seed", [(8, 0), (40, 1), (64, 2)])
    def test_ragged_padded_batches(self, interpret, n_queries, seed):
        """Documents of wildly different lengths in one bucketed batch:
        the PAD tail must be inert on both paths."""
        dtd, d, qs, nfa = workload(n_queries=n_queries, seed=seed)
        docs = [ev for n in (4, 30, 90) for ev in
                gen_corpus(dtd, n_docs=2, nodes_per_doc=n, seed=seed + n)]
        batch = EventBatch.from_streams(docs, bucket=64)
        scan, pallas = engine_pair(nfa, d, interpret)
        assert_same(scan.filter_batch(batch), pallas.filter_batch(batch))

    @pytest.mark.parametrize("interpret", MODES)
    def test_multi_block_plan(self, interpret):
        """Small blk forces several word-blocks per document."""
        dtd, d, qs, nfa = workload(n_queries=48, seed=3, p_desc=0.5)
        docs = gen_corpus(dtd, n_docs=4, nodes_per_doc=70, seed=3)
        batch = EventBatch.from_streams(docs, bucket=64)
        scan, pallas = engine_pair(nfa, d, interpret, blk=32, chunk=32)
        plan = pallas.plan_
        assert plan.meta["n_blocks"] > 1
        assert_same(scan.filter_batch(batch), pallas.filter_batch(batch))

    @pytest.mark.parametrize("interpret", MODES)
    def test_fused_bytes_path(self, interpret):
        """Raw wire bytes → verdict, parse+kernel in one program."""
        dtd, d, qs, nfa = workload(n_queries=24, seed=4)
        docs = gen_corpus(dtd, n_docs=5, nodes_per_doc=50, seed=4)
        bb = ByteBatch.from_streams(docs, text_fill=3, bucket=256)
        scan, pallas = engine_pair(nfa, d, interpret)
        assert_same(scan.filter_bytes(bb), pallas.filter_bytes(bb))


# --------------------------------------------------------- depth overflow
class TestDepthOverflow:
    def _deep_doc(self, d, tag_name, depth):
        tid = d.lookup(tag_name)
        kind = np.array([OPEN] * depth + [CLOSE] * depth, np.int8)
        return EventStream(kind, np.full(2 * depth, tid, np.int32))

    @pytest.mark.parametrize("interpret", MODES)
    @pytest.mark.parametrize("depth", [5, 6, 7, 12])
    def test_deeper_than_max_depth(self, interpret, depth):
        """Documents at/over the stack bound clip identically on both
        paths (host-built batches skip the parse-time depth check)."""
        dtd, d, qs, nfa = workload(n_queries=16, seed=5, p_wild=0.0)
        tag = next(st.tag for q in qs for st in q.steps if st.tag != "*")
        docs = [self._deep_doc(d, tag, depth)] \
            + gen_corpus(dtd, n_docs=2, nodes_per_doc=30, seed=5)
        batch = EventBatch.from_streams(docs, bucket=32)
        scan, pallas = engine_pair(nfa, d, interpret, max_depth=6)
        assert scan.plan_.meta["max_depth"] == 6
        assert pallas.plan_.meta["max_depth"] == 6
        assert_same(scan.filter_batch(batch), pallas.filter_batch(batch))


# ----------------------------------------------------------- churned plans
class TestChurnedPlans:
    @pytest.mark.parametrize("interpret", MODES)
    @pytest.mark.parametrize("n_parts", [1, 2])
    def test_add_remove_queries(self, interpret, n_parts):
        """Sharded plans stay bit-identical through subscribe (one-part
        recompile, incremental restack) and unsubscribe (tombstones)."""
        dtd, d, qs, nfa = workload(n_queries=20, seed=6)
        docs = gen_corpus(dtd, n_docs=4, nodes_per_doc=50, seed=6)
        batch = EventBatch.from_streams(docs, bucket=64)
        scan, pallas = engine_pair(nfa, d, interpret)
        sp_s = scan.plan_sharded(n_parts)
        sp_p = pallas.plan_sharded(n_parts)
        extra = gen_profiles(dtd, n=4, length=3, seed=77)
        gids_s: list[int] = []
        for q in extra:  # one op at a time: exercises the restack path
            sp_s, g1 = sp_s.add_queries([q])
            sp_p, g2 = sp_p.add_queries([q])
            assert g1 == g2
            gids_s += g1
        sp_s = sp_s.remove_queries([1, gids_s[0]])
        sp_p = sp_p.remove_queries([1, gids_s[0]])
        assert_same(scan.filter_batch_sharded(batch, sp_s),
                    pallas.filter_batch_sharded(batch, sp_p))

    @pytest.mark.parametrize("interpret", MODES)
    def test_sharded_bytes_2d(self, interpret):
        """The 2-D (data × model) bytes→verdict program through the
        kernel equals the scan program on the same mesh."""
        from repro.launch.mesh import make_filter_mesh

        dtd, d, qs, nfa = workload(n_queries=16, seed=7)
        docs = gen_corpus(dtd, n_docs=5, nodes_per_doc=40, seed=7)
        bb = ByteBatch.from_streams(docs, text_fill=2, bucket=256)
        scan, pallas = engine_pair(nfa, d, interpret)
        mesh = make_filter_mesh(2)
        assert_same(
            scan.filter_bytes_sharded2d(bb, scan.plan_sharded(2),
                                        mesh=mesh),
            pallas.filter_bytes_sharded2d(bb, pallas.plan_sharded(2),
                                          mesh=mesh))


# ------------------------------------------------- selection and autotune
class TestKernelSelection:
    def test_auto_prefers_scan_under_interpret(self, monkeypatch):
        """kernel="auto" = megakernel exactly when Pallas compiles (a
        real TPU); the interpreter is a correctness tool, not a path.
        The choice is frozen when the engine is constructed."""
        _, d, qs, nfa = workload(n_queries=8, seed=8)
        monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "1")
        eng = engines.create("streaming", nfa, dictionary=d)
        assert eng.kernel_mode == "auto" and not eng._kernel_on()
        monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "0")
        eng = engines.create("streaming", nfa, dictionary=d)
        assert eng._kernel_on()

    def test_invalid_mode_rejected(self):
        _, d, qs, nfa = workload(n_queries=4, seed=9)
        with pytest.raises(ValueError, match="kernel="):
            engines.create("streaming", nfa, dictionary=d, kernel="maybe")

    def test_autotune_blocks_respects_budgets(self):
        cfg = FilterEngine.autotune_blocks(4096, 64, n_tags=64)
        assert cfg["blk"] % 32 == 0 and cfg["chunk"] >= 32
        # a tiny NFA never gets a block wider than its padded state count
        small = FilterEngine.autotune_blocks(40, 64, n_tags=64)
        assert small["blk"] == 64
        # a huge tag space shrinks the block until the masks fit VMEM
        tight = FilterEngine.autotune_blocks(
            4096, 64, n_tags=4096, vmem_budget=128 << 10)
        assert tight["blk"] == 128 < cfg["blk"]
        # SMEM budget caps the event chunk (double-buffered int32)
        assert FilterEngine.autotune_blocks(
            256, 64, n_tags=16, smem_budget=512)["chunk"] == 64

    def test_engine_options_override_autotune(self):
        _, d, qs, nfa = workload(n_queries=24, seed=10)
        eng = engines.create("streaming", nfa, dictionary=d,
                             kernel="pallas", blk=64, chunk=96)
        assert eng.plan_.meta["blk"] % 32 == 0
        assert eng.plan_.meta["blk"] >= 64
        assert eng.plan_.meta["chunk"] == 96

    def test_scan_plans_skip_kernel_tables(self):
        """Scan-only engines (the default off TPU) pay neither the block
        layout nor the kb_* table memory; megakernel engines carry both."""
        _, d, qs, nfa = workload(n_queries=12, seed=13)
        scan = engines.create("streaming", nfa, dictionary=d, kernel="scan")
        assert not any(k.startswith("kb_") for k in scan.plan_.tables)
        assert "blk" not in scan.plan_.meta
        pallas = engines.create("streaming", nfa, dictionary=d,
                                kernel="pallas")
        assert "kb_tagmask" in pallas.plan_.tables

    def test_layout_pad_overflow_raises_typed_error(self):
        from repro.core.nfa import pad_states
        from repro.kernels.blocks import PadOverflow, state_layout

        _, d, qs, nfa = workload(n_queries=24, seed=14)
        nfa = pad_states(nfa, 32)
        mk = state_layout(nfa, blk=32)
        with pytest.raises(PadOverflow):
            state_layout(nfa, blk=32, n_blocks=mk.n_blocks - 1)
        with pytest.raises(PadOverflow):
            state_layout(nfa, blk=32,
                         block_queries=mk.block_queries - 1)

    def test_churn_sequence_never_overflows(self):
        """Long add/remove sequence on a kernel-enabled sharded plan:
        bucket overflows must reconcile (merge_pads / PadOverflow
        fallback), never crash, and stay bit-identical to the scan."""
        dtd, d, qs, nfa = workload(n_queries=12, seed=15)
        docs = gen_corpus(dtd, n_docs=3, nodes_per_doc=40, seed=15)
        batch = EventBatch.from_streams(docs, bucket=64)
        scan, pallas = engine_pair(nfa, d, True)
        sp_s, sp_p = scan.plan_sharded(2), pallas.plan_sharded(2)
        extra = gen_profiles(dtd, n=24, length=5, p_desc=0.5, seed=99)
        gids: list[int] = []
        for i, q in enumerate(extra):
            sp_s, g = sp_s.add_queries([q])
            sp_p, _ = sp_p.add_queries([q])
            gids += g
            if i % 3 == 2:
                sp_s = sp_s.remove_queries([gids[i // 3]])
                sp_p = sp_p.remove_queries([gids[i // 3]])
        assert_same(scan.filter_batch_sharded(batch, sp_s),
                    pallas.filter_batch_sharded(batch, sp_p))

    def test_plan_meta_threads_one_max_depth(self):
        """Satellite: kernel and scan read the same stack bound — the
        plan metadata, never a per-path default."""
        _, d, qs, nfa = workload(n_queries=8, seed=11)
        eng = engines.create("streaming", nfa, dictionary=d, max_depth=17)
        assert eng.plan_.meta["max_depth"] == 17
        from repro.kernels.ops import StreamFilterKernelEngine
        from repro.kernels.parse import DEFAULT_MAX_DEPTH
        ke = StreamFilterKernelEngine(list(qs), d)
        assert ke.max_depth == DEFAULT_MAX_DEPTH
        assert ke._eng.plan_.meta["max_depth"] == DEFAULT_MAX_DEPTH


class TestEventBucketThreading:
    def test_stage_bucket_reaches_engine_byte_path(self):
        """Satellite: a FilterStage's bucket becomes the engine default
        for every byte path instead of a silent hard-coded 128."""
        from repro.data.filter_stage import FilterStage

        dtd, d, qs, nfa = workload(n_queries=6, seed=12)
        stage = FilterStage(profiles=list(qs), dictionary=d, n_shards=2,
                            engine="streaming", bucket=64)
        assert stage._eng._event_bucket(None) == 64
        assert stage._eng._event_bucket(32) == 32
        # engines built standalone keep the documented default
        eng = engines.create("streaming", nfa, dictionary=d)
        from repro.core.engines.base import DEFAULT_EVENT_BUCKET
        assert eng._event_bucket(None) == DEFAULT_EVENT_BUCKET
