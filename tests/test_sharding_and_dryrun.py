"""Sharding rules + mini-mesh dry-run (subprocess, 8 placeholder devices).

The full 512-device dry-run is ``launch/dryrun.py``; here the same
machinery runs on a 4×2 mesh with reduced configs so the suite stays
fast while covering: rule sanitization, param/opt/cache spec trees,
lowering with in/out shardings, and the HLO analyzer.
"""
import json
import os
import subprocess
import sys

import pytest

SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))


class TestRules:
    def test_sanitize_drops_nondivisible(self):
        import jax
        from jax.sharding import PartitionSpec as P
        from repro.sharding.rules import sanitize
        mesh = jax.make_mesh((1, 1), ("data", "model"))

        # 1-device mesh: everything divides; use shape math instead
        s = sanitize(("data", "model"), (7, 8), mesh)
        assert s == P(None, None) or s == P("data", "model")

    def test_param_specs_cover_tree(self):
        import jax
        from jax.sharding import PartitionSpec as P
        from repro.configs import get_config
        from repro.models import transformer as T
        from repro.sharding.rules import param_specs
        mesh = jax.make_mesh((1, 1), ("data", "model"))
        for arch in ("qwen3-0.6b", "deepseek-v3-671b", "mamba2-780m",
                     "zamba2-7b", "whisper-large-v3"):
            cfg = get_config(arch, reduced=True)
            shapes = jax.eval_shape(
                lambda c=cfg: T.init_model(c, jax.random.PRNGKey(0)))
            specs = param_specs(cfg, shapes, mesh)
            n_spec = len(jax.tree.leaves(
                specs, is_leaf=lambda x: isinstance(x, P)))
            n_par = len(jax.tree.leaves(shapes))
            assert n_spec == n_par, arch


class TestHloAnalyzer:
    def test_group_size_parsing(self):
        from repro.launch.hlo_analysis import _group_size
        assert _group_size("replica_groups={{0,1,2,3},{4,5,6,7}}") == 4
        assert _group_size("replica_groups=[4,2]<=[8]") == 2
        assert _group_size("nothing here", default=1) == 1

    def test_wire_bytes_formulas(self):
        from repro.launch.hlo_analysis import Op, _collective_wire_bytes
        op = Op("x", "f32[16]", "all-reduce", "replica_groups=[1,4]<=[4]")
        assert _collective_wire_bytes(op) == 2 * 64 * 3 / 4
        op = Op("x", "f32[16]", "all-gather", "replica_groups=[1,4]<=[4]")
        assert _collective_wire_bytes(op) == 64 * 3 / 4
        op = Op("x", "f32[16]", "reduce-scatter",
                "replica_groups=[1,4]<=[4]")
        assert _collective_wire_bytes(op) == 64 * 3

    def test_trip_count_scaling_on_real_hlo(self):
        """End-to-end: analyzer flops must scale with scan length."""
        script = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys, json
sys.path.insert(0, %r)
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.launch.hlo_analysis import analyze_text
mesh = jax.make_mesh((4, 2), ("data", "model"))
out = {}
for nl in (4, 8):
    def step(params, x):
        def body(h, w):
            return h @ w, None
        h, _ = jax.lax.scan(body, x, params)
        return h.sum()
    f = jax.jit(jax.grad(step), in_shardings=(
        NamedSharding(mesh, P(None, "data", "model")),
        NamedSharding(mesh, P("data", None))))
    txt = f.lower(jax.ShapeDtypeStruct((nl, 64, 64), jnp.float32),
                  jax.ShapeDtypeStruct((8, 64), jnp.float32)).compile().as_text()
    out[nl] = analyze_text(txt)
print(json.dumps(out))
""" % SRC
        r = subprocess.run([sys.executable, "-c", script],
                           capture_output=True, text=True, timeout=300)
        assert r.returncode == 0, r.stderr[-2000:]
        out = json.loads(r.stdout.strip().splitlines()[-1])
        assert out["8"]["flops_per_device"] == pytest.approx(
            2 * out["4"]["flops_per_device"])
        assert out["8"]["collective_bytes_per_device"] > 0


@pytest.mark.parametrize("arch,shape", [
    ("qwen3-0.6b", "train_4k"),
    ("qwen3-moe-30b-a3b", "train_4k"),
    ("mamba2-780m", "decode_32k"),
    ("whisper-large-v3", "prefill_32k"),
    ("zamba2-7b", "long_500k"),
])
def test_mini_dryrun_lowers(arch, shape):
    """Reduced config × reduced shape through the real dry-run builder on
    a 4×2 mini-mesh (subprocess so XLA_FLAGS is isolated)."""
    script = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
sys.path.insert(0, %r)
import jax
from repro.launch import dryrun as D
from repro.launch.cells import Cell
from repro.models.config import ShapeSpec
from repro.configs import get_config
import repro.launch.cells as cells_mod

# shrink: reduced config + tiny shape of the same kind
orig = cells_mod.dryrun_config
def tiny_config(arch, pad_heads_to=2):
    return get_config(arch, reduced=True).with_(
        param_dtype="bfloat16", activ_dtype="bfloat16",
        pad_heads_to=pad_heads_to, remat=True, grad_accum=1,
        attn_chunk=16, ce_chunk=32)
cells_mod.dryrun_config = tiny_config
D.dryrun_config = tiny_config

kind = dict(train_4k="train", prefill_32k="prefill",
            decode_32k="decode", long_500k="decode")[%r]
shape = ShapeSpec("mini", 64, 8, kind)
cell = Cell(%r, shape, True)

import jax
mesh = jax.make_mesh((4, 2), ("data", "model"))
from repro.sharding import mesh_context
with mesh_context(mesh):
    cfg, fn, args = D.build_cell(cell, mesh)
    compiled = fn.lower(*args).compile()
print("MEM", compiled.memory_analysis().temp_size_in_bytes)
from repro.launch.hlo_analysis import analyze_text
a = analyze_text(compiled.as_text())
assert a["flops_per_device"] > 0
print("OK", a["flops_per_device"], a["collective_bytes_per_device"])
""" % (SRC, shape, arch)
    r = subprocess.run([sys.executable, "-c", script],
                       capture_output=True, text=True, timeout=600)
    assert r.returncode == 0, (r.stdout[-1000:], r.stderr[-3000:])
    assert "OK" in r.stdout
