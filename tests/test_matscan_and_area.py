"""matscan (paper-literal regex) semantics + Fig-8 area model tests."""
import numpy as np
import pytest

from repro.core.area import SCENARIOS, area_report, engine_table_bytes
from repro.core.dictionary import TagDictionary
from repro.core.engines.matscan import (MatscanEngine, MatscanUnsupported,
                                        exact_class)
from repro.core.engines.oracle import filter_document as oracle_filter
from repro.core.nfa import compile_queries
from repro.core.xpath import parse
from repro.data.generator import DTD, gen_document, gen_profiles

from test_engines import ev_from_nested, fresh_dict


class TestMatscan:
    def test_matches_oracle_on_exact_class(self):
        d = fresh_dict()
        ev = ev_from_nested([(0, [(1, [(2, [])]), (3, [])])])
        assert exact_class(ev)
        profiles = [parse(p) for p in
                    ["t0//t2", "t0//t3", "t3//t1", "//t1//t2", "t0//t1//t2"]]
        eng = MatscanEngine(profiles, d)
        got = eng.filter_document(ev)
        nfa = compile_queries(profiles, d)
        want = oracle_filter(nfa, ev, d)
        np.testing.assert_array_equal(got.matched, want.matched)
        np.testing.assert_array_equal(got.first_event, want.first_event)

    def test_randomized_exact_class_agreement(self):
        for seed in range(6):
            dtd = DTD.generate(n_tags=20, seed=seed)
            d = TagDictionary()
            dtd.register(d)
            profiles = [q for q in gen_profiles(dtd, n=20, length=3,
                                                p_desc=1.0, p_wild=0.0,
                                                seed=seed)]
            ev = gen_document(dtd, target_nodes=80, seed=seed + 100)
            if not exact_class(ev):
                continue
            eng = MatscanEngine(profiles, d)
            got = eng.filter_document(ev)
            nfa = compile_queries(profiles, d)
            want = oracle_filter(nfa, ev, d)
            np.testing.assert_array_equal(got.matched, want.matched)

    def test_known_negation_approximation(self):
        """The paper's negation block kills outer progress when a nested
        same-tag element closes — pinned divergence from tree semantics."""
        d = fresh_dict()
        # <t0> <t0></t0> <t1/> </t0> : tree semantics says t0//t1 matches
        ev = ev_from_nested([(0, [(0, []), (1, [])])])
        assert not exact_class(ev)
        eng = MatscanEngine([parse("t0//t1")], d)
        got = eng.filter_document(ev)
        assert not got.matched[0]  # flat-regex semantics: inner </t0> killed it
        nfa = compile_queries([parse("t0//t1")], d)
        want = oracle_filter(nfa, ev, d)
        assert want.matched[0]  # stack engines are exact

    def test_rejects_stack_group(self):
        d = fresh_dict()
        with pytest.raises(MatscanUnsupported):
            MatscanEngine([parse("t0/t1")], d)
        with pytest.raises(MatscanUnsupported):
            MatscanEngine([parse("//*")], d)


class TestAreaModel:
    def _workload(self, n, length, seed=0):
        dtd = DTD.generate(n_tags=12, seed=seed)
        d = TagDictionary()
        dtd.register(d)
        return gen_profiles(dtd, n=n, length=length, seed=seed), d

    def test_scenarios_ordering(self):
        """Com-P < Unop area; CharDec < full comparators (paper Fig 8)."""
        qs, d = self._workload(256, 4)
        costs = {s: area_report(qs, d, s).bit_cost for s in SCENARIOS}
        assert costs["Com-P"] < costs["Unop"]
        assert costs["Com-P-CharDec"] < costs["Unop-CharDec"]
        assert costs["Com-P-CharDec"] < costs["Unop"]
        assert costs["Unop-CharDec"] < costs["Unop"]

    def test_area_grows_with_queries_and_length(self):
        for scenario in SCENARIOS:
            prev = 0
            for n in (16, 64, 256):
                qs, d = self._workload(n, 4)
                c = area_report(qs, d, scenario).bit_cost
                assert c > prev
                prev = c
        a2 = area_report(*self._workload(128, 2), "Unop").bit_cost
        a6 = area_report(*self._workload(128, 6), "Unop").bit_cost
        assert a6 > a2

    def test_prefix_sharing_factor(self):
        """Paper reports 5–7× Unop→Com-P-CharDec improvement; the model
        reproduces an improvement in that ballpark (>=3x) on a
        PathGenerator-like workload."""
        qs, d = self._workload(1024, 6)
        unop = area_report(qs, d, "Unop").bit_cost
        best = area_report(qs, d, "Com-P-CharDec").bit_cost
        assert unop / best >= 3.0

    def test_table_bytes_reported(self):
        qs, d = self._workload(64, 4)
        nfa = compile_queries(qs, d)
        b = engine_table_bytes(nfa)
        assert b["levelwise_tables"] > b["streaming_tables"] > 0
