import os
import sys

# Tests run single-device (the dry-run subprocess sets its own XLA_FLAGS).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

SRC = os.path.join(os.path.dirname(__file__), "..", "src")
if SRC not in sys.path:
    sys.path.insert(0, os.path.abspath(SRC))
