"""Filter stage routing + token pipeline determinism."""
import numpy as np

from repro.core.dictionary import TagDictionary
from repro.core.engines.yfilter import YFilterEngine
from repro.core.nfa import compile_queries
from repro.data.filter_stage import FilterStage
from repro.data.generator import DTD, gen_corpus, gen_profiles
from repro.data.tokens import TokenPipeline, XMLBytePipeline


class TestFilterStage:
    def _setup(self, engine):
        dtd = DTD.generate(n_tags=16, seed=1)
        d = TagDictionary()
        dtd.register(d)
        profiles = gen_profiles(dtd, n=24, length=3, seed=1)
        docs = gen_corpus(dtd, n_docs=10, nodes_per_doc=80, seed=1)
        stage = FilterStage(profiles, d, n_shards=4, engine=engine,
                            batch_size=4)
        return stage, docs, profiles, d

    def test_routing_consistent_across_engines(self):
        routes = {}
        for engine in ("levelwise", "yfilter", "streaming"):
            stage, docs, _, _ = self._setup(engine)
            got = [r for batch in stage.route(docs) for r in batch]
            routes[engine] = {(r.doc_index, r.shard):
                              tuple(r.matched_profiles) for r in got}
        assert routes["levelwise"] == routes["yfilter"] == routes["streaming"]

    def test_routing_matches_ground_truth(self):
        stage, docs, profiles, d = self._setup("yfilter")
        nfa = compile_queries(profiles, d)
        eng = YFilterEngine(nfa)
        got = [r for batch in stage.route(docs) for r in batch]
        for r in got:
            res = eng.filter_document(docs[r.doc_index])
            want = set(np.nonzero(res.matched)[0])
            assert set(r.matched_profiles) <= want
            for q in r.matched_profiles:
                assert stage.shard_of_profile[q] == r.shard

    def test_selectivity(self):
        stage, docs, _, _ = self._setup("levelwise")
        s = stage.selectivity(docs)
        assert 0.0 <= s <= 1.0


class TestTokenPipelines:
    def test_deterministic_and_shard_disjoint(self):
        p0 = TokenPipeline(vocab=100, batch=2, seq_len=16, seed=7, shard=0)
        p0b = TokenPipeline(vocab=100, batch=2, seq_len=16, seed=7, shard=0)
        p1 = TokenPipeline(vocab=100, batch=2, seq_len=16, seed=7, shard=1)
        a, b, c = p0.batch_at(3), p0b.batch_at(3), p1.batch_at(3)
        np.testing.assert_array_equal(a["tokens"], b["tokens"])
        assert not np.array_equal(a["tokens"], c["tokens"])
        # next-token alignment
        np.testing.assert_array_equal(a["tokens"][:, 1:], a["labels"][:, :-1])

    def test_xml_byte_pipeline(self):
        dtd = DTD.generate(n_tags=8, seed=2)
        docs = gen_corpus(dtd, n_docs=4, nodes_per_doc=50, seed=2)
        p = XMLBytePipeline(docs, batch=2, seq_len=32)
        b = p.batch_at(0)
        assert b["tokens"].shape == (2, 32)
        assert b["tokens"].max() < 256
        np.testing.assert_array_equal(p.batch_at(1)["tokens"],
                                      p.batch_at(1)["tokens"])
