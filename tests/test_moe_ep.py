"""Expert-parallel shard_map MoE vs the dense reference path.

Runs in a subprocess with 4 placeholder devices (2×2 mesh) so the main
test process keeps its single-device config.
"""
import os
import subprocess
import sys

SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import sys; sys.path.insert(0, %r)
import jax, jax.numpy as jnp
from repro.configs import get_config
from repro.models import layers as L
from repro.sharding import mesh_context

cfg = get_config("qwen3-moe-30b-a3b", reduced=True)
params = L.init_moe(cfg, jax.random.PRNGKey(0))
x = jax.random.normal(jax.random.PRNGKey(1), (4, 8, cfg.d_model))
y_ref = L.moe(cfg, params, x)
g_ref = jax.grad(lambda p: (L.moe(cfg, p, x) ** 2).sum())(params)

mesh = jax.make_mesh((2, 2), ("data", "model"))
with mesh_context(mesh):
    y_ep = jax.jit(lambda p, xx: L.moe(cfg, p, xx))(params, x)
    g_ep = jax.jit(jax.grad(lambda p: (L.moe(cfg, p, x) ** 2).sum()))(params)

assert float(jnp.abs(y_ref - y_ep).max()) < 1e-4, "forward mismatch"
for k in ("router", "wi", "wo"):
    d = float(jnp.abs(g_ref[k] - g_ep[k]).max())
    s = float(jnp.abs(g_ref[k]).max()) + 1e-9
    assert d / s < 1e-5, (k, d, s)
print("EP_OK")
""" % SRC


def test_moe_ep_matches_dense():
    r = subprocess.run([sys.executable, "-c", SCRIPT],
                       capture_output=True, text=True, timeout=600)
    assert r.returncode == 0, (r.stdout[-800:], r.stderr[-3000:])
    assert "EP_OK" in r.stdout
