"""2-D (data × model) mesh filtering: both scaling axes in one program.

PR-level contract: for every registered engine, ``filter_batch_sharded2d``
and ``filter_bytes_sharded2d`` over a ``("data", "model")`` mesh are
bit-identical to the unsharded single-device path — including ragged
batches (padded to the data axis) and the fused bytes→verdict route —
and the async double-buffered serve loop routes identically to the
synchronous one.

The CI device-count matrix runs this file under
``XLA_FLAGS=--xla_force_host_platform_device_count={1,4,8}`` so the
degenerate (1×1), square (2×2) and non-square (4×1, 8×2…) mesh shapes
are all exercised on CPU runners.
"""
import numpy as np
import pytest

import jax

from repro.core import engines
from repro.core.dictionary import TagDictionary
from repro.core.events import ByteBatch, EventBatch, encode_bytes
from repro.core.nfa import compile_queries
from repro.data.filter_stage import FilterStage
from repro.data.generator import DTD, gen_profiles
from repro.launch.mesh import make_filter_mesh

from test_sharded import ALL_ENGINES, _workload

DEVICE_ENGINES = ("levelwise", "matscan", "streaming", "wavefront")


def _engine_with_workload(name, seed=0, n_docs=5, n_queries=18):
    profiles, docs, d = _workload(name, seed=seed, n_docs=n_docs,
                                  n_queries=n_queries)
    nfa = compile_queries(profiles, d, shared=True)
    return engines.create(name, nfa, dictionary=d), docs, d


# ------------------------------------------------------------------ the mesh
class TestFilterMesh2D:
    def test_axes_are_data_model(self):
        mesh = make_filter_mesh(2, data_shards=2)
        assert tuple(mesh.axis_names) == ("data", "model")

    def test_data_shards_shrink_to_divisor(self):
        """Any request is placeable: the data axis shrinks to the largest
        divisor of the device count, never an error."""
        n = len(jax.devices())
        for req in (1, 2, 3, 4, 7, 8, n + 3):
            mesh = make_filter_mesh(data_shards=req)
            shape = dict(mesh.shape)
            assert n % shape["data"] == 0
            assert shape["data"] <= max(req, 1)
            assert shape["data"] * shape["model"] <= n

    def test_model_axis_divides_parts(self):
        for parts in (1, 2, 3, 5, 6):
            shape = dict(make_filter_mesh(parts, data_shards=2).shape)
            assert parts % shape["model"] == 0

    def test_invalid_arguments_raise(self):
        with pytest.raises(ValueError, match="data_shards"):
            make_filter_mesh(data_shards=0)
        with pytest.raises(ValueError, match="n_parts"):
            make_filter_mesh(0)

    def test_full_device_grid(self):
        """data × model covers every device when both axes are asked for."""
        n = len(jax.devices())
        mesh = make_filter_mesh(n, data_shards=n)
        shape = dict(mesh.shape)
        assert shape["data"] * shape["model"] == n


# -------------------------------------------------------- plan metadata
class TestPlanPrepMetadata:
    """Every engine's plan records its document-prep form — what the 2-D
    bytes route keys the fused-vs-parse-first decision on."""

    EXPECTED = {"streaming": "events-device", "matscan": "events-device",
                "levelwise": "levels-host", "wavefront": "levels-host",
                "oracle": "host", "yfilter": "host"}

    @pytest.mark.parametrize("name", ALL_ENGINES)
    def test_prep_recorded(self, name):
        eng, _, _ = _engine_with_workload(name)
        assert eng.plan_.meta["prep"] == self.EXPECTED[name]

    @pytest.mark.parametrize("name", ALL_ENGINES)
    def test_prep_survives_sharded_stacking(self, name):
        eng, _, _ = _engine_with_workload(name)
        sp = eng.plan_sharded(2)
        assert sp.plans[0].meta["prep"] == self.EXPECTED[name]
        if eng.device_sharded:
            assert sp.stacked().meta["prep"] == self.EXPECTED[name]


# ------------------------------------------------------- 2-D equivalence
class Test2DEquivalence:
    """Acceptance: every engine, multiple (parts × data-shard) shapes,
    bit-identical to the unsharded single-device path."""

    @pytest.mark.parametrize("name", ALL_ENGINES)
    @pytest.mark.parametrize("n_parts,data_req", [(1, 2), (2, 2), (4, 4)])
    def test_2d_equals_unsharded(self, name, n_parts, data_req):
        eng, docs, _ = _engine_with_workload(name, seed=1)
        batch = EventBatch.from_streams(docs, bucket=32)
        want = eng.filter_batch(batch)
        sp = eng.plan_sharded(n_parts)
        mesh = make_filter_mesh(n_parts, data_shards=data_req)
        got = eng.filter_batch_sharded2d(batch, sp, mesh=mesh)
        np.testing.assert_array_equal(
            got.matched, want.matched,
            err_msg=f"{name}/{n_parts}p/{dict(mesh.shape)} matched")
        np.testing.assert_array_equal(
            got.first_event, want.first_event,
            err_msg=f"{name}/{n_parts}p/{dict(mesh.shape)} location")

    @pytest.mark.parametrize("name", ("oracle", "yfilter"))
    def test_host_engine_bytes_dispatch_honours_n_events(self, name):
        """The host-engine oracle fallback must respect an explicit
        event bound (the pipelined loop passes one so a device-placed
        byte tensor is never read back)."""
        eng, docs, _ = _engine_with_workload(name, seed=6)
        sp = eng.plan_sharded(2)
        mesh = make_filter_mesh(2, data_shards=2)
        bb = ByteBatch.from_buffers(
            [encode_bytes(x, text_fill=8) for x in docs], bucket=1024)
        n_events = bb.event_bound(bucket=128)
        handle = eng.dispatch_bytes_sharded2d(bb, sp, mesh=mesh,
                                              n_events=n_events)
        got = handle()
        want = eng.filter_batch(EventBatch.from_streams(docs, bucket=128))
        np.testing.assert_array_equal(got.matched, want.matched)

    @pytest.mark.parametrize("name", ALL_ENGINES)
    def test_bytes_2d_equals_unsharded(self, name):
        """The bytes→verdict route (fused single-program for
        device-prep engines, parse-then-filter otherwise, part loop for
        host engines) is bit-identical to the unsharded event path."""
        eng, docs, _ = _engine_with_workload(name, seed=3)
        sp = eng.plan_sharded(2)
        mesh = make_filter_mesh(2, data_shards=2)
        bb = ByteBatch.from_buffers(
            [encode_bytes(x, text_fill=8) for x in docs], bucket=1024)
        got = eng.filter_bytes_sharded2d(bb, sp, mesh=mesh)
        want = eng.filter_batch(EventBatch.from_streams(docs, bucket=128))
        np.testing.assert_array_equal(got.matched, want.matched)
        np.testing.assert_array_equal(got.first_event, want.first_event)

    @pytest.mark.parametrize("name", DEVICE_ENGINES)
    def test_ragged_batch_is_padded_and_sliced(self, name):
        """A batch size that does not divide the data axis gains inert
        pad documents on the way in and loses them on the way out."""
        eng, docs, _ = _engine_with_workload(name, seed=2, n_docs=5)
        assert len(docs) == 5  # stays ragged vs any data axis > 1
        batch = EventBatch.from_streams(docs, bucket=32)
        sp = eng.plan_sharded(2)
        mesh = make_filter_mesh(2, data_shards=4)
        got = eng.filter_batch_sharded2d(batch, sp, mesh=mesh)
        want = eng.filter_batch(batch)
        assert got.matched.shape == want.matched.shape
        np.testing.assert_array_equal(got.matched, want.matched)

    def test_dispatch_is_deferred_and_correct(self):
        """dispatch_* returns a materializer: calling it yields the same
        verdicts as the blocking convenience."""
        eng, docs, _ = _engine_with_workload("streaming", seed=4)
        batch = EventBatch.from_streams(docs, bucket=32)
        sp = eng.plan_sharded(2)
        mesh = make_filter_mesh(2, data_shards=2)
        handle = eng.dispatch_batch_sharded2d(batch, sp, mesh=mesh)
        assert callable(handle)
        res = handle()
        want = eng.filter_batch_sharded2d(batch, sp, mesh=mesh)
        np.testing.assert_array_equal(res.matched, want.matched)
        np.testing.assert_array_equal(res.first_event, want.first_event)

    def test_2d_after_churn_matches_fresh_compile(self):
        """The 2-D program executes a churned plan identically to a
        from-scratch compile of the surviving query set."""
        from test_sharded import _fresh_verdict
        eng, docs, d = _engine_with_workload("streaming", seed=5)
        pool = gen_profiles(DTD.generate(n_tags=24, seed=5), n=10,
                            length=3, seed=77)
        batch = EventBatch.from_streams(docs, bucket=32)
        sp = eng.plan_sharded(2)
        sp, gids = sp.add_queries(pool[:3])
        sp = sp.remove_queries([int(sp.live_ids()[0]), gids[1]])
        mesh = make_filter_mesh(2, data_shards=2)
        got = eng.filter_batch_sharded2d(batch, sp, mesh=mesh)
        want = _fresh_verdict("streaming", sp.live_queries(), d, batch)
        np.testing.assert_array_equal(got.matched, want.matched)
        np.testing.assert_array_equal(got.first_event, want.first_event)

    def test_mesh_without_axes_raises(self):
        eng, docs, _ = _engine_with_workload("streaming")
        sp = eng.plan_sharded(1)
        batch = EventBatch.from_streams(docs, bucket=32)
        bad = jax.make_mesh((1,), ("model",))
        with pytest.raises(ValueError, match="data"):
            eng.filter_batch_sharded2d(batch, sp, mesh=bad)
        with pytest.raises(ValueError, match="mesh"):
            eng.filter_batch_sharded2d(batch, sp, mesh=None)

    def test_model_axis_part_mismatch_raises(self):
        mesh = make_filter_mesh(4, data_shards=1)
        if dict(mesh.shape)["model"] == 1:
            pytest.skip("needs >1 model axis for a mismatch")
        eng, docs, _ = _engine_with_workload("streaming")
        sp = eng.plan_sharded(3)
        with pytest.raises(ValueError, match="not divisible"):
            eng.filter_batch_sharded2d(
                EventBatch.from_streams(docs, bucket=32), sp, mesh=mesh)


# -------------------------------------------------- batch-axis padding
class TestBatchAxisPadding:
    def test_event_batch_pad_batch_to(self):
        _, docs, _ = _engine_with_workload("streaming")
        batch = EventBatch.from_streams(docs, bucket=32)
        padded = batch.pad_batch_to(8)
        assert padded.batch_size == 8
        assert padded.length == batch.length
        np.testing.assert_array_equal(padded.kind[:len(docs)], batch.kind)
        assert not padded.valid[len(docs):].any()
        assert (padded.n_events[len(docs):] == 0).all()
        assert batch.pad_batch_to(batch.batch_size) is batch
        with pytest.raises(ValueError):
            batch.pad_batch_to(1)

    def test_byte_batch_pad_batch_to(self):
        bb = ByteBatch.from_buffers([b"<ab>x</ab>", b"<cd>"], bucket=16)
        padded = bb.pad_batch_to(4)
        assert padded.batch_size == 4
        assert (np.asarray(padded.data[2:]) == 0).all()
        assert (np.asarray(padded.n_bytes[2:]) == 0).all()
        # zero bytes decode to zero events: the bound is unchanged
        assert padded.event_bound() == bb.event_bound()
        with pytest.raises(ValueError):
            bb.pad_batch_to(1)

    def test_byte_batch_device_put(self):
        """Sharding-aware placement: padded to the data axis, device
        resident, bytes preserved."""
        _, docs, _ = _engine_with_workload("streaming", n_docs=3)
        bb = ByteBatch.from_buffers(
            [encode_bytes(x) for x in docs], bucket=256)
        mesh = make_filter_mesh(data_shards=2)
        placed = bb.device_put(mesh)
        data_ax = dict(mesh.shape)["data"]
        assert placed.is_device
        assert placed.batch_size % data_ax == 0
        host = placed.to_host()
        assert not host.is_device
        np.testing.assert_array_equal(host.data[:3], np.asarray(bb.data))


# ------------------------------------------------------ stage integration
class TestStage2D:
    def _routes(self, batches):
        return {(r.doc_index, r.shard): tuple(r.matched_profiles)
                for b in batches for r in b}

    def _workload(self, seed=6, n_docs=11):
        profiles, docs, _ = _workload("streaming", seed=seed, n_docs=n_docs)
        raw = [encode_bytes(x, text_fill=8) for x in docs]
        return profiles, docs, raw

    def test_routing_identical_with_and_without_data_shards(self):
        profiles, docs, raw = self._workload()
        mono = FilterStage(profiles, TagDictionary(), n_shards=3,
                           engine="streaming", batch_size=4)
        two_d = FilterStage(profiles, TagDictionary(), n_shards=3,
                            engine="streaming", batch_size=4,
                            query_shards=2, data_shards=2)
        assert dict(two_d.mesh.shape).keys() == {"data", "model"}
        assert self._routes(mono.route(docs)) == self._routes(
            two_d.route(docs))
        assert self._routes(mono.route_bytes(raw)) == self._routes(
            two_d.route_bytes(raw))

    def test_pipelined_routes_like_synchronous(self):
        """The async double-buffered loop is an optimization, not a
        semantic: routed output must equal route_bytes exactly."""
        profiles, docs, raw = self._workload(seed=7)
        a = FilterStage(profiles, TagDictionary(), n_shards=2,
                        engine="streaming", batch_size=4, data_shards=2)
        b = FilterStage(profiles, TagDictionary(), n_shards=2,
                        engine="streaming", batch_size=4, data_shards=2)
        # feed a generator: the loop must stream (stage one batch ahead,
        # never materialize the whole payload iterable)
        got = self._routes(a.route_bytes_pipelined(iter(raw)))
        want = self._routes(b.route_bytes(raw))
        assert got == want
        # 3 batches of 4 → the first two had a successor staged while
        # their filter step was in flight
        assert a.stats["overlapped_batches"] == 2
        assert a.stats["put_seconds"] >= 0.0

    def test_pipelined_falls_back_without_mesh(self):
        profiles, docs, raw = self._workload(seed=8, n_docs=5)
        stage = FilterStage(profiles, TagDictionary(), n_shards=2,
                            engine="streaming", batch_size=4)
        assert stage.mesh is None
        got = self._routes(stage.route_bytes_pipelined(raw))
        want = self._routes(
            FilterStage(profiles, TagDictionary(), n_shards=2,
                        engine="streaming",
                        batch_size=4).route_bytes(raw))
        assert got == want

    def test_data_shards_only_needs_no_query_shards(self):
        """data_shards=2 with a monolithic query set still runs the 2-D
        program (one part, stacked) and routes identically."""
        profiles, docs, raw = self._workload(seed=9, n_docs=6)
        mono = FilterStage(profiles, TagDictionary(), n_shards=2,
                           engine="streaming", batch_size=3)
        ds = FilterStage(profiles, TagDictionary(), n_shards=2,
                         engine="streaming", batch_size=3, data_shards=2)
        assert ds.sharded_ is not None and ds.sharded_.n_parts == 1
        assert self._routes(mono.route(docs)) == self._routes(ds.route(docs))

    def test_churn_on_2d_stage_route_parity(self):
        profiles, docs, raw = self._workload(seed=10, n_docs=6)
        extra = gen_profiles(DTD.generate(n_tags=24, seed=10), n=3,
                             length=3, seed=55)
        mono = FilterStage(profiles, TagDictionary(), n_shards=2,
                           engine="streaming", batch_size=3)
        two_d = FilterStage(profiles, TagDictionary(), n_shards=2,
                            engine="streaming", batch_size=3,
                            query_shards=2, data_shards=2)
        for stage in (mono, two_d):
            gids = [stage.subscribe(q) for q in extra]
            stage.unsubscribe(gids[1])
        assert self._routes(mono.route(docs)) == self._routes(
            two_d.route(docs))

    def test_throughput_reports_per_axis_stats(self):
        profiles, docs, raw = self._workload(seed=11, n_docs=5)
        stage = FilterStage(profiles, TagDictionary(), n_shards=2,
                            engine="streaming", batch_size=4,
                            query_shards=2, data_shards=2)
        list(stage.route_bytes_pipelined(raw))
        tp = stage.throughput()
        shape = dict(stage.mesh.shape)
        assert tp["data_shards"] == 2
        assert tp["mesh_data"] == shape["data"]
        assert tp["mesh_model"] == shape["model"]
        assert tp["docs_per_s_per_data_shard"] == pytest.approx(
            tp["docs_per_s"] / shape["data"])
        assert tp["queries_per_model_shard"] >= len(profiles) // 2
        assert "put_s" in tp and "overlapped_batches" in tp
