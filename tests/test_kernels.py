"""Pallas kernels vs pure-jnp ref oracles (interpret=True on CPU)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.dictionary import TagDictionary
from repro.core.engines.oracle import filter_document as oracle_filter
from repro.core.engines.levelwise import LevelwiseEngine
from repro.core.events import encode_bytes
from repro.core.nfa import compile_queries, pad_states
from repro.kernels import ops, ref
from repro.kernels.blocks import partition, state_layout
from repro.kernels.nfa_transition import nfa_transition_pallas
from repro.kernels.predecode import predecode_pallas
from repro.kernels.stream_filter import fuse_events, stream_filter_pallas
from repro.data.generator import DTD, gen_document, gen_profiles



class TestPredecodeKernel:
    @pytest.mark.parametrize("n_tags,text_fill", [(5, 0), (64, 3), (200, 9)])
    def test_matches_ref_and_codec(self, n_tags, text_fill):
        d = TagDictionary.build([f"t{i}" for i in range(n_tags)])
        rng = np.random.default_rng(n_tags)
        ids = rng.integers(0, n_tags, size=50)
        ks, ts = [], []
        for i in ids:
            ks += [0, 1]
            ts += [i, i]
        from repro.core.events import EventStream
        ev = EventStream(np.array(ks, np.int8), np.array(ts, np.int32))
        buf = encode_bytes(ev, text_fill=text_fill)
        arr = jnp.asarray(np.frombuffer(buf, np.uint8))
        k_ref, t_ref = ref.predecode(arr)
        k_pal, t_pal = predecode_pallas(arr, interpret=True)
        np.testing.assert_array_equal(np.asarray(k_pal), np.asarray(k_ref))
        np.testing.assert_array_equal(np.asarray(t_pal), np.asarray(t_ref))
        back = ops.decode_document(buf, d)
        np.testing.assert_array_equal(back.kind, ev.kind)
        np.testing.assert_array_equal(back.tag_id, ev.tag_id)

    @pytest.mark.parametrize("n", [1, 127, 128, 1025, 4096])
    def test_shape_sweep_random_bytes(self, n):
        rng = np.random.default_rng(n)
        arr = jnp.asarray(rng.integers(0, 256, size=n, dtype=np.uint8))
        k_ref, t_ref = ref.predecode(arr)
        k_pal, t_pal = predecode_pallas(arr, interpret=True)
        np.testing.assert_array_equal(np.asarray(k_pal), np.asarray(k_ref))
        np.testing.assert_array_equal(np.asarray(t_pal), np.asarray(t_ref))


class TestNfaTransitionKernel:
    @pytest.mark.parametrize("w,s_mult,n_q", [(4, 1, 8), (16, 2, 24),
                                              (130, 4, 64)])
    def test_matches_ref(self, w, s_mult, n_q):
        dtd = DTD.generate(n_tags=16, seed=w)
        d = TagDictionary()
        dtd.register(d)
        qs = gen_profiles(dtd, n=n_q, length=4, seed=w)
        nfa = pad_states(compile_queries(qs, d), 128 * s_mult)
        rng = np.random.default_rng(w)
        s = nfa.n_states
        parent = jnp.asarray(
            (rng.random((w, s)) < 0.2).astype(np.float32))
        tags = jnp.asarray(rng.integers(-1, nfa.n_tags, size=w).astype(np.int32))
        req = jnp.asarray(nfa.req_matrix())
        wild = jnp.asarray(nfa.wild_vector())
        p1h = jnp.asarray(nfa.parent_onehot())
        sl = jnp.asarray(nfa.tables.selfloop.astype(np.float32))
        want = ref.nfa_transition(parent, tags, req, wild, p1h, sl)
        for bw, bs in [(8, 128), (128, 128), (16, s)]:
            got = nfa_transition_pallas(parent, tags, req, wild, p1h, sl,
                                        bw=bw, bs=bs, interpret=True)
            np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                       err_msg=f"bw={bw} bs={bs}")

    def test_levelwise_engine_kernel_path(self):
        dtd = DTD.generate(n_tags=14, seed=9)
        d = TagDictionary()
        dtd.register(d)
        qs = gen_profiles(dtd, n=32, length=4, seed=9)
        ev = gen_document(dtd, target_nodes=100, seed=9)
        nfa = compile_queries(qs, d)
        want = oracle_filter(nfa, ev, d)
        eng = LevelwiseEngine(nfa, use_kernel=True)
        got = eng.filter_document(ev)
        np.testing.assert_array_equal(got.matched, want.matched)
        np.testing.assert_array_equal(got.first_event, want.first_event)


class TestStreamFilterKernel:
    def test_block_vs_ref_random_tables(self):
        """Megakernel vs the pure-jnp word-block oracle on random packed
        tables (no NFA semantics — pure kernel-vs-oracle agreement)."""
        rng = np.random.default_rng(0)
        blk, wb, n, n_tags, qb = 64, 2, 60, 8, 6
        kind = rng.integers(0, 3, size=(2, n)).astype(np.int32)
        tag = rng.integers(0, n_tags, size=(2, n)).astype(np.int32)
        events = fuse_events(jnp.asarray(kind), jnp.asarray(tag))
        tagmask = rng.integers(0, 2**32, size=(n_tags + 1, wb),
                               dtype=np.uint32)
        in_state = np.minimum(rng.integers(0, blk, blk),
                              np.arange(blk)).astype(np.int32)
        pw = (in_state >> 5).reshape(wb, 32).astype(np.int32)
        pb = (in_state & 31).reshape(wb, 32).astype(np.int32)
        selfw = rng.integers(0, 2**32, size=wb, dtype=np.uint32)
        initw = rng.integers(0, 2**32, size=wb, dtype=np.uint32)
        accw = rng.integers(0, wb, qb).astype(np.int32)
        accb = rng.integers(0, 32, qb).astype(np.int32)
        args = [jnp.asarray(a) for a in
                (tagmask, pw, pb, selfw, initw, accw, accb)]
        got_m, got_f = stream_filter_pallas(
            events, *(a[None] for a in args), max_depth=16, chunk=32,
            interpret=True)
        for b in range(2):
            want_m, want_f = ref.stream_filter_words(
                events[b], *args, max_depth=16)
            np.testing.assert_array_equal(
                np.asarray(got_m[b, 0]).astype(bool), np.asarray(want_m))
            np.testing.assert_array_equal(np.asarray(got_f[b, 0]),
                                          np.asarray(want_f))

    @pytest.mark.parametrize("seed,blk", [(0, 64), (1, 128), (2, 256)])
    def test_engine_matches_oracle(self, seed, blk):
        dtd = DTD.generate(n_tags=14, seed=seed)
        d = TagDictionary()
        dtd.register(d)
        qs = gen_profiles(dtd, n=40, length=4, p_wild=0.1, seed=seed)
        ev = gen_document(dtd, target_nodes=120, seed=seed)
        eng = ops.StreamFilterKernelEngine(qs, d, blk=blk, max_depth=32)
        got = eng.filter_document(ev)
        nfa = compile_queries(qs, d)
        want = oracle_filter(nfa, ev, d)
        np.testing.assert_array_equal(got.matched, want.matched)
        np.testing.assert_array_equal(got.first_event, want.first_event)

    def test_partition_blocks_closed_under_parents(self):
        dtd = DTD.generate(n_tags=10, seed=5)
        d = TagDictionary()
        dtd.register(d)
        qs = gen_profiles(dtd, n=64, length=5, seed=5)
        t = partition(qs, d, blk=128)
        # every parent pointer stays in-block by construction: P row sums
        for g in range(t.n_blocks):
            assert t.parent_1h[g].sum(axis=0).max() <= 1.0
        assert t.n_blocks >= 1

    def test_partition_word_aligns_block_size(self):
        dtd = DTD.generate(n_tags=10, seed=6)
        d = TagDictionary()
        dtd.register(d)
        qs = gen_profiles(dtd, n=16, length=4, seed=6)
        t = partition(qs, d, blk=100)  # rounds up to the next word
        assert t.blk % 32 == 0 and t.blk >= 100

    def test_state_layout_parent_closed_and_word_aligned(self):
        dtd = DTD.generate(n_tags=12, seed=7)
        d = TagDictionary()
        dtd.register(d)
        qs = gen_profiles(dtd, n=48, length=5, p_wild=0.1, seed=7)
        nfa = pad_states(compile_queries(qs, d, shared=True), 32)
        mk = state_layout(nfa, blk=64)
        t = nfa.tables
        assert mk.blk % 32 == 0
        for s in range(1, nfa.n_states):
            if mk.state_block[s] < 0:
                continue  # inert pad state dropped, or replicated context
            p = int(t.in_state[s])
            # parents stay in-block (root and constant-on context states
            # are replicated per block: state_block == -2)
            assert (p == 0 or mk.state_block[p] == -2
                    or mk.state_block[p] == mk.state_block[s])
        # every query's accept lane points at its accept state's bit
        for q in range(nfa.n_queries):
            a = int(t.accept_state[q])
            g, slot = int(mk.acc_block[q]), int(mk.acc_slot[q])
            loc = int(mk.state_local[a])
            assert mk.state_block[a] == g
            assert int(mk.acc_word[g, slot]) == loc >> 5
            assert int(mk.acc_bit[g, slot]) == loc & 31


class TestWavefrontKernelPath:
    def test_wavefront_kernel_matches_oracle(self):
        from repro.core.engines.levelwise import WavefrontEngine
        dtd = DTD.generate(n_tags=14, seed=11)
        d = TagDictionary()
        dtd.register(d)
        qs = gen_profiles(dtd, n=24, length=4, p_wild=0.1, seed=11)
        nfa = compile_queries(qs, d)
        for seed in range(3):
            ev = gen_document(dtd, target_nodes=90, seed=seed + 40)
            want = oracle_filter(nfa, ev, d)
            got = WavefrontEngine(nfa, chunk=32,
                                  use_kernel=True).filter_document(ev)
            np.testing.assert_array_equal(got.matched, want.matched)
            np.testing.assert_array_equal(got.first_event, want.first_event)
