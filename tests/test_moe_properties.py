"""Property tests for the MoE dispatch invariants (dense path)."""
import jax
import jax.numpy as jnp
import numpy as np
from _hypothesis_shim import given, settings, st

from repro.configs import get_config
from repro.models import layers as L


def _cfg(e=8, k=2, cf=1.25):
    return get_config("qwen3-moe-30b-a3b", reduced=True).with_(
        n_experts=e, moe_top_k=k, capacity_factor=cf)


class TestMoEProperties:
    @settings(max_examples=15, deadline=None)
    @given(seed=st.integers(0, 1000), b=st.integers(1, 4),
           l=st.sampled_from([1, 4, 8]))
    def test_finite_and_shaped(self, seed, b, l):
        cfg = _cfg()
        p = L.init_moe(cfg, jax.random.PRNGKey(seed % 7))
        x = jax.random.normal(jax.random.PRNGKey(seed), (b, l, cfg.d_model))
        y = L.moe(cfg, p, x)
        assert y.shape == x.shape
        assert np.isfinite(np.asarray(y)).all()

    def test_router_weights_sum_to_one(self):
        for router in ("softmax", "sigmoid"):
            cfg = _cfg().with_(router=router)
            logits = jax.random.normal(jax.random.PRNGKey(0), (32, 8))
            w, idx = L._router_weights(cfg, logits)
            np.testing.assert_allclose(np.asarray(w.sum(-1)), 1.0,
                                       rtol=1e-5)
            assert (np.asarray(idx) < cfg.n_experts).all()
            # top-k picks distinct experts per token
            for row in np.asarray(idx):
                assert len(set(row.tolist())) == cfg.moe_top_k

    def test_capacity_drop_is_graceful(self):
        """Overloading one expert (identical tokens, low cf) forces
        capacity drops: dropped tokens produce zero rows (no shared
        expert), never NaN; ample capacity keeps every token."""
        cfg_lo = _cfg(cf=0.1).with_(n_shared_experts=0)
        cfg_hi = _cfg(cf=8.0).with_(n_shared_experts=0)
        p = L.init_moe(cfg_hi, jax.random.PRNGKey(1))
        tok = jax.random.normal(jax.random.PRNGKey(2), (1, 1, cfg_hi.d_model))
        x = jnp.tile(tok, (1, 256, 1))   # 256 identical tokens → 1 expert
        y_lo = np.asarray(L.moe(cfg_lo, p, x))
        y_hi = np.asarray(L.moe(cfg_hi, p, x))
        assert np.isfinite(y_lo).all() and np.isfinite(y_hi).all()
        zero_lo = (np.abs(y_lo).max(-1) < 1e-9).sum()
        zero_hi = (np.abs(y_hi).max(-1) < 1e-9).sum()
        assert zero_lo > 0 and zero_hi == 0

    def test_identical_tokens_identical_outputs(self):
        """Permutation/consistency: duplicate tokens route identically."""
        cfg = _cfg(cf=8.0)
        p = L.init_moe(cfg, jax.random.PRNGKey(3))
        tok = jax.random.normal(jax.random.PRNGKey(4), (1, 1, cfg.d_model))
        x = jnp.tile(tok, (2, 3, 1))
        y = np.asarray(L.moe(cfg, p, x)).reshape(-1, cfg.d_model)
        for row in y[1:]:
            np.testing.assert_allclose(row, y[0], rtol=1e-4, atol=1e-5)
