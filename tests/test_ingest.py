"""Device-resident ingestion: ByteBatch → parse kernels → EventBatch.

The PR-level contract: a batch of raw paper-format byte streams becomes
a filter verdict with no per-event host Python, and the device parser
(:func:`repro.kernels.parse.parse_batch`) is *bit-identical* to the host
oracle (:meth:`repro.core.events.EventBatch.from_streams`) on every
well-formed corpus — kind, tag_id, depth, parent, valid and n_events.
"""
import numpy as np
import pytest

import jax.numpy as jnp

from _hypothesis_shim import given, settings, st
from repro.core import engines
from repro.core.dictionary import TagDictionary
from repro.core.events import (CLOSE, OPEN, ByteBatch, EventBatch,
                               EventStream, bucket_length, decode_bytes,
                               encode_bytes)
from repro.core.nfa import compile_queries
from repro.data.filter_stage import FilterStage
from repro.data.generator import DTD, gen_corpus, gen_document, gen_profiles
from repro.kernels import ops, ref
from repro.kernels.parse import parse_batch, structure_scan
from repro.kernels.predecode import predecode_pallas


def _corpus(seed, n_docs=5, nodes=60):
    dtd = DTD.generate(n_tags=24, seed=seed)
    d = TagDictionary()
    dtd.register(d)
    return dtd, d, gen_corpus(dtd, n_docs=n_docs, nodes_per_doc=nodes,
                              seed=seed)


def _assert_batches_identical(got: EventBatch, want: EventBatch, msg=""):
    got = got.to_host()
    for f in ("kind", "tag_id", "depth", "parent", "valid", "n_events"):
        np.testing.assert_array_equal(
            getattr(got, f), getattr(want, f),
            err_msg=f"{f} differs {msg}")


# -------------------------------------------------------------- ByteBatch
class TestByteBatch:
    def test_from_buffers_pads_and_recovers(self):
        bufs = [b"<aa><ab></ab></aa>", b"<ba></ba>"]
        bb = ByteBatch.from_buffers(bufs, bucket=32)
        assert bb.batch_size == 2
        assert bb.length == 32
        assert list(bb.n_bytes) == [18, 9]
        assert list(bb.buffers()) == bufs
        # zero padding: tail bytes decode to nothing
        assert (np.asarray(bb.data)[1, 9:] == 0).all()
        assert bb.nbytes_total() == 27

    def test_from_streams_matches_encode_bytes(self):
        _, _, docs = _corpus(0, n_docs=3, nodes=30)
        bb = ByteBatch.from_streams(docs, text_fill=3, bucket=64)
        for i, doc in enumerate(docs):
            assert bb.buffer(i) == encode_bytes(doc, text_fill=3)

    def test_max_events_bounds_true_event_count(self):
        _, _, docs = _corpus(1, n_docs=4, nodes=50)
        for tf in (0, 7):
            bb = ByteBatch.from_streams(docs, text_fill=tf)
            assert bb.max_events >= max(len(d) for d in docs)

    def test_empty_batch_raises(self):
        with pytest.raises(ValueError):
            ByteBatch.from_buffers([])


# ------------------------------------------- parse_batch vs host oracle
class TestParseBatchParity:
    """Acceptance criterion: bit-identical to EventBatch.from_streams."""

    @pytest.mark.parametrize("seed", [0, 1, 2])
    @pytest.mark.parametrize("text_fill", [0, 4])
    @pytest.mark.parametrize("bucket", [None, 64])
    def test_round_trips_generated_corpora(self, seed, text_fill, bucket):
        _, _, docs = _corpus(seed)
        bb = ByteBatch.from_streams(docs, text_fill=text_fill,
                                    bucket=bucket)
        got = parse_batch(bb)
        want = EventBatch.from_streams(docs).pad_to(got.length)
        _assert_batches_identical(
            got, want, f"(seed={seed} tf={text_fill} bucket={bucket})")

    def test_multi_root_forest(self):
        # forests (multiple top-level elements) exercise the depth floor
        ev = EventStream(
            np.array([OPEN, CLOSE, OPEN, OPEN, CLOSE, CLOSE, OPEN, CLOSE],
                     np.int8),
            np.array([1, 1, 2, 3, 3, 2, 1, 1], np.int32))
        bb = ByteBatch.from_streams([ev, ev], text_fill=2)
        got = parse_batch(bb)
        want = EventBatch.from_streams([ev, ev]).pad_to(got.length)
        _assert_batches_identical(got, want, "(forest)")

    def test_returns_device_arrays(self):
        _, _, docs = _corpus(3, n_docs=2, nodes=20)
        got = parse_batch(ByteBatch.from_streams(docs))
        assert got.is_device
        assert not isinstance(got.kind, np.ndarray)
        host = got.to_host()
        assert not host.is_device
        assert host.to_host() is host

    def test_explicit_n_events(self):
        _, _, docs = _corpus(4, n_docs=2, nodes=20)
        n = bucket_length(max(len(d) for d in docs), 32)
        got = parse_batch(ByteBatch.from_streams(docs), n_events=n)
        assert got.length == n
        _assert_batches_identical(
            got, EventBatch.from_streams(docs).pad_to(n), "(n_events)")

    @given(seed=st.integers(0, 10**6), text_fill=st.integers(0, 9),
           bucket=st.sampled_from([None, 16, 64, 128]))
    @settings(max_examples=15, deadline=None)
    def test_property_round_trip(self, seed, text_fill, bucket):
        """encode_bytes → parse_batch ≡ from_streams over random forests,
        text_fill values and bucket sizes (hypothesis; skipped without)."""
        dtd = DTD.generate(n_tags=16, seed=seed % 97)
        docs = [gen_document(dtd, target_nodes=10 + seed % 40,
                             max_depth=2 + seed % 9, seed=seed + i)
                for i in range(3)]
        bb = ByteBatch.from_streams(docs, text_fill=text_fill,
                                    bucket=bucket)
        got = parse_batch(bb)
        want = EventBatch.from_streams(docs).pad_to(got.length)
        _assert_batches_identical(got, want, f"(property seed={seed})")

    @pytest.mark.parametrize("use_kernel", [False, True])
    def test_pallas_and_oracle_predecode_paths_agree(self, use_kernel):
        """The ingest pipeline is identical through the Pallas kernel
        (interpret mode here) and its pure-jnp oracle pre-decode."""
        _, _, docs = _corpus(6, n_docs=3, nodes=40)
        bb = ByteBatch.from_streams(docs, text_fill=3, bucket=128)
        got = parse_batch(bb, use_kernel=use_kernel, interpret=True)
        want = EventBatch.from_streams(docs).pad_to(got.length)
        _assert_batches_identical(got, want, f"(use_kernel={use_kernel})")

    def test_deep_document_raises_instead_of_silent_clip(self):
        depth = 70
        ev = EventStream(
            np.array([OPEN] * depth + [CLOSE] * depth, np.int8),
            np.array(list(range(depth)) + list(range(depth))[::-1],
                     np.int32))
        bb = ByteBatch.from_streams([ev])
        with pytest.raises(ValueError, match="max_depth"):
            parse_batch(bb)  # default bound is 64
        got = parse_batch(bb, max_depth=depth)
        want = EventBatch.from_streams([ev]).pad_to(got.length)
        _assert_batches_identical(got, want, "(deep doc)")

    def test_too_small_n_events_truncates_consistently(self):
        _, _, docs = _corpus(7, n_docs=2, nodes=30)
        n = max(len(d) for d in docs) // 2
        got = parse_batch(ByteBatch.from_streams(docs), n_events=n)
        host = got.to_host()
        # counts must describe what the arrays actually contain
        assert int(host.n_events.max()) <= got.length
        np.testing.assert_array_equal(
            host.n_events, host.valid.sum(axis=1).astype(np.int32))

    def test_structure_scan_matches_structure_oracle(self):
        _, _, docs = _corpus(5, n_docs=4, nodes=80)
        for doc in docs:
            depth, parent = doc.structure()
            d_got, p_got = structure_scan(
                jnp.asarray(doc.kind.astype(np.int32)), max_depth=64)
            np.testing.assert_array_equal(np.asarray(d_got), depth)
            np.testing.assert_array_equal(np.asarray(p_got), parent)


# -------------------------------------------- batched predecode parity
class TestBatchedPredecode:
    @pytest.mark.parametrize("b,n", [(1, 64), (3, 127), (4, 256), (7, 1025)])
    def test_batched_equals_per_row(self, b, n):
        rng = np.random.default_rng(b * 1000 + n)
        data = rng.integers(0, 256, size=(b, n), dtype=np.uint8)
        k2, t2 = predecode_pallas(jnp.asarray(data), interpret=True)
        assert k2.shape == (b, n)
        for i in range(b):
            k1, t1 = predecode_pallas(jnp.asarray(data[i]), interpret=True)
            np.testing.assert_array_equal(np.asarray(k2[i]), np.asarray(k1),
                                          err_msg=f"row {i} kind")
            np.testing.assert_array_equal(np.asarray(t2[i]), np.asarray(t1),
                                          err_msg=f"row {i} tag")

    def test_no_bleed_across_document_boundaries(self):
        # doc 0 ends with a truncated '<a' split off by padding; doc 1
        # starts with symbol bytes — a flat decode would fuse them
        bufs = [b"<aa></aa><a", b"ab<ab></ab>"]
        bb = ByteBatch.from_buffers(bufs, bucket=16)
        k, t = predecode_pallas(jnp.asarray(bb.data), interpret=True)
        k_ref, t_ref = ref.predecode(jnp.asarray(bb.data))
        np.testing.assert_array_equal(np.asarray(k), np.asarray(k_ref))
        np.testing.assert_array_equal(np.asarray(t), np.asarray(t_ref))
        # the truncated tag in doc 0 must NOT produce an event
        assert (np.asarray(k[0]) != ref.PAD).sum() == 2

    def test_batched_ref_matches_stacked_1d(self):
        rng = np.random.default_rng(9)
        data = rng.integers(0, 256, size=(3, 200), dtype=np.uint8)
        k2, t2 = ref.predecode(jnp.asarray(data))
        for i in range(3):
            k1, t1 = ref.predecode(jnp.asarray(data[i]))
            np.testing.assert_array_equal(np.asarray(k2[i]), np.asarray(k1))
            np.testing.assert_array_equal(np.asarray(t2[i]), np.asarray(t1))


# --------------------------------------- host/kernel malformed parity
class TestDecodeBytesMalformed:
    """Regression: decode_bytes must reject invalid symbol bytes exactly
    like the kernel's ``ok = (v0 >= 0) & (v1 >= 0)`` check."""

    CASES = [
        b"<a#>x</ab>",          # invalid second open symbol
        b"<#a></aa>",           # invalid first open symbol
        b"</a*><ab>",           # invalid close symbol
        b"<aa><ab",             # truncated open at end of stream
        b"<aa></a",             # truncated close at end of stream
        b"<<aa>>",              # '<' immediately followed by '<'
        b"</",                  # bare close marker
    ]

    @pytest.mark.parametrize("buf", CASES)
    def test_host_matches_kernel(self, buf):
        d = TagDictionary.build(["t%d" % i for i in range(8)])
        host = decode_bytes(buf, d.symbol_value_table())
        dev = ops.decode_document(buf, d)
        np.testing.assert_array_equal(host.kind, dev.kind, err_msg=str(buf))
        np.testing.assert_array_equal(host.tag_id, dev.tag_id,
                                      err_msg=str(buf))

    def test_invalid_symbols_rejected(self):
        d = TagDictionary.build(["a"])
        ev = decode_bytes(b"<a#>", d.symbol_value_table())
        assert len(ev) == 0

    @pytest.mark.parametrize("seed", [0, 1])
    def test_random_bytes_host_matches_kernel(self, seed):
        rng = np.random.default_rng(seed)
        buf = bytes(rng.integers(0, 256, size=500, dtype=np.uint8))
        d = TagDictionary.build(["a"])
        host = decode_bytes(buf, d.symbol_value_table())
        dev = ops.decode_document(buf, d)
        np.testing.assert_array_equal(host.kind, dev.kind)
        np.testing.assert_array_equal(host.tag_id, dev.tag_id)


# ------------------------------------------------- fused filter path
class TestFilterBytes:
    def _workload(self, seed=0):
        dtd, d, docs = _corpus(seed, n_docs=6, nodes=50)
        qs = gen_profiles(dtd, n=16, length=3, seed=seed)
        nfa = compile_queries(qs, d, shared=True)
        return qs, nfa, d, docs

    @pytest.mark.parametrize("name", ["streaming", "levelwise", "oracle"])
    def test_filter_bytes_equals_filter_batch(self, name):
        qs, nfa, d, docs = self._workload(0)
        eng = engines.create(name, nfa, dictionary=d)
        want = eng.filter_batch(EventBatch.from_streams(docs))
        got = eng.filter_bytes(
            ByteBatch.from_streams(docs, text_fill=5, bucket=256))
        np.testing.assert_array_equal(got.matched, want.matched,
                                      err_msg=name)
        np.testing.assert_array_equal(got.first_event, want.first_event,
                                      err_msg=name)

    def test_route_bytes_matches_route(self):
        qs, nfa, d, docs = self._workload(1)
        payloads = [encode_bytes(doc, text_fill=4) for doc in docs]
        routes = {}
        for via in ("events", "bytes"):
            stage = FilterStage(qs, d, n_shards=3, engine="streaming",
                                batch_size=4)
            batches = (stage.route(docs) if via == "events"
                       else stage.route_bytes(payloads))
            routes[via] = {(r.doc_index, r.shard): tuple(r.matched_profiles)
                           for b in batches for r in b}
        assert routes["events"] == routes["bytes"]

    def test_route_bytes_accumulates_stats(self):
        qs, nfa, d, docs = self._workload(2)
        payloads = [encode_bytes(doc) for doc in docs]
        stage = FilterStage(qs, d, n_shards=2, engine="streaming",
                            batch_size=3)
        list(stage.route_bytes(payloads))
        tp = stage.throughput()
        assert tp["docs"] == len(docs)
        assert stage.stats["bytes"] == sum(len(p) for p in payloads)

    def test_from_filtered_bytes_pipeline(self):
        from repro.data.tokens import XMLBytePipeline

        qs, nfa, d, docs = self._workload(3)
        payloads = [encode_bytes(doc, text_fill=2) for doc in docs]
        stage = FilterStage(qs, d, n_shards=1, engine="streaming")
        pipe = XMLBytePipeline.from_filtered_bytes(payloads, stage,
                                                   batch=2, seq_len=16)
        b = pipe.batch_at(0)
        assert b["tokens"].shape == (2, 16)
        assert b["labels"].shape == (2, 16)
        with pytest.raises(ValueError):
            XMLBytePipeline(docs, batch=2, seq_len=8, payloads=payloads)
