"""CLI smoke tests for the serving driver (`repro.launch.serve`).

Each test drives ``main()`` end to end through ``sys.argv`` — model
init, pub-sub routing (the flag under test), churn, generation — and
asserts the *routed output parity* contract: whatever ingest path and
shard configuration the flags select, the replica queues printed by the
CLI must equal what a plain (monolithic, event-ingest) ``FilterStage``
routes for the same deterministic workload.
"""
import json
import math
import re
import sys

import pytest

import repro.launch.serve as serve
from repro.core.events import encode_bytes
from repro.data.filter_stage import TEXT_FILL, FilterStage
from repro.data.generator import DTD, gen_corpus, gen_profiles

REQUESTS, REPLICAS, BATCH = 8, 2, 4
BASE_ARGS = ["--requests", str(REQUESTS), "--replicas", str(REPLICAS),
             "--batch", str(BATCH), "--prompt-len", "4", "--gen-len", "2"]


def _run_main(monkeypatch, capsys, extra):
    monkeypatch.setattr(sys, "argv", ["serve"] + BASE_ARGS + list(extra))
    serve.main()
    return capsys.readouterr().out


def _printed_queues(out: str) -> list[int]:
    m = re.search(r"→ \[([0-9, ]*)\] per replica", out)
    assert m, f"no routed-queues line in output:\n{out}"
    return [int(x) for x in m.group(1).split(",")]


def _reference_queues() -> list[int]:
    """The parity oracle: a monolithic event-ingest FilterStage over the
    same deterministic workload ``main`` builds (seed 0 profiles, seed 1
    corpus)."""
    dtd = DTD.generate(n_tags=24, seed=0)
    from repro.core.dictionary import TagDictionary
    d = TagDictionary()
    dtd.register(d)
    profiles = gen_profiles(dtd, n=32, length=3, seed=0)
    stage = FilterStage(profiles, d, n_shards=REPLICAS, engine="levelwise",
                        keep_unmatched=True, batch_size=BATCH)
    payloads = gen_corpus(dtd, n_docs=REQUESTS, nodes_per_doc=60, seed=1)
    queues = [0] * REPLICAS
    for routed in stage.route(payloads):
        for r in routed:
            queues[r.shard] += 1
    return queues


@pytest.fixture(scope="module")
def reference_queues():
    return _reference_queues()


@pytest.mark.parametrize("extra", [
    ["--ingest", "bytes"],
    ["--query-shards", "2"],
    ["--data-shards", "2", "--ingest", "bytes"],
    ["--query-shards", "2", "--data-shards", "2", "--ingest", "bytes"],
], ids=["bytes", "qshards", "dshards-bytes", "2d-bytes"])
def test_cli_routes_identically_to_filter_stage(monkeypatch, capsys,
                                                reference_queues, extra):
    out = _run_main(monkeypatch, capsys, extra)
    assert f"[serve] routed {REQUESTS} requests" in out
    assert _printed_queues(out) == reference_queues
    # the full driver ran: churn served live, replicas generated tokens
    assert "[serve] live churn" in out
    assert "generated" in out


def test_cli_data_shards_prints_per_axis_stats(monkeypatch, capsys,
                                               reference_queues):
    out = _run_main(monkeypatch, capsys,
                    ["--data-shards", "2", "--ingest", "bytes"])
    m = re.search(r"2-D mesh data×model = (\d+)×(\d+)", out)
    assert m, f"no per-axis stats line in output:\n{out}"
    assert "docs/s per data shard" in out
    assert "queries per model shard" in out
    assert "overlapped transfers" in out
    assert _printed_queues(out) == reference_queues


def test_cli_continuous_replay_routes_identically(monkeypatch, capsys,
                                                  reference_queues):
    """--arrival switches to the continuous serve loop; with nothing
    shed its delivery queues must equal the batch driver's (the loop is
    schedule, not semantics), and the SLO summary must be printed."""
    out = _run_main(monkeypatch, capsys,
                    ["--arrival", "replay", "--rate", "2000"])
    assert f"[serve] routed {REQUESTS} requests (bytes, replay arrivals)" \
        in out
    assert _printed_queues(out) == reference_queues
    m = re.search(r"SLO bytes→verdict: p50 ([0-9.]+) ms, "
                  r"p99 ([0-9.]+) ms, p999 ([0-9.]+) ms", out)
    assert m, f"no SLO line in output:\n{out}"
    assert all(math.isfinite(float(g)) and float(g) > 0 for g in m.groups())
    assert f"{REQUESTS}/{REQUESTS} served" in out  # nothing shed
    assert "backpressure waits at K=2" in out
    # the rest of the driver still runs after loop mode
    assert "[serve] live churn" in out
    assert "generated" in out


def test_cli_burst_writes_latency_json(monkeypatch, capsys, tmp_path,
                                       reference_queues):
    path = tmp_path / "serve_latency.json"
    out = _run_main(monkeypatch, capsys,
                    ["--arrival", "burst", "--rate", "800",
                     "--deadline-ms", "20", "--max-inflight", "4",
                     "--queue-cap", "32", "--latency-json", str(path)])
    data = json.loads(path.read_text())
    assert data["arrival"] == "burst" and data["max_inflight"] == 4
    slo = data["slo"]
    assert slo["admitted"] + slo["shed"] == REQUESTS
    assert math.isfinite(slo["p99_ms"])
    assert sum(data["histogram"]["counts"]) == slo["completed"]
    assert len(data["latencies_ms"]) == slo["completed"]
    # cap 32 over 8 requests: nothing sheds, so parity must hold
    assert slo["shed"] == 0
    assert _printed_queues(out) == reference_queues


def test_cli_overload_block_never_sheds(monkeypatch, capsys,
                                        reference_queues):
    """A tiny queue cap under a hot trace with --overload block: the
    producer stalls instead of shedding, every request is served."""
    out = _run_main(monkeypatch, capsys,
                    ["--arrival", "poisson", "--rate", "4000",
                     "--queue-cap", "2", "--overload", "block"])
    assert "shed 0 = 0.0%" in out
    assert _printed_queues(out) == reference_queues


def test_route_requests_helper_matches_stage_routing():
    """The CLI's routing helper (2-D pipelined bytes path) fans out to
    the same queues as direct FilterStage routing."""
    stage, dtd = serve.build_stage(REPLICAS, batch_size=BATCH,
                                   query_shards=2, data_shards=2)
    payloads = gen_corpus(dtd, n_docs=REQUESTS, nodes_per_doc=60, seed=1)
    raw = [encode_bytes(doc, text_fill=TEXT_FILL) for doc in payloads]
    got = serve.route_requests(stage, payloads, ingest="bytes", raw=raw)
    assert [len(q) for q in got] == _reference_queues()
