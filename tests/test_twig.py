"""Twig filtering (paper §5 extension): parser, decomposition,
two-stage engine vs brute-force ground truth."""
import pytest
from _hypothesis_shim import given, settings, st

from repro.core.dictionary import TagDictionary
from repro.core.events import to_trees
from repro.core.twig import (TwigFilter, _twig_matches_tree, decompose,
                             parse_twig)
from repro.core.xpath import XPathSyntaxError

from test_engines import ev_from_nested, fresh_dict


class TestParserAndDecomposition:
    def test_parse_linear(self):
        tq = parse_twig("a//b/c")
        assert tq.is_linear
        assert [str(q) for q in decompose(tq)] == ["//a//b/c"]

    def test_parse_branches(self):
        tq = parse_twig("a[b//c][d]/e")
        assert not tq.is_linear
        # bare branch head = child axis (XPath predicate semantics)
        assert {str(q) for q in decompose(tq)} == \
            {"//a/b//c", "//a/d", "//a/e"}

    def test_nested_branches(self):
        tq = parse_twig("/a[b[c]/d]//e")
        paths = {str(q) for q in decompose(tq)}
        assert paths == {"/a/b/c", "/a/b/d", "/a//e"}

    @pytest.mark.parametrize("bad", ["a[", "a]b", "a[]", "a[b]]"])
    def test_rejects(self, bad):
        with pytest.raises(XPathSyntaxError):
            parse_twig(bad)


class TestTwigSemantics:
    def test_branch_needs_both(self):
        d = fresh_dict()
        #  t0 → (t1, t2)  vs  t0 → t1 only
        ev_both = ev_from_nested([(0, [(1, []), (2, [])])])
        ev_one = ev_from_nested([(0, [(1, [])])])
        f = TwigFilter(["t0[t1][t2]"], d)
        assert f.filter_document(ev_both).matched[0]
        assert not f.filter_document(ev_one).matched[0]

    def test_false_positive_eliminated(self):
        """Paths match in *different* subtrees — decomposition says yes,
        stage 2 must reject (the paper's stated failure mode)."""
        d = fresh_dict()
        # <t9><t0><t1/></t0><t0><t2/></t0></t9>: t0[t1][t2] has both paths
        # //t0//t1 and //t0//t2 matching, but never under the same t0
        ev = ev_from_nested([(9, [(0, [(1, [])]), (0, [(2, [])])])])
        f = TwigFilter(["t0[t1][t2]"], d)
        res = f.filter_document(ev)
        assert not res.matched[0]
        assert f.stats["stage2_rejects"] == 1

    def test_child_vs_descendant_branches(self):
        d = fresh_dict()
        ev = ev_from_nested([(0, [(1, [(2, [])])])])  # t0 > t1 > t2
        f = TwigFilter(["t0[/t2]", "t0[//t2]", "t0[/t1/t2]"], d)
        res = f.filter_document(ev)
        assert list(res.matched) == [False, True, True]

    def test_mixed_with_linear(self):
        d = fresh_dict()
        ev = ev_from_nested([(0, [(1, []), (2, [(3, [])])])])
        # t0[t3] needs a *child* t3 (t3 is a grandchild) → no match;
        # t0[//t3] (descendant) does match
        f = TwigFilter(["t0/t1", "t0[t1]/t2/t3", "t0[t3]/t1",
                        "t0[//t3]/t1"], d)
        res = f.filter_document(ev)
        assert list(res.matched) == [True, True, False, True]

    @settings(max_examples=30, deadline=None)
    @given(data=st.data())
    def test_property_vs_ground_truth(self, data):
        n_tags = data.draw(st.integers(2, 5))
        d = TagDictionary.build([f"t{i}" for i in range(n_tags)])

        def tree(depth):
            return st.tuples(
                st.integers(0, n_tags - 1),
                st.lists(tree(depth - 1), max_size=3) if depth > 0
                else st.just([]))

        spec = data.draw(st.lists(tree(3), min_size=1, max_size=2))
        ev = ev_from_nested(spec)
        tags = [f"t{j}" for j in range(n_tags)]
        # random twig: root with 1-2 branches, each 1-2 steps
        root = data.draw(st.sampled_from(tags))
        parts = []
        for _ in range(data.draw(st.integers(1, 2))):
            steps = [data.draw(st.sampled_from(["/", "//"]))
                     + data.draw(st.sampled_from(tags))
                     for _ in range(data.draw(st.integers(1, 2)))]
            parts.append("[" + "".join(steps) + "]")
        twig_s = root + "".join(parts)
        tq = parse_twig(twig_s)
        f = TwigFilter([tq], d)
        got = bool(f.filter_document(ev).matched[0])
        want = _twig_matches_tree(to_trees(ev), tq, d)
        assert got == want, twig_s
