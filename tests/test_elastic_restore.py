"""Elastic checkpoint restore: save under one mesh, restore under another.

The manifest stores the logical pytree only, so a checkpoint written on a
single device restores onto a 2×2 mesh with production shardings (and
back) — the property pod-elastic restarts rely on.  Subprocess keeps the
4-device XLA_FLAGS isolated.
"""
import os
import subprocess
import sys

SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import sys; sys.path.insert(0, %r)
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.checkpoint.store import CheckpointStore
from repro.configs import get_config
from repro.models import transformer as T
from repro.sharding import rules as R

ckpt_dir = sys.argv[1]
cfg = get_config("qwen3-0.6b", reduced=True).with_(n_layers=2)
params = T.init_model(cfg, jax.random.PRNGKey(0))

# 1. save from single-device (replicated) layout
store = CheckpointStore(ckpt_dir)
store.save(3, params, {"config": cfg.name, "mesh": "none"})

# 2. restore onto a 2x2 production-style mesh with rule shardings
mesh = jax.make_mesh((2, 2), ("data", "model"))
shapes = jax.eval_shape(lambda: T.init_model(cfg, jax.random.PRNGKey(0)))
shardings = R.param_shardings(cfg, shapes, mesh)
step, restored, manifest = store.restore_latest(params, shardings)
assert step == 3 and manifest["config"] == cfg.name

# values identical, placement resharded
for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored)):
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
n_sharded = sum(1 for l in jax.tree.leaves(restored)
                if len(l.sharding.device_set) > 1)
assert n_sharded > 0, "nothing actually resharded"

# 3. save from the sharded layout and restore replicated (shrink)
store.save(4, restored, {"mesh": "2x2"})
step2, back, _ = store.restore_latest(params, None)
assert step2 == 4
for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(back)):
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
print("ELASTIC_OK", n_sharded)
"""


def test_elastic_reshard_roundtrip(tmp_path):
    r = subprocess.run(
        [sys.executable, "-c", SCRIPT % SRC, str(tmp_path)],
        capture_output=True, text=True, timeout=600)
    assert r.returncode == 0, (r.stdout[-800:], r.stderr[-3000:])
    assert "ELASTIC_OK" in r.stdout
