"""Fault-tolerance tests: typed document errors, poison quarantine,
shadow-plan hot swap, and the crash-safe plan cache.

The containment contract under test (ISSUE: fault-tolerant serving):
a bad *document* — malformed bytes, over-depth nesting, a payload that
makes the device call raise — fails only the requests that carried it,
with a typed :class:`~repro.core.events.DocumentError`, while every
co-batched healthy request gets the bit-identical verdict a fault-free
run computes.  Subscription changes build on a shadow thread and commit
atomically at a batch boundary (or roll back, leaving the serving plan
untouched), and compiled plans persist in a content-addressed cache
whose entries survive torn writes.
"""
import os
import threading

import numpy as np
import pytest

from _hypothesis_shim import given, settings, st

from repro.checkpoint.store import (CheckpointStore, PlanCache,
                                    _valid_entry, _write_entry,
                                    _write_pointer)
from repro.core import engines
from repro.core.dictionary import TagDictionary
from repro.core.events import (DEFAULT_MAX_DEPTH, DepthOverflow,
                               DocumentError, KernelFault,
                               MalformedDocument, encode_bytes,
                               validate_payload)
from repro.core.nfa import compile_queries
from repro.data.filter_stage import (TEXT_FILL, FilterStage, PlanEpoch,
                                     StalePlanError)
from repro.data.generator import DTD, gen_corpus, gen_profiles
from repro.serve.faults import (DEFAULT_PLAN, FaultInjector, FaultPlan,
                                run_chaos_trace)
from repro.serve.loop import ServeLoop

ENGINE = "streaming"
N_QUERIES = 16
BATCH = 4


def _workload(n_docs=16, seed=0):
    dtd = DTD.generate(n_tags=24, seed=seed)
    d = TagDictionary()
    dtd.register(d)
    profiles = gen_profiles(dtd, n=N_QUERIES, length=3, seed=seed)
    docs = gen_corpus(dtd, n_docs=n_docs, nodes_per_doc=40, seed=1)
    raw = [encode_bytes(x, text_fill=TEXT_FILL) for x in docs]
    return profiles, d, dtd, raw


def _stage(profiles, d, **kw):
    kw.setdefault("engine", ENGINE)
    kw.setdefault("keep_unmatched", True)
    kw.setdefault("batch_size", BATCH)
    return FilterStage(profiles, d, n_shards=2, **kw)


def _nested(d, depth):
    return (b"".join(d.open_bytes(0) for _ in range(depth))
            + b"".join(d.close_bytes(0) for _ in range(depth)))


def _routes(tickets):
    return {(rd.doc_index, rd.shard): tuple(rd.matched_profiles)
            for t in tickets if not t.shed and not t.failed
            for rd in t.routed}


# ------------------------------------------------------- error taxonomy
class TestValidatePayload:
    def test_well_formed_corpus_validates(self):
        _, d, _, raw = _workload()
        for buf in raw:
            validate_payload(buf)  # must not raise

    def test_empty_payload_is_valid(self):
        validate_payload(b"")

    def test_unclosed_element_is_malformed(self):
        _, d, _, _ = _workload()
        with pytest.raises(MalformedDocument, match="unclosed"):
            validate_payload(d.open_bytes(0))

    def test_close_without_open_is_malformed(self):
        _, d, _, _ = _workload()
        with pytest.raises(MalformedDocument, match="without matching"):
            validate_payload(d.close_bytes(0))

    def test_undecodable_marker_is_malformed(self):
        with pytest.raises(MalformedDocument, match="undecodable"):
            validate_payload(b"<\xff\xff")

    def test_overdepth_is_depth_overflow(self):
        _, d, _, _ = _workload()
        with pytest.raises(DepthOverflow, match="max_depth"):
            validate_payload(_nested(d, DEFAULT_MAX_DEPTH + 1))

    def test_taxonomy_is_value_error(self):
        """Typed errors keep every pre-existing ``except ValueError``
        contract intact, and carry per-document attribution."""
        assert issubclass(MalformedDocument, DocumentError)
        assert issubclass(DepthOverflow, DocumentError)
        assert issubclass(KernelFault, DocumentError)
        assert issubclass(DocumentError, ValueError)
        e = DepthOverflow("deep", (3, 5))
        assert e.doc_indices == (3, 5)

    @given(depth=st.integers(min_value=1, max_value=2 * DEFAULT_MAX_DEPTH))
    @settings(max_examples=20, deadline=None)
    def test_depth_boundary_property(self, depth):
        """Nesting validates iff it fits the parser's bounded stack —
        the host check mirrors kernel semantics exactly."""
        d = TagDictionary()
        d.add("a")
        buf = _nested(d, depth)
        if depth <= DEFAULT_MAX_DEPTH:
            validate_payload(buf)
        else:
            with pytest.raises(DepthOverflow):
                validate_payload(buf)


class TestTypedErrorsOnRoutes:
    def test_route_bytes_overdepth_raises_typed(self):
        """The parse-path device route raises a typed ``DepthOverflow``
        (a ``ValueError``) naming the offending batch rows.  The
        streaming engine's fused byte path clips depth in-kernel
        instead of raising — which is exactly why the serve loop
        validates pre-admission (see the loop tests below)."""
        profiles, d, _, raw = _workload(n_docs=BATCH)
        stage = _stage(profiles, d, engine="levelwise")
        bad = raw[:2] + [_nested(d, DEFAULT_MAX_DEPTH + 16)] + raw[3:4]
        with pytest.raises(DepthOverflow) as ei:
            list(stage.route_bytes(bad))
        assert isinstance(ei.value, ValueError)
        assert 2 in ei.value.doc_indices

    @pytest.mark.parametrize("kw", [
        {}, {"sparse": True}, {"query_shards": 2},
        {"query_shards": 2, "data_shards": 2},
    ], ids=["dense", "sparse", "sharded", "mesh2d"])
    def test_loop_rejects_poison_on_every_route(self, kw):
        """Whatever route config serves the loop, malformed and
        over-depth payloads are rejected pre-admission with typed
        errors and the healthy co-submitted documents still get the
        fault-free verdicts."""
        profiles, d, _, raw = _workload(n_docs=6)
        want = _routes_ref(profiles, d, raw)
        loop = ServeLoop(_stage(profiles, d, **kw), max_batch=BATCH,
                         deadline_ms=60_000, queue_cap=64)
        with loop:
            bad_m = loop.submit(d.open_bytes(0))
            bad_d = loop.submit(_nested(d, DEFAULT_MAX_DEPTH + 1))
            tickets = [loop.submit(p) for p in raw]
        assert isinstance(bad_m.error, MalformedDocument)
        assert isinstance(bad_d.error, DepthOverflow)
        assert bad_m.seq == -1 and bad_d.seq == -1  # never admitted
        assert _routes(tickets) == want
        s = loop.slo_summary()
        assert s["rejected"] == 2 and s["quarantined"] == 2
        assert s["completed"] == len(raw)
        assert len(loop.dead_letter) == 2


def _routes_ref(profiles, d, raw):
    return {(r.doc_index, r.shard): tuple(r.matched_profiles)
            for b in _stage(profiles, d).route_bytes(raw) for r in b}


# -------------------------------------------------- quarantine/bisection
class _Poisoner:
    """Make the stage's batch call raise an *untyped* error whenever a
    marked payload is present — the loop must bisect to find it."""

    def __init__(self, stage, poison: set):
        self.poison = poison
        self.stage = stage
        self.calls = 0
        self._orig = stage._filter_bytebatch
        stage._filter_bytebatch = self._filter

    def _filter(self, bufs, record=True, epoch=None):
        self.calls += 1
        if any(b in self.poison for b in bufs):
            raise RuntimeError("poisoned batch")
        return self._orig(bufs, record=record, epoch=epoch)


class TestQuarantine:
    def _run(self, poison_at, n_docs=8):
        profiles, d, _, raw = _workload(n_docs=n_docs)
        healthy = [i for i in range(n_docs) if i not in poison_at]
        want = _routes_ref(profiles, d, [raw[i] for i in healthy])
        # poison payloads stay *valid* bytes (pass pre-admission);
        # uniqueness markers make them detectable by the poisoner
        marked = dict(enumerate(raw))
        for i in poison_at:
            marked[i] = raw[i] + d.open_bytes(1) + d.close_bytes(1)
        stage = _stage(profiles, d)
        _Poisoner(stage, {marked[i] for i in poison_at})
        loop = ServeLoop(stage, max_batch=BATCH, deadline_ms=60_000,
                         queue_cap=64)
        with loop:
            tickets = [loop.submit(marked[i]) for i in range(n_docs)]
        return loop, tickets, healthy, want

    def test_single_poison_quarantined_as_kernel_fault(self):
        loop, tickets, healthy, _ = self._run({2})
        t = tickets[2]
        assert t.failed and isinstance(t.error, KernelFault)
        assert t.error.doc_indices == (t.seq,)
        assert t.error.__cause__ is not None  # original fault chained
        s = loop.slo_summary()
        assert s["quarantined"] == 1 and s["failed"] == 0
        assert s["retries"] >= 1  # whole-batch retry ran before bisection
        recs = list(loop.dead_letter)
        assert len(recs) == 1 and recs[0]["error"] == "KernelFault"

    def test_healthy_verdicts_survive_quarantine(self):
        """Co-batched healthy documents get bit-identical verdicts —
        quarantine isolates, it never corrupts."""
        loop, tickets, healthy, want = self._run({2})
        got = {(rd.doc_index, rd.shard): tuple(rd.matched_profiles)
               for i in healthy for rd in tickets[i].routed}
        # doc_index is the per-delivery-batch row; compare the verdict
        # *sets* per shard instead (row numbering shifts when a poisoned
        # row is cut out)
        assert _verdict_sets(got) == _verdict_sets(want)

    @given(pos=st.sets(st.integers(min_value=0, max_value=7),
                       min_size=1, max_size=3))
    @settings(max_examples=5, deadline=None)
    def test_any_poison_subset_is_contained(self, pos):
        """Property: wherever the poison lands in the batch stream, the
        loop quarantines exactly those requests and completes the rest
        with fault-free verdicts."""
        loop, tickets, healthy, want = self._run(pos)
        for i in pos:
            assert tickets[i].failed
            assert isinstance(tickets[i].error, KernelFault)
        for i in healthy:
            assert not tickets[i].failed and tickets[i].routed is not None
        got = {(rd.doc_index, rd.shard): tuple(rd.matched_profiles)
               for i in healthy for rd in tickets[i].routed}
        assert _verdict_sets(got) == _verdict_sets(want)
        s = loop.slo_summary()
        assert s["quarantined"] == len(pos)
        assert s["arrived"] == (s["completed"] + s["shed"] + s["failed"]
                                + s["quarantined"])


def _verdict_sets(routes: dict) -> dict:
    out: dict[int, list] = {}
    for (_, shard), matched in sorted(routes.items()):
        out.setdefault(shard, []).append(tuple(sorted(matched)))
    return {k: sorted(v) for k, v in out.items()}


# ------------------------------------------------------------ accounting
class TestAccountingAndClose:
    def test_accounting_closes_with_mixed_outcomes(self):
        profiles, d, _, raw = _workload(n_docs=8)
        loop = ServeLoop(_stage(profiles, d), max_batch=BATCH,
                         deadline_ms=60_000, queue_cap=64)
        with loop:
            loop.submit(d.open_bytes(0))          # rejected
            for p in raw:
                loop.submit(p)                    # completed
        s = loop.slo_summary()
        assert s["arrived"] == s["admitted"] + s["shed"] + s["rejected"]
        assert s["arrived"] == (s["completed"] + s["shed"] + s["failed"]
                                + s["quarantined"])
        assert s["dead_letter_depth"] == 1

    def test_close_is_idempotent_and_reentrant(self):
        profiles, d, _, raw = _workload(n_docs=2)
        loop = ServeLoop(_stage(profiles, d), max_batch=BATCH,
                         deadline_ms=5, queue_cap=8)
        with loop:
            ts = [loop.submit(p) for p in raw]
        loop.close()   # second close after __exit__: no-op
        loop.close()   # third: still a no-op
        assert all(t.done.is_set() for t in ts)

    def test_concurrent_close_from_two_threads(self):
        profiles, d, _, raw = _workload(n_docs=2)
        loop = ServeLoop(_stage(profiles, d), max_batch=BATCH,
                         deadline_ms=5, queue_cap=8)
        for p in raw:
            loop.submit(p)
        t = threading.Thread(target=loop.close)
        t.start()
        loop.close()
        t.join(timeout=120)
        assert not t.is_alive()

    def test_submit_after_close_sheds(self):
        profiles, d, _, raw = _workload(n_docs=1)
        loop = ServeLoop(_stage(profiles, d), max_batch=BATCH,
                         deadline_ms=5, queue_cap=8)
        loop.close()
        t = loop.submit(raw[0])
        assert t.shed and t.done.is_set()

    def test_dead_letter_buffer_is_bounded(self):
        profiles, d, _, _ = _workload(n_docs=1)
        loop = ServeLoop(_stage(profiles, d), max_batch=BATCH,
                         deadline_ms=5, queue_cap=8, dead_letter_cap=3)
        with loop:
            for _ in range(10):
                loop.submit(d.open_bytes(0))
        assert len(loop.dead_letter) == 3
        assert loop.slo_summary()["rejected"] == 10


# ---------------------------------------------------- shadow-plan hot swap
class TestShadowSwap:
    def test_prepare_commit_subscribe(self):
        profiles, d, dtd, raw = _workload()
        stage = _stage(profiles, d, query_shards=2)
        q = gen_profiles(dtd, n=1, length=3, seed=50)[0]
        ep0 = stage.plan_epoch()
        pending = stage.prepare_subscribe(q)
        gid = stage.commit(pending)
        assert gid == N_QUERIES
        assert stage.plan_epoch().epoch == ep0.epoch + 1

    def test_stale_prepare_raises_and_retry_succeeds(self):
        """A prepare built against a superseded epoch must NOT commit
        (it would silently drop the interleaved change)."""
        profiles, d, dtd, raw = _workload()
        stage = _stage(profiles, d, query_shards=2)
        qa, qb = gen_profiles(dtd, n=2, length=3, seed=51)
        pending = stage.prepare_subscribe(qa)
        stage.subscribe(qb)                      # interleaved: epoch bump
        with pytest.raises(StalePlanError):
            stage.commit(pending)
        gid = stage.commit(stage.prepare_subscribe(qa))  # rebuilt: fine
        assert gid in stage.sharded_.live_ids()

    def test_epoch_pins_inflight_batch_plan(self):
        """A batch filtered against an epoch-N snapshot fans out with
        epoch N's plan and gid table even after a swap commits — the
        in-flight-batch consistency the loop's workers rely on."""
        profiles, d, dtd, raw = _workload(n_docs=BATCH)
        stage = _stage(profiles, d, query_shards=2)
        want = {(r.doc_index, r.shard): tuple(r.matched_profiles)
                for b in stage.route_bytes(raw) for r in b}
        ep = stage.plan_epoch()
        assert isinstance(ep, PlanEpoch)
        stage.subscribe(gen_profiles(dtd, n=1, length=3, seed=52)[0])
        assert stage.plan_epoch().epoch == ep.epoch + 1
        res = stage._filter_bytebatch(raw, record=False, epoch=ep)
        routed = stage._fan_out(res, [len(p) for p in raw], gids=ep.gids)
        got = {(r.doc_index, r.shard): tuple(r.matched_profiles)
               for r in routed}
        assert got == want
        assert np.array_equal(np.sort(np.asarray(ep.gids)),
                              np.arange(N_QUERIES))

    def test_loop_subscribe_swaps_without_drain(self):
        """A live subscribe through the loop: the ticket commits, and
        later documents match the new profile — all while the loop
        keeps serving (no queue drain, no restart)."""
        profiles, d, dtd, raw = _workload(n_docs=12)
        stage = _stage(profiles, d, query_shards=2)
        # warm post-swap shapes so the swap is a table swap, not a
        # recompile (pads never shrink on unsubscribe)
        q = gen_profiles(dtd, n=1, length=3, seed=53)[0]
        g = stage.subscribe(q)
        list(stage.route_bytes(raw))
        stage.unsubscribe(g)
        loop = ServeLoop(stage, max_batch=BATCH, deadline_ms=60_000,
                         queue_cap=64)
        with loop:
            pre = [loop.submit(p) for p in raw[:BATCH]]
            tk = loop.subscribe(q)
            assert tk.done.wait(timeout=120)
            post = [loop.submit(p) for p in raw[BATCH:]]
        assert tk.error is None and tk.gid is not None
        assert loop.slo_summary()["swaps"] == 1
        sw = loop.swap_summary()
        assert sw["swaps"] == 1 and sw["swap_rollbacks"] == 0
        assert np.isfinite(sw["commit_p50_ms"])
        # every pre-swap verdict is for the old gid set only
        for t in pre:
            for rd in t.routed:
                assert all(int(x) < N_QUERIES
                           for x in np.asarray(rd.matched_profiles))
        assert all(not t.failed for t in pre + post)

    def test_failed_shadow_build_rolls_back(self):
        """A prepare that raises must leave the serving plan untouched
        and surface the error on the ticket — never kill the loop."""
        profiles, d, dtd, raw = _workload(n_docs=8)
        stage = _stage(profiles, d, query_shards=2)
        orig = stage.prepare_subscribe
        stage.prepare_subscribe = lambda q: (_ for _ in ()).throw(
            RuntimeError("shadow build exploded"))
        loop = ServeLoop(stage, max_batch=BATCH, deadline_ms=60_000,
                         queue_cap=64)
        with loop:
            tk = loop.subscribe(gen_profiles(dtd, n=1, length=3,
                                             seed=54)[0])
            assert tk.done.wait(timeout=120)
            assert tk.error is not None
            assert "shadow build exploded" in str(tk.error)
            stage.prepare_subscribe = orig
            tickets = [loop.submit(p) for p in raw]   # loop still serves
        assert all(not t.failed for t in tickets)
        s = loop.slo_summary()
        assert s["swap_rollbacks"] == 1 and s["swaps"] == 0
        assert s["completed"] == len(raw)


# ------------------------------------------------------------- plan cache
class TestPlanCache:
    def test_put_get_roundtrip(self, tmp_path):
        cache = PlanCache(str(tmp_path))
        tables = {"a": np.arange(6).reshape(2, 3),
                  "b": np.ones(4, np.float32)}
        cache.put("k1", tables, {"meta": 1})
        hit = cache.get("k1")
        assert hit is not None
        got, manifest = hit
        assert np.array_equal(got["a"], tables["a"])
        assert manifest["meta"] == 1
        assert cache.hits == 1 and cache.misses == 0
        assert cache.keys() == ["k1"]

    def test_miss_and_corrupt_entry(self, tmp_path):
        cache = PlanCache(str(tmp_path))
        assert cache.get("nope") is None and cache.misses == 1
        cache.put("k", {"a": np.zeros(2)})
        os.remove(os.path.join(cache._path("k"), "manifest.json"))
        assert cache.get("k") is None       # torn entry reads as a miss
        assert "k" not in cache
        cache.put("k", {"a": np.ones(2)})   # and is overwritten cleanly
        assert np.array_equal(cache.get("k")[0]["a"], np.ones(2))

    def test_warm_cache_skips_recompilation(self, tmp_path):
        """The crash-recovery contract: a rebuilt engine against a warm
        cache is all hits, no misses — and plans identically."""
        profiles, d, dtd, raw = _workload()
        nfa = compile_queries(d.rewrite_profile_tags(profiles), d,
                              shared=True)
        cold = PlanCache(str(tmp_path))
        eng = engines.create(ENGINE, nfa, dictionary=d, plan_cache=cold)
        sp = eng.plan_sharded(2)
        assert cold.misses > 0
        warm = PlanCache(str(tmp_path))
        eng2 = engines.create(ENGINE, nfa, dictionary=d, plan_cache=warm)
        sp2 = eng2.plan_sharded(2)
        assert warm.misses == 0 and warm.hits == cold.misses
        assert dict(sp.pads) == dict(sp2.pads)

    def test_cached_stage_verdict_parity(self, tmp_path):
        """Cached-plan serving is bit-identical to compiled-from-scratch
        serving, end to end through the stage."""
        profiles, d, dtd, raw = _workload(n_docs=8)
        opts = {"plan_cache": str(tmp_path)}
        list(_stage(profiles, d, query_shards=2,
                    engine_options=opts).route_bytes(raw))  # populate
        want = {(r.doc_index, r.shard): tuple(r.matched_profiles)
                for b in _stage(profiles, d,
                                query_shards=2).route_bytes(raw)
                for r in b}
        got = {(r.doc_index, r.shard): tuple(r.matched_profiles)
               for b in _stage(profiles, d, query_shards=2,
                               engine_options=opts).route_bytes(raw)
               for r in b}
        assert got == want

    def test_key_covers_nfa_and_pads(self):
        profiles, d, dtd, raw = _workload()
        nfa = compile_queries(d.rewrite_profile_tags(profiles), d,
                              shared=True)
        eng = engines.create(ENGINE, nfa, dictionary=d)
        k1 = eng.plan_cache_key(nfa)
        k2 = eng.plan_cache_key(nfa, {"n_queries": 32, "n_states": 64})
        assert k1 != k2
        assert eng.plan_cache_key(nfa) == k1    # deterministic


# -------------------------------------------------- store crash safety
class TestStoreCrashSafety:
    def test_write_entry_is_atomic(self, tmp_path):
        d = str(tmp_path)
        final = _write_entry(d, "e1", {"x": np.arange(3)}, {"keys": ["x"]})
        assert _valid_entry(final)
        assert not os.path.exists(os.path.join(d, "e1.tmp"))

    def test_stale_tmp_dir_is_replaced(self, tmp_path):
        """A crash mid-write leaves ``<name>.tmp`` — the next write must
        clear it, and the torn dir must never read as an entry."""
        d = str(tmp_path)
        os.makedirs(os.path.join(d, "e1.tmp"))
        with open(os.path.join(d, "e1.tmp", "garbage"), "w") as f:
            f.write("torn")
        assert not _valid_entry(os.path.join(d, "e1.tmp"))
        final = _write_entry(d, "e1", {"x": np.zeros(2)}, {"keys": ["x"]})
        assert _valid_entry(final)
        assert not os.path.exists(os.path.join(d, "e1.tmp"))

    def test_pointer_update_is_atomic(self, tmp_path):
        d = str(tmp_path)
        _write_pointer(d, "LATEST", "step_00000001")
        _write_pointer(d, "LATEST", "step_00000002")
        with open(os.path.join(d, "LATEST")) as f:
            assert f.read() == "step_00000002"
        assert not os.path.exists(os.path.join(d, "LATEST.tmp"))

    def test_store_save_restores_after_torn_last_step(self, tmp_path):
        """restore_latest walks back past an invalid (torn) newest step
        — the crash-recovery path the serve loop's plan cache shares."""
        store = CheckpointStore(str(tmp_path), keep=4)
        tree = {"w": np.arange(4, dtype=np.float32)}
        store.save(1, tree, {"note": "good"})
        store.save(2, {"w": np.arange(4, dtype=np.float32) * 2})
        # tear step 2: manifest gone → invalid → walk back to step 1
        os.remove(os.path.join(str(tmp_path), "step_00000002",
                               "manifest.json"))
        step, got, manifest = store.restore_latest(tree)
        assert step == 1
        assert np.array_equal(got["w"], tree["w"])


# ------------------------------------------------------------ chaos trace
class TestChaosTrace:
    def test_default_drill_passes_every_check(self):
        """The CI chaos drill, in-suite: every containment invariant
        holds on the default fault plan."""
        report = run_chaos_trace(24, plan=FaultPlan(
            malformed=(3,), overdepth=(7,), kernel=(10,),
            worker_fault_batches=(2,), pad_overflow_adds=(2,)))
        assert report["ok"], report["checks"]
        assert report["slo"]["failed"] == 0
        errs = sorted(r["error"] for r in report["dead_letter"])
        assert errs == ["DepthOverflow", "KernelFault",
                        "MalformedDocument"]

    def test_injector_restores_stage(self):
        profiles, d, dtd, raw = _workload()
        stage = _stage(profiles, d, query_shards=2)
        orig_filter = stage._filter_bytebatch
        orig_plan = stage._eng.plan_part
        inj = FaultInjector(stage, DEFAULT_PLAN, set())
        assert stage._filter_bytebatch != orig_filter
        inj.remove()
        assert stage._filter_bytebatch == orig_filter
        assert stage._eng.plan_part == orig_plan
