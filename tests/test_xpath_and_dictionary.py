"""Unit tests: XPath parser, dictionary replacement, event codec."""
import numpy as np
import pytest
from _hypothesis_shim import given, settings, st

from repro.core import dictionary as dmod
from repro.core import xpath
from repro.core.events import CLOSE, OPEN, EventStream, decode_bytes, encode_bytes
from repro.core.dictionary import TagDictionary


class TestXPathParser:
    def test_basic(self):
        q = xpath.parse("/a/b//c")
        assert [(s.axis, s.tag) for s in q.steps] == [
            (xpath.CHILD, "a"), (xpath.CHILD, "b"), (xpath.DESC, "c")]
        assert q.anchored and q.has_parent_child

    def test_bare_leading_tag_is_descendant(self):
        q = xpath.parse("a0//b0")
        assert q.steps[0].axis == xpath.DESC
        assert not q.has_parent_child

    def test_wildcard(self):
        q = xpath.parse("//*/b")
        assert q.steps[0].tag == "*"

    @pytest.mark.parametrize("bad", ["", "/", "a/", "a b", "//", "/a//"])
    def test_rejects(self, bad):
        with pytest.raises(xpath.XPathSyntaxError):
            xpath.parse(bad)

    def test_roundtrip_str(self):
        for s in ["//a/b//c", "/x//y", "//*"]:
            assert str(xpath.parse(s)) == s


class TestDictionary:
    def test_fixed_length_encoding(self):
        d = TagDictionary.build(["test.document", "b"])
        tid = d.lookup("test.document")
        assert len(d.open_bytes(tid)) == dmod.OPEN_NBYTES
        assert len(d.close_bytes(tid)) == dmod.CLOSE_NBYTES

    def test_symbols_roundtrip(self):
        for tid in [0, 1, 63, 64, 4095]:
            sym = TagDictionary.symbols_of(tid)
            assert len(sym) == 2
            assert TagDictionary.id_of_symbols(sym) == tid

    def test_full(self):
        d = TagDictionary()
        with pytest.raises(dmod.DictionaryFull):
            for i in range(dmod.MAX_TAGS + 1):
                d.add(f"tag{i}")

    def test_idempotent_add(self):
        d = TagDictionary()
        assert d.add("x") == d.add("x")


class TestEventCodec:
    def _stream(self, ids):
        ks, ts = [], []
        for i in ids:
            ks += [OPEN, CLOSE]
            ts += [i, i]
        return EventStream(np.array(ks, np.int8), np.array(ts, np.int32))

    def test_roundtrip(self):
        d = TagDictionary.build([f"t{i}" for i in range(10)])
        ev = self._stream([0, 5, 9, 63])
        buf = encode_bytes(ev)
        back = decode_bytes(buf, d.symbol_value_table())
        np.testing.assert_array_equal(back.kind, ev.kind)
        np.testing.assert_array_equal(back.tag_id, ev.tag_id)

    def test_roundtrip_with_text(self):
        d = TagDictionary.build(["a"])
        ev = self._stream([0])
        buf = encode_bytes(ev, text_fill=7)
        back = decode_bytes(buf, d.symbol_value_table())
        np.testing.assert_array_equal(back.kind, ev.kind)

    @settings(max_examples=50, deadline=None)
    @given(st.lists(st.integers(0, 4095), min_size=0, max_size=40),
           st.integers(0, 5))
    def test_roundtrip_property(self, ids, fill):
        ev = self._stream(ids)
        d = TagDictionary()
        back = decode_bytes(encode_bytes(ev, text_fill=fill),
                            d.symbol_value_table())
        np.testing.assert_array_equal(back.kind, ev.kind)
        np.testing.assert_array_equal(back.tag_id, ev.tag_id)

    def test_nested_structure(self):
        ev = EventStream(np.array([OPEN, OPEN, CLOSE, OPEN, CLOSE, CLOSE], np.int8),
                         np.array([1, 2, 2, 3, 3, 1], np.int32))
        ev.check_balanced()
        assert ev.max_depth() == 2
        depth, parent = ev.structure()
        assert depth[0] == 1 and depth[1] == 2
        assert parent[1] == 0 and parent[3] == 0 and parent[0] == -1
