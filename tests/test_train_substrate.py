"""Optimizers, train step, compression, checkpointing, fault tolerance."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.store import CheckpointStore
from repro.configs import get_config
from repro.data.tokens import TokenPipeline
from repro.models import transformer as T
from repro.train.compression import (compressed_psum, dequantize_int8,
                                     make_error_feedback_compressor,
                                     quantize_int8)
from repro.train.loop import LoopConfig, run_training
from repro.train.optimizer import make_optimizer
from repro.train.train_step import make_train_step


@pytest.fixture(scope="module")
def tiny():
    cfg = get_config("qwen3-0.6b", reduced=True).with_(n_layers=2,
                                                       grad_accum=1)
    params = T.init_model(cfg, jax.random.PRNGKey(0))
    pipe = TokenPipeline(vocab=cfg.vocab, batch=4, seq_len=16, seed=1)
    return cfg, params, pipe


class TestOptimizers:
    @pytest.mark.parametrize("name", ["adamw", "adafactor"])
    def test_loss_decreases(self, name, tiny):
        cfg, params, pipe = tiny
        opt = make_optimizer(name, lr=5e-3 if name == "adamw" else 1e-2)
        state = opt.init(params)
        step = jax.jit(make_train_step(cfg, opt))
        batch = pipe.batch_at(0)  # overfit a single batch
        losses = []
        p = params
        for i in range(12):
            p, state, m = step(p, state, batch, jnp.int32(i))
            losses.append(float(m["loss"]))
        assert losses[-1] < losses[0] - 0.05, (name, losses)

    def test_adafactor_state_is_factored(self, tiny):
        cfg, params, _ = tiny
        opt = make_optimizer("adafactor")
        state = opt.init(params)
        n_param = sum(p.size for p in jax.tree.leaves(params))
        n_state = sum(s.size for s in jax.tree.leaves(state))
        assert n_state < 0.2 * n_param  # factored ⇒ way below 1 per param

    def test_grad_accum_matches_full_batch(self, tiny):
        cfg, params, pipe = tiny
        from repro.train.train_step import grads_and_metrics
        batch = pipe.batch_at(3)
        g1, _ = grads_and_metrics(cfg.with_(grad_accum=1), params, batch)
        g4, _ = grads_and_metrics(cfg.with_(grad_accum=4), params, batch)
        for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g4)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=2e-3, atol=2e-5)


class TestCompression:
    def test_quantize_roundtrip_error_bounded(self):
        rng = np.random.default_rng(0)
        g = jnp.asarray(rng.normal(size=(256,)).astype(np.float32))
        q, s = quantize_int8(g)
        err = np.abs(np.asarray(dequantize_int8(q, s) - g)).max()
        assert err <= float(s) * 0.5 + 1e-6

    def test_error_feedback_accumulates(self, tiny):
        cfg, params, pipe = tiny
        init, compress = make_error_feedback_compressor()
        opt = make_optimizer("adamw", lr=5e-3)
        state = opt.init(params)
        state["compression"] = init(params)
        step = make_train_step(cfg, opt, compress=compress)
        batch = pipe.batch_at(0)
        losses = []
        p = params
        for i in range(10):
            p, state, m = step(p, state, batch, jnp.int32(i))
            losses.append(float(m["loss"]))
        assert losses[-1] < losses[0] - 0.05
        ef_mag = max(float(jnp.abs(e).max())
                     for e in state["compression"]["ef"])
        assert ef_mag > 0  # residuals actually tracked

    def test_compressed_psum_matches_fp32(self):
        from jax.sharding import Mesh, PartitionSpec as P
        from jax.experimental.shard_map import shard_map
        mesh = Mesh(np.array(jax.devices()[:1]), ("data",))
        g = jnp.asarray(np.random.default_rng(1).normal(size=(8, 16))
                        .astype(np.float32))
        f = shard_map(lambda x: compressed_psum(x, "data"), mesh=mesh,
                      in_specs=P("data"), out_specs=P("data"))
        got = np.asarray(f(g))
        np.testing.assert_allclose(got, np.asarray(g), atol=2e-2)


class TestCheckpoint:
    def test_roundtrip_and_latest(self, tmp_path, tiny):
        cfg, params, _ = tiny
        store = CheckpointStore(str(tmp_path), keep=2)
        opt = make_optimizer("adamw")
        state = opt.init(params)
        store.save(5, (params, state), {"config": cfg.name})
        store.save(10, (params, state))
        assert store.latest_step() == 10
        (p2, s2), manifest = store.restore(10, (params, state))
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        # keep=2 gc
        store.save(15, (params, state))
        assert store.latest_step() == 15

    def test_corruption_fallback(self, tmp_path, tiny):
        cfg, params, _ = tiny
        store = CheckpointStore(str(tmp_path))
        store.save(1, params)
        store.save(2, params)
        # corrupt newest
        bad = os.path.join(str(tmp_path), "step_00000002", "arrays.npz")
        with open(bad, "wb") as f:
            f.write(b"garbage")
        assert store.latest_step() == 1

    def test_async_save(self, tmp_path, tiny):
        cfg, params, _ = tiny
        store = CheckpointStore(str(tmp_path))
        store.save_async(7, params)
        store.wait()
        assert store.latest_step() == 7


class TestFaultTolerantLoop:
    def _setup(self, tiny, tmp_path, total=12, ckpt_every=4):
        cfg, params, pipe = tiny
        opt = make_optimizer("adamw", lr=1e-3)
        state = opt.init(params)
        step = jax.jit(make_train_step(cfg, opt))
        loop = LoopConfig(total_steps=total, ckpt_every=ckpt_every,
                          ckpt_dir=str(tmp_path / "ck"), log_every=0)
        return cfg, params, state, step, pipe, loop

    def test_preemption_and_resume(self, tiny, tmp_path):
        cfg, params, state, step, pipe, loop = self._setup(tiny, tmp_path)
        loop.preempt_file = str(tmp_path / "PREEMPT")
        logs = []
        # run 1: preempt after a few steps
        open(loop.preempt_file, "w").close()
        r1 = run_training(cfg, loop, params=params, opt_state=state,
                          step_fn=step, batch_fn=pipe.batch_at,
                          log=logs.append)
        assert r1.preempted and r1.final_step < loop.total_steps
        os.remove(loop.preempt_file)
        # run 2: must resume from the checkpoint, not step 0
        r2 = run_training(cfg, loop, params=params, opt_state=state,
                          step_fn=step, batch_fn=pipe.batch_at,
                          log=logs.append)
        assert r2.resumed_from == r1.final_step
        assert r2.final_step == loop.total_steps

    def test_straggler_detection(self, tiny, tmp_path):
        cfg, params, state, step, pipe, loop = self._setup(
            tiny, tmp_path, total=3, ckpt_every=0)
        loop.step_deadline_s = 1e-9  # everything is a straggler
        r = run_training(cfg, loop, params=params, opt_state=state,
                         step_fn=step, batch_fn=pipe.batch_at,
                         log=lambda s: None)
        assert r.straggler_steps == 3

    def test_deterministic_replay(self, tiny, tmp_path):
        """Same seed/steps ⇒ identical loss trajectory after resume."""
        cfg, params, state, step, pipe, loop = self._setup(
            tiny, tmp_path, total=6, ckpt_every=3)
        r_full = run_training(cfg, loop, params=params, opt_state=state,
                              step_fn=step, batch_fn=pipe.batch_at,
                              log=lambda s: None)
        # fresh run resumes at 6 == total → no extra steps
        r_resume = run_training(cfg, loop, params=params, opt_state=state,
                                step_fn=step, batch_fn=pipe.batch_at,
                                log=lambda s: None)
        assert r_resume.resumed_from == 6


class TestServeEngine:
    def test_greedy_generation_matches_argmax(self, tiny):
        from repro.serve.engine import ServeEngine
        cfg, params, pipe = tiny
        batch = {"tokens": pipe.batch_at(0)["tokens"][:, :8]}
        eng = ServeEngine(cfg, params, batch=4, max_len=32,
                          cache_dtype=jnp.float32)
        out = eng.generate(batch, n_new=4)
        assert out.shape == (4, 4)
        # first generated token == argmax of the full forward
        logits, _ = T.forward_logits(cfg, params, batch)
        want = np.asarray(jnp.argmax(logits[:, -1, :cfg.vocab], -1))
        np.testing.assert_array_equal(out[:, 0], want)
