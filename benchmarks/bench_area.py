"""Fig-8 reproduction: area vs #profiles for the four scenarios (§4.1).

Reports the hardware cost model (bit-comparator equivalents, % of a
Virtex-4 LX200) per scenario × query count × path length, plus the
measured TPU analogue (transition-table bytes) — see DESIGN.md §2 for why
FPGA area maps to a model + bytes, not to a TPU-native metric.
"""
from __future__ import annotations

from repro.core.area import SCENARIOS, area_report, engine_table_bytes
from repro.core.dictionary import TagDictionary
from repro.core.nfa import compile_queries
from repro.data.generator import DTD, gen_profiles

QUERY_COUNTS = (16, 64, 256, 1024)
PATH_LENGTHS = (2, 4, 6)


def run(query_counts=QUERY_COUNTS, path_lengths=PATH_LENGTHS, seed=0):
    rows = []
    for plen in path_lengths:
        dtd = DTD.generate(n_tags=24, seed=seed)
        for n in query_counts:
            d = TagDictionary()
            dtd.register(d)
            qs = gen_profiles(dtd, n=n, length=plen, p_desc=0.3,
                              p_wild=0.05, seed=seed + plen)
            for sc in SCENARIOS:
                rep = area_report(qs, d, sc)
                rows.append({
                    "bench": "fig8_area",
                    "scenario": sc,
                    "path_len": plen,
                    "n_queries": n,
                    "n_states": rep.n_states,
                    "bit_cost": rep.bit_cost,
                    "chip_pct": round(100 * rep.chip_fraction, 2),
                })
            nfa = compile_queries(qs, d, shared=True)
            tb = engine_table_bytes(nfa)
            rows.append({
                "bench": "fig8_tpu_bytes",
                "scenario": "levelwise/streaming",
                "path_len": plen,
                "n_queries": n,
                "levelwise_tables_B": tb["levelwise_tables"],
                "streaming_tables_B": tb["streaming_tables"],
                "streaming_stack_B": tb["streaming_stack"],
            })
    return rows


def summarize(rows):
    """Headline: Unop → Com-P-CharDec improvement factor (paper: 5–7×)."""
    out = []
    for plen in PATH_LENGTHS:
        for n in QUERY_COUNTS:
            sel = {r["scenario"]: r for r in rows
                   if r["bench"] == "fig8_area"
                   and r["path_len"] == plen and r["n_queries"] == n}
            if len(sel) == len(SCENARIOS):
                f = sel["Unop"]["bit_cost"] / sel["Com-P-CharDec"]["bit_cost"]
                out.append({"bench": "fig8_factor", "path_len": plen,
                            "n_queries": n,
                            "unop_over_comp_chardec": round(f, 2)})
    return out


if __name__ == "__main__":
    import json
    for r in run() + summarize(run()):
        print(json.dumps(r))
