"""Benchmark orchestrator — one section per paper table/figure.

``PYTHONPATH=src python -m benchmarks.run [--full] [--only NAME] [--json [PATH]]``

Sections:
  fig8   — area model, 4 scenarios (paper Fig 8)
  fig9   — filtering throughput vs YFilter baseline (paper Fig 9)
  ingest — ingest_throughput: parse cost end-to-end over the three
           ingestion paths (events / bytes-host / bytes-device — the
           paper's same-chip parser+filter vs host parsing)
  qscale — query_scaling: docs/s as the standing profile set grows
           10²→10⁴, monolithic vs sharded query plans (the paper's
           scalability-in-profiles claim, §3.5)
  docscale — doc_scaling: docs/s over the (batch × data-shard ×
           query-shard) grid, bytes → verdict through the 2-D
           ("data", "model") mesh program (the paper's document-stream
           replication, §3.5 second axis)
  churn  — churn_latency: per-op subscribe/unsubscribe on a sharded
           plan vs a full recompile
  twig   — twig-pattern filtering cost structure (paper §5 extension)
  roofline — 3-term roofline per (arch × shape) from dry-run artifacts
             (only if launch/dryrun.py results exist; see EXPERIMENTS.md)

Output: JSON-lines to stdout (one row per measurement); ``--json``
additionally writes the rows to a file (default ``BENCH_filtering.json``)
so CI accumulates a perf trajectory.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale sweeps (slower)")
    ap.add_argument("--only", default=None,
                    help="run a single section: "
                         "fig8|fig9|ingest|qscale|docscale|churn|twig|"
                         "roofline")
    ap.add_argument("--json", nargs="?", const="BENCH_filtering.json",
                    default=None, metavar="PATH",
                    help="also write rows to a JSON file "
                         "(default: BENCH_filtering.json)")
    args = ap.parse_args()

    sections = [args.only] if args.only else ["fig8", "fig9", "ingest",
                                              "qscale", "docscale", "churn",
                                              "twig", "roofline"]
    rows = []

    if "fig8" in sections:
        from benchmarks import bench_area
        r = bench_area.run()
        rows += r + bench_area.summarize(r)

    if "fig9" in sections:
        from benchmarks import bench_throughput
        if args.full:
            rows += bench_throughput.run(n_docs=32, nodes_per_doc=2000)
        else:
            rows += bench_throughput.run(
                query_counts=(16, 64, 256), path_lengths=(2, 4),
                n_docs=8, nodes_per_doc=200)

    if "ingest" in sections:
        from benchmarks import bench_throughput
        if args.full:
            rows += bench_throughput.run_ingest(n_docs=32,
                                                nodes_per_doc=2000)
        else:
            rows += bench_throughput.run_ingest(
                query_counts=(16, 64), n_docs=8, nodes_per_doc=200)

    if "qscale" in sections:
        from benchmarks import bench_throughput
        if args.full:
            rows += bench_throughput.run_query_scaling(
                n_docs=16, nodes_per_doc=400)
        else:
            # acceptance sweep 10²→10⁴ profiles on a small doc batch
            rows += bench_throughput.run_query_scaling(
                query_counts=(100, 1000, 10000), shard_counts=(1, 2, 4),
                n_docs=4, nodes_per_doc=120, repeat=1)

    if "docscale" in sections:
        from benchmarks import bench_throughput
        if args.full:
            rows += bench_throughput.run_doc_scaling(
                batch_sizes=(16, 64), nodes_per_doc=400)
        else:
            # acceptance grid: docs/s per (batch, data, query) shard
            # point — batches big enough that per-shard work dominates
            # dispatch overhead, so the data-axis slope is visible
            rows += bench_throughput.run_doc_scaling(
                batch_sizes=(16,), data_shard_counts=(1, 2, 4),
                query_shard_counts=(1, 2), n_queries=64,
                nodes_per_doc=200, repeat=2)

    if "churn" in sections:
        from benchmarks import bench_throughput
        rows += bench_throughput.run_churn(
            n_queries=1024 if args.full else 256,
            n_ops=32 if args.full else 8)

    if "twig" in sections:
        from benchmarks import bench_twig
        rows += bench_twig.run(n_docs=24 if args.full else 10,
                               nodes_per_doc=300 if args.full else 120)

    if "roofline" in sections:
        from benchmarks import roofline
        rows += roofline.rows_from_artifacts()

    for r in rows:
        print(json.dumps(r))
    if args.json:
        with open(args.json, "w") as f:
            json.dump(rows, f, indent=1)
        print(f"# wrote {len(rows)} rows to {args.json}", file=sys.stderr)


if __name__ == "__main__":
    main()
