"""Benchmark orchestrator — one section per paper table/figure.

``PYTHONPATH=src python -m benchmarks.run [--full] [--only NAME] [--json [PATH]]``

Sections:
  fig8   — area model, 4 scenarios (paper Fig 8)
  fig9   — filtering throughput vs YFilter baseline (paper Fig 9)
  ingest — ingest_throughput: parse cost end-to-end over the three
           ingestion paths (events / bytes-host / bytes-device — the
           paper's same-chip parser+filter vs host parsing)
  kernel — kernel_vs_scan: the streaming megakernel (bit-packed Pallas
           hot path) vs the lax.scan oracle, events and one-launch
           fused-bytes variants (padded + segment-packed) over a
           (scenario × batch × n_queries) grid; the ``backend`` field
           records compiled (TPU) vs interpret rows, and the pallas
           bytes rows are re-emitted as measured ``bench="roofline"``
           rows (achieved stream bandwidth as % of the HBM ceiling)
  qscale — query_scaling: docs/s as the standing profile set grows
           10²→10⁴, monolithic vs sharded query plans (the paper's
           scalability-in-profiles claim, §3.5)
  docscale — doc_scaling: docs/s over the (batch × data-shard ×
           query-shard) grid, bytes → verdict through the 2-D
           ("data", "model") mesh program (the paper's document-stream
           replication, §3.5 second axis)
  churn  — churn_latency: per-op subscribe/unsubscribe on a sharded
           plan vs a full recompile
  serve  — serve_latency: p50/p99/p999 bytes→verdict latency + shed
           rate of the CONTINUOUS serve loop under seeded Poisson and
           bursty (ON/OFF) arrival traces — the service-level view of
           the paper's "very high input ratios" claim (admission
           control, adaptive batching, K-deep dispatch; see
           repro.serve.loop)
  twig   — twig-pattern filtering cost structure (paper §5 extension)
  roofline — 3-term roofline per (arch × shape) from dry-run artifacts
             (only if launch/dryrun.py results exist; see EXPERIMENTS.md)

Output: JSON-lines to stdout (one row per measurement); ``--json``
additionally writes the rows to a file (default ``BENCH_filtering.json``)
so CI accumulates a perf trajectory.  ``--profile [DIR]`` wraps the
whole bench run (typically paired with ``--only``) in
``jax.profiler.trace`` and prints the trace directory, so a kernel win
is inspectable in the profiler instead of inferred from wall clocks.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

ALL_SECTIONS = ("fig8", "fig9", "ingest", "kernel", "qscale", "docscale",
                "churn", "serve", "twig", "roofline")


def run_sections(sections, full: bool) -> list[dict]:
    rows: list[dict] = []

    if "fig8" in sections:
        from benchmarks import bench_area
        r = bench_area.run()
        rows += r + bench_area.summarize(r)

    if "fig9" in sections:
        from benchmarks import bench_throughput
        if full:
            rows += bench_throughput.run(n_docs=32, nodes_per_doc=2000)
        else:
            rows += bench_throughput.run(
                query_counts=(16, 64, 256), path_lengths=(2, 4),
                n_docs=8, nodes_per_doc=200)

    if "ingest" in sections:
        from benchmarks import bench_throughput
        if full:
            rows += bench_throughput.run_ingest(n_docs=32,
                                                nodes_per_doc=2000)
        else:
            rows += bench_throughput.run_ingest(
                query_counts=(16, 64), n_docs=8, nodes_per_doc=200)

    if "kernel" in sections:
        from benchmarks import bench_throughput, roofline
        if full:
            kr = bench_throughput.run_kernel_vs_scan(
                query_counts=(64, 256, 1024), batch_sizes=(8, 32),
                nodes_per_doc=400, repeat=3)
        else:
            # acceptance grid: megakernel vs scan, events + fused bytes
            # over both length scenarios (uniform + skewed — the packed
            # rows' events_per_slot win lives on the skewed one);
            # interpret-mode kernel rows are slow by design — small
            # batches keep the section's unrolled-grid cost bounded
            kr = bench_throughput.run_kernel_vs_scan(
                query_counts=(64, 256), batch_sizes=(4,),
                nodes_per_doc=150, repeat=1)
        # measured roofline view of the pallas bytes rows rides along
        rows += kr + roofline.megakernel_rows(kr)

    if "qscale" in sections:
        from benchmarks import bench_throughput
        if full:
            rows += bench_throughput.run_query_scaling(
                n_docs=16, nodes_per_doc=400)
        else:
            # acceptance sweep 10²→10⁵ profiles on a small doc batch;
            # the 10⁵ rows carry the subscription-axis columns
            # (state_compression, verdict_bytes, sparse_exact)
            rows += bench_throughput.run_query_scaling(
                max_queries=100_000, shard_counts=(1, 2, 4),
                n_docs=4, nodes_per_doc=120, repeat=1)

    if "docscale" in sections:
        from benchmarks import bench_throughput
        if full:
            rows += bench_throughput.run_doc_scaling(
                batch_sizes=(16, 64), nodes_per_doc=400)
        else:
            # acceptance grid: docs/s per (batch, data, query) shard
            # point — batches big enough that per-shard work dominates
            # dispatch overhead, so the data-axis slope is visible
            rows += bench_throughput.run_doc_scaling(
                batch_sizes=(16,), data_shard_counts=(1, 2, 4),
                query_shard_counts=(1, 2), n_queries=64,
                nodes_per_doc=200, repeat=2)

    if "churn" in sections:
        from benchmarks import bench_throughput
        rows += bench_throughput.run_churn(
            n_queries=1024 if full else 256,
            n_ops=32 if full else 8)

    if "serve" in sections:
        from benchmarks import bench_serve
        rows += bench_serve.run(full=full)

    if "twig" in sections:
        from benchmarks import bench_twig
        rows += bench_twig.run(n_docs=24 if full else 10,
                               nodes_per_doc=300 if full else 120)

    if "roofline" in sections:
        from benchmarks import roofline
        rows += roofline.rows_from_artifacts()

    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale sweeps (slower)")
    ap.add_argument("--only", default=None,
                    help="run a single section: " + "|".join(ALL_SECTIONS))
    ap.add_argument("--json", nargs="?", const="BENCH_filtering.json",
                    default=None, metavar="PATH",
                    help="also write rows to a JSON file "
                         "(default: BENCH_filtering.json)")
    ap.add_argument("--profile", nargs="?", const="/tmp/repro-bench-trace",
                    default=None, metavar="DIR",
                    help="wrap the bench run in jax.profiler.trace(DIR) "
                         "and print the trace dir (pair with --only to "
                         "profile one section)")
    args = ap.parse_args()

    sections = [args.only] if args.only else list(ALL_SECTIONS)

    if args.profile:
        import jax

        with jax.profiler.trace(args.profile):
            rows = run_sections(sections, args.full)
        print(f"# profiler trace written to {args.profile} "
              f"(tensorboard --logdir {args.profile})", file=sys.stderr)
    else:
        rows = run_sections(sections, args.full)

    for r in rows:
        print(json.dumps(r))
    if args.json:
        with open(args.json, "w") as f:
            json.dump(rows, f, indent=1)
        print(f"# wrote {len(rows)} rows to {args.json}", file=sys.stderr)


if __name__ == "__main__":
    main()
