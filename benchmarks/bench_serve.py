"""serve_latency bench: bytes→verdict latency SLOs under arrival traces.

The paper's motivating scenario is pub-sub filtering under "very high
input ratios" where processing *time* matters, not just steady-state
docs/s — so this section measures the continuous serve loop
(:mod:`repro.serve.loop`) as a service: seeded Poisson and bursty
(ON/OFF) arrival traces are driven open-loop through admission control,
adaptive batching and K-deep dispatch, and each row reports the
p50/p99/p999 enqueue→verdict latency, shed rate, batch fill and
backpressure occupancy.

Row identity is machine-independent by construction (fixed arrival
rates, not rates derived from a warmup measurement), so the regression
gate (``compare_baseline.py``) matches rows across machines and gates
the latency columns (lower is better) alongside the throughput ones.
"""
from __future__ import annotations

import sys
from os.path import dirname, join

sys.path.insert(0, join(dirname(__file__), "..", "src"))

import tempfile                                           # noqa: E402
import time                                               # noqa: E402

from repro.checkpoint import PlanCache                    # noqa: E402
from repro.core import engines as _engines                # noqa: E402
from repro.core.dictionary import TagDictionary           # noqa: E402
from repro.core.events import encode_bytes                # noqa: E402
from repro.core.nfa import compile_queries                # noqa: E402
from repro.data.filter_stage import TEXT_FILL, FilterStage  # noqa: E402
from repro.data.generator import DTD, gen_corpus, gen_profiles  # noqa: E402
from repro.serve.loop import ServeLoop, make_arrivals, run_trace  # noqa: E402

#: fixed trace rates (req/s) — identity fields, NEVER derived from the
#: machine: a Poisson stream well under the CPU service rate (~5k
#: docs/s warm for the streaming engine, so ample headroom on slower
#: runners), and a bursty ON-rate 4x it (50 ms on / 150 ms off → the
#: same mean rate, arriving in bursts that exercise the queue, the
#: size close and the K-deep pipeline; the low-rate Poisson trace
#: exercises the deadline close)
POISSON_RATE_HZ = 200.0
BURST_RATE_HZ = 800.0
BURST_ON_MS = 50.0
BURST_OFF_MS = 150.0


def _workload(n_requests: int, n_queries: int, seed: int = 0):
    dtd = DTD.generate(n_tags=24, seed=seed)
    d = TagDictionary()
    dtd.register(d)
    profiles = gen_profiles(dtd, n=n_queries, length=3, seed=seed)
    docs = gen_corpus(dtd, n_docs=n_requests, nodes_per_doc=60, seed=1)
    raw = [encode_bytes(doc, text_fill=TEXT_FILL) for doc in docs]
    return profiles, d, raw


def run_serve_latency(n_requests: int = 96, *, engine: str = "streaming",
                      n_queries: int = 32, max_batch: int = 8,
                      deadline_ms: float = 10.0, queue_cap: int = 64,
                      max_inflight: int = 2, seed: int = 0) -> list[dict]:
    """One row per arrival trace through a fresh serve loop."""
    profiles, d, raw = _workload(n_requests, n_queries)
    traces = [
        dict(arrival="poisson", rate_hz=POISSON_RATE_HZ),
        dict(arrival="burst", rate_hz=BURST_RATE_HZ,
             on_ms=BURST_ON_MS, off_ms=BURST_OFF_MS),
    ]
    rows = []
    for trace in traces:
        stage = FilterStage(profiles, d, engine=engine,
                            keep_unmatched=True, batch_size=max_batch)
        # warm the compiled programs outside the trace (the FULL corpus
        # once, so every byte-bucket shape the trace will see is
        # compiled): first-batch jit compile is a cold-start cost, not
        # a steady-state SLO
        list(stage.route_bytes(raw))
        stage.stats = {k: type(v)() for k, v in stage.stats.items()}
        arrivals = make_arrivals(
            trace["arrival"], len(raw), rate_hz=trace["rate_hz"],
            on_s=trace.get("on_ms", BURST_ON_MS) / 1e3,
            off_s=trace.get("off_ms", BURST_OFF_MS) / 1e3, seed=seed)
        deliveries: list = []
        loop = ServeLoop(stage, max_batch=max_batch,
                         deadline_ms=deadline_ms, queue_cap=queue_cap,
                         max_inflight=max_inflight, overload="shed",
                         deliver=deliveries.append)
        with loop:
            run_trace(loop, raw, arrivals)
        slo = loop.slo_summary()
        rows.append({
            "bench": "serve_latency", "engine": engine,
            "n_requests": n_requests, "n_queries": n_queries,
            "max_batch": max_batch, "deadline_ms": deadline_ms,
            "queue_cap": queue_cap, "max_inflight": max_inflight,
            "overload": "shed", "seed": seed, **trace,
            # measurements (all NON_IDENTITY in compare_baseline)
            "p50_ms": slo["p50_ms"], "p99_ms": slo["p99_ms"],
            "p999_ms": slo["p999_ms"], "mean_ms": slo["mean_ms"],
            "shed_rate": slo["shed_rate"], "completed": slo["completed"],
            "served_per_s": slo["served_per_s"],
            "batch_fill": slo["batch_fill"],
            "size_closes": slo["size_closes"],
            "deadline_closes": slo["deadline_closes"],
            "flush_closes": slo["flush_closes"],
            "backpressure_waits": slo["backpressure_waits"],
            "max_queue_depth": slo["max_queue_depth"],
            "deliveries": sum(len(b) for b in deliveries),
        })
    return rows


def run_hot_swap(n_requests: int = 96, *, engine: str = "streaming",
                 n_queries: int = 32, query_shards: int = 2,
                 max_batch: int = 8, deadline_ms: float = 10.0,
                 n_swaps: int = 6, seed: int = 0) -> list[dict]:
    """serve_latency row measuring live traffic *through* hot swaps.

    A Poisson trace runs while ``n_swaps`` subscription changes build on
    the shadow builder and commit at batch boundaries — the row's p50/
    p99 are the latency SLO *including* swap windows, and the
    ``swap_*_ms`` columns split the cost into shadow build (off the hot
    path) vs atomic commit (the only part a request can ever wait on).
    """
    profiles, d, raw = _workload(n_requests, n_queries)
    dtd = DTD.generate(n_tags=24, seed=seed)
    churn = gen_profiles(dtd, n=n_swaps, length=3, seed=7)
    stage = FilterStage(profiles, d, engine=engine, keep_unmatched=True,
                        batch_size=max_batch, query_shards=query_shards)
    # warm every compiled shape the trace will see, INCLUDING the
    # post-swap ones: subscribing the churn set grows the pad buckets
    # (they never shrink on unsubscribe), so the mid-trace re-adds fit
    # the warmed shapes and the row measures swap cost, not jit compiles
    warm_gids = [stage.subscribe(q) for q in churn]
    list(stage.route_bytes(raw))
    for g in warm_gids:
        stage.unsubscribe(g)
    list(stage.route_bytes(raw[:max_batch]))
    stage.stats = {k: type(v)() for k, v in stage.stats.items()}
    arrivals = make_arrivals("poisson", len(raw),
                             rate_hz=POISSON_RATE_HZ, seed=seed)
    loop = ServeLoop(stage, max_batch=max_batch, deadline_ms=deadline_ms,
                     queue_cap=256)
    every = max(1, n_requests // (n_swaps + 1))
    swap_tickets = []
    with loop:
        t0 = time.monotonic()
        for i, (p, due) in enumerate(zip(raw, arrivals)):
            lag = due - (time.monotonic() - t0)
            if lag > 0:
                time.sleep(lag)
            loop.submit(p)
            if i % every == every - 1 and len(swap_tickets) < n_swaps:
                swap_tickets.append(loop.subscribe(churn[len(swap_tickets)]))
        for tk in swap_tickets:
            tk.done.wait(timeout=120)
    slo = loop.slo_summary()
    sw = loop.swap_summary()
    return [{
        "bench": "serve_latency", "engine": engine, "arrival": "hotswap",
        "n_requests": n_requests, "n_queries": n_queries,
        "query_shards": query_shards, "max_batch": max_batch,
        "deadline_ms": deadline_ms, "n_swaps": n_swaps, "seed": seed,
        # measurements (all NON_IDENTITY in compare_baseline)
        "p50_ms": slo["p50_ms"], "p99_ms": slo["p99_ms"],
        "p999_ms": slo["p999_ms"], "mean_ms": slo["mean_ms"],
        "completed": slo["completed"], "served_per_s": slo["served_per_s"],
        "swaps": sw["swaps"], "swap_rollbacks": sw["swap_rollbacks"],
        "swap_build_p50_ms": sw["build_p50_ms"],
        "swap_build_p99_ms": sw["build_p99_ms"],
        "swap_commit_p50_ms": sw["commit_p50_ms"],
        "swap_commit_p99_ms": sw["commit_p99_ms"],
    }]


def run_plan_cache_cold_start(*, engine: str = "streaming",
                              n_queries: int = 64, n_parts: int = 4,
                              seed: int = 0) -> list[dict]:
    """churn_latency rows: cold start with vs without a warm plan cache.

    ``cold_start`` plans the sharded subscription set from scratch (the
    crash-recovery / first-boot cost); ``cold_start_cached`` rebuilds
    the same engine against a warm :class:`~repro.checkpoint.PlanCache`
    — every part plan is a content-hash hit, so recompilation is
    skipped and ``speedup_vs_recompile`` is the measured win.
    """
    dtd = DTD.generate(n_tags=24, seed=seed)
    d = TagDictionary()
    dtd.register(d)
    profiles = d.rewrite_profile_tags(
        gen_profiles(dtd, n=n_queries, length=3, seed=seed))
    nfa = compile_queries(profiles, d, shared=True)
    with tempfile.TemporaryDirectory() as tmp:
        t0 = time.perf_counter()
        eng = _engines.create(engine, nfa, dictionary=d,
                              plan_cache=PlanCache(tmp))
        eng.plan_sharded(n_parts)
        cold_s = time.perf_counter() - t0

        warm_cache = PlanCache(tmp)
        t0 = time.perf_counter()
        eng2 = _engines.create(engine, nfa, dictionary=d,
                               plan_cache=warm_cache)
        eng2.plan_sharded(n_parts)
        warm_s = time.perf_counter() - t0
        hits, misses = warm_cache.hits, warm_cache.misses
    common = {"bench": "churn_latency", "engine": engine,
              "n_queries": n_queries, "n_parts": n_parts, "n_ops": 1}
    return [
        {**common, "op": "cold_start", "seconds_per_op": round(cold_s, 6)},
        {**common, "op": "cold_start_cached",
         "seconds_per_op": round(warm_s, 6),
         "speedup_vs_recompile": round(cold_s / max(warm_s, 1e-9), 2),
         "cache_hits": hits, "cache_misses": misses},
    ]


def run(full: bool = False) -> list[dict]:
    if full:
        return (run_serve_latency(256)
                + run_serve_latency(256, deadline_ms=50.0, max_inflight=4)
                + run_hot_swap(256)
                + run_plan_cache_cold_start()
                + run_plan_cache_cold_start(n_queries=128, n_parts=8))
    return (run_serve_latency(96) + run_hot_swap()
            + run_plan_cache_cold_start())


if __name__ == "__main__":
    import json

    for row in run():
        print(json.dumps(row))
