"""serve_latency bench: bytes→verdict latency SLOs under arrival traces.

The paper's motivating scenario is pub-sub filtering under "very high
input ratios" where processing *time* matters, not just steady-state
docs/s — so this section measures the continuous serve loop
(:mod:`repro.serve.loop`) as a service: seeded Poisson and bursty
(ON/OFF) arrival traces are driven open-loop through admission control,
adaptive batching and K-deep dispatch, and each row reports the
p50/p99/p999 enqueue→verdict latency, shed rate, batch fill and
backpressure occupancy.

Row identity is machine-independent by construction (fixed arrival
rates, not rates derived from a warmup measurement), so the regression
gate (``compare_baseline.py``) matches rows across machines and gates
the latency columns (lower is better) alongside the throughput ones.
"""
from __future__ import annotations

import sys
from os.path import dirname, join

sys.path.insert(0, join(dirname(__file__), "..", "src"))

from repro.core.dictionary import TagDictionary           # noqa: E402
from repro.core.events import encode_bytes                # noqa: E402
from repro.data.filter_stage import TEXT_FILL, FilterStage  # noqa: E402
from repro.data.generator import DTD, gen_corpus, gen_profiles  # noqa: E402
from repro.serve.loop import ServeLoop, make_arrivals, run_trace  # noqa: E402

#: fixed trace rates (req/s) — identity fields, NEVER derived from the
#: machine: a Poisson stream well under the CPU service rate (~5k
#: docs/s warm for the streaming engine, so ample headroom on slower
#: runners), and a bursty ON-rate 4x it (50 ms on / 150 ms off → the
#: same mean rate, arriving in bursts that exercise the queue, the
#: size close and the K-deep pipeline; the low-rate Poisson trace
#: exercises the deadline close)
POISSON_RATE_HZ = 200.0
BURST_RATE_HZ = 800.0
BURST_ON_MS = 50.0
BURST_OFF_MS = 150.0


def _workload(n_requests: int, n_queries: int, seed: int = 0):
    dtd = DTD.generate(n_tags=24, seed=seed)
    d = TagDictionary()
    dtd.register(d)
    profiles = gen_profiles(dtd, n=n_queries, length=3, seed=seed)
    docs = gen_corpus(dtd, n_docs=n_requests, nodes_per_doc=60, seed=1)
    raw = [encode_bytes(doc, text_fill=TEXT_FILL) for doc in docs]
    return profiles, d, raw


def run_serve_latency(n_requests: int = 96, *, engine: str = "streaming",
                      n_queries: int = 32, max_batch: int = 8,
                      deadline_ms: float = 10.0, queue_cap: int = 64,
                      max_inflight: int = 2, seed: int = 0) -> list[dict]:
    """One row per arrival trace through a fresh serve loop."""
    profiles, d, raw = _workload(n_requests, n_queries)
    traces = [
        dict(arrival="poisson", rate_hz=POISSON_RATE_HZ),
        dict(arrival="burst", rate_hz=BURST_RATE_HZ,
             on_ms=BURST_ON_MS, off_ms=BURST_OFF_MS),
    ]
    rows = []
    for trace in traces:
        stage = FilterStage(profiles, d, engine=engine,
                            keep_unmatched=True, batch_size=max_batch)
        # warm the compiled programs outside the trace (the FULL corpus
        # once, so every byte-bucket shape the trace will see is
        # compiled): first-batch jit compile is a cold-start cost, not
        # a steady-state SLO
        list(stage.route_bytes(raw))
        stage.stats = {k: type(v)() for k, v in stage.stats.items()}
        arrivals = make_arrivals(
            trace["arrival"], len(raw), rate_hz=trace["rate_hz"],
            on_s=trace.get("on_ms", BURST_ON_MS) / 1e3,
            off_s=trace.get("off_ms", BURST_OFF_MS) / 1e3, seed=seed)
        deliveries: list = []
        loop = ServeLoop(stage, max_batch=max_batch,
                         deadline_ms=deadline_ms, queue_cap=queue_cap,
                         max_inflight=max_inflight, overload="shed",
                         deliver=deliveries.append)
        with loop:
            run_trace(loop, raw, arrivals)
        slo = loop.slo_summary()
        rows.append({
            "bench": "serve_latency", "engine": engine,
            "n_requests": n_requests, "n_queries": n_queries,
            "max_batch": max_batch, "deadline_ms": deadline_ms,
            "queue_cap": queue_cap, "max_inflight": max_inflight,
            "overload": "shed", "seed": seed, **trace,
            # measurements (all NON_IDENTITY in compare_baseline)
            "p50_ms": slo["p50_ms"], "p99_ms": slo["p99_ms"],
            "p999_ms": slo["p999_ms"], "mean_ms": slo["mean_ms"],
            "shed_rate": slo["shed_rate"], "completed": slo["completed"],
            "served_per_s": slo["served_per_s"],
            "batch_fill": slo["batch_fill"],
            "size_closes": slo["size_closes"],
            "deadline_closes": slo["deadline_closes"],
            "flush_closes": slo["flush_closes"],
            "backpressure_waits": slo["backpressure_waits"],
            "max_queue_depth": slo["max_queue_depth"],
            "deliveries": sum(len(b) for b in deliveries),
        })
    return rows


def run(full: bool = False) -> list[dict]:
    if full:
        return (run_serve_latency(256)
                + run_serve_latency(256, deadline_ms=50.0, max_inflight=4))
    return run_serve_latency(96)


if __name__ == "__main__":
    import json

    for row in run():
        print(json.dumps(row))
