"""Roofline analysis from dry-run artifacts (§Roofline of EXPERIMENTS.md).

This container cannot measure TPU wall time, so the three roofline terms
are *derived* from the compiled dry-run artifacts that
``launch/dryrun.py`` writes to ``artifacts/dryrun/*.json``:

  compute_s    = HLO_FLOPs  / (chips × 197e12 FLOP/s)     (bf16 v5e)
  memory_s     = HLO_bytes  / (chips × 819e9 B/s)         (HBM)
  collective_s = coll_bytes / (chips × 50e9  B/s)         (per-link ICI)

``coll_bytes`` is parsed from the HLO text: the summed operand bytes of
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute.
The dominant term is the bottleneck the §Perf loop iterates on.

The one-launch megakernel closes the loop from the *measured* side:
:func:`achieved_pct` turns a wall-clocked byte stream into "% of the
HBM roofline", and :func:`megakernel_rows` lifts the measured
``kernel_vs_scan`` bytes rows (``benchmarks.bench_throughput``) into
``bench="roofline"`` rows so the same artifact carries both the derived
ceilings and where the kernel actually lands under them.
"""
from __future__ import annotations

import glob
import json
import os

PEAK_FLOPS = 197e12      # bf16 per chip (TPU v5e)
HBM_BW = 819e9           # B/s per chip
ICI_BW = 50e9            # B/s per link

ARTIFACT_DIR = os.path.join(os.path.dirname(__file__), "..", "artifacts",
                            "dryrun")


def terms(flops: float, bytes_: float, coll_bytes: float, chips: int,
          model_flops: float | None = None) -> dict:
    compute_s = flops / (chips * PEAK_FLOPS)
    memory_s = bytes_ / (chips * HBM_BW)
    coll_s = coll_bytes / (chips * ICI_BW)
    dom = max(("compute", compute_s), ("memory", memory_s),
              ("collective", coll_s), key=lambda kv: kv[1])
    out = {
        "compute_s": compute_s,
        "memory_s": memory_s,
        "collective_s": coll_s,
        "bottleneck": dom[0],
        "bound_s": dom[1],
    }
    if model_flops:
        out["model_flops"] = model_flops
        out["useful_flop_frac"] = model_flops / flops if flops else 0.0
        # fraction of roofline: useful work at peak over the bound time
        out["roofline_frac"] = (model_flops / (chips * PEAK_FLOPS)) / dom[1] \
            if dom[1] > 0 else 0.0
    return out


def achieved_pct(bytes_streamed: float, seconds: float,
                 chips: int = 1) -> float:
    """Measured stream bandwidth as % of the HBM roofline.

    100% means the kernel moved ``bytes_streamed`` at exactly the HBM
    peak; an interpret-mode run sits at ≈ 0 (the number is still
    recorded so compiled rows land in the same artifact shape).
    """
    if seconds <= 0:
        return 0.0
    return 100.0 * (bytes_streamed / seconds) / (chips * HBM_BW)


def megakernel_rows(kernel_rows: list[dict]) -> list[dict]:
    """Lift measured ``kernel_vs_scan`` pallas-bytes rows into
    ``bench="roofline"`` rows (one per scenario × packing × n_queries ×
    batch) so BENCH_filtering.json carries the achieved-vs-ceiling view
    next to the artifact-derived ceilings."""
    out = []
    for r in kernel_rows:
        if (r.get("bench") != "kernel_vs_scan"
                or r.get("path") != "pallas"
                or r.get("variant") != "bytes"
                or "stream_bytes" not in r):
            continue
        out.append({
            "bench": "roofline",
            "cell": "megakernel-bytes",
            "source": "kernel_vs_scan",
            "backend": r.get("backend"),
            "scenario": r.get("scenario"),
            "packing": r.get("packing"),
            "n_queries": r.get("n_queries"),
            "batch": r.get("batch"),
            "stream_bytes": r.get("stream_bytes"),
            "events_per_slot": r.get("events_per_slot"),
            "mb_s": r.get("mb_s"),
            "roofline_pct": r.get("roofline_pct"),
        })
    return out


def rows_from_artifacts(art_dir: str = ARTIFACT_DIR) -> list[dict]:
    rows = []
    for path in sorted(glob.glob(os.path.join(art_dir, "*.json"))):
        with open(path) as f:
            a = json.load(f)
        if a.get("status") != "ok":
            rows.append({"bench": "roofline", "cell": a.get("cell"),
                         "status": a.get("status"),
                         "error": str(a.get("error"))[:200]})
            continue
        t = terms(a["flops"], a["bytes_accessed"], a["collective_bytes"],
                  a["chips"], a.get("model_flops"))
        rows.append({
            "bench": "roofline",
            "cell": a["cell"],
            "mesh": a["mesh"],
            "chips": a["chips"],
            "flops": a["flops"],
            "bytes": a["bytes_accessed"],
            "coll_bytes": a["collective_bytes"],
            "per_device_hbm_peak_B": a.get("per_device_hbm_peak"),
            **{k: (round(v, 6) if isinstance(v, float) else v)
               for k, v in t.items()},
        })
    return rows


if __name__ == "__main__":
    for r in rows_from_artifacts():
        print(json.dumps(r))
