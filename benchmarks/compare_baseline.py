"""Bench-regression gate: fresh rows vs the committed baseline.

CI runs the bench smoke (``python -m benchmarks.run --json
BENCH_fresh.json``) and then::

    python benchmarks/compare_baseline.py BENCH_filtering.json \
        BENCH_fresh.json [BENCH_fresh2.json ...] --threshold 0.25

Rows are matched by their *identity* fields (everything except the
measured metrics and metric-derived ratios); for every matched row the
throughput metrics (``docs_per_s``, ``mb_s``) are compared and the gate
fails when any fresh value regresses more than ``--threshold`` (default
25%) below the baseline.  ``speedup_vs_scan`` is additionally gated,
but ONLY on ``backend="compiled"`` rows — kernel-beats-scan is a
compiled-backend property, and interpret-only containers must not fail
the gate on interpreter noise (their docs_per_s/mb_s stay gated).  ``serve_latency`` rows are gated on
their latency columns (``p50_ms``, ``p99_ms``) with the ratio inverted —
lower is better — while ``p999_ms`` is reported but ungated (a single
stray request on a shared runner defines it).  Several fresh files may be given — the gate
takes each row's best measurement across runs (max throughput, min
latency), so one noisy run on a
shared CI machine cannot fail the gate alone (throughput noise is
one-sided: a machine can only be spuriously *slow*).  Rows present on
only one side (new benchmark sections, machine-dependent mesh shapes)
are reported but never fail the gate — adding a benchmark must not
require regenerating every baseline.

The committed baseline is machine-specific: a CI runner class slower
than the machine that produced it shifts *every* ratio down together.
The median ratio is the machine-delta diagnostic, and the gate uses it:
a row fails only when it regresses beyond the threshold *both* in
absolute terms and relative to the median (``ratio / median``).  On a
same-speed machine the median sits at ≈ 1 and the gate is exactly the
plain per-row check; on a uniformly slower runner the whole-suite shift
is reported as a baseline-refresh warning instead of failing every row
at once — a genuine code regression still shows up as an outlier
against whatever the machine trend is.

A markdown trend table is written to ``$GITHUB_STEP_SUMMARY`` when that
variable is set (the CI job summary), or to ``--summary PATH``.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

#: measured throughput metrics the gate compares (higher is better)
METRICS = ("docs_per_s", "mb_s")

#: measured latency metrics the gate compares on serve_latency rows —
#: LOWER is better, so the gated ratio is baseline/fresh (below 1 when
#: fresh is slower) and best-of-several-runs takes the *minimum*.  p999
#: is reported but ungated: a single stray request on a shared CI
#: runner defines it, which is exactly the noise the gate must ignore.
LATENCY_METRICS = ("p50_ms", "p99_ms")

#: ratio metrics gated only on ``backend="compiled"`` rows: the
#: kernel-beats-scan claim is a compiled-backend property, so on an
#: interpret-only container the ratio is tracked but can never fail the
#: gate on interpreter noise
COMPILED_ONLY_METRICS = ("speedup_vs_scan",)

#: measurement outputs and derived ratios — never part of a row's identity
NON_IDENTITY = frozenset(METRICS) | frozenset(COMPILED_ONLY_METRICS) | \
    frozenset(LATENCY_METRICS) | {
    "speedup_vs_yfilter", "vs_events", "speedup_vs_recompile",
    "seconds_per_op", "events_per_slot", "stream_bytes", "roofline_pct",
    # subscription-axis measurement columns (query_scaling rows):
    # state compression and sparse-delivery outputs, not configuration
    "verdict_bytes", "dense_verdict_bytes", "matches", "sparse_docs_per_s",
    "states_per_query", "state_compression", "sparse_exact", "n_states",
    # fused-sparse-epilogue measurement column: which delivery route ran
    # (kernel-fused / lane-compact / base-fallback / dense-overflow) is
    # backend-dependent output, not row configuration
    "verdict_path",
    # serve_latency measurement columns: SLO percentiles, shed/occupancy
    # counters and delivery accounting of the continuous serve loop —
    # all outputs of the trace run, not its configuration
    "p999_ms", "mean_ms", "shed_rate", "completed", "served_per_s",
    "batch_fill", "size_closes", "deadline_closes", "flush_closes",
    "backpressure_waits", "max_queue_depth", "deliveries",
    # fault-tolerance / hot-swap measurement columns: failure accounting
    # and shadow-swap timing are trace outputs, not configuration
    "failed", "quarantined", "rejected", "retries", "swaps",
    "swap_rollbacks", "delivery_errors", "dead_letter_depth",
    "swap_build_p50_ms", "swap_build_p99_ms", "swap_commit_p50_ms",
    "swap_commit_p99_ms", "cache_hits", "cache_misses",
}


def gated_metrics(row: dict) -> tuple[str, ...]:
    """Metrics the gate compares for this row (see COMPILED_ONLY_METRICS)."""
    metrics = METRICS + LATENCY_METRICS
    if row.get("backend") == "compiled":
        return metrics + COMPILED_ONLY_METRICS
    return metrics


def gate_ratio(metric: str, baseline: float, fresh: float) -> float:
    """Fresh-vs-baseline ratio oriented so < 1 is always a regression:
    fresh/baseline for throughput, baseline/fresh for latency."""
    if metric in LATENCY_METRICS:
        return baseline / fresh
    return fresh / baseline


def row_key(row: dict) -> str:
    """Stable identity of a measurement row (config fields only)."""
    ident = {k: v for k, v in row.items() if k not in NON_IDENTITY}
    return json.dumps(ident, sort_keys=True)


def load_rows(path: str) -> dict[str, dict]:
    with open(path) as f:
        rows = json.load(f)
    out: dict[str, dict] = {}
    for row in rows:
        if any(m in row for m in METRICS + LATENCY_METRICS):
            out[row_key(row)] = row
    return out


def merge_best(runs: list[dict[str, dict]]) -> dict[str, dict]:
    """Per-row best-of across fresh runs (max of each throughput
    metric, min of each latency metric)."""
    out: dict[str, dict] = {}
    for run in runs:
        for key, row in run.items():
            best = out.setdefault(key, dict(row))
            for metric in METRICS + COMPILED_ONLY_METRICS:
                if metric in row and metric in best:
                    best[metric] = max(best[metric], row[metric])
            for metric in LATENCY_METRICS:
                if metric in row and metric in best:
                    best[metric] = min(best[metric], row[metric])
    return out


def compare(baseline: dict[str, dict], fresh: dict[str, dict],
            threshold: float):
    """→ (table_rows, regressions).

    A row regresses when its ratio is below ``1 - threshold`` both
    absolutely and after normalizing by the median ratio (the
    machine-delta correction — see the module docstring).
    """
    table = []
    for key in sorted(baseline.keys() & fresh.keys()):
        b, f = baseline[key], fresh[key]
        for metric in gated_metrics(b):
            if metric not in b or metric not in f:
                continue
            if not b[metric] or not f[metric]:
                continue  # zero on either side: no ratio to gate on
            if metric in LATENCY_METRICS and (
                    b[metric] != b[metric] or f[metric] != f[metric]):
                continue  # NaN percentile (nothing completed): ungated
            ratio = gate_ratio(metric, b[metric], f[metric])
            label = "{} {}".format(
                b.get("bench", "?"),
                " ".join(f"{k}={v}" for k, v in sorted(b.items())
                         if k not in NON_IDENTITY and k != "bench"))
            table.append((label, metric, b[metric], f[metric], ratio))
    med = median_ratio(table)
    cut = 1.0 - threshold
    regressions = [e for e in table
                   if e[4] < cut and e[4] / max(med, 1e-9) < cut]
    return table, regressions


def median_ratio(table) -> float:
    """Median fresh/baseline ratio — the machine-delta diagnostic."""
    ratios = sorted(e[4] for e in table)
    if not ratios:
        return 1.0
    mid = len(ratios) // 2
    return (ratios[mid] if len(ratios) % 2
            else (ratios[mid - 1] + ratios[mid]) / 2)


def write_summary(path: str, table, regressions, unmatched: int,
                  threshold: float) -> None:
    lines = ["## Bench-regression gate", ""]
    verdict = ("❌ **{} regression(s) beyond {:.0%}**".format(
        len(regressions), threshold) if regressions
        else "✅ no regression beyond {:.0%}".format(threshold))
    med = median_ratio(table)
    lines += [f"{verdict} ({len(table)} compared metrics, "
              f"median ratio {med:.2f}×, "
              f"{unmatched} fresh rows without a baseline)", ""]
    if med < 1.0 - threshold:
        lines += ["> The *median* ratio is below the threshold — a "
                  "runner-class/machine delta, so per-row gating is "
                  "median-normalized.  Refresh the committed baseline "
                  "from a green main run's `BENCH_fresh.json` artifact.",
                  ""]
    lines += ["| row | metric | baseline | fresh | ratio |",
              "|---|---|---:|---:|---:|"]
    # regressions first, then the slowest-trending rows
    ranked = sorted(table, key=lambda e: e[4])
    for label, metric, b, f, ratio in ranked[:40]:
        flag = " ⚠️" if ratio < 1.0 - threshold else ""
        lines.append(f"| {label} | {metric} | {b:.2f} | {f:.2f} | "
                     f"{ratio:.2f}×{flag} |")
    if len(ranked) > 40:
        lines.append(f"| … {len(ranked) - 40} more | | | | |")
    with open(path, "a") as fh:
        fh.write("\n".join(lines) + "\n")


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("baseline", help="committed BENCH_filtering.json")
    ap.add_argument("fresh", nargs="+",
                    help="freshly measured rows; several files are "
                         "merged best-of per row")
    ap.add_argument("--threshold", type=float, default=0.25,
                    help="max tolerated fractional regression "
                         "(default 0.25 = 25%%)")
    ap.add_argument("--summary", default=None,
                    help="markdown summary path "
                         "(default: $GITHUB_STEP_SUMMARY when set)")
    args = ap.parse_args()

    baseline = load_rows(args.baseline)
    fresh = merge_best([load_rows(p) for p in args.fresh])
    unmatched = len(fresh.keys() - baseline.keys())
    table, regressions = compare(baseline, fresh, args.threshold)

    summary = args.summary or os.environ.get("GITHUB_STEP_SUMMARY")
    if summary:
        write_summary(summary, table, regressions, unmatched,
                      args.threshold)

    print(f"compared {len(table)} metrics over "
          f"{len(baseline.keys() & fresh.keys())} matched rows "
          f"(median ratio {median_ratio(table):.2f}x, "
          f"{unmatched} fresh rows without a baseline)")
    for label, metric, b, f, ratio in regressions:
        print(f"REGRESSION {label} {metric}: {b:.2f} -> {f:.2f} "
              f"({ratio:.2f}x)", file=sys.stderr)
    if regressions:
        print(f"FAIL: {len(regressions)} metric(s) regressed more than "
              f"{args.threshold:.0%}", file=sys.stderr)
        return 1
    print(f"OK: no regression beyond {args.threshold:.0%}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
