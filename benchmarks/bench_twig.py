"""Twig filtering benchmark (paper §5 extension).

Measures the two-stage cost structure the paper reasons about: shared-NFA
path filtering (stage 1) vs exact verification on candidates (stage 2),
and the decomposition false-positive rate that stage 2 eliminates.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core.dictionary import TagDictionary
from repro.core.twig import TwigFilter, decompose, parse_twig
from repro.data.generator import DTD, gen_corpus


def run(n_twigs=48, n_docs=24, nodes_per_doc=300, seed=0,
        engine="levelwise"):
    dtd = DTD.generate(n_tags=24, seed=seed)
    d = TagDictionary()
    dtd.register(d)
    rng = np.random.default_rng(seed)
    names = dtd.tag_names
    twigs = []
    for i in range(n_twigs):
        a, b, c = rng.choice(24, 3, replace=False)
        if i % 3 == 0:
            twigs.append(f"{names[a]}[//{names[b]}][//{names[c]}]")
        elif i % 3 == 1:
            twigs.append(f"{names[a]}[{names[b]}]//{names[c]}")
        else:
            twigs.append(f"{names[a]}//{names[b]}")
    docs = gen_corpus(dtd, n_docs=n_docs, nodes_per_doc=nodes_per_doc,
                      seed=seed + 1)
    f = TwigFilter(twigs, d, engine=engine)
    n_paths = sum(len(decompose(parse_twig(t))) for t in twigs)
    t0 = time.perf_counter()
    matches = sum(int(f.filter_document(doc).matched.sum())
                  for doc in docs)
    dt = time.perf_counter() - t0
    checks = f.stats["stage2_checks"]
    rejects = f.stats["stage2_rejects"]
    return [{
        "bench": "twig_filtering",
        "engine": engine,
        "n_twigs": n_twigs,
        "n_paths": n_paths,
        "shared_nfa_states": f.nfa.n_states,
        "n_docs": n_docs,
        "deliveries": matches,
        "stage2_checks": checks,
        "stage2_false_positives": rejects,
        "fp_rate_pct": round(100 * rejects / max(checks, 1), 1),
        "seconds": round(dt, 3),
    }]


if __name__ == "__main__":
    import json
    for r in run():
        print(json.dumps(r))
