"""Fig-9 reproduction: filtering throughput, hardware engines vs YFilter.

The paper streams 1–8 MB documents against 16–1024 profiles and reports
MB/s: the FPGA is ~100× the software YFilter and throughput degrades
gently with profile count.  We reproduce the *experiment* on this
container's CPU: the python YFilter baseline vs the JAX engines — all
constructed through the engine registry and driven through the one
batched API (``EventBatch`` in, ``(B, Q)`` ``FilterResult`` out), so the
Fig-9-style engine comparison is one flag::

    PYTHONPATH=src python benchmarks/bench_throughput.py \
        --engine streaming --engine levelwise --queries 256

Absolute numbers are CPU-bound; the *shape* of the comparison (orders of
magnitude over the scalar software path, slope vs #profiles) is the
reproduced claim; EXPERIMENTS.md §Paper-Fig9 reports both and the
§Roofline section projects TPU v5e throughput.
"""
from __future__ import annotations

import argparse
import os
import sys
import time

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core import engines
from repro.core.dictionary import TagDictionary
from repro.core.events import (ByteBatch, EventBatch, decode_bytes,
                               encode_bytes)
from repro.core.nfa import compile_queries
from repro.data.generator import DTD, gen_corpus, gen_profiles

TEXT_FILL = 8  # emulate element text content in the byte-size accounting

DEFAULT_ENGINES = ("yfilter", "levelwise", "wavefront", "streaming")

#: ingest paths for the parse-cost comparison (--ingest):
#:   events       — documents pre-parsed on the host; pad+structure pass
#:                  (EventBatch.from_streams) + filter_batch
#:   bytes-host   — raw wire bytes decoded by the host reference
#:                  (decode_bytes) then the events path
#:   bytes-device — raw wire bytes parsed AND filtered on device
#:                  (engine.filter_bytes; fused for the streaming engine)
INGEST_PATHS = ("events", "bytes-host", "bytes-device")


def _time(fn, repeat=3) -> float:
    best = float("inf")
    for _ in range(repeat):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


# pure-python engines: nothing compiles, so no warmup and one timed pass
HOST_ENGINES = frozenset({"yfilter", "oracle"})


def run(query_counts=(16, 64, 256, 1024), path_lengths=(2, 4, 6),
        n_docs=16, nodes_per_doc=400, seed=0,
        engines_to_run=DEFAULT_ENGINES, repeat=3):
    """One row per (engine, path_len, n_queries): docs/sec and MB/s
    through the uniform ``filter_batch`` API."""
    rows = []
    for plen in path_lengths:
        dtd = DTD.generate(n_tags=24, seed=seed)
        docs = gen_corpus(dtd, n_docs=n_docs, nodes_per_doc=nodes_per_doc,
                          seed=seed)
        batch = EventBatch.from_streams(docs, bucket=128)
        mb = float(batch.nbytes(TEXT_FILL).sum()) / 1e6
        for nq in query_counts:
            # one shared workload/NFA per config; matscan alone gets a
            # descendant-only profile set (the paper's regex-only class,
            # Fig 5 left) since it rejects child axes and wildcards
            d = TagDictionary()
            dtd.register(d)
            qs = gen_profiles(dtd, n=nq, length=plen, seed=seed + plen)
            nfa = compile_queries(qs, d, shared=True)
            config_rows = []
            for name in engines_to_run:
                if name == "matscan":
                    dm = TagDictionary()
                    dtd.register(dm)
                    qsm = gen_profiles(dtd, n=nq, length=plen, p_desc=1.0,
                                       p_wild=0.0, seed=seed + plen)
                    eng = engines.create(
                        name, compile_queries(qsm, dm, shared=True),
                        dictionary=dm)
                else:
                    eng = engines.create(name, nfa, dictionary=d)
                if name not in HOST_ENGINES:
                    eng.filter_batch(batch)  # compile warmup
                t = _time(lambda: eng.filter_batch(batch),
                          repeat=1 if name in HOST_ENGINES else repeat)
                config_rows.append(
                    {"bench": "fig9_throughput", "engine": name,
                     "path_len": plen, "n_queries": nq,
                     "doc_mb": round(mb, 3), "n_docs": n_docs,
                     "n_states": eng.nfa.n_states,
                     "docs_per_s": round(n_docs / t, 2),
                     "mb_s": round(mb / t, 3)})
            # order-independent speedup column; matscan runs a different
            # (descendant-only) profile set, so no cross-workload ratio
            baseline = next((r["mb_s"] for r in config_rows
                             if r["engine"] == "yfilter"), None)
            if baseline:
                for r in config_rows:
                    if r["engine"] not in ("yfilter", "matscan"):
                        r["speedup_vs_yfilter"] = round(
                            r["mb_s"] / baseline, 2)
            rows.extend(config_rows)
    return rows


def run_ingest(query_counts=(64, 256), path_len=4, n_docs=16,
               nodes_per_doc=400, seed=0, ingest_paths=INGEST_PATHS,
               engine="streaming", repeat=3):
    """Parse-cost comparison: raw payload → verdict, per ingest path.

    Unlike :func:`run` (which times only ``filter_batch`` on a prebuilt
    batch), every path here is timed *end to end from its wire input*,
    so the host-parse seam the device path removes is inside the
    measurement.  One row per (ingest, n_queries).
    """
    dtd = DTD.generate(n_tags=24, seed=seed)
    d = TagDictionary()
    dtd.register(d)
    docs = gen_corpus(dtd, n_docs=n_docs, nodes_per_doc=nodes_per_doc,
                      seed=seed)
    payloads = [encode_bytes(doc, text_fill=TEXT_FILL) for doc in docs]
    mb = sum(len(p) for p in payloads) / 1e6
    sym = d.symbol_value_table()

    rows = []
    for nq in query_counts:
        qs = gen_profiles(dtd, n=nq, length=path_len, seed=seed + path_len)
        nfa = compile_queries(qs, d, shared=True)
        eng = engines.create(engine, nfa, dictionary=d)

        def path_events():
            return eng.filter_batch(EventBatch.from_streams(docs, bucket=128))

        def path_bytes_host():
            decoded = [decode_bytes(p, sym) for p in payloads]
            return eng.filter_batch(
                EventBatch.from_streams(decoded, bucket=128))

        def path_bytes_device():
            return eng.filter_bytes(
                ByteBatch.from_buffers(payloads, bucket=1024))

        fns = {"events": path_events, "bytes-host": path_bytes_host,
               "bytes-device": path_bytes_device}
        for name in ingest_paths:
            fn = fns[name]
            fn()  # warmup: device paths compile once per shape
            t = _time(fn, repeat=repeat)
            rows.append(
                {"bench": "ingest_throughput", "ingest": name,
                 "engine": engine, "path_len": path_len, "n_queries": nq,
                 "n_docs": n_docs, "doc_mb": round(mb, 3),
                 "docs_per_s": round(n_docs / t, 2),
                 "mb_s": round(mb / t, 3)})
        base = next((r["mb_s"] for r in rows
                     if r["n_queries"] == nq and r["ingest"] == "events"),
                    None)
        if base:
            for r in rows:
                if r["n_queries"] == nq and r["ingest"] != "events":
                    r["vs_events"] = round(r["mb_s"] / base, 2)
    return rows


def _scenario_docs(dtd, scenario, b, nodes_per_doc, seed):
    """Document-length mix per scenario: ``uniform`` pads fairly;
    ``skewed`` (one long doc per 4, the rest 16× shorter) is the mix
    segment-packing exists for."""
    if scenario == "skewed":
        n_long = max(1, b // 4)
        return (gen_corpus(dtd, n_docs=n_long, nodes_per_doc=nodes_per_doc,
                           seed=seed)
                + gen_corpus(dtd, n_docs=b - n_long,
                             nodes_per_doc=max(2, nodes_per_doc // 16),
                             seed=seed + 1))
    return gen_corpus(dtd, n_docs=b, nodes_per_doc=nodes_per_doc, seed=seed)


def run_kernel_vs_scan(query_counts=(64, 256, 1024), batch_sizes=(4,),
                       path_len=4, nodes_per_doc=150, seed=0, repeat=2,
                       variants=("events", "bytes"),
                       scenarios=("uniform", "skewed")):
    """Megakernel vs scan on the streaming hot path, per ingest variant.

    One row per (scenario, variant, path, packing, batch, n_queries):
    the same profile set and batch driven through ``StreamingEngine``
    with ``kernel="scan"`` (the ``lax.scan`` oracle) and
    ``kernel="pallas"`` (the bit-packed megakernel).
    ``variant="events"`` times ``filter_batch`` on a prebuilt
    :class:`EventBatch`; ``variant="bytes"`` times the one-launch fused
    bytes→verdict program (``filter_bytes``), once padded
    (``packing="padded"``) and — on the pallas path — once
    segment-packed (``packing="packed"``, ``filter_bytes(pack=True)``).
    The ``backend`` field records whether Pallas *compiled* (a real
    TPU) or ran under its interpreter (everywhere else) — the
    kernel-beats-scan claim is a compiled-backend property; interpret
    rows exist so CI tracks both paths' health and the TPU rows land in
    the same artifact shape.  ``speedup_vs_scan`` on the pallas rows is
    the headline number.  Utilization/roofline columns:

    * ``events_per_slot`` — true parse events over the slots the kernel
      actually burns (event slots for the events variant, byte slots
      for the bytes variants); on the skewed scenario the packed rows
      must show ≥ 2× the padded rows — that ratio IS the padding waste
      segment-packing removes.
    * ``stream_bytes`` / ``roofline_pct`` (bytes rows) — bytes DMA'd
      through the kernel and the achieved stream bandwidth as % of the
      single-chip HBM roofline (:func:`benchmarks.roofline.achieved_pct`;
      only compiled-backend rows approach it, interpret rows sit at ~0).
    * sparse columns — every row also drives the sparse-verdict twin of
      its dense call: ``verdict_path`` (which route actually ran —
      ``kernel-fused`` on pallas rows means the in-kernel epilogue, the
      accept bitmap never left VMEM), ``sparse_docs_per_s``,
      ``verdict_bytes`` (O(matches), vs ``dense_verdict_bytes`` at
      O(B·Q)) and ``sparse_exact`` (densified bit-identical to the
      dense verdict of the same call).
    """
    from repro.core.events import pack_segments
    from repro.kernels import interpret_default
    try:
        from benchmarks.roofline import achieved_pct
    except ImportError:          # run as a script, not as a package
        from roofline import achieved_pct

    backend = "interpret" if interpret_default() else "compiled"
    dtd = DTD.generate(n_tags=24, seed=seed)
    d = TagDictionary()
    dtd.register(d)
    rows = []
    for nq in query_counts:
        qs = gen_profiles(dtd, n=nq, length=path_len, seed=seed + path_len)
        nfa = compile_queries(qs, d, shared=True)
        paths = {
            "scan": engines.create("streaming", nfa, dictionary=d,
                                   kernel="scan"),
            "pallas": engines.create("streaming", nfa, dictionary=d,
                                     kernel="pallas"),
        }
        for scenario in scenarios:
            for b in batch_sizes:
                docs = _scenario_docs(dtd, scenario, b, nodes_per_doc, seed)
                batch = EventBatch.from_streams(docs, bucket=128)
                ev_total = int(np.asarray(batch.n_events).sum())
                payloads = [encode_bytes(doc, text_fill=TEXT_FILL)
                            for doc in docs]
                bb = ByteBatch.from_buffers(payloads, bucket=1024)
                mb = sum(len(p) for p in payloads) / 1e6
                for variant in variants:
                    base_mb_s = None
                    for path, eng in paths.items():
                        packings = ("padded", "packed") \
                            if variant == "bytes" and path == "pallas" \
                            else ("padded",)
                        for packing in packings:
                            packed = packing == "packed"
                            if variant == "events":
                                fn = lambda: eng.filter_batch(batch)  # noqa: E731
                                fn_sparse = (  # noqa: E731
                                    lambda: eng.filter_batch_sparse(
                                        batch))
                                slots = int(np.asarray(batch.kind).size)
                                stream_bytes = None
                            elif packed:
                                fn = lambda: eng.filter_bytes(  # noqa: E731
                                    bb, pack=True)
                                fn_sparse = (  # noqa: E731
                                    lambda: eng.filter_bytes_sparse(
                                        bb, pack=True))
                                tgt = int(eng.plan_.meta.get(
                                    "segment_target", 4096))
                                slots = int(pack_segments(
                                    bb.to_host(),
                                    target_len=tgt).data.size)
                                stream_bytes = slots
                            else:
                                fn = lambda: eng.filter_bytes(bb)  # noqa: E731
                                fn_sparse = (  # noqa: E731
                                    lambda: eng.filter_bytes_sparse(bb))
                                slots = int(np.asarray(bb.data).size)
                                stream_bytes = slots
                            dense = fn()  # compile warmup
                            t = _time(fn, repeat=repeat)
                            sparse = fn_sparse()  # warmup + path sample
                            t_sparse = _time(fn_sparse, repeat=repeat)
                            row = {"bench": "kernel_vs_scan",
                                   "variant": variant, "path": path,
                                   "scenario": scenario,
                                   "packing": packing,
                                   "backend": backend,
                                   "engine": "streaming", "batch": b,
                                   "n_queries": nq, "path_len": path_len,
                                   "n_states": nfa.n_states,
                                   "doc_mb": round(mb, 3),
                                   "events_per_slot": round(
                                       ev_total / slots, 5),
                                   "docs_per_s": round(b / t, 2),
                                   "mb_s": round(mb / t, 3),
                                   "verdict_path": sparse.meta.get(
                                       "path"),
                                   "sparse_docs_per_s": round(
                                       b / t_sparse, 2),
                                   "matches": sparse.n_matches,
                                   "verdict_bytes":
                                       sparse.verdict_bytes,
                                   "dense_verdict_bytes":
                                       sparse.dense_bytes,
                                   "sparse_exact": bool(
                                       sparse.densify() == dense)}
                            if stream_bytes is not None:
                                row["stream_bytes"] = stream_bytes
                                row["roofline_pct"] = round(
                                    achieved_pct(stream_bytes, t), 6)
                            if path == "scan":
                                base_mb_s = row["mb_s"]
                            elif base_mb_s:
                                row["speedup_vs_scan"] = round(
                                    row["mb_s"] / base_mb_s, 3)
                            rows.append(row)
    return rows


def geometric_query_counts(max_queries: int, min_queries: int = 100,
                           growth: int = 10) -> tuple[int, ...]:
    """Capped geometric subscription series: ``min·growthᵏ`` up to and
    always including ``max_queries`` (so ``--max-queries 1000000`` is
    the 10⁶ smoke configuration of the same bench)."""
    counts, n = [], int(min_queries)
    while n < int(max_queries):
        counts.append(n)
        n *= int(growth)
    counts.append(int(max_queries))
    return tuple(counts)


def run_query_scaling(query_counts=None, shard_counts=(1, 2, 4),
                      path_len=3, n_docs=8, nodes_per_doc=200, seed=0,
                      engine="streaming", repeat=3, use_mesh=True,
                      max_queries=100_000, min_queries=100, growth=10,
                      minimize=True):
    """The paper's headline claim, measured: scalability in the number
    of standing profiles.

    One row per (n_queries, query_shards) over a capped geometric
    series (default 10²→10⁵, ``max_queries=10⁶`` is the smoke config):
    docs/s through the same batch, monolithic plan (``query_shards=1``,
    the seed architecture) vs the partitioned :class:`ShardedPlan`
    executed over the mesh ``"model"`` axis.  On a single device the
    sharded rows measure the stacking overhead; on a real mesh each
    device runs 1/P of the query set — the paper's
    profiles-across-chips replication (§3.5/Fig 9 slope).

    The subscription-axis columns each row carries:

    * ``states_per_query`` / ``state_compression`` — automaton sharing:
      minimized state count over live queries, and unshared states over
      minimized (the global-minimization win; ≥ 2× whenever profiles
      share structure, enormous on Com-P-style workloads).
    * ``sparse_docs_per_s`` / ``verdict_bytes`` / ``dense_verdict_bytes``
      / ``matches`` — sparse verdict delivery: the match-list wire size
      scales with matches while the dense bitmap scales with ``B × Q``.
    * ``sparse_exact`` — the sparse result densified bit-identically to
      the dense verdict of the same batch (checked every row).
    """
    from repro.launch.mesh import make_filter_mesh

    if query_counts is None:
        query_counts = geometric_query_counts(max_queries, min_queries,
                                              growth)
    dtd = DTD.generate(n_tags=24, seed=seed)
    d = TagDictionary()
    dtd.register(d)
    docs = gen_corpus(dtd, n_docs=n_docs, nodes_per_doc=nodes_per_doc,
                      seed=seed)
    batch = EventBatch.from_streams(docs, bucket=128)
    mb = float(batch.nbytes(TEXT_FILL).sum()) / 1e6
    rows = []
    for nq in query_counts:
        qs = gen_profiles(dtd, n=nq, length=path_len, seed=seed + path_len)
        nfa = compile_queries(qs, d, shared=True)
        eng = engines.create(engine, nfa, dictionary=d, minimize=minimize)
        ms = eng.minimize_stats
        for shards in shard_counts:
            if shards == 1:
                fn = lambda: eng.filter_batch(batch)  # noqa: E731
                fn_sparse = lambda: eng.filter_batch_sparse(  # noqa: E731
                    batch)
            else:
                sp = eng.plan_sharded(shards)
                mesh = make_filter_mesh(shards) if use_mesh else None
                fn = lambda: eng.filter_batch_sharded(  # noqa: E731
                    batch, sp, mesh=mesh)
                fn_sparse = (  # noqa: E731
                    lambda: eng.filter_batch_sharded_sparse(
                        batch, sp, mesh=mesh))
            dense = fn()  # compile warmup + the equivalence reference
            t = _time(fn, repeat=repeat)
            sparse = fn_sparse()  # compile warmup + wire-size sample
            t_sparse = _time(fn_sparse, repeat=repeat)
            rows.append(
                {"bench": "query_scaling", "engine": engine,
                 "n_queries": nq, "query_shards": shards,
                 "path_len": path_len, "n_docs": n_docs,
                 "doc_mb": round(mb, 3), "n_states": eng.nfa.n_states,
                 "states_per_query": round(eng.nfa.n_states / nq, 4),
                 "state_compression": (round(ms.compression, 2)
                                       if ms else 1.0),
                 "docs_per_s": round(n_docs / t, 2),
                 "mb_s": round(mb / t, 3),
                 "sparse_docs_per_s": round(n_docs / t_sparse, 2),
                 "verdict_path": sparse.meta.get("path"),
                 "matches": sparse.n_matches,
                 "verdict_bytes": sparse.verdict_bytes,
                 "dense_verdict_bytes": sparse.dense_bytes,
                 "sparse_exact": bool(sparse.densify() == dense)})
    return rows


def run_doc_scaling(batch_sizes=(8, 32), data_shard_counts=(1, 2, 4),
                    query_shard_counts=(1, 2), n_queries=128, path_len=3,
                    nodes_per_doc=200, seed=0, engine="streaming",
                    repeat=3):
    """Scaling the *document* axis: the paper's second replication
    dimension (§3.5 — the stream fanned across replicated filter
    hardware), measured as a (batch × data-shard × query-shard) grid.

    One row per grid point: raw wire bytes → verdict through the 2-D
    ``("data", "model")`` program (``filter_bytes_sharded2d``), docs/s
    and MB/s end to end.  ``data_shards`` records the *placed* mesh
    axis (the request shrinks to what the host offers — on one device
    every row is the same program, measuring stacking overhead; on a
    multi-device host docs/s grows with the data axis because each
    replica parses and filters only its slice of the stream).
    """
    from repro.launch.mesh import make_filter_mesh

    dtd = DTD.generate(n_tags=24, seed=seed)
    d = TagDictionary()
    dtd.register(d)
    qs = gen_profiles(dtd, n=n_queries, length=path_len, seed=seed + path_len)
    nfa = compile_queries(qs, d, shared=True)
    eng = engines.create(engine, nfa, dictionary=d)
    rows = []
    for b in batch_sizes:
        docs = gen_corpus(dtd, n_docs=b, nodes_per_doc=nodes_per_doc,
                          seed=seed)
        payloads = [encode_bytes(doc, text_fill=TEXT_FILL) for doc in docs]
        bb = ByteBatch.from_buffers(payloads, bucket=1024)
        mb = sum(len(p) for p in payloads) / 1e6
        for qshards in query_shard_counts:
            sp = eng.plan_sharded(qshards)
            for dshards in data_shard_counts:
                mesh = make_filter_mesh(qshards, data_shards=dshards)
                shape = dict(mesh.shape)
                fn = lambda: eng.filter_bytes_sharded2d(  # noqa: E731
                    bb, sp, mesh=mesh)
                fn()  # compile warmup
                t = _time(fn, repeat=repeat)
                rows.append(
                    {"bench": "doc_scaling", "engine": engine,
                     "batch": b, "n_queries": n_queries,
                     "path_len": path_len,
                     "data_shards_requested": dshards,
                     "data_shards": shape["data"],
                     "query_shards": qshards,
                     "model_shards": shape["model"],
                     "doc_mb": round(mb, 3),
                     "docs_per_s": round(b / t, 2),
                     "mb_s": round(mb / t, 3)})
    return rows


def run_churn(n_queries=512, n_parts=4, n_ops=16, path_len=3, seed=0,
              engine="streaming"):
    """Subscription-churn latency: the pub-sub system's defining op.

    Per-op seconds for subscribe (recompiles ONE partition) and
    unsubscribe (tombstone, no recompile) on a sharded plan, against
    the monolithic alternative — a full profile-set recompile per op.
    The steady-state gap is the O(n_queries / n_parts) claim.
    """
    dtd = DTD.generate(n_tags=24, seed=seed)
    d = TagDictionary()
    dtd.register(d)
    profiles = gen_profiles(dtd, n=n_queries, length=path_len,
                            seed=seed + path_len)
    extra = gen_profiles(dtd, n=n_ops, length=path_len, seed=seed + 977)
    eng = engines.create(engine, compile_queries(profiles, d, shared=True),
                         dictionary=d)
    sp = eng.plan_sharded(n_parts)

    t0 = time.perf_counter()
    added: list[int] = []
    for q in extra:
        sp, gids = sp.add_queries([q])
        added += gids
    add_s = (time.perf_counter() - t0) / n_ops

    t0 = time.perf_counter()
    for gid in added:
        sp = sp.remove_queries([gid])
    rm_s = (time.perf_counter() - t0) / n_ops

    # the monolithic alternative: every churn op recompiles everything
    t0 = time.perf_counter()
    engines.create(engine,
                   compile_queries(list(sp.live_queries()), d, shared=True),
                   dictionary=d)
    full_s = time.perf_counter() - t0

    common = {"bench": "churn_latency", "engine": engine,
              "n_queries": n_queries, "n_parts": n_parts, "n_ops": n_ops}
    return [
        {**common, "op": "subscribe", "seconds_per_op": round(add_s, 6),
         "speedup_vs_recompile": round(full_s / max(add_s, 1e-9), 2)},
        {**common, "op": "unsubscribe", "seconds_per_op": round(rm_s, 6),
         "speedup_vs_recompile": round(full_s / max(rm_s, 1e-9), 2)},
        {**common, "op": "full_recompile", "seconds_per_op": round(full_s, 6)},
    ]


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--engine", action="append", default=None,
                    choices=list(engines.names()),
                    help="repeatable; default: "
                         + ",".join(DEFAULT_ENGINES))
    ap.add_argument("--queries", type=int, nargs="+", default=None)
    ap.add_argument("--path-lengths", type=int, nargs="+", default=None)
    ap.add_argument("--docs", type=int, default=16)
    ap.add_argument("--nodes", type=int, default=400)
    ap.add_argument("--repeat", type=int, default=3)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ingest", action="append", default=None,
                    choices=list(INGEST_PATHS),
                    help="repeatable; measure parse cost end-to-end over "
                         "these ingest paths instead of the Fig-9 sweep")
    ap.add_argument("--query-shards", type=int, nargs="+", default=None,
                    metavar="P",
                    help="run the query-count scaling sweep (geometric "
                         "series up to --max-queries standing profiles) "
                         "over these shard counts instead of the Fig-9 "
                         "sweep")
    ap.add_argument("--max-queries", type=int, default=100_000,
                    help="cap of the query-scaling geometric series "
                         "(100·10ᵏ up to and including this; 1000000 is "
                         "the 10⁶ smoke configuration). Ignored when "
                         "--queries lists explicit counts.")
    ap.add_argument("--churn", action="store_true",
                    help="run the subscription-churn latency section "
                         "instead of the Fig-9 sweep")
    ap.add_argument("--kernel-vs-scan", action="store_true",
                    help="run the streaming megakernel vs scan comparison "
                         "(events + fused-bytes variants) instead of the "
                         "Fig-9 sweep")
    ap.add_argument("--data-shards", type=int, nargs="+", default=None,
                    metavar="D",
                    help="run the document-axis scaling grid (batch × "
                         "data-shard × query-shard, bytes → verdict over "
                         "the 2-D mesh) instead of the Fig-9 sweep")
    args = ap.parse_args()
    import json
    if args.data_shards:
        rows = run_doc_scaling(
            data_shard_counts=tuple(args.data_shards),
            query_shard_counts=tuple(args.query_shards or (1, 2)),
            n_queries=(args.queries or [128])[0],
            path_len=(args.path_lengths or [3])[0],
            nodes_per_doc=args.nodes, seed=args.seed,
            engine=(args.engine or ["streaming"])[0], repeat=args.repeat)
        for r in rows:
            print(json.dumps(r))
        return
    if args.query_shards:
        rows = run_query_scaling(
            query_counts=tuple(args.queries) if args.queries else None,
            shard_counts=tuple(args.query_shards),
            path_len=(args.path_lengths or [3])[0],
            n_docs=args.docs, nodes_per_doc=args.nodes, seed=args.seed,
            engine=(args.engine or ["streaming"])[0], repeat=args.repeat,
            max_queries=args.max_queries)
        for r in rows:
            print(json.dumps(r))
        return
    if args.kernel_vs_scan:
        rows = run_kernel_vs_scan(
            query_counts=tuple(args.queries or (64, 256, 1024)),
            batch_sizes=(args.docs,),
            path_len=(args.path_lengths or [4])[0],
            nodes_per_doc=args.nodes, seed=args.seed, repeat=args.repeat)
        for r in rows:
            print(json.dumps(r))
        return
    if args.churn:
        rows = run_churn(n_queries=(args.queries or [512])[0],
                         path_len=(args.path_lengths or [3])[0],
                         seed=args.seed,
                         engine=(args.engine or ["streaming"])[0])
        for r in rows:
            print(json.dumps(r))
        return
    if args.ingest:
        rows = run_ingest(
            query_counts=tuple(args.queries or (64, 256)),
            path_len=(args.path_lengths or [4])[0],
            n_docs=args.docs, nodes_per_doc=args.nodes, seed=args.seed,
            ingest_paths=tuple(args.ingest),
            engine=(args.engine or ["streaming"])[0], repeat=args.repeat)
        for r in rows:
            print(json.dumps(r))
        return
    kw = dict(n_docs=args.docs, nodes_per_doc=args.nodes, seed=args.seed,
              engines_to_run=tuple(args.engine or DEFAULT_ENGINES),
              repeat=args.repeat)
    if args.queries:
        kw["query_counts"] = tuple(args.queries)
    if args.path_lengths:
        kw["path_lengths"] = tuple(args.path_lengths)
    for r in run(**kw):
        print(json.dumps(r))


if __name__ == "__main__":
    main()
