"""Fig-9 reproduction: filtering throughput, hardware engines vs YFilter.

The paper streams 1–8 MB documents against 16–1024 profiles and reports
MB/s: the FPGA is ~100× the software YFilter and throughput degrades
gently with profile count.  We reproduce the *experiment* on this
container's CPU: the python YFilter baseline vs the JAX engines
(levelwise batched / streaming scan / matmul-kernel path).  Absolute
numbers are CPU-bound; the *shape* of the comparison (orders of magnitude
over the scalar software path, slope vs #profiles) is the reproduced
claim; EXPERIMENTS.md §Paper-Fig9 reports both and the §Roofline section
projects TPU v5e throughput.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core.dictionary import TagDictionary
from repro.core.engines.levelwise import LevelwiseEngine, levelize_batch
from repro.core.engines.streaming import StreamingEngine
from repro.core.engines.yfilter import YFilterEngine
from repro.core.events import event_stream_nbytes
from repro.core.nfa import compile_queries
from repro.data.generator import DTD, gen_corpus, gen_profiles

TEXT_FILL = 8  # emulate element text content in the byte-size accounting


def _mb(docs) -> float:
    return sum(event_stream_nbytes(d, TEXT_FILL) for d in docs) / 1e6


def _time(fn, repeat=3) -> float:
    best = float("inf")
    for _ in range(repeat):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def run(query_counts=(16, 64, 256, 1024), path_lengths=(2, 4, 6),
        n_docs=16, nodes_per_doc=400, seed=0, engines=("yfilter",
                                                       "levelwise",
                                                       "wavefront",
                                                       "streaming")):
    rows = []
    for plen in path_lengths:
        dtd = DTD.generate(n_tags=24, seed=seed)
        docs = gen_corpus(dtd, n_docs=n_docs, nodes_per_doc=nodes_per_doc,
                          seed=seed)
        mb = _mb(docs)
        for nq in query_counts:
            d = TagDictionary()
            dtd.register(d)
            qs = gen_profiles(dtd, n=nq, length=plen, seed=seed + plen)
            nfa = compile_queries(qs, d, shared=True)
            row = {"bench": "fig9_throughput", "path_len": plen,
                   "n_queries": nq, "doc_mb": round(mb, 3),
                   "n_states": nfa.n_states}
            if "yfilter" in engines:
                eng_y = YFilterEngine(nfa)
                t = _time(lambda: eng_y.filter_documents(docs), repeat=1)
                row["yfilter_mb_s"] = round(mb / t, 3)
            if "levelwise" in engines:
                eng_l = LevelwiseEngine(nfa)
                eng_l.filter_documents_batched(docs)  # compile warmup
                t = _time(lambda: eng_l.filter_documents_batched(docs))
                row["levelwise_mb_s"] = round(mb / t, 3)
            if "wavefront" in engines:
                from repro.core.engines.levelwise import WavefrontEngine
                eng_w = WavefrontEngine(nfa, chunk=128)
                eng_w.filter_documents_batched(docs)  # compile warmup
                t = _time(lambda: eng_w.filter_documents_batched(docs))
                row["wavefront_mb_s"] = round(mb / t, 3)
            if "streaming" in engines:
                eng_s = StreamingEngine(nfa, max_depth=32)
                n = max(len(doc) for doc in docs)
                kind = np.stack([doc.padded(n).kind for doc in docs])
                tag = np.stack([doc.padded(n).tag_id for doc in docs])
                eng_s.filter_documents_batched(kind, tag)  # warmup
                t = _time(lambda: eng_s.filter_documents_batched(kind, tag))
                row["streaming_mb_s"] = round(mb / t, 3)
            if "yfilter" in engines and "levelwise" in engines:
                row["speedup_levelwise_vs_yfilter"] = round(
                    row["levelwise_mb_s"] / row["yfilter_mb_s"], 2)
            rows.append(row)
    return rows


if __name__ == "__main__":
    import json
    for r in run():
        print(json.dumps(r))
