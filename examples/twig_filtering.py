"""Twig-pattern pub-sub — the paper's §5 future work, working.

Twig profiles (tree-shaped patterns with branch predicates) are filtered
with the paper's own sketched architecture: decompose into root-to-leaf
paths → all paths share ONE prefix-tree NFA (so the twig trunk is
evaluated once) → survivors verified exactly (false-positive
elimination).  Reports the stage-2 work so the decomposition's
false-positive rate — the cost the paper worried about — is visible.

Run:  PYTHONPATH=src python examples/twig_filtering.py
"""
import time

import numpy as np

from repro.core.dictionary import TagDictionary
from repro.core.twig import TwigFilter, decompose, parse_twig
from repro.data.generator import DTD, gen_corpus

dtd = DTD.generate(n_tags=24, seed=3)
d = TagDictionary()
dtd.register(d)

# twig subscriptions over the DTD's tag space
rng = np.random.default_rng(0)
names = dtd.tag_names
twigs = []
for i in range(48):
    a, b, c = rng.choice(24, 3, replace=False)
    kind = i % 3
    if kind == 0:
        twigs.append(f"{names[a]}[//{names[b]}][//{names[c]}]")
    elif kind == 1:
        twigs.append(f"{names[a]}[{names[b]}]//{names[c]}")
    else:
        twigs.append(f"{names[a]}//{names[b]}")   # linear control group

n_paths = sum(len(decompose(parse_twig(t))) for t in twigs)
docs = gen_corpus(dtd, n_docs=24, nodes_per_doc=300, seed=7)
f = TwigFilter(twigs, d, engine="levelwise")
print(f"{len(twigs)} twig profiles → {n_paths} decomposed paths → "
      f"{f.nfa.n_states} shared NFA states")

t0 = time.perf_counter()
n_match = 0
for doc in docs:
    res = f.filter_document(doc)
    n_match += int(res.matched.sum())
dt = time.perf_counter() - t0
checks, rejects = f.stats["stage2_checks"], f.stats["stage2_rejects"]
print(f"{len(docs)} documents in {dt:.2f}s: {n_match} twig deliveries")
print(f"stage-2 (join/verify): {checks} candidate checks, "
      f"{rejects} false positives eliminated "
      f"({100*rejects/max(checks,1):.0f}% of candidates — the paper's "
      f"§5 concern, measured)")
