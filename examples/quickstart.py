"""Quickstart: filter an XML document stream against XPath profiles.

The paper's core loop in ~40 lines of public API:
  parse profiles → compile the shared NFA → filter a document stream →
  report matching profiles + match locations.

Engines are constructed through the registry (`repro.core.engines`) —
every engine consumes the same `EventBatch` and returns the same
`FilterResult`, so comparing them is a loop over names.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import engines
from repro.core.dictionary import TagDictionary
from repro.core.events import EventBatch, EventStream, OPEN, CLOSE, encode_bytes
from repro.core.nfa import compile_queries
from repro.core.xpath import parse
from repro.kernels.ops import decode_document

# 1. user profiles (subscriptions) — the paper's §3 examples
PROFILES = ["a0//b0", "a0/b0", "/a0//c0", "//b0/c0", "a0//b0//c0"]

# 2. a document:  <a0><x><b0><c0/></b0></x></a0>
tags = {"a0": 0, "x": 1, "b0": 2, "c0": 3}
doc = EventStream(
    np.array([OPEN, OPEN, OPEN, OPEN, CLOSE, CLOSE, CLOSE, CLOSE], np.int8),
    np.array([0, 1, 2, 3, 3, 2, 1, 0], np.int32))

# 3. compile profiles → prefix-shared NFA (dictionary replacement included)
dictionary = TagDictionary.build(tags)
queries = [parse(p) for p in PROFILES]
nfa = compile_queries(queries, dictionary, shared=True)
print(f"{len(queries)} profiles → {nfa.n_states} NFA states "
      f"(prefix-shared, §3.3)")

# 4. round-trip the paper's byte format through the pre-decode kernel
buf = encode_bytes(doc, text_fill=3)
doc2 = decode_document(buf, dictionary)
assert np.array_equal(doc2.tag_id, doc.tag_id)
print(f"byte stream: {len(buf)} bytes → {len(doc2)} events "
      f"(§3.4 pre-decode kernel)")

# 5. filter with every registered engine through the one batched API
batch = EventBatch.from_streams([doc])
for name in ("streaming", "levelwise", "yfilter"):
    eng = engines.create(name, nfa, dictionary=dictionary)
    res = eng.filter_batch(batch)[0]
    hits = ", ".join(f"{PROFILES[q]} @ event {res.first_event[q]}"
                     for q in res.matching_queries())
    print(f"{name:>12}: {hits}")
