"""Quickstart: filter an XML document stream against XPath profiles.

The paper's core loop in ~40 lines of public API:
  parse profiles → compile the shared NFA → filter a document stream →
  report matching profiles + match locations.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core.dictionary import TagDictionary
from repro.core.engines.levelwise import LevelwiseEngine
from repro.core.engines.streaming import StreamingEngine
from repro.core.events import EventStream, OPEN, CLOSE, encode_bytes
from repro.core.nfa import compile_queries
from repro.core.xpath import parse
from repro.kernels.ops import decode_document

# 1. user profiles (subscriptions) — the paper's §3 examples
PROFILES = ["a0//b0", "a0/b0", "/a0//c0", "//b0/c0", "a0//b0//c0"]

# 2. a document:  <a0><x><b0><c0/></b0></x></a0>
tags = {"a0": 0, "x": 1, "b0": 2, "c0": 3}
doc = EventStream(
    np.array([OPEN, OPEN, OPEN, OPEN, CLOSE, CLOSE, CLOSE, CLOSE], np.int8),
    np.array([0, 1, 2, 3, 3, 2, 1, 0], np.int32))

# 3. compile profiles → prefix-shared NFA (dictionary replacement included)
dictionary = TagDictionary.build(tags)
queries = [parse(p) for p in PROFILES]
nfa = compile_queries(queries, dictionary, shared=True)
print(f"{len(queries)} profiles → {nfa.n_states} NFA states "
      f"(prefix-shared, §3.3)")

# 4. round-trip the paper's byte format through the pre-decode kernel
buf = encode_bytes(doc, text_fill=3)
doc2 = decode_document(buf, dictionary)
assert np.array_equal(doc2.tag_id, doc.tag_id)
print(f"byte stream: {len(buf)} bytes → {len(doc2)} events "
      f"(§3.4 pre-decode kernel)")

# 5. filter with both engines
for Engine in (StreamingEngine, LevelwiseEngine):
    res = Engine(nfa).filter_document(doc)
    hits = ", ".join(f"{PROFILES[q]} @ event {res.first_event[q]}"
                     for q in res.matching_queries())
    print(f"{Engine.__name__:>16}: {hits}")
