"""Pub-sub at the paper's scale: 1024 profiles × a stream of documents.

Reproduces the experimental setup of §4 (PathGenerator-style profiles over
a DTD, ToXGene-style documents) and reports throughput for the software
baseline (YFilter) vs the hardware-shaped engines — the Fig-9 experiment
as a runnable script.  All engines come from the registry and run the
same `EventBatch` through the same `filter_batch` API.

Run:  PYTHONPATH=src python examples/pubsub_filtering.py [--queries 256]
"""
import argparse
import time

import numpy as np

from repro.core import engines
from repro.core.dictionary import TagDictionary
from repro.core.events import EventBatch, encode_bytes
from repro.core.nfa import compile_queries
from repro.data.filter_stage import FilterStage
from repro.data.generator import DTD, gen_corpus, gen_profiles


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--queries", type=int, default=256)
    ap.add_argument("--docs", type=int, default=16)
    ap.add_argument("--nodes", type=int, default=500)
    ap.add_argument("--engines", nargs="+",
                    default=["yfilter", "streaming", "levelwise"],
                    choices=list(engines.names()))
    args = ap.parse_args()

    dtd = DTD.generate(n_tags=24, seed=0)
    d = TagDictionary()
    dtd.register(d)
    if "matscan" in args.engines:
        # matscan rejects child axes and wildcards — keep the shared
        # workload inside its class so every selected engine runs it
        print("(matscan selected: descendant-only profiles, no wildcards)")
        profiles = gen_profiles(dtd, n=args.queries, length=4, p_desc=1.0,
                                p_wild=0.0, seed=0)
    else:
        profiles = gen_profiles(dtd, n=args.queries, length=4, seed=0)
    docs = gen_corpus(dtd, n_docs=args.docs, nodes_per_doc=args.nodes,
                      seed=0)
    batch = EventBatch.from_streams(docs, bucket=128)
    mb = float(batch.nbytes(text_fill=8).sum()) / 1e6
    nfa = compile_queries(profiles, d, shared=True)
    print(f"{args.queries} profiles → {nfa.n_states} states; "
          f"{args.docs} docs = {mb:.2f} MB")

    results = {}
    baseline_t = None
    for name in args.engines:
        eng = engines.create(name, nfa, dictionary=d)
        eng.filter_batch(batch)  # warmup/compile
        t0 = time.perf_counter()
        results[name] = eng.filter_batch(batch)
        dt = time.perf_counter() - t0
        speed = f" ({baseline_t/dt:.1f}x)" if baseline_t else ""
        if baseline_t is None:
            baseline_t = dt
        print(f"{name:>12}: {mb/dt:8.2f} MB/s, "
              f"{args.docs/dt:8.1f} docs/s{speed}")

    # matscan's flat-regex semantics is approximate on documents with
    # nested same-tag occurrences (paper §3.2) — exclude it from the
    # strict agreement check on generated (recursive-DTD) documents
    exact = {n: r for n, r in results.items() if n != "matscan"}
    if len(exact) > 1:
        names_ = list(exact)
        ref = exact[names_[0]]
        for name in names_[1:]:
            np.testing.assert_array_equal(exact[name].matched, ref.matched)
        print(f"engine agreement ({', '.join(names_)}): OK")

    # routing stage (pub-sub delivery)
    stage = FilterStage(profiles, d, n_shards=4, engine="levelwise")
    fanout = sum(len(b) for b in stage.route(docs))
    tp = stage.throughput()
    print(f"routing: {fanout} deliveries to 4 subscriber shards; "
          f"selectivity {tp['selectivity']:.3f} "
          f"({tp['docs_per_s']:.0f} docs/s)")

    # device ingest: the same delivery from RAW WIRE BYTES — parse and
    # filter both run on device (the paper's same-chip dataflow, §1)
    payloads = [encode_bytes(doc, text_fill=8) for doc in docs]
    stage_b = FilterStage(profiles, d, n_shards=4, engine="streaming")
    fanout_b = sum(len(b) for b in stage_b.route_bytes(payloads))
    tp_b = stage_b.throughput()
    assert fanout_b == fanout, "byte ingest must route identically"
    print(f"routing from raw bytes (device parse): {fanout_b} deliveries; "
          f"{tp_b['mb_per_s']:.2f} MB/s end-to-end")


if __name__ == "__main__":
    main()
