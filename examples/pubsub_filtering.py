"""Pub-sub at the paper's scale: 1024 profiles × a stream of documents.

Reproduces the experimental setup of §4 (PathGenerator-style profiles over
a DTD, ToXGene-style documents) and reports throughput for the software
baseline (YFilter) vs the hardware-shaped engines — the Fig-9 experiment
as a runnable script.

Run:  PYTHONPATH=src python examples/pubsub_filtering.py [--queries 256]
"""
import argparse
import time

import numpy as np

from repro.core.dictionary import TagDictionary
from repro.core.engines.levelwise import LevelwiseEngine
from repro.core.engines.streaming import StreamingEngine
from repro.core.engines.yfilter import YFilterEngine
from repro.core.events import event_stream_nbytes
from repro.core.nfa import compile_queries
from repro.data.filter_stage import FilterStage
from repro.data.generator import DTD, gen_corpus, gen_profiles


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--queries", type=int, default=256)
    ap.add_argument("--docs", type=int, default=16)
    ap.add_argument("--nodes", type=int, default=500)
    args = ap.parse_args()

    dtd = DTD.generate(n_tags=24, seed=0)
    d = TagDictionary()
    dtd.register(d)
    profiles = gen_profiles(dtd, n=args.queries, length=4, seed=0)
    docs = gen_corpus(dtd, n_docs=args.docs, nodes_per_doc=args.nodes,
                      seed=0)
    mb = sum(event_stream_nbytes(doc, 8) for doc in docs) / 1e6
    nfa = compile_queries(profiles, d, shared=True)
    print(f"{args.queries} profiles → {nfa.n_states} states; "
          f"{args.docs} docs = {mb:.2f} MB")

    y = YFilterEngine(nfa)
    t0 = time.perf_counter()
    results = y.filter_documents(docs)
    ty = time.perf_counter() - t0
    print(f"YFilter (software baseline): {mb/ty:6.2f} MB/s")

    s = StreamingEngine(nfa, max_depth=32)
    n = max(len(doc) for doc in docs)
    kind = np.stack([doc.padded(n).kind for doc in docs])
    tag = np.stack([doc.padded(n).tag_id for doc in docs])
    s.filter_documents_batched(kind, tag)  # warmup/compile
    t0 = time.perf_counter()
    sres = s.filter_documents_batched(kind, tag)
    ts = time.perf_counter() - t0
    print(f"Streaming engine (paper-faithful datapath): {mb/ts:6.2f} MB/s "
          f"({ty/ts:.1f}x)")

    for i, r in enumerate(results):
        np.testing.assert_array_equal(r.matched, sres.matched[i])
    print("engine agreement: OK")

    # routing stage (pub-sub delivery)
    stage = FilterStage(profiles, d, n_shards=4, engine="levelwise")
    fanout = sum(len(batch) for batch in stage.route(docs))
    print(f"routing: {fanout} deliveries to 4 subscriber shards; "
          f"selectivity {stage.selectivity(docs):.3f}")


if __name__ == "__main__":
    main()
