"""End-to-end driver: train a ~100M-param LM for a few hundred steps on
the XML-filtered byte stream (pub-sub ingest → tokenize → train), with
checkpoint/restart enabled.

This is `repro.launch.train` parameterized to ~100M: qwen3-family reduced
to d_model=512, 12 layers, byte vocab.

Run:  PYTHONPATH=src python examples/train_lm.py [--steps 300]
"""
import argparse
import sys

from repro.launch import train as train_mod


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_example_ckpt")
    args = ap.parse_args()
    sys.argv = [
        "train", "--arch", "qwen3-0.6b", "--reduced",
        "--d-model", "512", "--layers", "12",
        "--steps", str(args.steps), "--batch", "8", "--seq-len", "128",
        "--data-filter", "--ckpt-dir", args.ckpt_dir,
    ]
    train_mod.main()


if __name__ == "__main__":
    main()
