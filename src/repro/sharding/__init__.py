"""Distribution: logical-axis sharding rules, mesh helpers, context."""
from .compat import shard_map_compat  # noqa: F401
from .ctx import constrain, axis_size, mesh_context  # noqa: F401
