"""Distribution: logical-axis sharding rules, mesh helpers, context."""
from .ctx import constrain, axis_size, mesh_context  # noqa: F401
