"""Logical-axis sharding rules: params, optimizer state, caches, batches.

Strategy (1000+ node posture, see DESIGN.md §5):

* **DP**  — batch over ``("pod", "data")``; gradients reduce hierarchically
  (ICI within a pod, DCN across pods).
* **FSDP** — parameters and optimizer state additionally shard one
  non-TP dimension over ``"data"`` (ZeRO-3-style; XLA inserts per-layer
  all-gathers inside the scan).  Pod-replicated: cross-pod traffic stays
  gradient-only.
* **TP**  — heads / d_ff / experts / vocab over ``"model"`` (head counts
  pre-padded by the config geometry, vocab padded to 128).
* **EP**  — MoE expert dim over ``"model"``; dispatch buffers shard
  (expert → "model", capacity → "data").

Specs are *preferences*: :func:`sanitize` drops any axis that does not
divide the concrete dimension, so odd shapes (kv=8 on a 16-way axis,
group dims) degrade to replication instead of failing to lower.
"""
from __future__ import annotations

from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..models.config import ModelConfig

# preferred spec for the *trailing* dims of each named parameter
_PARAM_RULES: list[tuple[str, tuple]] = [
    ("embed", ("model", "data")),
    ("unembed", ("model", "data")),
    ("patch_proj", ("data", "model")),
    # attention
    ("wq", ("data", "model", None)),
    ("wk", ("data", "model", None)),
    ("wv", ("data", "model", None)),
    ("wo", ("model", None, "data")),
    ("bq", ("model", None)),
    ("bk", ("model", None)),
    ("bv", ("model", None)),
    # MLA
    ("w_dq", ("data", "model")),
    ("w_uq", ("data", "model", None)),
    ("w_dkv", ("data", None)),
    ("w_uk", ("data", "model", None)),
    ("w_uv", ("data", "model", None)),
    # MLP / MoE
    ("wi", ("data", "model")),          # overridden for experts below
    ("router", ("data", "model")),
    # mamba2
    ("zx_proj", ("data", "model", None)),
    ("b_proj", ("data", None)),
    ("c_proj", ("data", None)),
    ("dt_proj", ("data", "model")),
    ("conv_x", (None, "model")),
    ("conv_bc", (None, None)),
    ("conv_b_x", ("model",)),
    ("conv_b_bc", (None,)),
    ("a_log", ("model",)),
    ("d_skip", ("model",)),
    ("dt_bias", ("model",)),
    ("out_proj", ("model", "data")),
    # mtp
    ("proj", ("data", "model")),
    ("scale", (None,)),
]

_EXPERT_RULES = {
    "wi": ("model", "data", None),      # (E, d, 2f)
    "wo": ("model", None, "data"),      # (E, f, d)
}


def _rule_for(path: tuple, shape: tuple) -> tuple:
    names = [getattr(k, "key", str(k)) for k in path]
    leaf = names[-1]
    if leaf in _EXPERT_RULES and len(shape) >= 3 and ("moe" in names):
        return _EXPERT_RULES[leaf]
    for key, spec in _PARAM_RULES:
        if leaf == key:
            return spec
    return ()  # replicate


def sanitize(spec: tuple, shape: tuple, mesh: Mesh) -> P:
    """Pad to rank, drop axes that don't divide the dim or the mesh."""
    spec = ((None,) * (len(shape) - len(spec))) + tuple(spec)
    spec = spec[-len(shape):] if shape else ()
    out = []
    for dim, ax in zip(shape, spec):
        if ax is None:
            out.append(None)
            continue
        axes = ax if isinstance(ax, tuple) else (ax,)
        size = int(np.prod([mesh.shape[a] for a in axes
                            if a in mesh.axis_names]))
        present = all(a in mesh.axis_names for a in axes)
        out.append(ax if (present and size > 0 and dim % size == 0) else None)
    return P(*out)


def param_specs(cfg: ModelConfig, params_shape: Any, mesh: Mesh) -> Any:
    """PartitionSpec pytree matching the params pytree."""
    def one(path, leaf):
        shape = leaf.shape
        return sanitize(_rule_for(path, shape), shape, mesh)

    return jax.tree_util.tree_map_with_path(one, params_shape)


def param_shardings(cfg: ModelConfig, params_shape: Any, mesh: Mesh) -> Any:
    return jax.tree.map(lambda s: NamedSharding(mesh, s),
                        param_specs(cfg, params_shape, mesh))


# ----------------------------------------------------------------- batches
def _dp(mesh: Mesh):
    got = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    return got if got else None


def batch_specs(cfg: ModelConfig, batch_shape: dict, mesh: Mesh) -> dict:
    out = {}
    for k, v in batch_shape.items():
        spec = (_dp(mesh),) + (None,) * (len(v.shape) - 1)
        out[k] = sanitize(spec, v.shape, mesh)
    return out


# ------------------------------------------------------------------ caches
def cache_specs(cfg: ModelConfig, cache_shape: Any, mesh: Mesh) -> Any:
    """KV/SSM cache specs: (layers, B, T, heads/rank, ...).

    Batch shards over DP when divisible; otherwise (long-context B=1)
    the *time* dim shards over "data" — context-parallel cache layout.
    """
    dp = _dp(mesh)

    def one(path, leaf):
        names = [getattr(k, "key", str(k)) for k in path]
        leaf_name = names[-1]
        shape = leaf.shape
        if leaf_name == "enc_out":
            return sanitize((dp, None, None), shape, mesh)
        dp_size = int(np.prod([mesh.shape[a] for a in (dp or ())]))
        batch_ok = len(shape) >= 2 and shape[1] % max(dp_size, 1) == 0
        if leaf_name in ("k", "v"):          # (L, B, T, kv, dh)
            t_ax = None if batch_ok else "data"
            return sanitize((None, dp if batch_ok else None, t_ax,
                             "model", None), shape, mesh)
        if leaf_name in ("c_kv", "k_rope"):  # (L, B, T, rank)
            t_ax = None if batch_ok else "data"
            return sanitize((None, dp if batch_ok else None, t_ax,
                             "model"), shape, mesh)
        if leaf_name == "ssd":               # (L, B, H, P, N)
            return sanitize((None, dp if batch_ok else None, "model",
                             None, None), shape, mesh)
        if leaf_name in ("conv_x", "conv_bc"):
            return sanitize((None, dp if batch_ok else None, None,
                             "model"), shape, mesh)
        return sanitize((None,) * len(shape), shape, mesh)

    return jax.tree_util.tree_map_with_path(one, cache_shape)


def logits_spec(mesh: Mesh) -> P:
    return P(_dp(mesh), None, "model")
