"""Version-compat wrappers over moving jax APIs."""
from __future__ import annotations

import jax


def shard_map_compat(f, mesh, in_specs, out_specs):
    """``jax.shard_map`` with replication checking off, across jax versions.

    Newer jax exposes ``jax.shard_map`` taking ``check_vma``; some
    releases expose ``jax.shard_map`` still taking ``check_rep``; older
    ones only have the experimental module.  Probe the kwarg instead of
    trusting the attribute's presence.
    """
    sm = getattr(jax, "shard_map", None)
    if sm is None:
        from jax.experimental.shard_map import shard_map as sm
    kwargs = dict(mesh=mesh, in_specs=in_specs, out_specs=out_specs)
    try:
        return sm(f, **kwargs, check_vma=False)
    except TypeError:
        return sm(f, **kwargs, check_rep=False)
