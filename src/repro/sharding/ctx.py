"""Mesh context for activation sharding constraints.

Model code calls ``constrain(x, ("data", None, "model"))`` at key points
(post-attention hidden, MoE dispatch buffers, logits).  Outside a mesh
context (unit tests, single-device smoke runs) it is a no-op; inside
``mesh_context(mesh)`` it resolves logical axis names against the active
mesh and applies ``jax.lax.with_sharding_constraint``.

Axis-name conventions (see launch/mesh.py):
  "dp"    → ("pod", "data") when the pod axis exists, else ("data",)
  "data"  / "model" / "pod" → themselves, if present in the mesh
"""
from __future__ import annotations

import contextlib
import threading

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_state = threading.local()


def _mesh() -> Mesh | None:
    return getattr(_state, "mesh", None)


@contextlib.contextmanager
def mesh_context(mesh: Mesh):
    prev = getattr(_state, "mesh", None)
    _state.mesh = mesh
    try:
        with mesh:
            yield mesh
    finally:
        _state.mesh = prev


def _resolve(axis, mesh: Mesh):
    names = mesh.axis_names
    if axis is None:
        return None
    if axis == "dp":
        got = tuple(a for a in ("pod", "data") if a in names)
        return got if got else None
    if isinstance(axis, (tuple, list)):
        got = tuple(a for a in axis if a in names)
        return got if got else None
    return axis if axis in names else None


def spec(*axes) -> P:
    mesh = _mesh()
    if mesh is None:
        return P()
    return P(*(_resolve(a, mesh) for a in axes))


def _axis_div(mesh: Mesh, axis) -> int:
    axes = axis if isinstance(axis, (tuple, list)) else (axis,)
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def constrain(x: jax.Array, axes) -> jax.Array:
    """Apply a sharding constraint if a mesh context is active.

    Rank-adaptive: specs are written for the canonical (B, L, D) layout;
    flattened (N, D) values keep the batch and trailing axes.  Axes that
    do not divide the concrete dim are dropped (replicated) rather than
    failing to lower.
    """
    mesh = _mesh()
    if mesh is None:
        return x
    axes = tuple(axes)
    rank = x.ndim
    if len(axes) != rank:
        if rank >= 2:
            axes = tuple(axes[:rank - 1]) + (axes[-1],) \
                if len(axes) > rank else \
                axes[:-1] + (None,) * (rank - len(axes)) + (axes[-1],)
        else:
            axes = axes[-rank:]
    resolved = []
    for dim, a in zip(x.shape, axes):
        r = _resolve(a, mesh)
        if r is not None and dim % _axis_div(mesh, r) != 0:
            r = None
        resolved.append(r)
    s = P(*resolved)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, s))


def axis_size(name: str, default: int = 1) -> int:
    mesh = _mesh()
    if mesh is None:
        return default
    if name == "dp":
        return (axis_size("pod") * axis_size("data"))
    try:
        return mesh.shape[name]
    except KeyError:
        return default
