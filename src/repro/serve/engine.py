"""Batched serving engine: continuous prefill→decode over request batches.

Minimal but real: fixed-batch slots, greedy sampling, per-slot stop
lengths.  ``serve_step`` (the function the decode dry-run lowers) is one
decode iteration for the whole batch.  Request *routing* by XML profile
(the paper's pub-sub use case) lives in launch/serve.py on top of this.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from ..models import transformer as T
from ..models.config import ModelConfig


@dataclass
class ServeEngine:
    cfg: ModelConfig
    params: Any
    batch: int
    max_len: int
    cache_dtype: Any = jnp.bfloat16

    def __post_init__(self) -> None:
        self._prefill = jax.jit(
            lambda p, b, c: T.prefill(self.cfg, p, b, c))
        self._decode = jax.jit(
            lambda p, t, c, pos: T.decode_step(self.cfg, p, t, c, pos))

    def generate(self, batch: dict, n_new: int,
                 greedy: bool = True) -> np.ndarray:
        """Prefill `batch["tokens"]` then decode n_new tokens greedily."""
        caches = T.init_cache(self.cfg, self.batch,
                              self.max_len, dtype=self.cache_dtype)
        logits, caches = self._prefill(self.params, batch, caches)
        prompt_len = batch["tokens"].shape[1]
        offset = (self.cfg.frontend_len
                  if self.cfg.family == "vlm" else 0)
        out = []
        tok = jnp.argmax(logits[:, -1, :self.cfg.vocab], axis=-1)[:, None]
        out.append(np.asarray(tok))
        for i in range(n_new - 1):
            pos = jnp.int32(offset + prompt_len + i)
            logits, caches = self._decode(self.params, tok.astype(jnp.int32),
                                          caches, pos)
            tok = jnp.argmax(logits[:, -1, :self.cfg.vocab], axis=-1)[:, None]
            out.append(np.asarray(tok))
        return np.concatenate(out, axis=1)
