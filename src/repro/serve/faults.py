"""Seeded fault injection for the serve loop — the chaos harness.

Reproducible failure drills for every containment path the loop claims
(:mod:`repro.serve.loop`): a :class:`FaultPlan` names *which* request
indices are poisoned and *which* batches/ops misbehave, a
:class:`FaultInjector` wires the non-document faults into a
:class:`~repro.data.filter_stage.FilterStage` (wrapping its batch entry
point and its engine's ``plan_part``), and :func:`run_chaos_trace`
drives a full arrival trace through the loop with the faults active and
checks the loop's promises afterwards:

* the loop *finishes* (no wedge, no thread death);
* accounting closes: ``arrived == completed + shed + failed +
  quarantined``;
* the dead-letter buffer lists exactly the injected poison documents,
  each with a typed error;
* every healthy document's verdict is bit-identical to a fault-free
  reference run (quarantine never corrupts co-batched requests);
* an injected one-shot worker fault is absorbed by the whole-batch
  retry (no quarantine);
* a forced :class:`~repro.kernels.blocks.PadOverflow` during a live
  subscribe exercises the full-replan path inside a shadow swap.

Fault taxonomy (each exercises a different containment layer):

``malformed`` / ``overdepth``
    byte-level poison caught by pre-admission validation
    (:func:`~repro.core.events.validate_payload`) — rejected at
    ``submit()``, never reaches a kernel.
``kernel``
    payload that *passes* validation but makes the device call raise an
    untyped error — isolated by retry + bisection, quarantined as
    :class:`~repro.core.events.KernelFault`.
``worker_fault_batches``
    one-shot transient worker exceptions — absorbed by the retry.
``slow_batches``
    injected service-time spikes (p99 visibility, no failure).
``pad_overflow_adds``
    forced ``PadOverflow`` on the next ``plan_part`` call of the n-th
    live subscribe — the shadow build takes the merge-pads full-replan
    path and still commits.

Run as a module for the CI chaos artifact::

    python -m repro.serve.faults --requests 48 --out chaos.json
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Any

import numpy as np

from ..core.dictionary import TagDictionary
from ..core.events import encode_bytes
from ..data.filter_stage import TEXT_FILL, FilterStage
from ..data.generator import DTD, gen_corpus, gen_profiles
from .loop import ServeLoop, make_arrivals


@dataclass(frozen=True)
class FaultPlan:
    """What to break, where — fully determined by its fields (seeded
    workload + fixed indices = reproducible chaos)."""

    #: request indices replaced by an unbalanced payload (pre-admission)
    malformed: tuple[int, ...] = ()
    #: request indices replaced by an over-depth payload (pre-admission)
    overdepth: tuple[int, ...] = ()
    #: request indices whose payload poisons the device call (bisection)
    kernel: tuple[int, ...] = ()
    #: 1-based batch-call ordinals that raise once then succeed on retry
    worker_fault_batches: tuple[int, ...] = ()
    #: 1-based batch-call ordinals delayed by ``slow_ms``
    slow_batches: tuple[int, ...] = ()
    slow_ms: float = 25.0
    #: 1-based live-subscribe ordinals whose first ``plan_part`` call
    #: raises ``PadOverflow`` (forcing the full-replan path)
    pad_overflow_adds: tuple[int, ...] = ()

    def poison_indices(self) -> tuple[int, ...]:
        return tuple(sorted({*self.malformed, *self.overdepth,
                             *self.kernel}))


#: the default CI drill: every fault class at least once.  The armed
#: pad overflow is the SECOND add — the first add naturally repads to
#: the next query bucket, so the second takes the fits-old-pads fast
#: path, which is the injection's (guarded) call site.
DEFAULT_PLAN = FaultPlan(malformed=(3,), overdepth=(11,), kernel=(17,),
                         worker_fault_batches=(2,), slow_batches=(4,),
                         pad_overflow_adds=(2,))


class FaultInjector:
    """Install a :class:`FaultPlan`'s non-document faults on a stage.

    Wraps ``stage._filter_bytebatch`` (worker faults, slow batches,
    kernel-poison payload detection) and the engine's ``plan_part``
    (armed ``PadOverflow``).  Document-level poisons are substitutions
    in the payload list — see :func:`poison_payloads` — not wrappers.
    """

    def __init__(self, stage: FilterStage, plan: FaultPlan,
                 kernel_payloads: set[bytes]) -> None:
        self.stage = stage
        self.plan = plan
        self.kernel_payloads = kernel_payloads
        self.batch_calls = 0
        self.worker_faults_left = set(plan.worker_fault_batches)
        self.slow_left = set(plan.slow_batches)
        self.pad_overflow_armed = 0
        self.pad_overflows_forced = 0
        self._orig_filter = stage._filter_bytebatch
        self._orig_plan_part = stage._eng.plan_part
        stage._filter_bytebatch = self._filter          # type: ignore
        stage._eng.plan_part = self._plan_part          # type: ignore

    def _filter(self, bufs, record: bool = True, epoch=None):
        self.batch_calls += 1
        n = self.batch_calls
        if n in self.worker_faults_left:
            self.worker_faults_left.discard(n)
            raise RuntimeError(f"injected one-shot worker fault "
                               f"(batch call {n})")
        if any(b in self.kernel_payloads for b in bufs):
            # untyped on purpose: the loop must *bisect* to find it
            raise RuntimeError("injected kernel fault (poison document)")
        if n in self.slow_left:
            self.slow_left.discard(n)
            time.sleep(self.plan.slow_ms / 1e3)
        return self._orig_filter(bufs, record=record, epoch=epoch)

    def _plan_part(self, nfa, pads=None):
        if self.pad_overflow_armed > 0 and pads is not None:
            # fire only at the guarded fits-old-pads attempt (its pads
            # argument is the live plan's own pad dict) — a raise inside
            # the merge-pads full replan would be a *new* failure mode,
            # not the overflow-at-old-buckets one this drills
            live = getattr(self.stage, "sharded_", None)
            if live is not None and dict(pads) == dict(live.pads):
                self.pad_overflow_armed -= 1
                self.pad_overflows_forced += 1
                from ..kernels.blocks import PadOverflow
                raise PadOverflow(
                    "injected pad overflow (forcing full replan)")
        return self._orig_plan_part(nfa, pads)

    def arm_pad_overflow(self) -> None:
        """The next fits-old-pads ``plan_part`` call raises
        ``PadOverflow`` (once), pushing the add onto the merge-pads full
        replan — which must still succeed and commit."""
        self.pad_overflow_armed += 1

    def remove(self) -> None:
        self.stage._filter_bytebatch = self._orig_filter   # type: ignore
        self.stage._eng.plan_part = self._orig_plan_part   # type: ignore


# ------------------------------------------------------------- workload
def _malformed_payload(d: TagDictionary) -> bytes:
    return d.open_bytes(0)                      # one unclosed element


def _overdepth_payload(d: TagDictionary, depth: int = 80) -> bytes:
    return (b"".join(d.open_bytes(0) for _ in range(depth))
            + b"".join(d.close_bytes(0) for _ in range(depth)))


def chaos_workload(n_requests: int, plan: FaultPlan, *,
                   n_queries: int = 16, seed: int = 0):
    """Seeded corpus with the plan's poisons substituted in.

    Returns ``(profiles, dictionary, dtd, payloads, kernel_payloads)``
    — ``kernel_payloads`` is the marker set the injector detects (valid
    bytes that pass pre-admission but "fault" on device).
    """
    dtd = DTD.generate(n_tags=24, seed=seed)
    d = TagDictionary()
    dtd.register(d)
    profiles = gen_profiles(dtd, n=n_queries, length=3, seed=seed)
    docs = gen_corpus(dtd, n_docs=n_requests, nodes_per_doc=40, seed=1)
    payloads = [encode_bytes(x, text_fill=TEXT_FILL) for x in docs]
    kernel_payloads: set[bytes] = set()
    for i in plan.malformed:
        payloads[i] = _malformed_payload(d)
    for i in plan.overdepth:
        payloads[i] = _overdepth_payload(d)
    for i in plan.kernel:
        # tag the payload with a unique valid suffix document so it
        # stays well-formed (passes validation) yet is recognizable
        marked = payloads[i] + d.open_bytes(1) + d.close_bytes(1)
        payloads[i] = marked
        kernel_payloads.add(marked)
    return profiles, d, dtd, payloads, kernel_payloads


# ----------------------------------------------------------- chaos trace
def run_chaos_trace(n_requests: int = 48, *, plan: FaultPlan = DEFAULT_PLAN,
                    engine: str = "streaming", n_queries: int = 16,
                    max_batch: int = 4, deadline_ms: float = 10.0,
                    queue_cap: int = 256, rate_hz: float = 400.0,
                    seed: int = 0, stage_opts: dict | None = None) -> dict:
    """One seeded arrival trace with every fault class active.

    Runs the chaos loop and a fault-free reference loop over the same
    healthy payloads, then verifies the containment contract (see
    module docstring).  Returns the report dict the CI chaos step
    writes as its artifact; ``report["ok"]`` is the overall verdict and
    ``report["checks"]`` itemizes each invariant.
    """
    stage_opts = dict(stage_opts or {})
    # the forced-PadOverflow drill needs the sharded add path (plan_part
    # is only on the sharded subscribe's call chain)
    stage_opts.setdefault("query_shards", 2)
    profiles, d, dtd, payloads, kernel_payloads = chaos_workload(
        n_requests, plan, n_queries=n_queries, seed=seed)
    poison = set(plan.poison_indices())
    healthy = [i for i in range(n_requests) if i not in poison]

    def build_stage():
        return FilterStage(profiles, d, n_shards=2, engine=engine,
                           keep_unmatched=True, batch_size=max_batch,
                           **stage_opts)

    def verdict(t):
        # original-profile gids only: the mid-trace churn legitimately
        # adds matches for gids >= n_queries, which are not part of the
        # "healthy verdicts are unchanged by faults" contract
        gids: set[int] = set()
        for rd in t.routed or []:
            gids.update(int(g) for g in np.asarray(rd.matched_profiles))
        return frozenset(g for g in gids if g < n_queries)

    # ---- reference: the same healthy payloads, no faults ----
    ref_stage = build_stage()
    ref_loop = ServeLoop(ref_stage, max_batch=max_batch,
                         deadline_ms=deadline_ms, queue_cap=queue_cap)
    with ref_loop:
        ref_tickets = [ref_loop.submit(payloads[i]) for i in healthy]
    reference = {i: verdict(t) for i, t in zip(healthy, ref_tickets)}

    # ---- chaos: all payloads, injector armed, churn mid-trace ----
    stage = build_stage()
    injector = FaultInjector(stage, plan, kernel_payloads)
    loop = ServeLoop(stage, max_batch=max_batch, deadline_ms=deadline_ms,
                     queue_cap=queue_cap)
    arrivals = make_arrivals("poisson", n_requests, rate_hz=rate_hz,
                             seed=seed)
    churn = gen_profiles(dtd, n=max(len(plan.pad_overflow_adds), 1) + 1,
                         length=3, seed=97)
    swap_tickets = []
    mid = n_requests // 2

    # submit on the trace manually so we can interleave churn mid-trace
    t0 = time.monotonic()
    tickets = []
    for k, (p, due) in enumerate(zip(payloads, arrivals)):
        lag = due - (time.monotonic() - t0)
        if lag > 0:
            time.sleep(lag)
        tickets.append(loop.submit(p))
        if k == mid:
            for j, q in enumerate(churn, start=1):
                if j in plan.pad_overflow_adds:
                    injector.arm_pad_overflow()
                swap_tickets.append(loop.subscribe(q))
    for tk in swap_tickets:
        tk.done.wait(timeout=120)
    loop.close()
    injector.remove()
    slo = loop.slo_summary()

    # ---- the containment contract ----
    dead = [{"seq": r["seq"], "error": r["error"], "message": r["message"]}
            for r in loop.dead_letter]
    dead_payloads = [r["payload"] for r in loop.dead_letter]
    want_dead = sorted(payloads[i] for i in poison)
    checks = {
        "finished": all(t.done.is_set() for t in tickets),
        "accounting_closed": slo["arrived"] == (
            slo["completed"] + slo["shed"] + slo["failed"]
            + slo["quarantined"]),
        "dead_letter_exact": sorted(dead_payloads) == want_dead,
        "poison_typed": all(tickets[i].failed
                            and tickets[i].error is not None
                            for i in poison),
        "healthy_verdicts_identical": all(
            not tickets[i].failed and verdict(tickets[i]) == reference[i]
            for i in healthy if not tickets[i].shed),
        "worker_fault_retried": (slo["retries"]
                                 >= len(plan.worker_fault_batches)),
        "pad_overflow_forced": (injector.pad_overflows_forced
                                >= len(plan.pad_overflow_adds)),
        "swaps_committed": all(tk.error is None for tk in swap_tickets),
        "no_loop_failure": slo["failed"] == 0,
    }
    return {
        "ok": all(checks.values()),
        "checks": checks,
        "slo": slo,
        "swaps": loop.swap_summary(),
        "dead_letter": dead,
        "injected": {
            "malformed": list(plan.malformed),
            "overdepth": list(plan.overdepth),
            "kernel": list(plan.kernel),
            "worker_fault_batches": list(plan.worker_fault_batches),
            "slow_batches": list(plan.slow_batches),
            "pad_overflow_adds": list(plan.pad_overflow_adds),
        },
        "n_requests": n_requests,
        "seed": seed,
    }


def main(argv: Any = None) -> int:
    import argparse
    import json

    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--requests", type=int, default=48)
    ap.add_argument("--engine", default="streaming")
    ap.add_argument("--queries", type=int, default=16)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--query-shards", type=int, default=0,
                    help="run the stage query-sharded (0 = monolithic)")
    ap.add_argument("--out", default=None, metavar="PATH",
                    help="write the chaos report JSON here (CI artifact)")
    args = ap.parse_args(argv)

    stage_opts = ({"query_shards": args.query_shards}
                  if args.query_shards > 1 else {})
    report = run_chaos_trace(args.requests, engine=args.engine,
                             n_queries=args.queries, max_batch=args.batch,
                             seed=args.seed, stage_opts=stage_opts)
    if args.out:
        with open(args.out, "w") as f:
            json.dump(report, f, indent=1, default=str)
    s = report["slo"]
    print(f"[chaos] {report['n_requests']} requests: "
          f"{s['completed']} completed, {s['quarantined']} quarantined "
          f"({s['rejected']} pre-admission), {s['retries']} retries, "
          f"{s['swaps']} swaps ({s['swap_rollbacks']} rollbacks)")
    for name, ok in report["checks"].items():
        print(f"[chaos]   {'PASS' if ok else 'FAIL'} {name}")
    return 0 if report["ok"] else 1


if __name__ == "__main__":
    raise SystemExit(main())
