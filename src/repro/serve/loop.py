"""Continuous pub-sub serve loop: bounded ingest, adaptive batching,
K-deep in-flight dispatch, latency SLOs.

This is the piece that turns the repo's batch drivers into a *service*:
the paper's whole pitch is filtering under "very high input ratios"
where per-document processing *time* — not just steady-state
throughput — is what matters, and a fixed-request-list driver cannot
measure that.  The loop is the software analogue of the
admission-controlled reconfigurable stream processor in Diba (see
PAPERS.md): documents arrive continuously, are admitted against a
bounded queue, batched adaptively, filtered on device, and delivered to
subscribers in order — with every stage's occupancy observable.

Dataflow (one :class:`ServeLoop` instance)::

      submit()                  batcher                workers (≤ K)
    ───────────►  ingest queue ─────────►  adaptive  ─────────────►
     admission    (≤ queue_cap)            batching    bytes→verdict
     shed|block                         size OR deadline
                                                            │ FIFO
      deliver()  ◄───────────  completer  ◄─────────────────┘
     subscribers    ordered     fan-out + latency timestamps

* **Admission control** — the ingest queue is bounded at ``queue_cap``;
  an arrival that finds it full is *shed* (counted, its ticket marked)
  or *blocks* the producer (``overload="block"``) until the loop
  drains.  Overload can never grow memory without bound.
* **Adaptive batching** — a batch closes on *size* (``max_batch``
  requests) or *deadline* (``deadline_ms`` after it opened), whichever
  fires first: full batches under load, bounded waiting when idle.
* **K-deep pipelining** — up to ``max_inflight`` closed batches may be
  in flight at once (the generalization of the 2-deep double buffer in
  :meth:`~repro.data.filter_stage.FilterStage.route_bytes_pipelined`);
  the batcher blocks when all K slots are busy, which is the explicit
  *backpressure* signal (counted in ``backpressure_waits``).
* **Ordered delivery** — a single completer thread resolves batches in
  dispatch order, so every subscriber sees its documents in admission
  order regardless of K and regardless of which worker finished first.
  Verdicts are bit-identical to the synchronous
  :meth:`~repro.data.filter_stage.FilterStage.route_bytes` path —
  batching and pipelining are schedule, not semantics.
* **SLOs** — every request is timestamped at admission and at verdict
  materialization; :meth:`ServeLoop.slo_summary` reports
  p50/p99/p999 bytes→verdict latency, shed rate, batch fill,
  close-reason counts, queue depth and backpressure occupancy.

Fault tolerance (the loop keeps serving through all of these):

* **Pre-admission validation** — :func:`repro.core.events.validate_payload`
  rejects known-bad bytes at :meth:`ServeLoop.submit` with a typed
  :class:`~repro.core.events.DocumentError` before they ever reach a
  kernel (``rejected`` counter; the ticket carries the error).
* **Poison isolation** — a batch whose device call raises is retried
  once (transient faults), then bisected to isolate the poison
  document(s); a typed error carrying ``doc_indices`` short-circuits
  the bisection.  Poison requests are *quarantined* into a bounded
  dead-letter buffer (:attr:`ServeLoop.dead_letter`) with their typed
  error; the co-batched healthy requests are re-filtered and complete
  with verdicts bit-identical to a fault-free run.
* **Shadow-plan hot swap** — :meth:`ServeLoop.subscribe` /
  :meth:`unsubscribe` / :meth:`rebalance` build the replacement plan on
  a background builder thread (``FilterStage.prepare_*``) and the
  completer commits it atomically at a batch boundary — churn never
  drains the queue and never stalls the latency path.  A failed build
  rolls back (``swap_rollbacks``): the live plan is untouched.
  In-flight batches are pinned to the :class:`~repro.data.filter_stage.PlanEpoch`
  they were dispatched under, so a swap can never tear a batch.

Arrival-trace helpers (:func:`poisson_arrivals`, :func:`burst_arrivals`,
:func:`replay_arrivals`) generate the seeded workloads the latency
benchmarks and the CI serve job drive through :func:`run_trace`.
"""
from __future__ import annotations

import threading
import time
from collections import deque
from concurrent.futures import ThreadPoolExecutor
from dataclasses import dataclass, field
from typing import Any, Callable, Sequence

import numpy as np

from ..core.engines import FilterResult
from ..core.events import (DEFAULT_MAX_DEPTH, DocumentError, KernelFault,
                           validate_payload)
from ..data.filter_stage import FilterStage, PlanEpoch, RoutedDocument

#: admission policies: drop the arrival (count it) vs stall the producer
OVERLOAD_POLICIES = ("shed", "block")


@dataclass
class ServeRequest:
    """One submitted payload's ticket through the loop.

    ``seq`` is the admission sequence number — it doubles as the
    document index in every :class:`RoutedDocument` the request fans out
    to, so delivery order per subscriber is admission order.  Shed
    requests never get a ``seq`` (they were never admitted); neither do
    requests rejected by pre-admission validation.

    ``error`` is the terminal failure state: a typed
    :class:`~repro.core.events.DocumentError` for rejected/quarantined
    poison documents, or the raw worker exception when the loop runs
    with ``recover=False``.  Exactly one of ``routed`` / ``error`` /
    ``shed`` describes a finished ticket.
    """

    payload: bytes
    t_submit: float
    seq: int = -1
    shed: bool = False
    t_verdict: float | None = None
    routed: list[RoutedDocument] | None = None
    error: BaseException | None = None
    done: threading.Event = field(default_factory=threading.Event,
                                  repr=False)

    @property
    def latency_s(self) -> float | None:
        """Enqueue→verdict seconds (``None`` until resolved / if shed)."""
        if self.t_verdict is None:
            return None
        return self.t_verdict - self.t_submit

    @property
    def failed(self) -> bool:
        """Terminal failure: rejected, quarantined, or worker error."""
        return self.error is not None


@dataclass
class ReconfigTicket:
    """One live-reconfiguration request's ticket through the shadow
    builder: prepared off the hot path, committed by the completer at a
    batch boundary.  ``error`` set (and the live plan untouched) when
    the build or commit failed — the rollback path."""

    op: str                            # "subscribe" | "unsubscribe" | "rebalance"
    done: threading.Event = field(default_factory=threading.Event,
                                  repr=False)
    gid: int | None = None             # result for subscribe/unsubscribe
    stats: dict | None = None          # result for rebalance
    error: BaseException | None = None
    build_s: float = 0.0               # shadow build (prepare) seconds
    commit_s: float = 0.0              # atomic swap seconds


class ServeLoop:
    """Continuous serving front-end over a :class:`FilterStage`.

    Use as a context manager: exiting flushes the queue, drains all
    in-flight batches and joins the worker threads — a wedged device
    call is therefore visible as a *hanging close*, which is exactly
    what the CI serve job's timeout guards.

    ``deliver`` (optional) is called by the completer with each batch's
    routed documents, in order; a consumer that blocks inside it stalls
    the completer, which fills the K in-flight slots, which blocks the
    batcher, which fills the ingest queue, which sheds (or blocks) new
    arrivals — end-to-end backpressure with no unbounded buffer
    anywhere.
    """

    def __init__(self, stage: FilterStage, *, max_batch: int | None = None,
                 deadline_ms: float = 10.0, queue_cap: int = 64,
                 max_inflight: int = 2, overload: str = "shed",
                 deliver: Callable[[list[RoutedDocument]], Any] | None = None,
                 pad_batches: bool = True, validate: bool = True,
                 recover: bool = True, dead_letter_cap: int = 256,
                 rebalance_every_batches: int = 0,
                 rebalance_tolerance: float | None = None,
                 clock: Callable[[], float] = time.monotonic):
        if overload not in OVERLOAD_POLICIES:
            raise ValueError(f"overload must be one of {OVERLOAD_POLICIES}, "
                             f"got {overload!r}")
        if queue_cap < 1 or max_inflight < 1:
            raise ValueError("queue_cap and max_inflight must be >= 1")
        self.stage = stage
        self.max_batch = int(max_batch or stage.batch_size)
        self.deadline_s = float(deadline_ms) / 1e3
        self.queue_cap = int(queue_cap)
        self.max_inflight = int(max_inflight)
        self.overload = overload
        self.deliver = deliver
        # compiled-shape discipline: a deadline-closed undersized batch
        # is padded back to max_batch (repeating its last payload; the
        # pad rows' verdicts are sliced off) so the device program keeps
        # ONE batch shape — otherwise every distinct deadline-close size
        # triggers a fresh compile on the latency path.  Sparse stages
        # skip it (their match lists carry real doc ids).
        self.pad_batches = bool(pad_batches) and not stage.sparse
        #: reject known-bad bytes at submit() with a typed error, before
        #: they reach a kernel (host-side, vectorized — cheap)
        self.validate = bool(validate)
        #: isolate poison documents on batch failure (retry + bisection)
        #: instead of failing the whole batch; ``False`` marks all the
        #: batch's requests failed and keeps serving
        self.recover = bool(recover)
        self._max_depth = int(getattr(stage._eng, "max_depth",
                                      DEFAULT_MAX_DEPTH))
        #: run a shadow rebalance every N completed batches (0 = never)
        self.rebalance_every_batches = int(rebalance_every_batches)
        self.rebalance_tolerance = rebalance_tolerance
        self._clock = clock

        self._lock = threading.Lock()
        self._not_empty = threading.Condition(self._lock)
        self._not_full = threading.Condition(self._lock)
        self._queue: deque[ServeRequest] = deque()
        self._closing = False
        self._closed = False
        self._error: BaseException | None = None
        # dispatched-but-undelivered batches are bounded at K: a slot is
        # taken at dispatch and released only after delivery
        self._slots = threading.Semaphore(self.max_inflight)
        self._comp_cv = threading.Condition()
        self._completion: deque = deque()
        self._latencies: list[float] = []
        self._batch_fills: list[float] = []
        #: bounded dead-letter buffer of quarantined documents: dicts of
        #: ``{seq, payload, error, message}`` (seq -1 = rejected at
        #: admission); oldest entries fall off at ``dead_letter_cap``
        self.dead_letter: deque[dict] = deque(maxlen=int(dead_letter_cap))
        #: committed hot swaps, in commit order: ``{op, build_s,
        #: commit_s, epoch}``
        self.swap_log: list[dict] = []
        self.counters = {"admitted": 0, "shed": 0, "completed": 0,
                         "batches": 0, "size_closes": 0,
                         "deadline_closes": 0, "flush_closes": 0,
                         "backpressure_waits": 0, "max_queue_depth": 0,
                         "rejected": 0, "quarantined": 0, "failed": 0,
                         "retries": 0, "swaps": 0, "swap_rollbacks": 0,
                         "delivery_errors": 0}
        self._t_first: float | None = None
        self._t_last: float | None = None
        self._batches_since_rebalance = 0
        self._auto_ticket: ReconfigTicket | None = None
        self._reconfig_cv = threading.Condition()
        self._reconfig_q: deque = deque()

        self._pool = ThreadPoolExecutor(max_workers=self.max_inflight,
                                        thread_name_prefix="serve-filter")
        self._batcher_t = threading.Thread(target=self._batcher,
                                           name="serve-batcher", daemon=True)
        self._completer_t = threading.Thread(target=self._completer,
                                             name="serve-completer",
                                             daemon=True)
        self._builder_t = threading.Thread(target=self._builder,
                                           name="serve-plan-builder",
                                           daemon=True)
        self._batcher_t.start()
        self._completer_t.start()
        self._builder_t.start()

    # ------------------------------------------------------------- ingest
    def submit(self, payload: bytes) -> ServeRequest:
        """Admit one raw wire payload; returns its ticket immediately.

        Under overload (queue at ``queue_cap``): ``overload="shed"``
        marks the ticket shed and returns at once; ``"block"`` stalls
        the caller until the loop drains a slot (producer-side
        backpressure).  A loop that is closing sheds rather than
        deadlocking a blocked producer.

        With ``validate=True`` (default) known-bad bytes are *rejected*
        here — the ticket comes back with a typed
        :class:`~repro.core.events.DocumentError` and a dead-letter
        record, and the payload never reaches a kernel.
        """
        req = ServeRequest(payload=payload, t_submit=self._clock())
        if self.validate:
            try:
                validate_payload(payload, max_depth=self._max_depth)
            except DocumentError as e:
                req.error = e
                req.done.set()
                with self._lock:
                    self.counters["rejected"] += 1
                    self.counters["quarantined"] += 1
                    self.dead_letter.append(
                        {"seq": -1, "payload": payload,
                         "error": type(e).__name__, "message": str(e)})
                return req
        with self._lock:
            if self.overload == "shed":
                if len(self._queue) >= self.queue_cap or self._closing:
                    req.shed = True
                    self.counters["shed"] += 1
                    req.done.set()
                    return req
            else:
                while len(self._queue) >= self.queue_cap \
                        and not self._closing:
                    self._not_full.wait()
                if self._closing:
                    req.shed = True
                    self.counters["shed"] += 1
                    req.done.set()
                    return req
            req.seq = self.counters["admitted"]
            self.counters["admitted"] += 1
            if self._t_first is None:
                self._t_first = req.t_submit
            self._queue.append(req)
            depth = len(self._queue)
            if depth > self.counters["max_queue_depth"]:
                self.counters["max_queue_depth"] = depth
            self._not_empty.notify()
        return req

    @property
    def queue_depth(self) -> int:
        with self._lock:
            return len(self._queue)

    # ----------------------------------------------------------- batching
    def _batcher(self) -> None:
        try:
            while True:
                with self._lock:
                    while not self._queue and not self._closing:
                        self._not_empty.wait()
                    if not self._queue and self._closing:
                        break
                    # batch opens now; close on size or deadline,
                    # whichever fires first (flush closes immediately)
                    deadline = self._clock() + self.deadline_s
                    while (len(self._queue) < self.max_batch
                           and not self._closing):
                        left = deadline - self._clock()
                        if left <= 0:
                            break
                        self._not_empty.wait(timeout=left)
                    n = min(self.max_batch, len(self._queue))
                    reqs = [self._queue.popleft() for _ in range(n)]
                    if n == self.max_batch:
                        self.counters["size_closes"] += 1
                    elif self._closing:
                        self.counters["flush_closes"] += 1
                    else:
                        self.counters["deadline_closes"] += 1
                    self.counters["batches"] += 1
                    self._not_full.notify_all()
                self._dispatch(reqs)
        except BaseException as e:  # pragma: no cover - defensive
            self._fail(e)
        finally:
            with self._comp_cv:
                self._completion.append(None)
                self._comp_cv.notify()

    def _dispatch(self, reqs: list[ServeRequest]) -> None:
        """Take an in-flight slot (counting the wait as backpressure)
        and hand the batch to a worker; completion order is dispatch
        order regardless of which worker finishes first."""
        if not self._slots.acquire(blocking=False):
            with self._lock:
                self.counters["backpressure_waits"] += 1
            self._slots.acquire()
        future = self._pool.submit(self._run_batch,
                                   [r.payload for r in reqs])
        with self._comp_cv:
            self._completion.append((reqs, future))
            self._comp_cv.notify()

    def _run_batch(self, payloads: list[bytes]):
        """Worker-thread body: the stage's device bytes→verdict call.

        The batch is pinned to a :meth:`FilterStage.plan_epoch`
        snapshot — a hot swap committing mid-flight cannot tear
        engine/plan/gids — and the snapshot rides along for the
        epoch-consistent fan-out.  ``record=False`` — stage stats are
        mutated only by the single-threaded completer, so K concurrent
        workers never race the accounting dict.
        """
        t0 = time.perf_counter()
        n = len(payloads)
        padded = payloads
        if self.pad_batches and n < self.max_batch:
            padded = payloads + [payloads[-1]] * (self.max_batch - n)
        ep = self.stage.plan_epoch()
        res = self.stage._filter_bytebatch(padded, record=False, epoch=ep)
        if len(padded) != n:
            res = FilterResult(res.matched[:n], res.first_event[:n],
                               res.live)
        return res, [len(p) for p in payloads], time.perf_counter() - t0, ep

    # ----------------------------------------------------------- delivery
    def _completer(self) -> None:
        # two producers feed the completion queue: the batcher (batches)
        # and the shadow builder (plan swaps); each appends one None
        # sentinel on exit, and the completer drains until both are done
        # — so a swap enqueued during shutdown still commits
        producers = 2
        try:
            while True:
                with self._comp_cv:
                    while not self._completion:
                        self._comp_cv.wait()
                    item = self._completion.popleft()
                if item is None:
                    producers -= 1
                    if producers == 0:
                        break
                    continue
                if item[0] == "swap":
                    self._commit_swap(item[1], item[2], item[3])
                    continue
                reqs, future = item
                try:
                    res, nbytes, dt, ep = future.result()
                except BaseException as e:
                    if self.recover:
                        self._recover(reqs, e, retry=True)
                    else:
                        self._fail_requests(reqs, e)
                else:
                    self._resolve(reqs, res, nbytes, dt, ep)
                self._slots.release()
                self._maybe_auto_rebalance()
        except BaseException as e:  # pragma: no cover - defensive
            self._fail(e)

    def _resolve(self, reqs: list[ServeRequest], res, nbytes: list[int],
                 dt: float, ep: PlanEpoch) -> None:
        """Fan a finished batch's verdicts out to its tickets.

        Routing uses the epoch the batch was *filtered* under
        (``ep.gids``) and the requests' own seqs — recovered subsets
        are non-contiguous, and a plan swapped after dispatch must not
        remap this batch's verdict columns."""
        t_done = self._clock()
        routed = self.stage._fan_out(res, nbytes, gids=ep.gids,
                                     seqs=[r.seq for r in reqs])
        self.stage._record(res, len(reqs), sum(nbytes), dt)
        by_doc: dict[int, list[RoutedDocument]] = {}
        for rd in routed:
            by_doc.setdefault(rd.doc_index, []).append(rd)
        for r in reqs:
            r.t_verdict = t_done
            r.routed = by_doc.get(r.seq, [])
            self._latencies.append(t_done - r.t_submit)
            r.done.set()
        self.counters["completed"] += len(reqs)
        self._t_last = t_done
        self._batch_fills.append(len(reqs) / self.max_batch)
        if self.deliver is not None:
            # a stalled consumer stalls HERE, holding the slot: that is
            # the backpressure chain's first link.  A *raising* consumer
            # must not kill the loop — its error is counted, not fatal.
            try:
                self.deliver(routed)
            except BaseException:
                self.counters["delivery_errors"] += 1

    # ------------------------------------------------- failure containment
    def _recover(self, reqs: list[ServeRequest], err: BaseException,
                 retry: bool) -> None:
        """Contain a failed batch: isolate poison, save the rest.

        A typed :class:`DocumentError` carrying ``doc_indices`` names
        the poison outright — quarantine those, re-filter the rest.
        Anything else gets one whole-batch retry (transient faults:
        worker hiccup, OOM race), then bisection: halves re-filter
        independently, singletons that still fail are quarantined as
        :class:`KernelFault`.  Healthy co-batched documents therefore
        always complete, with verdicts identical to a fault-free run.
        """
        if isinstance(err, DocumentError) and err.doc_indices:
            # pad rows repeat the last payload, so a pad-row index maps
            # back onto the last real request
            bad_idx = sorted({min(int(i), len(reqs) - 1)
                              for i in err.doc_indices})
            bad = set(bad_idx)
            self._quarantine([reqs[i] for i in bad_idx], err)
            rest = [r for i, r in enumerate(reqs) if i not in bad]
            if rest:
                self._try_subset(rest)
            return
        if retry:
            self.counters["retries"] += 1
            self._try_subset(reqs)
            return
        if len(reqs) == 1:
            self._quarantine(reqs, err)
            return
        mid = len(reqs) // 2
        self._try_subset(reqs[:mid])
        self._try_subset(reqs[mid:])

    def _try_subset(self, reqs: list[ServeRequest]) -> None:
        """Synchronously re-filter a subset on the completer thread;
        recurse into :meth:`_recover` (no further whole-batch retry) if
        it fails again."""
        try:
            res, nbytes, dt, ep = self._run_batch([r.payload for r in reqs])
        except BaseException as e:
            self._recover(reqs, e, retry=False)
            return
        self._resolve(reqs, res, nbytes, dt, ep)

    def _quarantine(self, reqs: list[ServeRequest],
                    err: BaseException) -> None:
        """Terminal poison state: typed error on each ticket (carrying
        the document's admission seq), bounded dead-letter record, loop
        keeps serving."""
        for r in reqs:
            if isinstance(err, DocumentError):
                e = type(err)(str(err), (r.seq,))
            else:
                e = KernelFault(f"{type(err).__name__}: {err}", (r.seq,))
            e.__cause__ = err if e is not err else None
            r.error = e
            with self._lock:
                self.counters["quarantined"] += 1
                self.dead_letter.append(
                    {"seq": r.seq, "payload": r.payload,
                     "error": type(e).__name__, "message": str(err)})
            r.done.set()

    def _fail_requests(self, reqs: Sequence[ServeRequest],
                       err: BaseException) -> None:
        """``recover=False`` terminal path: every request in the batch
        fails with the raw worker error; the loop keeps serving and
        ``close()`` re-raises the first such error."""
        with self._lock:
            if self._error is None:
                self._error = err
            self.counters["failed"] += len(reqs)
        for r in reqs:
            r.error = err
            r.done.set()

    def _fail(self, e: BaseException,
              reqs: Sequence[ServeRequest] = ()) -> None:
        with self._lock:
            if self._error is None:
                self._error = e
            self._not_full.notify_all()
        for r in reqs:
            r.error = e
            r.done.set()

    # ------------------------------------------------- shadow-plan hot swap
    def subscribe(self, profile, shard: int | None = None) -> ReconfigTicket:
        """Add a standing profile *live*: the replacement plan builds on
        the shadow builder thread and swaps in at a batch boundary — no
        queue drain, no filtering pause.  Wait on ``ticket.done`` for
        the gid (or the build error)."""
        return self._enqueue_reconfig("subscribe", profile, shard)

    def unsubscribe(self, gid: int) -> ReconfigTicket:
        """Drop a subscription live (shadow build + boundary swap)."""
        return self._enqueue_reconfig("unsubscribe", gid, None)

    def rebalance(self, tolerance: float | None = None) -> ReconfigTicket:
        """Shadow-rebalance the sharded plan; commits only if trie
        groups actually moved (``ticket.stats``)."""
        return self._enqueue_reconfig("rebalance", tolerance, None)

    def _enqueue_reconfig(self, op: str, arg, shard) -> ReconfigTicket:
        ticket = ReconfigTicket(op=op)
        with self._reconfig_cv:
            if self._closing:
                ticket.error = RuntimeError("serve loop is closing")
                ticket.done.set()
                return ticket
            self._reconfig_q.append((op, arg, shard, ticket))
            self._reconfig_cv.notify()
        return ticket

    def _builder(self) -> None:
        """Shadow-plan builder: one reconfiguration at a time, each
        prepared against the live epoch and handed to the completer for
        the atomic commit.  Serialized on ``ticket.done`` so the next
        prepare never races the previous commit (which would make it
        stale)."""
        try:
            while True:
                with self._reconfig_cv:
                    while not self._reconfig_q and not self._closing:
                        self._reconfig_cv.wait()
                    if not self._reconfig_q:
                        break            # closing, queue drained
                    op, arg, shard, ticket = self._reconfig_q.popleft()
                try:
                    if op == "subscribe":
                        pending = self.stage.prepare_subscribe(arg)
                    elif op == "unsubscribe":
                        pending = self.stage.prepare_unsubscribe(arg)
                    else:
                        pending = self.stage.prepare_rebalance(tolerance=arg)
                except BaseException as e:
                    # rollback: the live plan was never touched
                    ticket.error = e
                    with self._lock:
                        self.counters["swap_rollbacks"] += 1
                    ticket.done.set()
                    continue
                if pending is None:      # rebalance on an unsharded stage
                    ticket.done.set()
                    continue
                ticket.build_s = pending.build_s
                with self._comp_cv:
                    self._completion.append(("swap", ticket, pending, shard))
                    self._comp_cv.notify()
                ticket.done.wait()
        finally:
            with self._comp_cv:
                self._completion.append(None)
                self._comp_cv.notify()

    def _commit_swap(self, ticket: ReconfigTicket, pending,
                     shard) -> None:
        """Completer-side half of the hot swap: a few reference
        assignments under the stage's plan mutex, at a batch boundary
        (never mid-fan-out).  In-flight batches keep their dispatch
        epoch; the next ``_run_batch`` snapshot sees the new plan."""
        t0 = time.perf_counter()
        try:
            out = self.stage.commit(pending, shard=shard)
        except BaseException as e:
            ticket.error = e
            with self._lock:
                self.counters["swap_rollbacks"] += 1
        else:
            ticket.commit_s = time.perf_counter() - t0
            if pending.op == "rebalance":
                ticket.stats = out
            else:
                ticket.gid = out
            with self._lock:
                self.counters["swaps"] += 1
            self.swap_log.append(
                {"op": pending.op, "build_s": round(ticket.build_s, 6),
                 "commit_s": round(ticket.commit_s, 6),
                 "epoch": self.stage._epoch})
        ticket.done.set()

    def _maybe_auto_rebalance(self) -> None:
        """Traffic-driven rebalance: every N completed batches, kick a
        shadow rebalance (skipped while one is still in flight)."""
        if not self.rebalance_every_batches:
            return
        self._batches_since_rebalance += 1
        if self._batches_since_rebalance < self.rebalance_every_batches:
            return
        if self._auto_ticket is not None \
                and not self._auto_ticket.done.is_set():
            return
        self._batches_since_rebalance = 0
        self._auto_ticket = self.rebalance(self.rebalance_tolerance)

    # -------------------------------------------------------- lifecycle
    def close(self) -> None:
        """Flush the queue, drain every in-flight batch and pending
        reconfiguration, join threads.  Idempotent and re-entrant: the
        second and later calls are no-ops (no re-join, no re-raise).

        Raises the first *loop* error, if any (an internal thread crash,
        or a batch failure under ``recover=False``) — exactly once.
        Quarantined documents are not loop errors: their typed
        exceptions live on their tickets and in :attr:`dead_letter`.
        """
        with self._lock:
            if self._closed:
                return
            self._closed = True
            self._closing = True
            self._not_empty.notify_all()
            self._not_full.notify_all()
        with self._reconfig_cv:
            self._reconfig_cv.notify_all()
        self._batcher_t.join()
        self._builder_t.join()
        self._completer_t.join()
        self._pool.shutdown(wait=True)
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def __enter__(self) -> "ServeLoop":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        self.close()

    # ------------------------------------------------------------ metrics
    def slo_summary(self) -> dict:
        """Latency percentiles + occupancy counters for everything
        served so far (ms; ``nan`` percentiles until something
        completes).

        Accounting closes even under failures: every arrival ends in
        exactly one of completed / shed / failed / quarantined, so at
        quiescence ``arrived == completed + shed + failed +
        quarantined`` (``rejected`` — pre-admission — is the part of
        ``quarantined`` that never got a seq; ``arrived == admitted +
        shed + rejected``)."""
        lat_ms = np.asarray(self._latencies) * 1e3
        c = dict(self.counters)
        arrived = c["admitted"] + c["shed"] + c["rejected"]
        span = ((self._t_last - self._t_first)
                if self._t_first is not None and self._t_last is not None
                else 0.0)
        return {
            **c,
            "arrived": arrived,
            "shed_rate": c["shed"] / max(arrived, 1),
            "dead_letter_depth": len(self.dead_letter),
            "p50_ms": _pct(lat_ms, 50.0),
            "p99_ms": _pct(lat_ms, 99.0),
            "p999_ms": _pct(lat_ms, 99.9),
            "mean_ms": float(lat_ms.mean()) if lat_ms.size else float("nan"),
            "batch_fill": (float(np.mean(self._batch_fills))
                           if self._batch_fills else 0.0),
            "served_per_s": c["completed"] / span if span > 0 else 0.0,
        }

    def swap_summary(self) -> dict:
        """Hot-swap cost summary: shadow build vs atomic commit times
        (ms) over :attr:`swap_log` — the commit is the only part the
        latency path can ever observe."""
        builds = np.asarray([s["build_s"] for s in self.swap_log]) * 1e3
        commits = np.asarray([s["commit_s"] for s in self.swap_log]) * 1e3
        return {
            "swaps": self.counters["swaps"],
            "swap_rollbacks": self.counters["swap_rollbacks"],
            "build_p50_ms": _pct(builds, 50.0),
            "build_p99_ms": _pct(builds, 99.0),
            "commit_p50_ms": _pct(commits, 50.0),
            "commit_p99_ms": _pct(commits, 99.0),
        }

    def latencies_ms(self) -> np.ndarray:
        """Per-request enqueue→verdict latencies (ms), completion order."""
        return np.asarray(self._latencies) * 1e3

    def latency_histogram(self, n_bins: int = 32) -> dict:
        """Log-spaced latency histogram — the CI artifact payload."""
        lat = self.latencies_ms()
        if lat.size == 0:
            return {"edges_ms": [], "counts": []}
        lo = max(float(lat.min()), 1e-3)
        hi = max(float(lat.max()), lo * (1 + 1e-6))
        edges = np.geomspace(lo, hi, n_bins + 1)
        counts, _ = np.histogram(lat, bins=edges)
        return {"edges_ms": edges.tolist(), "counts": counts.tolist()}


def _pct(xs: np.ndarray, q: float) -> float:
    return float(np.percentile(xs, q)) if xs.size else float("nan")


# ------------------------------------------------------- arrival traces
def poisson_arrivals(n: int, rate_hz: float, *, seed: int = 0) -> np.ndarray:
    """``n`` absolute arrival offsets (s) of a Poisson process."""
    if rate_hz <= 0:
        raise ValueError("rate_hz must be > 0")
    rng = np.random.default_rng(seed)
    return np.cumsum(rng.exponential(1.0 / rate_hz, size=n))

def burst_arrivals(n: int, rate_hz: float, *, on_s: float = 0.05,
                   off_s: float = 0.15, seed: int = 0) -> np.ndarray:
    """ON/OFF-modulated Poisson: bursts at ``rate_hz`` for ``on_s``,
    silence for ``off_s`` — the bursty-input scenario the paper's
    "very high input ratios" motivation describes.  Mean rate is
    ``rate_hz * on_s / (on_s + off_s)``."""
    if rate_hz <= 0:
        raise ValueError("rate_hz must be > 0")
    rng = np.random.default_rng(seed)
    out: list[float] = []
    t = 0.0
    while len(out) < n:
        window_end = t + on_s
        while len(out) < n:
            t += rng.exponential(1.0 / rate_hz)
            if t >= window_end:
                break
            out.append(t)
        t = window_end + off_s
    return np.asarray(out[:n])

def replay_arrivals(n: int, rate_hz: float | None = None) -> np.ndarray:
    """Deterministic trace: back-to-back (``rate_hz=None``) or evenly
    spaced at ``rate_hz`` — replaying a fixed request list through the
    loop (the old batch driver's arrival pattern, as a trace)."""
    if rate_hz is None or rate_hz <= 0:
        return np.zeros(n)
    return np.arange(n, dtype=np.float64) / rate_hz


def make_arrivals(kind: str, n: int, *, rate_hz: float,
                  on_s: float = 0.05, off_s: float = 0.15,
                  seed: int = 0) -> np.ndarray:
    """Trace dispatcher for the CLI/bench ``--arrival`` knob."""
    if kind == "poisson":
        return poisson_arrivals(n, rate_hz, seed=seed)
    if kind == "burst":
        return burst_arrivals(n, rate_hz, on_s=on_s, off_s=off_s, seed=seed)
    if kind == "replay":
        return replay_arrivals(n, rate_hz)
    raise ValueError(f"unknown arrival trace {kind!r} "
                     f"(poisson|burst|replay)")


def run_trace(loop: ServeLoop, payloads: Sequence[bytes],
              arrivals: np.ndarray, *,
              clock: Callable[[], float] = time.monotonic,
              sleep: Callable[[float], Any] = time.sleep
              ) -> list[ServeRequest]:
    """Submit ``payloads[i]`` at offset ``arrivals[i]`` (open-loop: the
    trace does NOT slow down when the service falls behind, which is
    what makes shed/backpressure measurable).  Returns the tickets."""
    if len(payloads) != len(arrivals):
        raise ValueError(f"{len(payloads)} payloads vs "
                         f"{len(arrivals)} arrival offsets")
    t0 = clock()
    tickets = []
    for payload, due in zip(payloads, arrivals):
        lag = due - (clock() - t0)
        if lag > 0:
            sleep(lag)
        tickets.append(loop.submit(payload))
    return tickets
