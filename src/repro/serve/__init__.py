"""Serving: prefill/decode steps, batched engine, request routing."""
from .engine import ServeEngine  # noqa: F401
