"""Serving: prefill/decode steps, batched engine, request routing, and
the continuous pub-sub serve loop (admission control, adaptive batching,
K-deep pipelining, latency SLOs — see :mod:`repro.serve.loop`)."""
from .engine import ServeEngine  # noqa: F401
from .loop import (ServeLoop, ServeRequest, burst_arrivals,  # noqa: F401
                   make_arrivals, poisson_arrivals, replay_arrivals,
                   run_trace)
