"""Serving: prefill/decode steps, batched engine, request routing, and
the continuous pub-sub serve loop (admission control, adaptive batching,
K-deep pipelining, latency SLOs — see :mod:`repro.serve.loop`)."""
from .engine import ServeEngine  # noqa: F401
from .loop import (ReconfigTicket, ServeLoop, ServeRequest,  # noqa: F401
                   burst_arrivals, make_arrivals, poisson_arrivals,
                   replay_arrivals, run_trace)
