"""Document event streams and the fixed-width byte codec.

A document is represented as a balanced sequence of *events*:

  * ``OPEN``  — an element starts (carries the dictionary tag id)
  * ``CLOSE`` — the most recent open element ends
  * ``PAD``   — no-op filler so batched documents share a static length

This is exactly the view the paper's hardware sees after its tag-filter
block: the SAX-level structure of the document with tags already
dictionary-replaced (§3.1).  Text content does not influence structural
XPath matching, so the codec optionally interleaves filler text bytes (to
exercise the byte-level decoder) but the event stream drops it.

The byte format is the paper's: open tags are 4 bytes ``<xy>`` and close
tags 5 bytes ``</xy>`` where ``x``/``y`` come from the 64-symbol alphabet in
:mod:`repro.core.dictionary`.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Iterator, Sequence

import numpy as np

from .dictionary import (
    CLOSE_NBYTES,
    LT,
    OPEN_NBYTES,
    SLASH,
    TagDictionary,
)

OPEN, CLOSE, PAD = 0, 1, 2


# ------------------------------------------------------------ error taxonomy
class DocumentError(ValueError):
    """A *document* is bad — not the pipeline.

    The typed error contract the fault-tolerant serve loop is built on
    (:mod:`repro.serve.loop`): anything raised because of the *content*
    of specific documents derives from this class and carries the batch
    indices of the offending documents in ``doc_indices``, so a batch
    failure can be attributed — and quarantined — per document instead
    of poisoning the whole loop.  Subclassing :class:`ValueError` keeps
    every pre-existing ``except ValueError`` / ``pytest.raises``
    contract intact.
    """

    def __init__(self, message: str, doc_indices: Sequence[int] = ()):
        super().__init__(message)
        #: batch rows of the offending documents (empty when unknown —
        #: e.g. a single-document host-side validation failure)
        self.doc_indices: tuple[int, ...] = tuple(int(i) for i in doc_indices)


class MalformedDocument(DocumentError):
    """Bytes/events that do not form a balanced paper-format document
    (mismatched or unclosed tags, undecodable tag markers)."""


class DepthOverflow(DocumentError):
    """Document nesting exceeds the engine/parser ``max_depth`` bound —
    parent pointers past the bound would be silently wrong, so the
    document is rejected instead."""


class KernelFault(DocumentError):
    """A device program failed while filtering specific documents and
    bisection attributed the fault to them (the residual category: the
    batch works without these documents, fails with them)."""


#: parser/engine nesting-depth bound (the streaming engine's bounded
#: stack and the parse kernel's parent-pointer scan share it —
#: re-exported as :data:`repro.kernels.parse.DEFAULT_MAX_DEPTH`)
DEFAULT_MAX_DEPTH = 64


def _as_field(x, dtype):
    """Coerce a batch field without forcing device arrays to host.

    numpy input (or anything list-like) becomes a numpy array of the
    requested dtype; jax arrays keep their placement — ``EventBatch`` is
    duck-typed over the two so device-parsed batches flow to engines
    with no host round-trip.
    """
    if isinstance(x, np.ndarray):
        return np.asarray(x, dtype)
    if hasattr(x, "astype") and hasattr(x, "shape") and hasattr(x, "dtype"):
        return x if x.dtype == np.dtype(dtype) else x.astype(dtype)
    return np.asarray(x, dtype)


@dataclass
class EventStream:
    """Structure-of-arrays event stream for one document."""

    kind: np.ndarray     # (N,) int8 — OPEN / CLOSE / PAD
    tag_id: np.ndarray   # (N,) int32 — dictionary id for OPEN/CLOSE, -1 for PAD

    def __post_init__(self) -> None:
        self.kind = np.asarray(self.kind, dtype=np.int8)
        self.tag_id = np.asarray(self.tag_id, dtype=np.int32)
        assert self.kind.shape == self.tag_id.shape

    def __len__(self) -> int:
        return int(self.kind.shape[0])

    @property
    def n_nodes(self) -> int:
        return int((self.kind == OPEN).sum())

    # ------------------------------------------------------------ building
    @classmethod
    def from_pairs(cls, pairs) -> "EventStream":
        """pairs: iterable of (kind, tag_id)."""
        ks, ts = [], []
        for k, t in pairs:
            ks.append(k)
            ts.append(t)
        return cls(np.array(ks, dtype=np.int8), np.array(ts, dtype=np.int32))

    def padded(self, n: int) -> "EventStream":
        if n < len(self):
            raise ValueError(f"cannot pad {len(self)} events into {n}")
        k = np.full(n, PAD, dtype=np.int8)
        t = np.full(n, -1, dtype=np.int32)
        k[: len(self)] = self.kind
        t[: len(self)] = self.tag_id
        return EventStream(k, t)

    # ---------------------------------------------------------- validation
    def check_balanced(self) -> None:
        depth = 0
        stack: list[int] = []
        for k, t in zip(self.kind, self.tag_id):
            if k == OPEN:
                stack.append(int(t))
                depth += 1
            elif k == CLOSE:
                if not stack or stack[-1] != int(t):
                    raise MalformedDocument("unbalanced or mismatched close tag")
                stack.pop()
                depth -= 1
        if stack:
            raise MalformedDocument(f"{len(stack)} unclosed elements")

    def max_depth(self) -> int:
        delta = np.where(self.kind == OPEN, 1, np.where(self.kind == CLOSE, -1, 0))
        if len(delta) == 0:
            return 0
        return int(np.cumsum(delta).max(initial=0))

    # ------------------------------------------------------------ structure
    def structure(self) -> tuple[np.ndarray, np.ndarray]:
        """Per-event (depth, parent_event_index).

        ``depth[i]`` — for OPEN events, the node's depth (top-level = 1);
        for CLOSE/PAD, the depth after the event (unused by engines).
        ``parent[i]`` — for OPEN events, the event index of the parent OPEN,
        or -1 for top-level nodes.  CLOSE/PAD get -1.

        This is the host-side oracle for the jax implementations in
        :mod:`repro.core.engines.levelwise`.
        """
        n = len(self)
        depth = np.zeros(n, dtype=np.int32)
        parent = np.full(n, -1, dtype=np.int32)
        stack: list[int] = []
        for i in range(n):
            k = self.kind[i]
            if k == OPEN:
                parent[i] = stack[-1] if stack else -1
                stack.append(i)
                depth[i] = len(stack)
            elif k == CLOSE:
                if stack:
                    stack.pop()
                depth[i] = len(stack)
            else:
                depth[i] = len(stack)
        return depth, parent


# -------------------------------------------------------------- batch format
def bucket_length(n: int, bucket: int | None) -> int:
    """Round ``n`` up to a padding bucket boundary.

    Bucketed padding keeps the number of distinct (B, N) shapes — and
    therefore the number of XLA compilations — bounded: every batch is
    padded to the next multiple of ``bucket`` instead of its exact max
    length.  ``bucket=None`` disables bucketing (exact max-length pad).
    """
    if bucket is None or bucket <= 1:
        return max(1, n)
    return max(bucket, -(-n // bucket) * bucket)


@dataclass
class EventBatch:
    """Padded, device-ready batch of event streams — THE document format.

    Every filtering engine consumes this one structure (see
    :mod:`repro.core.engines.base`): a dense ``(B, N)`` structure-of-arrays
    view of ``B`` documents padded to a common event count ``N``, with the
    per-event structure (depth, parent pointer) that the levelwise engines
    need precomputed in the same host pass that pads.

    ``kind``/``tag_id`` are the raw SAX-level stream (what the streaming
    and matscan engines scan); ``depth``/``parent`` virtualize the
    document stack (what the levelwise engines bucket by); ``valid`` masks
    the padding tail; ``n_events[b]`` is the true length of document b.

    Fields are duck-typed over numpy and jax arrays: a batch built on the
    host (:meth:`from_streams`) carries numpy, a batch parsed on device
    (:func:`repro.kernels.parse.parse_batch`) carries jax arrays and
    stays resident — device engines consume it with no host round-trip,
    host engines call :meth:`to_host` first.
    """

    kind: np.ndarray      # (B, N) int8  — OPEN / CLOSE / PAD
    tag_id: np.ndarray    # (B, N) int32 — dictionary id, -1 for PAD
    depth: np.ndarray     # (B, N) int32 — node depth for OPEN events
    parent: np.ndarray    # (B, N) int32 — event idx of parent OPEN, -1 root
    valid: np.ndarray     # (B, N) bool  — kind != PAD
    n_events: np.ndarray  # (B,)   int32 — true per-document lengths

    def __post_init__(self) -> None:
        self.kind = _as_field(self.kind, np.int8)
        self.tag_id = _as_field(self.tag_id, np.int32)
        self.depth = _as_field(self.depth, np.int32)
        self.parent = _as_field(self.parent, np.int32)
        self.valid = _as_field(self.valid, bool)
        self.n_events = _as_field(self.n_events, np.int32)
        assert self.kind.ndim == 2
        assert self.kind.shape == self.tag_id.shape == self.depth.shape \
            == self.parent.shape == self.valid.shape
        assert self.n_events.shape == (self.kind.shape[0],)

    @property
    def is_device(self) -> bool:
        """True when fields are device (jax) arrays, not numpy."""
        return not isinstance(self.kind, np.ndarray)

    def to_host(self) -> "EventBatch":
        """Materialize on the host (no-op for numpy-backed batches)."""
        if not self.is_device:
            return self
        return EventBatch(*(np.asarray(a) for a in
                            (self.kind, self.tag_id, self.depth,
                             self.parent, self.valid, self.n_events)))

    # ----------------------------------------------------------- properties
    @property
    def batch_size(self) -> int:
        return int(self.kind.shape[0])

    @property
    def length(self) -> int:
        return int(self.kind.shape[1])

    def __len__(self) -> int:
        return self.batch_size

    # ----------------------------------------------------------- constructors
    @classmethod
    def from_streams(cls, docs: Sequence["EventStream"],
                     bucket: int | None = None) -> "EventBatch":
        """Pad ``docs`` to a common (bucketed) length and stack.

        One linear host pass per document computes (depth, parent)
        alongside the pad — the batch analogue of
        :meth:`EventStream.structure`.
        """
        if len(docs) == 0:
            raise ValueError("empty batch")
        n = bucket_length(max((len(d) for d in docs), default=1), bucket)
        b = len(docs)
        kind = np.full((b, n), PAD, dtype=np.int8)
        tag = np.full((b, n), -1, dtype=np.int32)
        depth = np.zeros((b, n), dtype=np.int32)
        parent = np.full((b, n), -1, dtype=np.int32)
        valid = np.zeros((b, n), dtype=bool)
        lengths = np.zeros(b, dtype=np.int32)
        for i, doc in enumerate(docs):
            m = len(doc)
            kind[i, :m] = doc.kind
            tag[i, :m] = doc.tag_id
            d, p = doc.structure()
            depth[i, :m] = d
            parent[i, :m] = p
            valid[i, :m] = doc.kind != PAD
            lengths[i] = m
        return cls(kind, tag, depth, parent, valid, lengths)

    def pad_to(self, n: int) -> "EventBatch":
        """Grow the event axis to ``n`` (no-op when already that long)."""
        cur = self.length
        if n < cur:
            raise ValueError(f"cannot pad {cur} events into {n}")
        if n == cur:
            return self
        b, extra = self.batch_size, n - cur
        return EventBatch(
            np.concatenate([self.kind, np.full((b, extra), PAD, np.int8)], 1),
            np.concatenate([self.tag_id, np.full((b, extra), -1, np.int32)], 1),
            np.concatenate([self.depth, np.zeros((b, extra), np.int32)], 1),
            np.concatenate([self.parent, np.full((b, extra), -1, np.int32)], 1),
            np.concatenate([self.valid, np.zeros((b, extra), bool)], 1),
            self.n_events,
        )

    def pad_batch_to(self, b: int) -> "EventBatch":
        """Grow the *batch* axis to ``b`` with inert all-PAD documents.

        The 2-D mesh path (``filter_batch_sharded2d``) partitions the
        batch axis over the mesh ``"data"`` axis, which requires the row
        count to divide evenly; pad documents carry zero events, so no
        engine can ever report a match for them, and callers slice the
        pad rows back off the result.
        """
        cur = self.batch_size
        if b < cur:
            raise ValueError(f"cannot pad batch of {cur} docs into {b}")
        if b == cur:
            return self
        extra, n = b - cur, self.length
        if self.is_device:
            import jax.numpy as jnp
            cat, full, zeros = jnp.concatenate, jnp.full, jnp.zeros
        else:
            cat, full, zeros = np.concatenate, np.full, np.zeros
        return EventBatch(
            cat([self.kind, full((extra, n), PAD, np.int8)]),
            cat([self.tag_id, full((extra, n), -1, np.int32)]),
            cat([self.depth, zeros((extra, n), np.int32)]),
            cat([self.parent, full((extra, n), -1, np.int32)]),
            cat([self.valid, zeros((extra, n), bool)]),
            cat([self.n_events, zeros(extra, np.int32)]),
        )

    # ------------------------------------------------------------ recovery
    def stream(self, i: int) -> "EventStream":
        """Document ``i`` as an un-padded :class:`EventStream`."""
        m = int(self.n_events[i])
        return EventStream(self.kind[i, :m].copy(), self.tag_id[i, :m].copy())

    def streams(self) -> Iterator["EventStream"]:
        for i in range(self.batch_size):
            yield self.stream(i)

    # ------------------------------------------------------------- metrics
    def nbytes(self, text_fill: int = 0) -> np.ndarray:
        """(B,) byte sizes in the paper's wire format (for MB/s stats)."""
        kind = np.asarray(self.kind)  # host metric; device batches transfer
        n_open = (kind == OPEN).sum(axis=1)
        n_close = (kind == CLOSE).sum(axis=1)
        return (n_open * (OPEN_NBYTES + text_fill)
                + n_close * CLOSE_NBYTES).astype(np.int64)


# ------------------------------------------------------------- byte batches
@dataclass
class ByteBatch:
    """Padded ``(B, L)`` uint8 batch of raw paper-format byte streams.

    The ingestion mirror of :class:`EventBatch`: where ``EventBatch`` is
    the *parsed* document format every engine consumes, ``ByteBatch`` is
    the *wire* format the device parser consumes —
    :func:`repro.kernels.parse.parse_batch` turns one into the other
    entirely on device (the paper's same-chip parser+filter, §1/§3.4).

    ``data`` is zero-padded: byte 0 is neither ``<`` nor a dictionary
    symbol, so padding decodes to no events by construction.  ``bucket``
    rounds ``L`` up to a boundary (see :func:`bucket_length`) to bound
    the number of compiled shapes, exactly like ``EventBatch`` padding.
    """

    data: np.ndarray     # (B, L) uint8 — raw bytes, zero-padded
    n_bytes: np.ndarray  # (B,)   int32 — true per-document byte counts

    def __post_init__(self) -> None:
        self.data = _as_field(self.data, np.uint8)
        self.n_bytes = _as_field(self.n_bytes, np.int32)
        assert self.data.ndim == 2
        assert self.n_bytes.shape == (self.data.shape[0],)

    @property
    def batch_size(self) -> int:
        return int(self.data.shape[0])

    @property
    def length(self) -> int:
        return int(self.data.shape[1])

    def __len__(self) -> int:
        return self.batch_size

    @property
    def is_device(self) -> bool:
        """True when ``data`` is a device (jax) array, not numpy."""
        return not isinstance(self.data, np.ndarray)

    def to_host(self) -> "ByteBatch":
        """Materialize on the host (no-op for numpy-backed batches)."""
        if not self.is_device:
            return self
        return ByteBatch(np.asarray(self.data), np.asarray(self.n_bytes))

    def pad_batch_to(self, b: int) -> "ByteBatch":
        """Grow the batch axis to ``b`` zero-byte rows (see
        :meth:`EventBatch.pad_batch_to`): byte 0 decodes to no events, so
        pad rows are inert by construction."""
        cur = self.batch_size
        if b < cur:
            raise ValueError(f"cannot pad batch of {cur} docs into {b}")
        if b == cur:
            return self
        extra = b - cur
        if self.is_device:
            import jax.numpy as jnp
            data = jnp.concatenate(
                [self.data, jnp.zeros((extra, self.length), jnp.uint8)])
        else:
            data = np.concatenate(
                [self.data, np.zeros((extra, self.length), np.uint8)])
        return ByteBatch(data, np.concatenate(
            [np.asarray(self.n_bytes), np.zeros(extra, np.int32)]))

    def device_put(self, mesh, axis: str = "data") -> "ByteBatch":
        """Sharding-aware placement: rows spread over a mesh axis.

        Pads the batch to a multiple of the mesh ``axis`` size (sharded
        placement needs even rows) and issues an *asynchronous*
        ``jax.device_put`` against a ``NamedSharding`` — the H2D transfer
        of batch *k+1* overlaps the filter step still running on batch
        *k*, which is what the double-buffered serve loop
        (:meth:`repro.data.filter_stage.FilterStage.route_bytes_pipelined`)
        builds on.  ``n_bytes`` stays host-side: it is batch metadata,
        read only by host accounting.
        """
        import jax

        ax = dict(mesh.shape).get(axis, 1)
        bb = self.pad_batch_to(bucket_length(self.batch_size, ax))
        sharding = jax.sharding.NamedSharding(
            mesh, jax.sharding.PartitionSpec(axis, None))
        return ByteBatch(jax.device_put(bb.data, sharding),
                         np.asarray(bb.n_bytes))

    @property
    def max_events(self) -> int:
        """Static upper bound on events per document.

        The fixed-width codec (§3.1) guarantees every event occupies at
        least ``OPEN_NBYTES`` bytes, so ``L // OPEN_NBYTES`` bounds the
        compacted event count — this is what makes the device parser's
        output shape static.
        """
        return max(1, self.length // OPEN_NBYTES)

    def event_bound(self, bucket: int | None = None) -> int:
        """Tight static bound on events per document: the max per-doc
        count of ``<`` markers (every event starts with one).

        One vectorized host pass over the byte tensor — batch *metadata*,
        like the length scan in :meth:`from_buffers`; the per-event
        validate/compact work stays on device.  Much tighter than
        :attr:`max_events` when documents carry text content, so the
        filter scan does not step through phantom padding events.
        """
        data = np.asarray(self.data)
        n = int((data == LT).sum(axis=1).max()) if data.size else 1
        return bucket_length(max(1, n), bucket)

    # ----------------------------------------------------------- building
    @classmethod
    def from_buffers(cls, bufs: Sequence[bytes],
                     bucket: int | None = None) -> "ByteBatch":
        """Stack raw byte payloads, zero-padded to a bucketed length."""
        if len(bufs) == 0:
            raise ValueError("empty batch")
        n = bucket_length(max((len(b) for b in bufs), default=1), bucket)
        data = np.zeros((len(bufs), n), dtype=np.uint8)
        lengths = np.zeros(len(bufs), dtype=np.int32)
        for i, buf in enumerate(bufs):
            arr = np.frombuffer(buf, dtype=np.uint8)
            data[i, : len(arr)] = arr
            lengths[i] = len(arr)
        return cls(data, lengths)

    @classmethod
    def from_streams(cls, docs: Sequence["EventStream"], text_fill: int = 0,
                     bucket: int | None = None) -> "ByteBatch":
        """Serialize event streams to the wire format and stack."""
        return cls.from_buffers(
            [encode_bytes(d, text_fill=text_fill) for d in docs],
            bucket=bucket)

    # ----------------------------------------------------------- recovery
    def buffer(self, i: int) -> bytes:
        """Document ``i`` as its un-padded byte string."""
        data = np.asarray(self.data)
        return bytes(data[i, : int(self.n_bytes[i])])

    def buffers(self) -> Iterator[bytes]:
        for i in range(self.batch_size):
            yield self.buffer(i)

    # ------------------------------------------------------------ metrics
    def nbytes_total(self) -> int:
        """True payload bytes across the batch (MB/s accounting)."""
        return int(np.asarray(self.n_bytes).sum())


# ------------------------------------------------------------ segment packing
#: ``starts`` sentinel past a segment's last real document.  The bytes
#: megakernel flushes document ``d`` when an event lands at or past
#: ``starts[d+1]``; event positions are always < 2³¹-1, so sentinel
#: boundaries are simply never crossed — no per-document count scalar.
SEG_SENTINEL = np.iinfo(np.int32).max


@dataclass
class SegmentPack:
    """Dense multi-document segments for the one-launch bytes megakernel.

    The padding-free counterpart of a ragged :class:`ByteBatch`: instead
    of every document padding to the longest, documents are concatenated
    back to back into ``(S, L)`` byte segments (first-fit decreasing, so
    short documents share a grid slot) with two per-segment tables:

    * ``starts`` ``(S, D+1)`` int32 — byte offset where each document
      begins; entries past the last real document are
      :data:`SEG_SENTINEL`.  The kernel resets its stack and flushes the
      finished document's accept lanes whenever the event stream crosses
      ``starts[d+1]``.
    * ``doc_ids`` ``(S, D)`` int32 — original batch row of each packed
      document, ``-1`` for unused slots; :meth:`scatter` uses it to map
      per-(segment, slot) verdicts back to ``(B, Q)`` batch order.

    Zero-byte documents are never packed (no bytes ⇒ no events ⇒ no
    match); scatter fills their rows with the no-match defaults.
    """

    data: np.ndarray      # (S, L) uint8 — concatenated docs, zero-padded
    starts: np.ndarray    # (S, D+1) int32 — doc start offsets + sentinels
    doc_ids: np.ndarray   # (S, D) int32 — original batch row, -1 unused
    batch_size: int       # B of the ByteBatch this was packed from
    n_bytes: np.ndarray   # (S,) int32 — live (non-pad) bytes per segment

    def __post_init__(self) -> None:
        self.data = _as_field(self.data, np.uint8)
        self.starts = _as_field(self.starts, np.int32)
        self.doc_ids = _as_field(self.doc_ids, np.int32)
        self.n_bytes = _as_field(self.n_bytes, np.int32)
        assert self.data.ndim == 2
        assert self.starts.shape[0] == self.data.shape[0]
        assert self.starts.shape[1] == self.doc_ids.shape[1] + 1
        assert self.n_bytes.shape == (self.data.shape[0],)

    @property
    def n_segments(self) -> int:
        return int(self.data.shape[0])

    @property
    def seg_len(self) -> int:
        return int(self.data.shape[1])

    @property
    def docs_per_segment(self) -> int:
        return int(self.doc_ids.shape[1])

    def pad_segments_to(self, s: int) -> "SegmentPack":
        """Grow the segment axis with inert all-sentinel segments (the
        2-D mesh data axis needs an even row count, cf.
        :meth:`ByteBatch.pad_batch_to`)."""
        cur = self.n_segments
        if s < cur:
            raise ValueError(f"cannot pad {cur} segments into {s}")
        if s == cur:
            return self
        extra = s - cur
        starts = np.full((extra, self.starts.shape[1]), SEG_SENTINEL,
                         np.int32)
        starts[:, 0] = 0
        return SegmentPack(
            np.concatenate([np.asarray(self.data),
                            np.zeros((extra, self.seg_len), np.uint8)]),
            np.concatenate([np.asarray(self.starts), starts]),
            np.concatenate([np.asarray(self.doc_ids),
                            np.full((extra, self.doc_ids.shape[1]), -1,
                                    np.int32)]),
            self.batch_size,
            np.concatenate([np.asarray(self.n_bytes),
                            np.zeros(extra, np.int32)]))

    def scatter(self, matched, first, no_match: int
                ) -> tuple[np.ndarray, np.ndarray]:
        """(S, D, Q) per-slot verdicts → (B, Q) batch-order results.

        ``no_match`` is the caller's first-event fill (the engine layer's
        ``NO_MATCH``) — passed in so this module stays engine-agnostic.
        Slots with ``doc_ids == -1`` (and dropped zero-byte documents)
        contribute nothing; their batch rows keep the no-match defaults.
        """
        q = matched.shape[-1]
        ids = np.asarray(self.doc_ids).ravel()
        live = ids >= 0
        m = np.zeros((self.batch_size, q), dtype=bool)
        f = np.full((self.batch_size, q), no_match, np.int32)
        m[ids[live]] = np.asarray(matched).reshape(-1, q)[live] != 0
        f[ids[live]] = np.asarray(first).reshape(-1, q)[live]
        return m, f

    def fill_fraction(self) -> float:
        """Live bytes / total segment bytes — the packing efficiency the
        ``events_per_slot`` benchmark metric builds on."""
        total = self.data.size
        if total == 0:
            return 0.0
        return float(np.asarray(self.n_bytes).sum()) / float(total)


def pack_segments(bb: "ByteBatch", *, target_len: int = 4096,
                  doc_bucket: int = 8) -> SegmentPack:
    """First-fit-decreasing pack of a :class:`ByteBatch` into segments.

    ``target_len`` is both the segment capacity target and the length
    bucket (the actual ``L`` is the smallest multiple of ``target_len``
    that fits the longest document, so one oversized document widens —
    never breaks — the pack).  ``doc_bucket`` buckets the per-segment
    document-slot count for shape stability across batches.
    """
    data = np.asarray(bb.data)
    lengths = np.asarray(bb.n_bytes).astype(np.int64)
    seg_len = bucket_length(max(1, int(lengths.max(initial=1))),
                            max(1, int(target_len)))
    order = np.argsort(-lengths, kind="stable")
    segs: list[list[int]] = []    # doc ids per segment
    used: list[int] = []          # bytes used per segment
    for i in order:
        n = int(lengths[i])
        if n == 0:
            continue              # no bytes ⇒ no events ⇒ never matches
        for s, u in enumerate(used):
            if u + n <= seg_len:
                segs[s].append(int(i))
                used[s] += n
                break
        else:
            segs.append([int(i)])
            used.append(n)
    if not segs:                  # all-empty batch: one inert segment
        segs, used = [[]], [0]
    d = bucket_length(max(len(s) for s in segs), max(1, int(doc_bucket)))
    out = np.zeros((len(segs), seg_len), np.uint8)
    starts = np.full((len(segs), d + 1), SEG_SENTINEL, np.int32)
    doc_ids = np.full((len(segs), d), -1, np.int32)
    for s, docs in enumerate(segs):
        off = 0
        for j, i in enumerate(docs):
            n = int(lengths[i])
            out[s, off:off + n] = data[i, :n]
            starts[s, j] = off
            doc_ids[s, j] = i
            off += n
        if not docs:
            starts[s, 0] = 0
    return SegmentPack(out, starts, doc_ids, bb.batch_size,
                       np.asarray(used, np.int32))


# ----------------------------------------------------------------- tree view
@dataclass
class Node:
    tag_id: int
    children: list["Node"]


def to_trees(ev: EventStream) -> list[Node]:
    """Event stream → forest of nodes (oracle engine input)."""
    roots: list[Node] = []
    stack: list[Node] = []
    for k, t in zip(ev.kind, ev.tag_id):
        if k == OPEN:
            node = Node(int(t), [])
            (stack[-1].children if stack else roots).append(node)
            stack.append(node)
        elif k == CLOSE:
            stack.pop()
    return roots


def from_trees(roots: list[Node]) -> EventStream:
    pairs: list[tuple[int, int]] = []

    def walk(n: Node) -> None:
        pairs.append((OPEN, n.tag_id))
        for c in n.children:
            walk(c)
        pairs.append((CLOSE, n.tag_id))

    for r in roots:
        walk(r)
    return EventStream.from_pairs(pairs)


# ----------------------------------------------------------------- byte codec
def encode_bytes(ev: EventStream, text_fill: int = 0) -> bytes:
    """Event stream → paper-format byte stream.

    ``text_fill`` inserts that many filler text bytes (``'x'``) after each
    open tag, emulating element text content (consumed by the paper's
    ``[\\w\\s]+`` regex blocks, structurally irrelevant).
    """
    out = bytearray()
    for k, t in zip(ev.kind, ev.tag_id):
        if k == OPEN:
            out += b"<" + TagDictionary.symbols_of(int(t)).encode() + b">"
            out += b"x" * text_fill
        elif k == CLOSE:
            out += b"</" + TagDictionary.symbols_of(int(t)).encode() + b">"
    return bytes(out)


def decode_bytes(buf: bytes, sym_table: np.ndarray) -> EventStream:
    """Byte stream → event stream (host reference for the predecode kernel).

    Vectorised with numpy the same way the Pallas kernel does it on-device:
    classify each byte position, then decode the two symbol bytes that follow
    each ``<`` / ``</`` marker.  Fixed-length tags (the paper's dictionary
    replacement) are what make this embarrassingly parallel.

    A ``<`` / ``</`` marker whose symbol bytes are not both in the
    64-symbol alphabet is *rejected* (no event emitted) — identical to
    the kernel's ``ok = (v0 >= 0) & (v1 >= 0)`` validation in
    :mod:`repro.kernels.predecode`, so host and device agree on
    malformed input.
    """
    b = np.frombuffer(buf, dtype=np.uint8)
    n = b.shape[0]
    if n == 0:
        return EventStream(np.zeros(0, np.int8), np.zeros(0, np.int32))
    is_lt = b == LT
    nxt = np.concatenate([b[1:], np.zeros(1, np.uint8)])
    is_close = is_lt & (nxt == SLASH)
    is_open = is_lt & ~is_close
    # symbol positions: open '<' at i → symbols at i+1, i+2 ; close at i+2, i+3
    idx = np.arange(n)
    s0 = np.where(is_close, idx + 2, idx + 1)
    s1 = s0 + 1
    # the kernel shifts zeros in past the end; byte 0 is not in the
    # alphabet, so out-of-range symbol positions are invalid there too
    v0 = np.where(s0 < n, sym_table[b[np.clip(s0, 0, n - 1)]], -1)
    v1 = np.where(s1 < n, sym_table[b[np.clip(s1, 0, n - 1)]], -1)
    ok = (v0 >= 0) & (v1 >= 0)
    tag = (v0 << 6) | v1
    keep = (is_open | is_close) & ok
    kind = np.where(is_close[keep], CLOSE, OPEN).astype(np.int8)
    return EventStream(kind, tag[keep].astype(np.int32))


_SYM_TABLE: np.ndarray | None = None


def _sym_table() -> np.ndarray:
    """The (256,) byte→symbol-value table (alphabet is fixed, §3.1)."""
    global _SYM_TABLE
    if _SYM_TABLE is None:
        _SYM_TABLE = TagDictionary().symbol_value_table()
    return _SYM_TABLE


def validate_payload(buf: bytes, *, max_depth: int = DEFAULT_MAX_DEPTH,
                     doc_index: int | None = None) -> None:
    """Cheap host-side pre-admission check for one wire payload.

    The serve loop's first failure domain (:meth:`repro.serve.loop.
    ServeLoop.submit`): known-bad bytes are rejected with a typed
    :class:`DocumentError` *before* they are batched with healthy
    documents or reach a kernel.  Vectorized numpy only — a handful of
    cumsums over the byte buffer, no per-event Python:

    * a ``<`` / ``</`` marker whose symbol bytes are outside the
      64-symbol alphabet (the kernel would silently drop it, skewing
      structure) → :class:`MalformedDocument`;
    * close-without-open or unclosed elements (depth scan goes negative
      / ends above zero) → :class:`MalformedDocument`;
    * nesting beyond ``max_depth`` (parent pointers past the parser's
      bounded stack would be wrong) → :class:`DepthOverflow`.

    An empty payload is *valid*: zero bytes decode to zero events, the
    inert document every batch-padding path already relies on.  Checks
    mirror kernel semantics exactly (cf. :func:`decode_bytes`): anything
    this function admits, the device parser handles deterministically.
    """
    idx = () if doc_index is None else (doc_index,)
    b = np.frombuffer(buf, dtype=np.uint8)
    n = b.shape[0]
    if n == 0:
        return
    sym = _sym_table()
    is_lt = b == LT
    nxt = np.concatenate([b[1:], np.zeros(1, np.uint8)])
    is_close = is_lt & (nxt == SLASH)
    is_open = is_lt & ~is_close
    pos = np.arange(n)
    s0 = np.where(is_close, pos + 2, pos + 1)
    s1 = s0 + 1
    v0 = np.where(s0 < n, sym[b[np.clip(s0, 0, n - 1)]], -1)
    v1 = np.where(s1 < n, sym[b[np.clip(s1, 0, n - 1)]], -1)
    ok = (v0 >= 0) & (v1 >= 0)
    marker = is_open | is_close
    bad = marker & ~ok
    if bad.any():
        where = int(np.flatnonzero(bad)[0])
        raise MalformedDocument(
            f"undecodable tag marker at byte {where}", idx)
    delta = np.where(is_open & ok, 1, 0) - np.where(is_close & ok, 1, 0)
    depth = np.cumsum(delta)
    if depth.min(initial=0) < 0:
        raise MalformedDocument("close tag without matching open", idx)
    if depth.size and depth[-1] != 0:
        raise MalformedDocument(f"{int(depth[-1])} unclosed elements", idx)
    dmax = int(depth.max(initial=0))
    if dmax > max_depth:
        raise DepthOverflow(
            f"document nesting depth {dmax} exceeds max_depth={max_depth}",
            idx)


def event_stream_nbytes(ev: EventStream, text_fill: int = 0) -> int:
    n_open = int((ev.kind == OPEN).sum())
    n_close = int((ev.kind == CLOSE).sum())
    return n_open * (OPEN_NBYTES + text_fill) + n_close * CLOSE_NBYTES
