"""FPGA area model — the Fig-8 reproduction (§4.1).

FPGA "area" (LUTs/FFs) has no direct TPU meaning, so the paper's area
experiment is reproduced with an explicit *hardware cost model* counting
bit-comparator equivalents per NFA block, the same unit the paper's own
optimizations act on:

* a tag matcher without the pre-decoder costs 8 bit-comparators per
  character (Fig 6); with the §3.4 pre-decoder it costs 1 per character
  (Fig 7) plus a one-time shared 256-line decoder;
* an ancestor-descendant step adds the waiting block and a negation
  (close-tag) matcher (Fig 3);
* a parent-child step adds a TOS compare against the 12-bit tag id; the
  tag stack itself is shared once per stream (Fig 4);
* common-prefix sharing (§3.3) is modelled by building the shared vs.
  unshared NFA and costing each state once.

The same module also reports the *measured* TPU analogue: bytes of
transition tables / working set per engine, used by benchmarks/bench_area.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Sequence

from .dictionary import CLOSE_NBYTES, OPEN_NBYTES, TagDictionary
from .nfa import K_LOOP, K_MATCH, NFA, WILD_TAG, compile_queries
from .xpath import Query

# Virtex-4 LX200 logic capacity (paper's target device, §3.5):
# 89,088 slices × 2 LUTs — used to express model cost as chip %.
VIRTEX4_LX200_LUTS = 178_176

SCENARIOS = ("Unop", "Com-P", "Unop-CharDec", "Com-P-CharDec")

TAG_ID_BITS = 12          # 4096-entry dictionary (§3.1)
STACK_DEPTH = 64          # shared document stack depth
FF_COST = 1               # one flip-flop per state
WAIT_CLASS_COST = 16      # [<\c\d>]* char-class logic, full comparators
WAIT_CLASS_COST_DEC = 2   # …with pre-decoded class lines
CHARDEC_COST = 2048       # shared 256-way decoder (256 × 8-bit compare)


@dataclass(frozen=True)
class AreaReport:
    scenario: str
    n_queries: int
    n_states: int
    bit_cost: int
    part: int | None = None  # partition index for sharded plans (per-FPGA)

    @property
    def chip_fraction(self) -> float:
        return self.bit_cost / VIRTEX4_LX200_LUTS


def _matcher_cost(nbytes: int, chardec: bool) -> int:
    return nbytes * (1 if chardec else 8)


def nfa_bit_cost(nfa: NFA, *, chardec: bool) -> int:
    """Cost of one compiled NFA under the block-level model."""
    t = nfa.tables
    cost = CHARDEC_COST if chardec else 0
    any_child = False
    for s in range(1, t.in_state.shape[0]):
        kind = int(t.kind[s])
        cost += FF_COST
        if kind == K_MATCH:
            if int(t.in_tag[s]) == WILD_TAG:
                cost += _matcher_cost(2, chardec)   # '<' '>' markers only
            else:
                cost += _matcher_cost(OPEN_NBYTES, chardec)
            # parent-child steps: the in-edge source is a match state, not a
            # loop — they carry the TOS compare (Fig 4).
            src_kind = int(t.kind[int(t.in_state[s])])
            if src_kind != K_LOOP:
                # root-anchored first steps also use level-1 check; count it
                cost += TAG_ID_BITS
                any_child = True
        elif kind == K_LOOP:
            # waiting block + negation (close-tag) matcher
            cost += (WAIT_CLASS_COST_DEC if chardec else WAIT_CLASS_COST)
            cost += _matcher_cost(CLOSE_NBYTES, chardec)
    # shared stack (once per stream) if any stack-group profile exists
    if any_child:
        cost += STACK_DEPTH * TAG_ID_BITS
    # output priority encoders (two: stack group and regex group, §3.5)
    q = nfa.n_queries
    cost += q * max(1, math.ceil(math.log2(max(q, 2))))
    return cost


def area_report(queries: Sequence[Query], dictionary: TagDictionary,
                scenario: str) -> AreaReport:
    if scenario not in SCENARIOS:
        raise ValueError(scenario)
    shared = scenario.startswith("Com-P")
    chardec = scenario.endswith("CharDec")
    nfa = compile_queries(list(queries), dictionary, shared=shared)
    return AreaReport(
        scenario=scenario,
        n_queries=len(queries),
        n_states=nfa.n_states,
        bit_cost=nfa_bit_cost(nfa, chardec=chardec),
    )


def area_report_sharded(queries: Sequence[Query], dictionary: TagDictionary,
                        scenario: str, n_parts: int) -> list[AreaReport]:
    """Per-part area of a partitioned profile set — one row per part.

    The paper's area model is per-FPGA; partitioning the query set
    across chips (§3.5, the multi-chip scaling table) makes the cost of
    each chip the cost of *its* sub-NFA.  Balanced partitions show up
    here directly: the max row bounds the required device, the sum is
    the total silicon.  Shared-prefix dedup happens within a part (the
    partitioner keeps prefix groups together precisely so this cost
    does not explode versus the monolithic NFA).
    """
    from .nfa import partition_queries

    if scenario not in SCENARIOS:
        raise ValueError(scenario)
    shared = scenario.startswith("Com-P")
    chardec = scenario.endswith("CharDec")
    parts, partition = partition_queries(list(queries), n_parts, dictionary,
                                         shared=shared)
    sizes = partition.part_sizes()
    return [
        AreaReport(
            scenario=scenario,
            n_queries=int(sizes[p]),
            n_states=nfa.n_states,
            bit_cost=nfa_bit_cost(nfa, chardec=chardec),
            part=p,
        )
        for p, nfa in enumerate(parts)
    ]


def engine_table_bytes(nfa: NFA) -> dict[str, int]:
    """Measured TPU analogue: bytes of device-resident transition state."""
    s = nfa.n_states
    t = nfa.n_tags
    q = nfa.n_queries
    return {
        # levelwise matmul path: REQ (T,S) f32 + parent one-hot (S,S) f32
        "levelwise_tables": 4 * (t * s + s * s + 4 * s + q),
        # streaming packed path: int32 vectors + uint32 words
        "streaming_tables": 4 * (3 * s + s // 32 + q),
        # per-document working set: stack of packed words
        "streaming_stack": 4 * (STACK_DEPTH + 2) * max(s // 32, 1),
    }
