"""XPath subset parser → Query IR.

The paper (§3) supports linear XPath profiles over two navigation axes:

  * parent-child        ``/``   (requires the stack + TOS-match hardware, Fig 4)
  * ancestor-descendant ``//``  (plain regular-expression hardware, Fig 3)

plus tag names and the ``*`` wildcard.  This module parses that subset into a
tiny immutable IR used by the NFA compiler (:mod:`repro.core.nfa`).

Grammar (no predicates, no attributes — same scope as the paper)::

    query  := axis? step (axis step)*
    axis   := '/' | '//'
    step   := NAME | '*'

Leading-axis convention: a leading ``/`` anchors the first step at the
document root (it must match a top-level element); a leading ``//`` (or a bare
leading tag, which PCRE's unanchored search semantics in the paper imply)
matches the first step at any depth.
"""
from __future__ import annotations

import re
from dataclasses import dataclass
from typing import Iterable, Sequence

CHILD = 0   # parent-child axis  '/'
DESC = 1    # ancestor-descendant axis '//'

_NAME_RE = re.compile(r"[A-Za-z_][-A-Za-z0-9_.]*|\*")

AXIS_NAMES = {CHILD: "/", DESC: "//"}

WILDCARD = "*"


class XPathSyntaxError(ValueError):
    """Raised when a profile string is outside the supported subset."""


@dataclass(frozen=True)
class Step:
    """One location step: an axis and a tag test."""

    axis: int       # CHILD or DESC
    tag: str        # tag name, or '*' for the wildcard node test

    def __post_init__(self) -> None:
        if self.axis not in (CHILD, DESC):
            raise XPathSyntaxError(f"bad axis {self.axis!r}")
        if not _NAME_RE.fullmatch(self.tag):
            raise XPathSyntaxError(f"bad tag test {self.tag!r}")

    def __str__(self) -> str:  # pragma: no cover - debugging aid
        return f"{AXIS_NAMES[self.axis]}{self.tag}"


@dataclass(frozen=True)
class Query:
    """A parsed linear XPath profile."""

    steps: tuple[Step, ...]
    raw: str

    @property
    def length(self) -> int:
        return len(self.steps)

    @property
    def has_parent_child(self) -> bool:
        """True if any *non-leading* '/' axis is present.

        The paper groups profiles into "with parent-child axes" (need the
        on-chip stack) and "without" (pure regex) — §3.5, Fig 5.  A leading
        '/' only anchors at the root which the regex engine can express, so
        the grouping looks at steps after the first.
        """
        return any(s.axis == CHILD for s in self.steps[1:])

    @property
    def anchored(self) -> bool:
        """True if the profile starts with a root-anchored '/' step."""
        return self.steps[0].axis == CHILD

    def __str__(self) -> str:
        return "".join(str(s) for s in self.steps)


def parse(profile: str) -> Query:
    """Parse one XPath profile string into a :class:`Query`."""
    s = profile.strip()
    if not s:
        raise XPathSyntaxError("empty profile")
    pos = 0
    steps: list[Step] = []
    first = True
    while pos < len(s):
        if s.startswith("//", pos):
            axis, pos = DESC, pos + 2
        elif s.startswith("/", pos):
            axis, pos = CHILD, pos + 1
        elif first:
            # bare leading tag: PCRE unanchored search ⇒ descendant semantics
            axis = DESC
        else:
            raise XPathSyntaxError(f"expected axis at {pos} in {profile!r}")
        m = _NAME_RE.match(s, pos)
        if not m:
            raise XPathSyntaxError(f"expected tag test at {pos} in {profile!r}")
        steps.append(Step(axis, m.group(0)))
        pos = m.end()
        first = False
    return Query(tuple(steps), profile)


def parse_all(profiles: Iterable[str]) -> list[Query]:
    return [parse(p) for p in profiles]


def tags_of(queries: Sequence[Query]) -> list[str]:
    """All distinct concrete tag names referenced by the profiles (sorted)."""
    tags = {st.tag for q in queries for st in q.steps if st.tag != WILDCARD}
    return sorted(tags)
