"""Dictionary replacement (§3.1 of the paper).

XML tags in both the documents and the profiles are replaced by *fixed
length* two-symbol strings so that every open tag occupies exactly 32 bits
(``<`` + 2 symbols + ``>``) and every close tag exactly 40 bits
(``</`` + 2 symbols + ``>``) on the wire.  Fixed-length tags are what make
the byte stream *parallel-decodable* — the property our TPU pre-decode
kernel (and the paper's character pre-decoder) relies on.

The symbol alphabet is 64 characters (``a-z A-Z 0-9 _ .``) giving 4096
distinct tags per dictionary, far more than any evaluated profile set.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Iterable, Mapping

import numpy as np

ALPHABET = (
    "abcdefghijklmnopqrstuvwxyz"
    "ABCDEFGHIJKLMNOPQRSTUVWXYZ"
    "0123456789_."
)
assert len(ALPHABET) == 64
_CHAR_TO_VAL = {c: i for i, c in enumerate(ALPHABET)}

MAX_TAGS = 64 * 64

OPEN_NBYTES = 4    # '<'  s0 s1 '>'   = 32 bits  (paper §3.1)
CLOSE_NBYTES = 5   # '<' '/' s0 s1 '>' = 40 bits

LT, GT, SLASH = ord("<"), ord(">"), ord("/")


class DictionaryFull(ValueError):
    pass


@dataclass
class TagDictionary:
    """Bidirectional tag-name ⇄ fixed-length-symbol-id mapping."""

    tag_to_id: dict[str, int] = field(default_factory=dict)
    id_to_tag: list[str] = field(default_factory=list)

    # ------------------------------------------------------------- building
    @classmethod
    def build(cls, tags: Iterable[str]) -> "TagDictionary":
        d = cls()
        for t in tags:
            d.add(t)
        return d

    def add(self, tag: str) -> int:
        if tag in self.tag_to_id:
            return self.tag_to_id[tag]
        if len(self.id_to_tag) >= MAX_TAGS:
            raise DictionaryFull(f"dictionary limited to {MAX_TAGS} tags")
        tid = len(self.id_to_tag)
        self.tag_to_id[tag] = tid
        self.id_to_tag.append(tag)
        return tid

    def __len__(self) -> int:
        return len(self.id_to_tag)

    def __contains__(self, tag: str) -> bool:
        return tag in self.tag_to_id

    def lookup(self, tag: str) -> int:
        return self.tag_to_id[tag]

    # ------------------------------------------------- symbol-level codecs
    @staticmethod
    def symbols_of(tid: int) -> str:
        """The two-symbol replacement string for a tag id (e.g. 0 → 'aa')."""
        return ALPHABET[tid >> 6] + ALPHABET[tid & 63]

    @staticmethod
    def id_of_symbols(sym: str) -> int:
        return (_CHAR_TO_VAL[sym[0]] << 6) | _CHAR_TO_VAL[sym[1]]

    def open_bytes(self, tid: int) -> bytes:
        return b"<" + self.symbols_of(tid).encode() + b">"

    def close_bytes(self, tid: int) -> bytes:
        return b"</" + self.symbols_of(tid).encode() + b">"

    # --------------------------------------------------- vectorised tables
    def symbol_value_table(self) -> np.ndarray:
        """(256,) int32: byte value → symbol value, -1 for non-alphabet."""
        table = np.full(256, -1, dtype=np.int32)
        for c, v in _CHAR_TO_VAL.items():
            table[ord(c)] = v
        return table

    def rewrite_profile_tags(self, queries) -> list:
        """Dictionary-replace tag names inside parsed queries (→ new Query list).

        Mirrors the paper's step 1: profiles and documents are rewritten to
        the fixed-length encoding *before* regex generation.
        """
        from .xpath import Query, Step, WILDCARD

        out = []
        for q in queries:
            steps = tuple(
                Step(s.axis, s.tag if s.tag == WILDCARD else self.symbols_of(self.add(s.tag)))
                for s in q.steps
            )
            out.append(Query(steps, q.raw))
        return out


def symbol_values(dictionary: Mapping[str, int] | TagDictionary) -> np.ndarray:
    if isinstance(dictionary, TagDictionary):
        return dictionary.symbol_value_table()
    raise TypeError(type(dictionary))
