"""Twig pattern filtering — the paper's §5 extension, implemented.

The paper closes with twig profiles as an open problem and sketches the
"straightforward solution": decompose the twig into root-to-leaf paths,
filter each path with the existing XPath architecture, and join the
results in post-processing, eliminating the two stated inefficiencies as
far as possible:

* false positives (paths matching in unrelated places) are removed by an
  exact structural verification pass, run only on the (few) documents
  whose every path matched;
* redundant common-section processing is avoided for free: all
  decomposed paths enter **one shared prefix-tree NFA** (§3.3), so the
  twig's trunk is evaluated once, by construction.

Syntax: linear steps as in :mod:`repro.core.xpath` plus branch
predicates in brackets — ``a[b//c][d]/e`` means: an ``a`` element with a
descendant chain ``b//c`` and a child... (branch axes are the branch's
leading axis), whose child ``e`` ends the output path.

Semantics: boolean filtering (does the document contain a match of the
whole twig?), same as the path engines.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Sequence

import numpy as np

from .dictionary import TagDictionary
from .engines.result import NO_MATCH, FilterResult
from .events import EventStream, to_trees, Node
from .nfa import compile_queries
from .xpath import CHILD, DESC, Query, Step, WILDCARD, XPathSyntaxError


@dataclass(frozen=True)
class TwigNode:
    axis: int          # axis from the parent twig node
    tag: str
    branches: tuple["TwigNode", ...]   # predicate branches
    child: "TwigNode | None"           # continuation of the main path

    def all_children(self) -> tuple["TwigNode", ...]:
        return self.branches + ((self.child,) if self.child else ())


@dataclass(frozen=True)
class TwigQuery:
    root: TwigNode
    raw: str

    @property
    def is_linear(self) -> bool:
        n, linear = self.root, True
        while n is not None:
            if n.branches:
                return False
            n = n.child
        return True


# ------------------------------------------------------------------ parser
def parse_twig(s: str) -> TwigQuery:
    pos = 0
    text = s.strip()

    def parse_axis(default: int | None) -> int:
        nonlocal pos
        if text.startswith("//", pos):
            pos += 2
            return DESC
        if text.startswith("/", pos):
            pos += 1
            return CHILD
        if default is not None:
            return default
        raise XPathSyntaxError(f"expected axis at {pos} in {s!r}")

    def parse_name() -> str:
        nonlocal pos
        import re
        m = re.compile(r"[A-Za-z_][-A-Za-z0-9_.]*|\*").match(text, pos)
        if not m:
            raise XPathSyntaxError(f"expected tag at {pos} in {s!r}")
        pos = m.end()
        return m.group(0)

    def parse_node(default_axis: int | None) -> TwigNode:
        nonlocal pos
        axis = parse_axis(default_axis)
        tag = parse_name()
        branches = []
        while pos < len(text) and text[pos] == "[":
            pos += 1
            # bare branch head = child axis (XPath predicate semantics)
            branches.append(parse_node(default_axis=CHILD))
            if pos >= len(text) or text[pos] != "]":
                raise XPathSyntaxError(f"unclosed '[' in {s!r}")
            pos += 1
        child = None
        if pos < len(text) and text[pos] == "/":
            child = parse_node(default_axis=None)
        elif pos < len(text) and text[pos] not in "]":
            raise XPathSyntaxError(f"unexpected {text[pos]!r} at {pos}")
        return TwigNode(axis, tag, tuple(branches), child)

    root = parse_node(default_axis=DESC)
    if pos != len(text):
        raise XPathSyntaxError(f"trailing input at {pos} in {s!r}")
    return TwigQuery(root, s)


# ------------------------------------------------- path decomposition (§5)
def decompose(tq: TwigQuery) -> list[Query]:
    """Twig → root-to-leaf linear paths (the paper's decomposition)."""
    paths: list[list[Step]] = []

    def walk(node: TwigNode, prefix: list[Step]) -> None:
        prefix = prefix + [Step(node.axis, node.tag)]
        kids = node.all_children()
        if not kids:
            paths.append(prefix)
            return
        for k in kids:
            walk(k, prefix)

    walk(tq.root, [])
    return [Query(tuple(p), tq.raw) for p in paths]


# ----------------------------------------------------- exact verification
def _twig_matches_tree(roots: list[Node], tq: TwigQuery,
                       dictionary: TagDictionary) -> bool:
    """Ground-truth recursive twig matcher (the join/verify step)."""

    def tag_ok(node: Node, tag: str) -> bool:
        return tag == WILDCARD or dictionary.tag_to_id.get(tag, -1) == \
            node.tag_id

    def match_at(node: Node, tn: TwigNode) -> bool:
        """tn matches rooted exactly at `node` (tag already to check)."""
        if not tag_ok(node, tn.tag):
            return False
        for b in tn.all_children():
            if not any(match_from(c, b, node) for c in _candidates(node, b)):
                return False
        return True

    def _candidates(node: Node, b: TwigNode):
        if b.axis == CHILD:
            return node.children
        out = []

        def collect(n: Node):
            for c in n.children:
                out.append(c)
                collect(c)

        collect(node)
        return out

    def match_from(node: Node, tn: TwigNode, parent: Node) -> bool:
        return match_at(node, tn)

    def all_nodes():
        out = []

        def collect(n: Node):
            out.append(n)
            for c in n.children:
                collect(c)

        for r in roots:
            collect(r)
        return out

    r = tq.root
    if r.axis == CHILD:  # anchored at document root
        cands = roots
    else:
        cands = all_nodes()
    return any(match_at(c, r) for c in cands)


# ----------------------------------------------------------------- engine
class TwigFilter:
    """Two-stage twig filtering (paper §5 'straightforward solution').

    Stage 1 — all decomposed paths of all twigs share ONE prefix-tree NFA
    and run on any path engine (levelwise by default); a twig survives iff
    every one of its paths matched (necessary condition).
    Stage 2 — survivors are verified exactly on the document tree,
    eliminating the decomposition's false positives.

    ``stats`` records how much work stage 2 actually did — the measure of
    the false-positive rate the paper worries about.
    """

    def __init__(self, twigs: Sequence[str | TwigQuery],
                 dictionary: TagDictionary, engine: str = "levelwise"):
        self.twigs = [t if isinstance(t, TwigQuery) else parse_twig(t)
                      for t in twigs]
        self.dictionary = dictionary
        self.paths: list[Query] = []
        self.path_owner: list[int] = []
        for ti, tq in enumerate(self.twigs):
            for q in decompose(tq):
                self.paths.append(q)
                self.path_owner.append(ti)
        self.nfa = compile_queries(self.paths, dictionary, shared=True)
        from . import engines as engine_registry
        self._eng = engine_registry.create(engine, self.nfa,
                                           dictionary=dictionary)
        self.stats = {"stage2_checks": 0, "stage2_rejects": 0}

    def filter_document(self, ev: EventStream) -> FilterResult:
        path_res = self._eng.filter_document(ev)
        n_t = len(self.twigs)
        candidate = np.ones(n_t, dtype=bool)
        for pi, owner in enumerate(self.path_owner):
            candidate[owner] &= bool(path_res.matched[pi])
        matched = np.zeros(n_t, dtype=bool)
        roots = None
        for ti in np.nonzero(candidate)[0]:
            if self.twigs[ti].is_linear:
                matched[ti] = True       # single path ⇒ exact already
                continue
            if roots is None:
                roots = to_trees(ev)
            self.stats["stage2_checks"] += 1
            ok = _twig_matches_tree(roots, self.twigs[ti], self.dictionary)
            matched[ti] = ok
            if not ok:
                self.stats["stage2_rejects"] += 1
        first = np.full(n_t, NO_MATCH, np.int32)
        for pi, owner in enumerate(self.path_owner):
            if matched[owner]:
                first[owner] = min(first[owner], path_res.first_event[pi])
        return FilterResult(matched, first)
