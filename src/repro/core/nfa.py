"""Query IR → NFA with single-parent trie structure (§3.2–3.3 of the paper).

The paper implements each XPath profile as a chain of hardware blocks
(Fig 3/4): per-tag matchers, "waiting" blocks (``[<\\c\\d>]*``) for the
ancestor-descendant axis, and a shared document stack for parent-child
checks.  YFilter's software equivalent is an NFA whose states form a
prefix-shared trie.

This module compiles parsed :class:`repro.core.xpath.Query` objects into a
*vector-friendly* NFA representation designed so that the whole active-set
transition is three dense vector ops (gather, compare, mask) — the TPU
analogue of the FPGA advancing every matcher block in one clock:

    active_v[s] = (A[in_state[s]] & tagmatch[s](t))  |  (selfloop[s] & A[s])

where ``A`` is the active set in the *parent context* (the paper's
top-of-stack) and ``t`` is the tag of the node being opened.

State kinds
-----------
* ``root`` (state 0) — active only in the document-root context.
* ``match`` (M) — one per location step; its in-edge carries the step's
  tag test.  The paper's per-tag comparator block.
* ``loop`` (L) — one per ancestor-descendant step; copies the in-edge of
  the step's *source* state and self-loops, which realises the ε-closure
  of YFilter's ``//`` construction without ε-edges:

      active[L] = (A[in(src)] & match(src-edge)) | A[L]
                =  active[src] | A[L]

  i.e. L switches on exactly when src does and stays on for the whole
  subtree — the paper's ``[<\\c\\d>]*`` waiting block, with the negation
  block on ``</src>`` realised *exactly* (not approximately) because the
  parent-context stack restores A on close.

Parent-child steps need no extra state: the in-edge from the parent's M
state only fires when that M is in the parent context — the TOS-match of
Fig 4 is implicit in the stack discipline.

Sharing (§3.3): :func:`compile_queries` with ``shared=True`` dedups states
by ``(source, axis, tag)`` so common prefixes are single blocks (Com-P
scenario); ``shared=False`` builds disjoint chains per query (Unop).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import NamedTuple, Sequence

import numpy as np

from .dictionary import TagDictionary
from .xpath import CHILD, DESC, Query, WILDCARD

# sentinel tag ids used in in_tag
WILD_TAG = -2   # matches every tag (the '*' node test)
NEVER_TAG = -3  # matches no tag (root, init-only loop states)

K_ROOT, K_MATCH, K_LOOP = 0, 1, 2


class NFATables(NamedTuple):
    """Dense vector form of the NFA — everything the engines need."""

    in_state: np.ndarray      # (S,) int32 — single parent state
    in_tag: np.ndarray        # (S,) int32 — tag id, WILD_TAG or NEVER_TAG
    selfloop: np.ndarray      # (S,) bool  — ancestor-descendant waiting states
    init: np.ndarray          # (S,) bool  — active in the root context
    accept_state: np.ndarray  # (Q,) int32 — accept state per query
    kind: np.ndarray          # (S,) int8  — K_ROOT / K_MATCH / K_LOOP

    @property
    def n_states(self) -> int:
        return int(self.in_state.shape[0])

    @property
    def n_queries(self) -> int:
        return int(self.accept_state.shape[0])


@dataclass
class NFA:
    tables: NFATables
    queries: tuple[Query, ...]
    shared: bool
    n_tags: int  # size of the tag-id space (dictionary size)

    @property
    def n_states(self) -> int:
        return self.tables.n_states

    @property
    def n_queries(self) -> int:
        return self.tables.n_queries

    # ------------------------------------------------------- dense matrices
    def req_matrix(self, dtype=np.float32) -> np.ndarray:
        """(T, S) 0/1 matrix: REQ[t, s] = 1 iff in_tag[s] == t.

        ``onehot(tag) @ REQ`` is the per-state tag-match vector — the MXU
        form of the paper's character pre-decoder (§3.4): the one-hot
        decode happens once per symbol and every matcher consumes 1 bit.
        """
        t = self.tables
        req = np.zeros((self.n_tags, t.in_state.shape[0]), dtype=dtype)
        concrete = t.in_tag >= 0
        req[t.in_tag[concrete], np.nonzero(concrete)[0]] = 1
        return req

    def wild_vector(self, dtype=np.float32) -> np.ndarray:
        """(S,) 0/1: states whose in-edge matches any tag."""
        return (self.tables.in_tag == WILD_TAG).astype(dtype)

    def parent_onehot(self, dtype=np.float32) -> np.ndarray:
        """(S, S) 0/1 matrix P with P[in_state[s], s] = 1.

        ``A @ P`` gathers each state's parent activity — the MXU form of
        the wire from the previous matcher block on the FPGA.
        """
        t = self.tables
        s = t.in_state.shape[0]
        p = np.zeros((s, s), dtype=dtype)
        p[t.in_state, np.arange(s)] = 1
        return p

    def accept_matrix(self, dtype=np.float32) -> np.ndarray:
        """(S, Q) 0/1: ACC[s, q] = 1 iff s is query q's accept state."""
        t = self.tables
        acc = np.zeros((self.n_states, self.n_queries), dtype=dtype)
        acc[t.accept_state, np.arange(self.n_queries)] = 1
        return acc

    # ------------------------------------------------ reference transition
    def initial_active(self) -> np.ndarray:
        return self.tables.init.copy()

    def step_active(self, parent_active: np.ndarray, tag: int) -> np.ndarray:
        """One OPEN-tag transition (numpy reference used by tests/engines)."""
        t = self.tables
        tagmatch = (t.in_tag == tag) | (t.in_tag == WILD_TAG)
        src = parent_active[t.in_state]
        return (src & tagmatch) | (t.selfloop & parent_active)


class _Builder:
    def __init__(self) -> None:
        self.in_state: list[int] = [0]
        self.in_tag: list[int] = [NEVER_TAG]
        self.selfloop: list[bool] = [False]
        self.init: list[bool] = [True]
        self.kind: list[int] = [K_ROOT]
        self._memo: dict[tuple, int] = {}

    def _new(self, in_state: int, in_tag: int, selfloop: bool, init: bool,
             kind: int) -> int:
        sid = len(self.in_state)
        self.in_state.append(in_state)
        self.in_tag.append(in_tag)
        self.selfloop.append(selfloop)
        self.init.append(init)
        self.kind.append(kind)
        return sid

    def step(self, cur: int, axis: int, tag_id: int, shared: bool) -> int:
        """Extend the trie from state ``cur`` with one location step."""
        if axis == CHILD:
            key = (cur, CHILD, tag_id)
            if shared and key in self._memo:
                return self._memo[key]
            m = self._new(cur, tag_id, False, False, K_MATCH)
            if shared:
                self._memo[key] = m
            return m
        # DESC: waiting/loop state L + match state M
        lkey = (cur, "loop")
        if shared and lkey in self._memo:
            loop = self._memo[lkey]
        else:
            # L copies cur's in-edge → switches on exactly when cur does,
            # self-loop keeps it on for the whole subtree of cur.
            loop = self._new(self.in_state[cur], self.in_tag[cur],
                             True, self.init[cur], K_LOOP)
            # if cur itself self-loops (never happens for M/root sources,
            # defensive), preserve reachability
            if shared:
                self._memo[lkey] = loop
        mkey = (loop, DESC, tag_id)
        if shared and mkey in self._memo:
            return self._memo[mkey]
        m = self._new(loop, tag_id, False, False, K_MATCH)
        if shared:
            self._memo[mkey] = m
        return m


def compile_queries(
    queries: Sequence[Query],
    dictionary: TagDictionary,
    *,
    shared: bool = True,
) -> NFA:
    """Compile parsed profiles to the vector NFA.

    Tag names in the queries are resolved through ``dictionary`` (adding
    them if absent — profiles are known ahead of time in pub-sub, §1).
    ``shared=True`` is the paper's common-prefix optimization (§3.3).
    """
    b = _Builder()
    accepts: list[int] = []
    for q in queries:
        cur = 0
        for st in q.steps:
            tag_id = WILD_TAG if st.tag == WILDCARD else dictionary.add(st.tag)
            cur = b.step(cur, st.axis, tag_id, shared)
        accepts.append(cur)
    tables = NFATables(
        in_state=np.asarray(b.in_state, dtype=np.int32),
        in_tag=np.asarray(b.in_tag, dtype=np.int32),
        selfloop=np.asarray(b.selfloop, dtype=bool),
        init=np.asarray(b.init, dtype=bool),
        accept_state=np.asarray(accepts, dtype=np.int32),
        kind=np.asarray(b.kind, dtype=np.int8),
    )
    return NFA(tables=tables, queries=tuple(queries), shared=shared,
               n_tags=max(len(dictionary), 1))


def pad_states(nfa: NFA, multiple: int = 128, *, to: int | None = None) -> NFA:
    """Pad the state space to a lane-aligned multiple (TPU tiling).

    ``multiple`` comes from the engine's plan metadata
    (:attr:`repro.core.engines.base.FilterEngine.state_multiple`): the
    streaming engine packs 32-state words, the MXU engines want 128-lane
    tiles, host engines need no padding at all.  ``to`` pads to an exact
    state count instead (used by sharded plans, where every partition
    must share one padded state space so per-part tables stack along a
    leading axis).

    Padding states are inert: parent = self? No — parent 0 with NEVER tag
    and no selfloop, never active.
    """
    t = nfa.tables
    s = t.in_state.shape[0]
    if to is not None:
        if to < s:
            raise ValueError(f"cannot pad {s} states into {to}")
        padded = to - s
    else:
        padded = -s % multiple
    if padded == 0:
        return nfa
    tables = NFATables(
        in_state=np.concatenate([t.in_state, np.zeros(padded, np.int32)]),
        in_tag=np.concatenate([t.in_tag, np.full(padded, NEVER_TAG, np.int32)]),
        selfloop=np.concatenate([t.selfloop, np.zeros(padded, bool)]),
        init=np.concatenate([t.init, np.zeros(padded, bool)]),
        accept_state=t.accept_state,
        kind=np.concatenate([t.kind, np.full(padded, K_MATCH, np.int8)]),
    )
    return NFA(tables=tables, queries=nfa.queries, shared=nfa.shared,
               n_tags=nfa.n_tags)


# ---------------------------------------------------------------- minimization
class MinimizeStats(NamedTuple):
    """What :func:`minimize` achieved, for bench/telemetry columns."""

    states_before: int      # states in the input automaton
    states_after: int       # states after global merging
    accept_classes: int     # distinct accept states (≤ n_queries)
    unshared_states: int    # Unop upper bound: disjoint chains per profile

    @property
    def compression(self) -> float:
        """State compression vs the paper's Unop (per-profile blocks)
        baseline — the §3.3 Com-P-vs-Unop area ratio, measured."""
        return self.unshared_states / max(self.states_after, 1)


def unshared_state_count(queries: Sequence[Query]) -> int:
    """States of the Unop layout (disjoint chain per profile) + root."""
    return 1 + sum(_query_weight(q) for q in queries)


def minimize(nfa: NFA) -> tuple[NFA, MinimizeStats]:
    """Globally merge equivalent states across queries (beyond ``shared``).

    Partition refinement over the single-parent DAG: two states merge
    when their *entire root paths* are identical — same local row
    (in-tag, selfloop, init, kind) and equivalent parents.  Activation is
    a function of the root path alone, so merged states are
    indistinguishable to every engine and the result is bit-identical.
    This collapses ``shared=False`` (Unop) chains into the shared-prefix
    trie, dedups repeated profiles from different subscribers, and merges
    replicated ``//`` waiting states — the global form of §3.3's sharing.

    Accept lanes become many-to-one: queries whose accept states merge
    share one state (and downstream one kernel lane); ``accept_state``
    keeps its (Q,) shape so verdict semantics are unchanged — use
    :func:`accept_classes` for the distinct-lane view.

    Suffix (right-language) merging is deliberately *not* attempted:
    states of different queries always differ in their accept behaviour
    (each subscriber needs its own verdict), so bottom-up merging can
    never cross accept classes — the states it could merge are exactly
    the path-equivalent ones this pass already merges.

    Returns the minimized NFA plus :class:`MinimizeStats`.
    """
    t = nfa.tables
    s = t.in_state.shape[0]
    local = np.stack([
        t.in_tag.astype(np.int64),
        t.selfloop.astype(np.int64),
        t.init.astype(np.int64),
        t.kind.astype(np.int64),
    ])
    cls = np.zeros(s, np.int64)
    n = 1
    while True:  # refine until stable; ≤ trie depth + 1 rounds
        sig = np.concatenate([cls[t.in_state][None, :], local])
        _, new = np.unique(sig, axis=1, return_inverse=True)
        new = new.reshape(-1)  # numpy≥2 returns the pre-axis-move shape
        m = int(new.max()) + 1
        if m == n:
            cls = new
            break
        cls, n = new, m
    # renumber classes by lowest member id: root stays 0 and parents keep
    # lower ids than children (the builder invariant engines rely on)
    reps = np.full(n, s, np.int64)
    np.minimum.at(reps, cls, np.arange(s))
    order = np.argsort(reps)
    rank = np.empty(n, np.int64)
    rank[order] = np.arange(n)
    cls = rank[cls]
    reps = reps[order]
    tables = NFATables(
        in_state=cls[t.in_state[reps]].astype(np.int32),
        in_tag=t.in_tag[reps],
        selfloop=t.selfloop[reps],
        init=t.init[reps],
        accept_state=cls[t.accept_state].astype(np.int32),
        kind=t.kind[reps],
    )
    stats = MinimizeStats(
        states_before=s,
        states_after=n,
        accept_classes=int(np.unique(tables.accept_state).shape[0]),
        unshared_states=unshared_state_count(nfa.queries),
    )
    return (NFA(tables=tables, queries=nfa.queries, shared=True,
                n_tags=nfa.n_tags), stats)


def accept_classes(accept_state: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """Many-to-one accept view: (class_of (Q,), class_state (C,)).

    Queries sharing an accept state share an accept *class* (one kernel
    lane, one verdict bit); classes are numbered by first query using
    them, so an unminimized automaton (all accept states distinct) gets
    the identity mapping.
    """
    class_state, class_of = np.unique(accept_state, return_inverse=True)
    class_of = class_of.reshape(-1)
    # renumber by first occurrence for stable, query-ordered class ids
    first = np.full(class_state.shape[0], accept_state.shape[0], np.int64)
    np.minimum.at(first, class_of, np.arange(accept_state.shape[0]))
    order = np.argsort(first)
    rank = np.empty_like(order)
    rank[order] = np.arange(order.shape[0])
    return (rank[class_of].astype(np.int32),
            class_state[order].astype(np.int32))


# ---------------------------------------------------------------- partitioning
@dataclass(frozen=True)
class QueryPartition:
    """Global query id ↔ (part, local column) index of a partitioned set.

    The query axis is the paper's scaling axis (§3.5: replicate query
    blocks across FPGA area/chips); this index is the software form of
    "which chip holds which profile".  Global ids are stable across
    subscription churn — a removed query's id is never reused, its column
    is tombstoned (``part_of[gid] = -1``) until the owning part is next
    recompiled.

    ``part_of[gid]``  — owning part, or -1 for removed/dead ids.
    ``local_of[gid]`` — column inside the owning part's plan.
    """

    part_of: np.ndarray    # (Qg,) int32, -1 = dead
    local_of: np.ndarray   # (Qg,) int32
    n_parts: int

    def __post_init__(self) -> None:
        object.__setattr__(self, "part_of",
                           np.asarray(self.part_of, np.int32))
        object.__setattr__(self, "local_of",
                           np.asarray(self.local_of, np.int32))
        assert self.part_of.shape == self.local_of.shape

    @property
    def n_global(self) -> int:
        """Total ids ever issued (alive + tombstoned)."""
        return int(self.part_of.shape[0])

    @property
    def n_live(self) -> int:
        return int((self.part_of >= 0).sum())

    def live_ids(self) -> np.ndarray:
        """Alive global ids, sorted — the canonical global query order."""
        return np.nonzero(self.part_of >= 0)[0].astype(np.int32)

    def lookup(self, gid: int) -> tuple[int, int]:
        """(part, local column) of a global id; raises on dead ids."""
        p = int(self.part_of[gid])
        if p < 0:
            raise KeyError(f"query id {gid} is not subscribed")
        return p, int(self.local_of[gid])

    def part_sizes(self) -> np.ndarray:
        """(P,) live query count per part — the load-balance view."""
        alive = self.part_of[self.part_of >= 0]
        return np.bincount(alive, minlength=self.n_parts).astype(np.int64)


def _prefix_key(q: Query) -> tuple[int, str]:
    """Trie-sharing group key: queries sharing their leading step share
    the root fan-out of the prefix trie (§3.3), so the partitioner keeps
    each group on one part instead of splitting the shared prefix."""
    st = q.steps[0]
    return (st.axis, st.tag)


def _query_weight(q: Query) -> int:
    """State-count estimate of one profile: a match state per step plus
    a waiting state per descendant step (the unshared upper bound)."""
    return q.length + sum(1 for st in q.steps if st.axis == DESC)


def partition_queries(
    queries: Sequence[Query],
    n_parts: int,
    dictionary: TagDictionary,
    *,
    shared: bool = True,
) -> tuple[list[NFA], QueryPartition]:
    """Split a subscription set into ``n_parts`` balanced sub-NFAs.

    The split respects shared-prefix trie groups: queries with the same
    leading step stay on the same part (their prefix states dedup inside
    that part's trie), and groups are greedily packed onto the least
    loaded part by estimated state weight — the multi-chip layout of
    §3.5 where each chip carries a balanced slice of the profile set.

    All tag names are registered in ``dictionary`` *before* any part is
    compiled, so every sub-NFA sees the same ``n_tags`` — a requirement
    for stacking per-part tables into one leading-axis device array.

    Returns the per-part NFAs plus the :class:`QueryPartition` index
    (global query id = position in ``queries``).
    """
    if n_parts < 1:
        raise ValueError(f"n_parts must be >= 1, got {n_parts}")
    queries = list(queries)
    # uniform tag-id space across parts (see docstring)
    for q in queries:
        for st in q.steps:
            if st.tag != WILDCARD:
                dictionary.add(st.tag)
    # group by shared prefix, heaviest groups first, least-loaded part wins
    groups: dict[tuple, list[int]] = {}
    for gid, q in enumerate(queries):
        groups.setdefault(_prefix_key(q), []).append(gid)
    weight = {k: sum(_query_weight(queries[g]) for g in gids)
              for k, gids in groups.items()}
    order = sorted(groups, key=lambda k: (-weight[k], k))
    load = [0] * n_parts
    members: list[list[int]] = [[] for _ in range(n_parts)]
    for k in order:
        p = min(range(n_parts), key=lambda i: (load[i], i))
        members[p].extend(groups[k])
        load[p] += weight[k]
    part_of = np.full(len(queries), -1, np.int32)
    local_of = np.zeros(len(queries), np.int32)
    parts: list[NFA] = []
    for p, gids in enumerate(members):
        gids.sort()  # deterministic local order = global order restricted
        for c, gid in enumerate(gids):
            part_of[gid] = p
            local_of[gid] = c
        parts.append(compile_queries([queries[g] for g in gids], dictionary,
                                     shared=shared))
    return parts, QueryPartition(part_of, local_of, n_parts)
