"""Paper-literal regex semantics as associative matrix-product scans.

§3.2 compiles ``a0//b0`` to the regex ``<a0>[\\w\\s]+[<\\c\\d>]*<b0>`` with
an automatic *negation block* on ``</a0>``: progress made under an element
is killed when that element closes.  This flat-stream semantics is exactly
a regular language over the *event* alphabet, so each event is a small 0/1
transition matrix and a whole document is the ordered product of its event
matrices — which ``jax.lax.associative_scan`` evaluates in O(log n) depth
with batched matmuls (the MXU replaces the FPGA's spatial pipeline).

Scope (same as the paper's regex-only group, Fig 5 left): profiles whose
non-leading axes are all ``//`` and with concrete tags.  The negation-block
semantics is *approximate* on documents where a tag occurs again inside
itself (the close of the inner occurrence kills outer progress) — the
paper's hardware has the same behaviour; tests pin both the agreement on
the exact document class and the known divergence.

Prefix products also give the *first matching event* for free — the
priority-encoder output of Fig 5.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from ..dictionary import TagDictionary
from ..events import CLOSE, OPEN, EventBatch, EventStream
from ..nfa import NFA, compile_queries
from ..xpath import CHILD, Query
from . import base
from .result import NO_MATCH, FilterResult


class MatscanUnsupported(ValueError):
    pass


def _check_supported(q: Query) -> None:
    if any(st.axis == CHILD for st in q.steps[1:]):
        raise MatscanUnsupported(
            f"{q.raw!r}: parent-child axis needs the stack group (Fig 5 right)")
    if q.steps[0].axis == CHILD:
        raise MatscanUnsupported(f"{q.raw!r}: root-anchored profile")
    if any(st.tag == "*" for st in q.steps):
        raise MatscanUnsupported(f"{q.raw!r}: wildcard tag test")


def _matrices(step_tags: jax.Array, kind: jax.Array,
              tag: jax.Array) -> jax.Array:
    """(N,) events → (N, Q, k+1, k+1) int8 transition matrices."""
    n = kind.shape[0]
    q, km = step_tags.shape
    eye = jnp.eye(km + 1, dtype=jnp.int8)
    # OPEN: I + advance i→i+1 where step i+1's tag equals the event tag
    adv = (step_tags[None, :, :] == tag[:, None, None])       # (N, Q, km)
    open_m = jnp.zeros((n, q, km + 1, km + 1), jnp.int8)
    idx = jnp.arange(km)
    open_m = open_m.at[:, :, idx, idx + 1].set(adv.astype(jnp.int8))
    open_m = open_m + eye
    # CLOSE </t>: negation block — progress at or beyond the first step
    # matching t collapses back to just before it.
    occurs = (step_tags[None, :, :] == tag[:, None, None])
    # first step index j (1-based) with tag t, km+1 if none
    jpos = jnp.where(occurs, idx[None, None, :] + 1, km + 1).min(axis=-1)
    rows = jnp.arange(km + 1)
    # target[i] = i if i < j else j-1
    tgt = jnp.where(rows[None, None, :] < jpos[:, :, None],
                    rows[None, None, :], jpos[:, :, None] - 1)
    close_m = jax.nn.one_hot(tgt, km + 1, dtype=jnp.int8)  # (N,Q,km+1,km+1)
    is_open = (kind == OPEN)[:, None, None, None]
    is_close = (kind == CLOSE)[:, None, None, None]
    return jnp.where(is_open, open_m,
                     jnp.where(is_close, close_m, eye[None, None]))


@jax.jit
def _scan(step_tags: jax.Array, accept_idx: jax.Array, kind: jax.Array,
          tag: jax.Array):
    mats = _matrices(step_tags, kind, tag).astype(jnp.int32)

    def compose(a, b):
        # ordered product: prefix(a) then b, saturated boolean semiring
        return jnp.minimum(jnp.einsum("...ij,...jk->...ik", a, b), 1)

    prefix = jax.lax.associative_scan(compose, mats, axis=0)
    # v0 = e_0 ⇒ reached states = prefix[:, :, 0, :]
    reach = prefix[:, :, 0, :]                       # (N, Q, km+1)
    acc = jnp.take_along_axis(
        reach, accept_idx[None, :, None], axis=-1)[..., 0]  # (N, Q)
    hit = acc > 0
    matched = hit.any(axis=0)
    first = jnp.where(hit, jnp.arange(kind.shape[0])[:, None],
                      NO_MATCH).min(axis=0)
    return matched, first


@jax.jit
def _scan_batch(step_tags: jax.Array, accept_idx: jax.Array,
                kind: jax.Array, tag: jax.Array):
    """(B, N) batched scan — PAD events are identity matrices, so padded
    tails are free (they cannot create or destroy matches)."""
    return jax.vmap(_scan, in_axes=(None, None, 0, 0))(
        step_tags, accept_idx, kind, tag)


@base.register("matscan")
class MatscanEngine(base.FilterEngine):
    """Batched per-query (k+1)×(k+1) transition-matrix scans."""

    device_sharded = True

    def __init__(self, nfa: NFA | list[Query],
                 dictionary: TagDictionary | None = None, **options) -> None:
        if dictionary is None:
            raise ValueError("matscan engine needs the tag dictionary")
        if not isinstance(nfa, NFA):  # legacy: a raw list of queries
            nfa = compile_queries(list(nfa), dictionary, shared=True)
        for q in nfa.queries:
            _check_supported(q)
        super().__init__(nfa, dictionary, **options)

    def plan(self, nfa: NFA) -> base.FilterPlan:
        return self._build_plan(nfa, kmax=None, n_queries=None)

    def _build_plan(self, nfa: NFA, kmax: int | None,
                    n_queries: int | None) -> base.FilterPlan:
        """Plan with optional uniform pads (the sharded-part compile).

        Padding queries carry no matchable steps (all ``-1``) and accept
        at index ``kmax`` — unreachable, since getting there would need a
        step-``kmax`` tag match that ``-1`` never produces; padding step
        columns likewise never advance or negate anything.
        """
        queries = list(nfa.queries)
        for q in queries:
            _check_supported(q)  # churn-added queries re-checked here
        kmax = max([q.length for q in queries] + [kmax or 1])
        nq = max(n_queries or 0, len(queries))
        step_tags = np.full((nq, kmax), -1, np.int32)
        accept_idx = np.full(nq, kmax, np.int32)
        for qi, q in enumerate(queries):
            for i, st in enumerate(q.steps):
                step_tags[qi, i] = self.dictionary.add(st.tag)
            accept_idx[qi] = q.length  # accept index = its own length
        return base.FilterPlan(
            "matscan",
            tables=dict(
                step_tags=jnp.asarray(step_tags),
                accept_idx=jnp.asarray(accept_idx),
            ),
            meta={"kmax": kmax, "n_queries": nq,
                  # the associative scan consumes the raw event stream,
                  # so the 2-D mesh path can fuse parse+filter
                  "prep": "events-device"},
        )

    # ------------------------------------------------------- sharded hooks
    def part_pads(self, parts, *, query_bucket: int = 8):
        """Uniform (Q, kmax) table shape across parts; no state axis —
        matscan's 'states' are per-query step indices.  ``kmax`` is
        bucketed like the other pad axes so subscribing a slightly
        longer query does not force an all-parts replan."""
        kmax = max((q.length for nfa in parts for q in nfa.queries),
                   default=1)
        nq = max((nfa.n_queries for nfa in parts), default=1)
        return {"kmax": base._round_up(kmax, 4),
                "n_queries": base._round_up(max(nq, 1), query_bucket)}

    def plan_part(self, nfa: NFA, pads) -> base.FilterPlan:
        if not pads:
            return self.plan(nfa)
        return self._build_plan(nfa, kmax=pads["kmax"],
                                n_queries=pads["n_queries"])

    def _prep(self, batch: EventBatch) -> tuple:
        return (jnp.asarray(batch.kind.astype(np.int32)),
                jnp.asarray(batch.tag_id))

    def _prep_arrays(self, kind, tag, depth, parent, valid, n_events):
        return (kind.astype(jnp.int32), tag)

    def _run_with_plan(self, plan: base.FilterPlan, prep: tuple):
        kind, tag = prep
        return _scan_batch(plan["step_tags"], plan["accept_idx"], kind, tag)

    def filter_document(self, ev: EventStream) -> FilterResult:
        p = self.plan_
        matched, first = _scan(p["step_tags"], p["accept_idx"],
                               jnp.asarray(ev.kind.astype(np.int32)),
                               jnp.asarray(ev.tag_id))
        return FilterResult(np.asarray(matched), np.asarray(first))

    def filter_batch(self, batch: EventBatch) -> FilterResult:
        return self.filter_batch_with_plan(self.plan_, batch)


def exact_class(ev: EventStream) -> bool:
    """True iff no tag re-occurs inside an open element with the same tag —
    the document class where the paper's negation-block regex semantics is
    exact w.r.t. tree semantics."""
    stack: list[int] = []
    for k, t in zip(ev.kind, ev.tag_id):
        if k == OPEN:
            if int(t) in stack:
                return False
            stack.append(int(t))
        elif k == CLOSE and stack:
            stack.pop()
    return True
