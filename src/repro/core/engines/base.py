"""The engine contract: ``FilterPlan`` + ``FilterEngine`` + the registry.

This is the single seam of the filtering stack.  The paper's architecture
(§3) compiles the standing profiles once into hardware blocks and then
streams every document through the same fixed datapath; the software
analogue is:

* :class:`FilterPlan` — the compiled form: a *frozen pytree* of
  precomputed device tables (REQ / parent-one-hot / accept matrices,
  packed init words, …) plus static metadata.  Built once per profile
  set by :meth:`FilterEngine.plan`; every ``filter_batch`` call reuses
  it, so tracing/compilation happens once and the plan can be passed
  through ``jax.jit`` boundaries as an ordinary pytree argument.
* :class:`FilterEngine` — the uniform engine interface: ``plan(nfa)``
  and ``filter_batch(EventBatch) -> FilterResult`` with ``(B, Q)``
  outputs.  Engines are free to run on device (streaming, levelwise,
  matscan) or on the host (oracle, yfilter) — callers cannot tell.
* the **registry** — engines self-register under a string key;
  ``engines.get("levelwise")`` / ``engines.create("levelwise", nfa)``
  is how every pipeline, benchmark and example constructs one, so an
  engine comparison is a flag, not an import.

Adding an engine::

    from repro.core.engines import base

    @base.register("myengine")
    class MyEngine(base.FilterEngine):
        def plan(self, nfa):
            return base.FilterPlan("myengine",
                                   tables={"req": jnp.asarray(...)},
                                   meta={"n_states": nfa.n_states})
        def filter_batch(self, batch):
            ...
"""
from __future__ import annotations

import abc
import dataclasses
import hashlib
import json
import os
from functools import partial
from typing import Any, ClassVar, Mapping, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from ...sharding.compat import shard_map_compat as _shard_map
from ..events import ByteBatch, EventBatch, EventStream
from ..nfa import NFA, MinimizeStats, QueryPartition, _query_weight, \
    compile_queries, minimize as minimize_nfa, pad_states, partition_queries
from ..xpath import Query, parse as parse_xpath
from .result import NO_MATCH, FilterResult, SparseResult


def _round_up(n: int, multiple: int) -> int:
    multiple = max(1, int(multiple))
    return max(multiple, -(-n // multiple) * multiple)


# ------------------------------------------------- sparse verdict compaction
def _compact_matches(matched, first, cols, cap: int):
    """Cumsum-compact a dense device verdict into a bounded match buffer.

    ``matched`` ``(B, K)`` bool and ``first`` ``(B, K)`` int32 live on
    device; ``cols`` ``(K,)`` int32 names each column (a query column,
    global id, or accept-lane class — ``-1`` marks dead/pad columns whose
    hits are discarded).  Every hit is assigned its rank by an exclusive
    cumsum over the flattened hit mask and scattered to that slot of a
    ``cap``-bounded buffer (out-of-range ranks drop), so the only
    device→host transfer is ``3 × cap`` int32 plus one count — delivery
    bandwidth scales with matches, not ``B × K``.  When the returned
    ``count`` exceeds ``cap`` the buffer is truncated and the caller
    must fall back to the dense path (``SparseResult.overflowed``).
    """
    hits = jnp.logical_and(matched, (cols >= 0)[None, :])
    flat = hits.reshape(-1)
    rank = jnp.cumsum(flat.astype(jnp.int32)) - 1
    dest = jnp.where(flat, rank, cap)          # non-hits park out of range
    doc = jax.lax.broadcasted_iota(jnp.int32, hits.shape, 0).reshape(-1)
    col = jnp.broadcast_to(cols[None, :], hits.shape).reshape(-1)
    buf_doc = jnp.full((cap,), -1, jnp.int32).at[dest].set(
        doc, mode="drop")
    buf_col = jnp.full((cap,), -1, jnp.int32).at[dest].set(
        col, mode="drop")
    buf_first = jnp.full((cap,), NO_MATCH, jnp.int32).at[dest].set(
        first.reshape(-1), mode="drop")
    return buf_doc, buf_col, buf_first, flat.sum(dtype=jnp.int32)


@partial(jax.jit, static_argnums=3)
def _compact_dense(matched, first, cols, cap: int):
    """Jitted :func:`_compact_matches` over a ``(B, K)`` device verdict."""
    return _compact_matches(matched, first, cols, cap)


@partial(jax.jit, static_argnums=3)
def _compact_parts(matched, first, cols, cap: int):
    """Jitted compaction over a stacked ``(P, B, Qpad)`` sharded verdict.

    ``cols`` is ``(P, Qpad)`` global ids (``-1`` = tombstoned/pad).  The
    part axis folds into the column axis, so one cumsum ranks hits
    across every part — rows come back doc-major but part-interleaved
    within a document; the host assembly lexsorts.
    """
    p, b, q = matched.shape
    m = jnp.moveaxis(matched, 0, 1).reshape(b, p * q)
    f = jnp.moveaxis(first, 0, 1).reshape(b, p * q)
    return _compact_matches(m, f, cols.reshape(-1), cap)



#: default event-axis padding bucket for the byte-ingest paths; engines
#: created with an ``event_bucket=`` option (``FilterStage`` threads its
#: own ``bucket`` through it) override this per instance
DEFAULT_EVENT_BUCKET = 128


# ----------------------------------------------------------------- the plan
class FilterPlan:
    """Frozen pytree: named device tables + static (hashable) metadata.

    ``plan.tables`` maps table name → array (the pytree leaves);
    ``plan.meta`` maps name → static value (pytree aux data, so jit
    retraces when it changes).  Instances are immutable — build a new
    plan instead of mutating one.
    """

    __slots__ = ("engine", "_names", "_arrays", "_meta")

    def __init__(self, engine: str, tables: Mapping[str, Any],
                 meta: Mapping[str, Any] | None = None) -> None:
        names = tuple(sorted(tables))
        object.__setattr__(self, "engine", engine)
        object.__setattr__(self, "_names", names)
        object.__setattr__(self, "_arrays", tuple(tables[n] for n in names))
        object.__setattr__(self, "_meta",
                           tuple(sorted((meta or {}).items())))

    def __setattr__(self, name: str, value: Any) -> None:
        raise AttributeError("FilterPlan is frozen")

    @property
    def tables(self) -> dict[str, Any]:
        return dict(zip(self._names, self._arrays))

    @property
    def meta(self) -> dict[str, Any]:
        return dict(self._meta)

    def table(self, name: str) -> Any:
        return self._arrays[self._names.index(name)]

    def __getitem__(self, name: str) -> Any:
        return self.table(name)

    def __repr__(self) -> str:  # pragma: no cover
        return (f"FilterPlan({self.engine!r}, tables={list(self._names)}, "
                f"meta={self.meta})")

    # pytree protocol -----------------------------------------------------
    def _flatten(self):
        return self._arrays, (self.engine, self._names, self._meta)

    @classmethod
    def _unflatten(cls, aux, leaves):
        engine, names, meta = aux
        self = cls.__new__(cls)
        object.__setattr__(self, "engine", engine)
        object.__setattr__(self, "_names", names)
        object.__setattr__(self, "_arrays", tuple(leaves))
        object.__setattr__(self, "_meta", meta)
        return self


jax.tree_util.register_pytree_node(
    FilterPlan, FilterPlan._flatten, FilterPlan._unflatten)


# ------------------------------------------------------------ sharded plans
class ShardedPlan:
    """Frozen pytree of per-part :class:`FilterPlan`\\ s — the query axis
    as a scaling axis.

    The paper scales in the number of profiles by replicating query
    blocks across FPGA area and chips (§3.5/§4); here the subscription
    set is partitioned (:func:`repro.core.nfa.partition_queries`) and
    each part compiled to its own plan.  Device engines compile every
    part with **uniform state/query padding** (the engine's
    :meth:`FilterEngine.part_pads` targets), so the per-part tables
    stack into one leading-axis ``(P, ...)`` array program —
    ``jax.vmap`` on one device, ``jax.shard_map`` over the mesh
    ``"model"`` axis when one is provided.  Host engines keep raw
    per-part plans and loop them.

    Instances are immutable; subscription churn returns a **new** plan:

    * :meth:`add_queries` — appends to the least-loaded part and
      recompiles *only that part* (other parts re-pad only when the new
      part overflows a shared pad bucket), so steady-state subscribe
      cost is O(n_queries / n_parts) instead of O(n_queries);
    * :meth:`remove_queries` — pure metadata: the column is tombstoned
      in the partition index and masked out of results; the dead column
      is reclaimed the next time its part recompiles.

    Global query ids are stable across churn (see
    :class:`repro.core.nfa.QueryPartition`); results are reported over
    the *live* ids in ascending order — for a freshly planned set this
    is exactly the original query order, so sharded and unsharded
    verdicts are directly comparable.

    Pytree note: the leaves are the per-part plans' tables (so a
    ``ShardedPlan`` can cross ``jax.jit`` boundaries like any pytree);
    the partition/query bookkeeping rides in aux data and compares by
    identity — pass :meth:`stacked` (a plain :class:`FilterPlan`) into
    jitted code instead of the ``ShardedPlan`` itself.
    """

    __slots__ = ("engine", "plans", "part_cols", "part_queries",
                 "part_nfas", "pads", "n_global", "query_bucket", "shared",
                 "_engine_obj", "_stacked", "_partition")

    def __init__(self, engine_obj: "FilterEngine",
                 plans: Sequence[FilterPlan],
                 part_cols: Sequence[Sequence[int]],
                 part_queries: Sequence[Sequence[Query | None]],
                 part_nfas: Sequence[NFA],
                 pads: Mapping[str, int],
                 n_global: int,
                 query_bucket: int,
                 shared: bool) -> None:
        object.__setattr__(self, "engine", engine_obj.name)
        object.__setattr__(self, "plans", tuple(plans))
        object.__setattr__(self, "part_cols",
                           tuple(tuple(c) for c in part_cols))
        object.__setattr__(self, "part_queries",
                           tuple(tuple(q) for q in part_queries))
        object.__setattr__(self, "part_nfas", tuple(part_nfas))
        object.__setattr__(self, "pads", dict(pads))
        object.__setattr__(self, "n_global", int(n_global))
        object.__setattr__(self, "query_bucket", int(query_bucket))
        object.__setattr__(self, "shared", bool(shared))
        object.__setattr__(self, "_engine_obj", engine_obj)
        object.__setattr__(self, "_stacked", None)
        object.__setattr__(self, "_partition", None)

    def __setattr__(self, name: str, value: Any) -> None:
        raise AttributeError("ShardedPlan is frozen")

    # ----------------------------------------------------------- structure
    @property
    def n_parts(self) -> int:
        return len(self.plans)

    @property
    def n_queries(self) -> int:
        """Live (subscribed) query count."""
        return sum(1 for cols in self.part_cols for g in cols if g >= 0)

    @property
    def partition(self) -> QueryPartition:
        """Global id ↔ (part, local column) index of the current layout."""
        if self._partition is None:
            part_of = np.full(self.n_global, -1, np.int32)
            local_of = np.zeros(self.n_global, np.int32)
            for p, cols in enumerate(self.part_cols):
                for c, gid in enumerate(cols):
                    if gid >= 0:
                        part_of[gid] = p
                        local_of[gid] = c
            object.__setattr__(self, "_partition",
                               QueryPartition(part_of, local_of,
                                              self.n_parts))
        return self._partition

    def live_ids(self) -> np.ndarray:
        return self.partition.live_ids()

    def live_queries(self) -> tuple[Query, ...]:
        """Subscribed queries in global-id order — compiling these from
        scratch must reproduce this plan's verdicts exactly (the churn
        equivalence invariant)."""
        by_gid: dict[int, Query] = {}
        for cols, qs in zip(self.part_cols, self.part_queries):
            for gid, q in zip(cols, qs):
                if gid >= 0:
                    by_gid[gid] = q
        return tuple(by_gid[g] for g in sorted(by_gid))

    def index_arrays(self) -> tuple[np.ndarray, np.ndarray]:
        """(part, local) gather index over live ids in global order."""
        part = self.partition
        live = part.live_ids()
        return part.part_of[live], part.local_of[live]

    def stacked(self) -> FilterPlan:
        """All parts as ONE plan with leading part axis (device engines).

        Uniform padding makes every per-part table the same shape, so
        table ``k`` stacks to ``(P, ...)`` — the array program form that
        ``vmap``/``shard_map`` partition over the mesh ``"model"`` axis.
        Cached: churn builds new ``ShardedPlan`` instances, so a cached
        stack can never go stale.
        """
        if self._stacked is None:
            names = list(self.plans[0].tables)
            tables = {k: jnp.stack([p[k] for p in self.plans])
                      for k in names}
            meta = dict(self.plans[0].meta)
            meta["n_parts"] = self.n_parts
            object.__setattr__(
                self, "_stacked", FilterPlan(self.engine, tables, meta))
        return self._stacked

    def part_sizes(self) -> np.ndarray:
        return self.partition.part_sizes()

    def gid_columns(self) -> np.ndarray:
        """``(P, Qpad)`` global id per compiled plan column.

        ``-1`` marks tombstoned and pad columns — the dead-column mask
        the sparse compaction path uses to discard their hits on device.
        """
        qpad = int(self.pads.get("n_queries", 0)) or max(
            (len(c) for c in self.part_cols), default=1)
        out = np.full((self.n_parts, qpad), -1, np.int32)
        for p, cols in enumerate(self.part_cols):
            if cols:
                out[p, :len(cols)] = cols
        return out

    # --------------------------------------------------------- rebalancing
    def part_weights(self) -> np.ndarray:
        """Estimated automaton load per part: Σ state weight of live
        queries (:func:`repro.core.nfa._query_weight` — length plus a
        loop state per ``//`` step), the same measure
        :func:`partition_queries` balances at plan time."""
        w = np.zeros(self.n_parts, np.int64)
        for p, (cols, qs) in enumerate(zip(self.part_cols,
                                           self.part_queries)):
            w[p] = sum(_query_weight(q)
                       for g, q in zip(cols, qs) if g >= 0)
        return w

    def imbalance(self) -> float:
        """Relative overload of the heaviest part: ``max/mean - 1``.

        0 means perfectly balanced; 1 means the hottest part carries
        twice the average automaton weight (and the stacked device
        program wastes half its padded area on the other parts).
        """
        w = self.part_weights().astype(float)
        mean = float(w.mean()) if w.size else 0.0
        return float(w.max() / mean - 1.0) if mean > 0 else 0.0

    def rebalance(self, *, tolerance: float = 0.25,
                  max_moves: int | None = None
                  ) -> tuple["ShardedPlan", dict]:
        """Migrate trie groups between parts until load is ~balanced.

        Long churn sequences erode the plan-time balance:
        :meth:`add_queries` always appends to the currently least-loaded
        part and :meth:`remove_queries` tombstones in place, so at 10⁵+
        subscriptions the partition drifts — one part's sub-NFA grows
        while others carry dead columns, and the uniformly-padded
        stacked program pays the hottest part's shape everywhere.

        This is the off-hot-path repair: shared-prefix trie groups (the
        :func:`partition_queries` migration unit, so prefix sharing
        survives the move) are moved greedily from the heaviest to the
        lightest part while each move strictly shrinks the spread; only
        the parts actually touched are recompiled — at the existing pad
        buckets when they fit (with an incremental restack of just those
        rows), falling back to a full re-pad otherwise.  Tombstoned
        columns of recompiled parts are compacted away for free.

        Returns ``(new_plan, stats)`` — the caller swaps the new frozen
        plan in atomically (see ``FilterStage.maybe_rebalance``); the
        old plan keeps serving until then.  Global ids, verdicts and
        live-id ordering are unchanged: rebalancing is invisible in
        results.  When the plan is already within ``tolerance``
        (``max/mean - 1 ≤ tolerance``), returns ``self`` unchanged.
        """
        from ...kernels.blocks import PadOverflow
        from ..nfa import _prefix_key

        eng = self._engine_obj
        imb0 = self.imbalance()
        stats = {"moves": 0, "moved_queries": 0, "recompiled_parts": 0,
                 "repadded": False, "imbalance_before": imb0,
                 "imbalance_after": imb0}
        if self.n_parts < 2 or imb0 <= tolerance:
            return self, stats

        # live queries per part, bucketed into trie-group migration units
        units: list[dict[Any, list[tuple[int, Query]]]] = []
        for cols, qs in zip(self.part_cols, self.part_queries):
            d: dict[Any, list[tuple[int, Query]]] = {}
            for g, q in zip(cols, qs):
                if g >= 0:
                    d.setdefault(_prefix_key(q), []).append((g, q))
            units.append(d)
        loads = [sum(_query_weight(q) for grp in d.values() for _, q in grp)
                 for d in units]
        mean = sum(loads) / len(loads)

        moves: list[tuple[int, int, int]] = []  # (donor, recv, n_queries)
        budget = max_moves if max_moves is not None else 4 * self.n_parts
        while len(moves) < budget:
            donor = int(np.argmax(loads))
            recv = int(np.argmin(loads))
            gap = loads[donor] - loads[recv]
            if gap <= 0 or loads[donor] <= (1.0 + tolerance) * mean:
                break
            # heaviest whole group that still strictly shrinks the
            # spread (w < gap ⇒ the receiver ends below the donor's old
            # load, so the same group can never ping-pong back)
            best_key, best_w = None, 0
            for key, grp in units[donor].items():
                w = sum(_query_weight(q) for _, q in grp)
                if best_w < w < gap:
                    best_key, best_w = key, w
            if best_key is not None:
                grp = units[donor].pop(best_key)
                units[recv].setdefault(best_key, []).extend(grp)
                loads[donor] -= best_w
                loads[recv] += best_w
                moves.append((donor, recv, len(grp)))
                continue
            # every group outweighs the gap (a popular prefix can dwarf
            # the per-part mean at 10⁵ profiles): split the heaviest one
            # at query granularity — co-locating a prefix group is a
            # balance heuristic, never a correctness invariant, and the
            # moved slice still shares its prefix *within* the receiver
            key = max(units[donor],
                      key=lambda k: sum(_query_weight(q)
                                        for _, q in units[donor][k]),
                      default=None)
            if key is None:
                break
            grp = units[donor][key]
            take, w = 0, 0
            for g, q in grp[:-1]:  # always leave one query behind
                qw = _query_weight(q)
                if w + qw >= gap:
                    break
                take += 1
                w += qw
                if w >= gap / 2:
                    break
            if take == 0:
                break
            units[donor][key] = grp[take:]
            units[recv].setdefault(key, []).extend(grp[:take])
            loads[donor] -= w
            loads[recv] += w
            moves.append((donor, recv, take))
        if not moves:
            return self, stats

        changed = sorted({p for d, r, _ in moves for p in (d, r)})
        part_cols = list(self.part_cols)
        part_queries = list(self.part_queries)
        part_nfas = list(self.part_nfas)
        for p in changed:
            entries = sorted(
                (g, q) for grp in units[p].values() for g, q in grp)
            part_cols[p] = tuple(g for g, _ in entries)
            part_queries[p] = tuple(q for _, q in entries)
            part_nfas[p] = eng._maybe_minimize(compile_queries(
                part_queries[p], eng.dictionary, shared=self.shared))

        fresh = eng.part_pads(part_nfas, query_bucket=self.query_bucket)
        pads, plans, stacked = self.pads, list(self.plans), self._stacked
        new_plans: dict[int, FilterPlan] | None = None
        if all(fresh.get(k, 0) <= pads.get(k, 0) for k in fresh):
            try:
                new_plans = {p: eng.plan_part(part_nfas[p], pads)
                             for p in changed}
            except PadOverflow:
                new_plans = None
        if new_plans is None:
            pads = eng.merge_pads(self.pads, fresh, part_nfas)
            plans = [eng.plan_part(nfa, pads) for nfa in part_nfas]
            stacked = None
            stats["repadded"] = True
            stats["recompiled_parts"] = self.n_parts
        else:
            for p, pl in new_plans.items():
                plans[p] = pl
            stats["recompiled_parts"] = len(changed)
            if stacked is not None:
                tables = stacked.tables
                for p in changed:
                    tables = {k: v.at[p].set(plans[p][k])
                              for k, v in tables.items()}
                stacked = FilterPlan(self.engine, tables, stacked.meta)

        sp = ShardedPlan(eng, plans, part_cols, part_queries, part_nfas,
                         pads, self.n_global, self.query_bucket,
                         self.shared)
        if stacked is not None:
            object.__setattr__(sp, "_stacked", stacked)
        stats["moves"] = len(moves)
        stats["moved_queries"] = sum(n for _, _, n in moves)
        stats["imbalance_after"] = sp.imbalance()
        return sp, stats

    def __repr__(self) -> str:  # pragma: no cover
        return (f"ShardedPlan({self.engine!r}, parts={self.n_parts}, "
                f"queries={self.n_queries}, pads={self.pads})")

    # ------------------------------------------------------ incremental churn
    def add_queries(self, queries: Sequence[Query | str]
                    ) -> tuple["ShardedPlan", list[int]]:
        """Subscribe new profiles; recompile only the least-loaded part.

        Returns ``(new_plan, new_global_ids)``.  The target part is
        compacted on the way (its tombstoned columns are dropped), and
        the other parts' plans are reused untouched unless the grown
        part overflows a shared pad bucket — only then is every part
        re-padded (a table rebuild from the stored sub-NFAs, not a
        query recompile).
        """
        from ...kernels.blocks import PadOverflow

        eng = self._engine_obj
        new_qs = [parse_xpath(q) if isinstance(q, str) else q
                  for q in queries]
        if not new_qs:
            return self, []
        sizes = self.partition.part_sizes()
        p = int(np.argmin(sizes))
        live = [(g, q) for g, q in
                zip(self.part_cols[p], self.part_queries[p]) if g >= 0]
        new_gids = list(range(self.n_global, self.n_global + len(new_qs)))
        cols_p = tuple(g for g, _ in live) + tuple(new_gids)
        qs_p = tuple(q for _, q in live) + tuple(new_qs)
        nfa_p = eng._maybe_minimize(
            compile_queries(qs_p, eng.dictionary, shared=self.shared))
        part_nfas = list(self.part_nfas)
        part_nfas[p] = nfa_p
        fresh = eng.part_pads(part_nfas, query_bucket=self.query_bucket)
        plans = list(self.plans)
        stacked = None
        one_part = None
        if all(fresh.get(k, 0) <= self.pads.get(k, 0) for k in fresh):
            # fits the existing buckets: touch one part.  Jointly-derived
            # targets (e.g. the megakernel's block layout) can still be
            # infeasible at the OLD buckets even when every key compares
            # ≤ — a PadOverflow falls through to the full replan below.
            try:
                one_part = eng.plan_part(nfa_p, self.pads)
            except PadOverflow:
                one_part = None
        if one_part is not None:
            pads = self.pads
            plans[p] = one_part
            if self._stacked is not None:
                # incremental restack: overwrite one row of the cached
                # (P, ...) tables instead of restacking all parts — the
                # device-side cost of a subscribe stays O(1/P)
                tables = {k: self._stacked[k].at[p].set(plans[p][k])
                          for k in self._stacked.tables}
                stacked = FilterPlan(self.engine, tables,
                                     self._stacked.meta)
        else:
            pads = eng.merge_pads(self.pads, fresh, part_nfas)
            plans = [eng.plan_part(nfa, pads) for nfa in part_nfas]
        part_cols = list(self.part_cols)
        part_queries = list(self.part_queries)
        part_cols[p] = cols_p
        part_queries[p] = qs_p
        sp = ShardedPlan(eng, plans, part_cols, part_queries, part_nfas,
                         pads, self.n_global + len(new_qs),
                         self.query_bucket, self.shared)
        if stacked is not None:
            object.__setattr__(sp, "_stacked", stacked)
        return sp, new_gids

    def remove_queries(self, gids: Sequence[int]) -> "ShardedPlan":
        """Unsubscribe by global id — O(1) metadata, no recompilation.

        The columns stay in the compiled plans (tombstoned: excluded
        from the partition index and from every result) and are
        physically dropped the next time their part recompiles.
        """
        dead = set(int(g) for g in gids)
        part = self.partition
        for g in dead:
            if not (0 <= g < self.n_global) or part.part_of[g] < 0:
                raise KeyError(f"query id {g} is not subscribed")
        part_cols = [tuple(-1 if g in dead else g for g in cols)
                     for cols in self.part_cols]
        sp = ShardedPlan(self._engine_obj, self.plans, part_cols,
                         self.part_queries, self.part_nfas, self.pads,
                         self.n_global, self.query_bucket, self.shared)
        # plans are identical (tombstoning lives in the index), so the
        # stacked tables carry over — a removal never restacks
        object.__setattr__(sp, "_stacked", self._stacked)
        return sp

    # pytree protocol -----------------------------------------------------
    def _flatten(self):
        aux = (self._engine_obj, self.part_cols, self.part_queries,
               self.part_nfas, tuple(sorted(self.pads.items())),
               self.n_global, self.query_bucket, self.shared)
        return self.plans, aux

    @classmethod
    def _unflatten(cls, aux, plans):
        engine_obj, cols, qs, nfas, pads, n_global, bucket, shared = aux
        return cls(engine_obj, tuple(plans), cols, qs, nfas, dict(pads),
                   n_global, bucket, shared)


jax.tree_util.register_pytree_node(
    ShardedPlan, ShardedPlan._flatten, ShardedPlan._unflatten)


# --------------------------------------------------------------- the engine
class FilterEngine(abc.ABC):
    """Uniform engine interface: compile once, filter batches forever.

    ``__init__`` compiles the profile set (via :meth:`plan`) exactly once;
    :meth:`filter_batch` is then a pure function of the plan and an
    :class:`~repro.core.events.EventBatch` — the only document format an
    engine ever sees.
    """

    #: registry key, set by the :func:`register` decorator
    name: ClassVar[str] = ""

    #: state-axis pad multiple this engine's plan tables require (32-state
    #: packed words, 128-lane MXU tiles, 1 = no padding).  Overridable per
    #: instance via the ``state_multiple=`` engine option and recorded in
    #: plan metadata — :func:`repro.core.nfa.pad_states` is always called
    #: with this value, never a hard-coded constant.
    state_multiple: ClassVar[int] = 1

    #: True when the engine runs per-part plans as ONE stacked device
    #: program (vmap/shard_map over the leading part axis); False (host
    #: engines) loops parts in python.
    device_sharded: ClassVar[bool] = False

    #: uniform pad targets threaded by :meth:`plan_part` for the duration
    #: of the :meth:`plan` call (sharded plans need every per-part table —
    #: including kernel block tables — at identical shapes so they stack)
    _plan_pads: Mapping[str, int] | None = None

    def __init__(self, nfa: NFA, dictionary=None, **options: Any) -> None:
        self.dictionary = dictionary
        if "state_multiple" in options:
            self.state_multiple = int(options.pop("state_multiple"))
        # global NFA minimization (``minimize=True`` engine option):
        # merge behavior-identical states across queries on top of the
        # shared-prefix trie before compiling any plan — the sharded and
        # churn paths route through _maybe_minimize so every compiled
        # part shrinks the same way
        self._minimize = bool(options.pop("minimize", False))
        self.minimize_stats: MinimizeStats | None = None
        if self._minimize:
            nfa, self.minimize_stats = minimize_nfa(nfa)
        # persistent compiled-plan cache (``plan_cache=`` engine option:
        # a PlanCache instance or a directory path) — every compilation
        # site routes through _plan_cached, so cold starts and shadow
        # rebuilds skip recompilation on a content-hash hit
        cache = options.pop("plan_cache", None)
        if isinstance(cache, (str, os.PathLike)):
            from ...checkpoint.store import PlanCache
            cache = PlanCache(os.fspath(cache))
        self.plan_cache = cache
        self.nfa = nfa
        self.options = options
        self.n_queries = nfa.n_queries
        self.plan_: FilterPlan = self._plan_cached(nfa)

    def _maybe_minimize(self, nfa: NFA) -> NFA:
        """Apply global minimization when the engine was built with it.

        Every compilation site — the initial plan, per-part sharded
        plans, churn recompiles, rebalance recompiles — routes new NFAs
        through here so verdict-equivalence is preserved uniformly.
        """
        if not getattr(self, "_minimize", False):
            return nfa
        return minimize_nfa(nfa)[0]

    # ------------------------------------------------------------ contract
    @abc.abstractmethod
    def plan(self, nfa: NFA) -> FilterPlan:
        """Compile the NFA into this engine's device tables (once)."""

    @abc.abstractmethod
    def filter_batch(self, batch: EventBatch) -> FilterResult:
        """Filter a document batch; returns a ``(B, Q)`` result."""

    # ------------------------------------------------- explicit-plan filter
    def _prep(self, batch: EventBatch) -> tuple:
        """Plan-independent document-side preparation (device engines).

        Whatever the engine's compiled program consumes — event arrays,
        level buckets, chunk layouts.  Shared across every part of a
        sharded plan: the document structure does not depend on which
        queries are asked of it.
        """
        raise NotImplementedError(
            f"{self.name}: no device prep (host engine)")

    def _run_with_plan(self, plan: FilterPlan, prep: tuple):
        """Pure-jax body: explicit plan + prepped batch → (matched, first).

        Must be vmappable over the plan's tables — the sharded path maps
        it over the leading part axis of :meth:`ShardedPlan.stacked`.
        """
        raise NotImplementedError(
            f"{self.name}: no device run (host engine)")

    def filter_batch_with_plan(self, plan: FilterPlan,
                               batch: EventBatch) -> FilterResult:
        """:meth:`filter_batch` against an explicit plan (any compiled
        profile set, not just ``self.plan_``) — the primitive both the
        unsharded and the per-part sharded paths are built from."""
        matched, first = self._run_with_plan(plan, self._prep(batch))
        return FilterResult(np.asarray(matched), np.asarray(first))

    # ------------------------------------------------------- sharded plans
    def part_pads(self, parts: Sequence[NFA], *,
                  query_bucket: int = 8) -> dict[str, int]:
        """Uniform pad targets for a set of partition NFAs.

        Device engines pad every part to common bucket sizes so the
        per-part tables stack (state axis to the engine's
        ``state_multiple``, query axis to ``query_bucket``); subclass
        engines extend with their own table axes (e.g. matscan's
        ``kmax``, levelwise's tag space).  Host engines return ``{}``
        (parts are looped, shapes never need to agree).  Buckets give
        churn headroom: an added query only forces a global re-pad when
        its part overflows a bucket boundary.
        """
        if not self.device_sharded:
            return {}
        s = max((nfa.n_states for nfa in parts), default=1)
        q = max((nfa.n_queries for nfa in parts), default=1)
        return {"n_states": _round_up(s, self.state_multiple),
                "n_queries": _round_up(max(q, 1), query_bucket)}

    def plan_part(self, nfa: NFA, pads: Mapping[str, int]) -> FilterPlan:
        """Compile one partition's NFA at the shared pad targets.

        Routes through the persistent plan cache when one is configured
        (see :meth:`_plan_cached`); the actual compile is
        :meth:`_plan_part_uncached`.
        """
        return self._plan_cached(nfa, pads)

    def _plan_part_uncached(self, nfa: NFA,
                            pads: Mapping[str, int]) -> FilterPlan:
        """The compile body of :meth:`plan_part`.

        The pad dict is exposed to :meth:`plan` as ``self._plan_pads``
        for the duration of the call — engines with derived plan tables
        whose shapes are not a pure function of ``(n_states, n_queries)``
        (e.g. the streaming megakernel's block count and accept-lane
        width) read their uniform targets from it so per-part tables
        stack along the leading part axis.
        """
        if not pads:
            return self.plan(nfa)
        if "n_tags" in pads and pads["n_tags"] > nfa.n_tags:
            nfa = dataclasses.replace(nfa, n_tags=pads["n_tags"])
        nfa = pad_states(nfa, to=pads["n_states"])
        self._plan_pads = pads
        try:
            plan = self.plan(nfa)
        finally:
            self._plan_pads = None
        return self._pad_plan_queries(plan, pads["n_queries"])

    # ------------------------------------------------ persistent plan cache
    def plan_cache_key(self, nfa: NFA,
                       pads: Mapping[str, int] | None = None) -> str:
        """Content hash identifying one compiled plan: NFA tables × pad
        targets × kernel config.

        Everything the compiled tables are a deterministic function of
        goes into the hash — the dense NFA table contents (so two
        different profile sets can only collide if they compile
        identically anyway), the query/tag space sizes, the engine name
        and its remaining options (block sizes, autotune policy, sparse
        knobs …), the state multiple, the uniform pad targets, and the
        kernel-environment switches (interpret mode, VMEM/SMEM budgets)
        that steer :meth:`kernel_config`.  A stale cache hit is
        therefore structurally impossible: any input that could change
        the tables changes the key.
        """
        from ...kernels import interpret_default

        h = hashlib.sha256()
        for part in (
                "v1", self.name, str(self.state_multiple),
                repr(sorted((k, repr(v)) for k, v in self.options.items())),
                str(int(nfa.n_tags)), str(int(nfa.n_queries)),
                "shared" if nfa.shared else "unshared",
                repr(sorted((pads or {}).items())),
                str(bool(interpret_default())),
                os.environ.get("REPRO_PALLAS_VMEM_BUDGET", ""),
                os.environ.get("REPRO_PALLAS_SMEM_BUDGET", "")):
            h.update(part.encode())
            h.update(b"\x00")
        for arr in nfa.tables:
            a = np.asarray(arr)
            h.update(str(a.dtype).encode())
            h.update(str(a.shape).encode())
            h.update(a.tobytes())
        return h.hexdigest()[:40]

    def _plan_cached(self, nfa: NFA,
                     pads: Mapping[str, int] | None = None) -> FilterPlan:
        """Compile via the persistent plan cache when one is configured.

        Only device engines cache (host plans hold python objects, and
        there is no compile cost to skip); a hit rebuilds the
        :class:`FilterPlan` from the stored numpy tables + JSON metadata
        with no ``plan()`` call at all — the cold-start/crash-recovery
        fast path.  A miss compiles and persists through the
        crash-safe :meth:`repro.checkpoint.store.PlanCache.put`.
        """
        cache = self.plan_cache
        if cache is None or not self.device_sharded:
            return (self._plan_part_uncached(nfa, pads)
                    if pads is not None else self.plan(nfa))
        key = self.plan_cache_key(nfa, pads)
        hit = cache.get(key)
        if hit is not None:
            tables, manifest = hit
            return FilterPlan(manifest.get("engine", self.name),
                              {k: jnp.asarray(v) for k, v in tables.items()},
                              manifest.get("meta", {}))
        plan = (self._plan_part_uncached(nfa, pads)
                if pads is not None else self.plan(nfa))
        # metadata must survive a JSON round-trip bit-exactly (it is jit
        # aux data); a plan whose meta does not is simply not cached
        meta = dict(plan.meta)
        if json.loads(json.dumps(meta)) == meta:
            cache.put(key, {k: np.asarray(v)
                            for k, v in plan.tables.items()},
                      {"engine": plan.engine, "meta": meta})
        return plan

    def _pad_plan_queries(self, plan: FilterPlan,
                          n_queries: int) -> FilterPlan:
        """Pad the plan's query axis with never-matching columns.

        Default handles engines whose only per-query table is
        ``accept_state``: padding columns accept at state 0 (the root,
        which no OPEN event ever activates), so they report unmatched
        forever — inert by construction, like pad states.
        """
        acc = plan["accept_state"]
        extra = n_queries - int(acc.shape[0])
        if extra <= 0:
            return plan
        tables = plan.tables
        # pad on the host: a device concatenate would XLA-compile once
        # per novel shape, dominating per-op churn latency
        acc_h = np.asarray(acc)
        tables["accept_state"] = jnp.asarray(
            np.concatenate([acc_h, np.zeros(extra, acc_h.dtype)]))
        return FilterPlan(plan.engine, tables, plan.meta)

    def merge_pads(self, old: Mapping[str, int], new: Mapping[str, int],
                   parts: Sequence[NFA]) -> dict[str, int]:
        """Reconcile churn pad targets when new queries overflow a bucket.

        The default is the per-key maximum of the existing and freshly
        derived targets.  Engines whose derived table shapes are *joint*
        functions of several targets (the streaming megakernel's block
        count and accept-lane width both depend on the block size)
        override this to re-derive the dependent keys at the merged
        independent ones — a per-key max of separately-derived values
        can otherwise be infeasible.
        """
        return {k: max(new.get(k, 0), old.get(k, 0))
                for k in set(new) | set(old)}

    # ---------------------------------------------- kernel autotune hook
    def kernel_config(self, n_states: int, n_tags: int) -> dict | None:
        """Plan-level kernel selection + launch-shape autotune hook.

        Engines with a Pallas hot path override this to pick their
        kernel launch parameters (state-block size, SMEM chunk length,
        …) from the plan's *static* shape at ``plan()`` time — so the
        choice is compiled into the plan once, not re-derived per batch.
        :meth:`autotune_blocks` is the shared sizing helper; the
        streaming engine adopts it for the megakernel, and any engine
        that grows a kernel path can reuse the same hook + helper pair.
        ``None`` (the default) means the engine has no kernel path.
        """
        return None

    @staticmethod
    def autotune_blocks(n_states: int, max_depth: int, *, n_tags: int,
                        vmem_budget: int | None = None,
                        smem_budget: int | None = None,
                        chunk: int = 256) -> dict:
        """Pick a (``blk``, ``chunk``) launch shape from static bounds.

        ``blk`` (states per kernel block, a multiple of 32) is the
        largest power-of-two candidate whose per-program VMEM footprint
        — packed-word stack, per-tag word masks, parent gather lanes —
        fits ``vmem_budget``, clamped down to the padded state count (no
        point in blocks wider than the whole NFA).  ``chunk`` (events
        per SMEM DMA chunk) is clamped to half of ``smem_budget`` (the
        event buffer is double-buffered int32).  Engine options override
        both knobs; this is only the default policy.

        Budgets default from the ``REPRO_PALLAS_VMEM_BUDGET`` /
        ``REPRO_PALLAS_SMEM_BUDGET`` env vars (bytes) when the caller
        passes ``None`` — CI and the measured autotune search exercise
        small-budget layouts without monkeypatching; explicit arguments
        always win.
        """
        if vmem_budget is None:
            vmem_budget = int(os.environ.get(
                "REPRO_PALLAS_VMEM_BUDGET", 4 << 20))
        if smem_budget is None:
            smem_budget = int(os.environ.get(
                "REPRO_PALLAS_SMEM_BUDGET", 8 << 10))
        blk = 32
        for cand in (1024, 512, 256, 128, 64, 32):
            wb = cand // 32
            need = 4 * ((max_depth + 2) * wb    # packed-word VMEM stack
                        + (n_tags + 1) * wb     # per-tag word masks
                        + 2 * 32 * wb           # parent word/bit lanes
                        + 4 * wb)               # state/work rows
            if need <= vmem_budget:
                blk = cand
                break
        blk = min(blk, _round_up(max(n_states, 1), 32))
        chunk = max(32, min(int(chunk), smem_budget // (2 * 4)))
        return {"blk": blk, "chunk": chunk}

    def plan_sharded(self, n_parts: int, *,
                     query_bucket: int = 8) -> ShardedPlan:
        """Partition this engine's profile set and compile per-part plans.

        The counterpart of :meth:`plan` for the sharded contract: split
        the subscription set (:func:`repro.core.nfa.partition_queries`),
        compile each part at uniform pad targets, and return the frozen
        :class:`ShardedPlan` that :meth:`filter_batch_sharded` executes
        and whose ``add_queries``/``remove_queries`` absorb churn.
        """
        parts, partition = partition_queries(
            list(self.nfa.queries), n_parts, self.dictionary,
            shared=self.nfa.shared)
        parts = [self._maybe_minimize(p) for p in parts]
        # local ids are assigned in ascending gid order within each part,
        # so appending in gid order reproduces the column layout
        part_cols: list[list[int]] = [[] for _ in range(n_parts)]
        for gid in range(len(self.nfa.queries)):
            part_cols[int(partition.part_of[gid])].append(gid)
        part_queries = [[self.nfa.queries[g] for g in cols]
                        for cols in part_cols]
        pads = self.part_pads(parts, query_bucket=query_bucket)
        plans = [self.plan_part(nfa, pads) for nfa in parts]
        return ShardedPlan(self, plans, part_cols, part_queries, parts,
                           pads, len(self.nfa.queries), query_bucket,
                           self.nfa.shared)

    def filter_batch_sharded(self, batch: EventBatch, sharded: ShardedPlan,
                             *, mesh=None) -> FilterResult:
        """Filter through a partitioned plan; ``(B, Q_live)`` result.

        Device engines run every part in ONE compiled program: the
        stacked ``(P, ...)`` tables are vmapped over the part axis, and
        when ``mesh`` is given (see
        :func:`repro.launch.mesh.make_filter_mesh`) the part axis is
        partitioned over the mesh ``"model"`` axis with ``shard_map`` —
        each device advances only its slice of the subscription set,
        the paper's profiles-across-chips scaling.  Host engines loop
        parts.  Columns come back in live-global-id order (original
        query order for an unchurned plan), tombstones excluded.
        """
        part_of, local_of = sharded.index_arrays()
        if self.device_sharded:
            matched, first = self._run_sharded(batch, sharded, mesh)
            matched = np.asarray(matched)   # (P, B, Qpad)
            first = np.asarray(first)
            return FilterResult(matched[part_of, :, local_of].T,
                                first[part_of, :, local_of].T)
        outs = [self.filter_batch_with_plan(plan, batch)
                for plan in sharded.plans]
        b = batch.batch_size
        matched = np.zeros((b, part_of.shape[0]), bool)
        first = np.full((b, part_of.shape[0]), NO_MATCH, np.int32)
        for j, (p, c) in enumerate(zip(part_of, local_of)):
            matched[:, j] = outs[p].matched[:, c]
            first[:, j] = outs[p].first_event[:, c]
        return FilterResult(matched, first)

    # ------------------------------------------------- sparse verdict path
    def match_cap(self, batch_size: int, n_cols: int,
                  cap: int | None = None) -> int:
        """Resolve the bounded match-buffer size for one sparse call.

        Explicit argument wins, then the ``match_cap=`` engine option,
        then ``match_cap`` from the compiled plan's metadata (set via
        :meth:`kernel_config` so autotune/persisted configs can carry
        it); the default budgets 32 matches per document (floor 4096) —
        far above realistic selectivity at 10⁵ profiles, while the dense
        fallback keeps rare hot batches exact.  Clamped to the dense
        size, past which overflow is impossible anyway.
        """
        if cap is None:
            cap = self.options.get("match_cap")
        if cap is None:
            plan = getattr(self, "plan_", None)
            if plan is not None:
                cap = plan.meta.get("match_cap")
        if cap is None:
            cap = max(4096, 32 * batch_size)
        return int(max(1, min(int(cap), batch_size * max(1, n_cols))))

    def _sparse_from_buffers(self, bufs, count: int, cap: int, *,
                             batch_size: int, n_queries: int,
                             live_ids=None, sort: bool = False,
                             meta: dict | None = None,
                             dense_fallback=None) -> SparseResult:
        """Assemble a :class:`SparseResult` from device compaction output.

        ``bufs`` is the ``(doc, col, first)`` buffer triple from
        :func:`_compact_matches`; only the first ``count`` rows are
        real.  ``count > cap`` means the buffer overflowed — the
        verdicts are recomputed via ``dense_fallback()`` (exact, just
        without the bandwidth win), flagged ``overflowed`` and named
        ``path="dense-overflow"`` (the route that WOULD have run stays
        visible as ``attempted_path``).
        """
        meta = dict(meta or (), match_cap=cap)
        if count > cap:
            sp = dense_fallback().sparsify(live_ids)
            sp.overflowed = True
            sp.meta.update(meta, matches=count,
                           attempted_path=meta.get("path"),
                           path="dense-overflow")
            return sp
        docs, cols, first = (np.asarray(b)[:count] for b in bufs)
        if sort:  # part-interleaved producers: restore (doc, id) order
            order = np.lexsort((cols, docs))
            docs, cols, first = docs[order], cols[order], first[order]
        return SparseResult(
            docs, cols, first, batch_size=batch_size, n_queries=n_queries,
            live_ids=(None if live_ids is None
                      else np.asarray(live_ids, np.int32)),
            meta=meta)

    def filter_batch_sparse(self, batch: EventBatch, *,
                            match_cap: int | None = None) -> SparseResult:
        """Sparse-verdict twin of :meth:`filter_batch`.

        Device engines compact the verdict **on device** (see
        :func:`_compact_matches`): the host receives a bounded
        ``(doc_id, query_id, first_event)`` match list instead of the
        dense ``(B, Q)`` bitmap, so result bandwidth scales with the
        matches.  Host engines sparsify the dense result (wire format
        only — they never had a device transfer to save).
        :meth:`SparseResult.densify` round-trips bit-exactly.
        """
        if not self.device_sharded:
            sp = self.filter_batch(batch).sparsify()
            sp.meta["path"] = "dense-host"
            return sp
        matched, first = self._run_with_plan(self.plan_, self._prep(batch))
        b = batch.batch_size
        q = int(matched.shape[-1])
        cap = self.match_cap(b, q, match_cap)
        *bufs, n = _compact_dense(matched, first,
                                  jnp.arange(q, dtype=jnp.int32), cap)
        return self._sparse_from_buffers(
            bufs, int(n), cap, batch_size=b, n_queries=q,
            meta={"path": "device-compact"},
            dense_fallback=lambda: FilterResult(np.asarray(matched),
                                                np.asarray(first)))

    def filter_batch_sharded_sparse(self, batch: EventBatch,
                                    sharded: ShardedPlan, *, mesh=None,
                                    match_cap: int | None = None
                                    ) -> SparseResult:
        """Sparse-verdict twin of :meth:`filter_batch_sharded`.

        One device compaction over the stacked ``(P, B, Qpad)`` output
        with columns named by **global subscriber id** (tombstoned and
        pad columns discarded on device), so at 10⁵ profiles the
        device→host transfer is the match list, not ``B × Q_live``.
        ``query_ids`` are global ids; ``densify`` restores the dense
        live-column layout of :meth:`filter_batch_sharded` bit-exactly.
        """
        live_ids = sharded.live_ids()
        if not self.device_sharded:
            sp = self.filter_batch_sharded(
                batch, sharded, mesh=mesh).sparsify(live_ids)
            sp.meta["path"] = "dense-host"
            return sp
        matched, first = self._run_sharded(batch, sharded, mesh)
        b = batch.batch_size
        cap = self.match_cap(b, len(live_ids), match_cap)
        *bufs, n = _compact_parts(matched, first,
                                  jnp.asarray(sharded.gid_columns()), cap)

        def dense_fallback() -> FilterResult:
            part_of, local_of = sharded.index_arrays()
            return FilterResult(
                np.asarray(matched)[part_of, :, local_of].T,
                np.asarray(first)[part_of, :, local_of].T)

        return self._sparse_from_buffers(
            bufs, int(n), cap, batch_size=b, n_queries=len(live_ids),
            live_ids=live_ids, sort=True,
            meta={"path": "device-compact"}, dense_fallback=dense_fallback)

    def filter_batch_sharded2d_sparse(self, batch: EventBatch,
                                      sharded: ShardedPlan, *, mesh,
                                      match_cap: int | None = None
                                      ) -> SparseResult:
        """Sparse wire format over the 2-D (data × model) path.

        The 2-D program's outputs are already partitioned per device;
        this sparsifies the gathered result on the host — the match-list
        format for delivery, without an extra device pass.
        """
        sp = self.filter_batch_sharded2d(
            batch, sharded, mesh=mesh).sparsify(sharded.live_ids())
        sp.meta["path"] = "dense-2d"
        return sp

    def filter_bytes_sparse(self, bb: ByteBatch, *,
                            bucket: int | None = None,
                            match_cap: int | None = None) -> SparseResult:
        """Bytes in, sparse match list out (device parse + compaction)."""
        from ...kernels.parse import DEFAULT_MAX_DEPTH, parse_batch

        max_depth = int(getattr(self, "max_depth", DEFAULT_MAX_DEPTH))
        return self.filter_batch_sparse(
            parse_batch(bb, n_events=bb.event_bound(
                bucket=self._event_bucket(bucket)), max_depth=max_depth),
            match_cap=match_cap)

    def filter_bytes_sharded_sparse(self, bb: ByteBatch,
                                    sharded: ShardedPlan, *,
                                    bucket: int | None = None, mesh=None,
                                    match_cap: int | None = None
                                    ) -> SparseResult:
        """Sharded bytes→sparse-verdict twin."""
        from ...kernels.parse import DEFAULT_MAX_DEPTH, parse_batch

        max_depth = int(getattr(self, "max_depth", DEFAULT_MAX_DEPTH))
        return self.filter_batch_sharded_sparse(
            parse_batch(bb, n_events=bb.event_bound(
                bucket=self._event_bucket(bucket)), max_depth=max_depth),
            sharded, mesh=mesh, match_cap=match_cap)

    def _cached_exec(self, key, build):
        """Per-engine cache of compiled sharded callables, keyed on the
        execution form (1d/2d/bytes2d × mesh × static shape knobs); jit
        keys on the plan pytree structure and prep shapes on top, so
        pad-bucket growth or a new batch shape retraces exactly once."""
        cache = getattr(self, "_sharded_exec", None)
        if cache is None:
            cache = {}
            self._sharded_exec = cache
        fn = cache.get(key)
        if fn is None:
            fn = build()
            cache[key] = fn
        return fn

    def _check_model_axis(self, sharded: ShardedPlan, mesh) -> None:
        if mesh is None:
            return
        axis = dict(mesh.shape).get("model", 1)
        if axis > 1 and sharded.n_parts % axis != 0:
            raise ValueError(
                f"n_parts={sharded.n_parts} not divisible by mesh "
                f"model axis {axis}")

    def _vmapped_parts(self):
        def vmapped(plan, *prep_args):
            return jax.vmap(
                lambda pl: self._run_with_plan(pl, prep_args))(plan)
        return vmapped

    def _run_sharded(self, batch: EventBatch, sharded: ShardedPlan, mesh):
        """Stacked-parts execution: vmap, or shard_map over the mesh."""
        prep = self._prep(batch)
        stacked = sharded.stacked()
        self._check_model_axis(sharded, mesh)

        def build():
            vmapped = self._vmapped_parts()
            if mesh is not None:
                ps = jax.sharding.PartitionSpec
                return jax.jit(_shard_map(
                    vmapped, mesh,
                    in_specs=(ps("model"),) + (ps(),) * len(prep),
                    out_specs=(ps("model"), ps("model"))))
            return jax.jit(vmapped)

        return self._cached_exec(("1d", mesh), build)(stacked, *prep)

    def _event_bucket(self, bucket: int | None) -> int:
        """Resolve an event-axis padding bucket for the byte paths.

        ``None`` (the default everywhere a caller did not choose one)
        falls back to the engine's ``event_bucket=`` option — which
        ``FilterStage`` sets to its own ``bucket`` — so every ingest
        path of one stage pads to the same boundaries instead of a
        hard-coded 128 silently taking over on some of them.
        """
        if bucket is not None:
            return int(bucket)
        return int(self.options.get("event_bucket", DEFAULT_EVENT_BUCKET))

    def filter_bytes_sharded(self, bb: ByteBatch, sharded: ShardedPlan, *,
                             bucket: int | None = None,
                             mesh=None) -> FilterResult:
        """Sharded twin of :meth:`filter_bytes`: device parse once, then
        one stacked parts program — bytes in, ``(B, Q_live)`` out."""
        from ...kernels.parse import DEFAULT_MAX_DEPTH, parse_batch

        max_depth = int(getattr(self, "max_depth", DEFAULT_MAX_DEPTH))
        return self.filter_batch_sharded(
            parse_batch(bb,
                        n_events=bb.event_bound(
                            bucket=self._event_bucket(bucket)),
                        max_depth=max_depth),
            sharded, mesh=mesh)

    # ------------------------------------------------ 2-D (data × model)
    def _prep_arrays(self, kind, tag, depth, parent, valid, n_events):
        """Device-side document prep straight from parse outputs.

        Implemented by engines whose plan metadata records ``prep ==
        "events-device"`` (streaming, matscan: their compiled program
        consumes the raw event stream) — what lets the fused
        bytes→verdict shard_map program run parse *and* filter inside
        one per-device body.  Engines with host-side prep (the levelwise
        family buckets by depth in numpy) or host execution never get
        here.
        """
        raise NotImplementedError(
            f"{self.name}: no device parse prep "
            f"(plan meta 'prep' is not 'events-device')")

    def _mesh_axes2d(self, mesh) -> tuple[int, int]:
        if mesh is None:
            raise ValueError(
                "the 2-D path needs a ('data', 'model') mesh — see "
                "repro.launch.mesh.make_filter_mesh(data_shards=...)")
        shape = dict(mesh.shape)
        if "data" not in shape or "model" not in shape:
            raise ValueError(
                f"2-D filtering needs a ('data', 'model') mesh, got axes "
                f"{tuple(shape)}")
        return shape["data"], shape["model"]

    def _gather2d(self, matched, first, sharded: ShardedPlan, b0: int):
        """Zero-arg materializer over the raw (P, Bpad, Qpad) outputs.

        Calling it blocks on the async device computation, gathers live
        columns in global-id order and slices off batch-pad rows — the
        deferred half of :meth:`dispatch_batch_sharded2d`.
        """
        part_of, local_of = sharded.index_arrays()

        def materialize() -> FilterResult:
            m = np.asarray(matched)[part_of, :, local_of].T[:b0]
            f = np.asarray(first)[part_of, :, local_of].T[:b0]
            return FilterResult(m, f)

        return materialize

    def dispatch_batch_sharded2d(self, batch: EventBatch,
                                 sharded: ShardedPlan, *, mesh):
        """Launch the 2-D (data × model) program; returns a zero-arg
        materializer — call it to block and get the ``(B, Q_live)``
        :class:`FilterResult`.

        Both of the paper's replication axes (§3.5) in ONE ``shard_map``
        program: the stacked per-part plan tables are partitioned over
        the mesh ``"model"`` axis (each device advances 1/P of the
        subscription set) and the document batch over ``"data"`` (each
        replica row sees 1/D of the stream).  The batch axis is padded
        to a multiple of the data axis with inert all-PAD documents
        (sliced back off the result), so any batch size is servable.

        Dispatch is asynchronous — the returned callable is the
        synchronization point, which is what the double-buffered ingest
        loop overlaps the next batch's ``device_put`` against.  Host
        engines compute eagerly (the part loop is the bit-equivalence
        oracle for this path) and return an already-resolved thunk.
        """
        if not self.device_sharded:
            res = self.filter_batch_sharded(batch, sharded)
            return lambda: res
        data_ax, _ = self._mesh_axes2d(mesh)
        self._check_model_axis(sharded, mesh)
        b0 = batch.batch_size
        batch = batch.pad_batch_to(_round_up(b0, data_ax))
        prep = self._prep(batch)
        stacked = sharded.stacked()

        def build():
            ps = jax.sharding.PartitionSpec
            return jax.jit(_shard_map(
                self._vmapped_parts(), mesh,
                in_specs=(ps("model"),) + (ps("data"),) * len(prep),
                out_specs=(ps("model", "data"), ps("model", "data"))))

        matched, first = self._cached_exec(("2d", mesh), build)(
            stacked, *prep)
        return self._gather2d(matched, first, sharded, b0)

    def filter_batch_sharded2d(self, batch: EventBatch,
                               sharded: ShardedPlan, *,
                               mesh) -> FilterResult:
        """Blocking convenience over :meth:`dispatch_batch_sharded2d`."""
        return self.dispatch_batch_sharded2d(batch, sharded, mesh=mesh)()

    def dispatch_bytes_sharded2d(self, bb: ByteBatch, sharded: ShardedPlan,
                                 *, bucket: int | None = None, mesh,
                                 n_events: int | None = None):
        """ByteBatch twin of :meth:`dispatch_batch_sharded2d`.

        When the plan's document prep is device-resident (plan metadata
        ``prep == "events-device"``), this is ONE shard_map bytes→verdict
        program: each device parses its ``"data"`` slice of the wire
        bytes locally (the parse kernels inline into the body) and runs
        its ``"model"`` slice of the stacked plan — the paper's same-chip
        parser+filter, replicated in both dimensions, with no host hop
        between payload and verdict.  Engines with host-side prep parse
        on device then run the 2-D event program; host engines loop
        parts (the bit-equivalence oracle).

        ``n_events`` is the static compacted event bound; pass a
        precomputed one when ``bb`` is device-resident (the pipelined
        ingest loop computes it from the host copy before ``device_put``
        — computing it here would force a device→host read of the byte
        tensor).  The fused path trusts the engine's ``max_depth`` bound
        (a pure-device program cannot host-check depth); the parse-first
        path keeps ``parse_batch``'s raise-on-overflow check.
        """
        from ...kernels.parse import (DEFAULT_MAX_DEPTH, parse_arrays,
                                      parse_batch)

        max_depth = int(getattr(self, "max_depth", DEFAULT_MAX_DEPTH))
        if n_events is None:
            n_events = bb.event_bound(bucket=self._event_bucket(bucket))
        if not self.device_sharded:
            # part-loop oracle; the explicit n_events keeps a placed
            # byte tensor from being read back just to re-derive it
            res = self.filter_batch_sharded(
                parse_batch(bb, n_events=n_events, max_depth=max_depth),
                sharded)
            return lambda: res
        if sharded.plans[0].meta.get("prep") != "events-device":
            eb = parse_batch(bb, n_events=n_events, max_depth=max_depth)
            return self.dispatch_batch_sharded2d(eb, sharded, mesh=mesh)
        data_ax, _ = self._mesh_axes2d(mesh)
        self._check_model_axis(sharded, mesh)
        b0 = bb.batch_size
        bb = bb.pad_batch_to(_round_up(b0, data_ax))
        stacked = sharded.stacked()

        def build():
            vmapped = self._vmapped_parts()

            def body(plan, data):
                parsed = parse_arrays(data, n_events=n_events,
                                      max_depth=max_depth)
                return vmapped(plan, *self._prep_arrays(*parsed))

            ps = jax.sharding.PartitionSpec
            return jax.jit(_shard_map(
                body, mesh,
                in_specs=(ps("model"), ps("data")),
                out_specs=(ps("model", "data"), ps("model", "data"))))

        matched, first = self._cached_exec(
            ("bytes2d", mesh, n_events, max_depth), build)(
                stacked, jnp.asarray(bb.data))
        return self._gather2d(matched, first, sharded, b0)

    def filter_bytes_sharded2d(self, bb: ByteBatch, sharded: ShardedPlan,
                               *, bucket: int | None = None, mesh,
                               n_events: int | None = None) -> FilterResult:
        """Blocking convenience over :meth:`dispatch_bytes_sharded2d`."""
        return self.dispatch_bytes_sharded2d(
            bb, sharded, bucket=bucket, mesh=mesh, n_events=n_events)()

    # ------------------------------------------------------ byte ingestion
    def filter_bytes(self, bb: ByteBatch, *,
                     bucket: int | None = None) -> FilterResult:
        """Raw wire bytes → ``(B, Q)`` verdict, parsed on device.

        The ingestion seam of the paper's same-chip architecture: the
        batch is parsed by :func:`repro.kernels.parse.parse_batch` (no
        per-event host Python) and fed to :meth:`filter_batch` as a
        device-resident :class:`~repro.core.events.EventBatch`.  Device
        engines that can fuse parse+filter into one compiled program
        override this (see ``StreamingEngine.filter_bytes``).

        The parse honours the engine's own ``max_depth`` bound when it
        has one and *raises* on documents nested deeper (parse_batch's
        depth check) — never a silently clipped verdict.  ``bucket``
        bounds the compiled event-axis shapes; ``None`` resolves through
        :meth:`_event_bucket` (callers with their own bucketing policy —
        e.g. ``FilterStage`` — thread theirs via the ``event_bucket=``
        engine option or pass it explicitly).
        """
        from ...kernels.parse import DEFAULT_MAX_DEPTH, parse_batch

        max_depth = int(getattr(self, "max_depth", DEFAULT_MAX_DEPTH))
        return self.filter_batch(
            parse_batch(bb,
                        n_events=bb.event_bound(
                            bucket=self._event_bucket(bucket)),
                        max_depth=max_depth))

    # --------------------------------------------------------- conveniences
    def filter_document(self, ev: EventStream) -> FilterResult:
        """Single-document convenience on top of :meth:`filter_batch`."""
        return self.filter_batch(EventBatch.from_streams([ev]))[0]

    def filter_documents(self, docs) -> FilterResult:
        return self.filter_batch(EventBatch.from_streams(list(docs)))


# -------------------------------------------------------------- the registry
_REGISTRY: dict[str, type[FilterEngine]] = {}


def register(name: str):
    """Class decorator: make the engine constructible by string key."""

    def deco(cls: type[FilterEngine]) -> type[FilterEngine]:
        cls.name = name
        _REGISTRY[name] = cls
        return cls

    return deco


def get(name: str) -> type[FilterEngine]:
    """Engine class for ``name`` (raises with the known names on miss)."""
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown engine {name!r}; registered: {sorted(_REGISTRY)}"
        ) from None


def create(name: str, nfa: NFA, dictionary=None,
           **options: Any) -> FilterEngine:
    """Construct a registered engine: ``create('levelwise', nfa)``."""
    return get(name)(nfa, dictionary=dictionary, **options)


def names() -> tuple[str, ...]:
    """All registered engine keys, sorted."""
    return tuple(sorted(_REGISTRY))
