"""The engine contract: ``FilterPlan`` + ``FilterEngine`` + the registry.

This is the single seam of the filtering stack.  The paper's architecture
(§3) compiles the standing profiles once into hardware blocks and then
streams every document through the same fixed datapath; the software
analogue is:

* :class:`FilterPlan` — the compiled form: a *frozen pytree* of
  precomputed device tables (REQ / parent-one-hot / accept matrices,
  packed init words, …) plus static metadata.  Built once per profile
  set by :meth:`FilterEngine.plan`; every ``filter_batch`` call reuses
  it, so tracing/compilation happens once and the plan can be passed
  through ``jax.jit`` boundaries as an ordinary pytree argument.
* :class:`FilterEngine` — the uniform engine interface: ``plan(nfa)``
  and ``filter_batch(EventBatch) -> FilterResult`` with ``(B, Q)``
  outputs.  Engines are free to run on device (streaming, levelwise,
  matscan) or on the host (oracle, yfilter) — callers cannot tell.
* the **registry** — engines self-register under a string key;
  ``engines.get("levelwise")`` / ``engines.create("levelwise", nfa)``
  is how every pipeline, benchmark and example constructs one, so an
  engine comparison is a flag, not an import.

Adding an engine::

    from repro.core.engines import base

    @base.register("myengine")
    class MyEngine(base.FilterEngine):
        def plan(self, nfa):
            return base.FilterPlan("myengine",
                                   tables={"req": jnp.asarray(...)},
                                   meta={"n_states": nfa.n_states})
        def filter_batch(self, batch):
            ...
"""
from __future__ import annotations

import abc
from typing import Any, ClassVar, Mapping

import jax

from ..events import ByteBatch, EventBatch, EventStream
from ..nfa import NFA
from .result import FilterResult


# ----------------------------------------------------------------- the plan
class FilterPlan:
    """Frozen pytree: named device tables + static (hashable) metadata.

    ``plan.tables`` maps table name → array (the pytree leaves);
    ``plan.meta`` maps name → static value (pytree aux data, so jit
    retraces when it changes).  Instances are immutable — build a new
    plan instead of mutating one.
    """

    __slots__ = ("engine", "_names", "_arrays", "_meta")

    def __init__(self, engine: str, tables: Mapping[str, Any],
                 meta: Mapping[str, Any] | None = None) -> None:
        names = tuple(sorted(tables))
        object.__setattr__(self, "engine", engine)
        object.__setattr__(self, "_names", names)
        object.__setattr__(self, "_arrays", tuple(tables[n] for n in names))
        object.__setattr__(self, "_meta",
                           tuple(sorted((meta or {}).items())))

    def __setattr__(self, name: str, value: Any) -> None:
        raise AttributeError("FilterPlan is frozen")

    @property
    def tables(self) -> dict[str, Any]:
        return dict(zip(self._names, self._arrays))

    @property
    def meta(self) -> dict[str, Any]:
        return dict(self._meta)

    def table(self, name: str) -> Any:
        return self._arrays[self._names.index(name)]

    def __getitem__(self, name: str) -> Any:
        return self.table(name)

    def __repr__(self) -> str:  # pragma: no cover
        return (f"FilterPlan({self.engine!r}, tables={list(self._names)}, "
                f"meta={self.meta})")

    # pytree protocol -----------------------------------------------------
    def _flatten(self):
        return self._arrays, (self.engine, self._names, self._meta)

    @classmethod
    def _unflatten(cls, aux, leaves):
        engine, names, meta = aux
        self = cls.__new__(cls)
        object.__setattr__(self, "engine", engine)
        object.__setattr__(self, "_names", names)
        object.__setattr__(self, "_arrays", tuple(leaves))
        object.__setattr__(self, "_meta", meta)
        return self


jax.tree_util.register_pytree_node(
    FilterPlan, FilterPlan._flatten, FilterPlan._unflatten)


# --------------------------------------------------------------- the engine
class FilterEngine(abc.ABC):
    """Uniform engine interface: compile once, filter batches forever.

    ``__init__`` compiles the profile set (via :meth:`plan`) exactly once;
    :meth:`filter_batch` is then a pure function of the plan and an
    :class:`~repro.core.events.EventBatch` — the only document format an
    engine ever sees.
    """

    #: registry key, set by the :func:`register` decorator
    name: ClassVar[str] = ""

    def __init__(self, nfa: NFA, dictionary=None, **options: Any) -> None:
        self.nfa = nfa
        self.dictionary = dictionary
        self.options = options
        self.n_queries = nfa.n_queries
        self.plan_: FilterPlan = self.plan(nfa)

    # ------------------------------------------------------------ contract
    @abc.abstractmethod
    def plan(self, nfa: NFA) -> FilterPlan:
        """Compile the NFA into this engine's device tables (once)."""

    @abc.abstractmethod
    def filter_batch(self, batch: EventBatch) -> FilterResult:
        """Filter a document batch; returns a ``(B, Q)`` result."""

    # ------------------------------------------------------ byte ingestion
    def filter_bytes(self, bb: ByteBatch, *,
                     bucket: int = 128) -> FilterResult:
        """Raw wire bytes → ``(B, Q)`` verdict, parsed on device.

        The ingestion seam of the paper's same-chip architecture: the
        batch is parsed by :func:`repro.kernels.parse.parse_batch` (no
        per-event host Python) and fed to :meth:`filter_batch` as a
        device-resident :class:`~repro.core.events.EventBatch`.  Device
        engines that can fuse parse+filter into one compiled program
        override this (see ``StreamingEngine.filter_bytes``).

        The parse honours the engine's own ``max_depth`` bound when it
        has one and *raises* on documents nested deeper (parse_batch's
        depth check) — never a silently clipped verdict.  ``bucket``
        bounds the compiled event-axis shapes (callers with their own
        bucketing policy — e.g. ``FilterStage`` — pass theirs through).
        """
        from ...kernels.parse import DEFAULT_MAX_DEPTH, parse_batch

        max_depth = int(getattr(self, "max_depth", DEFAULT_MAX_DEPTH))
        return self.filter_batch(
            parse_batch(bb, n_events=bb.event_bound(bucket=bucket),
                        max_depth=max_depth))

    # --------------------------------------------------------- conveniences
    def filter_document(self, ev: EventStream) -> FilterResult:
        """Single-document convenience on top of :meth:`filter_batch`."""
        return self.filter_batch(EventBatch.from_streams([ev]))[0]

    def filter_documents(self, docs) -> FilterResult:
        return self.filter_batch(EventBatch.from_streams(list(docs)))


# -------------------------------------------------------------- the registry
_REGISTRY: dict[str, type[FilterEngine]] = {}


def register(name: str):
    """Class decorator: make the engine constructible by string key."""

    def deco(cls: type[FilterEngine]) -> type[FilterEngine]:
        cls.name = name
        _REGISTRY[name] = cls
        return cls

    return deco


def get(name: str) -> type[FilterEngine]:
    """Engine class for ``name`` (raises with the known names on miss)."""
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown engine {name!r}; registered: {sorted(_REGISTRY)}"
        ) from None


def create(name: str, nfa: NFA, dictionary=None,
           **options: Any) -> FilterEngine:
    """Construct a registered engine: ``create('levelwise', nfa)``."""
    return get(name)(nfa, dictionary=dictionary, **options)


def names() -> tuple[str, ...]:
    """All registered engine keys, sorted."""
    return tuple(sorted(_REGISTRY))
