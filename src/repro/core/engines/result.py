"""Common result containers for all engines.

Two verdict forms share one semantics:

* :class:`FilterResult` — the dense ``(B, Q)`` bitmap every engine
  returns from ``filter_batch``.
* :class:`SparseResult` — the match-list wire form for the subscription
  scale-up: one ``(doc_id, query_id, first_event)`` row per match, so
  delivery bandwidth scales with ``matches`` instead of ``B × Q``.

Both carry an optional ``live`` column mask: a churned sharded plan
tombstones removed query columns without recompiling, and those dead
columns must not count in any selectivity denominator or show up in
``matching_queries``.  ``densify``/``sparsify`` round-trip exactly.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Iterator, Sequence

import numpy as np

NO_MATCH = np.iinfo(np.int32).max


def _live_mask(live, n_queries: int) -> np.ndarray | None:
    if live is None:
        return None
    live = np.asarray(live, dtype=bool)
    assert live.shape == (n_queries,), (live.shape, n_queries)
    return live


@dataclass
class FilterResult:
    """Per-query outcome of filtering one document — or a batch of them.

    Single document: ``matched``/``first_event`` have shape ``(Q,)``.
    Batched (the :meth:`repro.core.engines.base.FilterEngine.filter_batch`
    contract): shape ``(B, Q)``; ``res[i]`` recovers document i's view.

    ``matched[..., q]`` — document satisfies profile q.
    ``first_event[..., q]`` — event index of the first accepting OPEN event
    (the paper's "location of the match inside the document structure"),
    ``NO_MATCH`` when unmatched.
    ``live[q]`` — optional column-liveness mask: ``False`` marks a
    tombstoned (unsubscribed) or padded column, excluded from
    :meth:`matching_queries` and the :meth:`selectivity` denominator.
    ``None`` means every column is live.
    """

    matched: np.ndarray      # (..., Q) bool
    first_event: np.ndarray  # (..., Q) int32
    live: np.ndarray | None = None  # (Q,) bool, None = all live

    def __post_init__(self) -> None:
        self.matched = np.asarray(self.matched, dtype=bool)
        self.first_event = np.asarray(self.first_event, dtype=np.int32)
        assert self.matched.shape == self.first_event.shape
        self.live = _live_mask(self.live, self.matched.shape[-1])

    # ------------------------------------------------------------ structure
    @property
    def n_queries(self) -> int:
        return int(self.matched.shape[-1])

    @property
    def n_live(self) -> int:
        """Live query columns (tombstones excluded)."""
        if self.live is None:
            return self.n_queries
        return int(self.live.sum())

    @property
    def batch_shape(self) -> tuple[int, ...]:
        return tuple(self.matched.shape[:-1])

    def __len__(self) -> int:
        if not self.batch_shape:
            raise TypeError("len() of a single-document FilterResult")
        return int(self.batch_shape[0])

    def __getitem__(self, i) -> "FilterResult":
        if not self.batch_shape:
            raise TypeError("single-document FilterResult is not indexable")
        return FilterResult(self.matched[i], self.first_event[i], self.live)

    def per_document(self) -> Iterator["FilterResult"]:
        """Iterate a batched result as single-document results."""
        for i in range(len(self)):
            yield self[i]

    @classmethod
    def stack(cls, results: Sequence["FilterResult"]) -> "FilterResult":
        """Stack single-document results into one batched result."""
        return cls(np.stack([r.matched for r in results]),
                   np.stack([r.first_event for r in results]),
                   results[0].live)

    # ------------------------------------------------------------- queries
    def matching_queries(self) -> np.ndarray:
        if self.batch_shape:
            raise TypeError("matching_queries() needs a single-document "
                            "result; index the batch first")
        m = self.matched if self.live is None else self.matched & self.live
        return np.nonzero(m)[0]

    def selectivity(self) -> float:
        """Fraction of (doc, *live* profile) pairs that match.

        Tombstoned/padded columns are excluded from the denominator, so
        a churned sharded plan reports the selectivity of what is
        actually subscribed.
        """
        m = self.matched if self.live is None else self.matched[..., self.live]
        return float(m.mean()) if m.size else 0.0

    def sparsify(self, live_ids: np.ndarray | None = None) -> "SparseResult":
        """Match-list view of a batched result (see :class:`SparseResult`).

        ``live_ids`` optionally renames columns to global subscriber ids
        (``query_ids[k] = live_ids[column]``, the ``FilterStage`` gid
        mapping); without it columns keep their local indices.
        """
        if not self.batch_shape:
            raise TypeError("sparsify() needs a batched (B, Q) result")
        m = self.matched if self.live is None else self.matched & self.live
        docs, cols = np.nonzero(m)
        first = self.first_event[docs, cols]
        qids = cols if live_ids is None else np.asarray(live_ids)[cols]
        return SparseResult(
            doc_ids=docs.astype(np.int32),
            query_ids=qids.astype(np.int32),
            first_event=first.astype(np.int32),
            batch_size=int(self.matched.shape[0]),
            n_queries=self.n_queries,
            live=self.live,
            live_ids=(None if live_ids is None
                      else np.asarray(live_ids, np.int32)),
        )

    def __eq__(self, other: object) -> bool:  # pragma: no cover
        if not isinstance(other, FilterResult):
            return NotImplemented
        return bool(
            self.matched.shape == other.matched.shape
            and (self.matched == other.matched).all()
            and (self.first_event == other.first_event).all()
        )


@dataclass
class SparseResult:
    """Sparse verdicts: one row per (document, subscriber) match.

    The wire format of sparse delivery — three aligned int32 columns::

        doc_ids[k]      batch row of match k
        query_ids[k]    matching query (column index, or global id when
                        the producer supplied ``live_ids``)
        first_event[k]  event index of the first accepting OPEN

    Rows are sorted by (doc, column).  ``verdict_bytes`` is what delivery
    actually moves: 12 bytes per match instead of the dense ``B × Q × 5``
    — the whole point at 10⁵⁺ subscriptions, where selectivity is low
    and the dense bitmap is almost entirely zeros.

    ``overflowed=True`` records that the bounded device match buffer
    overflowed and the rows came from the dense fallback instead — the
    verdicts are still exact, only the bandwidth win is lost for that
    batch.  :meth:`densify` reconstructs the dense
    :class:`FilterResult` bit-exactly.
    """

    doc_ids: np.ndarray      # (M,) int32
    query_ids: np.ndarray    # (M,) int32
    first_event: np.ndarray  # (M,) int32
    batch_size: int
    n_queries: int           # dense column-space width
    live: np.ndarray | None = None      # (n_queries,) bool, None = all live
    live_ids: np.ndarray | None = None  # column → global id, when renamed
    overflowed: bool = False
    meta: dict = field(default_factory=dict)  # producer stats (buffer cap …)

    def __post_init__(self) -> None:
        self.doc_ids = np.asarray(self.doc_ids, np.int32)
        self.query_ids = np.asarray(self.query_ids, np.int32)
        self.first_event = np.asarray(self.first_event, np.int32)
        assert self.doc_ids.shape == self.query_ids.shape \
            == self.first_event.shape
        self.live = _live_mask(self.live, self.n_queries)

    @property
    def n_matches(self) -> int:
        return int(self.doc_ids.shape[0])

    @property
    def n_live(self) -> int:
        if self.live is None:
            return self.n_queries
        return int(self.live.sum())

    @property
    def verdict_bytes(self) -> int:
        """Bytes this verdict representation moves (3 int32 per match)."""
        return 12 * self.n_matches

    @property
    def dense_bytes(self) -> int:
        """What the dense ``(B, Q)`` twin would move (bool + int32)."""
        return self.batch_size * self.n_queries * 5

    def selectivity(self) -> float:
        """Matches over (doc, live profile) pairs — tombstones excluded."""
        pairs = self.batch_size * self.n_live
        return self.n_matches / pairs if pairs else 0.0

    def matching_queries(self, doc: int) -> np.ndarray:
        """Matching column/global ids of one document, ascending."""
        return np.sort(self.query_ids[self.doc_ids == doc])

    def densify(self) -> FilterResult:
        """Exact dense reconstruction (round-trip of ``sparsify``)."""
        cols = self.query_ids
        if self.live_ids is not None:  # global ids → column indices
            back = np.full(int(self.live_ids.max(initial=-1)) + 1, -1,
                           np.int32)
            back[self.live_ids] = np.arange(self.live_ids.shape[0],
                                            dtype=np.int32)
            cols = back[cols]
        matched = np.zeros((self.batch_size, self.n_queries), bool)
        first = np.full((self.batch_size, self.n_queries), NO_MATCH,
                        np.int32)
        matched[self.doc_ids, cols] = True
        first[self.doc_ids, cols] = self.first_event
        return FilterResult(matched, first, self.live)
