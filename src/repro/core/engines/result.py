"""Common result container for all engines."""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

NO_MATCH = np.iinfo(np.int32).max


@dataclass
class FilterResult:
    """Per-query outcome of filtering one document.

    ``matched[q]`` — document satisfies profile q.
    ``first_event[q]`` — event index of the first accepting OPEN event
    (the paper's "location of the match inside the document structure"),
    ``NO_MATCH`` when unmatched.  Engines that cannot report locations
    (matscan prefix products report them; oracle does) set it to
    ``NO_MATCH`` for unmatched queries only.
    """

    matched: np.ndarray      # (Q,) bool
    first_event: np.ndarray  # (Q,) int32

    def __post_init__(self) -> None:
        self.matched = np.asarray(self.matched, dtype=bool)
        self.first_event = np.asarray(self.first_event, dtype=np.int32)

    def matching_queries(self) -> np.ndarray:
        return np.nonzero(self.matched)[0]

    def __eq__(self, other: object) -> bool:  # pragma: no cover
        if not isinstance(other, FilterResult):
            return NotImplemented
        return bool(
            (self.matched == other.matched).all()
            and (self.first_event == other.first_event).all()
        )
