"""Common result container for all engines."""
from __future__ import annotations

from dataclasses import dataclass
from typing import Iterator, Sequence

import numpy as np

NO_MATCH = np.iinfo(np.int32).max


@dataclass
class FilterResult:
    """Per-query outcome of filtering one document — or a batch of them.

    Single document: ``matched``/``first_event`` have shape ``(Q,)``.
    Batched (the :meth:`repro.core.engines.base.FilterEngine.filter_batch`
    contract): shape ``(B, Q)``; ``res[i]`` recovers document i's view.

    ``matched[..., q]`` — document satisfies profile q.
    ``first_event[..., q]`` — event index of the first accepting OPEN event
    (the paper's "location of the match inside the document structure"),
    ``NO_MATCH`` when unmatched.
    """

    matched: np.ndarray      # (..., Q) bool
    first_event: np.ndarray  # (..., Q) int32

    def __post_init__(self) -> None:
        self.matched = np.asarray(self.matched, dtype=bool)
        self.first_event = np.asarray(self.first_event, dtype=np.int32)
        assert self.matched.shape == self.first_event.shape

    # ------------------------------------------------------------ structure
    @property
    def n_queries(self) -> int:
        return int(self.matched.shape[-1])

    @property
    def batch_shape(self) -> tuple[int, ...]:
        return tuple(self.matched.shape[:-1])

    def __len__(self) -> int:
        if not self.batch_shape:
            raise TypeError("len() of a single-document FilterResult")
        return int(self.batch_shape[0])

    def __getitem__(self, i) -> "FilterResult":
        if not self.batch_shape:
            raise TypeError("single-document FilterResult is not indexable")
        return FilterResult(self.matched[i], self.first_event[i])

    def per_document(self) -> Iterator["FilterResult"]:
        """Iterate a batched result as single-document results."""
        for i in range(len(self)):
            yield self[i]

    @classmethod
    def stack(cls, results: Sequence["FilterResult"]) -> "FilterResult":
        """Stack single-document results into one batched result."""
        return cls(np.stack([r.matched for r in results]),
                   np.stack([r.first_event for r in results]))

    # ------------------------------------------------------------- queries
    def matching_queries(self) -> np.ndarray:
        if self.batch_shape:
            raise TypeError("matching_queries() needs a single-document "
                            "result; index the batch first")
        return np.nonzero(self.matched)[0]

    def selectivity(self) -> float:
        """Fraction of (doc, profile) pairs that match."""
        return float(self.matched.mean())

    def __eq__(self, other: object) -> bool:  # pragma: no cover
        if not isinstance(other, FilterResult):
            return NotImplemented
        return bool(
            self.matched.shape == other.matched.shape
            and (self.matched == other.matched).all()
            and (self.first_event == other.first_event).all()
        )
