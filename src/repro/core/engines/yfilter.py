"""YFilter-style software baseline (the paper's §4 comparison system).

Event-driven NFA execution on the CPU, the way YFilter [11] does it: a
runtime stack of active-state sets, advanced per SAX event.  Pure python
and intentionally "von Neumann" — this is the baseline the FPGA (and our
TPU engines) are measured against in the Fig-9 reproduction.
"""
from __future__ import annotations

from collections import defaultdict

import numpy as np

from ..events import CLOSE, OPEN, EventBatch, EventStream
from ..nfa import NFA, WILD_TAG
from . import base
from .result import NO_MATCH, FilterResult


def _adjacency(nfa: NFA):
    """NFA tables → adjacency-list execution form (host-side 'plan')."""
    t = nfa.tables
    by_src_tag: dict[int, dict[int, list[int]]] = defaultdict(dict)
    by_src_wild: dict[int, list[int]] = defaultdict(list)
    for s in range(1, t.in_state.shape[0]):
        u = int(t.in_state[s])
        tag = int(t.in_tag[s])
        if tag == WILD_TAG:
            by_src_wild[u].append(s)
        elif tag >= 0:
            by_src_tag[u].setdefault(tag, []).append(s)
    accept_of_state: dict[int, list[int]] = defaultdict(list)
    for q, s in enumerate(t.accept_state.tolist()):
        accept_of_state[s].append(q)
    return dict(
        by_src_tag=dict(by_src_tag),
        by_src_wild=dict(by_src_wild),
        selfloop=frozenset(np.nonzero(t.selfloop)[0].tolist()),
        init=frozenset(np.nonzero(t.init)[0].tolist()),
        accept_of_state=dict(accept_of_state),
    )


@base.register("yfilter")
class YFilterEngine(base.FilterEngine):
    """Precompiled adjacency-list execution of the shared NFA.

    Host engine: sharded plans are looped part by part — the software
    baseline doubles as a second equivalence oracle for the stacked
    device execution.
    """

    def plan(self, nfa: NFA) -> base.FilterPlan:
        # host tables, not device arrays — the plan never enters jit
        return base.FilterPlan("yfilter", tables=_adjacency(nfa),
                               meta={"n_queries": nfa.n_queries,
                                     # host engine: 2-D mesh paths loop
                                     # parts (second equivalence oracle)
                                     "prep": "host"})

    # ------------------------------------------------------------------ run
    def filter_document(self, ev: EventStream) -> FilterResult:
        return self._run_document(self.plan_, ev)

    def _run_document(self, p: base.FilterPlan,
                      ev: EventStream) -> FilterResult:
        n_q = p.meta["n_queries"]
        matched = np.zeros(n_q, dtype=bool)
        first = np.full(n_q, NO_MATCH, dtype=np.int32)
        stack: list[frozenset[int]] = [p["init"]]
        kinds = ev.kind
        tags = ev.tag_id
        by_tag = p["by_src_tag"]
        by_wild = p["by_src_wild"]
        loops = p["selfloop"]
        accepts = p["accept_of_state"]
        for i in range(len(ev)):
            k = kinds[i]
            if k == OPEN:
                tag = int(tags[i])
                cur = stack[-1]
                nxt = set()
                for u in cur:
                    d = by_tag.get(u)
                    if d is not None:
                        nxt.update(d.get(tag, ()))
                    w = by_wild.get(u)
                    if w is not None:
                        nxt.update(w)
                    if u in loops:
                        nxt.add(u)
                for s in nxt:
                    qs = accepts.get(s)
                    if qs:
                        for q in qs:
                            if not matched[q]:
                                matched[q] = True
                                first[q] = i
                stack.append(frozenset(nxt))
            elif k == CLOSE:
                if len(stack) > 1:
                    stack.pop()
        return FilterResult(matched, first)

    def filter_batch_with_plan(self, plan: base.FilterPlan,
                               batch: EventBatch) -> FilterResult:
        return FilterResult.stack(
            [self._run_document(plan, ev)
             for ev in batch.to_host().streams()])

    def filter_batch(self, batch: EventBatch) -> FilterResult:
        return self.filter_batch_with_plan(self.plan_, batch)
