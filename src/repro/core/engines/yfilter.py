"""YFilter-style software baseline (the paper's §4 comparison system).

Event-driven NFA execution on the CPU, the way YFilter [11] does it: a
runtime stack of active-state sets, advanced per SAX event.  Pure python
and intentionally "von Neumann" — this is the baseline the FPGA (and our
TPU engines) are measured against in the Fig-9 reproduction.
"""
from __future__ import annotations

from collections import defaultdict

import numpy as np

from ..events import CLOSE, OPEN, EventStream
from ..nfa import NFA, WILD_TAG
from .result import NO_MATCH, FilterResult


class YFilterEngine:
    """Precompiled adjacency-list execution of the shared NFA."""

    def __init__(self, nfa: NFA) -> None:
        t = nfa.tables
        self.n_queries = nfa.n_queries
        # by_src_tag[u][tag] -> list of target states; wildcard edges separate
        by_src_tag: dict[int, dict[int, list[int]]] = defaultdict(dict)
        by_src_wild: dict[int, list[int]] = defaultdict(list)
        for s in range(1, t.in_state.shape[0]):
            u = int(t.in_state[s])
            tag = int(t.in_tag[s])
            if tag == WILD_TAG:
                by_src_wild[u].append(s)
            elif tag >= 0:
                by_src_tag[u].setdefault(tag, []).append(s)
        self.by_src_tag = dict(by_src_tag)
        self.by_src_wild = dict(by_src_wild)
        self.selfloop = frozenset(np.nonzero(t.selfloop)[0].tolist())
        self.init = frozenset(np.nonzero(t.init)[0].tolist())
        accept_of_state: dict[int, list[int]] = defaultdict(list)
        for q, s in enumerate(t.accept_state.tolist()):
            accept_of_state[s].append(q)
        self.accept_of_state = dict(accept_of_state)

    # ------------------------------------------------------------------ run
    def filter_document(self, ev: EventStream) -> FilterResult:
        matched = np.zeros(self.n_queries, dtype=bool)
        first = np.full(self.n_queries, NO_MATCH, dtype=np.int32)
        stack: list[frozenset[int]] = [self.init]
        kinds = ev.kind
        tags = ev.tag_id
        by_tag = self.by_src_tag
        by_wild = self.by_src_wild
        loops = self.selfloop
        accepts = self.accept_of_state
        for i in range(len(ev)):
            k = kinds[i]
            if k == OPEN:
                tag = int(tags[i])
                cur = stack[-1]
                nxt = set()
                for u in cur:
                    d = by_tag.get(u)
                    if d is not None:
                        nxt.update(d.get(tag, ()))
                    w = by_wild.get(u)
                    if w is not None:
                        nxt.update(w)
                    if u in loops:
                        nxt.add(u)
                for s in nxt:
                    qs = accepts.get(s)
                    if qs:
                        for q in qs:
                            if not matched[q]:
                                matched[q] = True
                                first[q] = i
                stack.append(frozenset(nxt))
            elif k == CLOSE:
                if len(stack) > 1:
                    stack.pop()
        return FilterResult(matched, first)

    def filter_documents(self, docs: list[EventStream]) -> list[FilterResult]:
        return [self.filter_document(d) for d in docs]
