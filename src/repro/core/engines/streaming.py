"""Paper-faithful JAX streaming engine.

Direct datapath analogue of the FPGA design (Fig 4/5): every NFA state is
one "hardware" lane; each event advances *all* lanes simultaneously; a
bounded on-chip stack of packed 32-bit state bitmasks realizes the paper's
tag stack (push on open, pop on close); the TOS-match is the read of the
stack top that feeds the transition.

The document is consumed with one ``lax.scan`` step per event — the TPU
analogue of the paper's one-symbol-per-clock pipeline (we step per *event*
rather than per byte; the byte→event pre-decode is its own parallel kernel,
:mod:`repro.kernels.predecode`, mirroring the paper's §3.4 pre-decoder).

State bitmasks are packed ``uint32`` words (the FPGA keeps one FF per
state; we keep one bit), so the scan carry is ``(max_depth+2, S/32)`` words
per document — small enough for VMEM at thousands of queries, and XLA
donates it in place across scan steps.

Compilation happens once, in :meth:`StreamingEngine.plan`; the batched
path is ``vmap`` of the same scan over an
:class:`~repro.core.events.EventBatch`.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from ..dictionary import OPEN_NBYTES
from ..events import CLOSE, OPEN, ByteBatch, EventBatch, EventStream
from ..nfa import NFA, WILD_TAG, pad_states
from . import base
from .result import NO_MATCH, FilterResult


def _pack_words(bits: jax.Array) -> jax.Array:
    """(..., S) int32 0/1 → (..., S/32) uint32."""
    s = bits.shape[-1]
    lanes = bits.reshape(bits.shape[:-1] + (s // 32, 32)).astype(jnp.uint32)
    weights = (jnp.uint32(1) << jnp.arange(32, dtype=jnp.uint32))
    return (lanes * weights).sum(axis=-1, dtype=jnp.uint32)


def _unpack_words(words: jax.Array) -> jax.Array:
    """(..., W) uint32 → (..., W*32) int32 0/1."""
    shifts = jnp.arange(32, dtype=jnp.uint32)
    bits = (words[..., None] >> shifts) & jnp.uint32(1)
    return bits.reshape(words.shape[:-1] + (words.shape[-1] * 32,)).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("n_states", "max_depth"))
def _run(kind, tag, in_state, in_tag, selfloop, init_words, accept_state,
         *, n_states: int, max_depth: int):
    n_ev = kind.shape[0]
    n_q = accept_state.shape[0]
    n_w = n_states // 32
    stack0 = jnp.zeros((max_depth + 2, n_w), dtype=jnp.uint32)
    stack0 = stack0.at[0].set(init_words)

    def step(carry, xs):
        stack, depth, matched, first = carry
        k, t, i = xs
        is_open = k == OPEN
        is_close = k == CLOSE
        row = jax.lax.dynamic_index_in_dim(stack, depth, keepdims=False)
        bits = _unpack_words(row)                       # (S,) int32 — the FFs
        tagmatch = ((in_tag == t) | (in_tag == WILD_TAG)).astype(jnp.int32)
        src = jnp.take(bits, in_state, axis=0)          # previous-block wire
        nxt = (src & tagmatch) | (selfloop & bits)      # all lanes, one "clock"
        words = _pack_words(nxt)
        # push on open (write at depth+1), no-op otherwise
        widx = jnp.clip(depth + 1, 0, max_depth + 1)
        old = jax.lax.dynamic_index_in_dim(stack, widx, keepdims=False)
        new_row = jnp.where(is_open, words, old)
        stack = jax.lax.dynamic_update_index_in_dim(stack, new_row, widx, 0)
        depth = depth + jnp.where(is_open, 1, jnp.where(is_close, -1, 0))
        depth = jnp.clip(depth, 0, max_depth + 1)
        # accept lanes → priority-encoder analogue
        acc = jnp.take(nxt, accept_state, axis=0).astype(bool) & is_open
        newly = acc & (~matched)
        first = jnp.where(newly, i, first)
        matched = matched | acc
        return (stack, depth, matched, first), None

    carry0 = (stack0, jnp.int32(0),
              jnp.zeros(n_q, dtype=bool), jnp.full(n_q, NO_MATCH, jnp.int32))
    (stack, depth, matched, first), _ = jax.lax.scan(
        step, carry0, (kind, tag, jnp.arange(n_ev, dtype=jnp.int32)))
    return matched, first


@jax.jit
def _run_batch(plan: base.FilterPlan, kind: jax.Array, tag: jax.Array):
    """vmap of the event scan over a (B, N) batch; plan is a pytree arg,
    so one trace serves every batch of the same shape."""
    meta = plan.meta
    fn = functools.partial(
        _run,
        in_state=plan["in_state"], in_tag=plan["in_tag"],
        selfloop=plan["selfloop"], init_words=plan["init_words"],
        accept_state=plan["accept_state"],
        n_states=meta["n_states"], max_depth=meta["max_depth"])
    return jax.vmap(fn, in_axes=(0, 0))(kind, tag)


@functools.partial(jax.jit, static_argnames=("n_events",))
def _run_bytes_batch(plan: base.FilterPlan, data: jax.Array,
                     n_events: int | None = None):
    """Fused ingest+filter: (B, L) raw wire bytes → (B, Q) verdicts as ONE
    compiled program — the paper's same-chip parser+filter (§1).

    The one byte→event pipeline (:func:`repro.kernels.parse.parse_arrays`:
    batched pre-decode + cumsum compaction) and the event-stream state
    scan inline into a single XLA computation; the structure outputs this
    engine doesn't read (depth/parent scans) are dead-code-eliminated.
    Between the byte tensor going in and the verdict coming out there is
    no host transfer and no per-event Python.  ``n_events`` is the static
    compacted length (callers pass the tight ``ByteBatch.event_bound``;
    defaults to the worst case L/4).
    """
    from repro.kernels import parse as parse_mod

    if n_events is None:
        n_events = max(1, data.shape[1] // OPEN_NBYTES)
    kind, tag, _depth, _parent, _valid, _n = parse_mod.parse_arrays(
        data, n_events=n_events)
    return _run_batch(plan, kind.astype(jnp.int32), tag)


@base.register("streaming")
class StreamingEngine(base.FilterEngine):
    """Public API: compile once (``plan``), filter many documents."""

    #: packed-word layout: the state axis must tile into 32-bit words
    state_multiple = 32
    device_sharded = True

    def __init__(self, nfa: NFA, dictionary=None, max_depth: int = 64,
                 **options) -> None:
        self.max_depth = max_depth
        sm = int(options.get("state_multiple", self.state_multiple))
        if sm % 32 != 0:
            raise ValueError(
                f"streaming packs 32-state words; state_multiple={sm} "
                f"is not a multiple of 32")
        super().__init__(nfa, dictionary, **options)

    def plan(self, nfa: NFA) -> base.FilterPlan:
        nfa = pad_states(nfa, self.state_multiple)
        t = nfa.tables
        init_words = jax.device_get(
            _pack_words(jnp.asarray(t.init.astype(np.int32))))
        return base.FilterPlan(
            "streaming",
            tables=dict(
                in_state=jnp.asarray(t.in_state),
                in_tag=jnp.asarray(t.in_tag),
                selfloop=jnp.asarray(t.selfloop.astype(np.int32)),
                init_words=jnp.asarray(init_words),
                accept_state=jnp.asarray(t.accept_state),
            ),
            meta={"n_states": int(t.in_state.shape[0]),
                  "max_depth": self.max_depth,
                  "state_multiple": self.state_multiple,
                  # document prep is pure-device (the scan consumes the
                  # raw event stream), so the 2-D mesh path can fuse
                  # parse+filter into one shard_map program
                  "prep": "events-device"},
        )

    # --------------------------------------------------- explicit-plan body
    def _prep(self, batch: EventBatch) -> tuple:
        return (jnp.asarray(batch.kind.astype(np.int32)),
                jnp.asarray(batch.tag_id))

    def _prep_arrays(self, kind, tag, depth, parent, valid, n_events):
        # the scan reads only (kind, tag); depth/parent/valid are
        # dead-code-eliminated out of the fused program
        return (kind.astype(jnp.int32), tag)

    def _run_with_plan(self, plan: base.FilterPlan, prep: tuple):
        kind, tag = prep
        return _run_batch(plan, kind, tag)

    def filter_document(self, ev: EventStream) -> FilterResult:
        p = self.plan_
        matched, first = _run(
            jnp.asarray(ev.kind.astype(np.int32)),
            jnp.asarray(ev.tag_id),
            p["in_state"], p["in_tag"], p["selfloop"], p["init_words"],
            p["accept_state"],
            n_states=p.meta["n_states"], max_depth=p.meta["max_depth"])
        return FilterResult(np.asarray(matched), np.asarray(first))

    def filter_batch(self, batch: EventBatch) -> FilterResult:
        return self.filter_batch_with_plan(self.plan_, batch)

    def filter_bytes(self, bb: ByteBatch, *,
                     bucket: int = 128) -> FilterResult:
        """Bytes → verdict as one jitted program (no intermediate
        EventBatch, no host round-trip) — see :func:`_run_bytes_batch`."""
        matched, first = _run_bytes_batch(self.plan_, jnp.asarray(bb.data),
                                          bb.event_bound(bucket=bucket))
        return FilterResult(np.asarray(matched), np.asarray(first))

    def filter_documents_batched(self, kind: np.ndarray,
                                 tag: np.ndarray) -> FilterResult:
        """Legacy raw-array batched API (prefer :meth:`filter_batch`)."""
        matched, first = _run_batch(
            self.plan_, jnp.asarray(np.asarray(kind).astype(np.int32)),
            jnp.asarray(tag))
        return FilterResult(np.asarray(matched), np.asarray(first))
