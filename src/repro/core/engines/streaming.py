"""Paper-faithful JAX streaming engine.

Direct datapath analogue of the FPGA design (Fig 4/5): every NFA state is
one "hardware" lane; each event advances *all* lanes simultaneously; a
bounded on-chip stack of packed 32-bit state bitmasks realizes the paper's
tag stack (push on open, pop on close); the TOS-match is the read of the
stack top that feeds the transition.

Two executions of the same semantics:

* **megakernel** (``kernel="pallas"``, the default device path on TPU) —
  :func:`repro.kernels.stream_filter.stream_filter_pallas`: one fused
  Pallas program gridded over (documents × state-word blocks), state
  packed in VMEM end to end, events DMA'd through double-buffered SMEM
  chunks.  Block tables are compiled into the plan
  (:func:`repro.kernels.blocks.state_layout`), block/chunk sizes come
  from the plan-level autotune hook
  (:meth:`repro.core.engines.base.FilterEngine.autotune_blocks`).
* **scan** (``kernel="scan"``, the oracle/fallback and the default off
  TPU, where Pallas only interprets) — one ``lax.scan`` step per event;
  the kernel is bit-identical to it by construction and by test
  (tests/test_megakernel.py).

State bitmasks are packed ``uint32`` words (the FPGA keeps one FF per
state; we keep one bit), so the per-document stack is ``(max_depth+2,
S/32)`` words — small enough for VMEM at thousands of queries.  The one
``max_depth`` in the plan metadata bounds *both* paths, so kernel and
scan can never disagree on stack clipping.

Compilation happens once, in :meth:`StreamingEngine.plan`; the batched
path is ``vmap`` of the scan — or one megakernel launch — over an
:class:`~repro.core.events.EventBatch`.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from ...kernels import blocks as blocks_mod
from ...kernels import interpret_default
from ...kernels import stream_filter as sf
from ...kernels.parse import DEFAULT_MAX_DEPTH
from ...sharding.compat import shard_map_compat as _shard_map
from ..dictionary import OPEN_NBYTES
from ..events import (CLOSE, OPEN, SEG_SENTINEL, ByteBatch, EventBatch,
                      EventStream, SegmentPack, pack_segments)
from ..nfa import NFA, WILD_TAG, pad_states
from . import base
from .result import NO_MATCH, FilterResult, SparseResult

#: execution modes for the ``kernel=`` engine option
KERNEL_MODES = ("auto", "pallas", "scan")

#: bytes per DMA chunk of the one-launch bytes megakernel (distinct from
#: the event kernel's events-per-chunk ``chunk``) and the segment-packer
#: capacity target — both autotunable (:mod:`repro.kernels.autotune`)
#: and overridable via the ``byte_chunk=`` / ``segment_target=`` engine
#: options
DEFAULT_BYTE_CHUNK = 512
DEFAULT_SEGMENT_TARGET = 4096

#: sublane tile of the fused sparse epilogue's emission window
#: (:func:`repro.kernels.stream_filter._epilogue_window`) — autotunable
#: and overridable via the ``ep_tile=`` engine option
DEFAULT_EP_TILE = 8

#: VMEM budget for the fused-epilogue match buffer: a ``(cap + win, 3)``
#: int32 block pads to one 128-lane tile per row (512 B).  Past this the
#: bounded buffer would crowd the block tables out of VMEM, so
#: ``sparse_epilogue="auto"`` falls back to the two-launch lane
#: compaction for that cap
DEFAULT_EPILOGUE_VMEM = 4 * 1024 * 1024

#: launch-shape knobs a measured-autotune cache entry may override
TUNABLE_KEYS = ("blk", "chunk", "byte_chunk", "grid_order",
                "segment_target", "ep_tile")


def _pack_words(bits: jax.Array) -> jax.Array:
    """(..., S) int32 0/1 → (..., S/32) uint32."""
    s = bits.shape[-1]
    lanes = bits.reshape(bits.shape[:-1] + (s // 32, 32)).astype(jnp.uint32)
    weights = (jnp.uint32(1) << jnp.arange(32, dtype=jnp.uint32))
    return (lanes * weights).sum(axis=-1, dtype=jnp.uint32)


def _unpack_words(words: jax.Array) -> jax.Array:
    """(..., W) uint32 → (..., W*32) int32 0/1."""
    shifts = jnp.arange(32, dtype=jnp.uint32)
    bits = (words[..., None] >> shifts) & jnp.uint32(1)
    return bits.reshape(words.shape[:-1] + (words.shape[-1] * 32,)).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("n_states", "max_depth"))
def _run(kind, tag, in_state, in_tag, selfloop, init_words, accept_state,
         *, n_states: int, max_depth: int):
    n_ev = kind.shape[0]
    n_q = accept_state.shape[0]
    n_w = n_states // 32
    stack0 = jnp.zeros((max_depth + 2, n_w), dtype=jnp.uint32)
    stack0 = stack0.at[0].set(init_words)

    def step(carry, xs):
        stack, depth, matched, first = carry
        k, t, i = xs
        is_open = k == OPEN
        is_close = k == CLOSE
        row = jax.lax.dynamic_index_in_dim(stack, depth, keepdims=False)
        bits = _unpack_words(row)                       # (S,) int32 — the FFs
        tagmatch = ((in_tag == t) | (in_tag == WILD_TAG)).astype(jnp.int32)
        src = jnp.take(bits, in_state, axis=0)          # previous-block wire
        nxt = (src & tagmatch) | (selfloop & bits)      # all lanes, one "clock"
        words = _pack_words(nxt)
        # push on open (write at depth+1), no-op otherwise
        widx = jnp.clip(depth + 1, 0, max_depth + 1)
        old = jax.lax.dynamic_index_in_dim(stack, widx, keepdims=False)
        new_row = jnp.where(is_open, words, old)
        stack = jax.lax.dynamic_update_index_in_dim(stack, new_row, widx, 0)
        depth = depth + jnp.where(is_open, 1, jnp.where(is_close, -1, 0))
        depth = jnp.clip(depth, 0, max_depth + 1)
        # accept lanes → priority-encoder analogue
        acc = jnp.take(nxt, accept_state, axis=0).astype(bool) & is_open
        newly = acc & (~matched)
        first = jnp.where(newly, i, first)
        matched = matched | acc
        return (stack, depth, matched, first), None

    carry0 = (stack0, jnp.int32(0),
              jnp.zeros(n_q, dtype=bool), jnp.full(n_q, NO_MATCH, jnp.int32))
    (stack, depth, matched, first), _ = jax.lax.scan(
        step, carry0, (kind, tag, jnp.arange(n_ev, dtype=jnp.int32)))
    return matched, first


@jax.jit
def _run_batch(plan: base.FilterPlan, kind: jax.Array, tag: jax.Array):
    """Scan path: vmap of the event scan over a (B, N) batch; plan is a
    pytree arg, so one trace serves every batch of the same shape."""
    meta = plan.meta
    fn = functools.partial(
        _run,
        in_state=plan["in_state"], in_tag=plan["in_tag"],
        selfloop=plan["selfloop"], init_words=plan["init_words"],
        accept_state=plan["accept_state"],
        n_states=meta["n_states"], max_depth=meta["max_depth"])
    return jax.vmap(fn, in_axes=(0, 0))(kind, tag)


@functools.partial(jax.jit, static_argnames=("interpret",))
def _run_batch_kernel(plan: base.FilterPlan, kind: jax.Array,
                      tag: jax.Array, interpret: bool | None = None):
    """Megakernel path: one fused Pallas launch over (docs × blocks),
    then the accept-lane → query gather (the priority encoder)."""
    meta = plan.meta
    mb, fb = sf.stream_filter_pallas(
        sf.fuse_events(kind, tag),
        plan["kb_tagmask"], plan["kb_pw"], plan["kb_pb"],
        plan["kb_selfloop"], plan["kb_init"],
        plan["kb_acc_word"], plan["kb_acc_bit"],
        max_depth=meta["max_depth"], chunk=meta["chunk"],
        interpret=interpret, grid_order=meta.get("grid_order", "bg"))
    matched = mb[:, plan["kb_acc_block"], plan["kb_acc_slot"]] != 0
    first = fb[:, plan["kb_acc_block"], plan["kb_acc_slot"]]
    return matched, first


@functools.partial(jax.jit, static_argnames=("interpret",))
def _run_parts_kernel(plan: base.FilterPlan, kind: jax.Array,
                      tag: jax.Array, interpret: bool | None = None):
    """Stacked sharded plan (leading part axis) through ONE megakernel
    launch: parts fold into the block-grid axis — more profiles are just
    more independent blocks, the paper's profiles-across-chips scaling
    without a second program.  Returns (P, B, Qpad) matched/first."""
    meta = plan.meta
    g = meta["n_blocks"]

    def fold(x):
        return x.reshape((x.shape[0] * x.shape[1],) + x.shape[2:])

    mb, fb = sf.stream_filter_pallas(
        sf.fuse_events(kind, tag),
        fold(plan["kb_tagmask"]), fold(plan["kb_pw"]), fold(plan["kb_pb"]),
        fold(plan["kb_selfloop"]), fold(plan["kb_init"]),
        fold(plan["kb_acc_word"]), fold(plan["kb_acc_bit"]),
        max_depth=meta["max_depth"], chunk=meta["chunk"],
        interpret=interpret, grid_order=meta.get("grid_order", "bg"))
    b = kind.shape[0]
    p = plan["kb_selfloop"].shape[0]
    mb = mb.reshape(b, p, g, -1)
    fb = fb.reshape(b, p, g, -1)
    gather = jax.vmap(lambda m, ab, sl: m[:, ab, sl], in_axes=(1, 0, 0))
    matched = gather(mb, plan["kb_acc_block"], plan["kb_acc_slot"]) != 0
    first = gather(fb, plan["kb_acc_block"], plan["kb_acc_slot"])
    return matched, first


@functools.partial(jax.jit, static_argnames=("cap", "interpret"))
def _run_batch_kernel_sparse(plan: base.FilterPlan, kind: jax.Array,
                             tag: jax.Array, lane_cls: jax.Array, cap: int,
                             interpret: bool | None = None):
    """Megakernel → bounded match buffer, skipping the dense gather.

    The compaction runs on the raw ``(B, G, QB)`` accept-lane bitmap —
    the kernel's native output — with each lane named by its **accept
    class** (``lane_cls``, ``-1`` = inert lane).  Minimized plans map
    many subscribers onto one lane, so the device emits one row per
    (document, accept class): strictly fewer rows than subscribers
    matched.  The host expands classes back to subscriber ids.
    """
    meta = plan.meta
    mb, fb = sf.stream_filter_pallas(
        sf.fuse_events(kind, tag),
        plan["kb_tagmask"], plan["kb_pw"], plan["kb_pb"],
        plan["kb_selfloop"], plan["kb_init"],
        plan["kb_acc_word"], plan["kb_acc_bit"],
        max_depth=meta["max_depth"], chunk=meta["chunk"],
        interpret=interpret, grid_order=meta.get("grid_order", "bg"))
    b = mb.shape[0]
    return base._compact_matches(
        mb.reshape(b, -1) != 0, fb.reshape(b, -1), lane_cls, cap)


@functools.partial(jax.jit, static_argnames=("cap", "interpret"))
def _run_parts_kernel_sparse(plan: base.FilterPlan, kind: jax.Array,
                             tag: jax.Array, lane_cls: jax.Array, cap: int,
                             interpret: bool | None = None):
    """Sharded twin of :func:`_run_batch_kernel_sparse`: the part axis
    folds into the block grid (ONE launch) and ``lane_cls`` carries
    globally-offset class ids in the same folded ``(P·G·QB,)`` order, so
    one cumsum compacts every part's accept lanes together."""
    meta = plan.meta

    def fold(x):
        return x.reshape((x.shape[0] * x.shape[1],) + x.shape[2:])

    mb, fb = sf.stream_filter_pallas(
        sf.fuse_events(kind, tag),
        fold(plan["kb_tagmask"]), fold(plan["kb_pw"]), fold(plan["kb_pb"]),
        fold(plan["kb_selfloop"]), fold(plan["kb_init"]),
        fold(plan["kb_acc_word"]), fold(plan["kb_acc_bit"]),
        max_depth=meta["max_depth"], chunk=meta["chunk"],
        interpret=interpret, grid_order=meta.get("grid_order", "bg"))
    b = mb.shape[0]
    return base._compact_matches(
        mb.reshape(b, -1) != 0, fb.reshape(b, -1), lane_cls, cap)


@functools.partial(jax.jit, static_argnames=("cap", "ep_tile", "interpret"))
def _run_batch_kernel_fused(plan: base.FilterPlan, kind: jax.Array,
                            tag: jax.Array, doc_ids: jax.Array,
                            lane_cls: jax.Array, cap: int,
                            ep_tile: int = DEFAULT_EP_TILE,
                            interpret: bool | None = None):
    """In-kernel sparse epilogue: the megakernel emits the bounded
    ``(doc, class, first)`` match buffer itself — the ``(B, G, QB)``
    accept bitmap never exists outside VMEM (the program's only outputs
    are the buffer and the running counter)."""
    meta = plan.meta
    buf, cnt = sf.stream_filter_pallas_sparse(
        sf.fuse_events(kind, tag), doc_ids,
        plan["kb_tagmask"], plan["kb_pw"], plan["kb_pb"],
        plan["kb_selfloop"], plan["kb_init"],
        plan["kb_acc_word"], plan["kb_acc_bit"], lane_cls,
        cap=cap, max_depth=meta["max_depth"], chunk=meta["chunk"],
        interpret=interpret, grid_order=meta.get("grid_order", "bg"),
        ep_tile=ep_tile)
    return buf[:cap], cnt


@functools.partial(jax.jit, static_argnames=("cap", "ep_tile", "interpret"))
def _run_parts_kernel_fused(plan: base.FilterPlan, kind: jax.Array,
                            tag: jax.Array, doc_ids: jax.Array,
                            lane_cls: jax.Array, cap: int,
                            ep_tile: int = DEFAULT_EP_TILE,
                            interpret: bool | None = None):
    """Sharded twin of :func:`_run_batch_kernel_fused`: parts fold into
    the block grid (ONE launch) and ``lane_cls`` (P, G, QB) carries
    globally-offset class ids, so the kernel's running counter compacts
    every part's accept lanes into one buffer."""
    meta = plan.meta

    def fold(x):
        return x.reshape((x.shape[0] * x.shape[1],) + x.shape[2:])

    buf, cnt = sf.stream_filter_pallas_sparse(
        sf.fuse_events(kind, tag), doc_ids,
        fold(plan["kb_tagmask"]), fold(plan["kb_pw"]), fold(plan["kb_pb"]),
        fold(plan["kb_selfloop"]), fold(plan["kb_init"]),
        fold(plan["kb_acc_word"]), fold(plan["kb_acc_bit"]),
        lane_cls.reshape(-1, lane_cls.shape[-1]),
        cap=cap, max_depth=meta["max_depth"], chunk=meta["chunk"],
        interpret=interpret, grid_order=meta.get("grid_order", "bg"),
        ep_tile=ep_tile)
    return buf[:cap], cnt


@functools.partial(jax.jit, static_argnames=("cap", "ep_tile", "interpret"))
def _run_bytes_fused_sparse(plan: base.FilterPlan, data: jax.Array,
                            starts: jax.Array, doc_map: jax.Array,
                            lane_cls: jax.Array, cap: int,
                            ep_tile: int = DEFAULT_EP_TILE,
                            interpret: bool | None = None):
    """ONE launch raw bytes → bounded match list: the fused bytes
    datapath ending in the in-kernel sparse epilogue (no event tensor,
    no accept bitmap, anywhere in the program)."""
    meta = plan.meta
    buf, cnt = sf.stream_filter_bytes_pallas_sparse(
        data, starts, doc_map,
        plan["kb_tagmask"], plan["kb_pw"], plan["kb_pb"],
        plan["kb_selfloop"], plan["kb_init"],
        plan["kb_acc_word"], plan["kb_acc_bit"], lane_cls,
        cap=cap, max_depth=meta["max_depth"],
        chunk=meta.get("byte_chunk", DEFAULT_BYTE_CHUNK),
        interpret=interpret, grid_order=meta.get("grid_order", "bg"),
        ep_tile=ep_tile)
    return buf[:cap], cnt


@functools.partial(jax.jit, static_argnames=("cap", "ep_tile", "interpret"))
def _run_parts_bytes_fused_sparse(plan: base.FilterPlan, data: jax.Array,
                                  starts: jax.Array, doc_map: jax.Array,
                                  lane_cls: jax.Array, cap: int,
                                  ep_tile: int = DEFAULT_EP_TILE,
                                  interpret: bool | None = None):
    """Stacked sharded plan through ONE bytes→match-list launch."""
    meta = plan.meta

    def fold(x):
        return x.reshape((x.shape[0] * x.shape[1],) + x.shape[2:])

    buf, cnt = sf.stream_filter_bytes_pallas_sparse(
        data, starts, doc_map,
        fold(plan["kb_tagmask"]), fold(plan["kb_pw"]), fold(plan["kb_pb"]),
        fold(plan["kb_selfloop"]), fold(plan["kb_init"]),
        fold(plan["kb_acc_word"]), fold(plan["kb_acc_bit"]),
        lane_cls.reshape(-1, lane_cls.shape[-1]),
        cap=cap, max_depth=meta["max_depth"],
        chunk=meta.get("byte_chunk", DEFAULT_BYTE_CHUNK),
        interpret=interpret, grid_order=meta.get("grid_order", "bg"),
        ep_tile=ep_tile)
    return buf[:cap], cnt


def _device_rows(buf, cnt, cap: int, ndev: int = 1
                 ) -> tuple[tuple, int, bool]:
    """Stacked per-device ``(cap, 3)`` match buffers + counts → host rows.

    ``shard_map`` concatenates each device's bounded buffer along the
    leading axis; only the first ``min(count_d, cap)`` rows of each are
    real.  Returns ``((docs, cls, first), total_count, overflowed)``
    where overflow means ANY device saturated its buffer.
    """
    buf = np.asarray(buf).reshape(ndev, -1, 3)
    cnt = np.asarray(cnt).reshape(ndev)
    rows = np.concatenate(
        [buf[dv, :min(int(c), cap)] for dv, c in enumerate(cnt)])
    return ((rows[:, 0], rows[:, 1], rows[:, 2]),
            int(cnt.sum()), bool((cnt > int(cap)).any()))


def _lane_classes(plan: base.FilterPlan) -> tuple[np.ndarray, np.ndarray]:
    """Accept-class tables of one kernel plan (host-side, on demand).

    Returns ``(class_of, lane_cls)``: ``class_of[q]`` is the accept
    class of query column q (``-1`` for inert pad columns) and
    ``lane_cls[g, qb]`` names each kernel lane's class (``-1`` for
    lanes no query accepts on, including every block's reserved inert
    lane).  Classes are numbered by first query occurrence, so member
    lists come out in ascending column order.  Derived from the
    many-to-one ``kb_acc_block``/``kb_acc_slot`` mapping rather than
    stored in the plan: the tables are pure bookkeeping the jitted
    program never reads.
    """
    ab = np.asarray(plan["kb_acc_block"])
    sl = np.asarray(plan["kb_acc_slot"])
    g, qb = np.asarray(plan["kb_acc_word"]).shape[-2:]
    inert = sl >= qb - 1          # the reserved inert lane
    key = ab.astype(np.int64) * qb + sl
    kv = key[~inert]
    uniq, inv = np.unique(kv, return_inverse=True)
    first_idx = np.full(uniq.shape, kv.shape[0], np.int64)
    np.minimum.at(first_idx, inv, np.arange(kv.shape[0]))
    rank = np.empty(uniq.shape, np.int64)
    rank[np.argsort(first_idx, kind="stable")] = np.arange(uniq.shape[0])
    class_of = np.full(key.shape, -1, np.int32)
    class_of[~inert] = rank[inv]
    lane_cls = np.full((g, qb), -1, np.int32)
    lane_cls[uniq // qb, uniq % qb] = rank
    return class_of, lane_cls


@functools.partial(jax.jit, static_argnames=("interpret",))
def _run_bytes_fused(plan: base.FilterPlan, data: jax.Array,
                     starts: jax.Array, interpret: bool | None = None):
    """ONE-launch bytes→verdict: the whole predecode+compact+filter
    datapath as a single Pallas program (no EventBatch through HBM) —
    see :func:`repro.kernels.stream_filter.stream_filter_bytes_pallas`.
    ``data``/``starts`` are segment form (an unpacked batch is the
    degenerate one-doc-per-segment case); returns (S, D, Q) matched
    bool / first int32 in segment-slot order."""
    meta = plan.meta
    mb, fb = sf.stream_filter_bytes_pallas(
        data, starts,
        plan["kb_tagmask"], plan["kb_pw"], plan["kb_pb"],
        plan["kb_selfloop"], plan["kb_init"],
        plan["kb_acc_word"], plan["kb_acc_bit"],
        max_depth=meta["max_depth"],
        chunk=meta.get("byte_chunk", DEFAULT_BYTE_CHUNK),
        interpret=interpret, grid_order=meta.get("grid_order", "bg"))
    mb = jnp.transpose(mb, (0, 2, 1, 3))    # (S, D, G, QB)
    fb = jnp.transpose(fb, (0, 2, 1, 3))
    matched = mb[:, :, plan["kb_acc_block"], plan["kb_acc_slot"]] != 0
    first = fb[:, :, plan["kb_acc_block"], plan["kb_acc_slot"]]
    return matched, first


@functools.partial(jax.jit, static_argnames=("interpret",))
def _run_parts_bytes_fused(plan: base.FilterPlan, data: jax.Array,
                           starts: jax.Array,
                           interpret: bool | None = None):
    """Stacked sharded plan through ONE bytes→verdict launch: the part
    axis folds into the block grid exactly like :func:`_run_parts_kernel`.
    Returns (P, S, D, Qpad) matched/first in segment-slot order."""
    meta = plan.meta
    g = meta["n_blocks"]

    def fold(x):
        return x.reshape((x.shape[0] * x.shape[1],) + x.shape[2:])

    mb, fb = sf.stream_filter_bytes_pallas(
        data, starts,
        fold(plan["kb_tagmask"]), fold(plan["kb_pw"]), fold(plan["kb_pb"]),
        fold(plan["kb_selfloop"]), fold(plan["kb_init"]),
        fold(plan["kb_acc_word"]), fold(plan["kb_acc_bit"]),
        max_depth=meta["max_depth"],
        chunk=meta.get("byte_chunk", DEFAULT_BYTE_CHUNK),
        interpret=interpret, grid_order=meta.get("grid_order", "bg"))
    s = data.shape[0]
    p = plan["kb_selfloop"].shape[0]
    d = starts.shape[1] - 1
    mb = mb.reshape(s, p, g, d, -1).transpose(1, 0, 3, 2, 4)  # (P,S,D,G,QB)
    fb = fb.reshape(s, p, g, d, -1).transpose(1, 0, 3, 2, 4)
    gather = jax.vmap(lambda m, ab, sl: m[:, :, ab, sl], in_axes=(0, 0, 0))
    matched = gather(mb, plan["kb_acc_block"], plan["kb_acc_slot"]) != 0
    first = gather(fb, plan["kb_acc_block"], plan["kb_acc_slot"])
    return matched, first


@functools.partial(jax.jit, static_argnames=("n_events", "kernel",
                                             "interpret"))
def _run_bytes_batch(plan: base.FilterPlan, data: jax.Array,
                     n_events: int | None = None, kernel: bool = False,
                     interpret: bool | None = None):
    """Fused ingest+filter: (B, L) raw wire bytes → (B, Q) verdicts as ONE
    compiled program — the paper's same-chip parser+filter (§1).

    The one byte→event pipeline (:func:`repro.kernels.parse.parse_arrays`:
    batched pre-decode + cumsum compaction) and the event-stream state
    advance — the megakernel when ``kernel=True``, the scan otherwise —
    inline into a single XLA computation; the structure outputs this
    engine doesn't read (depth/parent scans) are dead-code-eliminated.
    Between the byte tensor going in and the verdict coming out there is
    no host transfer and no per-event Python.  ``n_events`` is the static
    compacted length (callers pass the tight ``ByteBatch.event_bound``;
    defaults to the worst case L/4).
    """
    from repro.kernels import parse as parse_mod

    if n_events is None:
        n_events = max(1, data.shape[1] // OPEN_NBYTES)
    kind, tag, _depth, _parent, _valid, _n = parse_mod.parse_arrays(
        data, n_events=n_events)
    if kernel:
        return _run_batch_kernel(plan, kind.astype(jnp.int32), tag,
                                 interpret=interpret)
    return _run_batch(plan, kind.astype(jnp.int32), tag)


@base.register("streaming")
class StreamingEngine(base.FilterEngine):
    """Public API: compile once (``plan``), filter many documents.

    Engine options:

    * ``kernel=`` — ``"auto"`` (default: the megakernel on a real TPU,
      the scan elsewhere — the Pallas interpreter is a correctness tool,
      not a fast path), ``"pallas"`` (force the megakernel), ``"scan"``
      (force the oracle scan).
    * ``blk=`` / ``chunk=`` — override the autotuned states-per-block /
      events-per-SMEM-chunk launch shape (see
      :meth:`~repro.core.engines.base.FilterEngine.autotune_blocks`).
    * ``kernel_interpret=`` — force the Pallas interpret flag (tests);
      ``None`` auto-detects from the backend.
    * ``event_bucket=`` — event-axis padding bucket for the byte paths.
    * ``fuse=`` — ``True`` (default): byte ingestion runs the ONE-launch
      bytes→verdict megakernel; ``False``: the two-stage
      parse-then-filter program (the comparison baseline).
    * ``pack=`` / ``segment_target=`` — segment-pack ragged byte batches
      (host first-fit-decreasing packer, see
      :func:`repro.core.events.pack_segments`) before the fused kernel.
    * ``byte_chunk=`` / ``grid_order=`` — bytes-per-DMA-chunk and grid
      iteration order of the fused kernel.
    * ``sparse_epilogue=`` — ``"auto"`` (default: in-kernel bounded
      match-list emission whenever the ``(match_cap, 3)`` buffer fits
      the epilogue VMEM budget), ``"on"`` / ``"off"`` to force it.
    * ``ep_tile=`` — sublane tile of the fused epilogue's emission
      window (autotunable); ``match_cap=`` — bounded match-buffer size
      for sparse calls (also threaded via plan meta).
    * ``vmem_budget=`` / ``smem_budget=`` — static autotune budgets
      (else the ``REPRO_PALLAS_*_BUDGET`` env vars, else defaults).
    * ``autotune="measured"`` — overlay the persisted measured-search
      best config (:mod:`repro.kernels.autotune`) for this plan shape.
    """

    #: packed-word layout: the state axis must tile into 32-bit words
    state_multiple = 32
    device_sharded = True

    def __init__(self, nfa: NFA, dictionary=None,
                 max_depth: int = DEFAULT_MAX_DEPTH, **options) -> None:
        self.max_depth = max_depth
        sm = int(options.get("state_multiple", self.state_multiple))
        if sm % 32 != 0:
            raise ValueError(
                f"streaming packs 32-state words; state_multiple={sm} "
                f"is not a multiple of 32")
        mode = options.get("kernel", "auto")
        if mode not in KERNEL_MODES:
            raise ValueError(
                f"kernel={mode!r} is not one of {KERNEL_MODES}")
        self.kernel_mode = mode
        # resolved ONCE, before plan() runs: plans carry the kb_* block
        # tables only when this engine will actually run the megakernel
        # (scan-only engines skip the layout work and the table memory)
        self.kernel_enabled = (mode == "pallas"
                               or (mode == "auto"
                                   and not interpret_default()))
        super().__init__(nfa, dictionary, **options)

    # ------------------------------------------------------ kernel routing
    def _kernel_on(self) -> bool:
        """Megakernel or scan?  ``auto`` picks the kernel exactly when
        Pallas compiles for this backend (a real TPU); the choice is
        frozen at engine construction, matching the plan's tables."""
        return self.kernel_enabled

    def _kernel_interpret(self) -> bool | None:
        ki = self.options.get("kernel_interpret")
        return None if ki is None else bool(ki)

    def kernel_config(self, n_states: int, n_tags: int) -> dict:
        """Megakernel launch shape: static policy → measured cache →
        explicit engine options, in increasing precedence.

        The static :meth:`autotune_blocks` formula (honouring the
        ``vmem_budget=`` / ``smem_budget=`` options and their env vars)
        seeds the config; with ``autotune="measured"`` a persisted
        best-config from :mod:`repro.kernels.autotune` overlays it for
        this plan shape; explicit ``blk=`` / ``chunk=`` /
        ``byte_chunk=`` / ``grid_order=`` / ``segment_target=`` options
        always win.
        """
        vb = self.options.get("vmem_budget")
        sb = self.options.get("smem_budget")
        cfg = self.autotune_blocks(
            n_states, self.max_depth, n_tags=n_tags,
            vmem_budget=None if vb is None else int(vb),
            smem_budget=None if sb is None else int(sb))
        cfg.setdefault("byte_chunk", DEFAULT_BYTE_CHUNK)
        cfg.setdefault("grid_order", "bg")
        cfg.setdefault("segment_target", DEFAULT_SEGMENT_TARGET)
        cfg.setdefault("ep_tile", DEFAULT_EP_TILE)
        if self.options.get("autotune") == "measured":
            from ...kernels import autotune as autotune_mod

            ki = self._kernel_interpret()
            backend = ("interpret"
                       if (ki if ki is not None else interpret_default())
                       else "compiled")
            hit = autotune_mod.cached_config(autotune_mod.plan_key(
                backend, n_states, n_tags, self.max_depth,
                self.state_multiple))
            if hit:
                cfg.update({k: hit[k] for k in TUNABLE_KEYS if k in hit})
        for k in TUNABLE_KEYS:
            if k in self.options:
                cfg[k] = self.options[k]
        cfg["blk"] = int(cfg["blk"])
        cfg["chunk"] = max(32, int(cfg["chunk"]))
        cfg["byte_chunk"] = max(32, int(cfg["byte_chunk"]))
        cfg["segment_target"] = max(1, int(cfg["segment_target"]))
        cfg["ep_tile"] = max(1, int(cfg["ep_tile"]))
        if cfg["grid_order"] not in sf.GRID_ORDERS:
            raise ValueError(
                f"grid_order={cfg['grid_order']!r} is not one of "
                f"{sf.GRID_ORDERS}")
        return cfg

    def plan(self, nfa: NFA) -> base.FilterPlan:
        nfa = pad_states(nfa, self.state_multiple)
        t = nfa.tables
        init_words = jax.device_get(
            _pack_words(jnp.asarray(t.init.astype(np.int32))))
        tables = dict(
            in_state=jnp.asarray(t.in_state),
            in_tag=jnp.asarray(t.in_tag),
            selfloop=jnp.asarray(t.selfloop.astype(np.int32)),
            init_words=jnp.asarray(init_words),
            accept_state=jnp.asarray(t.accept_state),
        )
        meta = {"n_states": int(t.in_state.shape[0]),
                # ONE stack bound for scan and kernel alike — threaded
                # from here everywhere, never a per-path default
                "max_depth": self.max_depth,
                "state_multiple": self.state_multiple,
                # document prep is pure-device (scan and kernel both
                # consume the raw event stream), so the 2-D mesh path
                # can fuse parse+filter into one shard_map program
                "prep": "events-device"}
        if self.kernel_enabled:
            pads = dict(self._plan_pads or {})
            cfg = self.kernel_config(nfa.n_states, nfa.n_tags)
            mk = blocks_mod.state_layout(
                nfa, blk=int(pads.get("blk", cfg["blk"])),
                n_blocks=pads.get("n_blocks"),
                block_queries=pads.get("block_queries"))
            # megakernel block tables (kb_*): bit-packed per-block form
            # of the same NFA, compiled once per plan
            tables.update(
                kb_tagmask=jnp.asarray(mk.tagmask),
                kb_pw=jnp.asarray(mk.pw),
                kb_pb=jnp.asarray(mk.pb),
                kb_selfloop=jnp.asarray(mk.selfloop_words),
                kb_init=jnp.asarray(mk.init_words),
                kb_acc_word=jnp.asarray(mk.acc_word),
                kb_acc_bit=jnp.asarray(mk.acc_bit),
                kb_acc_block=jnp.asarray(mk.acc_block),
                kb_acc_slot=jnp.asarray(mk.acc_slot),
            )
            meta.update(blk=mk.blk, chunk=cfg["chunk"],
                        n_blocks=mk.n_blocks,
                        block_queries=mk.block_queries,
                        byte_chunk=cfg["byte_chunk"],
                        grid_order=cfg["grid_order"],
                        segment_target=cfg["segment_target"],
                        ep_tile=cfg["ep_tile"])
            if "match_cap" in self.options:
                meta["match_cap"] = int(self.options["match_cap"])
        return base.FilterPlan("streaming", tables, meta)

    # ------------------------------------------------------- sharded hooks
    def _kernel_pad_targets(self, parts, pads, *, min_blk: int = 0) -> dict:
        """Uniform megakernel layout targets for ``parts`` at the given
        (``n_states``, ``n_tags``) pads: one common block size (the
        autotuned candidate grown to every part's largest subtree and to
        ``min_blk``), then the block count and accept-lane width each
        part needs AT that block size — jointly derived, so the returned
        set is always feasible for these parts."""
        cfg = self.kernel_config(pads["n_states"], pads["n_tags"])
        padded = [pad_states(nfa, to=pads["n_states"]) for nfa in parts]
        blk = max([int(cfg["blk"]), int(min_blk)]
                  + [blocks_mod.min_block_size(nfa) for nfa in padded])
        layouts = [blocks_mod.state_layout(nfa, blk=blk) for nfa in padded]
        return {"blk": max([blk] + [lo.blk for lo in layouts]),
                "n_blocks": base._round_up(
                    max(lo.n_blocks for lo in layouts), 2),
                "block_queries": base._round_up(
                    max(lo.block_queries for lo in layouts), 8)}

    def part_pads(self, parts, *, query_bucket: int = 8):
        """Uniform pad targets incl. the megakernel block axes.

        Per-part block tables stack along the leading part axis, so all
        parts must agree on the tag space, the block size, the block
        count and the accept-lane width; each target is bucketed so
        churn rarely forces an all-parts replan.  Scan-only engines skip
        the kernel targets entirely (their plans carry no block tables).
        """
        pads = super().part_pads(parts, query_bucket=query_bucket)
        if not pads:
            return pads
        pads["n_tags"] = base._round_up(
            max((nfa.n_tags for nfa in parts), default=1), 64)
        if self.kernel_enabled:
            pads.update(self._kernel_pad_targets(parts, pads))
        return pads

    def merge_pads(self, old, new, parts):
        """Churn reconcile: per-key max for the independent targets,
        then re-derive the block layout keys at the merged block size —
        a per-key max of (``blk``, ``n_blocks``, ``block_queries``)
        derived at *different* block sizes can be infeasible (bigger
        blocks pack more subtrees, needing more accept lanes per
        block)."""
        merged = super().merge_pads(old, new, parts)
        if not self.kernel_enabled or "blk" not in merged:
            return merged
        # re-derive AT the final merged block size: layouts computed at
        # a smaller blk can under-count the lanes/blocks a bigger block
        # needs, so min_blk pins the derivation to the merged value
        targets = self._kernel_pad_targets(
            parts, {"n_states": merged["n_states"],
                    "n_tags": merged["n_tags"]},
            min_blk=merged["blk"])
        # keep monotone growth vs the old buckets (stacking headroom),
        # but never below what the merged block size actually needs
        for k, v in targets.items():
            merged[k] = max(merged.get(k, 0), v)
        return merged

    def _pad_plan_queries(self, plan: base.FilterPlan,
                          n_queries: int) -> base.FilterPlan:
        """Pad the query axis: accept columns at state 0 (never matches)
        and megakernel accept lanes at every block's reserved inert lane
        (``QB-1``, wired to the local root) — inert by construction."""
        if not self.kernel_enabled:  # scan plans carry no kb_* tables
            return super()._pad_plan_queries(plan, n_queries)
        acc = np.asarray(plan["accept_state"])
        extra = n_queries - int(acc.shape[0])
        if extra <= 0:
            return plan
        qb = plan.meta["block_queries"]
        tables = plan.tables
        ab = np.asarray(plan["kb_acc_block"])
        sl = np.asarray(plan["kb_acc_slot"])
        # pad on the host: a device concatenate would XLA-compile once
        # per novel shape, dominating per-op churn latency
        tables["accept_state"] = jnp.asarray(
            np.concatenate([acc, np.zeros(extra, acc.dtype)]))
        tables["kb_acc_block"] = jnp.asarray(
            np.concatenate([ab, np.zeros(extra, ab.dtype)]))
        tables["kb_acc_slot"] = jnp.asarray(
            np.concatenate([sl, np.full(extra, qb - 1, sl.dtype)]))
        return base.FilterPlan(plan.engine, tables, plan.meta)

    def _vmapped_parts(self):
        """Kernel path: parts fold into the megakernel's block grid (one
        launch, no vmap-of-pallas); scan path: the base vmap."""
        if not self._kernel_on():
            return super()._vmapped_parts()
        interpret = self._kernel_interpret()

        def run_parts(plan, *prep):
            kind, tag = prep
            return _run_parts_kernel(plan, kind, tag, interpret=interpret)

        return run_parts

    # --------------------------------------------------- explicit-plan body
    def _prep(self, batch: EventBatch) -> tuple:
        return (jnp.asarray(batch.kind.astype(np.int32)),
                jnp.asarray(batch.tag_id))

    def _prep_arrays(self, kind, tag, depth, parent, valid, n_events):
        # the state advance reads only (kind, tag); depth/parent/valid
        # are dead-code-eliminated out of the fused program
        return (kind.astype(jnp.int32), tag)

    def _run_with_plan(self, plan: base.FilterPlan, prep: tuple):
        kind, tag = prep
        if self._kernel_on():
            return _run_batch_kernel(plan, kind, tag,
                                     interpret=self._kernel_interpret())
        return _run_batch(plan, kind, tag)

    def filter_document(self, ev: EventStream) -> FilterResult:
        p = self.plan_
        matched, first = _run(
            jnp.asarray(ev.kind.astype(np.int32)),
            jnp.asarray(ev.tag_id),
            p["in_state"], p["in_tag"], p["selfloop"], p["init_words"],
            p["accept_state"],
            n_states=p.meta["n_states"], max_depth=p.meta["max_depth"])
        return FilterResult(np.asarray(matched), np.asarray(first))

    def filter_batch(self, batch: EventBatch) -> FilterResult:
        return self.filter_batch_with_plan(self.plan_, batch)

    # --------------------------------------------- lane-space sparse path
    def _lane_memo(self, obj, build):
        """Tiny identity-keyed memo for per-plan lane-class tables (plans
        are frozen, so identity is validity; bounded so churned-away
        plans don't pin memory)."""
        cache = self.__dict__.setdefault("_lane_cache", {})
        hit = cache.get(id(obj))
        if hit is not None and hit[0] is obj:
            return hit[1]
        val = build()
        if len(cache) >= 8:
            cache.pop(next(iter(cache)))
        cache[id(obj)] = (obj, val)
        return val

    def _plain_lane_tables(self, plan: base.FilterPlan):
        """((G, QB) lane→class names, class-member CSR) for one plan."""

        def build():
            class_of, lane_cls = _lane_classes(plan)
            valid = class_of >= 0
            order = np.argsort(class_of[valid], kind="stable")
            members = np.flatnonzero(valid)[order].astype(np.int32)
            n_cls = int(lane_cls.max(initial=-1)) + 1
            counts = np.bincount(class_of[valid], minlength=n_cls)
            offsets = np.concatenate(([0], np.cumsum(counts)))
            return lane_cls, offsets, members

        return self._lane_memo(plan, build)

    def _sharded_lane_tables(self, sharded):
        """Composed lane tables of a stacked sharded plan.

        Per-part accept classes get disjoint global ids (part-local id +
        running offset) and the member CSR stores **global subscriber
        ids** directly (tombstoned columns dropped at build time), so
        one device compaction over the folded ``(P·G·QB,)`` lane axis
        expands straight to (doc, gid) rows.  The lane table comes back
        ``(P, G, QB)`` so mesh paths can shard it over the part axis.
        """

        def build():
            gcols = sharded.gid_columns()
            lanes, member_parts, counts_parts = [], [], []
            off = 0
            for p, plan in enumerate(sharded.plans):
                class_of, lane_cls = _lane_classes(plan)
                n_cls = int(lane_cls.max(initial=-1)) + 1
                lanes.append(np.where(lane_cls >= 0, lane_cls + off, -1))
                valid = class_of >= 0
                order = np.argsort(class_of[valid], kind="stable")
                cols = np.flatnonzero(valid)[order]
                cls = class_of[valid][order]
                gids = gcols[p, cols]
                keep = gids >= 0          # drop tombstoned subscribers
                member_parts.append(gids[keep].astype(np.int32))
                counts_parts.append(
                    np.bincount(cls[keep], minlength=n_cls))
                off += n_cls
            counts = (np.concatenate(counts_parts)
                      if counts_parts else np.zeros(0, np.int64))
            offsets = np.concatenate(([0], np.cumsum(counts)))
            members = (np.concatenate(member_parts)
                       if member_parts else np.zeros(0, np.int32))
            return np.stack(lanes), offsets, members

        return self._lane_memo(sharded, build)

    def _ep_tile(self, plan: base.FilterPlan) -> int:
        return int(plan.meta.get("ep_tile", DEFAULT_EP_TILE))

    def _fused_sparse_ok(self, cap: int,
                         plan: base.FilterPlan | None = None) -> bool:
        """Run the in-kernel sparse epilogue for this cap?

        The ``sparse_epilogue=`` engine option forces it (``"on"`` /
        ``"off"``); ``"auto"`` (default) accepts whenever the bounded
        match buffer fits the epilogue VMEM budget — past that the
        two-launch lane compaction is the better trade.
        """
        mode = self.options.get("sparse_epilogue", "auto")
        if mode not in ("auto", "on", "off"):
            raise ValueError(
                f"sparse_epilogue={mode!r} is not one of "
                f"('auto', 'on', 'off')")
        if mode != "auto":
            return mode == "on"
        plan = self.plan_ if plan is None else plan
        win = sf._epilogue_window(int(plan.meta["block_queries"]),
                                  self._ep_tile(plan))
        return (int(cap) + win) * 512 <= DEFAULT_EPILOGUE_VMEM

    @staticmethod
    def _mark_base_path(sp: SparseResult) -> SparseResult:
        """Record that a sparse call left the kernel engine: the base
        class compacted (or densified) instead of the megakernel."""
        sp.meta["base_path"] = sp.meta.get("path")
        sp.meta["path"] = ("dense-overflow" if sp.overflowed
                           else "base-fallback")
        return sp

    def _expand_class_hits(self, bufs, count: int, cap: int, offsets,
                           members, *, batch_size: int, n_queries: int,
                           live_ids, meta: dict, dense_fallback,
                           overflowed: bool | None = None) -> SparseResult:
        """Device class-hit buffer → per-subscriber :class:`SparseResult`.

        Each compacted row names an accept class; ``offsets``/``members``
        is the class→subscriber CSR, expanded with one ``np.repeat`` —
        a row with k subscribers becomes k (doc, id) rows.  Overflow
        (``count > cap``, or the explicit flag from mesh paths whose
        per-device buffers each bound ``cap``) recomputes densely,
        exact but unbounded, and records ``path="dense-overflow"``.
        """
        over = (count > cap) if overflowed is None else bool(overflowed)
        if over:
            sp = dense_fallback().sparsify(live_ids)
            sp.overflowed = True
            sp.meta.update(meta, match_cap=cap, device_rows=int(count),
                           attempted_path=meta.get("path"),
                           path="dense-overflow")
            return sp
        docs, cls, first = (np.asarray(b)[:count] for b in bufs)
        meta = dict(meta, match_cap=cap, device_rows=int(docs.shape[0]))
        reps = (offsets[1:] - offsets[:-1])[cls]
        total = int(reps.sum())
        hit = np.repeat(np.arange(cls.shape[0]), reps)
        within = np.arange(total) - np.repeat(np.cumsum(reps) - reps, reps)
        qids = members[offsets[cls][hit] + within]
        docs, first = docs[hit], first[hit]
        order = np.lexsort((qids, docs))
        return SparseResult(
            docs[order], qids[order], first[order],
            batch_size=batch_size, n_queries=n_queries,
            live_ids=(None if live_ids is None
                      else np.asarray(live_ids, np.int32)),
            meta=meta)

    def filter_batch_sparse(self, batch: EventBatch, *,
                            match_cap: int | None = None) -> SparseResult:
        """Kernel engines emit the bounded match list straight from the
        megakernel (``path="kernel-fused"``: the accept bitmap never
        reaches HBM); caps past the epilogue VMEM budget keep the
        two-launch lane compaction (``"lane-compact"``); scan engines
        fall back to the base dense-verdict compaction
        (``"base-fallback"``).  All transfer O(cap), not O(B·Q)."""
        if not self._kernel_on():
            return self._mark_base_path(super().filter_batch_sparse(
                batch, match_cap=match_cap))
        kind, tag = self._prep(batch)
        lane_cls, offsets, members = self._plain_lane_tables(self.plan_)
        b = batch.batch_size
        cap = self.match_cap(b, self.n_queries, match_cap)
        if self._fused_sparse_ok(cap):
            doc_ids = jnp.arange(b, dtype=jnp.int32)[:, None]
            buf, cnt = _run_batch_kernel_fused(
                self.plan_, kind, tag, doc_ids, jnp.asarray(lane_cls),
                cap, ep_tile=self._ep_tile(self.plan_),
                interpret=self._kernel_interpret())
            bufs, n, over = _device_rows(buf, cnt, cap)
            path = "kernel-fused"
        else:
            *bufs, n = _run_batch_kernel_sparse(
                self.plan_, kind, tag,
                jnp.asarray(lane_cls.reshape(-1)), cap,
                interpret=self._kernel_interpret())
            n, over = int(n), None
            path = "lane-compact"
        return self._expand_class_hits(
            bufs, n, cap, offsets, members, batch_size=b,
            n_queries=self.n_queries, live_ids=None,
            meta={"path": path}, overflowed=over,
            dense_fallback=lambda: self.filter_batch(batch))

    def filter_batch_sharded_sparse(self, batch: EventBatch, sharded, *,
                                    mesh=None,
                                    match_cap: int | None = None
                                    ) -> SparseResult:
        """One megakernel launch (parts folded into the grid) straight
        into the bounded match buffer; classes expand to global
        subscriber ids on the host.  With a mesh the SAME fused program
        runs under ``shard_map`` over ``"model"`` — each device compacts
        its parts into its own bounded buffer (per-device cap), assembled
        on the host — instead of silently dropping to the base
        compaction; every route records ``meta["path"]``."""
        if not self._kernel_on():
            return self._mark_base_path(super().filter_batch_sharded_sparse(
                batch, sharded, mesh=mesh, match_cap=match_cap))
        kind, tag = self._prep(batch)
        lane_cls, offsets, members = self._sharded_lane_tables(sharded)
        live_ids = sharded.live_ids()
        b = batch.batch_size
        cap = self.match_cap(b, len(live_ids), match_cap)
        stacked = sharded.stacked()
        interpret = self._kernel_interpret()

        def dense_fallback():
            return self.filter_batch_sharded(batch, sharded, mesh=mesh)

        if not self._fused_sparse_ok(cap, stacked):
            *bufs, n = _run_parts_kernel_sparse(
                stacked, kind, tag, jnp.asarray(lane_cls.reshape(-1)),
                cap, interpret=interpret)
            return self._expand_class_hits(
                bufs, int(n), cap, offsets, members, batch_size=b,
                n_queries=len(live_ids), live_ids=live_ids,
                meta={"path": "lane-compact"},
                dense_fallback=dense_fallback)
        ep = self._ep_tile(stacked)
        doc_ids = jnp.arange(b, dtype=jnp.int32)[:, None]
        if mesh is None:
            buf, cnt = _run_parts_kernel_fused(
                stacked, kind, tag, doc_ids, jnp.asarray(lane_cls), cap,
                ep_tile=ep, interpret=interpret)
            bufs, n, over = _device_rows(buf, cnt, cap)
        else:
            self._check_model_axis(sharded, mesh)

            def build():
                def body(plan, kind, tag, doc_ids, lane):
                    return _run_parts_kernel_fused(
                        plan, kind, tag, doc_ids, lane, cap,
                        ep_tile=ep, interpret=interpret)

                ps = jax.sharding.PartitionSpec
                return jax.jit(_shard_map(
                    body, mesh,
                    in_specs=(ps("model"), ps(), ps(), ps(), ps("model")),
                    out_specs=(ps("model"), ps("model"))))

            buf, cnt = self._cached_exec(
                ("1d-fused-sparse", mesh, cap, ep), build)(
                stacked, kind, tag, doc_ids, jnp.asarray(lane_cls))
            bufs, n, over = _device_rows(buf, cnt, cap,
                                         mesh.shape["model"])
        return self._expand_class_hits(
            bufs, n, cap, offsets, members, batch_size=b,
            n_queries=len(live_ids), live_ids=live_ids,
            meta={"path": "kernel-fused"}, overflowed=over,
            dense_fallback=dense_fallback)

    def filter_batch_sharded2d_sparse(self, batch: EventBatch, sharded, *,
                                      mesh,
                                      match_cap: int | None = None
                                      ) -> SparseResult:
        """Sparse twin of the 2-D (data × model) dispatch: the fused
        epilogue runs INSIDE the shard_map body, so each device turns
        its "data" slice of documents × "model" slice of parts directly
        into a bounded match buffer — the previous host-side sparsify of
        the gathered dense result becomes the fallback route."""
        live_ids = sharded.live_ids()
        b0 = batch.batch_size
        cap = self.match_cap(b0, len(live_ids), match_cap)
        if not (self._kernel_on() and self._fused_sparse_ok(
                cap, sharded.stacked())):
            return self._mark_base_path(
                super().filter_batch_sharded2d_sparse(
                    batch, sharded, mesh=mesh, match_cap=match_cap))
        data_ax, _ = self._mesh_axes2d(mesh)
        self._check_model_axis(sharded, mesh)
        padded = batch.pad_batch_to(base._round_up(b0, data_ax))
        kind, tag = self._prep(padded)
        # pad documents carry no events — name them -1 so the kernel
        # drops them by construction rather than by accident
        ids = np.arange(padded.batch_size, dtype=np.int32)
        ids[b0:] = -1
        lane_cls, offsets, members = self._sharded_lane_tables(sharded)
        stacked = sharded.stacked()
        ep = self._ep_tile(stacked)
        interpret = self._kernel_interpret()

        def build():
            def body(plan, kind, tag, doc_ids, lane):
                return _run_parts_kernel_fused(
                    plan, kind, tag, doc_ids, lane, cap,
                    ep_tile=ep, interpret=interpret)

            ps = jax.sharding.PartitionSpec
            # bounded buffers stack device-major on axis 0 (one (cap, 3)
            # block per device of BOTH axes), unlike the dense 2-D path
            # whose (parts, docs) axes shard independently
            return jax.jit(_shard_map(
                body, mesh,
                in_specs=(ps("model"), ps("data"), ps("data"),
                          ps("data"), ps("model")),
                out_specs=(ps(("model", "data")), ps(("model", "data")))))

        buf, cnt = self._cached_exec(
            ("2d-fused-sparse", mesh, cap, ep), build)(
            stacked, kind, tag, jnp.asarray(ids[:, None]),
            jnp.asarray(lane_cls))
        ndev = int(np.prod(list(mesh.shape.values())))
        bufs, n, over = _device_rows(buf, cnt, cap, ndev)
        return self._expand_class_hits(
            bufs, n, cap, offsets, members, batch_size=b0,
            n_queries=len(live_ids), live_ids=live_ids,
            meta={"path": "kernel-fused"}, overflowed=over,
            dense_fallback=lambda: self.filter_batch_sharded2d(
                batch, sharded, mesh=mesh))

    # ---------------------------------------------------------- byte paths
    def _fused_bytes_on(self) -> bool:
        """One-launch bytes kernel or the parse-then-filter program?
        The fused path needs the megakernel tables; ``fuse=False`` keeps
        the two-stage program (the comparison baseline)."""
        return self._kernel_on() and bool(self.options.get("fuse", True))

    def _bytes_prep(self, bb: ByteBatch, pack: bool | None = None
                    ) -> tuple[jax.Array, jax.Array, SegmentPack | None]:
        """(data, starts, pack-or-None) for the one-launch kernel.

        ``pack=True`` (or the ``pack=`` engine option) runs the host
        segment packer — short documents share grid slots; otherwise the
        batch maps 1:1 to degenerate one-document segments whose only
        boundary is the sentinel.
        """
        if pack is None:
            pack = bool(self.options.get("pack", False))
        if pack:
            sp = pack_segments(
                bb.to_host(),
                target_len=int(self.plan_.meta.get(
                    "segment_target", DEFAULT_SEGMENT_TARGET)))
            return jnp.asarray(sp.data), jnp.asarray(sp.starts), sp
        starts = np.full((bb.batch_size, 2), SEG_SENTINEL, np.int32)
        starts[:, 0] = 0
        return jnp.asarray(bb.data), jnp.asarray(starts), None

    def _scatter_parts(self, sp: SegmentPack | None, matched, first
                       ) -> tuple[np.ndarray, np.ndarray]:
        """(P, S, D, Qpad) kernel outputs → (P, B, Qpad) batch order."""
        m = np.asarray(matched)
        f = np.asarray(first)
        p, s, d, q = m.shape
        if sp is None:       # unpacked: segment s IS batch row s, D == 1
            return m[:, :, 0, :], f[:, :, 0, :]
        mm = np.moveaxis(m, 0, 2).reshape(s, d, p * q)
        ff = np.moveaxis(f, 0, 2).reshape(s, d, p * q)
        m2, f2 = sp.scatter(mm, ff, NO_MATCH)
        b = sp.batch_size
        return (m2.reshape(b, p, q).transpose(1, 0, 2),
                f2.reshape(b, p, q).transpose(1, 0, 2))

    def filter_bytes(self, bb: ByteBatch, *, bucket: int | None = None,
                     pack: bool | None = None) -> FilterResult:
        """Bytes → verdict as one compiled program.

        Kernel engines run the ONE-launch bytes megakernel
        (:func:`_run_bytes_fused` — predecode, compaction and filtering
        inside one Pallas grid, optionally over segment-packed batches);
        scan engines (and ``fuse=False``) run the two-stage
        parse-then-filter program (:func:`_run_bytes_batch`).  Both are
        bit-identical by test.
        """
        if not self._fused_bytes_on():
            matched, first = _run_bytes_batch(
                self.plan_, jnp.asarray(bb.data),
                bb.event_bound(bucket=self._event_bucket(bucket)),
                kernel=self._kernel_on(),
                interpret=self._kernel_interpret())
            return FilterResult(np.asarray(matched), np.asarray(first))
        data, starts, sp = self._bytes_prep(bb, pack)
        matched, first = _run_bytes_fused(
            self.plan_, data, starts, interpret=self._kernel_interpret())
        if sp is None:
            return FilterResult(np.asarray(matched[:, 0]),
                                np.asarray(first[:, 0]))
        m, f = sp.scatter(np.asarray(matched), np.asarray(first), NO_MATCH)
        return FilterResult(m, f)

    def filter_bytes_sharded(self, bb: ByteBatch, sharded, *,
                             bucket: int | None = None,
                             mesh=None) -> FilterResult:
        """Sharded bytes path: ONE fused launch for the whole stacked
        plan (parts fold into the block grid; ``shard_map`` over the
        mesh ``"model"`` axis when given), segment-packed when the
        ``pack=`` option is on.  Scan engines keep the base class's
        parse-then-filter program."""
        if not self._fused_bytes_on():
            return super().filter_bytes_sharded(bb, sharded,
                                                bucket=bucket, mesh=mesh)
        self._check_model_axis(sharded, mesh)
        data, starts, sp = self._bytes_prep(bb)
        stacked = sharded.stacked()
        interpret = self._kernel_interpret()

        def build():
            def body(plan, data, starts):
                return _run_parts_bytes_fused(plan, data, starts,
                                              interpret=interpret)

            if mesh is not None:
                ps = jax.sharding.PartitionSpec
                return jax.jit(_shard_map(
                    body, mesh,
                    in_specs=(ps("model"), ps(), ps()),
                    out_specs=(ps("model"), ps("model"))))
            return jax.jit(body)

        matched, first = self._cached_exec(
            ("bytes1d-fused", mesh), build)(stacked, data, starts)
        m, f = self._scatter_parts(sp, matched, first)
        part_of, local_of = sharded.index_arrays()
        return FilterResult(m[part_of, :, local_of].T,
                            f[part_of, :, local_of].T)

    def dispatch_bytes_sharded2d(self, bb: ByteBatch, sharded, *,
                                 bucket: int | None = None, mesh,
                                 n_events: int | None = None):
        """2-D (data × model) bytes path: the one-launch kernel inside
        the shard_map body — each device streams its ``"data"`` slice of
        raw segment bytes through its ``"model"`` slice of the stacked
        plan, bytes in / verdicts out with no intermediate event tensor
        anywhere in the program.  ``n_events`` is accepted for signature
        compatibility; the fused kernel is byte-chunked and never
        materializes a compacted event axis."""
        if not self._fused_bytes_on():
            return super().dispatch_bytes_sharded2d(
                bb, sharded, bucket=bucket, mesh=mesh, n_events=n_events)
        data_ax, _ = self._mesh_axes2d(mesh)
        self._check_model_axis(sharded, mesh)
        b0 = bb.batch_size
        if bool(self.options.get("pack", False)):
            sp = pack_segments(
                bb.to_host(),
                target_len=int(self.plan_.meta.get(
                    "segment_target", DEFAULT_SEGMENT_TARGET)))
            sp = sp.pad_segments_to(
                base._round_up(sp.n_segments, data_ax))
            data, starts = jnp.asarray(sp.data), jnp.asarray(sp.starts)
        else:
            sp = None
            bbp = bb.pad_batch_to(base._round_up(b0, data_ax))
            st = np.full((bbp.batch_size, 2), SEG_SENTINEL, np.int32)
            st[:, 0] = 0
            data, starts = jnp.asarray(bbp.data), jnp.asarray(st)
        stacked = sharded.stacked()
        interpret = self._kernel_interpret()

        def build():
            def body(plan, data, starts):
                return _run_parts_bytes_fused(plan, data, starts,
                                              interpret=interpret)

            ps = jax.sharding.PartitionSpec
            return jax.jit(_shard_map(
                body, mesh,
                in_specs=(ps("model"), ps("data"), ps("data")),
                out_specs=(ps("model", "data"), ps("model", "data"))))

        matched, first = self._cached_exec(
            ("bytes2d-fused", mesh), build)(stacked, data, starts)
        part_of, local_of = sharded.index_arrays()

        def materialize() -> FilterResult:
            m, f = self._scatter_parts(sp, matched, first)
            return FilterResult(m[part_of, :, local_of].T[:b0],
                                f[part_of, :, local_of].T[:b0])

        return materialize

    def filter_bytes_sparse(self, bb: ByteBatch, *,
                            bucket: int | None = None,
                            match_cap: int | None = None,
                            pack: bool | None = None) -> SparseResult:
        """ONE launch raw bytes → bounded match list.

        The fused bytes megakernel ends in the in-kernel sparse
        epilogue: no event tensor AND no accept bitmap ever exist in
        HBM — the program's outputs are the ``(match_cap, 3)`` buffer
        and its counter (``path="kernel-fused"``, ``launch="bytes"``).
        Segment-packed batches ride along: ``doc_ids`` name each packed
        slot's original batch row (pads are ``-1``, dropped in-kernel).
        Non-kernel engines and oversized caps parse then route through
        :meth:`filter_batch_sparse`, which records its own path.
        """
        b = bb.batch_size
        cap = self.match_cap(b, self.n_queries, match_cap)
        if not (self._fused_bytes_on() and self._fused_sparse_ok(cap)):
            return super().filter_bytes_sparse(bb, bucket=bucket,
                                               match_cap=match_cap)
        data, starts, spk = self._bytes_prep(bb, pack)
        doc_map = (spk.doc_ids if spk is not None
                   else np.arange(b, dtype=np.int32)[:, None])
        lane_cls, offsets, members = self._plain_lane_tables(self.plan_)
        buf, cnt = _run_bytes_fused_sparse(
            self.plan_, data, starts, jnp.asarray(doc_map),
            jnp.asarray(lane_cls), cap,
            ep_tile=self._ep_tile(self.plan_),
            interpret=self._kernel_interpret())
        bufs, n, over = _device_rows(buf, cnt, cap)
        return self._expand_class_hits(
            bufs, n, cap, offsets, members, batch_size=b,
            n_queries=self.n_queries, live_ids=None,
            meta={"path": "kernel-fused", "launch": "bytes"},
            overflowed=over,
            dense_fallback=lambda: self.filter_bytes(bb, pack=pack))

    def filter_bytes_sharded_sparse(self, bb: ByteBatch, sharded, *,
                                    bucket: int | None = None, mesh=None,
                                    match_cap: int | None = None
                                    ) -> SparseResult:
        """Sharded bytes → bounded match list, still ONE launch: parts
        fold into the block grid (or shard over the mesh ``"model"``
        axis, each device filling its own bounded buffer)."""
        live_ids = sharded.live_ids()
        b = bb.batch_size
        cap = self.match_cap(b, len(live_ids), match_cap)
        stacked = sharded.stacked()
        if not (self._fused_bytes_on()
                and self._fused_sparse_ok(cap, stacked)):
            return super().filter_bytes_sharded_sparse(
                bb, sharded, bucket=bucket, mesh=mesh,
                match_cap=match_cap)
        self._check_model_axis(sharded, mesh)
        data, starts, spk = self._bytes_prep(bb)
        doc_map = (spk.doc_ids if spk is not None
                   else np.arange(b, dtype=np.int32)[:, None])
        lane_cls, offsets, members = self._sharded_lane_tables(sharded)
        ep = self._ep_tile(stacked)
        interpret = self._kernel_interpret()
        if mesh is None:
            buf, cnt = _run_parts_bytes_fused_sparse(
                stacked, data, starts, jnp.asarray(doc_map),
                jnp.asarray(lane_cls), cap, ep_tile=ep,
                interpret=interpret)
            bufs, n, over = _device_rows(buf, cnt, cap)
        else:
            def build():
                def body(plan, data, starts, doc_map, lane):
                    return _run_parts_bytes_fused_sparse(
                        plan, data, starts, doc_map, lane, cap,
                        ep_tile=ep, interpret=interpret)

                ps = jax.sharding.PartitionSpec
                return jax.jit(_shard_map(
                    body, mesh,
                    in_specs=(ps("model"), ps(), ps(), ps(),
                              ps("model")),
                    out_specs=(ps("model"), ps("model"))))

            buf, cnt = self._cached_exec(
                ("bytes1d-fused-sparse", mesh, cap, ep), build)(
                stacked, data, starts, jnp.asarray(doc_map),
                jnp.asarray(lane_cls))
            bufs, n, over = _device_rows(buf, cnt, cap,
                                         mesh.shape["model"])
        return self._expand_class_hits(
            bufs, n, cap, offsets, members, batch_size=b,
            n_queries=len(live_ids), live_ids=live_ids,
            meta={"path": "kernel-fused", "launch": "bytes"},
            overflowed=over,
            dense_fallback=lambda: self.filter_bytes_sharded(
                bb, sharded, mesh=mesh))

    def filter_documents_batched(self, kind: np.ndarray,
                                 tag: np.ndarray) -> FilterResult:
        """Legacy raw-array batched API (prefer :meth:`filter_batch`)."""
        matched, first = self._run_with_plan(
            self.plan_, (jnp.asarray(np.asarray(kind).astype(np.int32)),
                         jnp.asarray(tag)))
        return FilterResult(np.asarray(matched), np.asarray(first))
