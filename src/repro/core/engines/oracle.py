"""Ground-truth oracle: direct recursive XPath evaluation on the tree.

Completely independent of the NFA construction — it checks the XPath
semantics (axis chains with `/`, `//`, `*`) by dynamic programming over
each root-to-node path.  Used only by tests and tiny demos.
"""
from __future__ import annotations

import numpy as np

from ..dictionary import TagDictionary
from ..events import OPEN, EventBatch, EventStream
from ..nfa import NFA, WILD_TAG
from ..xpath import CHILD, Query, WILDCARD
from . import base
from .result import NO_MATCH, FilterResult


def _resolve_steps(q: Query, dictionary: TagDictionary) -> list[tuple[int, int]]:
    out = []
    for st in q.steps:
        tid = WILD_TAG if st.tag == WILDCARD else dictionary.tag_to_id.get(st.tag, -1)
        out.append((st.axis, tid))
    return out


def _path_matches(path: list[int], steps: list[tuple[int, int]]) -> bool:
    """steps match the full path with the last step at the last node."""
    k, d = len(steps), len(path)
    # g[i][j]: steps[:i] matches a chain ending exactly at path depth j
    g = [[False] * (d + 1) for _ in range(k + 1)]
    g[0][0] = True
    for i in range(1, k + 1):
        axis, tid = steps[i - 1]
        anyprev = [False] * (d + 1)  # anyprev[j] = OR of g[i-1][0..j-1]
        acc = False
        for j in range(d + 1):
            anyprev[j] = acc
            acc = acc or g[i - 1][j]
        for j in range(1, d + 1):
            if tid != WILD_TAG and path[j - 1] != tid:
                continue
            g[i][j] = g[i - 1][j - 1] if axis == CHILD else anyprev[j]
    return g[k][d]


def filter_document(nfa: NFA, ev: EventStream,
                    dictionary: TagDictionary) -> FilterResult:
    """Evaluate every profile against the document, recursively."""
    queries = [_resolve_steps(q, dictionary) for q in nfa.queries]
    return _filter_resolved(queries, ev)


def _filter_resolved(queries, ev: EventStream) -> FilterResult:
    """Same walk, with the name→id resolution already done."""
    matched = np.zeros(len(queries), dtype=bool)
    first = np.full(len(queries), NO_MATCH, dtype=np.int32)

    path: list[int] = []
    for i in range(len(ev)):
        k = int(ev.kind[i])
        if k == OPEN:
            path.append(int(ev.tag_id[i]))
            for qi, steps in enumerate(queries):
                if matched[qi]:
                    continue
                if _path_matches(path, steps):
                    matched[qi] = True
                    first[qi] = i
        elif k == 1:  # CLOSE
            if path:
                path.pop()
    return FilterResult(matched, first)


@base.register("oracle")
class OracleEngine(base.FilterEngine):
    """Registry adapter over the recursive ground truth.

    Needs the tag dictionary (queries carry tag *names*); "compilation"
    is just resolving names to ids once.  Host engine: sharded plans are
    looped part by part (the equivalence oracle for the device engines'
    stacked execution).
    """

    def __init__(self, nfa: NFA, dictionary: TagDictionary | None = None,
                 **options) -> None:
        if dictionary is None:
            raise ValueError("oracle engine needs the tag dictionary")
        super().__init__(nfa, dictionary, **options)
        self._steps = self.plan_.meta["steps"]

    def plan(self, nfa: NFA) -> base.FilterPlan:
        steps = tuple(tuple(_resolve_steps(q, self.dictionary))
                      for q in nfa.queries)
        return base.FilterPlan("oracle", tables={},
                               meta={"steps": steps,
                                     "n_queries": nfa.n_queries,
                                     # host engine: the 2-D mesh paths
                                     # fall back to the part loop (the
                                     # bit-equivalence oracle)
                                     "prep": "host"})

    def filter_document(self, ev: EventStream) -> FilterResult:
        # resolution happened once, in plan()
        return _filter_resolved(self._steps, ev)

    def filter_batch_with_plan(self, plan: base.FilterPlan,
                               batch: EventBatch) -> FilterResult:
        steps = plan.meta["steps"]
        return FilterResult.stack(
            [_filter_resolved(steps, ev)
             for ev in batch.to_host().streams()])

    def filter_batch(self, batch: EventBatch) -> FilterResult:
        return self.filter_batch_with_plan(self.plan_, batch)
