"""Filtering engines.

Five interchangeable implementations of the paper's filtering semantics:

* :mod:`.oracle`     — recursive tree-walk ground truth (pure python, tests).
* :mod:`.yfilter`    — event-driven software baseline (the paper's §4
  comparison system, reimplemented; pure python "von Neumann" path).
* :mod:`.streaming`  — paper-faithful JAX engine: ``lax.scan`` over the
  event stream with a bounded stack of packed state bitmasks (the FPGA
  datapath: every state advances each event, stack push/pop on open/close).
* :mod:`.levelwise`  — TPU-native engine: the stack is virtualized into
  precomputed (depth, parent) structure; the NFA advances level-by-level,
  every node of a level in parallel, transitions as one-hot matmuls.
* :mod:`.matscan`    — paper-literal regex semantics (§3.2) as per-event
  0/1 transition matrices composed with ``associative_scan`` (MXU form).

All engines consume :class:`repro.core.nfa.NFA` tables and
:class:`repro.core.events.EventStream` documents and report, per query:
``matched`` and the event index of the first match (the paper reports the
match location, §4).
"""
from .result import FilterResult  # noqa: F401
