"""Filtering engines behind one contract: ``FilterEngine`` + ``FilterPlan``.

Every engine implements the same two-method interface
(:mod:`repro.core.engines.base`):

* ``plan(nfa) -> FilterPlan`` — compile the standing profiles **once**
  into a frozen pytree of precomputed device tables (REQ / parent-one-hot
  / accept matrices, packed init words, …).  The paper's "program the
  FPGA once per profile set" step.
* ``filter_batch(EventBatch) -> FilterResult`` — filter a padded
  ``(B, N)`` document batch (:class:`repro.core.events.EventBatch`, the
  *only* document format engines see) into a ``(B, Q)`` result.
* ``filter_bytes(ByteBatch) -> FilterResult`` — same verdict from *raw
  wire bytes*, parsed on device (:mod:`repro.kernels.parse`); the
  streaming engine fuses parse+filter into one jitted program.

The **sharded contract** scales the query axis (the paper's
profiles-across-chips replication, §3.5):

* ``plan_sharded(n_parts) -> ShardedPlan`` — partition the profile set
  into balanced sub-NFAs (:func:`repro.core.nfa.partition_queries`,
  shared-prefix trie groups kept together) and compile each part at
  *uniform* state/query pad targets, so per-part tables stack into one
  leading-axis ``(P, ...)`` array.
* ``filter_batch_sharded(batch, sharded, mesh=None) -> FilterResult``
  — all parts in ONE device program: ``vmap`` over the part axis, or
  ``jax.shard_map`` over the mesh ``"model"`` axis when a mesh
  (:func:`repro.launch.mesh.make_filter_mesh`) is given.  Host engines
  (oracle, yfilter) loop parts instead and serve as the equivalence
  oracle.  Results cover live global query ids in ascending order —
  bit-identical to the unsharded ``filter_batch``.
* ``ShardedPlan.add_queries / remove_queries`` — incremental
  subscription churn: adds recompile only the least-loaded part
  (O(n_queries / n_parts) steady state), removals tombstone a column
  with no recompile at all.
* ``filter_bytes_sharded(bb, sharded)`` — the device-ingest twin.

The **2-D contract** composes both of §3.5's replication axes on one
``("data", "model")`` mesh (:func:`repro.launch.mesh.make_filter_mesh`
with ``data_shards=``):

* ``filter_batch_sharded2d(batch, sharded, mesh=...)`` — ONE
  ``shard_map`` program with the stacked plan tables partitioned over
  ``"model"`` and the document batch rows over ``"data"``; ragged
  batches are padded with inert all-PAD documents and sliced back off.
* ``filter_bytes_sharded2d(bb, sharded, mesh=...)`` — bytes → verdict;
  engines whose plan metadata records ``prep == "events-device"``
  (streaming, matscan) fuse the device parse INTO the same per-device
  body, the paper's same-chip parser+filter replicated in both
  dimensions.  Host engines loop parts (the bit-equivalence oracle).
* ``dispatch_batch_sharded2d / dispatch_bytes_sharded2d`` — async
  forms returning a materializer; the double-buffered serve loop
  (:meth:`repro.data.filter_stage.FilterStage.route_bytes_pipelined`)
  overlaps the next batch's ``ByteBatch.device_put`` against them.

Engines self-register under a string key, so construction is uniform::

    from repro.core import engines
    eng = engines.create("levelwise", nfa)            # or any name below
    res = eng.filter_batch(EventBatch.from_streams(docs))
    sp = eng.plan_sharded(4)                          # query-axis scaling
    res = eng.filter_batch_sharded(batch, sp)

Registered implementations of the paper's filtering semantics:

* ``oracle``     — recursive tree-walk ground truth (pure python, tests).
* ``yfilter``    — event-driven software baseline (the paper's §4
  comparison system, reimplemented; pure python "von Neumann" path).
* ``streaming``  — paper-faithful JAX engine: ``lax.scan`` over the
  event stream with a bounded stack of packed state bitmasks (the FPGA
  datapath: every state advances each event, stack push/pop on open/close).
* ``levelwise``  — TPU-native engine: the stack is virtualized into
  precomputed (depth, parent) structure; the NFA advances level-by-level,
  every node of a level in parallel, transitions as one-hot matmuls.
* ``wavefront``  — levelwise variant with fixed-width level chunks
  (less padding waste on skewed level widths).
* ``matscan``    — paper-literal regex semantics (§3.2) as per-event
  0/1 transition matrices composed with ``associative_scan`` (MXU form).

All engines report, per (document, query): ``matched`` and the event
index of the first match (the paper reports the match location, §4).
To add an engine, subclass :class:`base.FilterEngine` and decorate with
``@base.register("name")`` — see the ``base`` module docstring.
"""
from . import base  # noqa: F401
from .base import (FilterEngine, FilterPlan, ShardedPlan, create, get,  # noqa: F401
                   names, register)
from .result import NO_MATCH, FilterResult, SparseResult  # noqa: F401

# importing the implementation modules populates the registry
from . import oracle as _oracle          # noqa: F401,E402
from . import yfilter as _yfilter        # noqa: F401,E402
from . import streaming as _streaming    # noqa: F401,E402
from . import levelwise as _levelwise    # noqa: F401,E402
from . import matscan as _matscan        # noqa: F401,E402
