"""TPU-native levelwise engine.

The FPGA streams one symbol per clock because its parallelism is *spatial*
(all queries advance each clock).  A TPU's parallelism is *data* parallel,
so we restructure the same NFA semantics:

1. The document's structure — per-node ``(depth, parent)`` — is computed
   up-front (prefix sums / one host pass), *virtualizing the stack away*:
   the paper's TOS is simply "the parent node's active set".
2. Nodes are bucketed by depth into a dense ``(max_depth, width)`` layout.
3. The NFA advances **level by level**: every node of a level computes its
   active-state vector from its parent's vector *in parallel* —
   ``O(depth)`` sequential steps instead of ``O(events)``.

Per level the transition is two small matmuls plus a mask (the Pallas
kernel :mod:`repro.kernels.nfa_transition` implements exactly this):

    tagmatch = onehot(tags) @ REQ + wild          # §3.4 pre-decoder on MXU
    src      = parent_active @ P                  # parent-pointer gather
    next     = (src * tagmatch + parent_active * selfloop) > 0

The engine also has a gather/compare path (``use_matmul=False``) that maps
to VPU ops — the "no pre-decoder" scenario; §Perf compares both.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from ..events import OPEN, EventBatch, EventStream
from ..nfa import NFA, WILD_TAG, pad_states
from . import base
from .result import NO_MATCH, FilterResult


# --------------------------------------------------------------------- prep
@dataclass
class LevelDoc:
    """Depth-major dense bucketing of a document's OPEN events."""

    tags: np.ndarray         # (D, Wmax) int32, -1 padding
    parent_slot: np.ndarray  # (D, Wmax) int32 — slot in level d-1; Wmax ⇒ root
    valid: np.ndarray        # (D, Wmax) bool
    event_idx: np.ndarray    # (D, Wmax) int32 — original event position
    n_events: int

    @property
    def depth(self) -> int:
        return int(self.tags.shape[0])

    @property
    def width(self) -> int:
        return int(self.tags.shape[1])

    def padded(self, depth: int, width: int) -> "LevelDoc":
        if depth < self.depth or width < self.width:
            raise ValueError("cannot shrink")
        tags = np.full((depth, width), -1, np.int32)
        parent = np.full((depth, width), width, np.int32)
        valid = np.zeros((depth, width), bool)
        eidx = np.zeros((depth, width), np.int32)
        d, w = self.depth, self.width
        tags[:d, :w] = self.tags
        # re-point root sentinel (old Wmax) to new sentinel (new width)
        parent[:d, :w] = np.where(self.parent_slot == w, width, self.parent_slot)
        valid[:d, :w] = self.valid
        eidx[:d, :w] = self.event_idx
        return LevelDoc(tags, parent, valid, eidx, self.n_events)


def levelize(ev: EventStream) -> LevelDoc:
    """Host-side structure pass (the 'tokenizer' of this engine).

    One linear sweep — this is data preparation, the analogue of the
    paper's host streaming the document into the board.
    """
    kind, tag = ev.kind, ev.tag_id
    n = len(ev)
    depth_of: list[list[int]] = []   # per level: node slots in doc order
    tags_l: list[list[int]] = []
    parent_l: list[list[int]] = []
    eidx_l: list[list[int]] = []
    stack: list[int] = []  # slot of each open ancestor within its level
    for i in range(n):
        k = kind[i]
        if k == OPEN:
            d = len(stack)  # 0-based level
            while len(depth_of) <= d:
                depth_of.append([])
                tags_l.append([])
                parent_l.append([])
                eidx_l.append([])
            slot = len(depth_of[d])
            depth_of[d].append(slot)
            tags_l[d].append(int(tag[i]))
            parent_l[d].append(stack[-1] if stack else -1)
            eidx_l[d].append(i)
            stack.append(slot)
        elif k == 1:  # CLOSE
            if stack:
                stack.pop()
    d_max = max(1, len(depth_of))
    w_max = max(1, max((len(x) for x in depth_of), default=1))
    tags = np.full((d_max, w_max), -1, np.int32)
    parent = np.full((d_max, w_max), w_max, np.int32)
    valid = np.zeros((d_max, w_max), bool)
    eidx = np.zeros((d_max, w_max), np.int32)
    for d in range(len(depth_of)):
        w = len(depth_of[d])
        tags[d, :w] = tags_l[d]
        # level 0 nodes point at the root sentinel row (index w_max)
        parent[d, :w] = [p if p >= 0 else w_max for p in parent_l[d]]
        valid[d, :w] = True
        eidx[d, :w] = eidx_l[d]
    return LevelDoc(tags, parent, valid, eidx, n)


def levelize_batch(docs: list[EventStream]) -> LevelDoc:
    """Pad a batch of documents to common (D, W); stacks along axis 0."""
    return _stack_leveldocs([levelize(d) for d in docs])


def _stack_leveldocs(ls: list[LevelDoc]) -> LevelDoc:
    dm = max(l.depth for l in ls)
    wm = max(l.width for l in ls)
    ls = [l.padded(dm, wm) for l in ls]
    return LevelDoc(
        np.stack([l.tags for l in ls]),
        np.stack([l.parent_slot for l in ls]),
        np.stack([l.valid for l in ls]),
        np.stack([l.event_idx for l in ls]),
        max(l.n_events for l in ls),
    )


def levelize_from_arrays(kind: np.ndarray, tag: np.ndarray,
                         depth: np.ndarray, parent: np.ndarray) -> LevelDoc:
    """Vectorized levelize consuming precomputed (depth, parent) —
    the :class:`~repro.core.events.EventBatch` fast path.

    ``EventBatch.from_streams`` already ran the one linear host pass
    that computes per-event structure; here the depth-major bucketing
    is pure numpy (no per-event python loop), so the levelwise engines
    never re-walk the document.
    """
    open_idx = np.nonzero(kind == OPEN)[0]
    if len(open_idx) == 0:
        return LevelDoc(np.full((1, 1), -1, np.int32),
                        np.full((1, 1), 1, np.int32),
                        np.zeros((1, 1), bool),
                        np.zeros((1, 1), np.int32), int(kind.shape[0]))
    lev = depth[open_idx].astype(np.int64) - 1        # 0-based level
    d_max = int(lev.max()) + 1
    # slot within level = stable cumcount of the level sequence
    order = np.argsort(lev, kind="stable")
    sorted_lev = lev[order]
    starts = np.searchsorted(sorted_lev, np.arange(d_max))
    ranks = np.arange(len(open_idx)) - starts[sorted_lev]
    slot = np.empty(len(open_idx), np.int64)
    slot[order] = ranks
    widths = np.bincount(lev, minlength=d_max)
    w_max = max(1, int(widths.max()))
    slot_of_event = np.full(kind.shape[0], w_max, np.int64)
    slot_of_event[open_idx] = slot
    tags = np.full((d_max, w_max), -1, np.int32)
    parent_slot = np.full((d_max, w_max), w_max, np.int32)
    valid = np.zeros((d_max, w_max), bool)
    eidx = np.zeros((d_max, w_max), np.int32)
    tags[lev, slot] = tag[open_idx]
    p = parent[open_idx]
    parent_slot[lev, slot] = np.where(
        p >= 0, slot_of_event[np.clip(p, 0, None)], w_max).astype(np.int32)
    valid[lev, slot] = True
    eidx[lev, slot] = open_idx
    return LevelDoc(tags, parent_slot, valid, eidx, int(kind.shape[0]))


def _leveldocs_of_batch(batch) -> list[LevelDoc]:
    """One LevelDoc per document, from the batch's precomputed arrays."""
    batch = batch.to_host()  # depth-major bucketing is a host (numpy) pass
    out = []
    for i in range(batch.batch_size):
        n = int(batch.n_events[i])
        out.append(levelize_from_arrays(
            batch.kind[i, :n], batch.tag_id[i, :n],
            batch.depth[i, :n], batch.parent[i, :n]))
    return out


# ------------------------------------------------------------------- engine
def _level_plan(engine: str, nfa: NFA, lane: int = 128) -> base.FilterPlan:
    """Shared compile step for the levelwise-family engines: lane-pad the
    state space (``lane`` is the engine's ``state_multiple`` — 128 MXU
    lanes by default, smaller when the caller opts out of MXU tiling)
    and materialize the dense tables (REQ pre-decoder, parent one-hot,
    accept map) once."""
    nfa = pad_states(nfa, lane)
    t = nfa.tables
    return base.FilterPlan(
        engine,
        tables=dict(
            in_state=jnp.asarray(t.in_state),
            in_tag=jnp.asarray(t.in_tag),
            selfloop=jnp.asarray(t.selfloop.astype(np.float32)),
            init=jnp.asarray(t.init.astype(np.float32)),
            accept_state=jnp.asarray(t.accept_state),
            req=jnp.asarray(nfa.req_matrix()),
            wild=jnp.asarray(nfa.wild_vector()),
            parent_1h=jnp.asarray(nfa.parent_onehot()),
        ),
        meta={"n_states": int(t.in_state.shape[0]), "n_tags": nfa.n_tags,
              "state_multiple": lane,
              # document prep (depth-major bucketing) is a host numpy
              # pass, so the 2-D bytes route parses on device and
              # buckets on host before the shard_map program
              "prep": "levels-host"},
    )


@functools.partial(jax.jit, static_argnames=("n_states", "n_tags",
                                             "use_matmul", "use_kernel"))
def _run_level(tags, parent_slot, valid, event_idx,
               in_state, in_tag, selfloop, init, accept_state, req, wild,
               parent_1h, *, n_states: int, n_tags: int,
               use_matmul: bool, use_kernel: bool):
    d_max, w_max = tags.shape
    n_q = accept_state.shape[0]

    def level(carry, xs):
        prev, matched, first = carry     # prev: (Wmax+1, S) f32 (row Wmax=root)
        tg, psel, vld, eidx = xs
        parent_rows = jnp.take(prev, psel, axis=0)       # (W, S)
        if use_kernel:
            from repro.kernels import ops as kops
            nxt = kops.nfa_transition(parent_rows, tg, req, wild, parent_1h,
                                      selfloop)
        elif use_matmul:
            onehot = jax.nn.one_hot(tg, n_tags, dtype=jnp.float32)  # (W, T)
            tagmatch = onehot @ req + wild[None, :]                 # (W, S)
            src = parent_rows @ parent_1h                           # (W, S)
            nxt = jnp.minimum(src * tagmatch + parent_rows * selfloop[None, :],
                              1.0)
        else:
            tagmatch = ((in_tag[None, :] == tg[:, None])
                        | (in_tag == WILD_TAG)[None, :]).astype(jnp.float32)
            src = jnp.take(parent_rows, in_state, axis=1)
            nxt = jnp.minimum(src * tagmatch + parent_rows * selfloop[None, :],
                              1.0)
        nxt = nxt * vld[:, None].astype(jnp.float32)
        acc = jnp.take(nxt, accept_state, axis=1) > 0    # (W, Q)
        acc = acc & vld[:, None]
        ev_for_q = jnp.where(acc, eidx[:, None], NO_MATCH)
        first = jnp.minimum(first, ev_for_q.min(axis=0))
        matched = matched | acc.any(axis=0)
        prev_next = jnp.concatenate([nxt, init[None, :]], axis=0)
        return (prev_next, matched, first), None

    prev0 = jnp.concatenate(
        [jnp.zeros((w_max, n_states), jnp.float32), init[None, :]], axis=0)
    carry0 = (prev0, jnp.zeros(n_q, bool), jnp.full(n_q, NO_MATCH, jnp.int32))
    (prev, matched, first), _ = jax.lax.scan(
        level, carry0, (tags, parent_slot, valid, event_idx))
    return matched, first


# ------------------------------------------------------ wavefront engine
@dataclass
class ChunkDoc:
    """Chunked wavefront layout: levels split into fixed-width chunks.

    Rectangular (D, Wmax) bucketing wastes work when level widths are
    skewed (measured 5–10× padding on ToXGene-like corpora — see
    EXPERIMENTS.md §Perf-filter).  Here each level is split into chunks
    of width C; chunk i owns rows [i·C, (i+1)·C) of a flat node buffer
    and parents are *global* padded indices into that buffer, so the
    engine runs Σ⌈w_d/C⌉ dense steps with ≤C padding per level.
    """

    tags: np.ndarray         # (n_chunks, C) int32, -1 pad
    parent_idx: np.ndarray   # (n_chunks, C) int32 — global padded index;
    #                           buffer_len ⇒ virtual root row
    valid: np.ndarray        # (n_chunks, C) bool
    event_idx: np.ndarray    # (n_chunks, C) int32

    @property
    def n_chunks(self) -> int:
        return int(self.tags.shape[0])

    @property
    def chunk(self) -> int:
        return int(self.tags.shape[1])


def chunkize(ev: EventStream, chunk: int = 128) -> ChunkDoc:
    return chunkize_level(levelize(ev), chunk)


def chunkize_level(ld: LevelDoc, chunk: int = 128) -> ChunkDoc:
    d_max, w_max = ld.tags.shape
    # chunks per level and level→base-chunk mapping
    widths = ld.valid.sum(axis=1)
    n_per = [max(1, int(-(-w // chunk))) for w in widths]
    base = np.concatenate([[0], np.cumsum(n_per)[:-1]])
    n_chunks = int(sum(n_per))
    buf_len = n_chunks * chunk

    def gpos(d: int, slot: np.ndarray) -> np.ndarray:
        return ((base[d] + slot // chunk) * chunk + slot % chunk).astype(
            np.int32)

    tags = np.full((n_chunks, chunk), -1, np.int32)
    parent = np.full((n_chunks, chunk), buf_len, np.int32)
    valid = np.zeros((n_chunks, chunk), bool)
    eidx = np.zeros((n_chunks, chunk), np.int32)
    for d in range(d_max):
        w = int(widths[d])
        if w == 0:
            continue
        slots = np.arange(w)
        g = gpos(d, slots)
        ci, cj = g // chunk, g % chunk
        tags[ci, cj] = ld.tags[d, :w]
        p = ld.parent_slot[d, :w]
        parent[ci, cj] = np.where(p == w_max, buf_len,
                                  gpos(d - 1, np.clip(p, 0, None)))
        valid[ci, cj] = True
        eidx[ci, cj] = ld.event_idx[d, :w]
    return ChunkDoc(tags, parent, valid, eidx)


@functools.partial(jax.jit, static_argnames=("n_states", "n_tags"))
def _run_wavefront(tags, parent_idx, valid, event_idx,
                   in_state, in_tag, selfloop, init, accept_state,
                   *, n_states: int, n_tags: int):
    """Boolean-state wavefront (§Perf-filter iteration 2: 0/1 state lanes
    as bool — 4× less buffer traffic than f32; the MXU/kernel path keeps
    f32 for matmul form, this is the VPU/CPU path)."""
    n_chunks, c = tags.shape
    n_q = accept_state.shape[0]
    buf_len = n_chunks * c
    selfloop_b = selfloop > 0
    init_b = init > 0
    buf0 = jnp.zeros((buf_len + 1, n_states), bool)
    buf0 = buf0.at[buf_len].set(init_b)

    def step(carry, xs):
        buf, matched, first = carry
        i, tg, pidx, vld, eidx = xs
        parent_rows = jnp.take(buf, pidx, axis=0)          # (C, S) bool
        tagmatch = ((in_tag[None, :] == tg[:, None])
                    | (in_tag == WILD_TAG)[None, :])
        src = jnp.take(parent_rows, in_state, axis=1)
        nxt = (src & tagmatch) | (parent_rows & selfloop_b[None, :])
        nxt = nxt & vld[:, None]
        buf = jax.lax.dynamic_update_slice(buf, nxt, (i * c, 0))
        acc = jnp.take(nxt, accept_state, axis=1) & vld[:, None]
        first = jnp.minimum(
            first, jnp.where(acc, eidx[:, None], NO_MATCH).min(axis=0))
        matched = matched | acc.any(axis=0)
        return (buf, matched, first), None

    carry0 = (buf0, jnp.zeros(n_q, bool), jnp.full(n_q, NO_MATCH, jnp.int32))
    (buf, matched, first), _ = jax.lax.scan(
        step, carry0,
        (jnp.arange(n_chunks, dtype=jnp.int32), tags, parent_idx, valid,
         event_idx))
    return matched, first


@functools.partial(jax.jit, static_argnames=("n_states", "n_tags"))
def _run_wavefront_kernel(tags, parent_idx, valid, event_idx,
                          selfloop, init, accept_state, req, wild,
                          parent_1h, *, n_states: int, n_tags: int):
    """Wavefront with the Pallas transition kernel (MXU path, f32).

    Same chunk structure as :func:`_run_wavefront`; the per-chunk
    transition is the `nfa_transition` kernel (one-hot tag matmul +
    parent-pointer matmul), i.e. the TPU production configuration."""
    from repro.kernels import ops as kops
    n_chunks, c = tags.shape
    n_q = accept_state.shape[0]
    buf_len = n_chunks * c
    buf0 = jnp.zeros((buf_len + 1, n_states), jnp.float32)
    buf0 = buf0.at[buf_len].set(init)

    def step(carry, xs):
        buf, matched, first = carry
        i, tg, pidx, vld, eidx = xs
        parent_rows = jnp.take(buf, pidx, axis=0)          # (C, S)
        tg_masked = jnp.where(vld, tg, -1)
        nxt = kops.nfa_transition(parent_rows, tg_masked, req, wild,
                                  parent_1h, selfloop)
        buf = jax.lax.dynamic_update_slice(buf, nxt, (i * c, 0))
        acc = (jnp.take(nxt, accept_state, axis=1) > 0) & vld[:, None]
        first = jnp.minimum(
            first, jnp.where(acc, eidx[:, None], NO_MATCH).min(axis=0))
        matched = matched | acc.any(axis=0)
        return (buf, matched, first), None

    carry0 = (buf0, jnp.zeros(n_q, bool), jnp.full(n_q, NO_MATCH, jnp.int32))
    (buf, matched, first), _ = jax.lax.scan(
        step, carry0,
        (jnp.arange(n_chunks, dtype=jnp.int32), tags, parent_idx, valid,
         event_idx))
    return matched, first


class _LevelShardedMixin:
    """Shared sharded-contract bits of the levelwise family: the REQ
    pre-decoder is (T, S), so uniform stacking also needs a uniform tag
    space — pad ``n_tags`` to a bucket so churn that introduces new tags
    rarely forces a global re-pad."""

    def part_pads(self, parts, *, query_bucket: int = 8):
        pads = super().part_pads(parts, query_bucket=query_bucket)
        if pads:
            pads["n_tags"] = base._round_up(
                max((nfa.n_tags for nfa in parts), default=1), 16)
        return pads


@base.register("wavefront")
class WavefrontEngine(_LevelShardedMixin, base.FilterEngine):
    """Chunked-wavefront levelwise engine (§Perf-filter iteration 1)."""

    state_multiple = 128
    device_sharded = True

    def __init__(self, nfa: NFA, dictionary=None, chunk: int = 128,
                 use_kernel: bool = False, **options) -> None:
        self.chunk = chunk
        self.use_kernel = use_kernel
        super().__init__(nfa, dictionary, **options)

    def plan(self, nfa: NFA) -> base.FilterPlan:
        return _level_plan("wavefront", nfa, self.state_multiple)

    def _run_one(self, plan, cd_tags, cd_parent, cd_valid, cd_eidx):
        if self.use_kernel:
            return _run_wavefront_kernel(
                cd_tags, cd_parent, cd_valid, cd_eidx,
                plan["selfloop"], plan["init"], plan["accept_state"],
                plan["req"], plan["wild"], plan["parent_1h"],
                n_states=plan.meta["n_states"], n_tags=plan.meta["n_tags"])
        return _run_wavefront(
            cd_tags, cd_parent, cd_valid, cd_eidx,
            plan["in_state"], plan["in_tag"], plan["selfloop"],
            plan["init"], plan["accept_state"],
            n_states=plan.meta["n_states"], n_tags=plan.meta["n_tags"])

    def filter_document(self, ev: EventStream) -> FilterResult:
        cd = chunkize(ev, self.chunk)
        matched, first = self._run_one(
            self.plan_, jnp.asarray(cd.tags), jnp.asarray(cd.parent_idx),
            jnp.asarray(cd.valid), jnp.asarray(cd.event_idx))
        return FilterResult(np.asarray(matched), np.asarray(first))

    def _prep(self, batch: EventBatch) -> tuple:
        # precomputed batch structure → no per-event host re-walk
        cds = [chunkize_level(ld, self.chunk)
               for ld in _leveldocs_of_batch(batch)]
        nc = max(c.n_chunks for c in cds)

        def pad(c: ChunkDoc) -> ChunkDoc:
            extra = nc - c.n_chunks
            if extra == 0:
                # re-point root rows: buffer length differs per doc only
                # through n_chunks; keep as-is
                return c
            ck = c.chunk
            # grow: valid=False chunks at the end; parent root sentinel
            # must point at the NEW buffer end (nc*ck)
            old_len = c.n_chunks * ck
            parent = np.where(c.parent_idx == old_len, nc * ck,
                              c.parent_idx)
            return ChunkDoc(
                np.concatenate([c.tags, np.full((extra, ck), -1, np.int32)]),
                np.concatenate([parent,
                                np.full((extra, ck), nc * ck, np.int32)]),
                np.concatenate([c.valid, np.zeros((extra, ck), bool)]),
                np.concatenate([c.event_idx,
                                np.zeros((extra, ck), np.int32)]),
            )

        cds = [pad(c) for c in cds]
        # fix root sentinel for docs that already had nc chunks
        fixed = []
        for c in cds:
            parent = np.where(c.parent_idx >= nc * c.chunk, nc * c.chunk,
                              c.parent_idx)
            fixed.append(ChunkDoc(c.tags, parent, c.valid, c.event_idx))
        return (jnp.asarray(np.stack([c.tags for c in fixed])),
                jnp.asarray(np.stack([c.parent_idx for c in fixed])),
                jnp.asarray(np.stack([c.valid for c in fixed])),
                jnp.asarray(np.stack([c.event_idx for c in fixed])))

    def _run_with_plan(self, plan: base.FilterPlan, prep: tuple):
        return jax.vmap(
            lambda t, p_, v, e: self._run_one(plan, t, p_, v, e))(*prep)

    def filter_batch(self, batch: EventBatch) -> FilterResult:
        return self.filter_batch_with_plan(self.plan_, batch)

    def filter_documents_batched(self, docs: list[EventStream]) -> list[FilterResult]:
        """Legacy list API (prefer :meth:`filter_batch`)."""
        res = self.filter_batch(EventBatch.from_streams(docs))
        return list(res.per_document())


@base.register("levelwise")
class LevelwiseEngine(_LevelShardedMixin, base.FilterEngine):
    state_multiple = 128
    device_sharded = True

    def __init__(self, nfa: NFA, dictionary=None, use_matmul: bool = True,
                 use_kernel: bool = False, **options) -> None:
        self.use_matmul = use_matmul
        self.use_kernel = use_kernel
        super().__init__(nfa, dictionary, **options)

    def plan(self, nfa: NFA) -> base.FilterPlan:
        return _level_plan("levelwise", nfa, self.state_multiple)

    def _run_one(self, plan, ld_tags, ld_parent, ld_valid, ld_eidx):
        return _run_level(
            ld_tags, ld_parent, ld_valid, ld_eidx,
            plan["in_state"], plan["in_tag"], plan["selfloop"],
            plan["init"], plan["accept_state"], plan["req"], plan["wild"],
            plan["parent_1h"],
            n_states=plan.meta["n_states"], n_tags=plan.meta["n_tags"],
            use_matmul=self.use_matmul, use_kernel=self.use_kernel)

    def filter_document(self, ev: EventStream) -> FilterResult:
        ld = levelize(ev)
        matched, first = self._run_one(
            self.plan_, jnp.asarray(ld.tags), jnp.asarray(ld.parent_slot),
            jnp.asarray(ld.valid), jnp.asarray(ld.event_idx))
        return FilterResult(np.asarray(matched), np.asarray(first))

    def _prep(self, batch: EventBatch) -> tuple:
        # precomputed batch structure → no per-event host re-walk
        ld = _stack_leveldocs(_leveldocs_of_batch(batch))
        return (jnp.asarray(ld.tags), jnp.asarray(ld.parent_slot),
                jnp.asarray(ld.valid), jnp.asarray(ld.event_idx))

    def _run_with_plan(self, plan: base.FilterPlan, prep: tuple):
        return jax.vmap(
            lambda t, p_, v, e: self._run_one(plan, t, p_, v, e))(*prep)

    def filter_batch(self, batch: EventBatch) -> FilterResult:
        return self.filter_batch_with_plan(self.plan_, batch)

    def filter_documents_batched(self, docs: list[EventStream]) -> list[FilterResult]:
        """Legacy list API (prefer :meth:`filter_batch`)."""
        res = self.filter_batch(EventBatch.from_streams(docs))
        return list(res.per_document())
