"""Checkpoint store: atomic, async, manifest-driven, elastic.

Layout per step::

    <dir>/step_000123/
        manifest.json    # step, config name, pytree paths, shapes, dtypes
        arrays.npz       # one entry per leaf (path-keyed)
    <dir>/LATEST         # atomically updated pointer

Properties needed at 1000+ nodes (simulated here single-host, same code
path):

* **Atomicity** — entry contents are fsynced, the entry directory is
  written as ``<name>.tmp`` then ``os.rename``\\ d (POSIX atomic), and
  the ``LATEST`` pointer goes through an fsynced temp file +
  ``os.replace``; a crash at any point leaves either the old state or
  the new state, never a torn entry or a dangling pointer.
* **Async** — ``save_async`` snapshots device arrays to host then writes
  on a daemon thread; the train loop keeps stepping (checkpoint off the
  critical path).
* **Elastic restore** — the manifest stores the *logical* pytree, not the
  device layout; ``restore`` device_puts with whatever shardings the new
  mesh provides, so restarts may change pod/mesh shape freely.
* **Corruption fallback** — ``restore_latest`` validates and walks back
  to the newest intact checkpoint.

:class:`PlanCache` reuses the same write machinery for a different
payload: compiled filter-plan tables keyed by content hash (NFA tables ×
pad targets × kernel config — see
:meth:`repro.core.engines.base.FilterEngine.plan_cache_key`), so a serve
cold start or crash recovery skips recompilation and inherits the same
crash-safety guarantees.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any

import jax
import numpy as np


def _fsync_file(path: str) -> None:
    fd = os.open(path, os.O_RDONLY)
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


def _fsync_dir(path: str) -> None:
    # directory fsync makes the rename itself durable; some filesystems
    # refuse O_RDONLY on dirs — degrading to no-sync there is still no
    # worse than the pre-hardening behavior
    try:
        fd = os.open(path, os.O_RDONLY)
    except OSError:  # pragma: no cover - platform-dependent
        return
    try:
        os.fsync(fd)
    except OSError:  # pragma: no cover - platform-dependent
        pass
    finally:
        os.close(fd)


def _write_entry(directory: str, name: str, flat: dict[str, np.ndarray],
                 manifest: dict) -> str:
    """Crash-safe entry write shared by checkpoints and the plan cache.

    ``<dir>/<name>.tmp/{arrays.npz, manifest.json}`` is written, each
    file fsynced (manifest last, so a readable manifest implies readable
    arrays), then the directory atomically renamed to ``<dir>/<name>``
    and the parent directory fsynced — the entry either exists intact or
    not at all.
    """
    tmp = os.path.join(directory, name + ".tmp")
    final = os.path.join(directory, name)
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    np.savez(os.path.join(tmp, "arrays.npz"), **flat)
    _fsync_file(os.path.join(tmp, "arrays.npz"))
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    _fsync_dir(tmp)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    _fsync_dir(directory)
    return final


def _write_pointer(directory: str, pointer: str, value: str) -> None:
    """Atomically (re)point ``<dir>/<pointer>`` at ``value`` via an
    fsynced temp file + ``os.replace`` — a crash can never leave the
    pointer missing or half-written."""
    tmp = os.path.join(directory, pointer + ".tmp")
    with open(tmp, "w") as f:
        f.write(value)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, os.path.join(directory, pointer))
    _fsync_dir(directory)


def _valid_entry(path: str) -> bool:
    """Entry intact: manifest readable and every key present in the npz."""
    try:
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        with np.load(os.path.join(path, "arrays.npz")) as z:
            return sorted(z.files) == sorted(manifest["keys"])
    except Exception:
        return False


def _flatten(tree: Any) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        flat[key] = np.asarray(jax.device_get(leaf))
    return flat


def _tree_like(tree: Any, flat: dict[str, np.ndarray]) -> Any:
    paths, treedef = jax.tree_util.tree_flatten_with_path(tree)
    leaves = []
    for path, leaf in paths:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        arr = flat[key]
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"{key}: shape {arr.shape} != {leaf.shape}")
        leaves.append(arr)
    return jax.tree_util.tree_unflatten(treedef, leaves)


class CheckpointStore:
    def __init__(self, directory: str, keep: int = 3) -> None:
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: threading.Thread | None = None

    # ------------------------------------------------------------- saving
    def save(self, step: int, tree: Any, extra: dict | None = None) -> str:
        flat = _flatten(tree)
        return self._write(step, flat, extra or {})

    def save_async(self, step: int, tree: Any,
                   extra: dict | None = None) -> None:
        self.wait()  # at most one outstanding write
        flat = _flatten(tree)  # snapshot synchronously (device → host)
        self._thread = threading.Thread(
            target=self._write, args=(step, flat, extra or {}), daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, step: int, flat: dict, extra: dict) -> str:
        name = f"step_{step:08d}"
        manifest = {
            "step": step,
            "keys": sorted(flat.keys()),
            "shapes": {k: list(v.shape) for k, v in flat.items()},
            "dtypes": {k: str(v.dtype) for k, v in flat.items()},
            **extra,
        }
        final = _write_entry(self.dir, name, flat, manifest)
        _write_pointer(self.dir, "LATEST", name)
        self._gc()
        return final

    def _gc(self) -> None:
        steps = sorted(d for d in os.listdir(self.dir)
                       if d.startswith("step_") and not d.endswith(".tmp"))
        for d in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.dir, d), ignore_errors=True)

    # ------------------------------------------------------------ restore
    def _valid(self, name: str) -> bool:
        return _valid_entry(os.path.join(self.dir, name))

    def latest_step(self) -> int | None:
        steps = sorted(d for d in os.listdir(self.dir)
                       if d.startswith("step_") and not d.endswith(".tmp"))
        for name in reversed(steps):
            if self._valid(name):
                return int(name.split("_")[1])
        return None

    def restore(self, step: int, like: Any,
                shardings: Any | None = None) -> tuple[Any, dict]:
        d = os.path.join(self.dir, f"step_{step:08d}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        with np.load(os.path.join(d, "arrays.npz")) as z:
            flat = {k: z[k] for k in z.files}
        tree = _tree_like(like, flat)
        if shardings is not None:
            tree = jax.tree.map(
                lambda a, s: jax.device_put(a, s), tree, shardings)
        return tree, manifest

    def restore_latest(self, like: Any,
                       shardings: Any | None = None):
        step = self.latest_step()
        if step is None:
            return None
        tree, manifest = self.restore(step, like, shardings)
        return step, tree, manifest


# ------------------------------------------------------------- plan cache
class PlanCache:
    """Crash-safe persisted cache of compiled filter-plan tables.

    Layout: one entry per key under ``<dir>/plan_<key>/`` with the same
    ``{arrays.npz, manifest.json}`` format — and the same fsync +
    atomic-rename write path (:func:`_write_entry`) — as a checkpoint
    step, so a crash mid-``put`` leaves either the old entry or the new
    one, never a torn cache.  Keys are opaque content hashes (the engine
    layer derives them from NFA tables × pad targets × kernel config,
    :meth:`repro.core.engines.base.FilterEngine.plan_cache_key`), so a
    stale hit is structurally impossible: different inputs hash to a
    different entry.

    ``hits``/``misses`` count lookups for the cold-start benchmarks and
    the cache-hit tests; a corrupt entry reads as a miss (and is
    overwritten by the next ``put``), mirroring ``restore_latest``'s
    walk-back semantics.
    """

    def __init__(self, directory: str) -> None:
        self.dir = directory
        os.makedirs(directory, exist_ok=True)
        self.hits = 0
        self.misses = 0

    def _path(self, key: str) -> str:
        return os.path.join(self.dir, f"plan_{key}")

    def __contains__(self, key: str) -> bool:
        return _valid_entry(self._path(key))

    def get(self, key: str) -> tuple[dict[str, np.ndarray], dict] | None:
        """→ ``(tables, manifest)`` or ``None`` (miss/corrupt entry)."""
        d = self._path(key)
        if not _valid_entry(d):
            self.misses += 1
            return None
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        with np.load(os.path.join(d, "arrays.npz")) as z:
            tables = {k: z[k] for k in z.files}
        self.hits += 1
        return tables, manifest

    def put(self, key: str, tables: dict[str, np.ndarray],
            extra: dict | None = None) -> str:
        flat = {k: np.asarray(v) for k, v in tables.items()}
        manifest = {"keys": sorted(flat), **(extra or {})}
        return _write_entry(self.dir, f"plan_{key}", flat, manifest)

    def keys(self) -> list[str]:
        return sorted(d[len("plan_"):] for d in os.listdir(self.dir)
                      if d.startswith("plan_") and not d.endswith(".tmp"))
