"""Checkpoint store: atomic, async, manifest-driven, elastic.

Layout per step::

    <dir>/step_000123/
        manifest.json    # step, config name, pytree paths, shapes, dtypes
        arrays.npz       # one entry per leaf (path-keyed)
    <dir>/LATEST         # atomically updated pointer

Properties needed at 1000+ nodes (simulated here single-host, same code
path):

* **Atomicity** — writes go to ``step_X.tmp`` then ``os.rename`` (POSIX
  atomic); a crash mid-write never corrupts the latest checkpoint.
* **Async** — ``save_async`` snapshots device arrays to host then writes
  on a daemon thread; the train loop keeps stepping (checkpoint off the
  critical path).
* **Elastic restore** — the manifest stores the *logical* pytree, not the
  device layout; ``restore`` device_puts with whatever shardings the new
  mesh provides, so restarts may change pod/mesh shape freely.
* **Corruption fallback** — ``restore_latest`` validates and walks back
  to the newest intact checkpoint.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any

import jax
import numpy as np


def _flatten(tree: Any) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        flat[key] = np.asarray(jax.device_get(leaf))
    return flat


def _tree_like(tree: Any, flat: dict[str, np.ndarray]) -> Any:
    paths, treedef = jax.tree_util.tree_flatten_with_path(tree)
    leaves = []
    for path, leaf in paths:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        arr = flat[key]
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"{key}: shape {arr.shape} != {leaf.shape}")
        leaves.append(arr)
    return jax.tree_util.tree_unflatten(treedef, leaves)


class CheckpointStore:
    def __init__(self, directory: str, keep: int = 3) -> None:
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: threading.Thread | None = None

    # ------------------------------------------------------------- saving
    def save(self, step: int, tree: Any, extra: dict | None = None) -> str:
        flat = _flatten(tree)
        return self._write(step, flat, extra or {})

    def save_async(self, step: int, tree: Any,
                   extra: dict | None = None) -> None:
        self.wait()  # at most one outstanding write
        flat = _flatten(tree)  # snapshot synchronously (device → host)
        self._thread = threading.Thread(
            target=self._write, args=(step, flat, extra or {}), daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, step: int, flat: dict, extra: dict) -> str:
        name = f"step_{step:08d}"
        tmp = os.path.join(self.dir, name + ".tmp")
        final = os.path.join(self.dir, name)
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        np.savez(os.path.join(tmp, "arrays.npz"), **flat)
        manifest = {
            "step": step,
            "keys": sorted(flat.keys()),
            "shapes": {k: list(v.shape) for k, v in flat.items()},
            "dtypes": {k: str(v.dtype) for k, v in flat.items()},
            **extra,
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        with open(os.path.join(self.dir, "LATEST.tmp"), "w") as f:
            f.write(name)
        os.rename(os.path.join(self.dir, "LATEST.tmp"),
                  os.path.join(self.dir, "LATEST"))
        self._gc()
        return final

    def _gc(self) -> None:
        steps = sorted(d for d in os.listdir(self.dir)
                       if d.startswith("step_") and not d.endswith(".tmp"))
        for d in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.dir, d), ignore_errors=True)

    # ------------------------------------------------------------ restore
    def _valid(self, name: str) -> bool:
        d = os.path.join(self.dir, name)
        try:
            with open(os.path.join(d, "manifest.json")) as f:
                manifest = json.load(f)
            with np.load(os.path.join(d, "arrays.npz")) as z:
                return sorted(z.files) == manifest["keys"]
        except Exception:
            return False

    def latest_step(self) -> int | None:
        steps = sorted(d for d in os.listdir(self.dir)
                       if d.startswith("step_") and not d.endswith(".tmp"))
        for name in reversed(steps):
            if self._valid(name):
                return int(name.split("_")[1])
        return None

    def restore(self, step: int, like: Any,
                shardings: Any | None = None) -> tuple[Any, dict]:
        d = os.path.join(self.dir, f"step_{step:08d}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        with np.load(os.path.join(d, "arrays.npz")) as z:
            flat = {k: z[k] for k in z.files}
        tree = _tree_like(like, flat)
        if shardings is not None:
            tree = jax.tree.map(
                lambda a, s: jax.device_put(a, s), tree, shardings)
        return tree, manifest

    def restore_latest(self, like: Any,
                       shardings: Any | None = None):
        step = self.latest_step()
        if step is None:
            return None
        tree, manifest = self.restore(step, like, shardings)
        return step, tree, manifest
