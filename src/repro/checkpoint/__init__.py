"""Sharded checkpointing with manifest, async writes, elastic restore,
and the crash-safe compiled-plan cache."""
from .store import CheckpointStore, PlanCache  # noqa: F401
