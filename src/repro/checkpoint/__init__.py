"""Sharded checkpointing with manifest, async writes, elastic restore."""
from .store import CheckpointStore  # noqa: F401
