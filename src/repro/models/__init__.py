"""Assigned-architecture model zoo (pure JAX, scan-over-layers).

Families: dense GQA transformers, MLA, MoE (token-choice top-k with
sort-based dispatch), Mamba2/SSD, hybrid (Zamba2), encoder-decoder
(Whisper backbone), VLM (InternVL backbone).  Modality frontends are
stubs per the assignment: ``input_specs`` provides precomputed
frame/patch embeddings.
"""
from .config import ModelConfig, SHAPES, ShapeSpec  # noqa: F401
