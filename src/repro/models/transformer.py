"""Model assembly for every family in the zoo.

All forward passes share one entry point:

    params            = init_model(cfg, key)
    loss, metrics     = train_loss(cfg, params, batch)
    logits, cache     = prefill(cfg, params, batch, cache)
    logits, cache     = decode_step(cfg, params, tokens, cache, cache_pos)

Layers are stacked (leading ``n_layers`` axis) and applied with
``lax.scan`` so the HLO stays small at 60+ layers; ``cfg.remat`` wraps the
scanned body in ``jax.checkpoint`` (only layer-boundary activations are
kept live — the remat policy the §Perf notes discuss).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from ..sharding import constrain
from .config import ModelConfig
from . import layers as L

Params = dict[str, Any]

# Remat note: saving post-TP-reduction outputs (tagged "post_collective"
# in layers.py) to skip backward re-all-reduces was tried and REVERTED:
# collective term −10–15%, but the saved (B,L,D) tensors per layer cost
# +20–70 GiB/dev under grad accumulation — net loss.  The tags remain for
# future selective policies (e.g. save only every k-th layer).  See
# EXPERIMENTS.md §Perf iteration R1.
_REMAT_POLICY = jax.checkpoint_policies.save_only_these_names(
    "post_collective")


# ---------------------------------------------------------------- helpers
def _stack_init(fn, key, n: int) -> Params:
    return jax.vmap(fn)(jax.random.split(key, n))


def _sinusoid(positions: jax.Array, d: int) -> jax.Array:
    """On-the-fly sinusoidal embedding for arbitrary positions (b, l)."""
    dim = jnp.arange(0, d, 2, dtype=jnp.float32)[None, None, :]
    ang = positions.astype(jnp.float32)[..., None] / (10000 ** (dim / d))
    out = jnp.zeros(positions.shape + (d,), jnp.float32)
    out = out.at[..., 0::2].set(jnp.sin(ang))
    out = out.at[..., 1::2].set(jnp.cos(ang))
    return out


def _embed(cfg: ModelConfig, params: Params, tokens: jax.Array) -> jax.Array:
    return jnp.take(params["embed"], tokens, axis=0).astype(
        L.dtype_of(cfg))


def _unembed(cfg: ModelConfig, params: Params, x: jax.Array) -> jax.Array:
    w = params["embed"] if cfg.tie_embeddings else params["unembed"]
    logits = jnp.einsum("bld,vd->blv", x, w)
    logits = constrain(logits, ("dp", None, "model"))
    if cfg.vocab_eff != cfg.vocab:
        pad_mask = jnp.arange(cfg.vocab_eff) >= cfg.vocab
        logits = jnp.where(pad_mask[None, None, :], -1e30, logits)
    return logits


def cross_entropy(logits: jax.Array, labels: jax.Array,
                  mask: jax.Array | None = None):
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None],
                               axis=-1)[..., 0]
    nll = lse - gold
    if mask is None:
        return nll.mean()
    mask = mask.astype(jnp.float32)
    return (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)


def chunked_ce_from_hidden(cfg: ModelConfig, params: Params, h: jax.Array,
                           labels: jax.Array,
                           mask: jax.Array | None = None):
    """Cross-entropy without materializing (B, S, V) logits.

    The unembed matmul + logsumexp run per sequence chunk under a
    checkpointed scan — peak memory O(B·chunk·V) instead of O(B·S·V),
    which is what keeps the 150k-vocab configs inside HBM at seq 4k–32k.
    """
    b, s, d = h.shape
    chunk = cfg.ce_chunk
    if not chunk or s % chunk != 0 or s <= chunk:
        logits = _unembed(cfg, params, h)
        return cross_entropy(logits, labels, mask)
    nc = s // chunk
    hs = jnp.moveaxis(h.reshape(b, nc, chunk, d), 1, 0)
    ls = jnp.moveaxis(labels.reshape(b, nc, chunk), 1, 0)
    if mask is None:
        ms = jnp.ones((nc, b, chunk), jnp.float32)
    else:
        ms = jnp.moveaxis(mask.reshape(b, nc, chunk), 1, 0).astype(
            jnp.float32)

    @jax.checkpoint
    def body(acc, xs):
        hc, lc, mc = xs
        logits = _unembed(cfg, params, hc).astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, lc[..., None], axis=-1)[..., 0]
        nll = (lse - gold) * mc
        return (acc[0] + nll.sum(), acc[1] + mc.sum()), None

    (tot, cnt), _ = jax.lax.scan(body, (jnp.float32(0), jnp.float32(0)),
                                 (hs, ls, ms))
    return tot / jnp.maximum(cnt, 1.0)


# ------------------------------------------------------- decoder layer(s)
def _init_decoder_layer(cfg: ModelConfig, ffn: str, d_ff: int):
    def init(key):
        ks = jax.random.split(key, 4)
        dt = L.pdtype_of(cfg)
        p = {"ln1": L.init_norm(cfg.d_model, dt),
             "ln2": L.init_norm(cfg.d_model, dt)}
        if cfg.mla:
            p["attn"] = L.init_mla(cfg, ks[0])
        else:
            p["attn"] = L.init_attention(cfg, ks[0])
        if ffn == "moe":
            p["moe"] = L.init_moe(cfg, ks[1])
        else:
            p["mlp"] = L.init_mlp(cfg, ks[1], d_ff=d_ff, gelu=cfg.mlp_gelu)
        return p
    return init


def _decoder_layer(cfg: ModelConfig, lp: Params, x: jax.Array, *,
                   positions, cache, cache_pos, ffn: str):
    h = L.rms_norm(x, lp["ln1"], cfg.norm_eps)
    if cfg.mla:
        a, new_cache = L.mla_attention(cfg, lp["attn"], h,
                                       positions=positions, cache=cache,
                                       cache_pos=cache_pos)
    else:
        a, new_cache = L.attention(cfg, lp["attn"], h, positions=positions,
                                   causal=True, cache=cache,
                                   cache_pos=cache_pos)
    x = x + a
    h2 = L.rms_norm(x, lp["ln2"], cfg.norm_eps)
    f = L.moe(cfg, lp["moe"], h2) if ffn == "moe" \
        else L.mlp(cfg, lp["mlp"], h2, gelu=cfg.mlp_gelu)
    x = x + f
    x = constrain(x, ("dp", None, None))
    return x, new_cache


def _scan_stack(cfg: ModelConfig, stacked: Params, x: jax.Array, *,
                positions, caches, cache_pos, ffn: str):
    has_cache = caches is not None

    def body(carry, xs):
        lp, c = xs if has_cache else (xs, None)
        y, nc = _decoder_layer(cfg, lp, carry, positions=positions,
                               cache=c, cache_pos=cache_pos, ffn=ffn)
        return y, nc

    if cfg.remat:
        body = jax.checkpoint(body)
    xs = (stacked, caches) if has_cache else stacked
    x, new_caches = jax.lax.scan(body, x, xs)
    return x, (new_caches if has_cache else None)


# ===================================================== dense / moe / vlm
def _init_decoder_lm(cfg: ModelConfig, key) -> Params:
    ks = jax.random.split(key, 8)
    dt = L.pdtype_of(cfg)
    p: Params = {
        "embed": L._dense_init(ks[0], (cfg.vocab_eff, cfg.d_model), dt,
                               scale=0.02),
        "final_norm": L.init_norm(cfg.d_model, dt),
    }
    if not cfg.tie_embeddings:
        p["unembed"] = L._dense_init(ks[1], (cfg.vocab_eff, cfg.d_model),
                                     dt, scale=0.02)
    n_main = cfg.n_layers - cfg.dense_prefix
    if cfg.dense_prefix:
        p["prefix_layers"] = _stack_init(
            _init_decoder_layer(cfg, "mlp", cfg.dense_d_ff or cfg.d_ff),
            ks[2], cfg.dense_prefix)
    ffn = "moe" if cfg.n_experts else "mlp"
    p["layers"] = _stack_init(_init_decoder_layer(cfg, ffn, cfg.d_ff),
                              ks[3], n_main)
    if cfg.family == "vlm":
        p["patch_proj"] = L._dense_init(ks[4], (cfg.d_model, cfg.d_model), dt)
    if cfg.mtp:
        p["mtp"] = {
            "proj": L._dense_init(ks[5], (2 * cfg.d_model, cfg.d_model), dt),
            "norm": L.init_norm(cfg.d_model, dt),
            "layer": _init_decoder_layer(cfg, "mlp",
                                         cfg.dense_d_ff or cfg.d_ff)(ks[6]),
            "final_norm": L.init_norm(cfg.d_model, dt),
        }
    return p


def _decoder_lm_apply(cfg: ModelConfig, params: Params, tokens: jax.Array,
                      *, patches=None, caches=None, cache_pos=None,
                      return_hidden: bool = False):
    x = _embed(cfg, params, tokens)
    if cfg.family == "vlm" and patches is not None:
        pe = patches.astype(x.dtype) @ params["patch_proj"]
        x = jnp.concatenate([pe, x], axis=1)
    b, l, _ = x.shape
    if cache_pos is not None and tokens.shape[1] == 1:
        positions = jnp.full((b, 1), cache_pos, jnp.int32)
    else:
        positions = jnp.broadcast_to(jnp.arange(l, dtype=jnp.int32), (b, l))
    x = constrain(x, ("dp", None, None))
    new_caches: Params = {}
    if cfg.dense_prefix:
        c = caches.get("prefix") if caches else None
        x, nc = _scan_stack(cfg, params["prefix_layers"], x,
                            positions=positions, caches=c,
                            cache_pos=cache_pos, ffn="mlp")
        new_caches["prefix"] = nc
    ffn = "moe" if cfg.n_experts else "mlp"
    c = caches.get("main") if caches else None
    x, nc = _scan_stack(cfg, params["layers"], x, positions=positions,
                        caches=c, cache_pos=cache_pos, ffn=ffn)
    new_caches["main"] = nc
    h = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    if return_hidden:
        return h, (new_caches if caches else None)
    logits = _unembed(cfg, params, h)
    return logits, (new_caches if caches else None)


# ================================================================ ssm lm
def _init_ssm_lm(cfg: ModelConfig, key) -> Params:
    ks = jax.random.split(key, 4)
    dt = L.pdtype_of(cfg)

    def init_layer(k):
        return {"ln": L.init_norm(cfg.d_model, dt),
                "mamba": L.init_mamba2(cfg, k)}

    p: Params = {
        "embed": L._dense_init(ks[0], (cfg.vocab_eff, cfg.d_model), dt,
                               scale=0.02),
        "layers": _stack_init(init_layer, ks[1], cfg.n_layers),
        "final_norm": L.init_norm(cfg.d_model, dt),
    }
    if not cfg.tie_embeddings:
        p["unembed"] = L._dense_init(ks[2], (cfg.vocab_eff, cfg.d_model),
                                     dt, scale=0.02)
    return p


def _ssm_layer(cfg, lp, x, cache, cache_pos):
    h = L.rms_norm(x, lp["ln"], cfg.norm_eps)
    y, nc = L.mamba2(cfg, lp["mamba"], h, cache=cache, cache_pos=cache_pos)
    return x + y, nc


def _ssm_lm_apply(cfg: ModelConfig, params: Params, tokens: jax.Array, *,
                  caches=None, cache_pos=None, return_hidden: bool = False):
    x = _embed(cfg, params, tokens)
    has_cache = caches is not None

    def body(carry, xs):
        lp, c = xs if has_cache else (xs, None)
        y, nc = _ssm_layer(cfg, lp, carry, c, cache_pos)
        return y, nc

    if cfg.remat:
        body = jax.checkpoint(body)
    xs = (params["layers"], caches["main"]) if has_cache else params["layers"]
    x, nc = jax.lax.scan(body, x, xs)
    h = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    if return_hidden:
        return h, ({"main": nc} if has_cache else None)
    logits = _unembed(cfg, params, h)
    return logits, ({"main": nc} if has_cache else None)


# ============================================================= hybrid lm
def _n_attn_invocations(cfg: ModelConfig) -> int:
    return cfg.n_layers // cfg.hybrid_period


def _init_hybrid_lm(cfg: ModelConfig, key) -> Params:
    ks = jax.random.split(key, 6)
    dt = L.pdtype_of(cfg)

    def init_layer(k):
        return {"ln": L.init_norm(cfg.d_model, dt),
                "mamba": L.init_mamba2(cfg, k)}

    p: Params = {
        "embed": L._dense_init(ks[0], (cfg.vocab_eff, cfg.d_model), dt,
                               scale=0.02),
        "layers": _stack_init(init_layer, ks[1], cfg.n_layers),
        # the *shared* attention block (Zamba2): one set of weights,
        # invoked every `hybrid_period` layers
        "shared_attn": {"ln": L.init_norm(cfg.d_model, dt),
                        "attn": L.init_attention(cfg, ks[2]),
                        "ln2": L.init_norm(cfg.d_model, dt),
                        "mlp": L.init_mlp(cfg, ks[3])},
        "final_norm": L.init_norm(cfg.d_model, dt),
        "unembed": L._dense_init(ks[4], (cfg.vocab_eff, cfg.d_model), dt,
                                 scale=0.02),
    }
    return p


def _shared_attn_block(cfg, sp, x, positions, cache, cache_pos):
    h = L.rms_norm(x, sp["ln"], cfg.norm_eps)
    a, nc = L.attention(cfg, sp["attn"], h, positions=positions,
                        causal=True, cache=cache, cache_pos=cache_pos)
    x = x + a
    h2 = L.rms_norm(x, sp["ln2"], cfg.norm_eps)
    x = x + L.mlp(cfg, sp["mlp"], h2)
    return x, nc


def _hybrid_lm_apply(cfg: ModelConfig, params: Params, tokens: jax.Array,
                     *, caches=None, cache_pos=None,
                     return_hidden: bool = False):
    x = _embed(cfg, params, tokens)
    b, l, _ = x.shape
    if cache_pos is not None and l == 1:
        positions = jnp.full((b, 1), cache_pos, jnp.int32)
    else:
        positions = jnp.broadcast_to(jnp.arange(l, dtype=jnp.int32), (b, l))
    period = cfg.hybrid_period
    n_inv = _n_attn_invocations(cfg)
    has_cache = caches is not None
    sp = params["shared_attn"]

    attn_caches = caches["attn"] if has_cache else None  # stacked (n_inv,...)

    def body(carry, xs):
        x, attn_c = carry
        (lp, mc), idx = xs if has_cache else ((xs[0], None), xs[1])
        x, new_mc = _ssm_layer(cfg, lp, x, mc, cache_pos)
        is_attn = (idx % period) == (period - 1)
        inv = jnp.minimum(idx // period, n_inv - 1)

        def with_attn(operand):
            x, attn_c = operand
            if has_cache:
                c_l = jax.tree.map(
                    lambda t: jax.lax.dynamic_index_in_dim(t, inv, 0,
                                                           keepdims=False),
                    attn_c)
            else:
                c_l = None
            y, nc = _shared_attn_block(cfg, sp, x, positions, c_l, cache_pos)
            if has_cache:
                attn_c = jax.tree.map(
                    lambda t, u: jax.lax.dynamic_update_index_in_dim(
                        t, u.astype(t.dtype), inv, 0),
                    attn_c, nc)
            return y, attn_c

        x, attn_c = jax.lax.cond(is_attn, with_attn, lambda o: o,
                                 (x, attn_c))
        return (x, attn_c), new_mc

    if cfg.remat:
        body = jax.checkpoint(body)
    idxs = jnp.arange(cfg.n_layers, dtype=jnp.int32)
    xs = ((params["layers"], caches["main"]), idxs) if has_cache \
        else (params["layers"], idxs)
    (x, attn_caches), new_mamba = jax.lax.scan(body, (x, attn_caches), xs)
    h = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    nc = {"main": new_mamba, "attn": attn_caches} if has_cache else None
    if return_hidden:
        return h, nc
    logits = _unembed(cfg, params, h)
    return logits, nc


# ================================================================ encdec
def _init_encdec(cfg: ModelConfig, key) -> Params:
    ks = jax.random.split(key, 8)
    dt = L.pdtype_of(cfg)

    def init_enc_layer(k):
        k1, k2 = jax.random.split(k)
        return {"ln1": L.init_norm(cfg.d_model, dt),
                "attn": L.init_attention(cfg, k1),
                "ln2": L.init_norm(cfg.d_model, dt),
                "mlp": L.init_mlp(cfg, k2, gelu=True)}

    def init_dec_layer(k):
        k1, k2, k3 = jax.random.split(k, 3)
        return {"ln1": L.init_norm(cfg.d_model, dt),
                "self_attn": L.init_attention(cfg, k1),
                "ln_x": L.init_norm(cfg.d_model, dt),
                "cross_attn": L.init_attention(cfg, k2, cross=True),
                "ln2": L.init_norm(cfg.d_model, dt),
                "mlp": L.init_mlp(cfg, k3, gelu=True)}

    return {
        "embed": L._dense_init(ks[0], (cfg.vocab_eff, cfg.d_model), dt,
                               scale=0.02),
        "enc_layers": _stack_init(init_enc_layer, ks[1], cfg.n_enc_layers),
        "enc_norm": L.init_norm(cfg.d_model, dt),
        "dec_layers": _stack_init(init_dec_layer, ks[2], cfg.n_layers),
        "final_norm": L.init_norm(cfg.d_model, dt),
        "unembed": L._dense_init(ks[3], (cfg.vocab_eff, cfg.d_model), dt,
                                 scale=0.02),
    }


def _encode(cfg: ModelConfig, params: Params, frames: jax.Array):
    """Encoder over precomputed frame embeddings (conv frontend stub)."""
    b, t, _ = frames.shape
    positions = jnp.broadcast_to(jnp.arange(t, dtype=jnp.int32), (b, t))
    x = frames.astype(L.dtype_of(cfg)) + _sinusoid(
        positions, cfg.d_model).astype(L.dtype_of(cfg))

    def body(carry, lp):
        h = L.rms_norm(carry, lp["ln1"], cfg.norm_eps)
        a, _ = L.attention(cfg, lp["attn"], h, positions=positions,
                           causal=False)
        x = carry + a
        h2 = L.rms_norm(x, lp["ln2"], cfg.norm_eps)
        return x + L.mlp(cfg, lp["mlp"], h2, gelu=True), None

    if cfg.remat:
        body = jax.checkpoint(body)
    x, _ = jax.lax.scan(body, x, params["enc_layers"])
    return L.rms_norm(x, params["enc_norm"], cfg.norm_eps)


def _dec_layer(cfg, lp, x, enc_out, positions, cache, cache_pos):
    h = L.rms_norm(x, lp["ln1"], cfg.norm_eps)
    self_c = cache.get("self") if cache else None
    a, new_self = L.attention(cfg, lp["self_attn"], h, positions=positions,
                              causal=True, cache=self_c,
                              cache_pos=cache_pos)
    x = x + a
    hx = L.rms_norm(x, lp["ln_x"], cfg.norm_eps)
    cross_c = cache.get("cross") if cache else None
    ca, new_cross = L.attention(cfg, lp["cross_attn"], hx,
                                positions=positions, causal=False,
                                kv_x=enc_out, cache=cross_c,
                                cache_pos=cache_pos)
    x = x + ca
    h2 = L.rms_norm(x, lp["ln2"], cfg.norm_eps)
    x = x + L.mlp(cfg, lp["mlp"], h2, gelu=True)
    nc = {"self": new_self, "cross": new_cross} if cache else None
    return x, nc


def _encdec_apply(cfg: ModelConfig, params: Params, tokens: jax.Array, *,
                  frames=None, enc_out=None, caches=None, cache_pos=None,
                  return_hidden: bool = False):
    if enc_out is None and frames is not None:
        enc_out = _encode(cfg, params, frames)
    b, l = tokens.shape
    x = _embed(cfg, params, tokens)
    if cache_pos is not None and l == 1:
        positions = jnp.full((b, 1), cache_pos, jnp.int32)
    else:
        positions = jnp.broadcast_to(jnp.arange(l, dtype=jnp.int32), (b, l))
    x = x + _sinusoid(positions, cfg.d_model).astype(x.dtype)
    has_cache = caches is not None

    def body(carry, xs):
        lp, c = xs if has_cache else (xs, None)
        y, nc = _dec_layer(cfg, lp, carry, enc_out, positions, c, cache_pos)
        return y, nc

    if cfg.remat:
        body = jax.checkpoint(body)
    xs = (params["dec_layers"], caches["dec"]) if has_cache \
        else params["dec_layers"]
    x, nc = jax.lax.scan(body, x, xs)
    h = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    out_c = {"dec": nc, "enc_out": enc_out} if has_cache else None
    if return_hidden:
        return h, out_c
    logits = _unembed(cfg, params, h)
    return logits, out_c


# ============================================================== public API
def init_model(cfg: ModelConfig, key) -> Params:
    if cfg.family in ("dense", "moe", "vlm"):
        return _init_decoder_lm(cfg, key)
    if cfg.family == "ssm":
        return _init_ssm_lm(cfg, key)
    if cfg.family == "hybrid":
        return _init_hybrid_lm(cfg, key)
    if cfg.family == "encdec":
        return _init_encdec(cfg, key)
    raise ValueError(cfg.family)


def forward_logits(cfg: ModelConfig, params: Params, batch: dict,
                   caches=None, cache_pos=None, return_hidden: bool = False):
    """Train/prefill/decode logits (cache passthrough when given)."""
    if cfg.family in ("dense", "moe", "vlm"):
        return _decoder_lm_apply(cfg, params, batch["tokens"],
                                 patches=batch.get("patches"),
                                 caches=caches, cache_pos=cache_pos,
                                 return_hidden=return_hidden)
    if cfg.family == "ssm":
        return _ssm_lm_apply(cfg, params, batch["tokens"], caches=caches,
                             cache_pos=cache_pos,
                             return_hidden=return_hidden)
    if cfg.family == "hybrid":
        return _hybrid_lm_apply(cfg, params, batch["tokens"], caches=caches,
                                cache_pos=cache_pos,
                                return_hidden=return_hidden)
    if cfg.family == "encdec":
        return _encdec_apply(cfg, params, batch["tokens"],
                             frames=batch.get("frames"),
                             enc_out=(caches or {}).get("enc_out"),
                             caches=caches, cache_pos=cache_pos,
                             return_hidden=return_hidden)
    raise ValueError(cfg.family)


def train_loss(cfg: ModelConfig, params: Params, batch: dict):
    """Next-token loss (+ MTP auxiliary when configured).

    Computed from the final hidden states through the chunked-CE path so
    (B, S, vocab) logits are never materialized whole."""
    h, _ = forward_logits(cfg, params, batch, return_hidden=True)
    if cfg.family == "vlm":
        n_p = batch["patches"].shape[1]
        h_tok = h[:, n_p:, :]
    else:
        h_tok = h
    loss = chunked_ce_from_hidden(cfg, params, h_tok, batch["labels"],
                                  batch.get("loss_mask"))
    metrics = {"loss": loss}
    if cfg.mtp:
        mp = params["mtp"]
        emb_next = _embed(cfg, params, batch["labels"])
        cat = jnp.concatenate(
            [L.rms_norm(h, mp["norm"], cfg.norm_eps), emb_next], axis=-1)
        x2 = cat @ mp["proj"]
        b, l, _ = x2.shape
        positions = jnp.broadcast_to(jnp.arange(l, dtype=jnp.int32), (b, l))
        x2, _ = _decoder_layer(cfg, mp["layer"], x2, positions=positions,
                               cache=None, cache_pos=None, ffn="mlp")
        h2 = L.rms_norm(x2, mp["final_norm"], cfg.norm_eps)
        # position t predicts token t+2: pair h2[:, t] with labels[:, t+1];
        # pad + mask the last slot so the chunked CE keeps full length
        bsz, s = batch["labels"].shape
        labels_mtp = jnp.concatenate(
            [batch["labels"][:, 1:], batch["labels"][:, -1:]], axis=1)
        mask_mtp = jnp.concatenate(
            [jnp.ones((bsz, s - 1), jnp.float32),
             jnp.zeros((bsz, 1), jnp.float32)], axis=1)
        mtp_loss = chunked_ce_from_hidden(cfg, params, h2, labels_mtp,
                                          mask_mtp)
        metrics["mtp_loss"] = mtp_loss
        loss = loss + 0.3 * mtp_loss
        metrics["loss"] = loss
    return loss, metrics


# ------------------------------------------------------------ KV caches
def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               dtype=jnp.bfloat16, enc_len: int | None = None) -> Params:
    """Cache pytree matching forward_logits(caches=...) layout."""
    kv, dh = cfg.n_kv_eff, cfg.d_head

    def attn_cache(n_layers, length):
        return {"k": jnp.zeros((n_layers, batch, length, kv, dh), dtype),
                "v": jnp.zeros((n_layers, batch, length, kv, dh), dtype)}

    def mla_cache(n_layers, length):
        return {"c_kv": jnp.zeros((n_layers, batch, length,
                                   cfg.kv_lora_rank), dtype),
                "k_rope": jnp.zeros((n_layers, batch, length,
                                     cfg.qk_rope_dim), dtype)}

    def ssm_cache(n_layers):
        return {
            "conv_x": jnp.zeros((n_layers, batch, cfg.ssm_conv - 1,
                                 cfg.d_inner), dtype),
            "conv_bc": jnp.zeros((n_layers, batch, cfg.ssm_conv - 1,
                                  2 * cfg.ssm_groups * cfg.ssm_state), dtype),
            "ssd": jnp.zeros((n_layers, batch, cfg.ssm_heads,
                              cfg.ssm_headdim, cfg.ssm_state), jnp.float32),
        }

    if cfg.family in ("dense", "moe", "vlm"):
        total = max_len + (cfg.frontend_len if cfg.family == "vlm" else 0)
        n_main = cfg.n_layers - cfg.dense_prefix
        per = mla_cache if cfg.mla else attn_cache
        caches: Params = {"main": per(n_main, total)}
        if cfg.dense_prefix:
            caches["prefix"] = per(cfg.dense_prefix, total)
        return caches
    if cfg.family == "ssm":
        return {"main": ssm_cache(cfg.n_layers)}
    if cfg.family == "hybrid":
        n_inv = _n_attn_invocations(cfg)
        return {"main": ssm_cache(cfg.n_layers),
                "attn": attn_cache(n_inv, max_len)}
    if cfg.family == "encdec":
        el = enc_len or cfg.frontend_len
        return {"dec": {"self": attn_cache(cfg.n_layers, max_len),
                        "cross": attn_cache(cfg.n_layers, el)},
                "enc_out": jnp.zeros((batch, el, cfg.d_model), dtype)}
    raise ValueError(cfg.family)


def prefill(cfg: ModelConfig, params: Params, batch: dict, caches: Params):
    """Process the full prompt, return (last-position logits, caches)."""
    if cfg.family == "encdec":
        enc_out = _encode(cfg, params, batch["frames"])
        # precompute cross k/v per layer? stored as enc_out; cross attn
        # recomputes k/v from enc_out per step (compute/TPU tradeoff —
        # see DESIGN.md serving notes)
        caches = dict(caches)
        caches["enc_out"] = enc_out
    logits, caches = forward_logits(cfg, params, batch, caches=caches,
                                    cache_pos=None)
    return logits[:, -1:, :], caches


def decode_step(cfg: ModelConfig, params: Params, tokens: jax.Array,
                caches: Params, cache_pos):
    """One-token decode with a populated cache at position cache_pos."""
    logits, caches = forward_logits(cfg, params, {"tokens": tokens},
                                    caches=caches, cache_pos=cache_pos)
    return logits, caches
