"""Model + shape configuration.

One frozen dataclass drives every architecture in the zoo; per-arch
constructor modules live in :mod:`repro.configs`.  The four assigned
input shapes are global constants (per-arch applicability is resolved by
:func:`repro.launch.cells.enumerate_cells`).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # 'train' | 'prefill' | 'decode'


SHAPES: dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                 # dense|moe|ssm|hybrid|encdec|vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_head: int
    d_ff: int
    vocab: int

    # attention options
    qk_norm: bool = False
    qkv_bias: bool = False
    rope: bool = True           # whisper uses absolute sinusoid instead
    rope_theta: float = 1e6
    tie_embeddings: bool = False
    mlp_gelu: bool = False      # starcoder2/whisper: plain GELU MLP

    # MLA (deepseek-v3)
    mla: bool = False
    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_head_dim: int = 128

    # MoE
    n_experts: int = 0
    n_shared_experts: int = 0
    moe_top_k: int = 0
    d_expert: int = 0
    router: str = "softmax"     # softmax | sigmoid (deepseek-v3)
    capacity_factor: float = 1.25
    dense_prefix: int = 0       # first k layers dense (deepseek-v3: 3)
    dense_d_ff: int = 0         # d_ff of those dense layers

    # SSM / Mamba2
    ssm_state: int = 0
    ssm_headdim: int = 64
    ssm_chunk: int = 256
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_groups: int = 1

    # hybrid (zamba2): shared attention block every `hybrid_period` layers
    hybrid_period: int = 0

    # encoder-decoder / VLM stubs
    n_enc_layers: int = 0
    frontend: str = ""          # 'audio-frames' | 'vision-patches'
    frontend_len: int = 0       # 1500 frames / 256 patches

    # extra heads
    mtp: bool = False           # deepseek-v3 multi-token prediction

    # numerics / training shape
    optimizer: str = "adamw"    # huge configs use adafactor (DESIGN.md §5)
    attn_chunk: int = 1024      # query-chunked attention above this length
    ce_chunk: int = 2048        # chunked cross-entropy (0 = off)
    norm_eps: float = 1e-6
    param_dtype: str = "float32"
    activ_dtype: str = "float32"
    remat: bool = True
    grad_accum: int = 1         # microbatches per train step
    grad_accum_dtype: str = "float32"  # bf16 halves accumulator HBM (671B)

    # sharding: padded head counts (0 ⇒ unpadded); see sharding/rules.py
    pad_heads_to: int = 0
    kv_cache_mode: str = "auto"  # auto|heads|sequence|replicate

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_headdim

    @property
    def is_subquadratic(self) -> bool:
        return self.family in ("ssm", "hybrid")

    def _head_geometry(self) -> tuple[int, int, int, int]:
        """(h_eff, kv_eff, kv_factor, group_eff) for TP head padding.

        GQA: each real KV head is replicated ``kv_factor`` times
        consecutively; each replicated KV head serves ``group_eff`` query
        slots; real query heads fill the first ``n_heads//n_kv_heads``
        slots of each real-KV group, the rest are masked (inert).
        MHA: Q and KV pad together; padded heads masked.
        """
        h, kv, tp = self.n_heads, self.n_kv_heads, self.pad_heads_to
        if not tp or (h % tp == 0 and kv % tp == 0):
            return h, kv, 1, h // max(kv, 1)
        if kv == h:  # MHA
            h_eff = -(-h // tp) * tp
            return h_eff, h_eff, 1, 1
        if kv % tp == 0:
            kv_eff = kv
        elif tp % kv == 0:
            kv_eff = tp
        else:
            raise ValueError(
                f"{self.name}: kv={kv} and tp={tp} are not divisible "
                "either way — unsupported padding geometry")
        factor = kv_eff // kv
        g = h // kv
        g_eff = -(-g // factor)
        return kv_eff * g_eff, kv_eff, factor, g_eff

    @property
    def n_heads_eff(self) -> int:
        return self._head_geometry()[0]

    @property
    def n_kv_eff(self) -> int:
        return self._head_geometry()[1]

    @property
    def vocab_eff(self) -> int:
        """Vocab padded to 128 lanes (shards over any TP degree ≤128)."""
        return -(-self.vocab // 128) * 128

    def with_(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    def reduced(self, n_layers: int = 2, d_model: int = 64,
                vocab: int = 256, **kw) -> "ModelConfig":
        """Smoke-test sized version of the same family (see tests)."""
        scale = d_model / self.d_model
        upd = dict(
            n_layers=n_layers,
            d_model=d_model,
            n_heads=max(2, min(self.n_heads, 4)),
            n_kv_heads=max(1, min(self.n_kv_heads, 2)),
            d_head=16,
            d_ff=4 * d_model,
            vocab=vocab,
            q_lora_rank=32, kv_lora_rank=16, qk_nope_dim=16, qk_rope_dim=8,
            v_head_dim=16,
            grad_accum=1,
            remat=False,
        )
        if self.n_experts:
            upd.update(n_experts=8, moe_top_k=2, d_expert=2 * d_model,
                       dense_prefix=min(self.dense_prefix, 1),
                       dense_d_ff=4 * d_model,
                       n_shared_experts=min(self.n_shared_experts, 1))
        if self.ssm_state:
            upd.update(ssm_state=16, ssm_headdim=16, ssm_chunk=8)
        if self.hybrid_period:
            upd.update(hybrid_period=2, n_layers=max(n_layers, 4))
        if self.n_enc_layers:
            upd.update(n_enc_layers=2)
        if self.frontend_len:
            upd.update(frontend_len=8)
        upd.update(kw)
        return self.with_(**upd)

    # ----------------------------------------------------- analytics
    def param_count(self) -> int:
        """Approximate parameter count (embedding + layers + head)."""
        d, v = self.d_model, self.vocab
        n = v * d  # embed
        if not self.tie_embeddings:
            n += v * d
        per_layer = 0
        if self.family in ("dense", "vlm") or self.family == "encdec":
            if self.mla:
                per_layer += d * self.q_lora_rank
                per_layer += self.q_lora_rank * self.n_heads * (
                    self.qk_nope_dim + self.qk_rope_dim)
                per_layer += d * (self.kv_lora_rank + self.qk_rope_dim)
                per_layer += self.kv_lora_rank * self.n_heads * (
                    self.qk_nope_dim + self.v_head_dim)
                per_layer += self.n_heads * self.v_head_dim * d
            else:
                per_layer += d * self.n_heads * self.d_head      # q
                per_layer += 2 * d * self.n_kv_heads * self.d_head
                per_layer += self.n_heads * self.d_head * d      # o
            per_layer += (2 if self.mlp_gelu else 3) * d * self.d_ff
            n += self.n_layers * per_layer
            if self.family == "encdec":
                enc = 4 * d * self.n_heads * self.d_head + 3 * d * self.d_ff
                cross = 4 * d * self.n_heads * self.d_head
                n += self.n_enc_layers * enc + self.n_layers * cross
        elif self.family == "moe":
            if self.mla:
                attn = (d * self.q_lora_rank
                        + self.q_lora_rank * self.n_heads
                        * (self.qk_nope_dim + self.qk_rope_dim)
                        + d * (self.kv_lora_rank + self.qk_rope_dim)
                        + self.kv_lora_rank * self.n_heads
                        * (self.qk_nope_dim + self.v_head_dim)
                        + self.n_heads * self.v_head_dim * d)
            else:
                attn = (d * self.n_heads * self.d_head
                        + 2 * d * self.n_kv_heads * self.d_head
                        + self.n_heads * self.d_head * d)
            moe_l = (self.n_experts + self.n_shared_experts) * 3 * d * \
                self.d_expert + d * self.n_experts
            dense_l = 3 * d * (self.dense_d_ff or self.d_ff)
            n += self.dense_prefix * (attn + dense_l)
            n += (self.n_layers - self.dense_prefix) * (attn + moe_l)
        elif self.family in ("ssm", "hybrid"):
            di, ns, h = self.d_inner, self.ssm_state, self.ssm_heads
            per_layer = d * (2 * di + 2 * self.ssm_groups * ns + h)
            per_layer += di * d                                   # out proj
            per_layer += self.ssm_conv * (di + 2 * self.ssm_groups * ns)
            n += self.n_layers * per_layer
            if self.hybrid_period:
                shared = (4 * d * self.n_heads * self.d_head
                          + 3 * d * self.d_ff)
                n += shared  # shared block counted once
        return n

    def active_param_count(self) -> int:
        """Activated params per token (MoE: routed top-k + shared)."""
        if not self.n_experts:
            return self.param_count()
        full = self.param_count()
        moe_all = (self.n_layers - self.dense_prefix) * \
            self.n_experts * 3 * self.d_model * self.d_expert
        moe_act = (self.n_layers - self.dense_prefix) * \
            (self.moe_top_k * 3 * self.d_model * self.d_expert)
        return full - moe_all + moe_act
