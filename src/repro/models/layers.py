"""Layer primitives shared by the whole zoo.

Everything is a pure function ``(cfg, params, x, ...) -> y`` with explicit
parameter dicts, so layers stack cleanly under ``lax.scan`` and shard via
pjit param rules.  Attention logits and softmax run in fp32 regardless of
the activation dtype; matmuls use the config dtypes.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.ad_checkpoint import checkpoint_name

from ..sharding import constrain
from ..sharding.compat import shard_map_compat as _shard_map
from .config import ModelConfig

Params = dict[str, Any]


def dtype_of(cfg: ModelConfig):
    return jnp.dtype(cfg.activ_dtype)


def pdtype_of(cfg: ModelConfig):
    return jnp.dtype(cfg.param_dtype)


# ------------------------------------------------------------------- init
def _dense_init(key, shape, dtype, scale=None):
    fan_in = shape[0] if len(shape) >= 2 else 1
    scale = scale if scale is not None else fan_in ** -0.5
    return (jax.random.normal(key, shape) * scale).astype(dtype)


def init_norm(d: int, dtype) -> Params:
    return {"scale": jnp.ones((d,), dtype)}


# ------------------------------------------------------------------ norms
def rms_norm(x: jax.Array, p: Params, eps: float) -> jax.Array:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    y = x32 * jax.lax.rsqrt(jnp.mean(x32 * x32, axis=-1, keepdims=True) + eps)
    return (y * p["scale"].astype(jnp.float32)).astype(dt)


def rms_norm_gated(x: jax.Array, z: jax.Array, p: Params,
                   eps: float) -> jax.Array:
    """Mamba2's RMSNormGated: norm(x * silu(z))."""
    return rms_norm(x * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype),
                    p, eps)


# ------------------------------------------------------------------- rope
def rope_freqs(d: int, theta: float) -> np.ndarray:
    return 1.0 / (theta ** (np.arange(0, d, 2, dtype=np.float64) / d))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x (..., L, H, d) — rotate pairs (llama convention, fp32 math)."""
    d = x.shape[-1]
    freqs = jnp.asarray(rope_freqs(d, theta), jnp.float32)
    ang = positions.astype(jnp.float32)[..., None] * freqs  # (..., L, d/2)
    cos = jnp.cos(ang)[..., None, :]
    sin = jnp.sin(ang)[..., None, :]
    x32 = x.astype(jnp.float32)
    x1, x2 = jnp.split(x32, 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# -------------------------------------------------------------- attention
def _kv_param_heads(cfg: ModelConfig) -> int:
    """KV heads as stored in params.

    MHA (kv == heads): stored padded like Q (padded heads are masked).
    GQA (kv < heads): stored at the real count — replication to the
    sharded count happens in the forward pass so replicas stay tied
    (gradients sum over replicas ⇒ exact model math, see DESIGN.md).
    """
    if cfg.n_kv_heads == cfg.n_heads:
        return cfg.n_heads_eff
    return cfg.n_kv_heads


def init_attention(cfg: ModelConfig, key, cross: bool = False) -> Params:
    dt = pdtype_of(cfg)
    d, h, dh = cfg.d_model, cfg.n_heads_eff, cfg.d_head
    kvp = _kv_param_heads(cfg)
    ks = jax.random.split(key, 8)
    p: Params = {
        "wq": _dense_init(ks[0], (d, h, dh), dt),
        "wk": _dense_init(ks[1], (d, kvp, dh), dt),
        "wv": _dense_init(ks[2], (d, kvp, dh), dt),
        "wo": _dense_init(ks[3], (h, dh, d), dt,
                          scale=(h * dh) ** -0.5),
    }
    if cfg.qkv_bias and not cross:
        p["bq"] = jnp.zeros((h, dh), dt)
        p["bk"] = jnp.zeros((kvp, dh), dt)
        p["bv"] = jnp.zeros((kvp, dh), dt)
    if cfg.qk_norm:
        p["q_norm"] = init_norm(dh, dt)
        p["k_norm"] = init_norm(dh, dt)
    return p


def _head_mask(cfg: ModelConfig):
    """Zero padded query heads so TP head padding is mathematically inert.

    Layout (see ModelConfig._head_geometry): query slots are grouped per
    *real* KV head — ``kv_factor * group_eff`` slots each, of which the
    first ``n_heads // n_kv_heads`` are real.
    """
    h_eff, kv_eff, factor, g_eff = cfg._head_geometry()
    if h_eff == cfg.n_heads:
        return None
    if cfg.n_kv_heads == cfg.n_heads:  # MHA: padded tail
        return (jnp.arange(h_eff) < cfg.n_heads).astype(jnp.float32)
    g = cfg.n_heads // cfg.n_kv_heads
    per_group = factor * g_eff
    return jnp.tile((jnp.arange(per_group) < g),
                    cfg.n_kv_heads).astype(jnp.float32)


def _project_kv(cfg: ModelConfig, p: Params, x: jax.Array):
    """K/V projection to `n_kv_eff` heads.

    GQA with kv < TP degree: each real KV head is repeated
    ``n_kv_eff // n_kv_heads`` times *consecutively*, so query head i
    still attends to real KV head ``i // (n_heads // n_kv_heads)`` and
    the KV cache shards across the model axis.
    """
    k = jnp.einsum("bld,dkh->blkh", x, p["wk"])
    v = jnp.einsum("bld,dkh->blkh", x, p["wv"])
    if cfg.qkv_bias and "bk" in p:
        k = k + p["bk"]
        v = v + p["bv"]
    kvp = k.shape[2]
    if kvp != cfg.n_kv_eff:
        factor = cfg.n_kv_eff // kvp
        assert cfg.n_kv_eff % kvp == 0, (cfg.n_kv_eff, kvp)
        k = jnp.repeat(k, factor, axis=2)
        v = jnp.repeat(v, factor, axis=2)
    return k, v


def attention(cfg: ModelConfig, p: Params, x: jax.Array, *,
              positions: jax.Array, causal: bool = True,
              cache: Params | None = None, cache_pos=None,
              kv_x: jax.Array | None = None,
              window: int | None = None):
    """GQA attention with optional KV cache and cross-attention.

    cache: {"k","v"} (B, T, KV, dh); cache_pos: scalar int — current
    length (decode writes one token at cache_pos).  Returns (y, new_cache).
    """
    b, l, d = x.shape
    h, kv, dh = cfg.n_heads_eff, cfg.n_kv_eff, cfg.d_head
    q = jnp.einsum("bld,dhk->blhk", x, p["wq"])
    if cfg.qkv_bias and "bq" in p:
        q = q + p["bq"]
    src = x if kv_x is None else kv_x
    k, v = _project_kv(cfg, p, src)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    is_cross = kv_x is not None
    if not is_cross and cfg.rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)

    new_cache = None
    if cache is not None and not is_cross:
        off = cache_pos if l == 1 else 0
        k_all = jax.lax.dynamic_update_slice_in_dim(
            cache["k"], k.astype(cache["k"].dtype), off, axis=1)
        v_all = jax.lax.dynamic_update_slice_in_dim(
            cache["v"], v.astype(cache["v"].dtype), off, axis=1)
        new_cache = {"k": k_all, "v": v_all}
        k, v = k_all, v_all
    elif cache is not None and is_cross:
        if cache_pos is not None:
            # decode: reuse k/v precomputed at prefill
            k, v = cache["k"], cache["v"]
            new_cache = cache
        else:
            # prefill: populate the cross cache from the encoder output
            new_cache = {"k": k.astype(cache["k"].dtype),
                         "v": v.astype(cache["v"].dtype)}

    t = k.shape[1]
    g = h // kv
    qg = q.reshape(b, l, kv, g, dh)
    scale = dh ** -0.5

    key_pos = jnp.arange(t)
    if cache is not None and not is_cross:
        limit = (cache_pos + l) if cache_pos is not None else l
        valid = key_pos[None, :] < limit
    else:
        valid = jnp.ones((1, t), bool)

    def attend(qg_c, pos_c):
        """(b, lc, kv, g, dh) queries → (b, lc, kv, g, dh) context.

        Materializes only (lc, t) score tiles — query-chunked (flash-
        style) attention keeps prefill/train memory O(chunk·t), never
        O(seq²)."""
        lc = qg_c.shape[1]
        scores = jnp.einsum("blkgh,btkh->bklgt", qg_c,
                            k).astype(jnp.float32) * scale
        if causal and not is_cross:
            cmask = key_pos[None, None, :] <= pos_c[..., None]  # (b, lc, t)
            mask = cmask & valid[:, None, :]
        else:
            mask = jnp.broadcast_to(valid[:, None, :], (b, lc, t))
        if window is not None and causal and not is_cross:
            mask = mask & (key_pos[None, None, :]
                           > (pos_c[..., None] - window))
        scores = jnp.where(mask[:, None, :, None, :], scores, -1e30)
        w = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
        return jnp.einsum("bklgt,btkh->blkgh", w, v)

    chunk = cfg.attn_chunk
    if chunk and l > chunk and l % chunk == 0:
        nc = l // chunk
        qg_s = jnp.moveaxis(qg.reshape(b, nc, chunk, kv, g, dh), 1, 0)
        pos_s = jnp.moveaxis(positions.reshape(b, nc, chunk), 1, 0)
        # checkpoint: backward re-attends chunk-by-chunk instead of
        # keeping every chunk's (lc, t) score tile live at once
        body = jax.checkpoint(lambda _, xs: (None, attend(*xs)))
        _, ctx_s = jax.lax.scan(body, None, (qg_s, pos_s))
        ctx = jnp.moveaxis(ctx_s, 0, 1).reshape(b, l, h, dh)
    else:
        ctx = attend(qg, positions).reshape(b, l, h, dh)
    hm = _head_mask(cfg)
    if hm is not None:
        ctx = ctx * hm[None, None, :, None].astype(ctx.dtype)
    ctx = constrain(ctx, ("dp", None, "model", None))
    y = jnp.einsum("blhk,hkd->bld", ctx, p["wo"])
    y = checkpoint_name(y, "post_collective")
    return y, new_cache


# ------------------------------------------------------------ MLA (DSv3)
def init_mla(cfg: ModelConfig, key) -> Params:
    dt = pdtype_of(cfg)
    d, h = cfg.d_model, cfg.n_heads_eff
    qr, kr = cfg.q_lora_rank, cfg.kv_lora_rank
    dn, dr, dv = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    ks = jax.random.split(key, 8)
    return {
        "w_dq": _dense_init(ks[0], (d, qr), dt),
        "q_norm": init_norm(qr, dt),
        "w_uq": _dense_init(ks[1], (qr, h, dn + dr), dt),
        "w_dkv": _dense_init(ks[2], (d, kr + dr), dt),
        "kv_norm": init_norm(kr, dt),
        "w_uk": _dense_init(ks[3], (kr, h, dn), dt),
        "w_uv": _dense_init(ks[4], (kr, h, dv), dt),
        "wo": _dense_init(ks[5], (h, dv, d), dt, scale=(h * dv) ** -0.5),
    }


def mla_attention(cfg: ModelConfig, p: Params, x: jax.Array, *,
                  positions: jax.Array, cache: Params | None = None,
                  cache_pos=None, absorbed: bool | None = None):
    """DeepSeek-V3 Multi-head Latent Attention.

    Cache stores the *compressed* kv latent (B, T, kv_rank) + shared rope
    key (B, T, rope_dim) — the MLA memory saving.  ``absorbed`` selects
    the decode-time matmul absorption (w_uk folded into q, w_uv into out);
    defaults to True for single-token decode, False otherwise.
    """
    b, l, d = x.shape
    h = cfg.n_heads_eff
    dn, dr, dv = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    if absorbed is None:
        absorbed = l == 1 and cache is not None
    scale = (dn + dr) ** -0.5

    cq = rms_norm(x @ p["w_dq"], p["q_norm"], cfg.norm_eps)
    q = jnp.einsum("blr,rhk->blhk", cq, p["w_uq"])
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)

    dkv = x @ p["w_dkv"]
    c_kv = rms_norm(dkv[..., :cfg.kv_lora_rank], p["kv_norm"], cfg.norm_eps)
    k_rope = apply_rope(dkv[..., None, cfg.kv_lora_rank:], positions,
                        cfg.rope_theta)[:, :, 0]          # (b, l, dr)

    new_cache = None
    if cache is not None:
        off = cache_pos if l == 1 else 0
        ckv_all = jax.lax.dynamic_update_slice_in_dim(
            cache["c_kv"], c_kv.astype(cache["c_kv"].dtype), off, axis=1)
        kr_all = jax.lax.dynamic_update_slice_in_dim(
            cache["k_rope"], k_rope.astype(cache["k_rope"].dtype), off, axis=1)
        new_cache = {"c_kv": ckv_all, "k_rope": kr_all}
        c_kv, k_rope = ckv_all, kr_all
    t = c_kv.shape[1]

    key_pos = jnp.arange(t)
    limit = (cache_pos + l) if (cache is not None and cache_pos is not None) \
        else l if cache is not None else t
    valid = key_pos[None, :] < limit

    if not absorbed:
        k_nope = jnp.einsum("btr,rhk->bthk", c_kv, p["w_uk"])
        v_full = jnp.einsum("btr,rhv->bthv", c_kv, p["w_uv"])
        k_full = jnp.concatenate(
            [k_nope, jnp.broadcast_to(k_rope[:, :, None, :],
                                      (b, t, h, dr))], axis=-1)

    def attend(qn_c, qr_c, pos_c):
        """Query-chunked MLA attention: (b, lc, h, ·) → (b, lc, h, dv)."""
        lc = qn_c.shape[1]
        mask = ((key_pos[None, None, :] <= pos_c[..., None])
                & valid[:, None, :])[:, None, :, :]        # (b,1,lc,t)
        if absorbed:
            # fold w_uk into the query; score in latent (rank) space
            q_lat = jnp.einsum("blhk,rhk->blhr", qn_c, p["w_uk"])
            scores = (jnp.einsum("blhr,btr->bhlt", q_lat, c_kv)
                      + jnp.einsum("blhk,btk->bhlt", qr_c, k_rope)
                      ).astype(jnp.float32) * scale
            scores = jnp.where(mask, scores, -1e30)
            w = jax.nn.softmax(scores, axis=-1).astype(c_kv.dtype)
            ctx_lat = jnp.einsum("bhlt,btr->blhr", w, c_kv)
            return jnp.einsum("blhr,rhv->blhv", ctx_lat, p["w_uv"])
        qf = jnp.concatenate([qn_c, qr_c], axis=-1)
        scores = jnp.einsum("blhk,bthk->bhlt", qf,
                            k_full).astype(jnp.float32) * scale
        scores = jnp.where(mask, scores, -1e30)
        w = jax.nn.softmax(scores, axis=-1).astype(k_full.dtype)
        return jnp.einsum("bhlt,bthv->blhv", w, v_full)

    chunk = cfg.attn_chunk
    if chunk and l > chunk and l % chunk == 0:
        nc = l // chunk
        mv = lambda x: jnp.moveaxis(
            x.reshape((b, nc, chunk) + x.shape[2:]), 1, 0)
        body = jax.checkpoint(lambda _, xs: (None, attend(*xs)))
        _, ctx_s = jax.lax.scan(body, None,
                                (mv(q_nope), mv(q_rope), mv(positions)))
        ctx = jnp.moveaxis(ctx_s, 0, 1).reshape(b, l, h, cfg.v_head_dim)
    else:
        ctx = attend(q_nope, q_rope, positions)
    hm = _head_mask(cfg)
    if hm is not None:
        ctx = ctx * hm[None, None, :, None].astype(ctx.dtype)
    ctx = constrain(ctx, ("dp", None, "model", None))
    y = jnp.einsum("blhv,hvd->bld", ctx, p["wo"])
    y = checkpoint_name(y, "post_collective")
    return y, new_cache


# ---------------------------------------------------------------- MLP/MoE
def init_mlp(cfg: ModelConfig, key, d_ff: int | None = None,
             gelu: bool = False) -> Params:
    dt = pdtype_of(cfg)
    d = cfg.d_model
    f = d_ff or cfg.d_ff
    k1, k2 = jax.random.split(key)
    if gelu:
        return {"wi": _dense_init(k1, (d, f), dt),
                "wo": _dense_init(k2, (f, d), dt)}
    return {"wi": _dense_init(k1, (d, 2 * f), dt),
            "wo": _dense_init(k2, (f, d), dt)}


def mlp(cfg: ModelConfig, p: Params, x: jax.Array,
        gelu: bool = False) -> jax.Array:
    hp = x @ p["wi"]
    if gelu:
        hp = jax.nn.gelu(hp.astype(jnp.float32)).astype(x.dtype)
    else:
        gate, up = jnp.split(hp, 2, axis=-1)
        hp = jax.nn.silu(gate.astype(jnp.float32)).astype(x.dtype) * up
    hp = constrain(hp, ("dp", None, "model"))
    return checkpoint_name(hp @ p["wo"], "post_collective")


def init_moe(cfg: ModelConfig, key) -> Params:
    dt = pdtype_of(cfg)
    d, e, f = cfg.d_model, cfg.n_experts, cfg.d_expert
    ks = jax.random.split(key, 4)
    p: Params = {
        "router": _dense_init(ks[0], (d, e), jnp.float32, scale=d ** -0.5),
        "wi": _dense_init(ks[1], (e, d, 2 * f), dt),
        "wo": _dense_init(ks[2], (e, f, d), dt),
    }
    if cfg.n_shared_experts:
        p["shared"] = init_mlp(cfg, ks[3],
                               d_ff=cfg.n_shared_experts * f)
    return p


def _router_weights(cfg: ModelConfig, logits: jax.Array):
    """Top-k routing weights (N, k) and expert ids (N, k)."""
    if cfg.router == "sigmoid":          # deepseek-v3
        scores = jax.nn.sigmoid(logits)
        w, idx = jax.lax.top_k(scores, cfg.moe_top_k)
        w = w / (w.sum(-1, keepdims=True) + 1e-20)
    else:                                # qwen3: softmax then renormalize
        probs = jax.nn.softmax(logits, axis=-1)
        w, idx = jax.lax.top_k(probs, cfg.moe_top_k)
        w = w / (w.sum(-1, keepdims=True) + 1e-20)
    return w, idx


def _moe_ep_shardmap(cfg: ModelConfig, p: Params, x2: jax.Array,
                     mesh) -> jax.Array:
    """Expert-parallel MoE dispatch under shard_map.

    The pjit-auto formulation cannot partition the data-dependent
    gather/scatter of token dispatch — the SPMD partitioner replicates
    the (N·k, d) gathered tokens and emits a full-size all-reduce
    (measured: 224 GiB/device on deepseek-v3 prefill_32k).  Production
    MoE systems hand-write dispatch; so do we:

    * tokens stay on their data shard (activations are model-replicated,
      so no token exchange is needed at all);
    * each (data i, model m) device routes shard i's tokens to ITS
      e_loc = E/tp experts, packs them by inverse-map gather into an
      (e_loc, C, d) capacity buffer (never materializing (n·k, d)),
      runs the grouped SwiGLU GEMM, scatter-adds weighted outputs;
    * the combine is one psum over "model" (each token's k experts live
      on ≤k model shards).

    Capacity is enforced per (expert × data shard) — the standard EP
    behaviour.  Routing/top-k math is identical to :func:`moe`.
    """
    dp_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    tp = mesh.shape["model"]
    e, k = cfg.n_experts, cfg.moe_top_k
    assert e % tp == 0, (e, tp)
    e_loc = e // tp
    n = x2.shape[0]
    dp_size = 1
    for a in dp_axes:
        dp_size *= mesh.shape[a]
    n_loc = n // dp_size
    cap = int(np.ceil(cfg.capacity_factor * n_loc * k / e))
    cap = max(8, -(-cap // 8) * 8)
    d = x2.shape[1]

    def local(x_loc, router, wi_loc, wo_loc):
        m_idx = jax.lax.axis_index("model")
        y = _ep_local_compute(cfg, x_loc, router, wi_loc, wo_loc,
                              e_loc, m_idx, cap)
        return jax.lax.psum(y, "model")

    P_ = jax.sharding.PartitionSpec
    return _shard_map(
        local, mesh=mesh,
        in_specs=(P_(dp_axes or None, None), P_(None, None),
                  P_("model", None, None), P_("model", None, None)),
        out_specs=P_(dp_axes or None, None),
    )(x2, p["router"], p["wi"], p["wo"])


def _ep_local_compute(cfg, x_loc, router, wi_loc, wo_loc, e_loc, m_idx,
                      cap):
    """Per-device MoE dispatch → grouped GEMM → weighted combine.

    Inverse-map formulation: only (e_loc, C) int maps are scattered; the
    (n·k, d) gathered-token tensor is never materialized."""
    n_loc, d = x_loc.shape
    k = cfg.moe_top_k
    logits = x_loc.astype(jnp.float32) @ router
    w, idx = _router_weights(cfg, logits)              # (n_loc, k)
    rel = idx - m_idx * e_loc
    mine = (rel >= 0) & (rel < e_loc)
    flat_le = jnp.where(mine, rel, e_loc).reshape(-1)
    flat_w = (w * mine).reshape(-1)
    order = jnp.argsort(flat_le)
    se = flat_le[order]
    sw = flat_w[order]
    tok = order // k
    pos = jnp.arange(n_loc * k) - jnp.searchsorted(se, se, side="left")
    keep = (se < e_loc) & (pos < cap)
    src = jnp.full((e_loc + 1, cap + 1), n_loc, jnp.int32)
    src = src.at[jnp.where(keep, se, e_loc),
                 jnp.where(keep, pos, cap)].set(
        jnp.where(keep, tok, n_loc).astype(jnp.int32))
    wgt = jnp.zeros((e_loc + 1, cap + 1), jnp.float32)
    wgt = wgt.at[jnp.where(keep, se, e_loc),
                 jnp.where(keep, pos, cap)].set(jnp.where(keep, sw, 0.0))
    src_c, w_c = src[:e_loc, :cap], wgt[:e_loc, :cap]
    filled = (src_c < n_loc)[..., None].astype(x_loc.dtype)
    buf = x_loc[jnp.clip(src_c, 0, n_loc - 1)] * filled    # (e_loc, C, d)
    hgate = jnp.einsum("ecd,edf->ecf", buf, wi_loc)
    g, up = jnp.split(hgate, 2, axis=-1)
    hmid = jax.nn.silu(g.astype(jnp.float32)).astype(x_loc.dtype) * up
    out = jnp.einsum("ecf,efd->ecd", hmid, wo_loc)
    upd = (out * w_c[..., None].astype(out.dtype)).reshape(-1, d)
    y = jnp.zeros((n_loc, d), x_loc.dtype)
    return y.at[jnp.clip(src_c.reshape(-1), 0, n_loc - 1)].add(upd)


def _moe_ep_stationary(cfg: ModelConfig, p: Params, x2: jax.Array,
                       mesh) -> jax.Array:
    """Weights-stationary MoE for tiny token counts (decode).

    At decode, FSDP expert weights would be all-gathered over "data"
    *every layer, every token step* (measured 51 TB/step on
    deepseek-v3-671b decode_32k).  Inverting the movement: weights never
    move — wi stays sharded on its d (contraction) dim and wo on its f
    dim over "data"; the tiny token batch is feature-sharded in, and
    three small activation psums (router logits, hgate, combined output
    — MBs total) complete the contractions.  Capacity covers the whole
    global batch (n is tiny at decode).
    """
    dp_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    tp = mesh.shape["model"]
    data_size = mesh.shape.get("data", 1)
    e, k = cfg.n_experts, cfg.moe_top_k
    e_loc = e // tp
    n, d = x2.shape
    f = cfg.d_expert
    f_loc = f // data_size
    cap = int(np.ceil(cfg.capacity_factor * n * k / e))
    cap = max(8, -(-cap // 8) * 8)

    def local(x_sl, router_sl, wi_loc, wo_loc):
        m_idx = jax.lax.axis_index("model")
        d_idx = jax.lax.axis_index("data")
        # routing from feature-sliced tokens: partial logits + tiny psum
        logits = jax.lax.psum(x_sl.astype(jnp.float32) @ router_sl, "data")
        w, idx = _router_weights(cfg, logits)
        rel = idx - m_idx * e_loc
        mine = (rel >= 0) & (rel < e_loc)
        flat_le = jnp.where(mine, rel, e_loc).reshape(-1)
        flat_w = (w * mine).reshape(-1)
        order = jnp.argsort(flat_le)
        se, sw, tok = flat_le[order], flat_w[order], order // k
        pos = jnp.arange(n * k) - jnp.searchsorted(se, se, side="left")
        keep = (se < e_loc) & (pos < cap)
        src = jnp.full((e_loc + 1, cap + 1), n, jnp.int32)
        src = src.at[jnp.where(keep, se, e_loc),
                     jnp.where(keep, pos, cap)].set(
            jnp.where(keep, tok, n).astype(jnp.int32))
        wgt = jnp.zeros((e_loc + 1, cap + 1), jnp.float32)
        wgt = wgt.at[jnp.where(keep, se, e_loc),
                     jnp.where(keep, pos, cap)].set(jnp.where(keep, sw, 0.0))
        src_c, w_c = src[:e_loc, :cap], wgt[:e_loc, :cap]
        filled = (src_c < n)[..., None].astype(x_sl.dtype)
        buf = x_sl[jnp.clip(src_c, 0, n - 1)] * filled  # (e_loc, C, d/dp)
        # d-partial first GEMM + psum → full hgate (e_loc, C, 2f): ~MBs
        hgate = jax.lax.psum(
            jnp.einsum("ecd,edf->ecf", buf, wi_loc), "data")
        g, up = jnp.split(hgate, 2, axis=-1)
        hmid = jax.nn.silu(g.astype(jnp.float32)).astype(x_sl.dtype) * up
        hmid_sl = jax.lax.dynamic_slice(
            hmid, (0, 0, d_idx * f_loc), (e_loc, cap, f_loc))
        out = jnp.einsum("ecf,efd->ecd", hmid_sl, wo_loc)  # f-partial
        upd = (out * w_c[..., None].astype(out.dtype)).reshape(-1, d)
        y = jnp.zeros((n, d), x_sl.dtype)
        y = y.at[jnp.clip(src_c.reshape(-1), 0, n - 1)].add(upd)
        # NOT over "pod": pod replicas compute identical partials
        return jax.lax.psum(y, ("model", "data"))

    P_ = jax.sharding.PartitionSpec
    return _shard_map(
        local, mesh=mesh,
        in_specs=(P_(None, "data"), P_("data", None),
                  P_("model", "data", None), P_("model", "data", None)),
        out_specs=P_(None, None),
    )(x2, p["router"], p["wi"], p["wo"])


def moe(cfg: ModelConfig, p: Params, x: jax.Array) -> jax.Array:
    """Token-choice top-k MoE with sort-based capacity dispatch.

    Grouped-GEMM formulation: tokens are argsorted by expert, packed into
    an (E, C, d) buffer (capacity drop beyond C), expert SwiGLU runs as
    batched einsum (sharded over the "model" axis = expert parallelism),
    and outputs scatter-add back weighted by the router.

    Under an active mesh context the dispatch runs expert-parallel via
    :func:`_moe_ep_shardmap`; the single-device path below keeps the same
    routing math for tests and smoke runs.
    """
    b, l, d = x.shape
    n = b * l
    k = cfg.moe_top_k
    e = cfg.n_experts
    x2 = constrain(x.reshape(n, d), ("dp", None))

    from ..sharding.ctx import _mesh
    mesh = _mesh()
    if mesh is not None and "model" in mesh.axis_names \
            and e % mesh.shape["model"] == 0:
        dp_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
        dp_size = 1
        for a in dp_axes:
            dp_size *= mesh.shape[a]
        data_size = dict(mesh.shape).get("data", 1)
        stationary_ok = (
            n <= 2048 and "data" in mesh.axis_names
            and cfg.d_expert % data_size == 0
            and cfg.d_model % data_size == 0)
        if stationary_ok:
            # decode: tokens are tiny — move activations, never weights
            y2 = _moe_ep_stationary(cfg, p, x2, mesh)
            if cfg.n_shared_experts:
                y2 = y2 + mlp(cfg, p["shared"], x2)
            return y2.reshape(b, l, d)
        if n % max(dp_size, 1) == 0:
            y2 = _moe_ep_shardmap(cfg, p, x2, mesh)
            if cfg.n_shared_experts:
                y2 = y2 + mlp(cfg, p["shared"], x2)
            return y2.reshape(b, l, d)

    logits = (x2.astype(jnp.float32) @ p["router"])
    w, idx = _router_weights(cfg, logits)         # (n, k)

    # capacity rounded so the buffer's C dim shards over "data" (128 |
    # cap covers any dp degree); +128 spill region for dropped tokens
    cap = int(np.ceil(cfg.capacity_factor * n * k / e))
    cap = max(128, -(-cap // 128) * 128)
    cap_pad = cap + 128

    flat_e = idx.reshape(-1)                      # (n*k,)
    flat_w = w.reshape(-1)
    order = jnp.argsort(flat_e)
    se = flat_e[order]
    sw = flat_w[order]
    tok = order // k
    pos = jnp.arange(n * k) - jnp.searchsorted(se, se, side="left")
    keep = pos < cap
    slot = jnp.where(keep, pos, cap_pad - 1)      # dropped → spill slot
    gathered = constrain(x2[tok] * keep[:, None].astype(x.dtype),
                         ("dp", None))            # (n·k, d) stays sharded
    buf = jnp.zeros((e, cap_pad, d), x.dtype)
    buf = buf.at[se, slot].add(gathered)
    buf = constrain(buf, ("model", "dp", None))
    hgate = jnp.einsum("ecd,edf->ecf", buf, p["wi"])
    g, up = jnp.split(hgate, 2, axis=-1)
    hmid = constrain(jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * up,
                     ("model", "dp", None))
    out_buf = jnp.einsum("ecf,efd->ecd", hmid, p["wo"])
    out_buf = constrain(out_buf, ("model", "dp", None))
    vals = constrain(out_buf[se, slot] * (sw * keep)[:, None].astype(x.dtype),
                     ("dp", None))
    y2 = constrain(jnp.zeros((n, d), x.dtype).at[tok].add(vals),
                   ("dp", None))
    if cfg.n_shared_experts:
        y2 = y2 + mlp(cfg, p["shared"], x2)
    return y2.reshape(b, l, d)


# ----------------------------------------------------------- Mamba2 (SSD)
def init_mamba2(cfg: ModelConfig, key) -> Params:
    """Projections are stored separately (z/x shard over "model" with the
    SSM heads; B/C/dt are group-level and replicate) — see sharding rules."""
    dt = pdtype_of(cfg)
    d, di = cfg.d_model, cfg.d_inner
    g, ns, h = cfg.ssm_groups, cfg.ssm_state, cfg.ssm_heads
    ks = jax.random.split(key, 8)
    return {
        # z and x packed on an interleaved trailing axis: ONE matmul and —
        # critically — one backward dL/dx all-reduce instead of two
        # (§Perf-ssm iteration S2; interleaving keeps the di shards
        # aligned, unlike a [z|x] concat which would split across shards)
        "zx_proj": _dense_init(ks[0], (d, di, 2), dt),
        "b_proj": _dense_init(ks[2], (d, g * ns), dt),
        "c_proj": _dense_init(ks[3], (d, g * ns), dt),
        "dt_proj": _dense_init(ks[4], (d, h), dt),
        "conv_x": _dense_init(ks[5], (cfg.ssm_conv, di), dt, scale=0.5),
        "conv_bc": _dense_init(ks[6], (cfg.ssm_conv, 2 * g * ns), dt,
                               scale=0.5),
        "conv_b_x": jnp.zeros((di,), dt),
        "conv_b_bc": jnp.zeros((2 * g * ns,), dt),
        "a_log": jnp.zeros((h,), jnp.float32),
        "d_skip": jnp.ones((h,), jnp.float32),
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "gate_norm": init_norm(di, dt),
        "out_proj": _dense_init(ks[7], (di, d), dt),
    }


def _causal_conv(xbc: jax.Array, w: jax.Array, b: jax.Array,
                 state: jax.Array | None = None):
    """Depthwise causal conv1d, width K.  state: (B, K-1, C) carry."""
    k = w.shape[0]
    if state is None:
        pad = jnp.zeros((xbc.shape[0], k - 1, xbc.shape[2]), xbc.dtype)
    else:
        pad = state.astype(xbc.dtype)
    full = jnp.concatenate([pad, xbc], axis=1)
    out = sum(full[:, i:i + xbc.shape[1], :] * w[i] for i in range(k))
    new_state = full[:, -(k - 1):, :]
    return jax.nn.silu((out + b).astype(jnp.float32)).astype(xbc.dtype), \
        new_state


def ssd_chunked(xh, dt, a_neg, b_in, c_in, chunk: int, init_state=None):
    """Chunked state-space-duality scan (Mamba2 alg. 1).

    xh (B,L,H,P); dt (B,L,H) post-softplus; a_neg (H,) negative decay;
    b_in/c_in (B,L,G,N).  Returns (y (B,L,H,P), final_state (B,H,P,N)).

    Decay math (cumsum/exp) runs fp32; the quadratic intra-chunk and
    state einsums run in the input dtype (bf16 in production) with
    explicit head sharding pinned to "model" — without the constraints
    the SPMD partitioner repartitions the (B,nc,Q,Q,H) tensors through
    full all-reduces (§Perf-ssm iteration log).
    """
    bsz, l, h, p = xh.shape
    g, n = b_in.shape[2], b_in.shape[3]
    q = min(chunk, l)
    assert l % q == 0, (l, q)
    nc = l // q
    rep = h // g
    cdt = xh.dtype
    h_spec = ("dp", None, None, "model", None)

    def r(t):  # (B,L,...) → (B,nc,Q,...)
        return t.reshape((bsz, nc, q) + t.shape[2:])

    xc = constrain(r(xh), h_spec)
    dtc = r(dt)
    bc = constrain(jnp.repeat(r(b_in), rep, axis=3), h_spec)  # (B,nc,Q,H,N)
    cc = constrain(jnp.repeat(r(c_in), rep, axis=3), h_spec)
    a = dtc.astype(jnp.float32) * a_neg[None, None, None, :]  # (B,nc,Q,H) ≤0
    cum = jnp.cumsum(a, axis=2)
    # intra-chunk (quadratic within chunk)
    seg = cum[:, :, :, None, :] - cum[:, :, None, :, :]   # (B,nc,Qi,Qj,H)
    ii, jj = jnp.arange(q)[:, None], jnp.arange(q)[None, :]
    lmask = (ii >= jj)[None, None, :, :, None]
    decay = jnp.exp(jnp.where(lmask, seg, -jnp.inf))
    scores = jnp.einsum("bcihn,bcjhn->bcijh", cc, bc) \
        * (decay * dtc[:, :, None, :, :].astype(jnp.float32)).astype(cdt)
    scores = constrain(scores, ("dp", None, None, None, "model"))
    y_intra = jnp.einsum("bcijh,bcjhp->bcihp", scores, xc)
    # chunk summaries
    decay_end = jnp.exp(cum[:, :, -1:, :] - cum)            # (B,nc,Q,H)
    s_chunk = jnp.einsum("bcjhn,bcjh,bcjhp->bchpn", bc,
                         (decay_end * dtc.astype(jnp.float32)).astype(cdt),
                         xc)                                # (B,nc,H,P,N)
    a_total = jnp.exp(cum[:, :, -1, :]).astype(jnp.float32)  # (B,nc,H)

    def scan_fn(s, xs):
        s_c, at = xs
        out = s
        s_new = s * at[:, :, None, None] + s_c.astype(jnp.float32)
        return s_new, out

    s0 = (jnp.zeros((bsz, h, p, n), jnp.float32) if init_state is None
          else init_state.astype(jnp.float32))
    s_final, s_prev = jax.lax.scan(
        scan_fn, s0,
        (jnp.moveaxis(s_chunk, 1, 0), jnp.moveaxis(a_total, 1, 0)))
    s_prev = jnp.moveaxis(s_prev, 0, 1)                     # (B,nc,H,P,N)
    y_inter = jnp.einsum("bcihn,bchpn->bcihp",
                         cc * jnp.exp(cum)[..., None].astype(cdt),
                         s_prev.astype(cdt))
    y = (y_intra + y_inter.astype(y_intra.dtype)).reshape(bsz, l, h, p)
    return y, s_final


def mamba2(cfg: ModelConfig, p: Params, x: jax.Array, *,
           cache: Params | None = None, cache_pos=None):
    """Mamba2 block.  cache: {"conv_x": (B,K-1,di), "conv_bc": (B,K-1,2GN),
    "ssd": (B,H,P,N)}."""
    bsz, l, d = x.shape
    di, g, ns, h = cfg.d_inner, cfg.ssm_groups, cfg.ssm_state, cfg.ssm_heads
    hp = cfg.ssm_headdim
    zx = jnp.einsum("bld,dit->blit", x, p["zx_proj"])
    z, xs_raw = zx[..., 0], zx[..., 1]
    bc_raw = jnp.concatenate([x @ p["b_proj"], x @ p["c_proj"]], axis=-1)
    dt = x @ p["dt_proj"]
    xs, new_conv_x = _causal_conv(
        xs_raw, p["conv_x"], p["conv_b_x"],
        None if cache is None else cache["conv_x"])
    bc, new_conv_bc = _causal_conv(
        bc_raw, p["conv_bc"], p["conv_b_bc"],
        None if cache is None else cache["conv_bc"])
    b_in, c_in = jnp.split(bc, 2, axis=-1)
    xh = xs.reshape(bsz, l, h, hp)
    b_in = b_in.reshape(bsz, l, g, ns)
    c_in = c_in.reshape(bsz, l, g, ns)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    a_neg = -jnp.exp(p["a_log"])

    if l == 1 and cache is not None:
        # recurrent decode step
        s = cache["ssd"]
        rep = h // g
        bh = jnp.repeat(b_in[:, 0], rep, axis=1)           # (B,H,N)
        ch = jnp.repeat(c_in[:, 0], rep, axis=1)
        da = jnp.exp(dt[:, 0] * a_neg[None, :])            # (B,H)
        s_new = s * da[:, :, None, None] + jnp.einsum(
            "bhn,bh,bhp->bhpn", bh, dt[:, 0], xh[:, 0].astype(jnp.float32))
        y = jnp.einsum("bhn,bhpn->bhp", ch, s_new)[:, None]
        s_final = s_new.astype(s.dtype)
    else:
        pad = -l % cfg.ssm_chunk if l > cfg.ssm_chunk else 0
        if pad:
            pd = lambda t: jnp.pad(t, [(0, 0), (0, pad)] +
                                   [(0, 0)] * (t.ndim - 2))
            xh, dt, b_in, c_in = pd(xh), pd(dt), pd(b_in), pd(c_in)
        init_state = None if cache is None else cache["ssd"]
        y, s_final = ssd_chunked(xh, dt, a_neg, b_in, c_in,
                                 cfg.ssm_chunk, init_state)
        if pad:
            y = y[:, :l]
    y = y + xh[:, :l].astype(y.dtype) * p["d_skip"][None, None, :, None]
    y = y.reshape(bsz, l, di).astype(x.dtype)
    y = rms_norm_gated(y, z, p["gate_norm"], cfg.norm_eps)
    out = checkpoint_name(y @ p["out_proj"], "post_collective")
    new_cache = None
    if cache is not None:
        new_cache = {"conv_x": new_conv_x.astype(cache["conv_x"].dtype),
                     "conv_bc": new_conv_bc.astype(cache["conv_bc"].dtype),
                     "ssd": s_final.astype(cache["ssd"].dtype)}
    return out, new_cache
