"""starcoder2-7b — dense GQA + RoPE [arXiv:2402.19173; hf]."""
from ..models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="starcoder2-7b", family="dense",
        n_layers=32, d_model=4608, n_heads=36, n_kv_heads=4, d_head=128,
        d_ff=18432, vocab=49152,
        rope_theta=1e5, mlp_gelu=True,
        grad_accum=2,
    )
