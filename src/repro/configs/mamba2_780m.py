"""mamba2-780m — attention-free SSD [arXiv:2405.21060; unverified]."""
from ..models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="mamba2-780m", family="ssm",
        n_layers=48, d_model=1536, n_heads=1, n_kv_heads=1, d_head=1,
        d_ff=0, vocab=50280,
        ssm_state=128, ssm_headdim=64, ssm_chunk=256,
        tie_embeddings=True,
    )
