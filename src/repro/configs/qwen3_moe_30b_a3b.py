"""qwen3-moe-30b-a3b — 128 experts, top-8 [hf:Qwen/Qwen3-30B-A3B; hf]."""
from ..models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-moe-30b-a3b", family="moe",
        n_layers=48, d_model=2048, n_heads=32, n_kv_heads=4, d_head=128,
        d_ff=768, vocab=151936,
        qk_norm=True, rope_theta=1e6,
        n_experts=128, moe_top_k=8, d_expert=768,
        grad_accum=2,
    )
