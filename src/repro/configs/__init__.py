"""Architecture registry: one module per assigned architecture.

``get_config(name)`` returns the exact published configuration;
``get_config(name, reduced=True)`` returns the smoke-test-sized config of
the same family.  ``--arch <id>`` in the launchers resolves here.
"""
from __future__ import annotations

import importlib

from ..models.config import ModelConfig

ARCHS = (
    "qwen3-0.6b",
    "deepseek-coder-33b",
    "qwen1.5-110b",
    "starcoder2-7b",
    "zamba2-7b",
    "internvl2-76b",
    "mamba2-780m",
    "whisper-large-v3",
    "qwen3-moe-30b-a3b",
    "deepseek-v3-671b",
)


def get_config(name: str, *, reduced: bool = False, **overrides) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; known: {ARCHS}")
    mod = importlib.import_module(
        f"repro.configs.{name.replace('-', '_').replace('.', '_')}")
    cfg: ModelConfig = mod.config()
    if reduced:
        cfg = cfg.reduced()
    if overrides:
        cfg = cfg.with_(**overrides)
    return cfg
