"""zamba2-7b — Mamba2 backbone + shared attention blocks
[arXiv:2411.15242; unverified]."""
from ..models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="zamba2-7b", family="hybrid",
        n_layers=81, d_model=3584, n_heads=32, n_kv_heads=32, d_head=112,
        d_ff=14336, vocab=32000,
        ssm_state=64, ssm_headdim=64, ssm_chunk=256,
        hybrid_period=6,
        grad_accum=2,
    )
