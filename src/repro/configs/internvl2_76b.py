"""internvl2-76b — InternViT (stub) + LLaMA-3-70B-class backbone
[arXiv:2404.16821; unverified]."""
from ..models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="internvl2-76b", family="vlm",
        n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8, d_head=128,
        d_ff=28672, vocab=128256,
        rope_theta=5e5,
        frontend="vision-patches", frontend_len=256,
        grad_accum=8,
    )
