"""whisper-large-v3 — enc-dec, conv frontend stubbed to precomputed
frames [arXiv:2212.04356; unverified]."""
from ..models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="whisper-large-v3", family="encdec",
        n_layers=32, n_enc_layers=32,
        d_model=1280, n_heads=20, n_kv_heads=20, d_head=64,
        d_ff=5120, vocab=51866,
        rope=False,
        frontend="audio-frames", frontend_len=1500,
    )
