"""deepseek-v3-671b — MLA, 1 shared + 256 routed top-8, MTP
[arXiv:2412.19437; hf]."""
from ..models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v3-671b", family="moe",
        n_layers=61, d_model=7168, n_heads=128, n_kv_heads=128, d_head=128,
        d_ff=2048, vocab=129280,
        mla=True, q_lora_rank=1536, kv_lora_rank=512,
        qk_nope_dim=128, qk_rope_dim=64, v_head_dim=128,
        n_experts=256, n_shared_experts=1, moe_top_k=8, d_expert=2048,
        router="sigmoid", dense_prefix=3, dense_d_ff=18432,
        mtp=True,
        optimizer="adafactor",
        grad_accum=16, grad_accum_dtype="bfloat16",
    )
