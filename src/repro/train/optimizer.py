"""Optimizers as pure pytree transforms (no optax dependency).

* ``adamw``     — fp32 moments; states shard exactly like params (FSDP),
  so ZeRO-style optimizer sharding falls out of the sharding rules.
* ``adafactor`` — factored second moment (Shazeer & Stern), no first
  moment: optimizer-state HBM for deepseek-v3-671b drops from ~8
  bytes/param to O(rows+cols), which is what lets 671B train on one
  v5e pod (DESIGN.md §5 memory budget).

Both support decoupled weight decay and update clipping.  States are
flat lists parallel to ``jax.tree.leaves(params)`` — trivially
checkpointable and shardable with the param specs.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class Optimizer:
    name: str
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any, jax.Array], tuple[Any, Any]]
    # update(grads, state, params, step) -> (new_params, new_state)


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(tree)))


def make_adamw(lr: float = 3e-4, b1: float = 0.9, b2: float = 0.95,
               eps: float = 1e-8, weight_decay: float = 0.1,
               clip_norm: float = 1.0) -> Optimizer:
    def init(params):
        return {"m": [jnp.zeros(p.shape, jnp.float32)
                      for p in jax.tree.leaves(params)],
                "v": [jnp.zeros(p.shape, jnp.float32)
                      for p in jax.tree.leaves(params)]}

    def update(grads, state, params, step):
        gnorm = global_norm(grads)
        scale = jnp.minimum(1.0, clip_norm / (gnorm + 1e-9))
        t = (step + 1).astype(jnp.float32)
        c1, c2 = 1 - b1 ** t, 1 - b2 ** t
        leaves_g, treedef = jax.tree.flatten(grads)
        leaves_p = treedef.flatten_up_to(params)
        new_p, new_m, new_v = [], [], []
        for g, p, m, v in zip(leaves_g, leaves_p, state["m"], state["v"]):
            g = g.astype(jnp.float32) * scale
            m2 = b1 * m + (1 - b1) * g
            v2 = b2 * v + (1 - b2) * g * g
            u = (m2 / c1) / (jnp.sqrt(v2 / c2) + eps)
            u = u + weight_decay * p.astype(jnp.float32)
            new_p.append((p.astype(jnp.float32) - lr * u).astype(p.dtype))
            new_m.append(m2)
            new_v.append(v2)
        return treedef.unflatten(new_p), {"m": new_m, "v": new_v}

    return Optimizer("adamw", init, update)


def make_adafactor(lr: float = 1e-3, decay: float = 0.8,
                   eps: float = 1e-30, clip_threshold: float = 1.0,
                   weight_decay: float = 0.0) -> Optimizer:
    """Factored RMS scaling; β₂ anneals as 1 − t^−decay (paper schedule)."""

    def _factored(shape) -> bool:
        return len(shape) >= 2

    def init(params):
        def one(p):
            if _factored(p.shape):
                return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                        "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:],
                                        jnp.float32)}
            return {"v": jnp.zeros(p.shape, jnp.float32)}
        return {"stats": [one(p) for p in jax.tree.leaves(params)]}

    def update(grads, state, params, step):
        t = (step + 1).astype(jnp.float32)
        beta2 = 1.0 - t ** (-decay)
        leaves_g, treedef = jax.tree.flatten(grads)
        leaves_p = treedef.flatten_up_to(params)
        new_p, new_s = [], []
        for g, p, s in zip(leaves_g, leaves_p, state["stats"]):
            g = g.astype(jnp.float32)
            g2 = g * g + eps
            if _factored(g.shape):
                vr = beta2 * s["vr"] + (1 - beta2) * g2.mean(axis=-1)
                vc = beta2 * s["vc"] + (1 - beta2) * g2.mean(axis=-2)
                rfac = (vr / jnp.maximum(
                    vr.mean(axis=-1, keepdims=True), eps))[..., None]
                u = g * jax.lax.rsqrt(rfac * vc[..., None, :] + eps)
                ns = {"vr": vr, "vc": vc}
            else:
                v = beta2 * s["v"] + (1 - beta2) * g2
                u = g * jax.lax.rsqrt(v + eps)
                ns = {"v": v}
            rms = jnp.sqrt(jnp.mean(u * u) + eps)
            u = u / jnp.maximum(1.0, rms / clip_threshold)
            if weight_decay:
                u = u + weight_decay * p.astype(jnp.float32)
            new_p.append((p.astype(jnp.float32) - lr * u).astype(p.dtype))
            new_s.append(ns)
        return treedef.unflatten(new_p), {"stats": new_s}

    return Optimizer("adafactor", init, update)


def make_optimizer(name: str, **kw) -> Optimizer:
    if name == "adamw":
        return make_adamw(**kw)
    if name == "adafactor":
        return make_adafactor(**kw)
    raise ValueError(name)
