"""Training substrate: optimizers, train step (grad-accum + remat),
gradient compression, fault-tolerant loop."""
from .optimizer import make_optimizer  # noqa: F401
from .train_step import make_train_step  # noqa: F401
