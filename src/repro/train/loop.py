"""Fault-tolerant training loop.

Production posture (simulated single-host, identical code path):

* auto-resume from the newest valid checkpoint (elastic: mesh may differ);
* async checkpoint every ``ckpt_every`` steps, off the critical path;
* preemption handling — a signal file (or SIGTERM on real pods) triggers
  checkpoint-and-exit;
* straggler mitigation — per-step wall-clock deadline; overruns are
  logged and counted (on real pods this feeds the slow-host eviction
  policy; here it feeds tests);
* deterministic data — the token pipeline is keyed by (seed, step,
  shard), so restarts replay exactly.
"""
from __future__ import annotations

import dataclasses
import os
import time
from typing import Any, Callable

import numpy as np

from ..checkpoint.store import CheckpointStore
from ..models.config import ModelConfig


@dataclasses.dataclass
class LoopConfig:
    total_steps: int = 100
    ckpt_every: int = 20
    ckpt_dir: str = "/tmp/repro_ckpt"
    step_deadline_s: float = 0.0      # 0 = disabled
    preempt_file: str = ""            # touch this file to simulate SIGTERM
    log_every: int = 10


@dataclasses.dataclass
class LoopResult:
    final_step: int
    resumed_from: int | None
    straggler_steps: int
    preempted: bool
    losses: list


def run_training(cfg: ModelConfig, loop: LoopConfig, *,
                 params: Any, opt_state: Any,
                 step_fn: Callable, batch_fn: Callable[[int], dict],
                 shardings: tuple | None = None,
                 log: Callable[[str], None] = print) -> LoopResult:
    """Drive step_fn with checkpoint/restart/preemption semantics.

    ``step_fn(params, opt_state, batch, step_idx) -> (params, opt, metrics)``
    ``batch_fn(step) -> batch dict`` (deterministic per step).
    """
    store = CheckpointStore(loop.ckpt_dir)
    resumed_from = None
    start = 0
    restored = store.restore_latest((params, opt_state),
                                    shardings)
    if restored is not None:
        start, (params, opt_state), manifest = restored
        resumed_from = start
        log(f"[loop] resumed from step {start}"
            f" (mesh-independent manifest: {manifest.get('mesh', 'n/a')})")

    stragglers = 0
    preempted = False
    losses = []
    step = start
    for step in range(start, loop.total_steps):
        t0 = time.perf_counter()
        batch = batch_fn(step)
        params, opt_state, metrics = step_fn(params, opt_state, batch,
                                             np.int32(step))
        loss = float(metrics["loss"])
        losses.append(loss)
        dt = time.perf_counter() - t0
        if loop.step_deadline_s and dt > loop.step_deadline_s:
            stragglers += 1
            log(f"[loop] step {step}: straggler ({dt:.3f}s > "
                f"{loop.step_deadline_s:.3f}s deadline)")
        if loop.log_every and step % loop.log_every == 0:
            log(f"[loop] step {step}: loss={loss:.4f} ({dt:.3f}s)")
        done = step + 1
        if loop.ckpt_every and done % loop.ckpt_every == 0:
            store.save_async(done, (params, opt_state),
                             {"config": cfg.name})
        if loop.preempt_file and os.path.exists(loop.preempt_file):
            log(f"[loop] preemption signal at step {done}; checkpointing")
            store.wait()
            store.save(done, (params, opt_state), {"config": cfg.name})
            preempted = True
            break
    store.wait()
    final = step + 1 if (start < loop.total_steps) else start
    return LoopResult(final, resumed_from, stragglers, preempted, losses)
