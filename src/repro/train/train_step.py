"""The train step: grad accumulation, fp32 grad accumulators, optional
int8-compressed data-parallel gradient reduction.

Gradient flow under pjit: the batch is sharded over DP and params over
(FSDP "data" × TP "model"), so XLA emits reduce-scatters for the gradient
reduction automatically — overlapped with the backward scan.  Gradient
*accumulation* (``cfg.grad_accum``) runs as a ``lax.scan`` over
microbatches with an fp32 accumulator, which bounds activation memory for
the 100B+ configs (memory budget in DESIGN.md §5).
"""
from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp

from ..models import transformer as T
from ..models.config import ModelConfig
from .optimizer import Optimizer, global_norm


def _split_micro(batch: dict, ga: int) -> dict:
    def r(x):
        b = x.shape[0]
        assert b % ga == 0, (b, ga)
        return x.reshape((ga, b // ga) + x.shape[1:])
    return {k: r(v) for k, v in batch.items()}


def grads_and_metrics(cfg: ModelConfig, params: Any, batch: dict):
    """Accumulated fp32 grads + mean loss over microbatches."""
    ga = max(cfg.grad_accum, 1)

    def loss_fn(p, mb):
        loss, metrics = T.train_loss(cfg, p, mb)
        return loss, metrics

    if ga == 1:
        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, batch)
        grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
        return grads, metrics

    micro = _split_micro(batch, ga)
    acc_dt = jnp.dtype(cfg.grad_accum_dtype)

    def body(acc, mb):
        (loss, metrics), g = jax.value_and_grad(
            loss_fn, has_aux=True)(params, mb)
        acc = jax.tree.map(
            lambda a, gi: a + (gi.astype(jnp.float32) / ga).astype(acc_dt),
            acc, g)
        return acc, metrics

    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, acc_dt), params)
    grads, metrics = jax.lax.scan(body, zeros, micro)
    grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
    metrics = jax.tree.map(lambda m: m.mean(), metrics)
    return grads, metrics


def make_train_step(cfg: ModelConfig, opt: Optimizer,
                    compress: Callable | None = None):
    """Returns step(params, opt_state, batch, step_idx) → (p, s, metrics).

    ``compress``: optional gradient-compression transform (see
    train/compression.py) applied between grad computation and the
    optimizer — used in pure-DP replicated mode.
    """

    def step(params, opt_state, batch, step_idx):
        grads, metrics = grads_and_metrics(cfg, params, batch)
        if compress is not None:
            grads, opt_state = compress(grads, opt_state)
        metrics = dict(metrics)
        metrics["grad_norm"] = global_norm(grads)
        new_params, new_opt = opt.update(grads, opt_state, params, step_idx)
        # carry non-optimizer state (e.g. compression error feedback)
        for k, v in opt_state.items():
            if k not in new_opt:
                new_opt[k] = v
        return new_params, new_opt, metrics

    return step
