"""int8 gradient compression with error feedback (pure-DP mode).

At 1000+ nodes the cross-pod (DCN) gradient all-reduce dominates; int8
quantization with per-tensor scale cuts it 4× vs fp32 accumulators.
Residual quantization error is carried in an error-feedback buffer so the
*expected* update is unbiased (Seide et al. / EF-SGD).

This transform operates on the gradient pytree *before* the optimizer.
In replicated-DP deployments the quantize→psum→dequantize runs inside
``shard_map`` over the DP axes (``compressed_psum``); under FSDP the
reduction is XLA-managed, so only the quantize/dequantize (with error
feedback) is applied — still exercising the numerics path end to end.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp


def quantize_int8(g: jax.Array) -> tuple[jax.Array, jax.Array]:
    scale = jnp.maximum(jnp.abs(g).max(), 1e-12) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def make_error_feedback_compressor():
    """Returns (init_state_fn, compress_fn) for the train step."""

    def init(params):
        return {"ef": [jnp.zeros(p.shape, jnp.float32)
                       for p in jax.tree.leaves(params)]}

    def compress(grads, opt_state):
        leaves, treedef = jax.tree.flatten(grads)
        efs = opt_state["compression"]["ef"]
        out, new_ef = [], []
        for g, e in zip(leaves, efs):
            g32 = g.astype(jnp.float32) + e
            q, scale = quantize_int8(g32)
            deq = dequantize_int8(q, scale)
            new_ef.append(g32 - deq)
            out.append(deq)
        opt_state = dict(opt_state)
        opt_state["compression"] = {"ef": new_ef}
        return treedef.unflatten(out), opt_state

    return init, compress


def compressed_psum(g: jax.Array, axis_name: str) -> jax.Array:
    """int8 all-reduce (inside shard_map): quantize → psum int32 → scale.

    The per-shard scales are maxed across the axis so the int32 sum is
    exact in the shared scale.
    """
    scale = jnp.maximum(jnp.abs(g).max(), 1e-12) / 127.0
    scale = jax.lax.pmax(scale, axis_name)
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int32)
    total = jax.lax.psum(q, axis_name)
    return total.astype(jnp.float32) * scale
