"""Production mesh definition.

A function, not a module-level constant: importing this module never
touches jax device state (the dry-run sets the placeholder device count
before any jax initialization).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 = 256 chips/pod; multi-pod adds a leading 2-pod axis.

    Axes: "data" carries DP+FSDP, "model" carries TP/EP, "pod" composes
    with "data" for hierarchical data parallelism (gradient reduction over
    ICI within a pod, DCN across pods).
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model: int = 1):
    """Debug mesh over whatever devices exist (tests, examples)."""
    n = len(jax.devices())
    assert n % model == 0
    return jax.make_mesh((n // model, model), ("data", "model"))
