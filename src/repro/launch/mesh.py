"""Production mesh definition.

A function, not a module-level constant: importing this module never
touches jax device state (the dry-run sets the placeholder device count
before any jax initialization).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 = 256 chips/pod; multi-pod adds a leading 2-pod axis.

    Axes: "data" carries DP+FSDP, "model" carries TP/EP, "pod" composes
    with "data" for hierarchical data parallelism (gradient reduction over
    ICI within a pod, DCN across pods).
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model: int = 1):
    """Debug mesh over whatever devices exist (tests, examples)."""
    n = len(jax.devices())
    if model < 1 or n % model != 0:
        # a real error, not an assert: asserts vanish under ``python -O``
        raise ValueError(
            f"cannot build host mesh: {n} devices not divisible by "
            f"model={model}")
    return jax.make_mesh((n // model, model), ("data", "model"))


def make_filter_mesh(n_parts: int | None = None, *, data_shards: int = 1):
    """2-D ``("data", "model")`` mesh for filtering: both scaling axes.

    The paper's scalability argument (§3.5) is replication in *two*
    dimensions: profiles are spread across chips AND the document stream
    is fanned across replicas.  The software form is one mesh:

    * ``"model"`` — the query axis.  A
      :class:`repro.core.engines.base.ShardedPlan` stacks per-part tables
      on a leading axis and ``shard_map``\\ s them over ``"model"``, so
      each device advances only its slice of the subscription set.
    * ``"data"`` — the document axis.  ``filter_batch_sharded2d`` /
      ``filter_bytes_sharded2d`` partition the batch (``EventBatch`` /
      ``ByteBatch``) rows over ``"data"``, so each replica row of the
      mesh sees only its slice of the document stream.

    ``data_shards`` is a *request*: it is shrunk to the largest value
    that divides the device count, so any setting is placeable on any
    host (1 device ⇒ a ``(1, 1)`` mesh; the degenerate shapes are what
    the CI device-count matrix exercises).  The remaining devices form
    the ``"model"`` axis; ``n_parts`` (when given) shrinks that axis to
    the largest count dividing the part count — e.g. 6 parts on 4
    devices yields a 3-wide model axis, never an error.
    """
    n = len(jax.devices())
    if data_shards < 1:
        raise ValueError(f"data_shards must be >= 1, got {data_shards}")
    if n_parts is not None and n_parts < 1:
        raise ValueError(f"n_parts must be >= 1, got {n_parts}")
    data = min(int(data_shards), n)
    while n % data != 0:
        data -= 1
    model = n // data
    if n_parts is not None:
        while n_parts % model != 0:
            model -= 1
    return jax.make_mesh((data, model), ("data", "model"))
