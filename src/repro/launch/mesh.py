"""Production mesh definition.

A function, not a module-level constant: importing this module never
touches jax device state (the dry-run sets the placeholder device count
before any jax initialization).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 = 256 chips/pod; multi-pod adds a leading 2-pod axis.

    Axes: "data" carries DP+FSDP, "model" carries TP/EP, "pod" composes
    with "data" for hierarchical data parallelism (gradient reduction over
    ICI within a pod, DCN across pods).
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model: int = 1):
    """Debug mesh over whatever devices exist (tests, examples)."""
    n = len(jax.devices())
    if model < 1 or n % model != 0:
        # a real error, not an assert: asserts vanish under ``python -O``
        raise ValueError(
            f"cannot build host mesh: {n} devices not divisible by "
            f"model={model}")
    return jax.make_mesh((n // model, model), ("data", "model"))


def make_filter_mesh(n_parts: int | None = None):
    """1-D mesh for query-sharded filtering: every device on ``"model"``.

    The filtering stack scales along the *query* axis (the paper's
    profiles-across-chips replication, §3.5): a
    :class:`repro.core.engines.base.ShardedPlan` stacks per-part tables
    on a leading axis and ``shard_map``\\ s them over this mesh's
    ``"model"`` axis, so each device advances only its slice of the
    subscription set while documents are replicated.

    ``n_parts`` (when given) shrinks the mesh to the largest device
    count that divides the part count, so any partition is placeable —
    e.g. 6 parts on 4 devices yields a 3-device mesh, never an error.
    """
    n = len(jax.devices())
    if n_parts is not None:
        if n_parts < 1:
            raise ValueError(f"n_parts must be >= 1, got {n_parts}")
        while n_parts % n != 0:
            n -= 1
    return jax.make_mesh((n,), ("model",))
