"""Training driver: real steps on the host devices (CPU here, TPU pods in
production — same code path, different mesh).

Features demonstrated end to end:
  * ``--arch <id> --reduced`` — any zoo architecture at smoke scale;
  * ``--data-filter`` — the paper's XML filter as the ingest stage:
    documents are matched against standing profiles and routed to data
    shards before byte-tokenization (repro/data/filter_stage.py);
  * fault tolerance — checkpoints, auto-resume, preemption file,
    straggler deadline (repro/train/loop.py).

Usage::

  PYTHONPATH=src python -m repro.launch.train --arch qwen3-0.6b --reduced \
      --steps 50 --data-filter --ckpt-dir /tmp/ck
"""
import argparse

import jax

from repro.configs import ARCHS, get_config
from repro.core.dictionary import TagDictionary
from repro.data.filter_stage import FilterStage
from repro.data.generator import DTD, gen_corpus, gen_profiles
from repro.data.tokens import TokenPipeline, XMLBytePipeline
from repro.models import transformer as T
from repro.train.loop import LoopConfig, run_training
from repro.train.optimizer import make_optimizer
from repro.train.train_step import make_train_step


def build_filtered_pipeline(batch: int, seq_len: int, log=print,
                            ingest: str = "events"):
    """Pub-sub ingest: generate docs, filter by profiles, route shard 0.

    ``ingest='bytes'`` serializes the corpus to raw wire bytes first and
    runs the whole filter on device (``XMLBytePipeline.from_filtered_bytes``
    → ``FilterStage.route_bytes``) — the paper's same-chip parse+filter
    feeding LM training.
    """
    from repro.core.events import encode_bytes

    dtd = DTD.generate(n_tags=24, seed=0)
    d = TagDictionary()
    dtd.register(d)
    profiles = gen_profiles(dtd, n=64, length=4, seed=0)
    docs = gen_corpus(dtd, n_docs=64, nodes_per_doc=300, seed=0)
    stage = FilterStage(profiles, d, n_shards=1, engine="levelwise")
    if ingest == "bytes":
        # serialize with the stage's TEXT_FILL so recorded byte volumes
        # (and therefore MB/s) are comparable with the event path, which
        # charges TEXT_FILL synthetic bytes per element in its stats
        from repro.data.filter_stage import TEXT_FILL

        payloads = [encode_bytes(doc, text_fill=TEXT_FILL) for doc in docs]
        pipe = XMLBytePipeline.from_filtered_bytes(payloads, stage,
                                                   batch=batch,
                                                   seq_len=seq_len)
        log(f"[train] device-ingest filter kept "
            f"{len(pipe.payloads)}/{len(docs)} documents")
        return pipe
    kept = []
    for routed in stage.route(docs):
        kept += [r.doc_index for r in routed]
    kept = sorted(set(kept))
    log(f"[train] filter stage kept {len(kept)}/{len(docs)} documents "
        f"(selectivity {stage.selectivity(docs):.3f})")
    return XMLBytePipeline([docs[i] for i in kept], batch=batch,
                           seq_len=seq_len)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b", choices=list(ARCHS))
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--data-filter", action="store_true")
    ap.add_argument("--data-ingest", default="events",
                    choices=("events", "bytes"),
                    help="with --data-filter: host-parsed events or raw "
                         "bytes parsed+filtered on device")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--preempt-file", default="")
    ap.add_argument("--d-model", type=int, default=0,
                    help="override reduced d_model (e.g. ~100M: 768)")
    ap.add_argument("--layers", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch, reduced=args.reduced)
    overrides = {}
    if args.d_model:
        overrides["d_model"] = args.d_model
        overrides["d_ff"] = 4 * args.d_model
    if args.layers:
        overrides["n_layers"] = args.layers
    if args.data_filter:
        overrides["vocab"] = 256  # byte-level over XML stream
    if overrides:
        cfg = cfg.with_(**overrides)
    print(f"[train] {cfg.name} ({cfg.param_count()/1e6:.1f}M params), "
          f"{len(jax.devices())} device(s)")

    params = T.init_model(cfg, jax.random.PRNGKey(0))
    opt = make_optimizer(cfg.optimizer, lr=args.lr)
    opt_state = opt.init(params)
    step = jax.jit(make_train_step(cfg, opt), donate_argnums=(0, 1))

    if args.data_filter:
        pipe = build_filtered_pipeline(args.batch, args.seq_len,
                                       ingest=args.data_ingest)
    else:
        pipe = TokenPipeline(vocab=cfg.vocab, batch=args.batch,
                             seq_len=args.seq_len, seed=0)

    loop = LoopConfig(total_steps=args.steps, ckpt_every=args.ckpt_every,
                      ckpt_dir=args.ckpt_dir,
                      preempt_file=args.preempt_file, log_every=10)
    result = run_training(cfg, loop, params=params, opt_state=opt_state,
                          step_fn=step, batch_fn=pipe.batch_at)
    print(f"[train] done at step {result.final_step}; "
          f"loss {result.losses[0]:.3f} → {result.losses[-1]:.3f}"
          + (f" (resumed from {result.resumed_from})"
             if result.resumed_from else ""))


if __name__ == "__main__":
    main()
