"""Cell enumeration: (architecture × input shape) → dry-run spec.

40 cells total (10 archs × 4 shapes).  ``long_500k`` is runnable only for
the sub-quadratic families (ssm/hybrid); full-attention archs record a
documented SKIP (DESIGN.md §Arch-applicability) — still emitted so the
roofline table shows all 40 rows.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from ..configs import ARCHS, get_config
from ..models.config import SHAPES, ModelConfig, ShapeSpec


@dataclass(frozen=True)
class Cell:
    arch: str
    shape: ShapeSpec
    runnable: bool
    skip_reason: str = ""

    @property
    def name(self) -> str:
        return f"{self.arch}__{self.shape.name}"


def enumerate_cells() -> list[Cell]:
    cells = []
    for arch in ARCHS:
        cfg = get_config(arch)
        for sname, shape in SHAPES.items():
            if sname == "long_500k" and not cfg.is_subquadratic:
                cells.append(Cell(arch, shape, False,
                                  "full quadratic attention at 524k context"
                                  " — skipped per assignment"))
            else:
                cells.append(Cell(arch, shape, True))
    return cells


def dryrun_config(arch: str, pad_heads_to: int = 16) -> ModelConfig:
    """Full config in production numerics (bf16, remat, padded heads)."""
    return get_config(arch).with_(
        param_dtype="bfloat16", activ_dtype="bfloat16",
        pad_heads_to=pad_heads_to, remat=True)


def batch_struct(cfg: ModelConfig, shape: ShapeSpec) -> dict:
    """ShapeDtypeStruct stand-ins for every model input (no allocation)."""
    b, s = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    bf16 = jnp.bfloat16
    out = {
        "tokens": jax.ShapeDtypeStruct((b, s), i32),
        "labels": jax.ShapeDtypeStruct((b, s), i32),
    }
    if cfg.family == "vlm":
        out["patches"] = jax.ShapeDtypeStruct(
            (b, cfg.frontend_len, cfg.d_model), bf16)
    if cfg.family == "encdec":
        out["frames"] = jax.ShapeDtypeStruct(
            (b, cfg.frontend_len, cfg.d_model), bf16)
    return out


def serve_batch_struct(cfg: ModelConfig, shape: ShapeSpec) -> dict:
    """Prefill inputs: the request batch (no labels)."""
    out = batch_struct(cfg, shape)
    out.pop("labels")
    return out


def decode_tokens_struct(shape: ShapeSpec) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32)


def model_flops(cfg: ModelConfig, shape: ShapeSpec) -> float:
    """Useful model FLOPs for the roofline's MODEL_FLOPS row.

    train:   6·N_active·D   (fwd+bwd)
    prefill: 2·N_active·D
    decode:  2·N_active·B   (one token per sequence)
    """
    n = cfg.active_param_count()
    if shape.kind == "train":
        d = shape.global_batch * shape.seq_len
        return 6.0 * n * d
    if shape.kind == "prefill":
        d = shape.global_batch * shape.seq_len
        return 2.0 * n * d
    return 2.0 * n * shape.global_batch
