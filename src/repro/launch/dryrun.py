import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST be the first lines: jax locks the device count on first init.
# Placeholder CPU devices let jax.make_mesh build the production meshes
# (16×16 single-pod, 2×16×16 multi-pod) for lowering + compilation only —
# nothing is ever allocated (ShapeDtypeStruct stand-ins everywhere).

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell this driver:
  1. builds the full-size config (bf16, padded heads, remat, grad-accum),
  2. constructs abstract params / optimizer state / caches / batch
     (``jax.eval_shape`` — no allocation),
  3. jits the real train/prefill/decode step with explicit in/out
     shardings from :mod:`repro.sharding.rules`,
  4. ``.lower().compile()`` on the production mesh,
  5. prints ``memory_analysis()`` / ``cost_analysis()`` and writes a JSON
     artifact with trip-count-aware FLOPs / traffic / collective wire
     bytes (``hlo_analysis``) for §Roofline.

Usage::

  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-0.6b \
      --shape train_4k [--multipod] [--out artifacts/dryrun]
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multipod]
"""
import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.launch.cells import (Cell, batch_struct, decode_tokens_struct,
                                dryrun_config, enumerate_cells, model_flops,
                                serve_batch_struct)
from repro.launch.hlo_analysis import analyze_text
from repro.launch.mesh import make_production_mesh
from repro.models import transformer as T
from repro.models.config import SHAPES
from repro.sharding import mesh_context
from repro.sharding import rules as R
from repro.train.optimizer import make_optimizer
from repro.train.train_step import make_train_step

HBM_PER_CHIP = 16 * 1024 ** 3  # v5e


def _named(mesh, spec_tree):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P))


def opt_state_specs(opt_name: str, params_shape, pspecs, mesh):
    """Optimizer-state specs mirroring the param specs (ZeRO via FSDP)."""
    pleaves = jax.tree.leaves(params_shape)
    sleaves = jax.tree.leaves(pspecs, is_leaf=lambda x: isinstance(x, P))
    assert len(pleaves) == len(sleaves)
    if opt_name == "adamw":
        return {"m": list(sleaves), "v": list(sleaves)}
    stats = []
    for p, s in zip(pleaves, sleaves):
        t = tuple(s) + (None,) * (len(p.shape) - len(tuple(s)))
        if len(p.shape) >= 2:
            stats.append({"vr": P(*t[:-1]), "vc": P(*(t[:-2] + t[-1:]))})
        else:
            stats.append({"v": P(*t)})
    return {"stats": stats}


def build_cell(cell: Cell, mesh):
    """Returns (jitted_fn, abstract_args) for the cell's step."""
    cfg = dryrun_config(cell.arch)
    shape = cell.shape
    params_shape = jax.eval_shape(
        lambda: T.init_model(cfg, jax.random.PRNGKey(0)))
    pspecs = R.param_specs(cfg, params_shape, mesh)
    psh = _named(mesh, pspecs)
    repl = NamedSharding(mesh, P())

    if shape.kind == "train":
        opt = make_optimizer(cfg.optimizer)
        opt_shape = jax.eval_shape(opt.init, params_shape)
        osh = _named(mesh, opt_state_specs(cfg.optimizer, params_shape,
                                           pspecs, mesh))
        batch = batch_struct(cfg, shape)
        bsh = _named(mesh, R.batch_specs(cfg, batch, mesh))
        step = make_train_step(cfg, opt)
        fn = jax.jit(step,
                     in_shardings=(psh, osh, bsh, repl),
                     out_shardings=(psh, osh, None),
                     donate_argnums=(0, 1))
        args = (params_shape, opt_shape, batch,
                jax.ShapeDtypeStruct((), jnp.int32))
        return cfg, fn, args

    max_len = shape.seq_len
    cache_shape = jax.eval_shape(
        lambda: T.init_cache(cfg, shape.global_batch, max_len,
                             dtype=jnp.bfloat16))
    csh = _named(mesh, R.cache_specs(cfg, cache_shape, mesh))

    if shape.kind == "prefill":
        batch = serve_batch_struct(cfg, shape)
        bsh = _named(mesh, R.batch_specs(cfg, batch, mesh))

        def prefill_fn(params, b, caches):
            return T.prefill(cfg, params, b, caches)

        fn = jax.jit(prefill_fn, in_shardings=(psh, bsh, csh),
                     out_shardings=(None, csh), donate_argnums=(2,))
        return cfg, fn, (params_shape, batch, cache_shape)

    # decode: one new token against a full cache
    tokens = decode_tokens_struct(shape)
    tsh = _named(mesh, R.batch_specs(cfg, {"tokens": tokens},
                                     mesh))["tokens"]

    def decode_fn(params, tok, caches, pos):
        return T.decode_step(cfg, params, tok, caches, pos)

    fn = jax.jit(decode_fn, in_shardings=(psh, tsh, csh, repl),
                 out_shardings=(None, csh), donate_argnums=(2,))
    args = (params_shape, tokens, cache_shape,
            jax.ShapeDtypeStruct((), jnp.int32))
    return cfg, fn, args


def run_cell(cell: Cell, *, multi_pod: bool, out_dir: str,
             save_hlo: bool = False) -> dict:
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    chips = 512 if multi_pod else 256
    art = {"cell": cell.name, "arch": cell.arch, "shape": cell.shape.name,
           "mesh": mesh_name, "chips": chips}
    if not cell.runnable:
        art["status"] = "skip"
        art["error"] = cell.skip_reason
        return art
    t0 = time.time()
    try:
        mesh = make_production_mesh(multi_pod=multi_pod)
        with mesh_context(mesh):
            cfg, fn, args = build_cell(cell, mesh)
            lowered = fn.lower(*args)
            compiled = lowered.compile()
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        print(f"[{cell.name} @ {mesh_name}] memory_analysis:", mem)
        print(f"[{cell.name} @ {mesh_name}] cost_analysis flops:",
              cost.get("flops"), "bytes:", cost.get("bytes accessed"))
        txt = compiled.as_text()
        if save_hlo:
            with open(os.path.join(out_dir, cell.name + "." + mesh_name
                                   + ".hlo.txt"), "w") as f:
                f.write(txt)
        ana = analyze_text(txt)
        per_dev_hbm = (mem.argument_size_in_bytes + mem.temp_size_in_bytes
                       + mem.output_size_in_bytes - mem.alias_size_in_bytes)
        art.update({
            "status": "ok",
            "compile_s": round(time.time() - t0, 1),
            "flops": ana["flops_per_device"] * chips,
            "bytes_accessed": ana["traffic_bytes_per_device"] * chips,
            "collective_bytes": ana["collective_bytes_per_device"] * chips,
            "collective_breakdown": ana["collective_breakdown"],
            "xla_cost_flops_per_dev": cost.get("flops"),
            "memory_analysis": {
                "argument_B": mem.argument_size_in_bytes,
                "output_B": mem.output_size_in_bytes,
                "temp_B": mem.temp_size_in_bytes,
                "alias_B": mem.alias_size_in_bytes,
            },
            "per_device_hbm_peak": per_dev_hbm,
            "fits_hbm_16g": bool(per_dev_hbm <= HBM_PER_CHIP),
            "model_flops": model_flops(dryrun_config(cell.arch),
                                       cell.shape),
            "hlo_chars": len(txt),
        })
    except Exception as e:  # noqa: BLE001 — record the failure, keep sweeping
        art["status"] = "error"
        art["error"] = f"{type(e).__name__}: {e}"
        art["traceback"] = traceback.format_exc()[-4000:]
        art["compile_s"] = round(time.time() - t0, 1)
    return art


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES))
    ap.add_argument("--multipod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--save-hlo", action="store_true")
    ap.add_argument("--out", default="artifacts/dryrun")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    cells = enumerate_cells()
    if not args.all:
        cells = [c for c in cells
                 if (args.arch is None or c.arch == args.arch)
                 and (args.shape is None or c.shape.name == args.shape)]
    ok = True
    for cell in cells:
        art = run_cell(cell, multi_pod=args.multipod, out_dir=args.out,
                       save_hlo=args.save_hlo)
        mesh_name = art["mesh"]
        path = os.path.join(args.out, f"{cell.name}.{mesh_name}.json")
        with open(path, "w") as f:
            json.dump(art, f, indent=1)
        status = art["status"]
        extra = (f" compile={art.get('compile_s')}s"
                 f" hbm/dev={art.get('per_device_hbm_peak', 0)/2**30:.2f}GiB"
                 if status == "ok" else f" ({art.get('error', '')[:120]})")
        print(f"[dryrun] {cell.name} @ {mesh_name}: {status}{extra}",
              flush=True)
        ok &= status in ("ok", "skip")
    raise SystemExit(0 if ok else 1)


if __name__ == "__main__":
    main()
