"""Serving driver with pub-sub request routing — the paper's use case,
end to end.

Requests carry XML payloads; standing profiles (subscriptions) route each
request to a model replica (the paper's "deliver to interested
subscribers"), then the selected replica generates a response with the
batched serve engine.  The filter runs the TPU levelwise engine — on a
real deployment this sits on the same chips as the model, the paper's
"parser and filter on the same chip eliminates communication" argument.

``--ingest bytes`` serves *raw wire bytes*: payloads arrive as
paper-format byte strings and are parsed on device
(``FilterStage.route_bytes``), so routing runs bytes → verdict with no
per-event host Python — the full same-chip dataflow.  ``--ingest
events`` is the pre-parsed host path.

``--data-shards N`` turns on the second scaling axis: the stage builds
a 2-D ``("data", "model")`` mesh, documents are fanned over the
``"data"`` axis while each device keeps its slice of the subscription
set, and byte ingest runs the async K-deep pipelined serve loop
(``FilterStage.route_bytes_pipelined``: the ``device_put`` of the next
batches overlaps the filter step on batch k).

``--arrival {poisson,burst,replay}`` switches the routing step from the
fixed-request-list driver to the *continuous* serve loop
(:class:`repro.serve.loop.ServeLoop`): requests are submitted on a
seeded arrival trace, admitted against a bounded queue
(``--queue-cap``, ``--overload shed|block``), batched adaptively
(``--batch`` size or ``--deadline-ms``, whichever fires first), run up
to ``--max-inflight`` batches deep, and delivered in order — then the
SLO summary (p50/p99/p999 bytes→verdict latency, shed rate, batch fill,
backpressure waits) is printed and optionally written to
``--latency-json`` with the full latency histogram.

Usage::

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-0.6b --reduced \
      --requests 32 --replicas 2 --ingest bytes --query-shards 2 \
      --data-shards 2
  PYTHONPATH=src python -m repro.launch.serve --requests 64 \
      --arrival burst --rate 800 --deadline-ms 10 --max-inflight 4 \
      --queue-cap 32 --latency-json serve_latency.json
"""
import argparse
import json
import time

import jax
import numpy as np

from repro.configs import ARCHS, get_config
from repro.core import engines
from repro.core.dictionary import TagDictionary
from repro.core.events import encode_bytes
from repro.data.filter_stage import TEXT_FILL, FilterStage
from repro.data.generator import DTD, gen_corpus, gen_profiles
from repro.models import transformer as T
from repro.serve.engine import ServeEngine
from repro.serve.loop import OVERLOAD_POLICIES, ServeLoop, make_arrivals, run_trace


def build_stage(n_replicas: int, *, engine: str = "levelwise",
                batch_size: int = 8, query_shards: int = 1,
                data_shards: int = 1, seed: int = 0,
                plan_cache: str | None = None):
    """The serving driver's pub-sub routing layer, as a reusable piece.

    Deterministic for a given ``seed`` (the CLI smoke tests rebuild it
    to assert routed-output parity against ``main``'s printed queues).
    Returns ``(stage, dtd)`` — the workload generator is needed again
    for payloads and churn profiles.  ``plan_cache`` points the engine
    at a persistent :class:`~repro.checkpoint.PlanCache` directory so a
    restart skips plan recompilation (cold-start recovery).
    """
    dtd = DTD.generate(n_tags=24, seed=seed)
    d = TagDictionary()
    dtd.register(d)
    profiles = gen_profiles(dtd, n=32, length=3, seed=seed)
    opts = {"plan_cache": plan_cache} if plan_cache else {}
    # the stage builds its own ("data", "model") mesh when sharded
    stage = FilterStage(profiles, d, n_shards=n_replicas, engine=engine,
                        keep_unmatched=True, batch_size=batch_size,
                        query_shards=query_shards, data_shards=data_shards,
                        engine_options=opts)
    return stage, dtd


def route_requests(stage: FilterStage, payloads, *, ingest: str = "events",
                   raw=None) -> list[list[int]]:
    """Fan requests out to replica queues through the stage.

    ``ingest="bytes"`` routes ``raw`` wire payloads — through the async
    double-buffered loop when the stage has a 2-D data axis, the plain
    device-ingest path otherwise.
    """
    queues: list[list[int]] = [[] for _ in range(stage.n_shards)]
    if ingest == "bytes":
        routed_batches = (stage.route_bytes_pipelined(raw)
                          if stage.data_shards > 1 else
                          stage.route_bytes(raw))
    else:
        routed_batches = stage.route(payloads)
    for routed in routed_batches:
        for r in routed:
            queues[r.shard].append(r.doc_index)
    return queues


def serve_continuous(stage: FilterStage, raw: list[bytes],
                     args) -> tuple[list[list[int]], dict]:
    """Drive the continuous serve loop over a seeded arrival trace.

    Returns ``(queues, slo)`` — per-replica delivery queues (identical
    to what the batch driver routes when nothing is shed, the loop's
    semantics-vs-schedule contract) and the SLO summary dict.
    """
    deliveries: list = []
    arrivals = make_arrivals(args.arrival, len(raw), rate_hz=args.rate,
                             seed=args.seed)
    loop = ServeLoop(stage, max_batch=args.batch,
                     deadline_ms=args.deadline_ms,
                     queue_cap=args.queue_cap,
                     max_inflight=args.max_inflight,
                     overload=args.overload,
                     deliver=deliveries.append)
    with loop:
        run_trace(loop, raw, arrivals)
    slo = loop.slo_summary()
    queues: list[list[int]] = [[] for _ in range(stage.n_shards)]
    for routed in deliveries:
        for r in routed:
            queues[r.shard].append(r.doc_index)
    if args.latency_json:
        payload = {"arrival": args.arrival, "rate_hz": args.rate,
                   "deadline_ms": args.deadline_ms,
                   "queue_cap": args.queue_cap,
                   "max_inflight": args.max_inflight,
                   "overload": args.overload, "slo": slo,
                   "swaps": loop.swap_summary(),
                   "dead_letter": [
                       {"seq": r["seq"], "error": r["error"],
                        "message": r["message"]}
                       for r in loop.dead_letter],
                   "histogram": loop.latency_histogram(),
                   "latencies_ms": loop.latencies_ms().tolist()}
        with open(args.latency_json, "w") as f:
            json.dump(payload, f, indent=1)
    return queues, slo


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b", choices=list(ARCHS))
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--replicas", type=int, default=2)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen-len", type=int, default=8)
    ap.add_argument("--filter-engine", default="levelwise",
                    choices=list(engines.names()),
                    help="pub-sub routing engine (any registered engine)")
    ap.add_argument("--ingest", default="events",
                    choices=("events", "bytes"),
                    help="request payload form: pre-parsed event streams "
                         "(host parse) or raw wire bytes parsed on device")
    ap.add_argument("--query-shards", type=int, default=1,
                    help="partition the subscription set into this many "
                         "parts run as one stacked program over the mesh "
                         "'model' axis (1 = monolithic plan)")
    ap.add_argument("--data-shards", type=int, default=1,
                    help="fan the document stream over this many mesh "
                         "'data' replicas (2-D data × model program with "
                         "the async K-deep pipelined byte-ingest loop; "
                         "shrinks to what the host can place)")
    ap.add_argument("--arrival", default=None,
                    choices=("poisson", "burst", "replay"),
                    help="serve CONTINUOUSLY: submit requests on this "
                         "seeded arrival trace through the admission-"
                         "controlled serve loop and print the SLO "
                         "summary (default: the batch driver)")
    ap.add_argument("--rate", type=float, default=500.0,
                    help="arrival rate in req/s (burst: the ON-window "
                         "rate; mean is a quarter of it)")
    ap.add_argument("--deadline-ms", type=float, default=10.0,
                    help="adaptive batching: close a batch this long "
                         "after it opens even if under --batch size")
    ap.add_argument("--max-inflight", type=int, default=2,
                    help="K-deep pipelining: dispatched-but-undelivered "
                         "batches held in flight (2 = double buffer)")
    ap.add_argument("--queue-cap", type=int, default=64,
                    help="admission control: bound on the ingest queue; "
                         "arrivals beyond it are shed or block")
    ap.add_argument("--overload", default="shed",
                    choices=OVERLOAD_POLICIES,
                    help="overload policy at --queue-cap: shed the "
                         "arrival or block the producer")
    ap.add_argument("--seed", type=int, default=0,
                    help="arrival-trace seed (workload seeds are fixed)")
    ap.add_argument("--latency-json", default=None, metavar="PATH",
                    help="write the SLO summary + latency histogram "
                         "JSON here (the CI serve job's artifact)")
    ap.add_argument("--plan-cache", default=None, metavar="DIR",
                    help="persistent compiled-plan cache directory: "
                         "restarts with the same subscription set skip "
                         "plan recompilation (crash-recovery cold start)")
    args = ap.parse_args()

    cfg = get_config(args.arch, reduced=args.reduced).with_(vocab=256)
    params = T.init_model(cfg, jax.random.PRNGKey(0))
    replica_engines = [ServeEngine(cfg, params, batch=args.batch,
                                   max_len=args.prompt_len + args.gen_len + 4)
                       for _ in range(args.replicas)]

    # pub-sub routing layer: profiles → replicas
    stage, dtd = build_stage(args.replicas, engine=args.filter_engine,
                             batch_size=args.batch,
                             query_shards=args.query_shards,
                             data_shards=args.data_shards,
                             plan_cache=args.plan_cache)
    payloads = gen_corpus(dtd, n_docs=args.requests, nodes_per_doc=60,
                          seed=1)

    # serialization is request *arrival* (real deployments receive bytes),
    # so it happens outside the routing timer; the continuous loop is
    # always a bytes service — wire payloads are what arrives
    raw = ([encode_bytes(doc, text_fill=TEXT_FILL) for doc in payloads]
           if args.ingest == "bytes" or args.arrival else None)
    t0 = time.perf_counter()
    if args.arrival:
        queues, slo = serve_continuous(stage, raw, args)
        ingest_label = f"bytes, {args.arrival} arrivals"
    else:
        queues = route_requests(stage, payloads, ingest=args.ingest, raw=raw)
        slo = None
        ingest_label = f"{args.ingest} ingest"
    t_route = time.perf_counter() - t0
    tp = stage.throughput()
    print(f"[serve] routed {args.requests} requests ({ingest_label}) → "
          f"{[len(q) for q in queues]} per replica ({t_route*1e3:.1f} ms; "
          f"{tp['engine']}×{tp['query_shards']}: "
          f"{tp['docs_per_s']:.0f} docs/s, {tp['mb_per_s']:.2f} MB/s)")
    if slo is not None:
        print(f"[serve] SLO bytes→verdict: p50 {slo['p50_ms']:.2f} ms, "
              f"p99 {slo['p99_ms']:.2f} ms, p999 {slo['p999_ms']:.2f} ms "
              f"({slo['completed']}/{slo['arrived']} served at "
              f"{slo['served_per_s']:.0f}/s, shed {slo['shed']} = "
              f"{slo['shed_rate']:.1%})")
        if slo.get("quarantined") or slo.get("failed"):
            print(f"[serve] faults: {slo['quarantined']} quarantined "
                  f"({slo['rejected']} pre-admission), "
                  f"{slo['failed']} failed, {slo['retries']} retries, "
                  f"dead-letter depth {slo['dead_letter_depth']}")
        print(f"[serve] loop: {slo['batches']} batches "
              f"(fill {slo['batch_fill']:.2f}; {slo['size_closes']} size / "
              f"{slo['deadline_closes']} deadline / "
              f"{slo['flush_closes']} flush closes), max queue depth "
              f"{slo['max_queue_depth']}/{args.queue_cap}, "
              f"{slo['backpressure_waits']} backpressure waits at "
              f"K={args.max_inflight}")
    if args.data_shards > 1:
        print(f"[serve] 2-D mesh data×model = "
              f"{tp['mesh_data']}×{tp['mesh_model']}: "
              f"{tp['docs_per_s_per_data_shard']:.0f} docs/s per data "
              f"shard, {tp['queries_per_model_shard']} queries per model "
              f"shard, {tp['overlapped_batches']} overlapped transfers "
              f"({tp['put_s']*1e3:.1f} ms staging)")

    # live subscription churn — the defining pub-sub operation, served
    # without stopping the stream: sharded stages recompile only one
    # partition per op (O(n_queries / query_shards) steady state)
    churn = gen_profiles(dtd, n=4, length=3, seed=99)
    t0 = time.perf_counter()
    gids = [stage.subscribe(q) for q in churn]
    t_sub = time.perf_counter() - t0
    t0 = time.perf_counter()
    for gid in gids[:2]:
        stage.unsubscribe(gid)
    t_unsub = time.perf_counter() - t0
    re_routed = sum(len(r) for r in stage.route(payloads[:args.batch]))
    print(f"[serve] live churn: +{len(gids)} subscriptions "
          f"({t_sub/len(gids)*1e3:.1f} ms/op), -2 "
          f"({t_unsub/2*1e3:.1f} ms/op); re-routed {args.batch} requests "
          f"→ {re_routed} deliveries under the updated subscription set")

    rng = np.random.default_rng(0)
    t0 = time.perf_counter()
    n_tok = 0
    for rep, queue in enumerate(queues):
        for i in range(0, len(queue), args.batch):
            chunk = queue[i:i + args.batch]
            pad = args.batch - len(chunk)
            prompts = rng.integers(
                0, cfg.vocab, (args.batch, args.prompt_len)).astype(np.int32)
            out = replica_engines[rep].generate({"tokens": prompts},
                                                args.gen_len)
            n_tok += out.shape[1] * (len(chunk))
            del pad
    dt = time.perf_counter() - t0
    print(f"[serve] generated {n_tok} tokens across {args.replicas} "
          f"replicas in {dt:.2f}s ({n_tok/dt:.1f} tok/s)")


if __name__ == "__main__":
    main()
