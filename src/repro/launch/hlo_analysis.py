"""HLO-text analyzer: FLOPs / traffic / collective wire bytes, trip-aware.

``compiled.cost_analysis()`` does NOT multiply while-loop bodies by their
trip counts (verified on this jax/XLA build: a 12-layer and a 24-layer
scan report identical flops), so every number here is computed by walking
the HLO text ourselves:

* computations are parsed into (name → ops);
* ``while`` ops carry ``backend_config={"known_trip_count":{"n":...}}`` —
  body costs are multiplied through;
* fusion ops attribute their called computation's dot FLOPs to the call
  site and count operand/result bytes as traffic once;
* collective wire bytes use ring-algorithm per-chip traffic:
    all-reduce      2·b·(g-1)/g
    all-gather      b_result·(g-1)/g
    reduce-scatter  b_result·(g-1)
    all-to-all      b·(g-1)/g
    collective-permute  b
  where g is the replica-group size (explicit ``{{...}}`` or iota
  ``[G,S]<=[N]`` form).

All values are **per device** (the SPMD module is per-device); callers
multiply by chip count for global totals.
"""
from __future__ import annotations

import json
import re
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "token": 0, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*(\([^)]*\)|\S+)\s+([\w\-]+)\((.*)$")
_COMP_RE = re.compile(
    r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->\s*.*\{\s*$")
_OPERAND_RE = re.compile(r"%([\w\.\-]+)")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"')
_GROUPS_EXPLICIT_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=")

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")
_NO_TRAFFIC = {"parameter", "constant", "tuple", "get-tuple-element",
               "bitcast", "after-all", "partition-id", "replica-id", "iota"}


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_dims(type_str: str) -> tuple[list[int], str] | None:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return None
    dt, dims = m.groups()
    return ([int(d) for d in dims.split(",")] if dims else []), dt


@dataclass
class Op:
    name: str
    type_str: str
    opcode: str
    rest: str

    @property
    def result_bytes(self) -> int:
        return _shape_bytes(self.type_str)


@dataclass
class Computation:
    name: str
    ops: list[Op] = field(default_factory=list)
    shapes: dict[str, str] = field(default_factory=dict)


def parse_module(txt: str) -> dict[str, Computation]:
    comps: dict[str, Computation] = {}
    cur: Computation | None = None
    for line in txt.splitlines():
        stripped = line.strip()
        if cur is None:
            m = _COMP_RE.match(stripped)
            if m and stripped.endswith("{"):
                cur = Computation(m.group(1))
            continue
        if stripped == "}" or stripped.startswith("} "):
            comps[cur.name] = cur
            cur = None
            continue
        m = _OP_RE.match(line)
        if m:
            op = Op(*m.groups())
            cur.ops.append(op)
            cur.shapes[op.name] = op.type_str
    if cur is not None:
        comps[cur.name] = cur
    return comps


def _group_size(rest: str, default: int = 1) -> int:
    m = _GROUPS_EXPLICIT_RE.search(rest)
    if m:
        return len(m.group(1).split(","))
    m = _GROUPS_IOTA_RE.search(rest)
    if m:
        return int(m.group(2))
    return default


def _collective_wire_bytes(op: Op) -> float:
    g = _group_size(op.rest)
    if g <= 1:
        return 0.0
    b = op.result_bytes
    kind = op.opcode.replace("-start", "")
    if kind == "all-reduce":
        return 2.0 * b * (g - 1) / g
    if kind == "all-gather":
        return b * (g - 1) / g
    if kind == "reduce-scatter":
        return float(b) * (g - 1)
    if kind == "all-to-all":
        return b * (g - 1) / g
    if kind == "collective-permute":
        return float(b)
    return 0.0


def _dot_flops(op: Op, shapes: dict[str, str]) -> float:
    dims = _shape_dims(op.type_str)
    if dims is None:
        return 0.0
    result_n = 1
    for d in dims[0]:
        result_n *= d
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.rest)
    lhs_name = _OPERAND_RE.search(op.rest)
    if not m or not lhs_name:
        return 2.0 * result_n  # degenerate
    lhs_shape = shapes.get(lhs_name.group(1))
    if lhs_shape is None:
        return 2.0 * result_n
    lhs_dims = _shape_dims(lhs_shape)
    if lhs_dims is None:
        return 2.0 * result_n
    k = 1
    for idx in (m.group(1).split(",") if m.group(1) else []):
        i = int(idx)
        if i < len(lhs_dims[0]):
            k *= lhs_dims[0][i]
    return 2.0 * result_n * k


@dataclass
class Costs:
    flops: float = 0.0
    traffic_bytes: float = 0.0
    collective_bytes: float = 0.0
    collective_ops: dict = field(default_factory=dict)

    def __iadd__(self, other: "Costs"):
        self.flops += other.flops
        self.traffic_bytes += other.traffic_bytes
        self.collective_bytes += other.collective_bytes
        for k, v in other.collective_ops.items():
            self.collective_ops[k] = self.collective_ops.get(k, 0) + v
        return self

    def scaled(self, f: float) -> "Costs":
        return Costs(self.flops * f, self.traffic_bytes * f,
                     self.collective_bytes * f,
                     {k: v * f for k, v in self.collective_ops.items()})


class ModuleAnalysis:
    def __init__(self, txt: str) -> None:
        self.comps = parse_module(txt)
        self.entry = self._find_entry(txt)
        self._fusion_bodies = self._collect_fusion_bodies()
        self._memo: dict[str, Costs] = {}

    def _find_entry(self, txt: str) -> str:
        m = re.search(r"^ENTRY\s+%?([\w\.\-]+)", txt, re.MULTILINE)
        if m:
            return m.group(1)
        # fallback: computation named main-ish
        for name in self.comps:
            if "main" in name:
                return name
        return next(iter(self.comps))

    def _collect_fusion_bodies(self) -> set:
        bodies = set()
        for comp in self.comps.values():
            for op in comp.ops:
                if op.opcode == "fusion":
                    m = re.search(r"calls=%([\w\.\-]+)", op.rest)
                    if m:
                        bodies.add(m.group(1))
        return bodies

    def _comp_dot_flops(self, name: str) -> float:
        comp = self.comps.get(name)
        if comp is None:
            return 0.0
        total = 0.0
        for op in comp.ops:
            if op.opcode == "dot":
                total += _dot_flops(op, comp.shapes)
        return total

    def costs_of(self, name: str) -> Costs:
        if name in self._memo:
            return self._memo[name]
        self._memo[name] = Costs()  # cycle guard
        comp = self.comps.get(name)
        if comp is None:
            return Costs()
        total = Costs()
        for op in comp.ops:
            kind = op.opcode
            if kind == "while":
                m_body = re.search(r"body=%?([\w\.\-]+)", op.rest)
                trips = 1
                mt = _TRIP_RE.search(op.rest)
                if mt:
                    trips = int(mt.group(1))
                if m_body:
                    total += self.costs_of(m_body.group(1)).scaled(trips)
                total.traffic_bytes += op.result_bytes
                continue
            if kind == "conditional":
                branches = re.findall(
                    r"(?:branch_computations=\{([^}]*)\}|"
                    r"(?:true|false)_computation=%?([\w\.\-]+))", op.rest)
                names = []
                for grp, single in branches:
                    if grp:
                        names += _OPERAND_RE.findall(grp)
                    if single:
                        names.append(single)
                if names:
                    sub = [self.costs_of(n) for n in names]
                    # executed once; take the max-cost branch
                    best = max(sub, key=lambda c: c.flops + c.traffic_bytes)
                    total += best
                total.traffic_bytes += op.result_bytes
                continue
            if kind == "call":
                m = re.search(r"to_apply=%?([\w\.\-]+)", op.rest)
                if m:
                    total += self.costs_of(m.group(1))
                continue
            if kind == "fusion":
                m = re.search(r"calls=%([\w\.\-]+)", op.rest)
                if m:
                    total.flops += self._comp_dot_flops(m.group(1))
                total.traffic_bytes += self._op_traffic(op, comp)
                continue
            if kind == "dot":
                total.flops += _dot_flops(op, comp.shapes)
                total.traffic_bytes += self._op_traffic(op, comp)
                continue
            if any(kind.startswith(c) for c in COLLECTIVES):
                wire = _collective_wire_bytes(op)
                total.collective_bytes += wire
                base = kind.replace("-start", "")
                total.collective_ops[base] = \
                    total.collective_ops.get(base, 0) + wire
                total.traffic_bytes += op.result_bytes
                continue
            if kind in _NO_TRAFFIC or kind.endswith("-done"):
                continue
            total.traffic_bytes += self._op_traffic(op, comp)
        self._memo[name] = total
        return total

    def _operand_bytes(self, op: Op, comp: Computation) -> int:
        # operands up to metadata/attribute section
        head = op.rest.split("metadata=")[0]
        total = 0
        for name in _OPERAND_RE.findall(head):
            if name in comp.shapes:
                total += _shape_bytes(comp.shapes[name])
        return total

    def _op_traffic(self, op: Op, comp: Computation) -> float:
        """HBM traffic model for one op: operands + result, EXCEPT that
        in-place updates (dynamic-update-slice and DUS-shaped fusions)
        only move the updated slice — XLA aliases the big buffer.
        Without this, every KV-cache write counts the whole cache per
        step (measured 200+ GiB/step phantom traffic on decode)."""
        head = op.rest.split("metadata=")[0]
        opnds = [_shape_bytes(comp.shapes[n])
                 for n in _OPERAND_RE.findall(head) if n in comp.shapes]
        res = op.result_bytes
        total_opnds = sum(opnds)
        big = max(opnds) if opnds else 0
        others = total_opnds - big
        if op.opcode == "dynamic-update-slice":
            return 2.0 * others
        if op.opcode in ("dynamic-slice", "slice"):
            return 2.0 * res  # reads only the slice, not the buffer
        if op.opcode == "fusion":
            if opnds and res == big and res > 4 * max(others, 1):
                return 2.0 * others       # in-place update pattern
            if "kind=kLoop" in op.rest:
                # elementwise/slice fusion: each output element touches
                # O(1) elements per operand — cap operand reads at the
                # result size (otherwise loop-carried big buffers read
                # through a dynamic-slice count as full-buffer traffic)
                return float(res + sum(min(o, res) for o in opnds))
        return float(res + total_opnds)

    def entry_costs(self) -> Costs:
        return self.costs_of(self.entry)


def analyze_text(txt: str) -> dict:
    mod = ModuleAnalysis(txt)
    c = mod.entry_costs()
    return {
        "flops_per_device": c.flops,
        "traffic_bytes_per_device": c.traffic_bytes,
        "collective_bytes_per_device": c.collective_bytes,
        "collective_breakdown": c.collective_ops,
    }


def traffic_breakdown(txt: str, top: int = 20) -> list[tuple[str, float]]:
    """Per-op-name traffic attribution (trip-aware) — the dry-run
    'profile' used by the §Perf loop to find what dominates the memory
    term.  Groups by the jax op_name metadata suffix."""
    mod = ModuleAnalysis(txt)
    # compute trip multiplier per computation by walking from entry
    mult: dict[str, float] = {}

    def walk(name: str, m: float) -> None:
        if m <= mult.get(name, 0):
            return
        mult[name] = max(mult.get(name, 0), m)
        comp = mod.comps.get(name)
        if comp is None:
            return
        for op in comp.ops:
            if op.opcode == "while":
                mb = re.search(r"body=%?([\w\.\-]+)", op.rest)
                mt = _TRIP_RE.search(op.rest)
                trips = int(mt.group(1)) if mt else 1
                if mb:
                    walk(mb.group(1), m * trips)
            elif op.opcode == "call":
                mc = re.search(r"to_apply=%?([\w\.\-]+)", op.rest)
                if mc:
                    walk(mc.group(1), m)
            elif op.opcode == "conditional":
                for nm in _OPERAND_RE.findall(op.rest.split("metadata=")[0]):
                    if nm in mod.comps:
                        walk(nm, m)

    walk(mod.entry, 1.0)
    agg: dict[str, float] = {}
    for cname, m in mult.items():
        comp = mod.comps[cname]
        if cname in mod._fusion_bodies:
            continue
        for op in comp.ops:
            if op.opcode in _NO_TRAFFIC or op.opcode in ("while", "call",
                                                         "conditional"):
                continue
            meta = re.search(r'op_name="([^"]*)"', op.rest)
            key = meta.group(1).split("/")[-1] if meta else op.opcode
            b = mod._op_traffic(op, comp) * m
            agg[key] = agg.get(key, 0) + b
    return sorted(agg.items(), key=lambda kv: -kv[1])[:top]


if __name__ == "__main__":  # pragma: no cover
    import sys
    print(json.dumps(analyze_text(open(sys.argv[1]).read()), indent=2))
