"""Data plane: synthetic XML workload generation (ToXGene-like, §4),
profile generation (YFilter PathGenerator-like), the pub-sub filter stage,
and the LM token pipeline."""
from .generator import DTD, gen_document, gen_profiles  # noqa: F401
