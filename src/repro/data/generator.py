"""Synthetic XML workload generator.

The paper evaluates with ToXGene-generated documents over a DTD and
YFilter's ``PathGenerator`` for profiles (§4): profiles of path length
2/4/6, query counts 16–1024, documents of 1–8 MB.  This module generates
the equivalent workload:

* :class:`DTD` — a randomly generated parent→children tag grammar (like
  the NITF/book DTDs used with ToXGene): a rooted DAG-ish tag hierarchy.
* :func:`gen_document` — random trees following the DTD, serialized as
  event streams (and paper-format bytes via :mod:`repro.core.events`).
* :func:`gen_profiles` — random root-to-descendant paths through the DTD
  with configurable ``//`` and ``*`` probabilities — exactly what
  PathGenerator does.

Deterministic given the seed; no external data needed.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from ..core.dictionary import TagDictionary
from ..core.events import CLOSE, OPEN, EventStream
from ..core.xpath import Query, parse


@dataclass
class DTD:
    """tag id → allowed child tag ids (root children from tag -1)."""

    n_tags: int
    children: dict[int, list[int]]
    tag_names: list[str]

    @classmethod
    def generate(cls, n_tags: int = 24, fanout: int = 4,
                 seed: int = 0) -> "DTD":
        rng = np.random.default_rng(seed)
        names = [f"t{i}" for i in range(n_tags)]
        children: dict[int, list[int]] = {}
        # layered hierarchy with some cross-links → realistic recursion-free
        # core plus a few recursive tags (XML DTDs commonly have both)
        layers = np.array_split(np.arange(n_tags), max(2, n_tags // 6))
        children[-1] = list(layers[0])
        for li, layer in enumerate(layers):
            nxt = layers[li + 1] if li + 1 < len(layers) else layer
            for t in layer:
                k = int(rng.integers(1, fanout + 1))
                opts = rng.choice(nxt, size=min(k, len(nxt)), replace=False)
                children[int(t)] = [int(x) for x in opts]
        # a couple of recursive tags
        for t in rng.choice(n_tags, size=max(1, n_tags // 12), replace=False):
            children[int(t)].append(int(t))
        return cls(n_tags, children, names)

    def register(self, dictionary: TagDictionary) -> None:
        for n in self.tag_names:
            dictionary.add(n)


def gen_document(dtd: DTD, *, target_nodes: int = 200, max_depth: int = 12,
                 seed: int = 0) -> EventStream:
    """Random document tree following the DTD (event-stream form)."""
    rng = np.random.default_rng(seed)
    kinds: list[int] = []
    tags: list[int] = []
    budget = [target_nodes]

    def emit(tag: int, depth: int) -> None:
        if budget[0] <= 0:
            return
        budget[0] -= 1
        kinds.append(OPEN)
        tags.append(tag)
        if depth < max_depth:
            opts = dtd.children.get(tag, [])
            if opts:
                n_kids = int(rng.integers(0, 4))
                for _ in range(n_kids):
                    if budget[0] <= 0:
                        break
                    emit(int(rng.choice(opts)), depth + 1)
        kinds.append(CLOSE)
        tags.append(tag)

    while budget[0] > 0:
        emit(int(rng.choice(dtd.children[-1])), 1)
    return EventStream(np.array(kinds, np.int8), np.array(tags, np.int32))


def gen_profiles(dtd: DTD, *, n: int = 64, length: int = 4,
                 p_desc: float = 0.3, p_wild: float = 0.1,
                 seed: int = 0) -> list[Query]:
    """PathGenerator-equivalent: random DTD paths with //, * mutations."""
    rng = np.random.default_rng(seed)
    out: list[Query] = []
    for _ in range(n):
        tags: list[int] = []
        cur = -1
        for _ in range(length):
            opts = dtd.children.get(cur, [])
            if not opts:
                break
            cur = int(rng.choice(opts))
            tags.append(cur)
        parts = []
        for i, t in enumerate(tags):
            axis = "//" if (i == 0 or rng.random() < p_desc) else "/"
            name = "*" if rng.random() < p_wild else dtd.tag_names[t]
            parts.append(axis + name)
        out.append(parse("".join(parts)))
    return out


def gen_corpus(dtd: DTD, *, n_docs: int, nodes_per_doc: int = 200,
               seed: int = 0) -> list[EventStream]:
    return [gen_document(dtd, target_nodes=nodes_per_doc, seed=seed + i)
            for i in range(n_docs)]
