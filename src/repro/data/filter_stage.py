"""Pub-sub content routing as a data-pipeline stage.

This is where the paper's contribution is a *first-class feature* of the
framework: a stream of XML documents is matched against standing profiles
(subscriptions) and routed — exactly the paper's pub-sub filtering — as a
stage in front of the training/serving data pipeline:

* training: documents are filtered by topic profiles and routed to
  data-parallel shards (``launch/train.py --data-filter``);
* serving: requests carrying XML payloads are routed to model replicas by
  subscription (``launch/serve.py``).

The stage batches documents and runs the levelwise TPU engine by default;
``engine='yfilter'`` selects the software baseline (useful for the Fig-9
comparison in situ).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Iterable, Iterator, Sequence

import numpy as np

from ..core.dictionary import TagDictionary
from ..core.engines.levelwise import LevelwiseEngine
from ..core.engines.streaming import StreamingEngine
from ..core.engines.yfilter import YFilterEngine
from ..core.events import EventStream, event_stream_nbytes
from ..core.nfa import NFA, compile_queries
from ..core.xpath import Query, parse


@dataclass
class RoutedDocument:
    doc_index: int
    matched_profiles: np.ndarray       # (n_matched,) int32 profile indices
    shard: int                         # destination data shard
    nbytes: int


@dataclass
class FilterStage:
    """Standing-profile filter + router.

    ``shard_of_profile[q]`` maps each subscription to a destination shard
    (defaults to round-robin).  A document goes to every shard that has at
    least one matching subscription; unmatched documents are dropped
    (classic pub-sub) or sent to shard 0 with ``keep_unmatched=True``.
    """

    profiles: Sequence[Query]
    dictionary: TagDictionary
    n_shards: int = 1
    engine: str = "levelwise"
    keep_unmatched: bool = False
    batch_size: int = 32
    shard_of_profile: np.ndarray = field(default=None)  # type: ignore

    def __post_init__(self) -> None:
        if isinstance(self.profiles[0], str):
            self.profiles = [parse(p) for p in self.profiles]
        self.nfa: NFA = compile_queries(list(self.profiles), self.dictionary,
                                        shared=True)
        if self.shard_of_profile is None:
            self.shard_of_profile = (
                np.arange(len(self.profiles)) % self.n_shards).astype(np.int32)
        if self.engine == "levelwise":
            self._eng = LevelwiseEngine(self.nfa)
        elif self.engine == "streaming":
            self._eng = StreamingEngine(self.nfa)
        elif self.engine == "yfilter":
            self._eng = YFilterEngine(self.nfa)
        else:
            raise ValueError(self.engine)

    # ----------------------------------------------------------------- run
    def _filter_batch(self, docs: list[EventStream]):
        if self.engine == "levelwise":
            return self._eng.filter_documents_batched(docs)
        return [self._eng.filter_document(d) for d in docs]

    def route(self, docs: Iterable[EventStream]) -> Iterator[list[RoutedDocument]]:
        """Yield routed batches; each doc may fan out to several shards."""
        batch: list[EventStream] = []
        base = 0
        for doc in docs:
            batch.append(doc)
            if len(batch) == self.batch_size:
                yield self._route_batch(batch, base)
                base += len(batch)
                batch = []
        if batch:
            yield self._route_batch(batch, base)

    def _route_batch(self, docs: list[EventStream],
                     base: int) -> list[RoutedDocument]:
        results = self._filter_batch(docs)
        out: list[RoutedDocument] = []
        for i, (doc, res) in enumerate(zip(docs, results)):
            qids = res.matching_queries()
            nb = event_stream_nbytes(doc)
            if len(qids) == 0:
                if self.keep_unmatched:
                    out.append(RoutedDocument(base + i, qids, 0, nb))
                continue
            for shard in np.unique(self.shard_of_profile[qids]):
                mine = qids[self.shard_of_profile[qids] == shard]
                out.append(RoutedDocument(base + i, mine, int(shard), nb))
        return out

    # ------------------------------------------------------------- metrics
    def selectivity(self, docs: list[EventStream]) -> float:
        """Fraction of (doc, profile) pairs that match — workload stat."""
        results = self._filter_batch(docs)
        m = np.stack([r.matched for r in results])
        return float(m.mean())
