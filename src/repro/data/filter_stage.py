"""Pub-sub content routing as a data-pipeline stage.

This is where the paper's contribution is a *first-class feature* of the
framework: a stream of XML documents is matched against standing profiles
(subscriptions) and routed — exactly the paper's pub-sub filtering — as a
stage in front of the training/serving data pipeline:

* training: documents are filtered by topic profiles and routed to
  data-parallel shards (``launch/train.py --data-filter``);
* serving: requests carrying XML payloads are routed to model replicas by
  subscription (``launch/serve.py``).

The stage is engine-agnostic: any registered engine name
(:func:`repro.core.engines.names`) works, because every engine consumes
the same :class:`~repro.core.events.EventBatch` and returns the same
batched ``(B, Q)`` :class:`~repro.core.engines.FilterResult`.  Batches
are padded to bucket boundaries so the number of compiled shapes stays
bounded, and ``stage.stats`` accumulates per-batch throughput and
selectivity.

Two ingest paths feed the same router: :meth:`FilterStage.route` takes
pre-parsed event streams (host parse), :meth:`FilterStage.route_bytes`
takes raw paper-format byte payloads and parses them *on device*
(:func:`repro.kernels.parse.parse_batch` / the engine's fused
``filter_bytes``) — the paper's same-chip parser+filter dataflow.
"""
from __future__ import annotations

import threading
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Iterable, Iterator, Sequence

import numpy as np

from ..core import engines
from ..core.dictionary import TagDictionary
from ..core.engines import FilterResult, SparseResult
from ..core.events import (ByteBatch, EventBatch, EventStream,
                           event_stream_nbytes)
from ..core.nfa import NFA, compile_queries
from ..core.xpath import Query, parse

TEXT_FILL = 8  # filler text bytes per element in the MB/s accounting


@dataclass
class RoutedDocument:
    doc_index: int
    matched_profiles: np.ndarray       # (n_matched,) int32 profile indices
    shard: int                         # destination data shard
    nbytes: int


class StalePlanError(RuntimeError):
    """A prepared plan's base epoch no longer matches the live plan.

    Raised by :meth:`FilterStage.commit` when another commit landed
    between ``prepare_*`` and ``commit`` — the pending plan was built
    against a subscription set that no longer exists.  The caller
    re-prepares against the current plan (the synchronous churn methods
    do this automatically; the serve loop's shadow builder records it as
    a rollback)."""


@dataclass
class PlanEpoch:
    """Immutable snapshot of the live plan, taken at dispatch time.

    A batch dispatched against epoch *E* filters with *E*'s engine,
    sharded plan and gid mapping even if churn commits a replacement
    mid-flight — verdict columns and the gid axis always agree, which is
    what makes the serve loop's shadow-plan hot swap safe with in-flight
    batches (no queue drain)."""

    epoch: int
    eng: Any
    sharded: Any                       # ShardedPlan | None
    gids: np.ndarray


@dataclass
class PendingPlan:
    """A fully built replacement plan awaiting an atomic commit.

    Produced off the hot path by ``prepare_subscribe`` /
    ``prepare_unsubscribe`` / ``prepare_rebalance`` — all the expensive
    work (NFA compile, part re-plan, rebalance migration) happens during
    *prepare*, against a snapshot, without mutating the stage; ``commit``
    is a handful of reference assignments under the plan mutex."""

    op: str                            # "subscribe" | "unsubscribe" | "rebalance"
    base_epoch: int
    gid: int | None = None
    stats: dict | None = None          # rebalance stats
    sharded: Any = None                # replacement ShardedPlan
    eng: Any = None                    # replacement engine (unsharded path)
    nfa: Any = None
    live: dict | None = None
    gids: np.ndarray | None = None
    build_s: float = 0.0


@dataclass
class FilterStage:
    """Standing-profile filter + router over any registered engine.

    ``shard_of_profile[q]`` maps each subscription to a destination shard
    (defaults to round-robin).  A document goes to every shard that has at
    least one matching subscription; unmatched documents are dropped
    (classic pub-sub) or sent to shard 0 with ``keep_unmatched=True``.

    ``bucket`` controls padded-batch bucketing: each batch's event axis is
    padded to the next multiple, capping the number of distinct shapes
    the device engines compile for; ``byte_bucket`` does the same for the
    raw-byte axis of the device-ingest path (:meth:`route_bytes`).

    ``query_shards > 1`` partitions the subscription set into that many
    balanced parts (:meth:`FilterEngine.plan_sharded`) and filters
    through the sharded path — all parts in one stacked device program,
    spread over the mesh ``"model"`` axis (auto-built when none is
    given, shrunk to what the host can place).  Routing
    is by **global query id** through the partition index, so documents
    fan out to data shards identically with and without query sharding.
    Subscriptions can then churn live: :meth:`subscribe` recompiles only
    the least-loaded part, :meth:`unsubscribe` is pure metadata.

    ``data_shards > 1`` adds the second scaling axis: batches run
    through the 2-D ``("data", "model")`` program
    (:meth:`FilterEngine.filter_batch_sharded2d`), documents spread over
    the mesh ``"data"`` axis while each device keeps its 1/P slice of
    the queries — the paper's §3.5 replication in both dimensions.  A
    mesh is built automatically when none is given.  The bytes path gets
    an async double-buffered serve loop on top:
    :meth:`route_bytes_pipelined` overlaps the ``jax.device_put`` of
    batch *k+1* with the filter step still running on batch *k*.
    """

    profiles: Sequence[Query]
    dictionary: TagDictionary
    n_shards: int = 1
    engine: str = "levelwise"
    keep_unmatched: bool = False
    batch_size: int = 32
    bucket: int = 128
    byte_bucket: int = 1024
    query_shards: int = 1
    data_shards: int = 1
    #: in-flight depth of :meth:`route_bytes_pipelined` — how many
    #: dispatched-but-unmaterialized batches the loop keeps (2 = the
    #: classic double buffer; the serve loop raises it via its own
    #: ``max_inflight``)
    pipeline_depth: int = 2
    mesh: Any = None
    shard_of_profile: np.ndarray = field(default=None)  # type: ignore
    stats: dict = field(default_factory=dict)
    #: deliver verdicts as sparse (doc, gid) match lists — the bounded
    #: device match buffer instead of the dense (B, Q) bitmap (engines'
    #: ``filter_batch_sparse`` family); routing output is identical
    sparse: bool = False
    #: run :meth:`maybe_rebalance` automatically every N churn ops
    #: (0 = manual only); ``rebalance_tolerance`` is the max/mean-1
    #: imbalance the plan is allowed before groups migrate
    rebalance_every: int = 0
    rebalance_tolerance: float = 0.25
    #: extra engine options (e.g. ``{"minimize": True}`` for global NFA
    #: minimization, ``{"match_cap": ...}`` for the sparse buffer bound)
    engine_options: dict = field(default_factory=dict)

    def __post_init__(self) -> None:
        if isinstance(self.profiles[0], str):
            self.profiles = [parse(p) for p in self.profiles]
        # live subscription set, keyed by stable global query id;
        # ids are never reused (monotonic counter), matching ShardedPlan
        self._live: dict[int, Query] = dict(enumerate(self.profiles))
        self._next_gid = len(self.profiles)
        self._gids = np.arange(len(self.profiles), dtype=np.int32)
        self.nfa: NFA = compile_queries(list(self.profiles), self.dictionary,
                                        shared=True)
        # event_bucket threads this stage's padding bucket into every
        # engine byte path, so a call that omits bucket= can never fall
        # back to a different (hard-coded) boundary than the stage's own
        self._eng = engines.create(self.engine, self.nfa,
                                   dictionary=self.dictionary,
                                   event_bucket=self.bucket,
                                   **self.engine_options)
        self._churn_ops = 0
        if (self.query_shards > 1 or self.data_shards > 1) \
                and self.mesh is None:
            from ..launch.mesh import make_filter_mesh
            # n_parts caps the model axis at the part count (a monolithic
            # plan gets a 1-wide model axis, all devices on "data")
            self.mesh = make_filter_mesh(max(1, self.query_shards),
                                         data_shards=self.data_shards)
        # the data axis needs a sharded plan even with one query part
        # (the 2-D program executes a stacked ShardedPlan)
        self.sharded_ = (self._eng.plan_sharded(max(1, self.query_shards))
                         if self.query_shards > 1 or self.data_shards > 1
                         else None)
        if self.shard_of_profile is None:
            self.shard_of_profile = (
                np.arange(len(self.profiles)) % self.n_shards).astype(np.int32)
        self.stats = {"batches": 0, "docs": 0, "bytes": 0,
                      "seconds": 0.0, "pair_matches": 0, "pairs": 0,
                      "put_seconds": 0.0, "overlapped_batches": 0,
                      "verdict_bytes": 0, "rebalances": 0}
        # plan epoch: bumped on every committed plan change; the mutex
        # covers only snapshot/commit (reference assignments), never a
        # compile — prepare_* does the expensive work outside it
        self._plan_mtx = threading.Lock()
        self._epoch = 0

    # --------------------------------------------------- subscription churn
    def plan_epoch(self) -> PlanEpoch:
        """Consistent (epoch, engine, plan, gids) snapshot for dispatch.

        A batch filtered against this snapshot and fanned out with its
        ``gids`` is correct even if a plan swap commits while the batch
        is in flight."""
        with self._plan_mtx:
            return PlanEpoch(self._epoch, self._eng, self.sharded_,
                             self._gids)

    def prepare_subscribe(self, profile: Query | str) -> PendingPlan:
        """Build (but do not install) the plan that adds ``profile``.

        Pure with respect to the stage: sharded stages re-plan only the
        least-loaded part (:meth:`ShardedPlan.add_queries`), unsharded
        stages compile the full replacement engine — either way against
        a snapshot, so a failed build (e.g. a rejected profile) leaves
        the live plan untouched with nothing to roll back."""
        q = parse(profile) if isinstance(profile, str) else profile
        t0 = time.perf_counter()
        with self._plan_mtx:
            base = self._epoch
            sharded = self.sharded_
            live = dict(self._live)
            gid = self._next_gid
        if sharded is not None:
            sp, new = sharded.add_queries([q])
            gid = new[0]
            live[gid] = q
            return PendingPlan("subscribe", base, gid=gid, sharded=sp,
                               live=live, gids=sp.live_ids(),
                               build_s=time.perf_counter() - t0)
        live[gid] = q
        gids = sorted(live)
        nfa = compile_queries([live[g] for g in gids], self.dictionary,
                              shared=True)
        eng = engines.create(self.engine, nfa, dictionary=self.dictionary,
                             event_bucket=self.bucket, **self.engine_options)
        return PendingPlan("subscribe", base, gid=gid, eng=eng, nfa=nfa,
                           live=live, gids=np.asarray(gids, np.int32),
                           build_s=time.perf_counter() - t0)

    def prepare_unsubscribe(self, gid: int) -> PendingPlan:
        """Build the plan that drops ``gid`` (tombstone when sharded)."""
        if gid not in self._live:
            raise KeyError(f"query id {gid} is not subscribed")
        t0 = time.perf_counter()
        with self._plan_mtx:
            base = self._epoch
            sharded = self.sharded_
            live = dict(self._live)
        del live[gid]
        if sharded is not None:
            sp = sharded.remove_queries([gid])
            return PendingPlan("unsubscribe", base, gid=gid, sharded=sp,
                               live=live, gids=sp.live_ids(),
                               build_s=time.perf_counter() - t0)
        gids = sorted(live)
        nfa = compile_queries([live[g] for g in gids], self.dictionary,
                              shared=True)
        eng = engines.create(self.engine, nfa, dictionary=self.dictionary,
                             event_bucket=self.bucket, **self.engine_options)
        return PendingPlan("unsubscribe", base, gid=gid, eng=eng, nfa=nfa,
                           live=live, gids=np.asarray(gids, np.int32),
                           build_s=time.perf_counter() - t0)

    def prepare_rebalance(self, *, tolerance: float | None = None
                          ) -> PendingPlan | None:
        """Build the rebalanced plan (sharded stages only, else None).

        ``pending.sharded`` is ``None`` when no trie groups needed to
        move — committing such a plan is a no-op that still returns the
        stats."""
        if self.sharded_ is None:
            return None
        tol = (self.rebalance_tolerance
               if tolerance is None else tolerance)
        t0 = time.perf_counter()
        with self._plan_mtx:
            base = self._epoch
            sharded = self.sharded_
        new, stats = sharded.rebalance(tolerance=tol)
        moved = bool(stats["moves"])
        return PendingPlan("rebalance", base, stats=stats,
                           sharded=new if moved else None,
                           gids=new.live_ids() if moved else None,
                           build_s=time.perf_counter() - t0)

    def commit(self, pending: PendingPlan, shard: int | None = None):
        """Atomically install a prepared plan at the current epoch.

        A handful of reference assignments under the plan mutex —
        batches dispatched against the previous :meth:`plan_epoch`
        snapshot keep filtering the old plan; the next snapshot sees the
        new one.  Raises :class:`StalePlanError` (leaving the live plan
        untouched) if another commit landed since ``prepare_*``.
        Returns the gid for churn ops, the stats dict for rebalances."""
        with self._plan_mtx:
            if pending.base_epoch != self._epoch:
                raise StalePlanError(
                    f"plan prepared against epoch {pending.base_epoch}, "
                    f"live plan is at {self._epoch}; re-prepare")
            if pending.op == "rebalance":
                if pending.sharded is not None:
                    self.sharded_ = pending.sharded
                    self._gids = pending.gids
                    self.stats["rebalances"] += 1
                    self._epoch += 1
                return pending.stats
            self._live = pending.live
            if pending.sharded is not None:
                self.sharded_ = pending.sharded
            else:
                self.nfa = pending.nfa
                self._eng = pending.eng
            self._gids = pending.gids
            self._epoch += 1
            if pending.op == "subscribe":
                self._next_gid = max(self._next_gid, pending.gid + 1)
                self._grow_shard_map(pending.gid, shard)
            return pending.gid

    def subscribe(self, profile: Query | str, shard: int | None = None) -> int:
        """Add a standing profile live; returns its global query id.

        Sharded stages recompile only the least-loaded part
        (:meth:`ShardedPlan.add_queries`); unsharded stages pay the full
        recompile — the cost gap is the point of query sharding.
        Prepare/commit under the hood: a failed build never touches the
        live plan, and a concurrent commit just means one re-prepare.
        """
        while True:
            pending = self.prepare_subscribe(profile)
            try:
                gid = self.commit(pending, shard=shard)
                break
            except StalePlanError:
                continue
        self._after_churn()
        return gid

    def unsubscribe(self, gid: int) -> None:
        """Remove a subscription by global id (live, no re-plan when
        sharded — the column is tombstoned)."""
        while True:
            pending = self.prepare_unsubscribe(gid)
            try:
                self.commit(pending)
                break
            except StalePlanError:
                continue
        self._after_churn()

    def _after_churn(self) -> None:
        self._churn_ops += 1
        if (self.rebalance_every
                and self._churn_ops >= self.rebalance_every):
            self._churn_ops = 0
            self.maybe_rebalance()

    def maybe_rebalance(self, *, tolerance: float | None = None
                        ) -> dict | None:
        """Off-hot-path shard-load repair (sharded stages only).

        Runs :meth:`ShardedPlan.rebalance` against the live plan and, if
        any trie groups moved, swaps the new frozen plan in with a
        single reference assignment — batches already dispatched keep
        filtering the old plan, the next batch picks up the new one, and
        verdicts/routing are identical either way (the rebalance
        invariant).  Returns the rebalance stats, or ``None`` when the
        stage is unsharded.
        """
        while True:
            pending = self.prepare_rebalance(tolerance=tolerance)
            if pending is None:
                return None
            try:
                return self.commit(pending)
            except StalePlanError:
                continue

    def _grow_shard_map(self, gid: int, shard: int | None) -> None:
        if gid >= len(self.shard_of_profile):
            extra = np.arange(len(self.shard_of_profile), gid + 1)
            self.shard_of_profile = np.concatenate(
                [self.shard_of_profile,
                 (extra % self.n_shards).astype(np.int32)])
        if shard is not None:
            self.shard_of_profile[gid] = shard

    # ----------------------------------------------------------------- run
    def _filter_batch(self, docs: list[EventStream],
                      record: bool = True) -> FilterResult:
        """Uniform batched path: every engine gets one EventBatch and
        returns one (B, Q) FilterResult.  ``record=False`` keeps
        metric-only reads (e.g. :meth:`selectivity`) out of the
        cumulative routing stats."""
        batch = EventBatch.from_streams(docs, bucket=self.bucket)
        t0 = time.perf_counter()
        if self.data_shards > 1:
            res = (self._eng.filter_batch_sharded2d_sparse if self.sparse
                   else self._eng.filter_batch_sharded2d)(
                       batch, self.sharded_, mesh=self.mesh)
        elif self.sharded_ is not None:
            res = (self._eng.filter_batch_sharded_sparse if self.sparse
                   else self._eng.filter_batch_sharded)(
                       batch, self.sharded_, mesh=self.mesh)
        elif self.sparse:
            res = self._eng.filter_batch_sparse(batch)
        else:
            res = self._eng.filter_batch(batch)
        dt = time.perf_counter() - t0
        if record:
            self._record(res, batch.batch_size,
                         int(batch.nbytes(TEXT_FILL).sum()), dt)
        return res

    def _record(self, res: FilterResult | SparseResult, n_docs: int,
                n_bytes: int, dt: float) -> None:
        """One accounting path for both ingest forms, so throughput()
        stays comparable between them."""
        self.stats["batches"] += 1
        self.stats["docs"] += n_docs
        self.stats["bytes"] += n_bytes
        self.stats["seconds"] += dt
        if isinstance(res, SparseResult):
            self.stats["pair_matches"] += res.n_matches
            self.stats["pairs"] += res.batch_size * res.n_live
            self.stats["verdict_bytes"] += res.verdict_bytes
        else:
            self.stats["pair_matches"] += int(res.matched.sum())
            self.stats["pairs"] += res.matched.size
            self.stats["verdict_bytes"] += res.matched.size * 5

    def _filter_bytebatch(self, bufs: list[bytes], record: bool = True,
                          epoch: PlanEpoch | None = None) -> FilterResult:
        """Device-ingest batched path: raw wire bytes in, ``(B, Q)``
        verdicts out, parsed on device by ``engine.filter_bytes`` — no
        per-event host Python between payload and verdict.  ``epoch``
        pins the batch to a :meth:`plan_epoch` snapshot so a concurrent
        plan swap cannot tear engine/plan/gids mid-batch."""
        eng = self._eng if epoch is None else epoch.eng
        sharded = self.sharded_ if epoch is None else epoch.sharded
        bb = ByteBatch.from_buffers(bufs, bucket=self.byte_bucket)
        t0 = time.perf_counter()
        if self.data_shards > 1:
            res = eng.filter_bytes_sharded2d(bb, sharded,
                                             bucket=self.bucket,
                                             mesh=self.mesh)
            if self.sparse:
                res = res.sparsify(sharded.live_ids())
        elif sharded is not None:
            res = (eng.filter_bytes_sharded_sparse if self.sparse
                   else eng.filter_bytes_sharded)(
                       bb, sharded, bucket=self.bucket,
                       mesh=self.mesh)
        elif self.sparse:
            res = eng.filter_bytes_sparse(bb, bucket=self.bucket)
        else:
            res = eng.filter_bytes(bb, bucket=self.bucket)
        dt = time.perf_counter() - t0
        if record:
            self._record(res, bb.batch_size, bb.nbytes_total(), dt)
        return res

    def _chunks(self, items: Iterable) -> Iterator[list]:
        """Accumulate an (unbounded) iterable into batch_size chunks —
        the one batching loop all three routing paths share."""
        batch: list = []
        for item in items:
            batch.append(item)
            if len(batch) == self.batch_size:
                yield batch
                batch = []
        if batch:
            yield batch

    def route(self, docs: Iterable[EventStream]) -> Iterator[list[RoutedDocument]]:
        """Yield routed batches; each doc may fan out to several shards."""
        base = 0
        for batch in self._chunks(docs):
            yield self._route_batch(batch, base)
            base += len(batch)

    def route_bytes(self, payloads: Iterable[bytes]
                    ) -> Iterator[list[RoutedDocument]]:
        """Route raw paper-format byte payloads (device-ingest twin of
        :meth:`route`): each batch is parsed *and* filtered on device,
        then fanned out to shards exactly like the event path."""
        base = 0
        for batch in self._chunks(payloads):
            yield self._route_byte_batch(batch, base)
            base += len(batch)

    # ------------------------------------------- double-buffered serve loop
    def _stage_in(self, bufs: list[bytes]):
        """Host-side staging of one batch: pack, take the event bound
        (a host metadata scan — done BEFORE placement so the device copy
        is never read back), then issue the async ``device_put`` against
        the mesh ``"data"`` axis."""
        bb = ByteBatch.from_buffers(bufs, bucket=self.byte_bucket)
        n_events = bb.event_bound(bucket=self.bucket)
        t0 = time.perf_counter()
        placed = bb.device_put(self.mesh)
        # device_put is async: this times dispatch, not the transfer —
        # the transfer itself overlaps the previous batch's filter step
        self.stats["put_seconds"] += time.perf_counter() - t0
        return bufs, bb, placed, n_events

    def _dispatch_byte_batch(self, bufs: list[bytes]):
        """Stage one raw-byte batch (exactly once — ``put_seconds``
        counts each batch's ``device_put`` dispatch a single time) and
        launch the async 2-D bytes→verdict program.  Returns the
        in-flight entry the K-deep loop materializes later."""
        bufs, bb, placed, n_events = self._stage_in(bufs)
        t0 = time.perf_counter()
        materialize = self._eng.dispatch_bytes_sharded2d(
            placed, self.sharded_, mesh=self.mesh, n_events=n_events)
        return bufs, bb, materialize, t0

    def _materialize_routed(self, entry, base: int) -> list[RoutedDocument]:
        """Block on one in-flight batch's verdicts, account, fan out."""
        bufs, bb, materialize, t0 = entry
        res = materialize()
        # slice off data-axis pad rows before accounting/fan-out
        res = FilterResult(res.matched[:len(bufs)],
                           res.first_event[:len(bufs)])
        self._record(res, bb.batch_size, bb.nbytes_total(),
                     time.perf_counter() - t0)
        return self._fan_out(res, [len(b) for b in bufs], base)

    def route_bytes_pipelined(self, payloads: Iterable[bytes], *,
                              depth: int | None = None
                              ) -> Iterator[list[RoutedDocument]]:
        """K-deep pipelined twin of :meth:`route_bytes` for the 2-D
        mesh: while the bytes→verdict program runs on batch *k*, up to
        ``depth - 1`` successor batches are already packed, their H2D
        transfers in flight and their filter programs dispatched.

        Per batch: (1) stage (pack + async ``ByteBatch.device_put``) and
        dispatch the 2-D filter program
        (:meth:`FilterEngine.dispatch_bytes_sharded2d` — asynchronous,
        returns a materializer); (2) once ``depth`` batches are in
        flight, block on the *oldest* one's verdicts and fan out (FIFO —
        routed order is identical to :meth:`route_bytes`).  ``depth``
        defaults to :attr:`pipeline_depth` (2 = the classic double
        buffer); the serve loop passes its own ``max_inflight``.  Each
        batch is staged exactly once, so ``put_seconds`` accounts every
        ``device_put`` dispatch a single time at any depth.  Throughput
        and overlap accounting land in ``stats`` (``put_seconds``,
        ``overlapped_batches``).  Falls back to :meth:`route_bytes`
        when the stage has no mesh to overlap against.
        """
        if self.mesh is None or self.sharded_ is None:
            yield from self.route_bytes(payloads)
            return
        k = max(1, self.pipeline_depth if depth is None else depth)

        # streaming K-deep window: only the k in-flight batches are
        # ever held — an unbounded payload stream yields verdicts batch
        # by batch, exactly like route_bytes
        inflight: deque = deque()
        base = 0
        for bufs in self._chunks(payloads):
            if inflight:
                # a predecessor's filter step is still in flight while
                # this batch stages: the overlap the pipeline exists for
                self.stats["overlapped_batches"] += 1
            inflight.append(self._dispatch_byte_batch(bufs))
            if len(inflight) >= k:
                entry = inflight.popleft()
                yield self._materialize_routed(entry, base)
                base += len(entry[0])
        while inflight:
            entry = inflight.popleft()
            yield self._materialize_routed(entry, base)
            base += len(entry[0])

    def _route_batch(self, docs: list[EventStream],
                     base: int) -> list[RoutedDocument]:
        results = self._filter_batch(docs)
        return self._fan_out(results, [event_stream_nbytes(d) for d in docs],
                             base)

    def _route_byte_batch(self, bufs: list[bytes],
                          base: int) -> list[RoutedDocument]:
        results = self._filter_bytebatch(bufs)
        return self._fan_out(results, [len(b) for b in bufs], base)

    def _fan_out(self, results: FilterResult | SparseResult,
                 nbytes: list[int], base: int = 0, *,
                 gids: np.ndarray | None = None,
                 seqs: Sequence[int] | None = None) -> list[RoutedDocument]:
        """Verdicts → routed documents.  ``gids`` pins the live-column →
        global-id mapping to the epoch the batch was filtered under
        (defaults to the current plan); ``seqs`` assigns explicit,
        possibly non-contiguous document indices (the serve loop's
        quarantine retries filter recovered subsets whose seqs are not
        ``base + i``)."""
        sparse = isinstance(results, SparseResult)
        live = self._gids if gids is None else gids
        out: list[RoutedDocument] = []
        for i, nb in enumerate(nbytes):
            doc = base + i if seqs is None else int(seqs[i])
            # result columns are live-query columns; route by global id
            # through the partition index so churn/sharding never change
            # which data shard a profile delivers to.  Sparse producers
            # with live_ids already speak global ids.
            if sparse:
                qids = results.matching_queries(i)
                if results.live_ids is None:
                    qids = live[qids]
            else:
                qids = live[results[i].matching_queries()]
            if len(qids) == 0:
                if self.keep_unmatched:
                    out.append(RoutedDocument(doc, qids, 0, nb))
                continue
            for shard in np.unique(self.shard_of_profile[qids]):
                mine = qids[self.shard_of_profile[qids] == shard]
                out.append(RoutedDocument(doc, mine, int(shard), nb))
        return out

    # ------------------------------------------------------------- metrics
    def selectivity(self, docs: list[EventStream]) -> float:
        """Fraction of (doc, profile) pairs that match — workload stat.

        Read-only: does not count toward :meth:`throughput`."""
        return self._filter_batch(list(docs), record=False).selectivity()

    def throughput(self) -> dict:
        """Cumulative filtering throughput over everything routed so far.

        Per-axis view: ``mesh_data``/``mesh_model`` are the *placed*
        mesh axis sizes (the requested shard counts shrink to what the
        host can place — see ``make_filter_mesh``);
        ``docs_per_s_per_data_shard`` is each document replica's share
        of the stream, and ``queries_per_model_shard`` each device's
        slice of the subscription set.
        """
        s = self.stats
        dt = max(s["seconds"], 1e-9)
        axes = dict(self.mesh.shape) if self.mesh is not None else {}
        mesh_data = axes.get("data", 1)
        mesh_model = axes.get("model", 1)
        n_live = len(self._gids)
        return {
            "engine": self.engine,
            "query_shards": self.query_shards,
            "data_shards": self.data_shards,
            "mesh_data": mesh_data,
            "mesh_model": mesh_model,
            "docs": s["docs"],
            "docs_per_s": s["docs"] / dt,
            "docs_per_s_per_data_shard": s["docs"] / dt / mesh_data,
            "queries_per_model_shard": -(-n_live // max(mesh_model, 1)),
            "mb_per_s": s["bytes"] / 1e6 / dt,
            "put_s": s["put_seconds"],
            "overlapped_batches": s["overlapped_batches"],
            "selectivity": s["pair_matches"] / max(s["pairs"], 1),
        }
