"""Deterministic synthetic LM token pipeline.

Generates reproducible token batches for the training examples / smoke
tests without external data: a per-shard counter-based PRNG (threefry via
jax would pull device state; we use numpy Philox keyed by (seed, step,
shard)) so every data-parallel shard sees a disjoint stream and restarts
are exactly resumable from the step counter — the property checkpoint
restore relies on.

Optionally the stream is fed from the XML filter stage: documents that
match routing profiles are serialized (paper-format bytes) and tokenized
at the byte level — the pub-sub path feeding the LM, end to end.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Iterator

import numpy as np

from ..core.events import EventStream, encode_bytes


@dataclass
class TokenPipeline:
    vocab: int
    batch: int            # per-host batch (sequences)
    seq_len: int
    seed: int = 0
    shard: int = 0
    n_shards: int = 1

    def batch_at(self, step: int) -> dict[str, np.ndarray]:
        """Batch for a given step — pure function of (seed, step, shard)."""
        bits = np.random.Philox(
            key=np.uint64(self.seed),
            counter=[0, 0, np.uint64(self.shard), np.uint64(step)])
        rng = np.random.Generator(bits)
        tokens = rng.integers(
            0, self.vocab, size=(self.batch, self.seq_len + 1),
            dtype=np.int32)
        return {"tokens": tokens[:, :-1], "labels": tokens[:, 1:]}

    def __iter__(self) -> Iterator[dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


@dataclass
class XMLBytePipeline:
    """Byte-level tokens from filtered XML documents (filter stage output).

    Tokens are raw bytes of the paper-format serialized documents (vocab
    256), padded/packed to seq_len.  Demonstrates the paper's filter as
    the ingest stage of LM training (examples/train_lm.py --data-filter).

    Input is either parsed event streams (``docs``, serialized here) or
    raw wire-byte payloads (``payloads``) — the latter is what
    :meth:`from_filtered_bytes` produces: payloads routed through
    ``FilterStage.route_bytes`` (parsed *and* filtered on device) with
    only the matched documents kept, so the whole ingest side of the LM
    pipeline is the paper's same-chip dataflow.
    """

    docs: list[EventStream] | None
    batch: int
    seq_len: int
    text_fill: int = 4
    payloads: list[bytes] | None = None

    def __post_init__(self) -> None:
        if (self.docs is None) == (self.payloads is None):
            raise ValueError("pass exactly one of docs= or payloads=")
        bufs = (self.payloads if self.payloads is not None else
                [encode_bytes(d, text_fill=self.text_fill)
                 for d in self.docs])
        self._buf = np.concatenate(
            [np.frombuffer(b, np.uint8) for b in bufs]).astype(np.int32)

    @classmethod
    def from_filtered_bytes(cls, payloads: list[bytes], stage, batch: int,
                            seq_len: int) -> "XMLBytePipeline":
        """Device-filter raw payloads, keep the matched ones, tokenize.

        ``stage`` is a :class:`~repro.data.filter_stage.FilterStage`;
        payloads that match no standing profile are dropped (unless the
        stage keeps unmatched docs), exactly like pub-sub delivery.
        """
        keep = sorted({r.doc_index for routed in stage.route_bytes(payloads)
                       for r in routed})
        kept = [payloads[i] for i in keep]
        if not kept:
            raise ValueError("no payloads matched the standing profiles")
        return cls(docs=None, batch=batch, seq_len=seq_len, payloads=kept)

    def batch_at(self, step: int) -> dict[str, np.ndarray]:
        need = self.batch * (self.seq_len + 1)
        start = (step * need) % max(1, len(self._buf) - need - 1)
        chunk = self._buf[start:start + need]
        if len(chunk) < need:
            chunk = np.pad(chunk, (0, need - len(chunk)))
        tok = chunk.reshape(self.batch, self.seq_len + 1)
        return {"tokens": tok[:, :-1], "labels": tok[:, 1:]}
