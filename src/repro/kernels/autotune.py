"""Measured megakernel autotune: timed search + persisted config cache.

:meth:`repro.core.engines.base.FilterEngine.autotune_blocks` picks the
megakernel launch shape from a *static* VMEM/SMEM budget formula — a safe
default, but blind to everything the formula cannot see (DMA latency vs
compute overlap, grid iteration order, packing density).  This module
closes the loop the way every serious kernel library does:

* :func:`search` — run the actual one-launch bytes→verdict engine over a
  representative workload for every candidate ``(blk, byte_chunk,
  grid_order, segment_target)`` combination, best-of-``trials`` wall
  clock each, and return the fastest.
* a tiny **JSON cache** keyed by plan shape
  (:func:`plan_key`: backend × padded states × tags × depth × word
  multiple) and persisted at :func:`cache_path` (the
  ``REPRO_AUTOTUNE_CACHE`` env var, default
  ``~/.cache/repro/autotune.json``) — engines constructed with
  ``autotune="measured"`` overlay the cached best config at ``plan()``
  time (:meth:`repro.core.engines.streaming.StreamingEngine.kernel_config`),
  so the search cost is paid once per plan shape per machine.

CLI (exercised by CI with a 2-trial cap under interpret)::

    python -m repro.kernels.autotune --queries 64 --trials 2

Writes/updates the cache and prints the per-candidate timings as JSON.

Migration note: :data:`KEY_VERSION` 2 added the fused-sparse-epilogue
``ep_tile`` dimension; v1 keys (no ``v…:`` prefix) are simply never read
again, so stale ``(blk, byte_chunk, grid_order, segment_target)``
entries can't mis-configure the fused kernel — re-run the search to
repopulate.
"""
from __future__ import annotations

import argparse
import itertools
import json
import os
import tempfile
import time
from typing import Any, Mapping, Sequence

CACHE_ENV = "REPRO_AUTOTUNE_CACHE"
DEFAULT_CACHE = "~/.cache/repro/autotune.json"

#: bumped whenever the tunable-config schema changes (v2: ``ep_tile``);
#: part of every :func:`plan_key`, so old-schema entries miss cleanly
KEY_VERSION = 2

#: candidate grids for the measured search (kept small: the search is
#: measured, so every candidate costs a compile + ``trials`` timed runs)
DEFAULT_BLKS = (32, 64, 128)
DEFAULT_BYTE_CHUNKS = (128, 256, 512)
DEFAULT_GRID_ORDERS = ("bg", "gb")
DEFAULT_SEGMENT_TARGETS = (2048, 4096)
DEFAULT_EP_TILES = (8, 32)


# ------------------------------------------------------------------- cache
def cache_path(path: str | None = None) -> str:
    """Resolve the cache file: explicit arg → env var → default."""
    return os.path.expanduser(
        path or os.environ.get(CACHE_ENV) or DEFAULT_CACHE)


def plan_key(backend: str, n_states: int, n_tags: int, max_depth: int,
             state_multiple: int) -> str:
    """Cache key: everything the launch shape may legitimately depend
    on, nothing it must not (batch contents, query text) — prefixed by
    :data:`KEY_VERSION` so schema changes invalidate old entries."""
    return (f"v{KEY_VERSION}:{backend}:s{int(n_states)}:t{int(n_tags)}"
            f":d{int(max_depth)}:w{int(state_multiple)}")


def load_cache(path: str | None = None) -> dict[str, Any]:
    """Read the cache file ({} on missing/corrupt — never raises)."""
    p = cache_path(path)
    try:
        with open(p) as fh:
            data = json.load(fh)
    except (OSError, ValueError):
        return {}
    entries = data.get("entries")
    return entries if isinstance(entries, dict) else {}


def save_cache(entries: Mapping[str, Any],
               path: str | None = None) -> str:
    """Atomically persist the cache (tmp file + rename)."""
    p = cache_path(path)
    os.makedirs(os.path.dirname(p) or ".", exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=os.path.dirname(p) or ".",
                               suffix=".tmp")
    try:
        with os.fdopen(fd, "w") as fh:
            json.dump({"version": 1, "entries": dict(entries)}, fh,
                      indent=2, sort_keys=True)
        os.replace(tmp, p)
    finally:
        if os.path.exists(tmp):
            os.unlink(tmp)
    return p


def cached_config(key: str, path: str | None = None) -> dict | None:
    """Best known config for ``key`` (None on miss) — what
    ``autotune="measured"`` engines overlay at plan time."""
    entry = load_cache(path).get(key)
    if isinstance(entry, dict) and "config" in entry:
        return dict(entry["config"])
    return None


# ------------------------------------------------------------------ search
def _time_engine(eng, bb, trials: int) -> float:
    """Best-of-``trials`` wall seconds for one packed filter_bytes call
    plus one packed sparse call (the fused-epilogue path — the
    ``ep_tile`` dimension only matters there); the first, untimed calls
    pay compilation."""
    eng.filter_bytes(bb, pack=True)
    eng.filter_bytes_sparse(bb, pack=True)
    best = float("inf")
    for _ in range(max(1, trials)):
        t0 = time.perf_counter()
        eng.filter_bytes(bb, pack=True)
        eng.filter_bytes_sparse(bb, pack=True)
        best = min(best, time.perf_counter() - t0)
    return best


def search(nfa, dictionary, bb, *, max_depth: int | None = None,
           blks: Sequence[int] = DEFAULT_BLKS,
           byte_chunks: Sequence[int] = DEFAULT_BYTE_CHUNKS,
           grid_orders: Sequence[str] = DEFAULT_GRID_ORDERS,
           segment_targets: Sequence[int] = DEFAULT_SEGMENT_TARGETS,
           ep_tiles: Sequence[int] = DEFAULT_EP_TILES,
           trials: int = 3, interpret: bool | None = None,
           cache: bool = True, cache_file: str | None = None
           ) -> tuple[dict, list[dict]]:
    """Measured search over the megakernel launch shape.

    Times the REAL one-launch bytes path (``filter_bytes(pack=True)``)
    on ``bb`` for every feasible candidate, returns ``(best, rows)``
    where ``rows`` carries every candidate's config + seconds (or its
    skip reason), and — with ``cache=True`` — persists the winner under
    this plan shape's :func:`plan_key`.
    """
    from ..core import engines
    from ..core.engines.base import _round_up
    from ..kernels import interpret_default
    from ..kernels.parse import DEFAULT_MAX_DEPTH

    if max_depth is None:
        max_depth = DEFAULT_MAX_DEPTH
    rows: list[dict] = []
    best: dict | None = None
    for blk, bc, go, st, ep in itertools.product(
            blks, byte_chunks, grid_orders, segment_targets, ep_tiles):
        cfg = {"blk": int(blk), "byte_chunk": int(bc),
               "grid_order": str(go), "segment_target": int(st),
               "ep_tile": int(ep)}
        try:
            eng = engines.create(
                "streaming", nfa, dictionary=dictionary,
                kernel="pallas", kernel_interpret=interpret,
                max_depth=max_depth, pack=True, **cfg)
            secs = _time_engine(eng, bb, trials)
        except Exception as e:  # infeasible layout (blk too small, …)
            rows.append({**cfg, "skipped": f"{type(e).__name__}: {e}"})
            continue
        row = {**cfg, "seconds": secs}
        rows.append(row)
        if best is None or secs < best["seconds"]:
            best = row
    if best is None:
        raise RuntimeError("autotune: no feasible candidate "
                           f"(tried {len(rows)}; see rows for reasons)")
    if cache:
        backend = ("interpret"
                   if (interpret if interpret is not None
                       else interpret_default())
                   else "compiled")
        key = plan_key(backend, _round_up(nfa.n_states, 32), nfa.n_tags,
                       max_depth, 32)
        entries = load_cache(cache_file)
        entries[key] = {
            "config": {k: best[k] for k in
                       ("blk", "byte_chunk", "grid_order",
                        "segment_target", "ep_tile")},
            "seconds": best["seconds"],
            "trials": int(trials),
            "timestamp": time.time(),
        }
        save_cache(entries, cache_file)
    return best, rows


# --------------------------------------------------------------------- CLI
def _int_list(s: str) -> tuple[int, ...]:
    return tuple(int(x) for x in s.split(",") if x.strip())


def main(argv: Sequence[str] | None = None) -> int:
    from ..core.dictionary import TagDictionary
    from ..core.events import ByteBatch
    from ..core.nfa import compile_queries
    from ..data.generator import DTD, gen_corpus, gen_profiles

    ap = argparse.ArgumentParser(
        description="measured megakernel autotune search")
    ap.add_argument("--queries", type=int, default=64)
    ap.add_argument("--n-tags", type=int, default=24)
    ap.add_argument("--docs", type=int, default=16)
    ap.add_argument("--nodes", type=int, default=60)
    ap.add_argument("--text-fill", type=int, default=4)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--trials", type=int, default=3)
    ap.add_argument("--blks", type=_int_list, default=DEFAULT_BLKS)
    ap.add_argument("--byte-chunks", type=_int_list,
                    default=DEFAULT_BYTE_CHUNKS)
    ap.add_argument("--grid-orders",
                    type=lambda s: tuple(x for x in s.split(",") if x),
                    default=DEFAULT_GRID_ORDERS)
    ap.add_argument("--segment-targets", type=_int_list,
                    default=DEFAULT_SEGMENT_TARGETS)
    ap.add_argument("--ep-tiles", type=_int_list, default=DEFAULT_EP_TILES)
    ap.add_argument("--cache", default=None,
                    help=f"cache file (default ${CACHE_ENV} or "
                         f"{DEFAULT_CACHE})")
    args = ap.parse_args(argv)

    dtd = DTD.generate(n_tags=args.n_tags, seed=args.seed)
    d = TagDictionary()
    dtd.register(d)
    qs = gen_profiles(dtd, n=args.queries, length=4, p_wild=0.1,
                      p_desc=0.3, seed=args.seed)
    nfa = compile_queries(qs, d, shared=True)
    # skewed lengths on purpose: packing quality is part of what the
    # segment_target dimension is tuned against
    docs = (gen_corpus(dtd, n_docs=max(1, args.docs // 4),
                       nodes_per_doc=args.nodes, seed=args.seed)
            + gen_corpus(dtd, n_docs=args.docs - max(1, args.docs // 4),
                         nodes_per_doc=max(2, args.nodes // 8),
                         seed=args.seed + 1))
    bb = ByteBatch.from_streams(docs, text_fill=args.text_fill, bucket=256)
    best, rows = search(
        nfa, d, bb, blks=args.blks, byte_chunks=args.byte_chunks,
        grid_orders=args.grid_orders, segment_targets=args.segment_targets,
        ep_tiles=args.ep_tiles, trials=args.trials, cache_file=args.cache)
    print(json.dumps({"best": best, "rows": rows,
                      "cache": cache_path(args.cache)}, indent=2))
    return 0


if __name__ == "__main__":  # pragma: no cover
    raise SystemExit(main())
