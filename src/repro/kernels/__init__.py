"""Pallas TPU kernels for the filtering hot spots.

* :mod:`.predecode`      -- byte->event character pre-decode (paper 3.4),
                            batched; host oracles: ref.predecode,
                            core.events.decode_bytes
* :mod:`.parse`          -- device-resident byte->EventBatch parsing
                            (compaction, depth scan, parent stacks);
                            host oracle: EventBatch.from_streams
* :mod:`.nfa_transition` -- levelwise NFA transition (2 matmuls + mask);
                            host oracle: ref.nfa_transition
* :mod:`.stream_filter`  -- batched bit-packed streaming megakernel
                            (docs x word-blocks grid, packed VMEM stack,
                            SMEM event chunks); host oracles:
                            ref.stream_filter_words (one block) and the
                            StreamingEngine kernel="scan" path (end to
                            end)
* :mod:`.blocks`         -- word-aligned parent-closed state-block
                            layout the megakernel consumes
* :mod:`.ops`            -- jit'd public wrappers (+ interpret switch)
* :mod:`.ref`            -- pure-jnp oracles (tests assert allclose)

Kernel selection: every ``*_pallas`` entry point takes
``interpret=None``, which auto-detects from the backend — compiled on
TPU, interpreter everywhere else (overridable with the
``REPRO_PALLAS_INTERPRET`` env var; see :func:`interpret_default`).
"""
from __future__ import annotations

import os


def interpret_default() -> bool:
    """Should Pallas kernels run in interpret mode on this backend?

    ``REPRO_PALLAS_INTERPRET=0/1`` forces it; otherwise interpret
    everywhere except a real TPU backend (the kernels are written for
    TPU and validated via the interpreter on CPU).
    """
    env = os.environ.get("REPRO_PALLAS_INTERPRET")
    if env is not None:
        return env not in ("0", "false", "False")
    import jax

    return jax.default_backend() != "tpu"
