"""Pallas TPU kernels for the filtering hot spots.

* :mod:`.predecode`      -- byte->event character pre-decode (paper 3.4)
* :mod:`.nfa_transition` -- levelwise NFA transition (2 matmuls + mask)
* :mod:`.stream_filter`  -- FPGA-analogue streaming filter, VMEM stack
* :mod:`.ops`            -- jit'd public wrappers (+ interpret switch)
* :mod:`.ref`            -- pure-jnp oracles (tests assert allclose)
"""
