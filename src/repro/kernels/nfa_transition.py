"""Pallas kernel: levelwise NFA transition (the filtering hot loop).

One document level advances all W nodes × S states at once:

    src      = parent_rows @ P          -- parent-pointer gather on the MXU
    tagmatch = onehot(tags) @ REQ + wild -- §3.4 pre-decoder as a matmul
    next     = min(src*tagmatch + parent_rows*selfloop, 1) * valid

Tiling: grid (W/bw, S/bs).  Each program reads a (bw, S) strip of
parent_rows (full reduction dim for the P matmul — the NFA trie's parent
pointers may cross column tiles) and produces a (bw, bs) output tile.
VMEM working set per program ≈ bw·S + S·bs + T·bs floats; block sizes are
chosen so it stays under ~4 MB at S up to 8192 states.

Host oracle: :func:`repro.kernels.ref.nfa_transition` (pure jnp, same
signature); tests/test_kernels.py asserts exact agreement across
shape/tile sweeps.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(parent_ref, onehot_ref, req_ref, wild_ref, p1h_ref, self_ref,
            valid_ref, out_ref, *, bs: int):
    j = pl.program_id(1)
    parent_full = parent_ref[...]                        # (bw, S)
    src = jnp.dot(parent_full, p1h_ref[...],
                  preferred_element_type=jnp.float32)    # (bw, bs)
    tagmatch = jnp.dot(onehot_ref[...], req_ref[...],
                       preferred_element_type=jnp.float32) + wild_ref[...]
    parent_sub = jax.lax.dynamic_slice(
        parent_full, (0, j * bs), (parent_full.shape[0], bs))
    nxt = jnp.minimum(src * tagmatch + parent_sub * self_ref[...], 1.0)
    out_ref[...] = nxt * valid_ref[...]


@functools.partial(jax.jit, static_argnames=("bw", "bs", "interpret"))
def nfa_transition_pallas(parent_rows: jax.Array, tags: jax.Array,
                          req: jax.Array, wild: jax.Array,
                          parent_1h: jax.Array, selfloop: jax.Array,
                          *, bw: int = 128, bs: int = 512,
                          interpret: bool | None = None) -> jax.Array:
    """See :func:`repro.kernels.ref.nfa_transition` for semantics.

    ``interpret=None`` auto-detects from the backend (compiled on TPU,
    interpreter elsewhere).  Both the node axis (W) and the state axis
    (S) are padded up to the block grid; padding states are inert (no
    parent edge, REQ column zero) so the sliced-back result is exact.
    """
    from . import interpret_default

    if interpret is None:
        interpret = interpret_default()
    w, s = parent_rows.shape
    t = req.shape[0]
    bw = min(bw, max(8, w))
    bs = min(bs, s)
    w_pad, s_pad = -w % bw, -s % bs
    onehot = jax.nn.one_hot(tags, t, dtype=jnp.float32)
    valid = (tags >= 0).astype(jnp.float32)[:, None]
    if w_pad:
        parent_rows = jnp.pad(parent_rows, ((0, w_pad), (0, 0)))
        onehot = jnp.pad(onehot, ((0, w_pad), (0, 0)))
        valid = jnp.pad(valid, ((0, w_pad), (0, 0)))
    if s_pad:
        # grow the state axis with inert states: zero REQ/wild/selfloop
        # columns and no parent-one-hot edges ⇒ padding lanes stay 0.
        parent_rows = jnp.pad(parent_rows, ((0, 0), (0, s_pad)))
        req = jnp.pad(req, ((0, 0), (0, s_pad)))
        wild = jnp.pad(wild, (0, s_pad))
        selfloop = jnp.pad(selfloop, (0, s_pad))
        parent_1h = jnp.pad(parent_1h, ((0, s_pad), (0, s_pad)))
    wp, sp = parent_rows.shape
    grid = (wp // bw, sp // bs)
    out = pl.pallas_call(
        functools.partial(_kernel, bs=bs),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bw, sp), lambda i, j: (i, 0)),   # parent strip
            pl.BlockSpec((bw, t), lambda i, j: (i, 0)),    # onehot tags
            pl.BlockSpec((t, bs), lambda i, j: (0, j)),    # REQ tile
            pl.BlockSpec((1, bs), lambda i, j: (0, j)),    # wild
            pl.BlockSpec((sp, bs), lambda i, j: (0, j)),   # parent one-hot
            pl.BlockSpec((1, bs), lambda i, j: (0, j)),    # selfloop
            pl.BlockSpec((bw, 1), lambda i, j: (i, 0)),    # valid col
        ],
        out_specs=pl.BlockSpec((bw, bs), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((wp, sp), jnp.float32),
        interpret=interpret,
    )(parent_rows, onehot, req, wild[None, :], parent_1h,
      selfloop[None, :], valid)
    return out[:w, :s]
