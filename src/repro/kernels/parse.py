"""Device-resident byte→event parsing: the paper's same-chip parser.

The paper's central architectural claim (§1, §3.4) is that parser and
filter share one chip, so a document goes wire-bytes → verdict with no
host↔device hop.  This module is that parser for the TPU: a batch of raw
paper-format byte streams (:class:`repro.core.events.ByteBatch`) becomes
a fully structured :class:`repro.core.events.EventBatch` with *no
per-event host Python* —

1. **pre-decode** — every byte position classified in parallel into
   (kind, tag) by the batched Pallas kernel
   :func:`repro.kernels.predecode.predecode_pallas` (§3.4's character
   pre-decoder; possible because dictionary tags are fixed-length, §3.1);
2. **compaction** — the sparse per-position hits are packed into a dense
   event list by cumsum indexing (a masked stream compaction: position
   of event *i* = number of hits before it);
3. **depth** — a ``+1/-1`` prefix scan over open/close events, floored
   at zero exactly like a pop-on-empty stack (running sum minus its
   clipped running minimum);
4. **parent pointers** — the paper's §3.3 per-state stacks, vectorized:
   an associative scan carries "last open event seen at each depth", and
   every open event reads slot ``depth-1`` — stack virtualization with
   ``O(log N)`` depth instead of a sequential walk.

Host oracles: :meth:`repro.core.events.EventStream.structure` for
(depth, parent) and :meth:`repro.core.events.EventBatch.from_streams`
for the whole pipeline — ``parse_batch`` is bit-identical to it on every
well-formed corpus (tests/test_ingest.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from ..core.events import (DEFAULT_MAX_DEPTH, DepthOverflow,  # noqa: F401
                           EventBatch, ByteBatch, bucket_length)
from . import ref
from .predecode import predecode_pallas

# DEFAULT_MAX_DEPTH (re-exported above): depth bound for the vectorized
# parent-pointer stacks (matches the streaming engine's default bounded
# stack).  ``parse_batch`` *raises* ``DepthOverflow`` on deeper documents
# by default (``check_depth=True``) — pass a larger ``max_depth`` for
# deep corpora; only ``check_depth=False`` silently clips parents past
# the bound.


def fused_predecode(b0: jax.Array, b1: jax.Array, b2: jax.Array,
                    b3: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Positionwise byte classify → fused ``(kind<<16)|tag`` event words.

    The §3.4 character pre-decoder in the exact form the one-launch
    megakernel consumes (see
    :func:`repro.kernels.stream_filter.stream_filter_bytes_pallas`):
    ``b0..b3`` are the byte value and its three lookahead shifts (any
    matching shapes — the kernel passes ``(1, CHUNK)`` rows sliced from
    a VMEM chunk), and the result is bit-identical at every position to
    :func:`repro.kernels.ref.predecode` followed by
    :func:`repro.kernels.stream_filter.fuse_events` — the property the
    fused path's equivalence tests rest on.  Returns ``(fused, keep)``;
    positions with ``keep == False`` are PAD (no tag starts there) and
    carry the inert ``(PAD<<16) | 0xFFFF`` word.
    """
    is_lt = b0 == ref._LT
    is_close = is_lt & (b1 == ref._SLASH)
    is_open = is_lt & ~is_close
    s0 = jnp.where(is_close, b2, b1)
    s1 = jnp.where(is_close, b3, b2)
    v0, v1 = ref.symbol_value(s0), ref.symbol_value(s1)
    ok = (v0 >= 0) & (v1 >= 0)
    kind = jnp.where(is_open & ok, ref.OPEN,
                     jnp.where(is_close & ok, ref.CLOSE, ref.PAD))
    tag = jnp.where(kind != ref.PAD, v0 * 64 + v1, -1)
    fused = (kind.astype(jnp.int32) << 16) | (tag.astype(jnp.int32) & 0xFFFF)
    return fused, kind != ref.PAD


def compact_events(kind_pos: jax.Array, tag_pos: jax.Array,
                   n_events: int) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Masked stream compaction: per-position hits → dense event list.

    ``kind_pos``/``tag_pos`` are per *byte position* (PAD where no tag
    starts); the result is the first ``n_events`` true events in order,
    padded with PAD/-1.  The destination of each hit is the cumulative
    count of hits before it — pure cumsum indexing, no host loop.
    Positions beyond ``n_events`` (impossible when ``n_events ≥ L //
    OPEN_NBYTES``) are dropped.
    """
    keep = kind_pos != ref.PAD
    pos = jnp.cumsum(keep) - 1
    idx = jnp.where(keep, pos, n_events)  # n_events ⇒ out of range ⇒ drop
    kind = jnp.full((n_events,), ref.PAD, jnp.int8)
    kind = kind.at[idx].set(kind_pos.astype(jnp.int8), mode="drop")
    tag = jnp.full((n_events,), -1, jnp.int32)
    tag = tag.at[idx].set(tag_pos, mode="drop")
    # clamp so a too-small n_events yields a *consistent* truncated batch
    # (n_events ≤ length) rather than a count the arrays don't contain
    n = jnp.minimum(keep.sum(), n_events).astype(jnp.int32)
    return kind, tag, n


def structure_scan(kind: jax.Array, max_depth: int
                   ) -> tuple[jax.Array, jax.Array]:
    """Per-event (depth, parent) from the event-kind stream, on device.

    Host oracle: :meth:`repro.core.events.EventStream.structure` (the
    sequential stack walk).  Depth is the ``+1/-1`` running sum floored
    at zero (``s - min(cummin(s), 0)`` reproduces pop-on-empty).  Parents
    come from an associative scan over "last open event per depth"
    vectors — the stack, virtualized: a later open at depth *d* shadows
    any closed earlier one, so no pop/invalidate step is needed.
    """
    n = kind.shape[0]
    is_open = kind == ref.OPEN
    is_close = kind == ref.CLOSE
    delta = jnp.where(is_open, 1, jnp.where(is_close, -1, 0)).astype(jnp.int32)
    s = jnp.cumsum(delta)
    depth = (s - jnp.minimum(jax.lax.cummin(s), 0)).astype(jnp.int32)

    d_slots = max_depth + 2
    d_pub = jnp.clip(depth, 0, d_slots - 1)
    levels = jnp.arange(d_slots, dtype=jnp.int32)[None, :]
    event_idx = jnp.arange(n, dtype=jnp.int32)[:, None]
    pub = jnp.where(is_open[:, None] & (levels == d_pub[:, None]),
                    event_idx, -1)
    last_open_at = jax.lax.associative_scan(
        lambda a, b: jnp.where(b >= 0, b, a), pub, axis=0)
    lookup = jnp.clip(d_pub - 1, 0, d_slots - 1)
    parent = jnp.where(
        is_open,
        last_open_at[jnp.arange(n), lookup],
        -1).astype(jnp.int32)
    return depth, parent


def _predecode(data: jax.Array, use_kernel: bool | None,
               interpret: bool | None) -> tuple[jax.Array, jax.Array]:
    """Kernel selection for the pre-decode stage.

    Follows the package convention (cf. ``LevelwiseEngine(use_kernel=)``
    and :func:`repro.kernels.interpret_default`): the Pallas kernel on a
    real TPU, the bit-identical pure-jnp oracle (XLA-compiled) elsewhere
    — the Pallas *interpreter* is a correctness tool, not a fast path.
    ``use_kernel=True`` forces the kernel (tests pair it with
    ``interpret=True`` for interpreter coverage).
    """
    from . import interpret_default

    if use_kernel is None:
        use_kernel = not interpret_default()
    if use_kernel:
        return predecode_pallas(data, interpret=interpret)
    return ref.predecode(data)


@functools.partial(jax.jit, static_argnames=("n_events", "max_depth",
                                             "use_kernel", "interpret"))
def parse_arrays(data: jax.Array, *, n_events: int,
                 max_depth: int = DEFAULT_MAX_DEPTH,
                 use_kernel: bool | None = None,
                 interpret: bool | None = None):
    """jit core of :func:`parse_batch`: (B, L) bytes → EventBatch fields.

    One compiled program per (B, L, n_events) shape: batched pre-decode
    over all documents at once (Pallas kernel or its jnp oracle — see
    :func:`_predecode`), then vmapped compaction and structure scans.
    Returns ``(kind, tag, depth, parent, valid, n_per_doc)`` as device
    arrays.
    """
    kind_pos, tag_pos = _predecode(data, use_kernel, interpret)
    kind, tag, n_per_doc = jax.vmap(
        lambda k, t: compact_events(k, t, n_events))(kind_pos, tag_pos)
    depth, parent = jax.vmap(
        lambda k: structure_scan(k, max_depth))(kind)
    valid = kind != ref.PAD
    return kind, tag, depth, parent, valid, n_per_doc


def parse_batch(bb: ByteBatch, *, n_events: int | None = None,
                bucket: int | None = None,
                max_depth: int = DEFAULT_MAX_DEPTH,
                use_kernel: bool | None = None,
                interpret: bool | None = None,
                check_depth: bool = True) -> EventBatch:
    """Device parse: :class:`ByteBatch` → device-resident `EventBatch`.

    The returned batch holds jax arrays (``batch.is_device``) — device
    engines consume it with no host round-trip; host engines call
    ``batch.to_host()``.  ``n_events`` defaults to the static bound
    ``bb.max_events`` (optionally bucketed); pass the event length of a
    host-built batch to compare the two paths shape-for-shape.

    Parent pointers are exact only up to ``max_depth``;
    ``check_depth=True`` (default) verifies the batch against the bound
    and raises instead of silently clipping — one O(1) scalar sync, not
    a per-event host pass.  Pure device pipelines that guarantee the
    bound can pass ``check_depth=False``.
    """
    if n_events is None:
        n_events = bucket_length(bb.max_events, bucket)
    kind, tag, depth, parent, valid, n_per_doc = parse_arrays(
        jnp.asarray(bb.data), n_events=n_events, max_depth=max_depth,
        use_kernel=use_kernel, interpret=interpret)
    if check_depth:
        per_doc = jax.device_get(depth.max(axis=1))
        dmax = int(per_doc.max(initial=0))
        if dmax > max_depth:
            bad = [int(i) for i in (per_doc > max_depth).nonzero()[0]]
            raise DepthOverflow(
                f"document nesting depth {dmax} exceeds max_depth="
                f"{max_depth} (documents {bad}); re-parse with "
                f"parse_batch(..., max_depth={dmax}) or larger",
                doc_indices=bad)
    return EventBatch(kind, tag, depth, parent, valid, n_per_doc)
