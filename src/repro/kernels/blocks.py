"""State-block partitioners for the streaming filter megakernel.

The paper (§3.3) sorts the regexes alphabetically, clusters them into
common-prefix trees, and lays each cluster out as an independent hardware
region.  This module does the same at two levels:

* :func:`partition` — the original query-level flow: queries are sorted,
  greedily packed into blocks of ≤BLK NFA states (each block compiled as
  its own shared prefix trie, so parent pointers never cross a block).
  Blocks are **word-aligned** (BLK is rounded up to a multiple of 32) so
  the per-block state space always tiles into packed 32-bit words.
* :func:`state_layout` — the megakernel's layout: an already-compiled
  NFA is decomposed into its root-hanging subtrees (the prefix trie's
  natural fan-out), subtrees are first-fit-decreasing packed into
  word-aligned blocks closed under parent pointers (the root context
  state is replicated per block — it carries no dynamics, exactly like
  the FPGA replicating the stream interface per region), and every
  per-state table is emitted **bit-packed**: per-tag word masks, parent
  word/bit gather indices, self-loop/init words, and per-block accept
  lanes.  These are the tables
  :func:`repro.kernels.stream_filter.stream_filter_pallas` consumes.

Blocks never communicate — exactly the property that lets the paper tile
thousands of queries across FPGA regions and chips.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Sequence

import numpy as np

from ..core.dictionary import TagDictionary
from ..core.nfa import NFA, NEVER_TAG, WILD_TAG, compile_queries
from ..core.xpath import Query

WORD_BITS = 32


class PadOverflow(ValueError):
    """A uniform pad target (``n_blocks`` / ``block_queries``) is too
    small for the layout a plan actually needs.  Raised by
    :func:`state_layout`; the churn path (``ShardedPlan.add_queries``)
    catches it and falls back to a full replan at reconciled targets
    (``FilterEngine.merge_pads``)."""


def _round_up(n: int, multiple: int) -> int:
    multiple = max(1, int(multiple))
    return max(multiple, -(-int(n) // multiple) * multiple)


@dataclass
class BlockTables:
    in_tag: np.ndarray      # (G, BLK) int32
    wild: np.ndarray        # (G, BLK) f32
    selfloop: np.ndarray    # (G, BLK) f32
    init: np.ndarray        # (G, BLK) f32
    parent_1h: np.ndarray   # (G, BLK, BLK) f32
    accept_block: np.ndarray  # (Q,) int32 — block of each query's accept
    accept_local: np.ndarray  # (Q,) int32 — local state index
    query_order: np.ndarray   # (Q,) int32 — original index of sorted query q
    blk: int

    @property
    def n_blocks(self) -> int:
        return int(self.in_tag.shape[0])


def partition(queries: Sequence[Query], dictionary: TagDictionary,
              blk: int = 256) -> BlockTables:
    blk = _round_up(blk, WORD_BITS)  # word-aligned: BLK states = BLK/32 words
    order = sorted(range(len(queries)), key=lambda i: str(queries[i]))
    groups: list[list[int]] = []
    cur: list[int] = []
    for qi in order:
        trial = cur + [qi]
        nfa = compile_queries([queries[i] for i in trial], dictionary,
                              shared=True)
        if nfa.n_states > blk and cur:
            groups.append(cur)
            cur = [qi]
        else:
            cur = trial
    if cur:
        groups.append(cur)

    g = len(groups)
    in_tag = np.full((g, blk), NEVER_TAG, np.int32)
    wild = np.zeros((g, blk), np.float32)
    selfloop = np.zeros((g, blk), np.float32)
    init = np.zeros((g, blk), np.float32)
    p1h = np.zeros((g, blk, blk), np.float32)
    accept_block = np.zeros(len(queries), np.int32)
    accept_local = np.zeros(len(queries), np.int32)
    for gi, grp in enumerate(groups):
        nfa = compile_queries([queries[i] for i in grp], dictionary,
                              shared=True)
        if nfa.n_states > blk:
            raise ValueError(
                f"single query group exceeds block size {blk}: "
                f"{nfa.n_states} states")
        t = nfa.tables
        s = nfa.n_states
        in_tag[gi, :s] = t.in_tag
        wild[gi, :s] = (t.in_tag == WILD_TAG).astype(np.float32)
        selfloop[gi, :s] = t.selfloop
        init[gi, :s] = t.init
        p1h[gi, t.in_state, np.arange(s)] = 1.0
        for qq, acc in zip(grp, t.accept_state):
            accept_block[qq] = gi
            accept_local[qq] = acc
    return BlockTables(in_tag, wild, selfloop, init, p1h,
                       accept_block, accept_local,
                       np.asarray(order, np.int32), blk)


# -------------------------------------------------- megakernel state layout
@dataclass
class MegaBlockTables:
    """Bit-packed per-block tables for the streaming megakernel.

    ``G`` blocks of ``BLK`` states = ``WB = BLK/32`` packed words each;
    local state 0 of every block is its replica of the root context
    state.  ``QB`` accept lanes per block, the last lane of every block
    reserved and wired to the (never-activating) local root so padded
    query columns stay inert by construction.
    """

    tagmask: np.ndarray         # (G, T+1, WB) uint32 — per-tag match words;
    #                             row T is the wild-only row (out-of-range tags)
    pw: np.ndarray              # (G, WB, 32) int32 — parent *word* per state
    pb: np.ndarray              # (G, WB, 32) int32 — parent *bit* per state
    selfloop_words: np.ndarray  # (G, WB) uint32
    init_words: np.ndarray      # (G, WB) uint32
    acc_word: np.ndarray        # (G, QB) int32 — accept lane → local word
    acc_bit: np.ndarray         # (G, QB) int32 — accept lane → bit in word
    acc_block: np.ndarray       # (Q,) int32 — query → block
    acc_slot: np.ndarray        # (Q,) int32 — query → accept lane in block
    state_block: np.ndarray     # (S,) int32 — block of each NFA state (-1 =
    #                             inert pad state dropped; -2 = context
    #                             state replicated in every block)
    state_local: np.ndarray     # (S,) int32 — local index within the block
    context: np.ndarray         # (C,) int32 — replicated context states
    blk: int

    @property
    def n_blocks(self) -> int:
        return int(self.selfloop_words.shape[0])

    @property
    def words(self) -> int:
        return int(self.selfloop_words.shape[1])

    @property
    def block_queries(self) -> int:
        return int(self.acc_word.shape[1])


def _pack_bits(bits: np.ndarray) -> np.ndarray:
    """(..., W*32) bool/int → (..., W) uint32 packed words."""
    shaped = bits.reshape(bits.shape[:-1] + (-1, WORD_BITS)).astype(np.uint32)
    weights = np.uint32(1) << np.arange(WORD_BITS, dtype=np.uint32)
    return (shaped * weights).sum(axis=-1, dtype=np.uint32)


#: replicate at most this many context states per block — a *shared*
#: trie has ~1 (the root's `//` waiting state); an unshared (Unop) trie
#: has one per `//`-leading query, where replication would explode and
#: per-query subtrees are small anyway, so we fall back to root-only
CONTEXT_CAP = 8


def _context_states(t) -> np.ndarray:
    """Constant-on root-level waiting states, replicated like the root.

    A state with ``in_state == 0``, a NEVER in-tag, a self-loop and
    ``init`` (the compiled form of a leading ``//`` step) is active in
    *every* stack context: its transition reduces to ``nxt[s] =
    bits[s]`` and row 0 starts it on.  It carries no cross-state
    dynamics, so each block can keep its own copy — which is what lets
    the shared prefix trie (where every ``//tag`` profile hangs off ONE
    such state) split into independent blocks at all.
    """
    sid = np.arange(t.n_states)
    const_on = ((t.in_state == 0) & (t.in_tag == NEVER_TAG)
                & t.selfloop & t.init & (sid > 0))
    ctx = np.nonzero(const_on)[0].astype(np.int32)
    return ctx if len(ctx) <= CONTEXT_CAP else ctx[:0]


def _subtrees(nfa: NFA) -> tuple[np.ndarray, dict[int, list[int]]]:
    """Context-hanging subtree decomposition of the single-parent trie.

    Returns the replicated context states and the member lists per live
    subtree: a subtree root is any non-context state whose parent is the
    root or a context state (parents always precede children in the
    builder's numbering, so one forward pass suffices).  Inert padding
    singletons (NEVER tag, no self-loop, not init, no accept) are
    dropped — they can never activate, so leaving them out of the block
    layout cannot change any verdict.
    """
    t = nfa.tables
    s = t.n_states
    ctx = _context_states(t)
    in_ctx = np.zeros(s, bool)
    in_ctx[0] = True
    in_ctx[ctx] = True
    top = np.full(s, -1, np.int32)
    for i in range(1, s):
        if in_ctx[i]:
            continue
        p = int(t.in_state[i])
        top[i] = i if in_ctx[p] else top[p]
    groups: dict[int, list[int]] = {}
    for i in range(1, s):
        if top[i] >= 0:
            groups.setdefault(int(top[i]), []).append(i)
    has_accept = np.zeros(s, bool)
    acc = t.accept_state[(t.accept_state >= 0) & (t.accept_state < s)]
    has_accept[acc] = True
    live = {
        tid: members for tid, members in groups.items()
        if not (len(members) == 1 and t.in_tag[tid] == NEVER_TAG
                and not t.selfloop[tid] and not t.init[tid]
                and not has_accept[tid])
    }
    return ctx, live


def min_block_size(nfa: NFA) -> int:
    """Smallest word-aligned BLK that fits this NFA's largest subtree
    (local slots are always reserved for the block's root + context
    replicas)."""
    ctx, live = _subtrees(nfa)
    largest = max((len(m) for m in live.values()), default=0)
    return _round_up(largest + 1 + len(ctx), WORD_BITS)


def state_layout(nfa: NFA, blk: int = 256, *,
                 n_blocks: int | None = None,
                 block_queries: int | None = None) -> MegaBlockTables:
    """Decompose a compiled NFA into word-aligned parent-closed blocks.

    ``blk`` is rounded up to a multiple of 32 and auto-grown when a
    single subtree does not fit; ``n_blocks``/``block_queries`` pad the
    block and accept-lane axes to uniform targets (sharded plans stack
    per-part tables along a leading axis, so every part must agree on
    ``(G, QB)`` — see ``StreamingEngine.part_pads``).
    """
    t = nfa.tables
    s = t.n_states
    ctx, live = _subtrees(nfa)
    largest = max((len(m) for m in live.values()), default=0)
    blk = max(_round_up(blk, WORD_BITS),
              _round_up(largest + 1 + len(ctx), WORD_BITS))
    cap = blk - 1 - len(ctx)  # slot 0 = root replica, then context replicas

    # first-fit decreasing, deterministic: heaviest subtrees first,
    # ties broken by subtree-root state id
    order = sorted(live.items(), key=lambda kv: (-len(kv[1]), kv[0]))
    bins: list[list[int]] = []
    loads: list[int] = []
    for _tid, members in order:
        for bi in range(len(bins)):
            if loads[bi] + len(members) <= cap:
                bins[bi].extend(members)
                loads[bi] += len(members)
                break
        else:
            bins.append(list(members))
            loads.append(len(members))
    g = max(1, len(bins))
    if n_blocks is not None:
        if len(bins) > n_blocks:
            raise PadOverflow(
                f"layout needs {len(bins)} blocks but n_blocks="
                f"{n_blocks} was requested")
        g = max(g, int(n_blocks))
    wb = blk // WORD_BITS

    # local per-block tables: slot 0 = root replica, slots 1..C = context
    # replicas (identical in every block — they carry no cross-state
    # dynamics), then the block's subtrees; unused slots stay inert
    state_block = np.full(s, -1, np.int32)
    state_local = np.zeros(s, np.int32)
    l_in_state = np.zeros((g, blk), np.int32)
    l_in_tag = np.full((g, blk), NEVER_TAG, np.int32)
    l_selfloop = np.zeros((g, blk), bool)
    l_init = np.zeros((g, blk), bool)
    l_init[:, 0] = bool(t.init[0])  # the root context is active at depth 0
    for j, cs in enumerate(sorted(int(c) for c in ctx)):
        loc = j + 1
        state_block[cs] = -2  # replicated: lives in every block
        state_local[cs] = loc
        l_in_tag[:, loc] = t.in_tag[cs]
        l_selfloop[:, loc] = t.selfloop[cs]
        l_init[:, loc] = t.init[cs]
    base = 1 + len(ctx)
    for gi, members in enumerate(bins):
        members = sorted(members)  # ascending global id ⇒ parents first
        for j, gs in enumerate(members):
            loc = base + j
            state_block[gs] = gi
            state_local[gs] = loc
            l_in_tag[gi, loc] = t.in_tag[gs]
            l_selfloop[gi, loc] = t.selfloop[gs]
            l_init[gi, loc] = t.init[gs]
        for gs in members:
            p = int(t.in_state[gs])
            l_in_state[gi, state_local[gs]] = 0 if p == 0 else state_local[p]

    # bit-packed tables: per-tag word masks (+ one wild-only row for
    # out-of-range tags), parent word/bit gather indices, state words
    n_tags = int(nfa.n_tags)
    wild_words = _pack_bits(l_in_tag == WILD_TAG)           # (G, WB)
    tagmask = np.repeat(wild_words[:, None, :], n_tags + 1, axis=1)
    gg, jj = np.nonzero(l_in_tag >= 0)
    tags = l_in_tag[gg, jj]
    valid = tags < n_tags
    gg, jj, tags = gg[valid], jj[valid], tags[valid]
    np.bitwise_or.at(
        tagmask, (gg, tags, jj // WORD_BITS),
        np.uint32(1) << (jj % WORD_BITS).astype(np.uint32))
    pw = (l_in_state >> 5).reshape(g, wb, WORD_BITS).astype(np.int32)
    pb = (l_in_state & 31).reshape(g, wb, WORD_BITS).astype(np.int32)

    # accept lanes: queries grouped by owning block; the mapping is
    # many-to-one — queries sharing an accept state (minimized automata,
    # duplicate subscriber profiles) share ONE lane, so the verdict width
    # QB is bounded by distinct accept states (≤ BLK), not by Q.  Lane
    # QB-1 of every block is reserved (wired to the inert local root)
    # for padded columns.
    nq = int(t.accept_state.shape[0])
    acc_block = np.zeros(nq, np.int32)
    acc_slot = np.zeros(nq, np.int32)
    counts = np.zeros(g, np.int32)
    lanes: list[list[tuple[int, int]]] = [[] for _ in range(g)]
    lane_of: dict[int, tuple[int, int]] = {}  # accept state → (block, lane)
    for q in range(nq):
        a = int(t.accept_state[q])
        if a <= 0 or state_block[a] < 0:  # root/pad accept: inert column
            acc_block[q] = 0
            acc_slot[q] = -1  # patched to QB-1 below
            continue
        if a in lane_of:
            acc_block[q], acc_slot[q] = lane_of[a]
            continue
        gi = int(state_block[a])
        acc_block[q] = gi
        acc_slot[q] = counts[gi]
        lanes[gi].append((int(counts[gi]), int(state_local[a])))
        lane_of[a] = (gi, int(counts[gi]))
        counts[gi] += 1
    qb = int(counts.max(initial=0)) + 1
    if block_queries is not None:
        if qb > int(block_queries):
            raise PadOverflow(
                f"layout needs {qb} accept lanes but block_queries="
                f"{block_queries} was requested")
        qb = int(block_queries)
    acc_slot[acc_slot < 0] = qb - 1
    acc_word = np.zeros((g, qb), np.int32)
    acc_bit = np.zeros((g, qb), np.int32)
    for gi in range(g):
        for slot, loc in lanes[gi]:
            acc_word[gi, slot] = loc >> 5
            acc_bit[gi, slot] = loc & 31

    return MegaBlockTables(
        tagmask=tagmask, pw=pw, pb=pb,
        selfloop_words=_pack_bits(l_selfloop),
        init_words=_pack_bits(l_init),
        acc_word=acc_word, acc_bit=acc_bit,
        acc_block=acc_block, acc_slot=acc_slot,
        state_block=state_block, state_local=state_local,
        context=np.asarray(sorted(int(c) for c in ctx), np.int32), blk=blk)
