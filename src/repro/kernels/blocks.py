"""State-block partitioner for the streaming filter kernel.

The paper (§3.3) sorts the regexes alphabetically, clusters them into
common-prefix trees, and lays each cluster out as an independent hardware
region.  We do the same: queries are sorted, greedily packed into blocks of
≤BLK NFA states (each block compiled as its own shared prefix trie, so
parent pointers never cross a block), and the per-block tables are stacked
into the (G, BLK, ...) arrays the kernel consumes.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Sequence

import numpy as np

from ..core.dictionary import TagDictionary
from ..core.nfa import NFA, WILD_TAG, compile_queries, pad_states
from ..core.xpath import Query


@dataclass
class BlockTables:
    in_tag: np.ndarray      # (G, BLK) int32
    wild: np.ndarray        # (G, BLK) f32
    selfloop: np.ndarray    # (G, BLK) f32
    init: np.ndarray        # (G, BLK) f32
    parent_1h: np.ndarray   # (G, BLK, BLK) f32
    accept_block: np.ndarray  # (Q,) int32 — block of each query's accept
    accept_local: np.ndarray  # (Q,) int32 — local state index
    query_order: np.ndarray   # (Q,) int32 — original index of sorted query q
    blk: int

    @property
    def n_blocks(self) -> int:
        return int(self.in_tag.shape[0])


def partition(queries: Sequence[Query], dictionary: TagDictionary,
              blk: int = 256) -> BlockTables:
    order = sorted(range(len(queries)), key=lambda i: str(queries[i]))
    groups: list[list[int]] = []
    cur: list[int] = []
    for qi in order:
        trial = cur + [qi]
        nfa = compile_queries([queries[i] for i in trial], dictionary,
                              shared=True)
        if nfa.n_states > blk and cur:
            groups.append(cur)
            cur = [qi]
        else:
            cur = trial
    if cur:
        groups.append(cur)

    g = len(groups)
    in_tag = np.full((g, blk), -3, np.int32)   # NEVER
    wild = np.zeros((g, blk), np.float32)
    selfloop = np.zeros((g, blk), np.float32)
    init = np.zeros((g, blk), np.float32)
    p1h = np.zeros((g, blk, blk), np.float32)
    accept_block = np.zeros(len(queries), np.int32)
    accept_local = np.zeros(len(queries), np.int32)
    for gi, grp in enumerate(groups):
        nfa = compile_queries([queries[i] for i in grp], dictionary,
                              shared=True)
        if nfa.n_states > blk:
            raise ValueError(
                f"single query group exceeds block size {blk}: "
                f"{nfa.n_states} states")
        t = nfa.tables
        s = nfa.n_states
        in_tag[gi, :s] = t.in_tag
        wild[gi, :s] = (t.in_tag == WILD_TAG).astype(np.float32)
        selfloop[gi, :s] = t.selfloop
        init[gi, :s] = t.init
        p1h[gi, t.in_state, np.arange(s)] = 1.0
        # zero out the padding columns' parent edges (they stay inert via
        # NEVER tags anyway) and the root self-edge contribution
        for qq, acc in zip(grp, t.accept_state):
            accept_block[qq] = gi
            accept_local[qq] = acc
    return BlockTables(in_tag, wild, selfloop, init, p1h,
                       accept_block, accept_local,
                       np.asarray(order, np.int32), blk)
