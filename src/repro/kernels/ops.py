"""Public jit'd wrappers around the Pallas kernels.

Kernel selection (interpret vs compiled) is auto-detected from the
backend by :func:`repro.kernels.interpret_default`: interpret mode
everywhere except a real TPU (this container is CPU-only; the kernels
are written for TPU and validated in interpret mode, per the
hardware-adaptation notes in DESIGN.md).  ``REPRO_PALLAS_INTERPRET=0/1``
overrides.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from ..core.dictionary import TagDictionary
from ..core.engines.result import FilterResult
from ..core.events import EventStream
from ..core.xpath import Query
from . import ref
from .nfa_transition import nfa_transition_pallas
from .parse import DEFAULT_MAX_DEPTH
from .predecode import predecode_pallas


def predecode(bytes_: jax.Array) -> tuple[jax.Array, jax.Array]:
    return predecode_pallas(jnp.asarray(bytes_))


def nfa_transition(parent_rows, tags, req, wild, parent_1h, selfloop,
                   **kw):
    # pick bs dividing S when possible (states are padded to 128 lanes);
    # the kernel pads the state axis itself otherwise
    s = parent_rows.shape[-1]
    kw.setdefault("bs", min(512, s) if s % min(512, s) == 0 else 128)
    return nfa_transition_pallas(parent_rows, tags, req, wild, parent_1h,
                                 selfloop, **kw)


def decode_document(buf: bytes, dictionary: TagDictionary) -> EventStream:
    """Byte stream → EventStream via the predecode kernel + compaction."""
    arr = jnp.asarray(np.frombuffer(buf, dtype=np.uint8))
    kind, tag = predecode(arr)
    kind, tag = np.asarray(kind), np.asarray(tag)
    keep = kind != ref.PAD
    return EventStream(kind[keep].astype(np.int8), tag[keep])


class StreamFilterKernelEngine:
    """End-to-end engine on the streaming megakernel (Fig 5 layout).

    Queries are compiled to one shared NFA, decomposed into parent-closed
    word-aligned state blocks (:func:`repro.kernels.blocks.state_layout`)
    and advanced over the event stream inside one pallas_call; accept
    lanes map back to query ids (the output priority encoder).  A thin
    demo wrapper over ``StreamingEngine(kernel="pallas")`` — the full
    engine (batched, sharded, byte-fused) lives there.
    """

    def __init__(self, queries: list[Query], dictionary: TagDictionary,
                 blk: int = 256,
                 max_depth: int = DEFAULT_MAX_DEPTH) -> None:
        from ..core.engines.streaming import StreamingEngine
        from ..core.nfa import compile_queries

        self.max_depth = max_depth
        self._eng = StreamingEngine(
            compile_queries(list(queries), dictionary, shared=True),
            dictionary, max_depth=max_depth, kernel="pallas", blk=blk)
        self.n_queries = self._eng.n_queries

    def filter_document(self, ev: EventStream) -> FilterResult:
        from ..core.events import EventBatch

        return self._eng.filter_batch(EventBatch.from_streams([ev]))[0]
