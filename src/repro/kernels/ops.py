"""Public jit'd wrappers around the Pallas kernels.

Kernel selection (interpret vs compiled) is auto-detected from the
backend by :func:`repro.kernels.interpret_default`: interpret mode
everywhere except a real TPU (this container is CPU-only; the kernels
are written for TPU and validated in interpret mode, per the
hardware-adaptation notes in DESIGN.md).  ``REPRO_PALLAS_INTERPRET=0/1``
overrides.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from ..core.dictionary import TagDictionary
from ..core.engines.result import NO_MATCH, FilterResult
from ..core.events import EventStream
from ..core.xpath import Query
from . import blocks as blocks_mod
from . import interpret_default as _interpret_default
from . import ref
from .nfa_transition import nfa_transition_pallas
from .predecode import predecode_pallas
from .stream_filter import stream_filter_pallas


def predecode(bytes_: jax.Array) -> tuple[jax.Array, jax.Array]:
    return predecode_pallas(jnp.asarray(bytes_))


def nfa_transition(parent_rows, tags, req, wild, parent_1h, selfloop,
                   **kw):
    # pick bs dividing S when possible (states are padded to 128 lanes);
    # the kernel pads the state axis itself otherwise
    s = parent_rows.shape[-1]
    kw.setdefault("bs", min(512, s) if s % min(512, s) == 0 else 128)
    return nfa_transition_pallas(parent_rows, tags, req, wild, parent_1h,
                                 selfloop, **kw)


def decode_document(buf: bytes, dictionary: TagDictionary) -> EventStream:
    """Byte stream → EventStream via the predecode kernel + compaction."""
    arr = jnp.asarray(np.frombuffer(buf, dtype=np.uint8))
    kind, tag = predecode(arr)
    kind, tag = np.asarray(kind), np.asarray(tag)
    keep = kind != ref.PAD
    return EventStream(kind[keep].astype(np.int8), tag[keep])


class StreamFilterKernelEngine:
    """End-to-end engine on the stream_filter kernel (Fig 5 layout).

    Queries are packed into parent-closed state blocks; all blocks advance
    over the event stream inside one pallas_call; accept states map back
    to query ids (the output priority encoder).
    """

    def __init__(self, queries: list[Query], dictionary: TagDictionary,
                 blk: int = 256, max_depth: int = 48) -> None:
        self.tables = blocks_mod.partition(queries, dictionary, blk=blk)
        self.max_depth = max_depth
        t = self.tables
        self._dev = dict(
            in_tag=jnp.asarray(t.in_tag), wild=jnp.asarray(t.wild),
            selfloop=jnp.asarray(t.selfloop), init=jnp.asarray(t.init),
            parent_1h=jnp.asarray(t.parent_1h))
        self.n_queries = len(t.accept_block)

    def filter_document(self, ev: EventStream) -> FilterResult:
        ever, first = stream_filter_pallas(
            jnp.asarray(ev.kind.astype(np.int32)), jnp.asarray(ev.tag_id),
            self._dev["in_tag"], self._dev["wild"], self._dev["selfloop"],
            self._dev["init"], self._dev["parent_1h"],
            max_depth=self.max_depth, interpret=_interpret_default())
        ever, first = np.asarray(ever), np.asarray(first)
        t = self.tables
        matched = ever[t.accept_block, t.accept_local] > 0
        fe = first[t.accept_block, t.accept_local]
        return FilterResult(matched, np.where(matched, fe, NO_MATCH))
